"""Test-suite bootstrap: register the deterministic hypothesis shim when the
real package is not installed, so collection works on bare environments."""

import sys

try:
    import hypothesis  # noqa: F401
except ImportError:
    import _hypothesis_shim

    hyp = sys.modules["hypothesis"] = _hypothesis_shim
    sys.modules["hypothesis.strategies"] = hyp.strategies
