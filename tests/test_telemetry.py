"""Unified telemetry (DESIGN.md §15): log-bucketed histogram math,
registry thread-safety and drain semantics, CounterGroup Counter-compat,
trace spans / Chrome-trace export, the analytical cost model, Prometheus
rendering, and the structural overhead pin for the tracked kernel row."""

import json
import math
import threading

import numpy as np
import pytest

from repro.kernels import ops as kops
from repro.runtime import telemetry
from repro.runtime.telemetry import (CounterGroup, Histogram,
                                     MetricsRegistry, PimCostModel, Tracer,
                                     render_prometheus)


@pytest.fixture(autouse=True)
def _registry_leak_check():
    """Mirror tests/test_faults.py: the global registry is shared state,
    so every test starts from drained model/exec/cache counters and must
    not leave health/media counters behind for its neighbours."""
    telemetry.drain_model_counters()
    telemetry.REGISTRY.drain("pim.cache.")
    kops.drain_health()
    yield
    telemetry.drain_model_counters()
    telemetry.REGISTRY.drain("pim.cache.")
    leaked = kops.drain_health()
    assert not leaked, f"test leaked undrained HEALTH counters: {leaked}"


# ------------------------------------------------------------- histograms

def test_histogram_bucket_edges_exact():
    """Powers of 2**(1/4) are bucket edges: observing exactly [1,2,4,8]
    makes every quantile land on an edge, so p50 is exactly 2.0 (no
    interpolation error at edges)."""
    h = Histogram()
    for v in (1.0, 2.0, 4.0, 8.0):
        h.observe(v)
    assert h.percentile(0.50) == pytest.approx(2.0)
    assert h.percentile(0.0) >= 1.0
    assert h.percentile(1.0) == pytest.approx(8.0)
    s = h.summary()
    assert s["count"] == 4 and s["min"] == 1.0 and s["max"] == 8.0
    assert s["sum"] == pytest.approx(15.0)


def test_histogram_percentile_accuracy_and_monotonicity():
    """Bucket width bounds the relative error: estimates stay within the
    ~19%-wide bucket of the true quantile, and quantiles never invert."""
    rng = np.random.default_rng(0)
    vals = rng.lognormal(mean=3.0, sigma=1.5, size=5000)
    h = Histogram()
    for v in vals:
        h.observe(float(v))
    prev = 0.0
    for q in (0.1, 0.25, 0.5, 0.75, 0.9, 0.95, 0.99):
        true = float(np.quantile(vals, q))
        est = h.percentile(q)
        assert est == pytest.approx(true, rel=0.20)
        assert est >= prev        # monotone in q
        prev = est
    assert h.percentile(1.0) == pytest.approx(float(vals.max()))


def test_histogram_single_value_and_zeros():
    h = Histogram()
    h.observe(37.0)
    for q in (0.0, 0.5, 0.99, 1.0):   # clamped to the [min,max] envelope
        assert h.percentile(q) == pytest.approx(37.0)
    hz = Histogram()
    hz.observe(0.0)
    hz.observe(-1.0)
    assert hz.zeros == 2 and hz.count == 2
    assert hz.percentile(0.5) == 0.0
    empty = Histogram().summary()
    assert empty == {"count": 0, "sum": 0.0, "min": 0.0, "max": 0.0,
                     "mean": 0.0, "p50": 0.0, "p95": 0.0, "p99": 0.0}
    assert math.isnan(Histogram().percentile(0.5))


# ------------------------------------------------------------- registry

def test_registry_drain_resets_to_zero():
    reg = MetricsRegistry()
    reg.inc("a.x", 3)
    reg.inc("a.y", 2)
    reg.inc("b.z")
    assert reg.drain("a.") == {"a.x": 3, "a.y": 2}
    assert reg.drain("a.") == {}                  # drained clean
    assert reg.counter("a.x") == 0
    assert reg.drain() == {"b.z": 1}
    reg.observe("h", 5.0)
    assert reg.drain_histograms()["h"]["count"] == 1
    assert reg.summary("h") is None               # histogram drained too


def test_registry_threaded_increments_exact():
    """8 threads x 10k atomic adds through every mutation surface: the
    single registry lock must lose nothing (the historical ``HEALTH``
    Counter was unguarded; this is the regression test for its fix)."""
    reg = MetricsRegistry()
    grp = reg.group("pim.t")
    per, nthreads = 10_000, 8

    def worker():
        for _ in range(per):
            grp.add("k")
            reg.inc("raw")
            reg.observe("h", 1.0)

    ts = [threading.Thread(target=worker) for _ in range(nthreads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert grp["k"] == per * nthreads
    assert reg.counter("raw") == per * nthreads
    assert reg.summary("h")["count"] == per * nthreads


def test_counter_group_counter_compat():
    """The Counter surface the HEALTH/MEDIA call sites ride on."""
    reg = MetricsRegistry()
    g = reg.group("pim.g")
    assert not g and len(g) == 0
    g.add("hits", 2)
    g["gauge"] = 7                         # absolute set (spans_still_bad)
    g.add("hits")
    assert g["hits"] == 3 and g.get("none") == 0 and "hits" in g
    assert sorted(g.keys()) == ["gauge", "hits"] and bool(g)
    assert dict(g.items())["gauge"] == 7
    assert g.drain() == {"hits": 3, "gauge": 7}
    assert not g and g["hits"] == 0        # drain reset the view
    g.add("x")
    g.clear()
    assert len(g) == 0
    assert isinstance(g.registry, MetricsRegistry)


def test_drain_health_shim_still_counter_shaped():
    """ops.HEALTH is now a registry view; its historical drain contract
    (plain non-zero int dict, reset on read) must survive unchanged."""
    kops.HEALTH.add("retries", 2)
    kops.HEALTH.add("faults_detected")
    got = kops.drain_health()
    assert got == {"retries": 2, "faults_detected": 1}
    assert kops.drain_health() == {}


# ------------------------------------------------------------- tracer

def test_tracer_disabled_is_null_and_enabled_nests():
    tr = Tracer()
    assert tr.span("x") is telemetry._NULL_SPAN      # shared, no alloc
    tr.event("x", 0.0, 1.0)
    tr.instant("y")
    assert tr.drain() == []                          # disabled: recorded 0
    tr.enabled = True
    with tr.span("outer", cat="test", rows=4):
        with tr.span("inner", cat="test"):
            pass
    tr.instant("mark", cat="test")
    evs = tr.drain()
    assert [e["name"] for e in evs] == ["inner", "outer", "mark"]
    outer = evs[1]
    assert outer["ph"] == "X" and outer["pid"] == 1 and "tid" in outer
    assert outer["args"] == {"rows": 4}
    assert outer["dur"] >= evs[0]["dur"]             # inner nests inside
    assert evs[2]["dur"] == 0.0                      # instant
    assert tr.drain() == []                          # drained clean


def test_tracer_chrome_trace_file(tmp_path):
    tr = Tracer()
    tr.enabled = True
    with tr.span("stage", cat="pim.serve"):
        pass
    p = tmp_path / "trace.json"
    assert tr.write_chrome_trace(str(p)) == 1
    doc = json.loads(p.read_text())
    assert doc["displayTimeUnit"] == "ms"
    (ev,) = doc["traceEvents"]
    assert ev["name"] == "stage" and ev["cat"] == "pim.serve"
    assert ev["ts"] >= 0 and ev["dur"] >= 0


def test_tracer_ring_is_bounded():
    tr = Tracer(capacity=8)
    tr.enabled = True
    for i in range(20):
        tr.instant(f"e{i}")
    evs = tr.drain()
    assert len(evs) == 8 and evs[0]["name"] == "e12"  # oldest dropped


# ------------------------------------------------------------- cost model

def test_cost_model_schedule_and_program():
    from repro.core.pim_numerics import program_for
    prog = program_for("int-serial", "add", 8)
    sched = kops.program_schedule(prog)
    m = telemetry.COST_MODEL.schedule_cost(sched)
    assert m.gates == int(sched.n_gates) + int(sched.copy_gates)
    assert m.cycles == m.gates + m.init_cycles
    assert m.levels == int(sched.n_levels)
    assert m.io_bits == sum(len(c) for c in sched.ports.values())
    assert m.latency_us == pytest.approx(
        m.cycles * telemetry.PIM_DEFAULT.cycle_ns * 1e-3)
    assert m.energy_pj(10) == pytest.approx(10 * m.energy_pj_per_row)
    # gate term alone bounds energy from below
    assert m.energy_pj_per_row > m.cycles * telemetry.ENERGY_PJ["nor"]
    ms = telemetry.COST_MODEL.program_cost(prog.cost())
    assert ms.gates == prog.cost().nor_gates
    # the serial order pays every INIT; the levelized schedule folds them
    assert ms.cycles >= m.init_cycles


def test_record_dispatch_fills_model_counters():
    from repro.core.pim_numerics import program_for
    prog = program_for("int-serial", "add", 8)
    rng = np.random.default_rng(0)
    ins = {"x": rng.integers(0, 256, 16).astype(np.uint64),
           "y": rng.integers(0, 256, 16).astype(np.uint64)}
    telemetry.drain_model_counters()
    kops.run_program(prog, ins, 16, backend="ref")
    c = telemetry.drain_model_counters()
    assert c["pim.exec.dispatches"] == 1 and c["pim.exec.rows"] == 16
    m = telemetry.COST_MODEL.schedule_cost(kops.program_schedule(prog))
    assert c["pim.model.cycles"] == m.cycles
    assert c["pim.model.energy_pj"] == pytest.approx(m.energy_pj(16))
    # the numpy oracle records through the serial model, no cache entry
    n_entries = len(kops._compiled)
    kops.run_program(prog, ins, 16, backend="numpy")
    c2 = telemetry.drain_model_counters()
    assert c2["pim.exec.dispatches"] == 1
    assert c2["pim.model.cycles"] == telemetry.COST_MODEL.program_cost(
        prog.cost()).cycles
    assert len(kops._compiled) == n_entries


def test_dispatch_overhead_is_structural():
    """The <2% overhead budget on kernel/fp16_add_8k_rows, pinned
    structurally: one dispatch performs exactly one registry lock
    acquisition (one add_many) and zero tracer work when disabled --
    independent of row count and schedule size."""
    from repro.core.pim_numerics import program_for
    prog = program_for("int-serial", "add", 8)
    rng = np.random.default_rng(1)
    calls = {"add_many": 0, "observe": 0}
    orig_add_many = telemetry.REGISTRY.add_many
    orig_observe = telemetry.REGISTRY.observe

    def counting_add_many(d):
        calls["add_many"] += 1
        orig_add_many(d)

    def counting_observe(n, v):
        calls["observe"] += 1
        orig_observe(n, v)

    telemetry.REGISTRY.add_many = counting_add_many
    telemetry.REGISTRY.observe = counting_observe
    try:
        for n in (8, 64):
            ins = {"x": rng.integers(0, 256, n).astype(np.uint64),
                   "y": rng.integers(0, 256, n).astype(np.uint64)}
            before = dict(calls)
            kops.run_program(prog, ins, n, backend="ref")
            assert calls["add_many"] - before["add_many"] == 1
            assert calls["observe"] == before["observe"]
    finally:
        telemetry.REGISTRY.add_many = orig_add_many
        telemetry.REGISTRY.observe = orig_observe
    assert not telemetry.TRACER.enabled    # default: spans are one attr read


def test_compiled_cache_hit_miss_counters():
    from repro.core.pim_numerics import program_for
    prog = program_for("int-serial", "add", 9)
    rng = np.random.default_rng(2)
    ins = {"x": rng.integers(0, 512, 8).astype(np.uint64),
           "y": rng.integers(0, 512, 8).astype(np.uint64)}
    kops._compiled.pop(kops.cache_key(prog, kops.make_plan(backend="ref")),
                       None)
    telemetry.REGISTRY.drain("pim.cache.")
    kops.run_program(prog, ins, 8, backend="ref")
    kops.run_program(prog, ins, 8, backend="ref")
    c = telemetry.REGISTRY.drain("pim.cache.")
    assert c["pim.cache.misses"] == 1
    assert c.get("pim.cache.hits", 0) >= 1


# ------------------------------------------------------------- prometheus

def test_render_prometheus():
    reg = MetricsRegistry()
    reg.inc("pim.serve.requests", 5)
    reg.set_gauge("pim.serve.depth", 2.5)
    for v in (1.0, 2.0, 4.0, 8.0):
        reg.observe("pim.serve.queue_us", v)
    text = render_prometheus(reg)
    assert "# TYPE pim_serve_requests counter\npim_serve_requests 5" in text
    assert "# TYPE pim_serve_depth gauge\npim_serve_depth 2.5" in text
    assert '# TYPE pim_serve_queue_us summary' in text
    assert 'pim_serve_queue_us{quantile="0.5"} 2' in text
    assert "pim_serve_queue_us_count 4" in text
    assert "pim_serve_queue_us_sum 15" in text
    assert text.endswith("\n")
    # multiple registries concatenate
    reg2 = MetricsRegistry()
    reg2.inc("other", 1)
    both = render_prometheus(reg, reg2)
    assert "pim_serve_requests 5" in both and "other 1" in both


def test_stats_is_registry_backed():
    """Serving Stats route through a per-runtime registry: attribute
    reads/writes, atomic add and as_dict stay coherent."""
    from repro.runtime.pim_batch import Stats
    st = Stats()
    assert st.requests == 0 and st.exec_s == 0.0
    st.add("requests", 3)
    st.rows = 128
    st.exec_s = 0.5
    assert st.requests == 3 and st.rows == 128
    assert st.rows_per_s() == pytest.approx(256.0)
    d = st.as_dict()
    assert d["requests"] == 3 and d["rows"] == 128
    assert isinstance(d["requests"], int)
    with pytest.raises(AttributeError):
        st.not_a_field
