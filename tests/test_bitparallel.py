"""Bit-parallel suite: partition toolbox + Algorithms 5.1-5.3 + 6.1."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import bitparallel as bp
from repro.core import bitparallel_fp as bpf
from repro.core.floatfmt import FP16
from repro.core.partitions import (PartitionedBuilder, broadcast, pshift,
                                   reduce_tree)

_cache = {}


def _prog(key, builder):
    if key not in _cache:
        _cache[key] = builder()
    return _cache[key]


# ------------------------------------------------------------- toolbox
def test_toolbox_shift_broadcast_reduce():
    pb = PartitionedBuilder(8, 64)
    x = pb.input("x", range(8))
    s = pshift(pb, x, +2, fill=0)
    pb.output("s", s)
    bb = broadcast(pb, x[3])
    pb.output("b", bb)
    r = reduce_tree(pb, list(x), "or")
    pb.output("r", [r])
    p = pb.finish()
    o = p.exec_row({"x": 0b10110001})
    assert o["s"] == (0b10110001 << 2) & 0xFF
    assert o["b"] == 0  # bit 3 of x is 0 -> broadcast zeros
    assert o["r"] == 1
    o = p.exec_row({"x": 0b1000})
    assert o["b"] == 0xFF and o["r"] == 1


def test_toolbox_cycle_counts():
    """shift: |d|+1 cycles; broadcast/reduce: ~log2(k) (paper Fig. 6)."""
    k = 16
    pb = PartitionedBuilder(k, 64)
    x = pb.input("x", range(k))
    n0 = len(pb._steps)
    pshift(pb, x, +1, fill=None)
    assert len(pb._steps) - n0 == 2
    n0 = len(pb._steps)
    broadcast(pb, x[0])
    assert len(pb._steps) - n0 <= int(np.ceil(np.log2(k))) + 1
    n0 = len(pb._steps)
    reduce_tree(pb, list(x), "and")
    assert len(pb._steps) - n0 == int(np.log2(k))


def test_section_validator_rejects_overlap():
    pb = PartitionedBuilder(4, 64)
    x = pb.input("x", range(4))
    with pytest.raises(RuntimeError):
        with pb.cycle():
            pb.id_(x[0], p_out=2)     # spans 0..2
            pb.id_(x[1], p_out=3)     # spans 1..3 -> overlap


# ------------------------------------------------------------ arithmetic
@given(st.integers(0, 2 ** 16 - 1), st.integers(0, 2 ** 16 - 1))
@settings(max_examples=30, deadline=None)
def test_bp_add_property(x, y):
    p = _prog("add16", lambda: bp.build_bp_add(16))
    assert p.exec_row({"x": x, "y": y})["z"] == x + y


def test_bp_add_log_latency():
    """Alg 5.1 is O(log N): 32-bit adds in ~2x the cycles of 8-bit."""
    c8 = bp.build_bp_add(8).parallel_cost().abstract_steps
    c32 = bp.build_bp_add(32).parallel_cost().abstract_steps
    assert c32 < 2.2 * c8
    serial32 = 32  # FACC steps of the bit-serial adder
    assert c32 < 3 * serial32


@given(st.integers(0, 2 ** 16 - 1), st.integers(0, 2 ** 16 - 1))
@settings(max_examples=20, deadline=None)
def test_bp_mul_property(x, y):
    p = _prog("mul16", lambda: bp.build_bp_mul(16))
    assert p.exec_row({"x": x, "y": y})["z"] == x * y


@given(st.integers(1, 2 ** 16 - 1), st.integers(0, 2 ** 16 - 1), st.data())
@settings(max_examples=20, deadline=None)
def test_bp_div_property(d, q, data):
    r = data.draw(st.integers(0, d - 1))
    p = _prog("div16", lambda: bp.build_bp_div(16))
    o = p.exec_row({"z": q * d + r, "d": d})
    assert o["q"] == q and o["r"] == r


def test_bp_div_latency_beats_serial():
    """Alg 5.3 O(N log N) vs bit-serial O(N^2) (paper §5.5)."""
    from repro.core import bitserial as bs
    par = bp.build_bp_div(32, cpk=320).parallel_cost().nor_gates
    ser = bs.build_div(32).cost().nor_gates
    assert par < ser


# ---------------------------------------------------------------- 6.1/FP
@given(st.integers(0, 2 ** 16 - 1), st.integers(0, 15))
@settings(max_examples=30, deadline=None)
def test_bp_var_shift_property(x, t):
    p = _prog("vs", lambda: bpf.build_bp_var_shift(16, 4))
    assert p.exec_row({"x": x, "t": t})["z"] == x >> t


@given(st.integers(0, 2 ** 16 - 1))
@settings(max_examples=30, deadline=None)
def test_bp_var_normalize_property(x):
    p = _prog("vn", lambda: bpf.build_bp_var_normalize(16))
    o = p.exec_row({"x": x})
    if x == 0:
        assert o["z"] == 0
    else:
        lz = 16 - x.bit_length()
        assert o["t"] == lz and o["z"] == (x << lz) & 0xFFFF


def test_bp_fp16_ops():
    rng = np.random.default_rng(9)
    for op, bld in [("add", lambda: bpf.build_bp_fp_add(FP16)),
                    ("mul", lambda: bpf.build_bp_fp_mul(FP16)),
                    ("div", lambda: bpf.build_bp_fp_div(FP16))]:
        p = _prog(("fp", op), bld)
        xs = FP16.random_bits(rng, 30, emin=12, emax=18)
        ys = FP16.random_bits(rng, 30, emin=12, emax=18)
        for xb, yb in zip(xs, ys):
            try:
                want = FP16.op_exact(op, int(xb), int(yb))
            except OverflowError:
                continue
            assert p.exec_row({"x": int(xb), "y": int(yb)})["z"] == want
