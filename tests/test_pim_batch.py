"""Batched serving runtime (DESIGN.md §10): planner/coalescer/splitter
bit-exactness on randomized mixed traffic, grouping structure, the
LRU-pinned schedule working set, admission-window semantics, the scoped
ufunc config, the serving error paths, and the CLI/bench smokes."""

import io
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro import pim_ufunc as pim
from repro.kernels import ops as kops
from repro.launch import serve
from repro.runtime import pim_batch as pb

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _fp16(rng, n):
    """Normal-range fp16 values (mid exponents, per the paper's domain)."""
    return (rng.integers(10, 21, n).astype(np.uint16) << 10 |
            rng.integers(0, 1 << 10, n).astype(np.uint16)).view(np.float16)


def _mixed_traffic(rng, n_requests):
    """Randomized mixed request stream over 8 op kinds: fixed point
    (uint8/uint16, incl. div and an object-dtype wide width) + fp16."""
    kinds = []
    for i in range(n_requests):
        n = int(rng.integers(1, 40))
        k = i % 8
        if k == 0:
            kinds.append(("add", rng.integers(0, 256, n).astype(np.uint8),
                          rng.integers(0, 256, n).astype(np.uint8), {}))
        elif k == 1:
            kinds.append(("sub", rng.integers(0, 256, n).astype(np.uint8),
                          rng.integers(0, 256, n).astype(np.uint8), {}))
        elif k == 2:
            kinds.append(("mul",
                          rng.integers(0, 1 << 16, n).astype(np.uint16),
                          rng.integers(0, 1 << 16, n).astype(np.uint16), {}))
        elif k == 3:
            kinds.append(("div", rng.integers(0, 256, n).astype(np.uint8),
                          rng.integers(1, 256, n).astype(np.uint8), {}))
        elif k == 4:
            kinds.append(("fp_add", _fp16(rng, n), _fp16(rng, n), {}))
        elif k == 5:
            kinds.append(("fp_sub", _fp16(rng, n), _fp16(rng, n), {}))
        elif k == 6:
            kinds.append(("fp_mul", _fp16(rng, n), _fp16(rng, n), {}))
        else:
            # object-dtype arbitrary precision exercises the padded-io
            # (non-fused) executor path through the coalescer
            kinds.append((
                "add",
                np.array([(1 << 69) + int(v)
                          for v in rng.integers(0, 100, n)], object),
                np.array([int(v) for v in rng.integers(0, 100, n)], object),
                {"width": 70}))
    return kinds


# ------------------------------------------------------------ bit-exactness

def test_batched_equals_serial_mixed_stream():
    """Acceptance: coalesced execution equals per-request execution for
    every request of a randomized mixed stream (fixed + FP, div's (q, r)
    pair included), row for row."""
    rng = np.random.default_rng(42)
    traffic = _mixed_traffic(rng, 32)
    preps = [pim.prepare(op, x, y, **kw) for op, x, y, kw in traffic]
    rt = pb.BatchRuntime(pin_cap=8)
    try:
        results = rt.execute(preps)
        assert len(results) == len(traffic)
        for (op, x, y, kw), res in zip(traffic, results):
            want = getattr(pim, op)(x, y, **kw)     # independent serial run
            if op == "div":
                assert np.array_equal(res.value[0], want[0])
                assert np.array_equal(res.value[1], want[1])
            else:
                assert np.array_equal(res.value, want), op
        # accounting invariants
        assert rt.stats.requests == len(traffic)
        assert rt.stats.rows == sum(p.n_rows for p in preps)
        assert rt.stats.groups == len({pb.group_key(p) for p in preps})
        for res in results:
            assert res.group_size >= 1 and res.group_rows <= rt.stats.rows
    finally:
        rt.close()
    assert len(rt.pins) == 0


def test_empty_and_single_request_batches():
    rt = pb.BatchRuntime(pin_cap=2)
    try:
        assert rt.execute([]) == []
        p = pim.prepare("add", np.uint8([7]), np.uint8([8]))
        (res,) = rt.execute([p])
        assert np.array_equal(res.value, [15])
        assert res.group_size == 1 and res.group_rows == 1
    finally:
        rt.close()


# ----------------------------------------------------------------- planner

def test_plan_groups_structure_and_order():
    a1 = pim.prepare("add", np.uint8([1, 2]), np.uint8([3, 4]))
    b1 = pim.prepare("mul", np.uint8([5]), np.uint8([6]))
    a2 = pim.prepare("add", np.uint8([7, 8, 9]), np.uint8([1, 1, 1]))
    c1 = pim.prepare("add", np.uint16([1]), np.uint16([2]))  # other width
    plan = pb.plan_groups([a1, b1, a2, c1])
    assert [g.members for g in plan] == [[0, 2], [1], [3]]
    assert plan[0].n_rows == 5 and plan[1].n_rows == 1
    # coalesced rows keep arrival order per port
    ins = pb.coalesce(plan[0])
    assert np.array_equal(ins["x"], [1, 2, 7, 8, 9])
    assert np.array_equal(ins["y"], [3, 4, 1, 1, 1])


def test_group_key_separates_exec_config():
    x, y = np.uint8([1]), np.uint8([2])
    base = pim.prepare("add", x, y)
    dense = pim.prepare("add", x, y, schedule="dense")
    numpy_be = pim.prepare("add", x, y, backend="numpy")
    assert base.key == dense.key == numpy_be.key        # same structure
    plan = pb.plan_groups([base, dense, numpy_be])
    assert len(plan) == 3                               # but never merged


# ------------------------------------------------------ group-execute entry

def test_run_program_groups_matches_run_program():
    """ops-level group executor: heterogeneous groups (incl. one larger
    than chunk_rows, so it tiles inside the pipeline, and one on the
    synchronous numpy oracle) must match one-shot run_program."""
    from repro.core import bitserial as bs

    rng = np.random.default_rng(5)
    p16, p8 = bs.build_add(16), bs.build_mul(8)
    x = rng.integers(0, 1 << 16, 100).astype(np.uint64)
    y = rng.integers(0, 1 << 16, 100).astype(np.uint64)
    u = rng.integers(0, 256, 7).astype(np.uint64)
    v = rng.integers(0, 256, 7).astype(np.uint64)
    outs = kops.run_program_groups([
        dict(program=p16, inputs={"x": x, "y": y}, n_rows=100,
             chunk_rows=32),                            # 4 chunks
        dict(program=p8, inputs={"x": u, "y": v}, n_rows=7),
        dict(program=p16, inputs={"x": x[:3], "y": y[:3]}, n_rows=3,
             backend="numpy"),                          # sync barrier
    ])
    assert np.array_equal(outs[0]["z"],
                          kops.run_program(p16, {"x": x, "y": y}, 100,
                                           backend="ref")["z"])
    assert np.array_equal(outs[1]["z"], u * v)
    assert np.array_equal(outs[2]["z"], (x[:3] + y[:3]))
    with pytest.raises(ValueError, match="rows"):
        kops.run_program_groups([
            dict(program=p16, inputs={"x": x[:5], "y": y}, n_rows=100)])


# --------------------------------------------------------------- pin cache

def _mini_program(seed, n_gates=12):
    from repro.core.gates import Builder

    rng = np.random.default_rng(seed)
    b = Builder()
    avail = b.input("x", 16) + b.input("y", 16)
    fns = [b.nor, b.or_, b.and_, b.xor, b.xnor, b.nand]
    for _ in range(n_gates):
        f = fns[rng.integers(0, len(fns))]
        i, j = rng.integers(0, len(avail), 2)
        avail.append(f(avail[i], avail[j]))
    b.output("z", avail[-16:])
    return b.finish()


def test_pinned_working_set_survives_cache_churn():
    """A pinned hot program must keep its compiled entry while cold
    traffic churns the bounded LRU; the pin cache's own overflow unpins."""
    hot = _mini_program(1)
    rng = np.random.default_rng(0)
    ins = {"x": rng.integers(0, 1 << 16, 33).astype(np.uint64),
           "y": rng.integers(0, 1 << 16, 33).astype(np.uint64)}
    want = kops.run_program(hot, ins, 33, backend="numpy")["z"]
    old_cap = kops.set_compiled_cache_cap(2)
    pins = pb.PinnedSchedules(cap=1)
    try:
        kops.run_program(hot, ins, 33, backend="ref")
        key = pins.touch(hot)
        assert key in kops._compiled and key in kops._pinned
        for s in range(4):                       # churn with cold programs
            kops.run_program(_mini_program(50 + s), ins, 33, backend="ref")
        assert key in kops._compiled             # survived eviction
        assert kops.is_compiled(hot)
        assert len(kops._compiled) <= 2 + 1      # cap + the pinned entry
        # pin LRU overflow unpins the older program
        other = _mini_program(2)
        kops.run_program(other, ins, 33, backend="ref")
        pins.touch(other)
        assert key not in kops._pinned and len(pins) == 1
        # eviction was invisible: recompilation is pure
        got = kops.run_program(hot, ins, 33, backend="ref")["z"]
        assert np.array_equal(got, want)
    finally:
        pins.clear()
        kops.set_compiled_cache_cap(old_cap)
    assert not kops._pinned


def test_pin_refcounts_nest():
    prog = _mini_program(3)
    key = kops.pin_program(prog)
    assert kops.pin_program(prog) == key
    assert kops.unpin_program(key) is True       # one pin remains
    assert key in kops._pinned
    assert kops.unpin_program(key) is False
    assert key not in kops._pinned
    pins = pb.PinnedSchedules(cap=0)             # disabled
    assert pins.touch(prog) is None and len(pins) == 0


# ---------------------------------------------------------- admission queue

def test_batch_queue_row_cap_and_eof():
    q = pb.BatchQueue(window_ms=200, max_batch_rows=100)
    for i in range(5):
        q.put(i, n_rows=30)
    q.close()
    # 30+30+30 < 100 admits a 4th (crossing request never splits), then
    # stops; the 5th lands in the next batch; then end-of-stream
    assert q.collect() == [0, 1, 2, 3]
    assert q.collect() == [4]
    assert q.collect() is None
    assert q.collect() is None                   # stays closed


def test_batch_queue_zero_window_drains_backlog():
    q = pb.BatchQueue(window_ms=0, max_batch_rows=1 << 30)
    for i in range(3):
        q.put(i, n_rows=1)
    assert q.collect() == [0, 1, 2]              # whatever is queued
    q.close()
    assert q.collect() is None
    with pytest.raises(ValueError):
        pb.BatchQueue(max_batch_rows=0)


# ------------------------------------------------------------ scoped config

def test_options_scopes_and_restores():
    assert pim.config.schedule == "slots"
    with pim.options(schedule="dense", backend="numpy") as cfg:
        assert cfg is pim.config
        assert pim.config.schedule == "dense"
        assert pim.prepare("add", np.uint8([1]), np.uint8([2])).schedule \
            == "dense"
    assert pim.config.schedule == "slots" and pim.config.backend == "ref"
    with pytest.raises(ValueError):              # restored on exception
        with pim.options(schedule="dense"):
            raise ValueError("boom")
    assert pim.config.schedule == "slots"
    with pytest.raises(TypeError):               # validated before applied
        with pim.options(schedule="dense", bogus=1):
            pass
    assert pim.config.schedule == "slots"


def test_configure_validates_atomically():
    with pytest.raises(TypeError):
        pim.configure(backend="numpy", not_a_field=1)
    assert pim.config.backend == "ref"           # nothing was applied


# ---------------------------------------------------------- prepared handle

def test_prepared_handle_api():
    x, y = np.uint16([9, 7]), np.uint16([4, 2])
    p = pim.prepare("add", x, y)
    assert p.op == "add" and p.n_rows == 2
    assert np.array_equal(p.run(), pim.add(x, y))
    outs = kops.run_program(p.program, p.inputs, p.n_rows, backend="ref")
    assert np.array_equal(p.finish(outs), pim.add(x, y))
    q, r = pim.prepare("div", x, y).run()
    assert np.array_equal(q, [2, 3]) and np.array_equal(r, [1, 1])
    with pytest.raises(ValueError):
        pim.prepare("nope", x, y)
    with pytest.raises(TypeError):
        pim.prepare("add", x, y, fmt="bf16")     # fixed point takes no fmt
    with pytest.raises(TypeError):
        pim.prepare("fp_add", np.float16([1]), np.float16([1]), width=8)


def test_prepared_cached_flag_lifecycle():
    # width 29 is used nowhere else in the suite -> first sight uncached
    xo = np.array([123], object)
    yo = np.array([456], object)
    p = pim.prepare("add", xo, yo, width=29)
    assert not p.cached
    p.warm()
    assert p.cached
    assert pim.prepare("add", xo, yo, width=29).cached
    assert np.array_equal(p.run(), [579])


# -------------------------------------------------------- serve error paths

_BAD_LINES = ('{"op":"add","dtype":"uint8","x":[1,2],"y":[3,4]}\n'
              '\n'                                        # blank: skipped
              'not json at all\n'
              '{"op":"nope","x":[1],"y":[1]}\n'
              '{"op":"fp_add","dtype":"uint16","x":[1],"y":[2]}\n'
              '{"op":"add","dtype":"float16","x":[1.0],"y":[2.0]}\n'
              '{"op":"div","dtype":"uint8","x":[1],"y":[0]}\n'
              '{"x":[1],"y":[2]}\n'                       # missing op
              '{"op":"div","dtype":"uint8","x":[17],"y":[5]}\n')


def _check_protocol_responses(lines):
    assert lines[0]["result"] == [4, 6]
    # structured error taxonomy (DESIGN.md §12): {"code","message",
    # "retriable"}; request-shape failures are never retriable
    assert lines[1]["error"]["code"] == "bad_json"
    assert "JSONDecodeError" in lines[1]["error"]["message"]
    assert lines[1]["error"]["retriable"] is False
    assert lines[2]["error"]["code"] == "bad_request"
    assert "unknown op" in lines[2]["error"]["message"]
    assert lines[2]["error"]["retriable"] is False
    assert "float16/float32" in lines[3]["error"]["message"]  # fp op, int dt
    assert "infer width" in lines[4]["error"]["message"]  # int op, fp dtype
    assert "zero divisor" in lines[5]["error"]["message"]
    assert "KeyError" in lines[6]["error"]["message"]
    assert all(lines[i]["error"]["code"] == "bad_request"
               for i in range(3, 7))
    assert (lines[7]["q"], lines[7]["r"]) == ([3], [2])


def test_serve_stdin_error_paths():
    outp = io.StringIO()
    served = serve.serve_pim_stdin(io.StringIO(_BAD_LINES), outp)
    lines = [json.loads(l) for l in outp.getvalue().splitlines()]
    assert served == 8 and len(lines) == 8                # blank skipped
    _check_protocol_responses(lines)
    ok = lines[0]
    assert ok["rows"] == 2 and "us" in ok and "cached" in ok


def test_serve_batched_matches_stdin_protocol():
    """The batched loop speaks the same protocol: same results and same
    error lines, in input order, plus batch accounting fields."""
    outp = io.StringIO()
    stats = serve.serve_pim_batched(io.StringIO(_BAD_LINES), outp,
                                    window_ms=25, stats=False)
    lines = [json.loads(l) for l in outp.getvalue().splitlines()]
    assert stats["served"] == 8 and len(lines) == 8
    _check_protocol_responses(lines)
    assert stats["errors"] == 6
    for resp in (lines[0], lines[7]):
        assert resp["batched"] >= 1
        assert {"us", "queue_us", "exec_us", "cached"} <= set(resp)


def test_serve_batched_coalesces_same_program():
    reqs = "".join('{"op":"add","dtype":"uint8","x":[%d],"y":[%d]}\n'
                   % (i, i + 1) for i in range(6))
    outp = io.StringIO()
    stats = serve.serve_pim_batched(io.StringIO(reqs), outp, window_ms=50,
                                    stats=False)
    lines = [json.loads(l) for l in outp.getvalue().splitlines()]
    assert [l["result"] for l in lines] == [[2 * i + 1] for i in range(6)]
    # all six share one program structure -> one group per batch
    assert stats["groups"] == stats["batches"]
    assert any(l["batched"] > 1 for l in lines)


def test_pim_request_reports_compile_separately():
    r1 = serve.pim_request({"op": "add", "width": 27, "x": [5], "y": [9]})
    assert r1["result"] == [14]
    r2 = serve.pim_request({"op": "add", "width": 27, "x": [6], "y": [9]})
    assert r2["cached"] is True and "compile_us" not in r2
    # the cold-call compile cost, when it happens, is reported separately
    # (width 27 may have been compiled by an earlier test run in-process,
    # so only the invariant is asserted, not r1's flag itself)
    if not r1["cached"]:
        assert r1["compile_us"] > 0


# ------------------------------------------------------------------ smokes

def _env():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    return env


def test_serve_batched_cli_roundtrip():
    """--pim-serve subprocess round-trip: small rows, strict timeout; a
    serving regression fails tests, not just benchmarks."""
    reqs = ('{"op":"add","dtype":"uint8","x":[1,2],"y":[3,4]}\n'
            '{"op":"div","dtype":"uint8","x":[17],"y":[5]}\n'
            'broken\n'
            '{"op":"add","dtype":"uint8","x":[9],"y":[9]}\n')
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--pim-serve",
         "--pim-window-ms", "25", "--pim-max-batch-rows", "4096"],
        input=reqs, cwd=REPO, env=_env(), capture_output=True, text=True,
        timeout=420)
    assert proc.returncode == 0, proc.stderr[-2000:]
    lines = [json.loads(l) for l in proc.stdout.splitlines()]
    assert len(lines) == 4
    assert lines[0]["result"] == [4, 6]
    assert (lines[1]["q"], lines[1]["r"]) == ([3], [2])
    assert "error" in lines[2]
    assert lines[3]["result"] == [18]
    assert "pim-serve:" in proc.stderr                    # the stats line


def test_bench_serve_rows_and_compare_gate_smoke(tmp_path):
    """The mixed-traffic rows emit in --json format and the --compare
    BENCH_3.json invocation passes (serve/ rows are new there; the loose
    threshold keeps this a machinery smoke, not a timing assertion --
    BENCH_4.json records the real figures)."""
    out = tmp_path / "serve.json"
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--only", "serve/mixed",
         "--json", str(out), "--compare",
         os.path.join(REPO, "BENCH_3.json"), "--threshold", "100"],
        cwd=REPO, env=_env(), capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stdout + proc.stderr[-2000:]
    assert "perf gate: OK" in proc.stdout
    rows = {r["name"]: r for r in json.loads(out.read_text())["rows"]}
    serial = rows["serve/mixed_8op_serial"]
    batched = rows["serve/mixed_8op_batched"]
    assert serial["rows_per_s"] > 0 and batched["rows_per_s"] > 0
    # the acceptance bar is 2x (recorded in BENCH_4.json); the in-test
    # bar is looser so a loaded CI host cannot flake it
    assert batched["rows_per_s"] > 1.2 * serial["rows_per_s"]
    assert batched["speedup_vs_serial"] >= 1.2


# ----------------------------------------- fault tolerance & admission
# (DESIGN.md §12: backpressure, deadlines, degradation, error taxonomy)

from repro.runtime.faults import FaultModel  # noqa: E402


def _drive(lines, **kw):
    """Run serve_pim_batched over a canned request list; returns (parsed
    responses in order, the summary dict)."""
    text = "\n".join(json.dumps(l) if isinstance(l, dict) else l
                     for l in lines) + "\n"
    outp = io.StringIO()
    kw.setdefault("window_ms", 0)
    info = serve.serve_pim_batched(io.StringIO(text), outp, stats=False,
                                   **kw)
    return [json.loads(s) for s in outp.getvalue().splitlines()], info


def test_batch_queue_backpressure_offer():
    q = pb.BatchQueue(window_ms=0, max_queue_rows=16)
    assert q.offer("a", 8) and q.offer("b", 8)
    assert not q.offer("c", 8)              # 16 pending + 8 > 16
    assert q.collect() == ["a", "b"]        # draining frees the backlog
    assert q.offer("big", 100)              # oversized admits on empty queue
    q.close()
    assert q.collect() == ["big"] and q.collect() is None


def test_classify_error_taxonomy():
    from repro.runtime.faults import DeadlineExceeded, FaultError
    assert pb.classify_error(ValueError("x"))["error"] == {
        "code": "bad_request", "message": "ValueError: x",
        "retriable": False}
    assert pb.classify_error(DeadlineExceeded("x"))["error"]["code"] == \
        "deadline_exceeded"
    assert pb.classify_error(FaultError("x"))["error"] == {
        "code": "exec_failed", "message": "FaultError: x",
        "retriable": True}
    assert pb.classify_error(RuntimeError("x"))["error"]["retriable"]


def test_serve_backpressure_flood_no_deadlock_no_loss():
    """Flood far past the admission cap: every request gets exactly one
    response (nothing lost, nothing deadlocked), rejections are structured
    retriable 'overloaded' errors, and admitted requests stay correct."""
    reqs = [{"op": "add", "dtype": "uint16",
             "x": list(range(8)), "y": list(range(8))} for _ in range(40)]
    rs, info = _drive(reqs, max_queue_rows=16)
    assert len(rs) == 40 and info["served"] == 40
    ok = [r for r in rs if "result" in r]
    rej = [r for r in rs if "error" in r]
    assert len(ok) + len(rej) == 40 and len(ok) >= 1 and len(rej) >= 1
    assert all(r["error"]["code"] == "overloaded" and
               r["error"]["retriable"] is True for r in rej)
    assert all(r["result"] == [2 * i for i in range(8)] for r in ok)
    assert info["rejected"] == len(rej)


def test_serve_deadline_default_and_per_request():
    # server-wide deadline_ms=0: everything expires at dequeue
    rs, info = _drive([{"op": "add", "dtype": "uint8", "x": [1], "y": [2]}],
                      deadline_ms=0.0)
    assert rs[0]["error"]["code"] == "deadline_exceeded"
    assert rs[0]["error"]["retriable"] is True and info["expired"] == 1
    # per-request deadline_ms overrides the server default
    rs, info = _drive([{"op": "add", "dtype": "uint8", "x": [1], "y": [2],
                        "deadline_ms": 60000}], deadline_ms=0.0)
    assert rs[0]["result"] == [3] and info["expired"] == 0


def test_partial_group_failure_degrades_not_batch():
    """One poisoned member of a group: the healthy member of the SAME
    group and every other group still serve bit-exactly; only the
    poisoned request errors (PR 4's fallback, now chunk-of-blast-radius
    = one request)."""
    x = np.arange(50, dtype=np.uint16)
    y = x[::-1].copy()
    good = pim.prepare("add", x, y)
    bad = pim.prepare("add", x, y)
    bad.inputs["y"] = np.array(["nope"] * 50, dtype=object)  # unpackable
    other = pim.prepare("mul", x[:8], y[:8])
    rt = pb.BatchRuntime(pin_cap=4)
    rs = rt.execute([good, bad, other])
    assert not rs[2].degraded and np.array_equal(
        rs[2].value, x[:8].astype(np.uint64) * y[:8])
    assert rs[0].degraded and rs[0].error is None
    assert np.array_equal(rs[0].value, x.astype(np.uint64) + y)
    assert rs[1].degraded and rs[1].error["code"] == "bad_request"
    assert rt.stats.degraded_groups == 1
    rt.close()


def test_group_exec_failure_degrades_per_request():
    """A group whose verified execution exhausts retries (hard fault
    rate) degrades; the other group in the batch is untouched."""
    x = np.arange(30, dtype=np.uint16)
    y = (x * 5).astype(np.uint16)
    with pim.options(faults=FaultModel(seed=2, p_flip=1.0), verify=True):
        doomed = pim.prepare("add", x[:10], y[:10])
    rt = pb.BatchRuntime(pin_cap=4)
    rs = rt.execute([pim.prepare("add", x, y), doomed])
    assert rs[0].error is None and np.array_equal(
        rs[0].value, x.astype(np.uint64) + y)
    assert rs[1].degraded and rs[1].error["code"] == "exec_failed"
    assert rs[1].error["retriable"] is True
    rt.close()
    kops.drain_health()


def test_verified_faulty_serving_bit_exact_with_health():
    with pim.options(faults=FaultModel(seed=4, force_flips=((0, 3),)),
                     verify=True):
        rs, info = _drive([{"op": "add", "dtype": "uint16",
                            "x": [10, 20], "y": [30, 40]}])
    assert rs[0]["result"] == [40, 60]
    assert rs[0]["health"]["faults_corrected"] >= 1
    assert info["faults_corrected"] >= 1 and info["retries"] >= 1


def test_reader_thread_error_mid_stream_keeps_serving(monkeypatch):
    """A reader-side crash on one line becomes a structured 'internal'
    response; later lines still serve."""
    real = serve._pim_prepare_request

    def flaky(req):
        if req.get("op") == "crashme":
            raise RuntimeError("reader exploded")
        return real(req)

    monkeypatch.setattr(serve, "_pim_prepare_request", flaky)
    rs, info = _drive([{"op": "crashme", "x": [1], "y": [1]},
                       {"op": "add", "dtype": "uint8", "x": [2], "y": [3]}])
    assert rs[0]["error"]["code"] == "internal"
    assert rs[0]["error"]["retriable"] is True
    assert rs[1]["result"] == [5]


def test_eof_mid_stream_answers_admitted_requests():
    """The input stream dying mid-iteration (reader-thread exception)
    still answers everything admitted before the death -- the queue is
    closed in the reader's finally, so the main loop drains and exits
    instead of deadlocking."""
    class DyingStream:
        def __iter__(self):
            yield '{"op":"add","dtype":"uint8","x":[1],"y":[2]}\n'
            yield '{"op":"mul","dtype":"uint8","x":[3],"y":[4]}\n'
            raise OSError("stream torn down")

    outp = io.StringIO()
    info = serve.serve_pim_batched(DyingStream(), outp, window_ms=25,
                                   stats=False)
    rs = [json.loads(s) for s in outp.getvalue().splitlines()]
    assert info["served"] == 2
    assert rs[0]["result"] == [3] and rs[1]["result"] == [12]


def test_serve_heartbeat_and_straggler_counters(tmp_path):
    hb = tmp_path / "HEARTBEAT"
    rs, info = _drive([{"op": "add", "dtype": "uint8", "x": [1], "y": [2]}],
                      heartbeat=str(hb))
    assert rs[0]["result"] == [3]
    assert hb.exists() and hb.read_text().split()[0].isdigit()
    assert info["stragglers"] == 0          # single batch cannot spike


def test_serve_faulty_cli_smoke():
    """--pim-serve subprocess under a nonzero fault rate with verified
    execution: responses stay bit-exact (the whole point of DESIGN §12),
    and the stats line carries the health counters."""
    reqs = ('{"op":"add","dtype":"uint16","x":[100,200],"y":[55,45]}\n'
            '{"op":"mul","dtype":"uint8","x":[12],"y":[12]}\n')
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--pim-serve",
         "--pim-window-ms", "25", "--pim-verify",
         "--pim-fault-flip", "2e-4", "--pim-fault-seed", "7"],
        input=reqs, cwd=REPO, env=_env(), capture_output=True, text=True,
        timeout=420)
    assert proc.returncode == 0, proc.stderr[-2000:]
    lines = [json.loads(l) for l in proc.stdout.splitlines()]
    assert lines[0]["result"] == [155, 245]
    assert lines[1]["result"] == [144]
    assert "pim-serve:" in proc.stderr and "faults=" in proc.stderr


# -------------------------------------- circuit breakers (DESIGN.md §14)

import time  # noqa: E402

from repro.runtime.faults import FaultError, VerifyPolicy  # noqa: E402


def test_breaker_policy_validation():
    for bad in (dict(window=0), dict(trip_failures=0), dict(probes=0),
                dict(cooldown_s=-1.0)):
        with pytest.raises(ValueError):
            pb.BreakerPolicy(**bad)


def test_breaker_state_machine():
    """closed -> trip -> shed -> (cooldown) half-open probes -> close,
    and a failed probe re-trips; stale non-probe outcomes are ignored."""
    pol = pb.BreakerPolicy(window=8, trip_failures=3, cooldown_s=0.05,
                           probes=2)
    br = pb.CircuitBreaker(pol)
    t = 0.0
    assert br.admit(t) == "run"
    assert br.record(False, t) is None
    assert br.record(False, t) is None
    assert br.record(False, t) == "trip" and br.state == "open"
    assert br.admit(t + 0.01) == "shed"             # still cooling down
    assert br.record(False, t + 0.02) is None       # stale, ignored
    assert br.admit(t + 0.06) == "probe"
    assert br.admit(t + 0.06) == "probe"
    assert br.admit(t + 0.06) == "shed"             # probe budget spent
    assert br.record(True, t + 0.07, probe=True) is None
    assert br.record(True, t + 0.07, probe=True) == "close"
    assert br.state == "closed"
    # window slides: 2 old failures + 1 success + 2 fresh failures < 3
    # failures only if the window dropped the old ones
    for _ in range(3):
        br.record(False, t)
    assert br.state == "open"
    assert br.admit(t + 0.1) == "probe"
    assert br.record(False, t + 0.1, probe=True) == "trip"  # probe fails
    assert br.state == "open"


def test_classify_error_fault_context():
    """FaultError's structured context rides into the wire-format error
    body under "fault" (absent for a bare FaultError)."""
    e = FaultError("exhausted", program_key="ab12ef", attempts=3,
                   remapped_base=None)
    body = pb.classify_error(e)["error"]
    assert body["code"] == "exec_failed" and body["retriable"]
    assert body["fault"] == {"program_key": "ab12ef", "attempts": 3}
    assert "fault" not in pb.classify_error(FaultError("x"))["error"]


def _doomed_prep(x, y, faulty=True):
    """A Prepared whose primary-path execution always fails (p_flip=1.0
    with one retry) -- or its healthy same-family counterpart."""
    doom = FaultModel(seed=3, p_flip=1.0)
    vp = VerifyPolicy(max_retries=1, remap_after=99, backoff_s=1e-6)
    with pim.options(backend="ref", faults=doom if faulty else None,
                     verify=vp if faulty else None):
        return pim.prepare("add", x, y)


def test_breaker_trips_sheds_and_recovers():
    """Sustained retriable failures trip the family's breaker; tripped
    traffic is shed to the numpy oracle (correct, degraded, never lost);
    after the cooldown a probe on the primary path closes it again."""
    rt = pb.BatchRuntime(breaker=pb.BreakerPolicy(window=8, trip_failures=3,
                                                  cooldown_s=0.05, probes=1))
    x = np.arange(64, dtype=np.uint16)
    y = x[::-1].copy()
    want = (x.astype(np.uint32) + y) & 0xFFFF
    for _ in range(3):
        r = rt.execute([_doomed_prep(x, y)])[0]
        assert r.error is not None and r.error["code"] == "exec_failed"
        assert r.error["fault"]["attempts"] >= 1
    assert rt.stats.breaker_trips == 1
    fam = _doomed_prep(x, y).key
    assert rt.breakers[fam].state == "open"
    # shed phase: same family served on the oracle -- bit-exact, flagged
    r = rt.execute([_doomed_prep(x, y)])[0]
    assert r.error is None and r.shed and r.degraded
    assert np.array_equal(np.asarray(r.value, dtype=np.uint32), want)
    assert rt.stats.shed_requests == 1
    # recovery: post-cooldown probe on a healthy plan (same program
    # family -- the family key is plan-independent) closes the breaker
    time.sleep(0.06)
    r = rt.execute([_doomed_prep(x, y, faulty=False)])[0]
    assert r.error is None and not r.shed
    assert np.array_equal(np.asarray(r.value, dtype=np.uint32), want)
    assert rt.stats.breaker_probes == 1 and rt.stats.breaker_closes == 1
    assert rt.breakers[fam].state == "closed"
    rt.close()
    kops.drain_health()


def test_record_expired_feeds_breaker():
    rt = pb.BatchRuntime(breaker=pb.BreakerPolicy(trip_failures=2,
                                                  cooldown_s=9.0))
    x = np.arange(8, dtype=np.uint8)
    p = _doomed_prep(x, x, faulty=False)
    rt.record_expired(p)
    rt.record_expired(p)
    assert rt.stats.breaker_trips == 1
    assert rt.breakers[p.key].state == "open"
    # tripped family sheds immediately -- and still answers correctly
    r = rt.execute([_doomed_prep(x, x, faulty=False)])[0]
    assert r.shed and r.error is None
    assert np.array_equal(np.asarray(r.value, dtype=np.uint16),
                          (x.astype(np.uint16) + x) & 0xFF)
    rt.close()


def test_breaker_disabled_never_sheds():
    rt = pb.BatchRuntime(breaker=None)
    x = np.arange(32, dtype=np.uint16)
    for _ in range(6):
        r = rt.execute([_doomed_prep(x, x)])[0]
        assert r.error is not None and not r.shed
    assert not rt.breakers and rt.stats.shed_requests == 0
    rt.record_expired(_doomed_prep(x, x, faulty=False))   # no-op
    assert not rt.breakers
    rt.close()
    kops.drain_health()


def test_serve_breaker_cli_smoke():
    """--pim-serve subprocess: a program family whose requests keep dying
    (deadline expiry in the queue) trips its circuit breaker; traffic then
    degrades to the shed path without request loss, and after the cooldown
    a half-open probe on the primary path closes the breaker again."""
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.serve", "--pim-serve",
         "--pim-window-ms", "5", "--pim-breaker-failures", "2",
         "--pim-breaker-cooldown-ms", "400", "--pim-breaker-probes", "1"],
        cwd=REPO, env=_env(), stdin=subprocess.PIPE, stdout=subprocess.PIPE,
        stderr=subprocess.PIPE, text=True, bufsize=1)
    try:
        def send(line):
            proc.stdin.write(line + "\n")
            proc.stdin.flush()
            return json.loads(proc.stdout.readline())

        # trip: two dead-on-arrival requests of one family
        doomed = ('{"op":"add","dtype":"uint8","x":[1],"y":[2],'
                  '"deadline_ms":0}')
        for _ in range(2):
            r = send(doomed)
            assert r["error"]["code"] == "deadline_exceeded", r
        # shed: the family is open -> served degraded, still bit-exact
        r = send('{"op":"add","dtype":"uint8","x":[20],"y":[22]}')
        assert r["result"] == [42], r
        assert r.get("shed") and r.get("degraded"), r
        # recover: past the cooldown, a probe runs the primary path
        time.sleep(0.9)
        r = send('{"op":"add","dtype":"uint8","x":[5],"y":[6]}')
        assert r["result"] == [11] and "shed" not in r, r
        _, err = proc.communicate(timeout=420)      # EOF + drain stderr
    finally:
        proc.kill()
    assert proc.returncode == 0, err[-2000:]
    assert "breaker=1/1/1" in err and "shed=1" in err, err[-2000:]
