"""Per-architecture smoke tests: reduced same-family configs, one forward +
train step on CPU, finite outputs, decode-vs-forward consistency."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS
from repro.models import model as M
from repro.optim import adamw

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=32):
    batch = {}
    if cfg.frontend == "audio":
        batch["frames"] = jnp.ones((b, s, cfg.frontend_dim), jnp.float32)
    else:
        batch["tokens"] = jnp.zeros((b, s), jnp.int32)
    if cfg.frontend == "vision":
        batch["vision"] = jnp.ones((b, cfg.vision_seq, cfg.frontend_dim),
                                   jnp.float32)
    batch["labels"] = jnp.zeros((b, s), jnp.int32)
    return batch


@pytest.mark.parametrize("name", list(ARCHS))
def test_smoke_forward_and_train_step(name):
    cfg = ARCHS[name].reduced()
    params = M.init_model(cfg, KEY)
    batch = _batch(cfg)
    logits, aux = M.forward(cfg, params, batch)
    assert logits.shape == (2, 32, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    # one SGD-ish step: grads exist and are finite
    loss, grads = jax.value_and_grad(
        lambda p: M.loss_fn(cfg, p, batch)[0])(params)
    assert jnp.isfinite(loss)
    gn = adamw.global_norm(grads)
    assert jnp.isfinite(gn) and gn > 0


@pytest.mark.parametrize("name", ["qwen3-8b", "recurrentgemma-2b",
                                  "rwkv6-1.6b", "deepseek-v2-236b",
                                  "qwen3-moe-235b-a22b",
                                  "llama-3.2-vision-90b"])
def test_decode_matches_forward(name):
    cfg = ARCHS[name].reduced()
    if cfg.moe:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=100.0))
    params = M.init_model(cfg, KEY)
    B, T = 2, 8
    toks = jax.random.randint(KEY, (B, T), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    vis = None
    if cfg.frontend == "vision":
        vis = jax.random.normal(KEY, (B, cfg.vision_seq, cfg.frontend_dim))
        batch["vision"] = vis
    full, _ = M.forward(cfg, params, batch, remat=False)
    caches = M.init_caches(cfg, B, 16)
    errs = []
    for t in range(T):
        lg, caches = M.decode_step(cfg, params, caches, toks[:, t],
                                   jnp.int32(t), vision=vis)
        errs.append(float(jnp.max(jnp.abs(
            lg.astype(jnp.float32) - full[:, t].astype(jnp.float32)))))
    assert max(errs) < 0.2, errs


def test_prefill_then_decode_continues():
    cfg = ARCHS["qwen3-8b"].reduced()
    params = M.init_model(cfg, KEY)
    B, S = 2, 16
    toks = jax.random.randint(KEY, (B, S + 4), 0, cfg.vocab)
    full, _ = M.forward(cfg, params, {"tokens": toks}, remat=False)
    logits, caches = M.prefill(cfg, params, {"tokens": toks[:, :S]})
    np.testing.assert_allclose(np.asarray(logits, np.float32),
                               np.asarray(full[:, S - 1], np.float32),
                               atol=0.05)
    # pad prefill caches out to S+4 and continue decoding
    def grow(path, c):
        if c.ndim >= 2 and c.shape[-2 if False else 1] == S and c.ndim >= 3:
            pad = [(0, 0)] * c.ndim
            pad[1] = (0, 4)
            return jnp.pad(c, pad)
        return c
    # only dense attention caches have a seq axis at dim 1 (after group dim
    # they are stacked: [G, B, S, ...])
    def grow_stacked(c):
        if c.ndim >= 4 and c.shape[2] == S:
            pad = [(0, 0)] * c.ndim
            pad[2] = (0, 4)
            return jnp.pad(c, pad)
        return c
    caches = {"prefix": caches["prefix"],
              "groups": jax.tree.map(grow_stacked, caches["groups"])}
    errs = []
    for t in range(S, S + 4):
        lg, caches = M.decode_step(cfg, params, caches, toks[:, t],
                                   jnp.int32(t))
        errs.append(float(jnp.max(jnp.abs(
            lg.astype(jnp.float32) - full[:, t].astype(jnp.float32)))))
    assert max(errs) < 0.1, errs


def test_param_counts_match_config_math():
    for name in ("qwen3-8b", "deepseek-v2-236b", "qwen3-moe-235b-a22b"):
        cfg = ARCHS[name]
        expected = {"qwen3-8b": 8.2e9, "deepseek-v2-236b": 236e9,
                    "qwen3-moe-235b-a22b": 235e9}[name]
        assert abs(cfg.n_params - expected) / expected < 0.06, \
            (name, cfg.n_params)


def test_reduced_param_tree_counts():
    cfg = ARCHS["qwen3-8b"].reduced()
    params = M.init_model(cfg, KEY)
    actual = sum(x.size for x in jax.tree.leaves(params))
    assert abs(actual - cfg.n_params) / cfg.n_params < 0.1
