"""Ufunc frontend + streaming/sharded executor: dispatch, validation, the
1M-row chunked path vs the cycle-accurate oracle, LRU cache eviction, and
the executor shape guards."""

import os
import subprocess
import sys

import numpy as np
import pytest

from repro import pim_ufunc as pim
from repro.core import bitserial as bs
from repro.core.floatfmt import BF16, FORMATS
from repro.kernels import ops as kops

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# --------------------------------------------------------------- int ufuncs

@pytest.mark.parametrize("dtype", [np.uint8, np.uint16, np.uint32])
def test_int_ufuncs_match_numpy(dtype):
    rng = np.random.default_rng(int(np.dtype(dtype).itemsize))
    hi = 1 << (np.dtype(dtype).itemsize * 8)
    x = rng.integers(0, hi, 200).astype(dtype)
    y = rng.integers(0, hi, 200).astype(dtype)
    d = rng.integers(1, hi, 200).astype(dtype)
    w = np.dtype(dtype).itemsize * 8
    assert np.array_equal(pim.add(x, y), x.astype(np.uint64) + y)
    assert np.array_equal(
        pim.sub(x, y),
        ((x.astype(np.int64) - y) % hi).astype(np.uint64))
    assert np.array_equal(pim.mul(x, y), x.astype(np.uint64) * y)
    q, r = pim.div(x, d)
    assert np.array_equal(q, x.astype(np.uint64) // d)
    assert np.array_equal(r, x.astype(np.uint64) % d)
    assert w  # width inferred, no exception


def test_int_ufunc_broadcast_and_shape():
    rng = np.random.default_rng(3)
    m = rng.integers(0, 256, (6, 5)).astype(np.uint8)
    out = pim.add(m, np.uint8(7))
    assert out.shape == (6, 5)
    assert np.array_equal(out, m.astype(np.uint64) + 7)


def test_int_ufunc_explicit_width_object_dtype():
    """width > 64: arbitrary-precision object arrays in and out."""
    x = np.array([(1 << 70) + 3, 5, 0], object)
    y = np.array([(1 << 70) + 1, 2, 0], object)
    out = pim.add(x, y, width=71)
    assert out.dtype == object
    assert [int(v) for v in out] == [int(a) + int(b) for a, b in zip(x, y)]


def test_int_ufunc_validation():
    u8 = np.arange(4, dtype=np.uint8)
    with pytest.raises(TypeError):
        pim.add(np.arange(4, dtype=np.int32), np.arange(4, dtype=np.int32))
    with pytest.raises(TypeError):
        pim.add(u8, np.arange(4, dtype=np.uint16))   # mixed widths
    with pytest.raises(ValueError):
        pim.add(np.array([300], object), np.array([1], object), width=8)
    with pytest.raises(ValueError):
        pim.div(u8, np.zeros(4, np.uint8))
    with pytest.raises(TypeError):
        pim.add(u8, u8, not_an_option=1)
    with pytest.raises(ValueError):
        pim.add(u8, u8, backend="verilog")


# ---------------------------------------------------------------- fp ufuncs

def test_fp_ufuncs_match_numpy():
    rng = np.random.default_rng(0)
    for dtype in (np.float16, np.float32):
        a = rng.standard_normal(128).astype(dtype)
        b = (rng.standard_normal(128).astype(dtype) +
             np.asarray(2.0, dtype) * np.sign(rng.standard_normal(128))
             .astype(dtype))
        b = np.where(b == 0, np.asarray(1.0, dtype), b)
        assert np.array_equal(pim.fp_add(a, b), (a + b).astype(dtype))
        assert np.array_equal(pim.fp_sub(a, b), (a - b).astype(dtype))
        assert np.array_equal(pim.fp_mul(a, b), (a * b).astype(dtype))
        assert np.array_equal(pim.fp_div(a, b), (a / b).astype(dtype))


def test_fp_ufunc_bf16_bits_vs_oracle():
    rng = np.random.default_rng(1)
    xb = BF16.random_bits(rng, 80, emin=120, emax=134).astype(np.uint64)
    yb = BF16.random_bits(rng, 80, emin=120, emax=134).astype(np.uint64)
    for op in ("add", "mul"):
        got = getattr(pim, f"fp_{op}")(xb, yb, fmt="bf16")
        want = [BF16.op_exact(op, int(a), int(b)) for a, b in zip(xb, yb)]
        assert [int(v) for v in got] == want, op


def test_fp_ufunc_validation():
    f = np.ones(4, np.float32)
    with pytest.raises(ValueError):
        pim.fp_add(np.array([np.nan], np.float32), f[:1])
    with pytest.raises(ValueError):
        pim.fp_add(f[:1], np.array([np.inf], np.float32))
    with pytest.raises(ValueError):            # subnormal
        pim.fp_mul(np.array([1e-42], np.float32), f[:1])
    with pytest.raises(ValueError):
        pim.fp_div(f, np.zeros(4, np.float32))
    with pytest.raises(TypeError):
        pim.fp_add(f, np.ones(4, np.float16))  # mixed dtypes
    with pytest.raises(ValueError):
        pim.fp_add(np.array([1], np.uint64), np.array([1], np.uint64),
                   fmt="fp128")
    # check=False skips the operand scan (results then undefined, but the
    # call must go through the executor unimpeded)
    out = pim.fp_add(f, f, check=False)
    assert out.shape == (4,)


# ----------------------------------------------- streaming + sharded 1M row

def test_stream_1m_rows_bit_exact_vs_oracle():
    """Acceptance: pim.add on >= 1M rows via the chunked path, bit-exact
    against the cycle-accurate numpy oracle on sampled rows (and against
    numpy's own arithmetic on every row)."""
    rng = np.random.default_rng(7)
    n = (1 << 20) + 17                        # ragged last chunk
    x = rng.integers(0, 1 << 32, n).astype(np.uint32)
    y = rng.integers(0, 1 << 32, n).astype(np.uint32)
    out = pim.add(x, y)                       # routes through streaming
    assert np.array_equal(out, x.astype(np.uint64) + y)
    idx = rng.integers(0, n, 64)
    oracle = pim.add(x[idx], y[idx], backend="numpy")
    assert np.array_equal(out[idx], oracle)


def test_stream_1m_rows_fp16_sampled_vs_oracle():
    rng = np.random.default_rng(8)
    n = 1 << 20
    xb = FORMATS["fp16"].random_bits(rng, n, emin=10, emax=20)
    yb = FORMATS["fp16"].random_bits(rng, n, emin=10, emax=20)
    x = xb.astype(np.uint16).view(np.float16)
    y = yb.astype(np.uint16).view(np.float16)
    out = pim.fp_add(x, y)
    idx = rng.integers(0, n, 48)
    oracle = pim.fp_add(x[idx], y[idx], backend="numpy")
    assert np.array_equal(out[idx], oracle)
    assert np.array_equal(out[idx], (x[idx] + y[idx]).astype(np.float16))


def test_streaming_matches_monolithic_across_chunk_edges():
    """Chunk boundaries at n_rows {0, 1, 31, 32, 33} offsets from the edge
    must be invisible: streaming == one-shot run_program."""
    p = bs.build_add(16)
    rng = np.random.default_rng(9)
    for n in (96, 97, 127, 128, 129):
        x = rng.integers(0, 1 << 16, n).astype(np.uint64)
        y = rng.integers(0, 1 << 16, n).astype(np.uint64)
        one = kops.run_program(p, {"x": x, "y": y}, n, backend="ref")
        stream = kops.run_program_streaming(p, {"x": x, "y": y}, n,
                                            backend="ref", chunk_rows=32)
        assert set(one) == set(stream)
        for k in one:
            assert np.array_equal(one[k], stream[k]), (n, k)


def test_streaming_rejects_bad_inputs():
    p = bs.build_add(8)
    x = np.arange(64, dtype=np.uint64)
    with pytest.raises(ValueError):
        kops.run_program_streaming(p, {"x": x, "y": x}, 64, backend="numpy")
    with pytest.raises(ValueError):
        kops.run_program_streaming(p, {"x": x[:10], "y": x[:10]}, 64,
                                   backend="ref", chunk_rows=32)


def test_sharded_parity_subprocess():
    """Real multi-device sharding (forced 4-device CPU child): streamed +
    sharded results must be bit-exact vs host arithmetic on both executor
    families (fused <= 32-cell ports and padded-io wide ports)."""
    code = """
import numpy as np
from repro.core import bitserial as bs
from repro.kernels import ops as kops
import jax
assert len(jax.devices()) == 4, jax.devices()
mesh = kops.row_mesh()
assert mesh is not None and mesh.devices.size == 4
rng = np.random.default_rng(0)
n = 100_001
x = rng.integers(0, 1 << 32, n).astype(np.uint64)
y = rng.integers(0, 1 << 32, n).astype(np.uint64)
for backend in ("ref", "pallas"):
    out = kops.run_program_streaming(bs.build_add(32), {"x": x, "y": y}, n,
                                     backend=backend, chunk_rows=32768,
                                     mesh=mesh)["z"]
    assert np.array_equal(out, x + y), backend
pm = bs.build_mul(48)             # 96-cell z port -> padded-io + object out
xm = x[:3000] & ((1 << 48) - 1)
ym = y[:3000] & ((1 << 48) - 1)
zm = kops.run_program_streaming(pm, {"x": xm, "y": ym}, 3000, backend="ref",
                                chunk_rows=1024, mesh=mesh)["z"]
assert all(int(g) == int(a) * int(b) for g, a, b in zip(zm, xm, ym))
print("SHARDED-OK")
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=4").strip()
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    proc = subprocess.run([sys.executable, "-c", code], env=env, cwd=REPO,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "SHARDED-OK" in proc.stdout


# ------------------------------------------------------- LRU compiled cache

def _mini_program(seed, n_gates=12):
    from repro.core.gates import Builder

    rng = np.random.default_rng(seed)
    b = Builder()
    avail = b.input("x", 16) + b.input("y", 16)
    fns = [b.nor, b.or_, b.and_, b.xor, b.xnor, b.nand]
    for _ in range(n_gates):
        f = fns[rng.integers(0, len(fns))]
        i, j = rng.integers(0, len(avail), 2)
        avail.append(f(avail[i], avail[j]))
    b.output("z", avail[-16:])
    return b.finish()


def test_compiled_cache_lru_eviction_bit_exact():
    """The compiled-program cache must stay bounded, and eviction must be
    invisible to results (recompilation is pure)."""
    old_cap = kops.set_compiled_cache_cap(2)
    try:
        progs = [_mini_program(100 + s) for s in range(5)]
        rng = np.random.default_rng(0)
        ins = {"x": rng.integers(0, 1 << 16, 33).astype(np.uint64),
               "y": rng.integers(0, 1 << 16, 33).astype(np.uint64)}
        want = [kops.run_program(p, ins, 33, backend="numpy")["z"]
                for p in progs]
        for _ in range(2):                    # second pass hits evictions
            for p, w in zip(progs, want):
                got = kops.run_program(p, ins, 33, backend="ref")["z"]
                assert np.array_equal(got, w)
                assert len(kops._compiled) <= 2
    finally:
        kops.set_compiled_cache_cap(old_cap)
    with pytest.raises(ValueError):
        kops.set_compiled_cache_cap(0)


# ------------------------------------------------------ executor shape guard

def test_executor_shape_checks_raise_value_error():
    """Shape guards must be explicit raises (assert dies under python -O)."""
    import jax.numpy as jnp
    from repro.kernels import pim_exec

    ops = jnp.zeros(1, jnp.int32)
    good = jnp.zeros((4, pim_exec.TILE_W), jnp.uint32)
    with pytest.raises(ValueError, match="n_cells"):
        pim_exec.pim_exec_padded(good, ops, ops, ops, ops, n_cells=5)
    with pytest.raises(ValueError, match="TILE_W"):
        pim_exec.pim_exec_padded(
            jnp.zeros((4, pim_exec.TILE_W + 1), jnp.uint32),
            ops, ops, ops, ops, n_cells=4)
    la = jnp.zeros((1, 1), jnp.int32)
    with pytest.raises(ValueError, match="n_cells"):
        pim_exec.pim_exec_level_padded(good, la, la, la, n_cells=3)
    with pytest.raises(ValueError, match="TILE_W"):
        pim_exec.pim_exec_level_padded(
            jnp.zeros((4, 8), jnp.uint32), la, la, la, n_cells=4)


# -------------------------------------------------------------- serving API

def test_serve_pim_request_roundtrip():
    from repro.launch import serve

    r = serve.pim_request({"op": "add", "dtype": "uint16",
                           "x": [3, 5], "y": [4, 6]})
    assert r["result"] == [7, 11] and r["rows"] == 2
    r = serve.pim_request({"op": "div", "dtype": "uint8",
                           "x": [17], "y": [5]})
    assert (r["q"], r["r"]) == ([3], [2])
    r = serve.pim_request({"op": "fp_add", "fmt": "bf16",
                           "x": [16256], "y": [16256]})
    assert r["result"] == [16384]             # 1.0 + 1.0 == 2.0
    r = serve.pim_request({"op": "nope", "x": [], "y": []})
    assert "error" in r
    r = serve.pim_request({"op": "div", "dtype": "uint8",
                           "x": [1], "y": [0]})
    assert r["error"]["code"] == "bad_request"
    assert "zero divisor" in r["error"]["message"]


def test_serve_pim_stdin_loop():
    import io
    import json

    from repro.launch import serve

    inp = io.StringIO('{"op":"add","dtype":"uint8","x":[1],"y":[2]}\n'
                      '\nnot json\n')
    outp = io.StringIO()
    served = serve.serve_pim_stdin(inp, outp)
    lines = [json.loads(l) for l in outp.getvalue().splitlines()]
    assert served == 2
    assert lines[0]["result"] == [3]
    assert "error" in lines[1]
