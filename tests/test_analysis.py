"""Analysis substrate: HLO census, roofline model, offload planner, elastic
mesh selection, fp64 extension of the FP suite."""

import numpy as np
import pytest

from repro.configs.registry import ARCHS
from repro.core import bitserial_fp as fp
from repro.core.floatfmt import FP64
from repro.core.offload import decode_step_plan, report
from repro.launch.hlo_census import HloCensus
from repro.launch.roofline import kv_cache_bytes, model_flops, traffic_model
from repro.launch.steps import SHAPES
from repro.runtime.elastic import choose_mesh

_HLO = """\
HloModule jit_f, is_scheduled=true

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %ag = f32[8,8]{1,0} all-gather(%x), channel_id=1, replica_groups=[2,4]<=[8], dimensions={0}
  %d = f32[8,8]{1,0} dot(%ag, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %t = (s32[], f32[8,8]) tuple(%i, %d)
}

%cond (p2: (s32[], f32[8,8])) -> pred[] {
  %p2 = (s32[], f32[8,8]) parameter(0)
  ROOT %lt = pred[] constant(true)
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8]{1,0} parameter(0)
  %c = s32[] constant(0)
  %tup = (s32[], f32[8,8]) tuple(%c, %a)
  %w = (s32[], f32[8,8]) while(%tup), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"},"known_init_step":{"init":"0","step":"1"}}
  %ar = f32[8,8]{1,0} all-reduce(%a), channel_id=2, replica_groups=[8]<=[8], to_apply=%cond
  ROOT %o = f32[8,8]{1,0} get-tuple-element(%w), index=1
}
"""


def test_hlo_census_loop_awareness():
    t = HloCensus(_HLO).totals()
    # dot: 2*8*8*8 = 1024 flops, x5 trips
    assert t["dot_flops"] == 5 * 1024
    # all-gather inside loop x5 x256B x1.0; all-reduce outside x1 x256B x2.0
    assert t["collectives"]["all-gather"]["bytes"] == 5 * 256
    assert t["collectives"]["all-gather"]["count"] == 5
    assert t["collectives"]["all-reduce"]["bytes"] == 2 * 256
    assert t["collectives"]["all-reduce"]["count"] == 1


def test_roofline_models_sane():
    cfg = ARCHS["qwen3-8b"]
    plan = SHAPES["train_4k"]
    mf = model_flops(cfg, plan)
    assert abs(mf - 6 * cfg.n_params * 4096 * 256) / mf < 1e-9
    tm = traffic_model(cfg, plan, 256)
    assert tm["total"] == tm["weights"] + tm["optimizer"] + tm["activations"]
    assert tm["weights"] > 0 and tm["optimizer"] > 0
    # decode kv bytes: qwen3-8b @ 32k x 128 streams ~ 600 GB total
    kv = kv_cache_bytes(cfg, 32768, 128)
    assert 5e11 < kv < 8e11
    # recurrent archs: O(1) state
    kv_rwkv = kv_cache_bytes(ARCHS["rwkv6-1.6b"], 524288, 1)
    assert kv_rwkv < 1e9


def test_moe_flops_use_active_params():
    cfg = ARCHS["qwen3-moe-235b-a22b"]
    assert cfg.n_params_active < cfg.n_params / 8
    mf = model_flops(cfg, SHAPES["train_4k"])
    assert mf < 6 * cfg.n_params * 4096 * 256 / 8


def test_offload_planner():
    plans = decode_step_plan(ARCHS["rwkv6-1.6b"], batch=128, seq=32768)
    assert any(p.offload for p in plans)       # Mi-scale elementwise wins
    small = decode_step_plan(ARCHS["rwkv6-1.6b"].reduced(), batch=1, seq=8)
    assert not all(p.offload for p in small)   # tiny vectors lose (latency)
    assert "offload plan" in report(ARCHS["rwkv6-1.6b"])


def test_elastic_mesh_respects_divisors():
    for n in (256, 255, 128, 96, 17):
        data, model = choose_mesh(n, model_divisors=[32, 8])
        assert 32 % model == 0 and 8 % model == 0
        assert data * model <= n


def test_fp64_extension():
    """The suite generalizes to double precision unchanged."""
    rng = np.random.default_rng(1)
    p = fp.build_fp_add(FP64)
    xs = FP64.random_bits(rng, 6, emin=900, emax=1100)
    ys = FP64.random_bits(rng, 6, emin=900, emax=1100)
    for a, b in zip(xs, ys):
        assert p.exec_row({"x": int(a), "y": int(b)})["z"] == \
            FP64.op_exact("add", int(a), int(b))
