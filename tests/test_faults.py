"""Fault injection + verified execution (DESIGN.md §12): deterministic
fault maps, single-fault recovery bit-exactness across every schedule x
layout, retry/remap exhaustion, deadlines, and the zero-overhead-when-off
guarantee."""

import time

import numpy as np
import pytest

from repro import pim_ufunc as pim
from repro.core.pim_numerics import program_for
from repro.kernels import ops as kops
from repro.kernels.plan import LAYOUTS, SCHEDULES
from repro.runtime.faults import (DeadlineExceeded, FaultError, FaultModel,
                                  VerifyPolicy, word_coords)


def _operands(n=160, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.integers(0, 1 << 16, n).astype(np.uint16)
    y = rng.integers(0, 1 << 16, n).astype(np.uint16)
    return x, y, x.astype(np.uint64) + y


PROG = program_for("int-serial", "add", 16)


# ------------------------------------------------------------- fault maps

def test_fault_model_validation():
    with pytest.raises(ValueError):
        FaultModel(p_flip=1.5)
    with pytest.raises(ValueError):
        FaultModel(p_dead_row=-0.1)
    with pytest.raises(ValueError):
        FaultModel(spare_base=33)           # must be 64-aligned
    with pytest.raises(ValueError):
        VerifyPolicy(max_retries=-1)
    with pytest.raises(ValueError):
        VerifyPolicy(remap_after=0)


def test_fault_maps_deterministic_and_subrange_consistent():
    fm = FaultModel(seed=11, p_dead_row=0.03, p_stuck=0.05)
    assert np.array_equal(fm.dead_rows(0, 4096), fm.dead_rows(0, 4096))
    whole = fm.dead_rows(0, 4096)
    lo = fm.dead_rows(0, 1000)
    hi = fm.dead_rows(1000, 4096)
    assert np.array_equal(whole, np.concatenate([lo, hi]))
    w1, f1 = fm.stuck_cols(0, 256)
    w2, f2 = fm.stuck_cols(0, 256)
    assert np.array_equal(w1, w2) and np.array_equal(f1, f2)
    # a different seed moves the map
    other = FaultModel(seed=12, p_dead_row=0.03, p_stuck=0.05)
    assert not np.array_equal(whole, other.dead_rows(0, 4096))


def test_forced_faults_and_span_bad():
    fm = FaultModel(seed=0, force_dead_rows=(70, 3), force_stuck=((2, 1),))
    assert np.array_equal(fm.dead_rows(0, 100), [3, 70])
    assert fm.span_bad(0, 64) and fm.span_bad(64, 64)
    assert not fm.span_bad(128, 64)
    w, fills = fm.stuck_cols(0, 8)
    assert 2 in w and fills[list(w).index(2)] == 0xFFFFFFFF


def test_transient_flips_attempt0_only():
    fm = FaultModel(seed=0, force_flips=((1, 9),))
    c0, r0 = fm.sample_flips(5, 0, 3, 4, 64)
    c1, r1 = fm.sample_flips(5, 1, 3, 4, 64)
    assert (1 in c0) and (9 in r0)          # forced flip fires on attempt 0
    assert len(c1) == 0                     # ...and only attempt 0
    # random flips vary by attempt but are reproducible
    fm = FaultModel(seed=3, p_flip=0.02)
    a = fm.sample_flips(5, 1, 8, 4, 64)
    b = fm.sample_flips(5, 1, 8, 4, 64)
    assert np.array_equal(a[0], b[0]) and np.array_equal(a[1], b[1])


def test_word_coords_roundtrip():
    rows = np.array([0, 31, 32, 63, 64, 70, 127, 128])
    for planes in (1, 2):
        pl, w, bit = word_coords(rows, planes)
        rpw = 32 * planes
        assert np.array_equal(w * rpw + pl * 32 + bit, rows)


def test_check_words_xor_fold():
    import jax.numpy as jnp
    blk = np.random.default_rng(0).integers(
        0, 1 << 32, (5, 7), dtype=np.uint64).astype(np.uint32)
    got = np.asarray(kops.check_words(jnp.asarray(blk), 0))
    want = np.bitwise_xor.reduce(blk, axis=0)
    assert np.array_equal(got, want)


# -------------------------------------------------- plan-layer integration

def test_plan_key_includes_faults_but_compile_key_does_not():
    base = kops.make_plan(backend="ref")
    faulty = kops.make_plan(backend="ref", faults=FaultModel(seed=1),
                            verify=True)
    assert base.key != faulty.key           # serving must never coalesce
    assert base.compile_key == faulty.compile_key   # same compiled artifact


def test_numpy_backend_rejects_faults():
    with pytest.raises(ValueError):
        kops.make_plan(backend="numpy", faults=FaultModel(seed=1))


def test_ufunc_config_plumbs_faults_and_verify():
    x, y, want = _operands(40)
    with pim.options(faults=FaultModel(seed=3, force_flips=((0, 2),)),
                     verify=True):
        got = pim.add(x, y)
    assert np.array_equal(got, want)
    h = kops.drain_health()
    assert h["faults_detected"] >= 1 and h["faults_corrected"] >= 1
    # numpy drops faults/verify (it IS the oracle)
    got = pim.add(x, y, backend="numpy", verify=True,
                  faults=FaultModel(seed=1, p_flip=1.0))
    assert np.array_equal(got, want) and not kops.drain_health()


# ------------------------------------------- detect -> retry -> remap

FAULT_KINDS = {
    "flip": FaultModel(seed=5, force_flips=((1, 9),)),
    "dead": FaultModel(seed=5, force_dead_rows=(70,)),
    "stuck": FaultModel(seed=5, force_stuck=((1, 1),)),
}


@pytest.mark.parametrize("schedule", SCHEDULES)
@pytest.mark.parametrize("layout", sorted(LAYOUTS))
@pytest.mark.parametrize("kind", sorted(FAULT_KINDS))
def test_single_fault_recovery_matrix(schedule, layout, kind):
    """A single injected fault of each kind recovers bit-exactly vs the
    numpy oracle on every schedule x layout, through the multi-chunk
    streaming executor."""
    x, y, want = _operands(seed=hash((schedule, layout, kind)) & 0xFFFF)
    plan = kops.make_plan(backend="ref", schedule=schedule, layout=layout,
                          chunk_rows=64, faults=FAULT_KINDS[kind],
                          verify=VerifyPolicy(backoff_s=1e-5))
    kops.drain_health()
    got = kops.run_program_streaming(PROG, {"x": x, "y": y}, len(x), plan)
    assert np.array_equal(got["z"], want)
    h = kops.drain_health()
    assert h.get("faults_detected", 0) + h.get("remapped_rows", 0) > 0


def test_randomized_low_rate_faults_recover():
    x, y, want = _operands(n=300, seed=7)
    for seed in range(3):
        plan = kops.make_plan(
            backend="ref", chunk_rows=128,
            faults=FaultModel(seed=seed, p_flip=2e-4, p_dead_row=1e-3),
            verify=VerifyPolicy(backoff_s=1e-5))
        got = kops.run_program_streaming(PROG, {"x": x, "y": y}, len(x),
                                         plan)
        assert np.array_equal(got["z"], want), seed
    kops.drain_health()


def test_unverified_faults_corrupt_observably():
    x, y, want = _operands()
    plan = kops.make_plan(backend="ref",
                          faults=FaultModel(seed=1, force_flips=((0, 7),)))
    got = kops.run_program(PROG, {"x": x, "y": y}, len(x), plan)
    assert not np.array_equal(got["z"], want)
    h = kops.drain_health()
    assert h["faults_injected"] >= 1 and "faults_detected" not in h


def test_retry_exhaustion_raises_fault_error():
    x, y, _ = _operands(64)
    plan = kops.make_plan(backend="ref",
                          faults=FaultModel(seed=2, p_flip=1.0),
                          verify=VerifyPolicy(max_retries=2, backoff_s=1e-6))
    with pytest.raises(FaultError):
        kops.run_program(PROG, {"x": x, "y": y}, len(x), plan)
    h = kops.drain_health()
    assert h["retries"] >= 2


def test_media_scan_exhaustion_raises_fault_error():
    x, y, _ = _operands(64)
    plan = kops.make_plan(backend="ref",
                          faults=FaultModel(seed=2, p_dead_row=1.0),
                          verify=VerifyPolicy(scan_limit=4, backoff_s=1e-6))
    with pytest.raises(FaultError):
        kops.run_program(PROG, {"x": x, "y": y}, len(x), plan)
    kops.drain_health()


def test_verify_without_faults_is_clean_passthrough():
    x, y, want = _operands(80)
    plan = kops.make_plan(backend="ref", verify=True)
    got = kops.run_program(PROG, {"x": x, "y": y}, len(x), plan)
    assert np.array_equal(got["z"], want)
    h = kops.drain_health()
    assert "faults_detected" not in h and "retries" not in h


def test_plain_plan_skips_verified_dispatch(monkeypatch):
    """FaultModel unset + verify unset must cost nothing: the verified
    dispatcher is never entered (the 0%-overhead guarantee)."""
    def boom(*a, **k):
        raise AssertionError("_verified_dispatch entered on a plain plan")
    monkeypatch.setattr(kops, "_verified_dispatch", boom)
    x, y, want = _operands(80)
    plan = kops.make_plan(backend="ref", chunk_rows=32)
    got = kops.run_program_streaming(PROG, {"x": x, "y": y}, len(x), plan)
    assert np.array_equal(got["z"], want)


# ----------------------------------------------------------- deadlines

def test_streaming_deadline_raises():
    x, y, _ = _operands(200)
    plan = kops.make_plan(backend="ref", chunk_rows=32)
    with pytest.raises(DeadlineExceeded):
        kops.run_program_streaming(PROG, {"x": x, "y": y}, len(x), plan,
                                   deadline=time.monotonic() - 1.0)


def test_group_deadline_key():
    x, y, _ = _operands(64)
    specs = [dict(program=PROG, inputs={"x": x, "y": y}, n_rows=len(x),
                  plan=kops.make_plan(backend="ref"),
                  deadline=time.monotonic() - 1.0)]
    with pytest.raises(DeadlineExceeded):
        kops.run_program_groups(specs)
