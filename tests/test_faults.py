"""Fault injection + verified execution (DESIGN.md §12): deterministic
fault maps, single-fault recovery bit-exactness across every schedule x
layout, retry/remap exhaustion, deadlines, and the zero-overhead-when-off
guarantee."""

import time

import numpy as np
import pytest

from repro import pim_ufunc as pim
from repro.core.pim_numerics import program_for
from repro.kernels import ops as kops
from repro.kernels.plan import LAYOUTS, SCHEDULES
from repro.runtime.faults import (DeadlineExceeded, FaultError, FaultModel,
                                  Scrubber, VerifyPolicy, drain_media_health,
                                  note_quarantine, quarantined_spans,
                                  release_span, wear_snapshot, word_coords)


@pytest.fixture(autouse=True)
def _health_leak_check():
    """Drained HEALTH is part of every test's contract: a test that leaves
    counters behind corrupts its neighbours' assertions, so start clean
    and fail loudly on leaks.  Media state (quarantine queue, MEDIA
    counters) is likewise reset so scrub tests see only their own spans."""
    kops.drain_health()
    drain_media_health()
    for base in quarantined_spans():
        release_span(base)
    yield
    for base in quarantined_spans():
        release_span(base)
    drain_media_health()
    leaked = kops.drain_health()
    assert not leaked, f"test leaked undrained HEALTH counters: {leaked}"


def _operands(n=160, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.integers(0, 1 << 16, n).astype(np.uint16)
    y = rng.integers(0, 1 << 16, n).astype(np.uint16)
    return x, y, x.astype(np.uint64) + y


PROG = program_for("int-serial", "add", 16)


# ------------------------------------------------------------- fault maps

def test_fault_model_validation():
    with pytest.raises(ValueError):
        FaultModel(p_flip=1.5)
    with pytest.raises(ValueError):
        FaultModel(p_dead_row=-0.1)
    with pytest.raises(ValueError):
        FaultModel(spare_base=33)           # must be 64-aligned
    with pytest.raises(ValueError):
        VerifyPolicy(max_retries=-1)
    with pytest.raises(ValueError):
        VerifyPolicy(remap_after=0)


def test_fault_maps_deterministic_and_subrange_consistent():
    fm = FaultModel(seed=11, p_dead_row=0.03, p_stuck=0.05)
    assert np.array_equal(fm.dead_rows(0, 4096), fm.dead_rows(0, 4096))
    whole = fm.dead_rows(0, 4096)
    lo = fm.dead_rows(0, 1000)
    hi = fm.dead_rows(1000, 4096)
    assert np.array_equal(whole, np.concatenate([lo, hi]))
    w1, f1 = fm.stuck_cols(0, 256)
    w2, f2 = fm.stuck_cols(0, 256)
    assert np.array_equal(w1, w2) and np.array_equal(f1, f2)
    # a different seed moves the map
    other = FaultModel(seed=12, p_dead_row=0.03, p_stuck=0.05)
    assert not np.array_equal(whole, other.dead_rows(0, 4096))


def test_forced_faults_and_span_bad():
    fm = FaultModel(seed=0, force_dead_rows=(70, 3), force_stuck=((2, 1),))
    assert np.array_equal(fm.dead_rows(0, 100), [3, 70])
    assert fm.span_bad(0, 64) and fm.span_bad(64, 64)
    assert not fm.span_bad(128, 64)
    w, fills = fm.stuck_cols(0, 8)
    assert 2 in w and fills[list(w).index(2)] == 0xFFFFFFFF


def test_transient_flips_attempt0_only():
    fm = FaultModel(seed=0, force_flips=((1, 9),))
    c0, r0 = fm.sample_flips(5, 0, 3, 4, 64)
    c1, r1 = fm.sample_flips(5, 1, 3, 4, 64)
    assert (1 in c0) and (9 in r0)          # forced flip fires on attempt 0
    assert len(c1) == 0                     # ...and only attempt 0
    # random flips vary by attempt but are reproducible
    fm = FaultModel(seed=3, p_flip=0.02)
    a = fm.sample_flips(5, 1, 8, 4, 64)
    b = fm.sample_flips(5, 1, 8, 4, 64)
    assert np.array_equal(a[0], b[0]) and np.array_equal(a[1], b[1])


def test_word_coords_roundtrip():
    rows = np.array([0, 31, 32, 63, 64, 70, 127, 128])
    for planes in (1, 2):
        pl, w, bit = word_coords(rows, planes)
        rpw = 32 * planes
        assert np.array_equal(w * rpw + pl * 32 + bit, rows)


def test_check_words_xor_fold():
    import jax.numpy as jnp
    blk = np.random.default_rng(0).integers(
        0, 1 << 32, (5, 7), dtype=np.uint64).astype(np.uint32)
    got = np.asarray(kops.check_words(jnp.asarray(blk), 0))
    want = np.bitwise_xor.reduce(blk, axis=0)
    assert np.array_equal(got, want)


# -------------------------------------------------- plan-layer integration

def test_plan_key_includes_faults_but_compile_key_does_not():
    base = kops.make_plan(backend="ref")
    faulty = kops.make_plan(backend="ref", faults=FaultModel(seed=1),
                            verify=True)
    assert base.key != faulty.key           # serving must never coalesce
    assert base.compile_key == faulty.compile_key   # same compiled artifact


def test_numpy_backend_rejects_faults():
    with pytest.raises(ValueError):
        kops.make_plan(backend="numpy", faults=FaultModel(seed=1))


def test_ufunc_config_plumbs_faults_and_verify():
    x, y, want = _operands(40)
    with pim.options(faults=FaultModel(seed=3, force_flips=((0, 2),)),
                     verify=True):
        got = pim.add(x, y)
    assert np.array_equal(got, want)
    h = kops.drain_health()
    assert h["faults_detected"] >= 1 and h["faults_corrected"] >= 1
    # numpy drops faults/verify (it IS the oracle)
    got = pim.add(x, y, backend="numpy", verify=True,
                  faults=FaultModel(seed=1, p_flip=1.0))
    assert np.array_equal(got, want) and not kops.drain_health()


# ------------------------------------------- detect -> retry -> remap

FAULT_KINDS = {
    "flip": FaultModel(seed=5, force_flips=((1, 9),)),
    "dead": FaultModel(seed=5, force_dead_rows=(70,)),
    "stuck": FaultModel(seed=5, force_stuck=((1, 1),)),
}


@pytest.mark.parametrize("schedule", SCHEDULES)
@pytest.mark.parametrize("layout", sorted(LAYOUTS))
@pytest.mark.parametrize("kind", sorted(FAULT_KINDS))
def test_single_fault_recovery_matrix(schedule, layout, kind):
    """A single injected fault of each kind recovers bit-exactly vs the
    numpy oracle on every schedule x layout, through the multi-chunk
    streaming executor."""
    x, y, want = _operands(seed=hash((schedule, layout, kind)) & 0xFFFF)
    plan = kops.make_plan(backend="ref", schedule=schedule, layout=layout,
                          chunk_rows=64, faults=FAULT_KINDS[kind],
                          verify=VerifyPolicy(backoff_s=1e-5))
    kops.drain_health()
    got = kops.run_program_streaming(PROG, {"x": x, "y": y}, len(x), plan)
    assert np.array_equal(got["z"], want)
    h = kops.drain_health()
    assert h.get("faults_detected", 0) + h.get("remapped_rows", 0) > 0


def test_randomized_low_rate_faults_recover():
    x, y, want = _operands(n=300, seed=7)
    for seed in range(3):
        plan = kops.make_plan(
            backend="ref", chunk_rows=128,
            faults=FaultModel(seed=seed, p_flip=2e-4, p_dead_row=1e-3),
            verify=VerifyPolicy(backoff_s=1e-5))
        got = kops.run_program_streaming(PROG, {"x": x, "y": y}, len(x),
                                         plan)
        assert np.array_equal(got["z"], want), seed
    kops.drain_health()


def test_unverified_faults_corrupt_observably():
    x, y, want = _operands()
    plan = kops.make_plan(backend="ref",
                          faults=FaultModel(seed=1, force_flips=((0, 7),)))
    got = kops.run_program(PROG, {"x": x, "y": y}, len(x), plan)
    assert not np.array_equal(got["z"], want)
    h = kops.drain_health()
    assert h["faults_injected"] >= 1 and "faults_detected" not in h


def test_retry_exhaustion_raises_fault_error():
    x, y, _ = _operands(64)
    plan = kops.make_plan(backend="ref",
                          faults=FaultModel(seed=2, p_flip=1.0),
                          verify=VerifyPolicy(max_retries=2, backoff_s=1e-6))
    with pytest.raises(FaultError):
        kops.run_program(PROG, {"x": x, "y": y}, len(x), plan)
    h = kops.drain_health()
    assert h["retries"] >= 2


def test_media_scan_exhaustion_raises_fault_error():
    x, y, _ = _operands(64)
    plan = kops.make_plan(backend="ref",
                          faults=FaultModel(seed=2, p_dead_row=1.0),
                          verify=VerifyPolicy(scan_limit=4, backoff_s=1e-6))
    with pytest.raises(FaultError):
        kops.run_program(PROG, {"x": x, "y": y}, len(x), plan)
    kops.drain_health()


def test_verify_without_faults_is_clean_passthrough():
    x, y, want = _operands(80)
    plan = kops.make_plan(backend="ref", verify=True)
    got = kops.run_program(PROG, {"x": x, "y": y}, len(x), plan)
    assert np.array_equal(got["z"], want)
    h = kops.drain_health()
    assert "faults_detected" not in h and "retries" not in h


def test_plain_plan_skips_verified_dispatch(monkeypatch):
    """FaultModel unset + verify unset must cost nothing: the verified
    dispatcher is never entered (the 0%-overhead guarantee)."""
    def boom(*a, **k):
        raise AssertionError("_verified_dispatch entered on a plain plan")
    monkeypatch.setattr(kops, "_verified_dispatch", boom)
    x, y, want = _operands(80)
    plan = kops.make_plan(backend="ref", chunk_rows=32)
    got = kops.run_program_streaming(PROG, {"x": x, "y": y}, len(x), plan)
    assert np.array_equal(got["z"], want)


# ---------------------------------- packed-domain + fused paths (§14)

PACKED_FAULTS = {
    "flip": FaultModel(seed=5, force_flips=((0, 2),)),
    "dead": FaultModel(seed=5, force_dead_rows=(1,)),
    "stuck": FaultModel(seed=5, force_stuck=((0, 1),)),
    "rate": FaultModel(seed=9, p_flip=5e-4),
}


@pytest.mark.parametrize("schedule", SCHEDULES)
@pytest.mark.parametrize("layout", sorted(LAYOUTS))
@pytest.mark.parametrize("kind", sorted(PACKED_FAULTS))
def test_packed_tree_and_fused_fault_recovery_matrix(schedule, layout, kind):
    """The compound paths -- dot, gemv (packed log-depth reduction trees)
    and a depth-3 fused chain -- recover bit-exactly vs the numpy oracle
    from every fault kind on every schedule x layout: forced single
    faults plus the acceptance-rate transient flips (p_flip=5e-4)."""
    rng = np.random.default_rng(hash((schedule, layout, kind)) & 0xFFFF)
    fm = PACKED_FAULTS[kind]
    vp = VerifyPolicy(backoff_s=1e-5)
    with pim.options(backend="ref", schedule=schedule, layout=layout,
                     faults=fm, verify=vp):
        xd = rng.integers(0, 256, 64).astype(np.uint8)
        yd = rng.integers(0, 256, 64).astype(np.uint8)
        assert int(pim.dot(xd, yd)) == int(pim.dot(xd, yd, backend="numpy", layout="rows32"))
        a = rng.integers(0, 1 << 16, (3, 8)).astype(np.uint16)
        v = rng.integers(0, 1 << 16, 8).astype(np.uint16)
        got = pim.gemv(a, v)
        want = pim.gemv(a, v, backend="numpy", layout="rows32")
        assert np.array_equal(np.asarray(got, object),
                              np.asarray(want, object))
        x = rng.integers(0, 256, 48).astype(np.uint8)
        y = rng.integers(1, 256, 48).astype(np.uint8)
        z = rng.integers(0, 256, 48).astype(np.uint8)
        chain = pim.sub(pim.add(pim.mul(pim.lazy(x), pim.lazy(y)),
                                pim.lazy(z)), pim.lazy(x))
        got = chain.run()
        want = chain.run(backend="numpy", layout="rows32")
        assert np.array_equal(np.asarray(got, object),
                              np.asarray(want, object))
    kops.drain_health()


def test_gemv_wide_group_rows64_faulty():
    """A K=96 reduction on the paired rows64 layout walks the plane-aware
    tree pairings (word slice / plane re-seam / in-word shift) under a
    forced transient flip and still lands bit-exact."""
    rng = np.random.default_rng(96)
    a = rng.integers(0, 1 << 16, (2, 96)).astype(np.uint16)
    v = rng.integers(0, 1 << 16, 96).astype(np.uint16)
    with pim.options(backend="ref", layout="rows64",
                     faults=FaultModel(seed=5, force_flips=((0, 2),)),
                     verify=VerifyPolicy(backoff_s=1e-5)):
        got = pim.gemv(a, v)
        want = pim.gemv(a, v, backend="numpy", layout="rows32")
    assert np.array_equal(np.asarray(got, object), np.asarray(want, object))
    h = kops.drain_health()
    assert h.get("faults_detected", 0) >= 1


def test_packed_tree_deadline_between_levels():
    x = np.arange(64, dtype=np.uint8)
    with pim.options(backend="ref"):
        with pytest.raises(DeadlineExceeded):
            pim.dot(x, x, deadline=time.monotonic() - 1.0)


def test_plain_plan_skips_verified_packed_dispatch(monkeypatch):
    """Packed-domain mirror of the 0%-overhead guarantee: with faults and
    verify unset, the verified packed dispatcher is never entered."""
    def boom(*a, **k):
        raise AssertionError(
            "_verified_dispatch_packed entered on a plain plan")
    monkeypatch.setattr(kops, "_verified_dispatch_packed", boom)
    x = np.arange(64, dtype=np.uint8)
    y = x[::-1].copy()
    with pim.options(backend="ref"):
        got = pim.dot(x, y)
    assert int(got) == int(np.dot(x.astype(np.int64), y.astype(np.int64)))


def test_fault_error_structured_context():
    """FaultError carries machine-readable context (None values dropped);
    retry exhaustion populates it with the failing program + attempts."""
    assert FaultError("x").context == {}
    e = FaultError("bad", program_key="ab12", attempts=3, chunk_start=None)
    assert e.context == {"program_key": "ab12", "attempts": 3}
    x, y, _ = _operands(64)
    plan = kops.make_plan(
        backend="ref", faults=FaultModel(seed=2, p_flip=1.0),
        verify=VerifyPolicy(max_retries=1, backoff_s=1e-6, remap_after=99))
    with pytest.raises(FaultError) as ei:
        kops.run_program(PROG, {"x": x, "y": y}, len(x), plan)
    ctx = ei.value.context
    assert ctx["attempts"] >= 1 and ctx["rows"] == 64
    assert "program_key" in ctx
    kops.drain_health()


# ------------------------------- media lifecycle: wear + scrubbing (§14)

def test_wear_and_quarantine_from_verified_run():
    """A persistent dead row makes verified execution abandon the span:
    it lands in quarantine, and the spare that replaced it accumulates
    wear -- both observable through the media health counters."""
    x, y, want = _operands(64)
    plan = kops.make_plan(backend="ref", chunk_rows=64,
                          faults=FaultModel(seed=4, force_dead_rows=(1,)),
                          verify=VerifyPolicy(backoff_s=1e-5))
    got = kops.run_program_streaming(PROG, {"x": x, "y": y}, len(x), plan)
    assert np.array_equal(got["z"], want)
    kops.drain_health()
    assert quarantined_spans()
    assert wear_snapshot()
    m = drain_media_health()
    assert m["wear_writes"] >= 1 and m["quarantined_spans"] >= 1


def test_scrubber_reclaims_transient_quarantine_keeps_bad():
    fm = FaultModel(seed=0, force_dead_rows=(70,))
    note_quarantine(0, 64)          # clean: dead row 70 is in [64, 128)
    note_quarantine(64, 64)         # persistently bad
    r = Scrubber(fm).scrub_once()
    assert r == {"scrubbed": 2, "reclaimed": 1, "still_bad": 1}
    assert quarantined_spans() == {64: 64}
    m = drain_media_health()
    assert m["scrub_passes"] == 1 and m["spans_reclaimed"] == 1
    assert m["spans_still_bad"] == 1


def test_scrubber_thread_runs_and_stops():
    note_quarantine(128, 64)        # clean under a fault-free model
    s = Scrubber(FaultModel(seed=0), interval_s=0.01).start()
    deadline = time.monotonic() + 5.0
    while quarantined_spans() and time.monotonic() < deadline:
        time.sleep(0.01)
    s.stop()
    assert not quarantined_spans()  # reclaimed in the background
    assert drain_media_health()["scrub_passes"] >= 1
    s.stop()                        # idempotent


# ----------------------------------------------------------- deadlines

def test_streaming_deadline_raises():
    x, y, _ = _operands(200)
    plan = kops.make_plan(backend="ref", chunk_rows=32)
    with pytest.raises(DeadlineExceeded):
        kops.run_program_streaming(PROG, {"x": x, "y": y}, len(x), plan,
                                   deadline=time.monotonic() - 1.0)


def test_group_deadline_key():
    x, y, _ = _operands(64)
    specs = [dict(program=PROG, inputs={"x": x, "y": y}, n_rows=len(x),
                  plan=kops.make_plan(backend="ref"),
                  deadline=time.monotonic() - 1.0)]
    with pytest.raises(DeadlineExceeded):
        kops.run_program_groups(specs)
