"""Pallas executor vs ref.py oracle vs cycle-accurate simulator:
shape x dtype x program sweep (interpret mode on CPU)."""

import numpy as np
import pytest

from repro.core import bitserial as bs, bitserial_fp as bsf
from repro.core.floatfmt import FP16
from repro.kernels import ops as kops

_cache = {}


def _prog(key, builder):
    if key not in _cache:
        _cache[key] = builder()
    return _cache[key]


@pytest.mark.parametrize("rows", [1, 31, 32, 33, 257])
@pytest.mark.parametrize("width", [8, 16, 32])
def test_add_sweep_backends(rows, width):
    p = _prog(("add", width), lambda: bs.build_add(width))
    rng = np.random.default_rng(rows * width)
    hi = 2 ** width
    x = rng.integers(0, hi, rows).astype(np.uint64)
    y = rng.integers(0, hi, rows).astype(np.uint64)
    want = x + y
    ref = kops.run_program(p, {"x": x, "y": y}, rows, backend="ref")["z"]
    pal = kops.run_program(p, {"x": x, "y": y}, rows, backend="pallas")["z"]
    npy = kops.run_program(p, {"x": x, "y": y}, rows, backend="numpy")["z"]
    for got in (ref, pal, npy):
        assert np.array_equal(np.asarray(got, np.uint64), want)


def test_mul_backends():
    p = _prog("mul16", lambda: bs.build_mul(16))
    rng = np.random.default_rng(0)
    x = rng.integers(0, 2 ** 16, 100).astype(np.uint64)
    y = rng.integers(0, 2 ** 16, 100).astype(np.uint64)
    pal = kops.run_program(p, {"x": x, "y": y}, 100, backend="pallas")["z"]
    assert np.array_equal(np.asarray(pal, np.uint64), x * y)


def test_div_backends():
    p = _prog("div8", lambda: bs.build_div(8))
    rng = np.random.default_rng(1)
    d = rng.integers(1, 256, 64).astype(np.uint64)
    q = rng.integers(0, 256, 64).astype(np.uint64)
    r = (rng.random(64) * d).astype(np.uint64)
    z = q * d + r
    o = kops.run_program(p, {"z": z, "d": d}, 64, backend="pallas")
    assert np.array_equal(np.asarray(o["q"], np.uint64), q)
    assert np.array_equal(np.asarray(o["r"], np.uint64), r)


def test_fp16_add_element_parallel():
    """2k rows execute ONE shared program on the kernel -- the
    element-parallel model end to end."""
    p = _prog("fp16add", lambda: bsf.build_fp_add(FP16))
    rng = np.random.default_rng(2)
    xb = FP16.random_bits(rng, 333, emin=10, emax=20).astype(np.uint64)
    yb = FP16.random_bits(rng, 333, emin=10, emax=20).astype(np.uint64)
    got = kops.run_program(p, {"x": xb, "y": yb}, 333, backend="pallas")["z"]
    for i in range(333):
        want = FP16.op_exact("add", int(xb[i]), int(yb[i]))
        assert int(got[i]) == want


def test_pallas_matches_ref_on_random_program():
    from repro.core.gates import Builder
    b = Builder()
    x = b.input("x", 32)
    y = b.input("y", 32)
    z = b.vec_xor(b.vec_and(x, y), b.vec_or(x, y))
    b.output("z", z)
    p = b.finish()
    rng = np.random.default_rng(3)
    xs = rng.integers(0, 2 ** 32, 100).astype(np.uint64)
    ys = rng.integers(0, 2 ** 32, 100).astype(np.uint64)
    ref = kops.run_program(p, {"x": xs, "y": ys}, 100, backend="ref")["z"]
    pal = kops.run_program(p, {"x": xs, "y": ys}, 100, backend="pallas")["z"]
    assert np.array_equal(np.asarray(ref), np.asarray(pal))
    assert np.array_equal(np.asarray(pal, np.uint64), (xs & ys) ^ (xs | ys))
