"""Compound-program fusion (DESIGN.md §13): lazy expression graphs, the
cross-op composed netlist, the packed-domain reduction trees behind
pim.dot / pim.gemv, the weight-aware compiled-program LRU, and the
"expr" serving form.

The load-bearing claims under test: a fused chain is ONE compiled
program (single dispatch, single pack, single unpack), bit-exact against
the per-op unfused chain and the host oracle, across every schedule and
word layout; reductions stay in the packed word domain end to end.
"""

import io
import json

import numpy as np
import pytest

from repro import pim_ufunc as pim
from repro.core import pim_numerics as pn
from repro.kernels import ops as kops

SCHEDULES = ("slots", "slots-static", "dense")
LAYOUTS = ("rows32", "rows64")


# ----------------------------------------------------------------- helpers

def _host_int_chain(spec, leaves):
    """Exact host semantics of an int chain: per node, operands
    zero-extend to w = max child widths; add -> w+1 bits exact, sub ->
    mod 2**w, mul -> exact.  Returns (values, width)."""
    if isinstance(spec, int):
        return leaves[spec].astype(object), 4
    op, ls, rs = spec
    x, wx = _host_int_chain(ls, leaves)
    y, wy = _host_int_chain(rs, leaves)
    w = max(wx, wy)
    if op == "add":
        return x + y, w + 1
    if op == "sub":
        return (x - y) % (1 << w), w
    return x * y, 2 * w


def _lazy_int_chain(spec, leaves):
    if isinstance(spec, int):
        return pim.lazy(leaves[spec], width=4)
    op, ls, rs = spec
    return getattr(pim, op)(_lazy_int_chain(ls, leaves),
                            _lazy_int_chain(rs, leaves))


def _lazy_fp_chain(spec, leaves, fmt):
    if isinstance(spec, int):
        return pim.lazy(leaves[spec]) if fmt is None \
            else pim.lazy(leaves[spec], fmt=fmt)
    op, ls, rs = spec
    return getattr(pim, "fp_" + op)(_lazy_fp_chain(ls, leaves, fmt),
                                    _lazy_fp_chain(rs, leaves, fmt))


def _eager_fp_chain(spec, leaves, fmt, **kw):
    """The unfused reference: the same chain as per-op eager ufunc calls
    (one pack/execute/unpack round trip per node)."""
    if isinstance(spec, int):
        return leaves[spec]
    op, ls, rs = spec
    return getattr(pim, "fp_" + op)(
        _eager_fp_chain(ls, leaves, fmt, **kw),
        _eager_fp_chain(rs, leaves, fmt, **kw),
        **(kw if fmt is None else dict(kw, fmt=fmt)))


def _rand_chain(rng, n_ops):
    """A random left-ish chain spec of ``n_ops`` nodes over n_ops+1
    leaves, mixing add/sub/mul (at most two muls so int widths stay in
    uint64 range)."""
    muls = 0
    spec = 0
    for i in range(n_ops):
        op = rng.choice(["add", "sub", "mul"])
        if op == "mul":
            if muls >= 2:
                op = rng.choice(["add", "sub"])
            else:
                muls += 1
        spec = (op, spec, i + 1) if rng.random() < 0.7 \
            else (op, i + 1, spec)
    return spec


def _host_fp16_tree_sum(prods, total):
    """Same-shape host reference for the in-memory fp16 adder tree."""
    p = np.zeros(total, np.float16)
    p[:len(prods)] = prods
    while len(p) > 1:
        h = len(p) // 2
        p = (p[:h] + p[h:]).astype(np.float16)
    return p[0]


# ------------------------------------------- chain parity: schedules/layouts

@pytest.mark.parametrize("schedule", SCHEDULES)
@pytest.mark.parametrize("layout", LAYOUTS)
def test_fused_chain_parity_all_schedules_layouts(schedule, layout):
    """One int + one fp16 + one bf16 depth-3 chain, fused, on every
    schedule x word layout, vs the exact host oracle / eager references
    computed once on the numpy backend."""
    rng = np.random.default_rng(7)
    kw = dict(backend="ref", schedule=schedule, layout=layout)
    n = 33                                   # exercises rows64 padding

    ints = [rng.integers(0, 16, n).astype(np.uint64) for _ in range(4)]
    spec = ("add", ("mul", 0, 1), ("sub", 2, 3))
    want, _ = _host_int_chain(spec, ints)
    got = _lazy_int_chain(spec, ints).run(**kw)
    assert [int(v) for v in got] == [int(v) for v in want]

    fps = [rng.standard_normal(n).astype(np.float16) for _ in range(4)]
    gotf = _lazy_fp_chain(spec, fps, None).run(**kw)
    wantf = ((fps[0] * fps[1]).astype(np.float16)
             + (fps[2] - fps[3]).astype(np.float16))
    assert gotf.dtype == np.float16
    assert np.array_equal(gotf.view(np.uint16), wantf.view(np.uint16))

    bits = [((rng.integers(100, 140, n) << 7)
             | rng.integers(0, 128, n)).astype(np.uint64)
            for _ in range(4)]               # positive normal bf16 patterns
    gotb = _lazy_fp_chain(spec, bits, "bf16").run(**kw)
    wantb = _eager_fp_chain(spec, bits, "bf16", backend="numpy")
    assert np.array_equal(np.asarray(gotb, np.uint64),
                          np.asarray(wantb, np.uint64))


def test_randomized_chains_fused_vs_unfused():
    """Randomized chains, depth (op count) 2..5, fused result bit-equal
    to the per-op unfused chain and (int) the exact host oracle."""
    rng = np.random.default_rng(11)
    for n_ops in (2, 3, 4, 5):
        spec = _rand_chain(rng, n_ops)
        ints = [rng.integers(0, 16, 40).astype(np.uint64)
                for _ in range(n_ops + 1)]
        want, _ = _host_int_chain(spec, ints)
        got = _lazy_int_chain(spec, ints).run(backend="ref")
        assert [int(v) for v in got] == [int(v) for v in want], spec

        fps = [rng.standard_normal(40).astype(np.float16)
               for _ in range(n_ops + 1)]
        gotf = _lazy_fp_chain(spec, fps, None).run(backend="ref")
        wantf = _eager_fp_chain(spec, fps, None, backend="numpy")
        assert np.array_equal(gotf.view(np.uint16),
                              wantf.view(np.uint16)), spec


def test_fused_chain_is_one_program_one_pack_one_unpack():
    """The acceptance claim: a depth-3 fused chain executes as ONE
    compiled program -- one levelized dispatch, one input pack, one
    output unpack -- where the unfused chain needs one per op."""
    rng = np.random.default_rng(3)
    a, b, c = (rng.integers(0, 256, 65).astype(np.uint64)
               for _ in range(3))
    calls = []
    orig_d = kops._dispatch_levelized

    def count_d(*args, **kw):
        calls.append(kw.get("packed_in") is None)
        return orig_d(*args, **kw)

    kops._dispatch_levelized = count_d
    try:
        e = (pim.lazy(a, width=8) * pim.lazy(b, width=8)) \
            + pim.lazy(c, width=8)
        out = e.run(backend="ref")
        # one dispatch == one compiled program == one pack + one unpack
        # (the dispatch packs its value inputs and unpacks its own ports)
        assert calls == [True]
        calls.clear()
        unfused = pim.add(pim.mul(a, b, width=8, backend="ref"), c,
                          width=16, backend="ref")
        assert len(calls) == 2              # one dispatch per op
    finally:
        kops._dispatch_levelized = orig_d
    assert np.array_equal(out, a * b + c)
    assert np.array_equal(unfused, a * b + c)


def test_fusion_validation():
    a = np.arange(4, dtype=np.uint8)
    la = pim.lazy(a)
    with pytest.raises(TypeError):
        pim.div(la, la)                      # division does not fuse
    with pytest.raises(TypeError):
        pim.fp_div(pim.lazy(a.astype(np.float16)), np.float16(1))
    with pytest.raises(TypeError):
        pim.add(la, pim.lazy(a.astype(np.float16)))   # kind mismatch
    with pytest.raises(TypeError):
        pim.fp_add(pim.lazy(np.full(4, 0x3f80, np.uint64), fmt="bf16"),
                   pim.lazy(a.astype(np.float16)))    # fmt mismatch
    with pytest.raises(TypeError):
        pim.add(la, la, backend="ref")       # exec kw on a lazy node
    with pytest.raises(ValueError):
        pim.fuse(la + la, parallel=True)     # bit-parallel cannot fuse
    with pytest.raises(TypeError):
        pim.fuse(a)                          # not a LazyExpr


# --------------------------------------------------------- dot / gemv oracle

@pytest.mark.parametrize("n", [1, 31, 64, 1000])
def test_dot_int_vs_numpy(n):
    rng = np.random.default_rng(n)
    x = rng.integers(0, 256, n).astype(np.uint64)
    y = rng.integers(0, 256, n).astype(np.uint64)
    want = int(np.dot(x.astype(object), y.astype(object)))
    assert int(pim.dot(x, y, width=8, backend="ref")) == want


@pytest.mark.parametrize("n", [17, 48])
def test_dot_fp16_tree_order_nonpow2(n):
    """Non-power-of-two reduction widths: zero rows pad to the tree and
    the result is the same-shape host tree sum, bit-exact."""
    rng = np.random.default_rng(n)
    x = rng.standard_normal(n).astype(np.float16)
    y = rng.standard_normal(n).astype(np.float16)
    got = pim.dot(x, y, backend="ref")
    total = 1
    while total < n:
        total *= 2
    want = _host_fp16_tree_sum((x * y).astype(np.float16), total)
    assert got.dtype == np.float16
    assert got.view(np.uint16) == want.view(np.uint16)


def test_dot_fused_equals_unfused_fallback():
    """fused=False runs the identical pairing through per-op round trips;
    results must be bit-identical (int and fp16, non-pow2 length)."""
    rng = np.random.default_rng(5)
    x = rng.integers(0, 256, 37).astype(np.uint64)
    y = rng.integers(0, 256, 37).astype(np.uint64)
    assert int(pim.dot(x, y, width=8, backend="ref")) == \
        int(pim.dot(x, y, width=8, backend="ref", fused=False))
    xf = rng.standard_normal(37).astype(np.float16)
    yf = rng.standard_normal(37).astype(np.float16)
    a = pim.dot(xf, yf, backend="ref")
    b = pim.dot(xf, yf, backend="ref", fused=False)
    assert a.view(np.uint16) == b.view(np.uint16)


@pytest.mark.parametrize("m", [1, 31, 64, 1000])
def test_gemv_int_vs_numpy(m):
    rng = np.random.default_rng(m)
    k = 17                                   # non-pow2 reduction width
    a = rng.integers(0, 16, (m, k)).astype(np.uint64)
    x = rng.integers(0, 16, k).astype(np.uint64)
    got = pim.gemv(a, x, width=4, backend="ref")
    want = a @ x
    assert np.array_equal(np.asarray(got, np.uint64), want)


def test_gemv_fp16_vs_host_tree():
    rng = np.random.default_rng(9)
    m, k = 5, 12
    a = rng.standard_normal((m, k)).astype(np.float16)
    x = rng.standard_normal(k).astype(np.float16)
    got = pim.gemv(a, x, backend="ref")
    assert got.dtype == np.float16
    want = np.array([_host_fp16_tree_sum((a[i] * x).astype(np.float16),
                                         16) for i in range(m)],
                    np.float16)
    assert np.array_equal(got.view(np.uint16), want.view(np.uint16))


def test_reduce_sum_of_fused_expression():
    """The elementwise stage of a reduction can itself be a fused chain:
    sum((a*b)+c) executes the chain program once, in the tree."""
    rng = np.random.default_rng(13)
    a, b, c = (rng.integers(0, 16, 20).astype(np.uint64)
               for _ in range(3))
    e = (pim.lazy(a, width=4) * pim.lazy(b, width=4)) \
        + pim.lazy(c, width=4)
    got = pim.reduce_sum(e, backend="ref")
    assert int(got) == int(np.sum(a * b + c))


def test_dot_packed_domain_single_pack_unpack():
    """An 8k-row dot stays in the packed word domain: exactly one
    value-domain pack (the products' operands) and one single-row unpack
    (the scalar), with log2(8192) + 1 dispatches in between."""
    rng = np.random.default_rng(17)
    x = rng.integers(0, 256, 8000).astype(np.uint64)
    y = rng.integers(0, 256, 8000).astype(np.uint64)
    packs, unpacks = [], []
    orig_d, orig_u = kops._dispatch_levelized, kops._unpack_sub

    def count_d(*args, **kw):
        packs.append(kw.get("packed_in") is None)
        return orig_d(*args, **kw)

    def count_u(*args, **kw):
        unpacks.append(1)
        return orig_u(*args, **kw)

    kops._dispatch_levelized, kops._unpack_sub = count_d, count_u
    try:
        got = pim.dot(x, y, width=8, backend="ref")
    finally:
        kops._dispatch_levelized, kops._unpack_sub = orig_d, orig_u
    assert int(got) == int(np.dot(x.astype(object), y.astype(object)))
    assert sum(packs) == 1                   # only the product pack
    assert len(packs) == 1 + 13              # mul + log2(8192) tree levels
    assert len(unpacks) == 1                 # the final scalar


# --------------------------------------------- weight-aware compiled-LRU

def test_compiled_cache_weight_cap_and_min_resident():
    """Eviction accounts schedule size, not just entry count: a tiny
    weight cap evicts down to the min-resident floor, pinned entries
    survive weight pressure, and results stay bit-exact through it."""
    old_cap = kops.set_compiled_cache_cap(64)
    old_w = kops._COMPILED_WEIGHT_CAP
    kops._compiled.clear()
    pin_key = None
    try:
        progs = [pn.program_for("int-serial", "add", w)
                 for w in range(4, 12)]
        rng = np.random.default_rng(0)
        ins = {"x": rng.integers(0, 8, 33).astype(np.uint64),
               "y": rng.integers(0, 8, 33).astype(np.uint64)}
        want = [kops.run_program(p, ins, 33, backend="numpy")["z"]
                for p in progs]
        kops.run_program(progs[0], ins, 33, backend="ref")
        assert all(e.weight > 0 for e in kops._compiled.values())
        pin_key = kops.pin_program(progs[0], kops.make_plan(backend="ref"))
        kops.set_compiled_cache_cap(64, weight_cap=1)   # max pressure
        for p, wv in zip(progs, want):
            got = kops.run_program(p, ins, 33, backend="ref")["z"]
            assert np.array_equal(got, wv)
            unpinned = sum(1 for k in kops._compiled
                           if k not in kops._pinned)
            # the floor counts entries *besides* the protected fresh one
            assert unpinned <= kops._COMPILED_MIN_RESIDENT + 1
        assert pin_key in kops._compiled     # pinned survived the churn
        with pytest.raises(ValueError):
            kops.set_compiled_cache_cap(64, weight_cap=0)
    finally:
        if pin_key is not None:
            kops.unpin_program(pin_key)
        kops.set_compiled_cache_cap(old_cap, weight_cap=old_w)


# ------------------------------------------------------------- serving form

def test_serve_expr_request():
    from repro.launch import serve

    r = serve.pim_request({"op": "expr", "dtype": "uint8",
                           "expr": ["add", ["mul", "a", "b"], "c"],
                           "inputs": {"a": [3, 5], "b": [7, 9],
                                      "c": [1, 2]}})
    assert r["result"] == [22, 47]
    assert r["op"] == "expr" and r["fused_ops"] == 2
    r = serve.pim_request({"op": "expr", "dtype": "uint8",
                           "expr": ["div", "a", "b"],
                           "inputs": {"a": [4], "b": [2]}})
    assert r["error"]["code"] == "bad_request"
    r = serve.pim_request({"op": "expr", "dtype": "uint8",
                           "expr": ["add", "a", "missing"],
                           "inputs": {"a": [1]}})
    assert r["error"]["code"] == "bad_request"


def test_serve_batched_expr_coalescing_and_stats():
    """Two identical-structure expr requests coalesce into one group; the
    run's stats count the fused programs."""
    from repro.launch import serve

    reqs = [{"op": "expr", "dtype": "uint8",
             "expr": ["add", ["mul", "a", "b"], "c"],
             "inputs": {"a": [i, 2], "b": [3, 4], "c": [5, 6]}}
            for i in range(2)]
    reqs.append({"op": "add", "dtype": "uint8", "x": [1], "y": [1]})
    inp = io.StringIO("\n".join(json.dumps(r) for r in reqs) + "\n")
    outp = io.StringIO()
    ret = serve.serve_pim_batched(inp, outp, window_ms=200.0, stats=False)
    lines = [json.loads(l) for l in outp.getvalue().splitlines()]
    assert ret["errors"] == 0 and ret["fused_programs"] == 2
    for i, l in enumerate(lines[:2]):
        assert l["result"] == [i * 3 + 5, 2 * 4 + 6]
        assert l["fused_ops"] == 2
    assert lines[2]["result"] == [2]
    if lines[0]["batched"] == 2:             # same window -> one group
        assert lines[1]["batched"] == 2


def test_batch_runtime_counts_fused_programs():
    from repro.runtime.pim_batch import BatchRuntime

    rng = np.random.default_rng(1)
    a = rng.integers(0, 16, 8).astype(np.uint64)
    e = pim.lazy(a, width=4) * pim.lazy(a, width=4)
    fused = pim.fuse(e + pim.lazy(a, width=4), backend="ref")
    plain = pim.prepare("add", a, a, width=4, backend="ref")
    rt = BatchRuntime(pin_cap=0)
    try:
        res = rt.execute([fused, plain])
        assert rt.stats.fused_programs == 1
        assert "fused=1" in rt.stats.summary()
        assert np.array_equal(res[0].value, a * a + a)
    finally:
        rt.close()
