"""Backend autotuner (DESIGN.md §16): the default configuration can never
lose the sweep, winners persist/install as a per-target tuned.json, the
plan-resolution overlay applies tuned values only to knobs the caller left
at hand defaults, and the CLI quick path runs end to end."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro import pim_ufunc as pim
from repro.kernels import plan as kplan
from repro.runtime import tune
from repro.runtime.artifact_cache import device_target

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(autouse=True)
def _clean_overlay():
    kplan.clear_tuned()
    yield
    kplan.clear_tuned()


def test_candidates_default_first():
    for quick in (False, True):
        cands = tune.candidates(quick)
        assert cands[0] == {}, "hand defaults must be the baseline"
        keys = [json.dumps(c, sort_keys=True) for c in cands]
        assert len(keys) == len(set(keys)), "duplicate sweep point"


def test_parse_family():
    assert tune.parse_family("add:16") == ("add", {"width": 16})
    assert tune.parse_family("fp_mul:fp16") == ("fp_mul", {"fmt": "fp16"})
    with pytest.raises(ValueError):
        tune.parse_family("add")
    with pytest.raises(ValueError):
        tune.parse_family("fp_add:fp11")


def test_tune_family_never_loses_to_defaults():
    """The safety property the tracked benchmark rows rely on: whatever
    the sweep measures, the winner's wall time is <= the hand-default
    candidate's, because defaults are swept first and only strictly
    faster candidates replace them."""
    e = tune.tune_family("add:16", rows=256, reps=1, quick=True)
    assert e["candidates"][0]["overrides"] == {}
    assert e["us"] <= e["default_us"]
    assert e["model_cycles"] > 0


def test_save_install_and_overlay(tmp_path):
    """A tuned.json round-trip: save to a cache directory, install, and
    the ufunc frontend resolves tuned values -- but only onto knobs left
    at hand defaults; explicit choices and ``tuned=False`` win."""
    doc = {"version": tune.DOC_VERSION, "target": device_target(),
           "entries": [{"family": "add:16", "layout": "rows32",
                        "backend": "ref",
                        "overrides": {"slot_width": 4,
                                      "schedule": "dense"}}]}
    path = tune.save(doc, str(tmp_path))
    assert os.path.basename(path) == "tuned.json"
    assert tune.install(path) == 1

    x = np.arange(64, dtype=np.uint16)
    prep = pim.prepare("add", x, x, width=16)
    assert prep.plan.backend.slot_width == 4
    assert prep.plan.schedule == "dense"

    # an explicit schedule beats the overlay; untouched knobs still tune
    prep = pim.prepare("add", x, x, width=16, schedule="slots-static")
    assert prep.plan.schedule == "slots-static"
    assert prep.plan.backend.slot_width == 4

    # a different family is untouched
    prep = pim.prepare("mul", x, x, width=16)
    assert prep.plan.schedule == kplan.DEFAULT_SCHEDULE

    # tuned=False forces hand defaults wholesale
    with pim.options(tuned=False):
        prep = pim.prepare("add", x, x, width=16)
    assert prep.plan.schedule == kplan.DEFAULT_SCHEDULE
    assert prep.plan.backend.slot_width == \
        kplan.BACKENDS["ref"].slot_width


def test_save_merges_per_target(tmp_path):
    base = {"version": tune.DOC_VERSION, "target": device_target(),
            "entries": [{"family": "add:16", "layout": "rows32",
                         "backend": "ref", "overrides": {"slot_width": 4}}]}
    tune.save(base, str(tmp_path))
    update = {"version": tune.DOC_VERSION, "target": device_target(),
              "entries": [{"family": "mul:16", "layout": "rows32",
                           "backend": "ref",
                           "overrides": {"slot_width": 8}}]}
    path = tune.save(update, str(tmp_path))
    with open(path) as f:
        merged = json.load(f)
    fams = {e["family"] for e in merged["entries"]}
    assert fams == {"add:16", "mul:16"}


def test_install_skips_other_targets_and_versions(tmp_path):
    alien = {"version": tune.DOC_VERSION, "target": "tpu:v9",
             "entries": [{"family": "add:16", "layout": "rows32",
                          "backend": "ref", "overrides": {"slot_width": 4}}]}
    assert tune.install(alien) == 0
    stale = {"version": tune.DOC_VERSION + 1, "target": device_target(),
             "entries": alien["entries"]}
    assert tune.install(stale) == 0
    # defaults-won entries (empty overrides) install nothing either
    nop = {"version": tune.DOC_VERSION, "target": device_target(),
           "entries": [{"family": "add:16", "layout": "rows32",
                        "backend": "ref", "overrides": {}}]}
    assert tune.install(nop) == 0


def test_register_tuned_rejects_bad_overrides():
    with pytest.raises((KeyError, ValueError)):
        kplan.register_tuned("add:16", "rows32", "ref", {"bogus_knob": 1})
    with pytest.raises((KeyError, ValueError)):
        kplan.register_tuned("add:16", "rows32", "ref",
                             {"schedule": "verilog"})


def test_tune_cli_quick_smoke(tmp_path):
    """The tier-1-adjacent CLI smoke: a --quick sweep of one family writes
    a valid tuned.json beside the artifact cache."""
    out = tmp_path / "cache"
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.runtime.tune", "--quick",
         "--families", "add:16", "--rows", "128", "--reps", "1",
         "--out", str(out)],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "add:16" in proc.stdout
    with open(out / "tuned.json") as f:
        doc = json.load(f)
    assert doc["version"] == tune.DOC_VERSION
    (e,) = doc["entries"]
    assert e["family"] == "add:16" and e["us"] <= e["default_us"]
