"""Contiguous-slot schedules and their executors (DESIGN.md §9): layout
invariants, copy-gate accounting, bit-exact parity of the scan and
straight-line (static) emissions against the cycle-accurate numpy oracle
across every memoized build_* program family, and buffer donation."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import bitparallel as bp
from repro.core import bitparallel_fp as bpf
from repro.core import bitserial as bs
from repro.core import bitserial_fp as bsf
from repro.core.floatfmt import BF16, FP16
from repro.core.gates import Builder, levelize
from repro.kernels import ops as kops
from repro.kernels import slots as kslots

# every memoized build_* constructor family: serial + parallel, fixed + FP
ALL_PROGRAMS = [
    ("add16", lambda: bs.build_add(16)),
    ("sub16", lambda: bs.build_sub(16)),
    ("mul8", lambda: bs.build_mul(8)),
    ("div8", lambda: bs.build_div(8)),
    ("fp16_add", lambda: bsf.build_fp_add(FP16)),
    ("fp16_mul", lambda: bsf.build_fp_mul(FP16)),
    ("fp16_div", lambda: bsf.build_fp_div(FP16)),
    ("bf16_add", lambda: bsf.build_fp_add(BF16)),
    ("bp_add16", lambda: bp.build_bp_add(16)),
    ("bp_mul8", lambda: bp.build_bp_mul(8)),
    ("bp_fp16_add", lambda: bpf.build_bp_fp_add(FP16)),
    ("bp_fp16_mul", lambda: bpf.build_bp_fp_mul(FP16)),
]


def _rand_inputs(prog, rows, seed):
    rng = np.random.default_rng(seed)
    return {n: np.array([int(x) for x in rng.integers(
        0, 1 << min(len(prog.ports[n]), 62), rows)], np.uint64)
        for n in prog.in_ports}


# ------------------------------------------------------------- layout
@pytest.mark.parametrize("name,build", ALL_PROGRAMS)
def test_slot_layout_invariants(name, build):
    """Slot schedules deliver the static-offset contract: every level's
    outputs are one contiguous band at ``out[l, 0]``, stacked input cells
    form one run starting at cell 0, and stacked output finals form one
    run."""
    prog = build()
    sched = levelize(prog, alloc="slots", max_width=8)
    assert sched.alloc == "slots" and sched.slot_width == 8
    out_expect = sched.out[:, :1] + np.arange(sched.width, dtype=np.int32)
    assert np.array_equal(sched.out, out_expect)
    stacked_in = [c for n in sorted(sched.in_cells)
                  for c in sched.in_cells[n]]
    assert stacked_in == list(range(len(stacked_in)))
    names = sorted(sched.out_ports or sched.ports)
    outs = [c for n in names for c in sched.ports[n]]
    assert outs == list(range(outs[0], outs[0] + len(outs)))
    # pad lanes read cell 0, which no level ever writes
    assert int(sched.out.min()) > 0 or sched.n_levels == 0


@pytest.mark.parametrize("name,build", ALL_PROGRAMS[:6])
def test_slot_hazard_freedom(name, build):
    """Within a level no lane (real or pad) reads a cell the level writes,
    and output indices stay unique."""
    sched = levelize(build(), alloc="slots", max_width=8)
    for l in range(sched.n_levels):
        outs = sched.out[l]
        assert len(set(outs.tolist())) == len(outs)
        w = sched.level_width[l]
        written = set(outs.tolist())            # incl. the slot's pad tail
        reads = set(sched.a[l, :w].tolist()) | set(sched.b[l, :w].tolist())
        assert not (written & reads)


@pytest.mark.parametrize("name,build", ALL_PROGRAMS)
def test_slot_mode_preserves_cost_and_reports_copies(name, build):
    """Slot allocation is an executor artifact: the Program's cost model is
    byte-identical before/after, and inserted copy gates are reported
    separately from the DCE'd gate count."""
    prog = build()
    before = prog.cost().as_dict()
    pbefore = prog.parallel_cost()
    dense = levelize(prog, max_width=8)
    sched = levelize(prog, alloc="slots", max_width=8)
    assert prog.cost().as_dict() == before
    after = prog.parallel_cost()
    if pbefore is None:
        assert after is None
    else:
        assert after.as_dict() == pbefore.as_dict()
    # n_gates excludes copies; the dense schedule agrees on the gate count
    assert sched.n_gates == dense.n_gates
    assert sched.copy_gates % 2 == 0
    if sched.copy_gates:
        names = sorted(sched.out_ports or sched.ports)
        k = sum(len(sched.ports[n]) for n in names)
        assert sched.copy_gates == 2 * k
    # copy lanes appear in the dense form but never in the cost model
    total_lanes = int(sched.level_width.sum())
    assert total_lanes == sched.n_gates + sched.copy_gates


# ------------------------------------------------------------- executors
@pytest.mark.parametrize("name,build", ALL_PROGRAMS)
def test_scan_executors_match_numpy_oracle(name, build):
    """Bit-exact parity of the slot scan executors (ref + pallas) against
    the cycle-accurate numpy oracle, for all build_* families."""
    prog = build()
    rows = 37
    ins = _rand_inputs(prog, rows, hash(name) & 0xFFFF)
    want = kops.run_program(prog, ins, rows, backend="numpy")
    for backend in ("ref", "pallas"):
        got = kops.run_program(prog, ins, rows, backend=backend,
                               schedule="slots")
        for port in want:
            assert np.array_equal(np.asarray(got[port], np.uint64),
                                  np.asarray(want[port], np.uint64)), \
                (backend, port)


@pytest.mark.parametrize("name,build", ALL_PROGRAMS[:8])
def test_static_executor_matches_numpy_oracle(name, build):
    """The straight-line (schedule-to-jaxpr) emission is bit-exact too,
    including across segment boundaries (seg_levels exercised well below
    the default so multi-segment chains are covered)."""
    prog = build()
    rows = 19
    ins = _rand_inputs(prog, rows, hash(name) & 0xFFF)
    want = kops.run_program(prog, ins, rows, backend="numpy")
    got = kops.run_program(prog, ins, rows, backend="ref",
                           schedule="slots-static")
    for port in want:
        assert np.array_equal(np.asarray(got[port], np.uint64),
                              np.asarray(want[port], np.uint64)), port


def test_static_chain_segmentation_boundaries():
    """Short segments force live bands across many chain boundaries; the
    result must stay bit-exact."""
    prog = bsf.build_fp_add(FP16)
    sched = levelize(prog, alloc="slots", max_width=8)
    in_names = sorted(prog.in_ports)
    in_widths = tuple(len(sched.pack_cells(n)) for n in in_names)
    out_names = sorted(sched.out_ports)
    out_widths = tuple(len(sched.ports[n]) for n in out_names)
    in_cells = [c for n in in_names for c in sched.pack_cells(n)]
    run = kslots.build_static_chain(sched, in_widths, out_widths, out_names,
                                    in_cells, seg_levels=17, fused=True)
    rows = 11
    ins = _rand_inputs(prog, rows, 7)
    n_words = (rows + 31) // 32
    in_vals = np.zeros((len(in_names), n_words * 32), np.uint32)
    for p, n in enumerate(in_names):
        in_vals[p, :rows] = ins[n].astype(np.uint32)
    out = np.asarray(run(jnp.asarray(in_vals)))
    want = kops.run_program(prog, ins, rows, backend="numpy")
    for p, n in enumerate(out_names):
        assert np.array_equal(out[p, :rows].astype(np.uint64),
                              np.asarray(want[n], np.uint64)), n


def test_static_pallas_kernel_matches():
    """The rewritten static-slice Pallas kernel (zero dynamic indexing) is
    bit-exact on a multi-level program."""
    prog = bs.build_mul(8)
    rows = 23
    ins = _rand_inputs(prog, rows, 5)
    want = kops.run_program(prog, ins, rows, backend="numpy")
    got = kops.run_program(prog, ins, rows, backend="pallas",
                           schedule="slots-static")
    for port in want:
        assert np.array_equal(np.asarray(got[port], np.uint64),
                              np.asarray(want[port], np.uint64)), port


def test_slots_streaming_and_degenerate_programs():
    """Slot dispatch covers the streaming path and degenerate programs
    (passthrough, constant generator) via the documented fallbacks."""
    prog = bs.build_add(16)
    n = 1500
    rng = np.random.default_rng(3)
    x = rng.integers(0, 1 << 16, n).astype(np.uint64)
    y = rng.integers(0, 1 << 16, n).astype(np.uint64)
    out = kops.run_program_streaming(prog, {"x": x, "y": y}, n,
                                     backend="ref", chunk_rows=512,
                                     schedule="slots")["z"]
    assert np.array_equal(np.asarray(out, np.uint64), x + y)

    b = Builder()
    xs = b.input("x", 8)
    b.output("z", xs)
    p = b.finish()
    vals = np.arange(40, dtype=np.uint64) * 5 % 256
    for schedule in ("slots", "slots-static"):
        for backend in ("ref", "pallas"):
            got = kops.run_program(p, {"x": vals}, 40, backend=backend,
                                   schedule=schedule)["z"]
            assert np.array_equal(np.asarray(got, np.uint64), vals), \
                (backend, schedule)

    b = Builder()
    ones = [b.const(1) for _ in range(3)]
    b.output("z", ones + [b.const(0)])
    p = b.finish()
    for backend in ("ref", "pallas"):
        got = kops.run_program(p, {}, 9, backend=backend, schedule="slots")
        assert np.array_equal(np.asarray(got["z"], np.uint64),
                              np.full(9, 0b0111, np.uint64)), backend


def test_partial_inputs_agree_across_schedules():
    """Callers may provide a subset of the input ports (missing ports are
    zero); every schedule mode must agree -- the slots-static scatter
    fallback used to crash here."""
    prog = bs.build_add(8)
    x = np.arange(16, dtype=np.uint64) * 9 % 256
    want = kops.run_program(prog, {"y": x}, 16, backend="numpy")["z"]
    for schedule in ("slots", "slots-static", "dense"):
        for backend in ("ref", "pallas"):
            got = kops.run_program(prog, {"y": x}, 16, backend=backend,
                                   schedule=schedule)["z"]
            assert np.array_equal(np.asarray(got, np.uint64),
                                  np.asarray(want, np.uint64)), \
                (backend, schedule)


def test_butterfly_transpose_roundtrip():
    """pack_values/unpack_values are inverse bijections and match the
    bit-definition (bit w of word i is row 32*i + w)."""
    rng = np.random.default_rng(0)
    widths = (16, 7, 32)
    vals = np.stack([rng.integers(0, 1 << w, 96).astype(np.uint32)
                     for w in widths])
    packed = np.asarray(kslots.pack_values(jnp.asarray(vals), widths))
    off = 0
    for p, w in enumerate(widths):
        for c in range(w):
            for i in range(3):
                word = int(packed[off + c, i])
                for r in range(32):
                    assert (word >> r) & 1 == (int(vals[p, 32 * i + r])
                                               >> c) & 1
        off += w
    back = np.asarray(kslots.unpack_values(jnp.asarray(packed), widths))
    assert np.array_equal(back, vals)


# ------------------------------------------------------------- donation
def test_ref_level_state_donation():
    """pim_exec_ref_level consumes its state buffer in place: the donated
    input is invalidated, i.e. no defensive copy exists."""
    from repro.kernels.ref import pim_exec_ref_level
    la = jnp.zeros((1, 2), jnp.int32)
    lo = jnp.asarray(np.array([[2, 3]], np.int32))
    st = jnp.asarray(np.arange(8, dtype=np.uint32).reshape(4, 2))
    out = pim_exec_ref_level(st, la, la, lo)
    assert out.shape == (4, 2)
    assert st.is_deleted()          # buffer donated, not copied


def test_level_padded_state_donation():
    """pim_exec_level_padded donates its padded state argument."""
    from repro.kernels import pim_exec
    n_cells = 3
    st = jnp.zeros((n_cells, pim_exec.TILE_W), jnp.uint32)
    la = jnp.zeros((1, 1), jnp.int32)
    lo = jnp.asarray(np.array([[1]], np.int32))
    out = pim_exec.pim_exec_level_padded(st, la, la, lo, n_cells=n_cells)
    assert out.shape == (n_cells, pim_exec.TILE_W)
    assert st.is_deleted()


def test_slots_default_matches_dense_everywhere():
    """The flipped default (schedule='slots') is invisible to callers:
    dense and slot paths agree bit-exactly on the ufunc frontend."""
    from repro import pim_ufunc as pim
    rng = np.random.default_rng(11)
    x = rng.integers(0, 1 << 16, 200).astype(np.uint16)
    y = rng.integers(0, 1 << 16, 200).astype(np.uint16)
    a = pim.add(x, y)
    b = pim.add(x, y, schedule="dense")
    c = pim.add(x, y, schedule="slots-static")
    assert np.array_equal(a, b) and np.array_equal(a, c)
