"""Minimal deterministic stand-in for the ``hypothesis`` API surface this
suite uses (``given``, ``settings``, ``strategies.integers/lists/
sampled_from/data``).

Installed by ``conftest.py`` as the ``hypothesis`` module only when the real
package is missing, so `pytest -x -q` collects and runs on a bare
environment.  Example generation is seeded per test name (zlib.crc32), so
runs are reproducible; the first two examples pin every strategy to its
min/max boundary, the rest are pseudo-random.
"""

from __future__ import annotations


import random
import types
import zlib


class _Strategy:
    def __init__(self, sample):
        self._sample = sample


def integers(min_value, max_value):
    def sample(rng, idx):
        if idx == 0:
            return min_value
        if idx == 1:
            return max_value
        return rng.randint(min_value, max_value)
    return _Strategy(sample)


def sampled_from(elements):
    elements = list(elements)

    def sample(rng, idx):
        return elements[idx % len(elements)] if idx < 2 \
            else rng.choice(elements)
    return _Strategy(sample)


def lists(elements, min_size=0, max_size=10):
    def sample(rng, idx):
        size = min_size if idx == 0 else (
            max_size if idx == 1 else rng.randint(min_size, max_size))
        return [elements._sample(rng, 2 + rng.randint(0, 1 << 30))
                for _ in range(size)]
    return _Strategy(sample)


class _DataStrategy:
    pass


def data():
    return _DataStrategy()


class _DataObject:
    def __init__(self, rng):
        self._rng = rng

    def draw(self, strategy, label=None):
        return strategy._sample(self._rng, 2 + self._rng.randint(0, 1 << 30))


def settings(max_examples=20, **_kwargs):
    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn
    return deco


def given(*strategies):
    def deco(fn):
        # the runner must expose a ZERO-argument signature (pytest would
        # otherwise read the wrapped function's parameters as fixtures)
        def runner():
            # @settings may sit outside @given (attribute lands on runner)
            # or inside (attribute lands on fn); honor both orders
            n = getattr(runner, "_shim_max_examples",
                        getattr(fn, "_shim_max_examples", 20))
            rng = random.Random(zlib.crc32(fn.__name__.encode()))
            for idx in range(n):
                vals = []
                for s in strategies:
                    if isinstance(s, _DataStrategy):
                        vals.append(_DataObject(rng))
                    else:
                        vals.append(s._sample(rng, idx))
                fn(*vals)
        runner.__name__ = fn.__name__
        runner.__doc__ = fn.__doc__
        return runner
    return deco


strategies = types.ModuleType("hypothesis.strategies")
strategies.integers = integers
strategies.lists = lists
strategies.sampled_from = sampled_from
strategies.data = data
