"""ExecPlan pipeline (DESIGN.md §11): plan normalization/validation,
WordLayout bridge round-trips (rows32 + rows64 across the row/width edge
grid), bit-exact rows64 vs rows32 executor parity across every memoized
build_* family and all three schedules, plan-keyed group separation, and
the pin-vs-LRU-cap regression audit."""

import numpy as np
import pytest

from repro import pim_ufunc as pim
from repro.core import bitparallel, bitparallel_fp, bitserial, bitserial_fp
from repro.core.floatfmt import FP16, FORMATS
from repro.core.pim_numerics import program_for
from repro.kernels import ops as kops
from repro.kernels import plan as kplan
from repro.kernels import slots as kslots
from repro.runtime import pim_batch as pb


# ----------------------------------------------------------- plan normalize

def test_as_plan_normalization_and_validation():
    p = kops.as_plan(backend="ref", schedule="dense", layout="rows64")
    assert p.backend.name == "ref" and p.schedule == "dense"
    assert p.layout is kplan.ROWS64 and p.layout.rows_per_word == 64
    # a ready plan passes through untouched; overrides rebuild
    assert kops.as_plan(p) is p
    q = kops.as_plan(p, schedule="slots")
    assert q.schedule == "slots" and q.layout is kplan.ROWS64
    # positional backend-string convention still works
    assert kops.as_plan("pallas").backend.name == "pallas"
    assert kops.as_plan("pallas").backend.pad_to == kplan.TILE_W
    with pytest.raises(ValueError, match="unknown backend"):
        kops.as_plan(backend="cuda")
    with pytest.raises(ValueError, match="unknown schedule"):
        kops.as_plan(schedule="bogus")
    with pytest.raises(ValueError, match="unknown layout"):
        kops.as_plan(layout="rows128")
    with pytest.raises(ValueError, match="conflicting backends"):
        kops.as_plan("ref", backend="pallas")
    # layout/mesh constraints are enforced at construction, not dispatch
    with pytest.raises(ValueError, match="rows64"):
        kops.as_plan(backend="numpy", layout="rows64")
    with pytest.raises(TypeError):
        kops.as_plan(42)


def test_plan_keys_separate_every_dimension():
    base = kops.make_plan(backend="ref")
    assert base.key != kops.make_plan(backend="pallas").key
    assert base.key != kops.make_plan(backend="ref", schedule="dense").key
    assert base.key != kops.make_plan(backend="ref", layout="rows64").key
    assert base.key != kops.make_plan(backend="ref", chunk_rows=4096).key
    # a custom retuned Backend separates the group key too (its tunables
    # flatten into plan.key)
    retuned = kplan.Backend("ref", level_max_width=4)
    assert base.key != kops.make_plan(backend=retuned).key
    # compile_key tracks the artifact universe only: backend name, layout
    # and schedule kind are all excluded (ref/pallas share schedule
    # arrays; rows32/rows64 share every schedule artifact; one entry
    # lazily holds all schedule kinds) -- allocator tunables are included
    assert base.compile_key == kops.make_plan(backend="pallas").compile_key
    assert base.compile_key == \
        kops.make_plan(backend="ref", layout="rows64").compile_key
    assert base.compile_key == \
        kops.make_plan(backend="ref", schedule="dense").compile_key
    assert base.compile_key != kops.make_plan(backend=retuned).compile_key
    # chunk alignment follows the layout's word granularity
    assert kops.make_plan(chunk_rows=100).effective_chunk_rows == 128
    assert kops.make_plan(chunk_rows=100,
                          layout="rows64").effective_chunk_rows == 128
    assert kops.make_plan(chunk_rows=65,
                          layout="rows64").effective_chunk_rows == 128
    assert kops.make_plan(chunk_rows=1).effective_chunk_rows == 32


# -------------------------------------------------- WordLayout bridge tests

BRIDGE_ROWS = (0, 1, 31, 32, 33, 63, 64, 65)
BRIDGE_WIDTHS = (31, 32, 33, 64)


def _rand_width_vals(rng, rows, width):
    """Random row values of exactly `width` bits (object beyond 63)."""
    if width > 63:
        return np.array([int.from_bytes(rng.bytes(width // 8 + 1), "little")
                         & ((1 << width) - 1) for _ in range(rows)], object)
    return rng.integers(0, 1 << width, rows).astype(np.uint64) \
        if width < 64 else rng.integers(0, 1 << 63, rows).astype(np.uint64)


@pytest.mark.parametrize("layout_name", ["rows32", "rows64"])
def test_pack_unpack_round_trip_grid(layout_name):
    """pack_rows -> unpack_rows is the identity for every (rows, width)
    edge combination of both layouts, including the one_cell constant."""
    layout = kplan.LAYOUTS[layout_name]
    rng = np.random.default_rng(7)
    for rows in BRIDGE_ROWS:
        for width in BRIDGE_WIDTHS:
            vals = _rand_width_vals(rng, rows, width)
            ports = {"a": list(range(width)),
                     "b": list(range(width + 1, 2 * width + 1))}
            other = _rand_width_vals(rng, rows, width)
            n_cells = 2 * width + 2
            state = kops.pack_rows({"a": vals, "b": other}, ports, rows,
                                   n_cells, one_cell=width, pad_to=1,
                                   layout=layout)
            assert state.shape == layout.state_shape(
                n_cells, layout.n_words(rows, 1))
            # the folded INIT1 cell is all-ones in every plane
            assert (state[..., width, :] == np.uint32(0xFFFFFFFF)).all()
            got = kops.unpack_rows(state, ports, rows)
            for name, want in (("a", vals), ("b", other)):
                assert len(got[name]) == rows
                assert all(int(g) == int(w)
                           for g, w in zip(got[name], want)), \
                    (layout_name, rows, width, name)


def test_rows64_state_is_plane_split_of_rows32():
    """The paired layout is exactly the little-endian uint64 split of the
    rows32 words: plane h of word i == rows32 word 2i+h."""
    rng = np.random.default_rng(8)
    vals = rng.integers(0, 1 << 16, 130).astype(np.uint64)
    ports = {"v": list(range(16))}
    s32 = kops.pack_rows({"v": vals}, ports, 130, 16, pad_to=1,
                         layout=kplan.ROWS32)
    s64 = kops.pack_rows({"v": vals}, ports, 130, 16, pad_to=1,
                         layout=kplan.ROWS64)
    n64 = s64.shape[-1]
    # rows64 word-pairs cover ceil(130/64)*64 rows; pad the rows32 words
    # out to the same span before comparing strides
    w32 = np.zeros((16, 2 * n64), np.uint32)
    w32[:, :s32.shape[1]] = s32
    assert np.array_equal(s64[0], w32[:, 0::2])
    assert np.array_equal(s64[1], w32[:, 1::2])


@pytest.mark.parametrize("planes", [1, 2])
def test_pack_values_in_jit_round_trip(planes):
    """The fused in-jit butterfly bridges round-trip for both layouts and
    agree with the host packer."""
    import jax.numpy as jnp
    rng = np.random.default_rng(9)
    widths = (31, 32, 7)
    n_rows = 32 * planes * 3
    in_vals = np.stack([
        rng.integers(0, 1 << min(w, 32), n_rows).astype(np.uint32)
        for w in widths])
    packed = np.asarray(kslots.pack_values(jnp.asarray(in_vals), widths,
                                           planes))
    layout = kplan.ROWS32 if planes == 1 else kplan.ROWS64
    host = np.concatenate(
        [kops._pack_port_words(in_vals[p], w, layout.n_words(n_rows, 1),
                               layout)
         for p, w in enumerate(widths)], axis=-2)
    assert np.array_equal(packed, host)
    back = np.asarray(kslots.unpack_values(jnp.asarray(packed), widths,
                                           planes))
    assert np.array_equal(back, in_vals)


# ------------------------------------------- rows64 executor parity sweeps

def _family_cases():
    """One representative per memoized build_* family (pim_numerics
    program_for kinds), with oracle-checkable inputs."""
    rng = np.random.default_rng(11)
    n = 70                       # crosses the 64-row pair boundary
    x16 = rng.integers(0, 1 << 16, n).astype(np.uint64)
    y16 = rng.integers(0, 1 << 16, n).astype(np.uint64)
    d16 = rng.integers(1, 1 << 16, n).astype(np.uint64)
    fx = FP16.random_bits(rng, n, emin=10, emax=20).astype(np.uint64)
    fy = FP16.random_bits(rng, n, emin=10, emax=20).astype(np.uint64)
    return [
        ("int-serial", program_for("int-serial", "add", 16),
         {"x": x16, "y": y16}),
        ("int-serial-div", program_for("int-serial", "div", 16),
         {"z": x16, "d": d16}),
        ("int-parallel", program_for("int-parallel", "add", 16),
         {"x": x16, "y": y16}),
        ("fp-serial", program_for("fp-serial", "add", "fp16"),
         {"x": fx, "y": fy}),
        ("fp-parallel", program_for("fp-parallel", "mul", "fp16"),
         {"x": fx, "y": fy}),
    ]


@pytest.mark.parametrize("schedule", ["slots", "slots-static", "dense"])
def test_rows64_parity_all_families(schedule):
    """Acceptance: rows64 output is bit-exact with rows32 (and the numpy
    oracle) for every build_* family under every schedule.  The ref
    backend runs the full grid; the pallas executors share the exact same
    layout-polymorphic bodies and get their own cross-schedule check in
    :func:`test_rows64_parity_pallas` (running every family through the
    unrolled interpret-mode pallas kernels would double suite time for no
    added code coverage)."""
    for label, prog, inputs in _family_cases():
        n = len(next(iter(inputs.values())))
        want = kops.run_program(prog, inputs, n, "numpy")
        p32 = kops.make_plan(backend="ref", schedule=schedule,
                             layout="rows32")
        p64 = kops.make_plan(backend="ref", schedule=schedule,
                             layout="rows64")
        got32 = kops.run_program(prog, inputs, n, p32)
        got64 = kops.run_program(prog, inputs, n, p64)
        assert sorted(got32) == sorted(want) == sorted(got64)
        for port in want:
            assert np.array_equal(got32[port], want[port]), \
                (label, schedule, port)
            assert np.array_equal(got64[port], got32[port]), \
                (label, schedule, port)


def test_rows64_parity_pallas():
    """The pallas executor family (scan slot kernel, dense gather kernel,
    static-slice kernel) under both layouts, on the int-serial builders
    (divider included: two output ports)."""
    rng = np.random.default_rng(13)
    n = 70
    prog = program_for("int-serial", "div", 8)
    ins = {"z": rng.integers(0, 1 << 8, n).astype(np.uint64),
           "d": rng.integers(1, 1 << 8, n).astype(np.uint64)}
    want = kops.run_program(prog, ins, n, "numpy")
    for schedule in ("slots", "slots-static", "dense"):
        for layout in ("rows32", "rows64"):
            got = kops.run_program(prog, ins, n, kops.make_plan(
                backend="pallas", schedule=schedule, layout=layout))
            for port in want:
                assert np.array_equal(got[port], want[port]), \
                    (schedule, layout, port)


def test_rows64_ufunc_and_streaming_parity():
    rng = np.random.default_rng(12)
    n = 3000
    x = rng.integers(0, 1 << 16, n).astype(np.uint16)
    y = rng.integers(0, 1 << 16, n).astype(np.uint16)
    want = x.astype(np.uint64) + y.astype(np.uint64)
    got = pim.add(x, y, layout="rows64", chunk_rows=512)   # 6 chunks
    assert np.array_equal(got, want)
    with pim.options(layout="rows64"):
        assert pim.prepare("add", x, y).plan.layout is kplan.ROWS64
    assert pim.config.layout == "rows32"                   # scoped


def test_rows64_rejects_non_levelized_paths():
    prog = bitserial.build_add(8)
    x = np.arange(4, dtype=np.uint64)
    with pytest.raises(ValueError, match="rows64"):
        kops.run_program(prog, {"x": x, "y": x}, 4, "numpy",
                         layout="rows64")
    with pytest.raises(ValueError, match="rows64"):
        kops.run_program(prog, {"x": x, "y": x}, 4, "ref",
                         levelized=False, layout="rows64")


# ------------------------------------------------ plan-keyed group planning

def test_group_key_separates_word_layout():
    """Requests differing only in word layout must never coalesce (the
    packed states are shaped differently; merging would corrupt rows)."""
    x, y = np.uint8([1, 2]), np.uint8([3, 4])
    r32 = pim.prepare("add", x, y)
    r64 = pim.prepare("add", x, y, layout="rows64")
    assert r32.key == r64.key                     # same program structure
    assert pb.group_key(r32) != pb.group_key(r64)
    plan = pb.plan_groups([r32, r64, pim.prepare("add", x, y)])
    assert [g.members for g in plan] == [[0, 2], [1]]
    # ...and the merged group + the rows64 singleton both execute right
    rt = pb.BatchRuntime(pin_cap=4)
    try:
        res = rt.execute([r32, r64])
        assert np.array_equal(res[0].value, [4, 6])
        assert np.array_equal(res[1].value, [4, 6])
    finally:
        rt.close()


def test_group_key_covers_full_plan():
    """Every ExecPlan dimension lands in the group key -- including the
    per-backend tunables that used to be module globals."""
    x, y = np.uint8([5]), np.uint8([6])
    keys = {pb.group_key(pim.prepare("add", x, y, **kw))
            for kw in ({}, {"schedule": "dense"}, {"backend": "numpy"},
                       {"layout": "rows64"}, {"chunk_rows": 4096})}
    assert len(keys) == 5


# -------------------------------------------------- pin vs LRU-cap audit

def _mini_program(seed, n_gates=10):
    from repro.core.gates import Builder

    rng = np.random.default_rng(seed)
    b = Builder()
    avail = b.input("x", 8) + b.input("y", 8)
    for _ in range(n_gates):
        i, j = rng.integers(0, len(avail), 2)
        avail.append(b.nor(avail[i], avail[j]))
    b.output("z", avail[-8:])
    return b.finish()


def test_cap_shrink_below_pinned_count():
    """Regression (ISSUE 5 satellite): shrinking the LRU cap below the
    pinned count must never evict a pinned entry, must still evict the
    unpinned ones, and must leave no pin leak after release."""
    progs = [_mini_program(100 + i) for i in range(3)]
    cold = _mini_program(999)
    ins = {"x": np.arange(5, dtype=np.uint64) % 256,
           "y": np.arange(5, dtype=np.uint64) % 256}
    old_cap = kops.set_compiled_cache_cap(8)
    keys = []
    try:
        for p in progs:
            kops.run_program(p, ins, 5, "ref")
            keys.append(kops.pin_program(p))
        kops.run_program(cold, ins, 5, "ref")        # unpinned entry
        cold_key = kops.cache_key(cold)
        assert cold_key in kops._compiled
        kops.set_compiled_cache_cap(1)               # below pinned count
        for k in keys:
            assert k in kops._compiled               # pinned survive
            assert k in kops._pinned
        assert cold_key not in kops._compiled        # unpinned evicted
        assert len(kops._compiled) == 3              # over cap, all pinned
        # executions still resolve against the pinned (compiled) entries
        for p in progs:
            assert kops.is_compiled(p)
        # releasing pins lets the cache shrink back to cap
        for k in keys:
            assert kops.unpin_program(k) is False
        assert not kops._pinned
        assert len(kops._compiled) <= 1
    finally:
        for k in keys:                               # idempotent cleanup
            kops.unpin_program(k)
        kops.set_compiled_cache_cap(old_cap)


def test_saturated_cap_never_orphans_new_entries():
    """Regression (audit fix): with the cap fully saturated by pinned
    entries, compiling a *new* program must not evict the entry just
    created -- otherwise its artifacts are built on an orphaned object and
    a later pin lands on an empty twin (recompiling forever)."""
    pinned_progs = [_mini_program(200 + i) for i in range(2)]
    newcomer = _mini_program(300)
    ins = {"x": np.arange(3, dtype=np.uint64),
           "y": np.arange(3, dtype=np.uint64)}
    old_cap = kops.set_compiled_cache_cap(2)
    keys = []
    try:
        for p in pinned_progs:
            kops.run_program(p, ins, 3, "ref")
            keys.append(kops.pin_program(p))
        kops.set_compiled_cache_cap(1)               # saturated by pins
        kops.run_program(newcomer, ins, 3, "ref")
        # the just-compiled entry survived its own creation...
        assert kops.is_compiled(newcomer)
        # ...and pinning it pins the entry that holds the artifacts
        nk = kops.pin_program(newcomer)
        assert kops.is_compiled(newcomer)
        assert kops.unpin_program(nk) is False
    finally:
        for k in keys:
            kops.unpin_program(k)
        kops.set_compiled_cache_cap(old_cap)
    assert not kops._pinned


def test_pin_is_plan_scoped():
    """The LRU and the pin refcounts key on (structure, plan artifact
    identity): plans that share every compiled artifact -- rows32 vs
    rows64, ref vs pallas, slots vs dense (one entry lazily holds all
    schedule kinds) -- share one entry and one pin, while a retuned
    Backend (different allocator widths => different artifacts) gets its
    own entry that a default-plan pin does not cover."""
    prog = _mini_program(400)
    ins = {"x": np.arange(3, dtype=np.uint64),
           "y": np.arange(3, dtype=np.uint64)}
    retuned = kops.make_plan(backend=kplan.Backend("ref", slot_width=4))
    kops.run_program(prog, ins, 3, "ref")
    kops.run_program(prog, ins, 3, retuned)
    kdef = kops.cache_key(prog)
    kret = kops.cache_key(prog, retuned)
    assert kdef != kret
    # artifact-invariant plans dedup into the default entry
    for p in (kops.make_plan(backend="ref", layout="rows64"),
              kops.make_plan(backend="pallas"),
              kops.make_plan(backend="ref", schedule="dense")):
        assert kops.cache_key(prog, p) == kdef
    assert kops.is_compiled(prog) and kops.is_compiled(prog, retuned)
    # one entry, both schedule kinds: a dense run fills the same slot
    kops.run_program(prog, ins, 3, kops.make_plan(backend="ref",
                                                  schedule="dense"))
    assert kops.is_compiled(prog, kops.make_plan(backend="ref",
                                                 schedule="dense"))
    key = kops.pin_program(prog)                     # default plan only
    try:
        assert key == kdef
        assert kdef in kops._pinned and kret not in kops._pinned
    finally:
        assert kops.unpin_program(key) is False


# ---------------------------------------------------------- serve requests

def test_serve_request_layout_key():
    from repro.launch import serve
    r = serve.pim_request({"op": "add", "dtype": "uint8",
                           "x": [10, 20], "y": [1, 2],
                           "layout": "rows64"})
    assert r["result"] == [11, 22]
    bad = serve.pim_request({"op": "add", "dtype": "uint8",
                             "x": [1], "y": [2], "layout": "rows128"})
    assert bad["error"]["code"] == "bad_request"
    assert not bad["error"]["retriable"]
    assert "unknown layout" in bad["error"]["message"]
