"""Smoke test for the benchmark harness: the --json machine-readable mode
(the per-PR perf trajectory format) and the --only section filter."""

import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_bench_json_smoke(tmp_path):
    out = tmp_path / "bench.json"
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--only", "kernel",
         "--json", str(out)],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert proc.stdout.startswith("name,us_per_call,derived")

    doc = json.loads(out.read_text())
    assert doc["meta"]["suite"] == "aritpim-repro"
    names = {r["name"] for r in doc["rows"]}
    assert "kernel/fp16_add_8k_rows" in names
    for r in doc["rows"]:
        assert isinstance(r["us_per_call"], (int, float))
    row = next(r for r in doc["rows"]
               if r["name"] == "kernel/fp16_add_8k_rows")
    assert row["levelized"] == 1 and row["levels"] > 0
