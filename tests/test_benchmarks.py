"""Smoke tests for the benchmark harness: the --json machine-readable mode
(the per-PR perf trajectory format), the --only section filter, and the
--compare perf-regression gate."""

import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _bench_env():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    return env


def _run_bench(args, timeout=900):
    return subprocess.run(
        [sys.executable, "-m", "benchmarks.run"] + args,
        cwd=REPO, env=_bench_env(), capture_output=True, text=True,
        timeout=timeout)


def _fake_baseline(path, name, us):
    path.write_text(json.dumps(
        {"meta": {"suite": "aritpim-repro"},
         "rows": [{"name": name, "us_per_call": us}]}))


def test_bench_compare_gate(tmp_path):
    """--compare exits nonzero with a delta table when a tracked kernel row
    regresses past the threshold, and passes against a slow baseline.
    Measured on the cheap single-row section to keep the smoke test fast."""
    only = ["--only", "kernel/fp16_add_8k_rows_serial"]
    fast = tmp_path / "fast.json"
    _fake_baseline(fast, "kernel/fp16_add_8k_rows_serial", 0.001)
    proc = _run_bench(only + ["--compare", str(fast)])
    assert proc.returncode == 1, proc.stdout + proc.stderr
    assert "REGRESSED" in proc.stdout
    slow = tmp_path / "slow.json"
    _fake_baseline(slow, "kernel/fp16_add_8k_rows_serial", 1e9)
    proc = _run_bench(only + ["--compare", str(slow)])
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "perf gate: OK" in proc.stdout
    # untracked (non-kernel) rows never gate
    proc = _run_bench(["--only", "karatsuba/N8", "--compare", str(fast)])
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_bench_json_smoke(tmp_path):
    """The 8k-row kernel family emits in --json format, *and* the
    --compare BENCH_7.json gate runs as part of the tier-1-adjacent suite
    so word-layout regressions fail loudly here, not just in a manual
    benchmark run.  The compare threshold is loose (this host-shared CPU
    jitters; BENCH_8.json records the real figures) -- the hard in-test
    bar is the *relative* rows64-vs-rows32 assertion below, which load
    cannot skew."""
    out = tmp_path / "bench.json"
    proc = _run_bench(["--only", "kernel/fp16_add_8k_rows",
                       "--json", str(out), "--compare",
                       os.path.join(REPO, "BENCH_7.json"),
                       "--threshold", "100"], timeout=900)
    assert proc.returncode == 0, proc.stdout + proc.stderr[-2000:]
    assert proc.stdout.startswith("name,us_per_call,derived")
    assert "perf gate: OK" in proc.stdout

    doc = json.loads(out.read_text())
    assert doc["meta"]["suite"] == "aritpim-repro"
    names = {r["name"] for r in doc["rows"]}
    assert "kernel/fp16_add_8k_rows" in names
    assert "kernel/fp16_add_8k_rows_pallas_fused" in names
    assert "kernel/fp16_add_8k_rows_pallas_static" in names
    for r in doc["rows"]:
        assert isinstance(r["us_per_call"], (int, float))
    row = next(r for r in doc["rows"]
               if r["name"] == "kernel/fp16_add_8k_rows")
    assert row["levelized"] == 1 and row["levels"] > 0
    assert row["schedule"] == "slots"
    # the paired-uint32 layout row rides the same family and must stay
    # within noise of the rows32 anchor on CPU (identical bit volume; the
    # halved word axis pays off on 64-bit datapaths, not XLA:CPU)
    r64 = next(r for r in doc["rows"]
               if r["name"] == "kernel/fp16_add_8k_rows_rows64")
    assert r64["layout"] == "rows64" and r64["rows_per_s"] > 0
    assert r64["us_per_call"] < 3 * row["us_per_call"]
