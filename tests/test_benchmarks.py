"""Smoke tests for the benchmark harness: the --json machine-readable mode
(the per-PR perf trajectory format), the --only section filter, and the
--compare perf-regression gate."""

import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _bench_env():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    return env


def _run_bench(args, timeout=900):
    return subprocess.run(
        [sys.executable, "-m", "benchmarks.run"] + args,
        cwd=REPO, env=_bench_env(), capture_output=True, text=True,
        timeout=timeout)


def _fake_baseline(path, name, us):
    path.write_text(json.dumps(
        {"meta": {"suite": "aritpim-repro"},
         "rows": [{"name": name, "us_per_call": us}]}))


def test_bench_compare_gate(tmp_path):
    """--compare exits nonzero with a delta table when a tracked kernel row
    regresses past the threshold, and passes against a slow baseline.
    Measured on the cheap single-row section to keep the smoke test fast."""
    only = ["--only", "kernel/fp16_add_8k_rows_serial"]
    fast = tmp_path / "fast.json"
    _fake_baseline(fast, "kernel/fp16_add_8k_rows_serial", 0.001)
    proc = _run_bench(only + ["--compare", str(fast)])
    assert proc.returncode == 1, proc.stdout + proc.stderr
    assert "REGRESSED" in proc.stdout
    slow = tmp_path / "slow.json"
    _fake_baseline(slow, "kernel/fp16_add_8k_rows_serial", 1e9)
    proc = _run_bench(only + ["--compare", str(slow)])
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "perf gate: OK" in proc.stdout
    # untracked (non-kernel) rows never gate
    proc = _run_bench(["--only", "karatsuba/N8", "--compare", str(fast)])
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_bench_json_smoke(tmp_path):
    """The 8k-row kernel family emits in --json format, *and* the
    --compare BENCH_9.json gate runs as part of the tier-1-adjacent suite
    so word-layout regressions fail loudly here, not just in a manual
    benchmark run.  The compare threshold is loose (this host-shared CPU
    jitters; BENCH_10.json records the real figures) -- the hard in-test
    bar is the *relative* rows64-vs-rows32 assertion below, which load
    cannot skew."""
    out = tmp_path / "bench.json"
    proc = _run_bench(["--only", "kernel/fp16_add_8k_rows",
                       "--json", str(out), "--compare",
                       os.path.join(REPO, "BENCH_9.json"),
                       "--threshold", "100"], timeout=900)
    assert proc.returncode == 0, proc.stdout + proc.stderr[-2000:]
    assert proc.stdout.startswith("name,us_per_call,derived")
    assert "perf gate: OK" in proc.stdout

    doc = json.loads(out.read_text())
    assert doc["meta"]["suite"] == "aritpim-repro"
    names = {r["name"] for r in doc["rows"]}
    assert "kernel/fp16_add_8k_rows" in names
    assert "kernel/fp16_add_8k_rows_pallas_fused" in names
    assert "kernel/fp16_add_8k_rows_pallas_static" in names
    for r in doc["rows"]:
        assert isinstance(r["us_per_call"], (int, float))
    row = next(r for r in doc["rows"]
               if r["name"] == "kernel/fp16_add_8k_rows")
    assert row["levelized"] == 1 and row["levels"] > 0
    assert row["schedule"] == "slots"
    # telemetry-era fields (DESIGN.md §15): every tracked kernel row now
    # carries wall percentiles and the modeled device cycles/energy next
    # to the headline min-of-reps wall time
    for r in doc["rows"]:
        if not r["name"].startswith("kernel/"):
            continue
        assert r["lat_p99_us"] >= r["lat_p50_us"] > 0, r["name"]
        assert r["model_cycles"] > 0 and r["model_energy_nj"] > 0, r["name"]
        assert r["model_us"] > 0, r["name"]
    # the modeled device latency is schedule-derived, identical for every
    # row executing the same fp16-add schedule regardless of backend
    same_sched = [r for r in doc["rows"]
                  if r.get("schedule") == "slots" and "fused" not in r
                  and not r.get("verified")]
    assert len({r["model_cycles"] for r in same_sched}) == 1
    # the paired-uint32 layout row rides the same family and must stay
    # within noise of the rows32 anchor on CPU (identical bit volume; the
    # halved word axis pays off on 64-bit datapaths, not XLA:CPU)
    r64 = next(r for r in doc["rows"]
               if r["name"] == "kernel/fp16_add_8k_rows_rows64")
    assert r64["layout"] == "rows64" and r64["rows_per_s"] > 0
    assert r64["us_per_call"] < 3 * row["us_per_call"]


def test_serve_telemetry_smoke(tmp_path):
    """--pim-serve under mixed traffic (ISSUE 9 acceptance): periodic
    JSON stats lines with queue/exec percentiles and the cache hit rate,
    a machine-parseable shutdown summary line, a Prometheus metrics file
    carrying the tracked histogram names, and a Chrome trace with the
    pipeline span taxonomy."""
    reqs = [json.dumps({"op": ["add", "mul", "sub"][i % 3],
                        "dtype": "uint8", "x": [1, 2, 3], "y": [3, 2, 1]})
            for i in range(6)]
    metrics = tmp_path / "metrics.prom"
    trace = tmp_path / "trace.json"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--pim-serve",
         "--pim-window-ms", "20", "--pim-stats-interval-ms", "1",
         "--pim-metrics-file", str(metrics),
         "--pim-trace-file", str(trace)],
        input="\n".join(reqs) + "\n", cwd=REPO, env=_bench_env(),
        capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    out_lines = [json.loads(l) for l in proc.stdout.splitlines()]
    assert len(out_lines) == 6 and all("result" in l for l in out_lines)

    jlines = [json.loads(l) for l in proc.stderr.splitlines()
              if l.startswith("{")]
    stats = [l for l in jlines if l["type"] == "stats"]
    (summary,) = [l for l in jlines if l["type"] == "summary"]
    assert stats, "no periodic stats line emitted"
    assert "rows_per_s" in stats[0] and "cache" in stats[0]
    assert summary["served"] == 6 and summary["errors"] == 0
    lat = summary["latency"]
    for h in ("queue_us", "request_us", "exec_us", "occupancy_rows"):
        assert h in lat, h
    assert lat["queue_us"]["p99"] >= lat["queue_us"]["p50"] > 0
    assert 0.0 <= summary["cache"]["hit_rate"] <= 1.0

    text = metrics.read_text()
    for name in ("pim_serve_queue_us", "pim_serve_request_us",
                 "pim_batch_exec_us", "pim_batch_occupancy_rows",
                 "pim_cache_misses"):
        assert name in text, f"{name} missing from metrics file"
    assert 'quantile="0.99"' in text

    tdoc = json.loads(trace.read_text())
    names = {e["name"] for e in tdoc["traceEvents"]}
    assert {"prepare", "enqueue", "exec"} <= names, names
