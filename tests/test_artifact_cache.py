"""Persistent compiled-artifact cache (DESIGN.md §16): disk round-trips
are bit-exact against the numpy oracle across layouts and schedules,
corruption and version skew silently recompute, the size cap evicts
least-recently-used artifacts, concurrent writers on one directory never
tear files, and ``warm()`` (in-process and via a second ``--pim-serve``
replica) restores a process to hot with zero recompiles."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro import pim_ufunc as pim
from repro.kernels import ops as kops
from repro.kernels import plan as kplan
from repro.runtime import telemetry
from repro.runtime.artifact_cache import ArtifactCache, _MAGIC

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _c(name: str) -> int:
    return int(telemetry.REGISTRY.counter(f"pim.cache.{name}"))


@pytest.fixture
def cache(tmp_path):
    """A fresh on-disk cache installed process-wide, uninstalled (and the
    in-memory compiled cache cleared) afterwards so tests stay isolated."""
    c = ArtifactCache(tmp_path / "cache")
    kops.set_artifact_cache(c)
    try:
        yield c
    finally:
        kops.set_artifact_cache(None)
        kops.clear_compiled_cache()
        kplan.clear_tuned()


def _fp16_operands(rng, n):
    # mid-range exponents: products/sums stay normal (no NaN/Inf/subnormal)
    def bits(k):
        return (rng.integers(10, 21, k).astype(np.uint16) << 10 |
                rng.integers(0, 1 << 10, k).astype(np.uint16)
                ).view(np.float16)
    return bits(n), bits(n)


def test_disk_roundtrip_bit_exact_all_layouts_schedules(cache):
    """Populate the disk tier, drop all in-memory compiled state, and
    re-execute: every (layout x schedule) combination must come back from
    disk (zero fresh levelizations) bit-identical to the numpy oracle."""
    rng = np.random.default_rng(0)
    n = 256
    x = rng.integers(0, 1 << 16, n).astype(np.uint16)
    y = rng.integers(0, 1 << 16, n).astype(np.uint16)
    fx, fy = _fp16_operands(rng, n)
    combos = [(lay, sch) for lay in ("rows32", "rows64")
              for sch in ("slots", "slots-static", "dense")]

    def run_all():
        outs = []
        for lay, sch in combos:
            outs.append(pim.add(x, y, width=16, layout=lay, schedule=sch))
            outs.append(pim.fp_mul(fx, fy, layout=lay, schedule=sch))
        return outs

    run_all()                                   # populate disk
    assert _c("disk_writes") > 0
    assert kops.clear_compiled_cache() > 0      # drop in-memory state

    lev0, hits0 = _c("levelized"), _c("disk_hits")
    outs = run_all()
    assert _c("levelized") == lev0, "schedule came from levelize, not disk"
    assert _c("disk_hits") > hits0
    for i in range(0, len(outs), 2):
        assert np.array_equal(outs[i], x.astype(np.uint64) + y)
        assert np.array_equal(outs[i + 1], (fx * fy).astype(np.float16))


def test_corruption_recomputes_and_heals(cache):
    """A byte flipped anywhere in an artifact fails the checksum: the load
    counts ``disk_errors``, unlinks the bad file, recomputes the correct
    answer, and the write-through heals the entry for the next reader."""
    rng = np.random.default_rng(1)
    x = rng.integers(0, 1 << 8, 64).astype(np.uint8)
    y = rng.integers(0, 1 << 8, 64).astype(np.uint8)
    pim.mul(x, y, width=8)
    files = sorted(e.path for e in cache._files())
    assert files
    for path in files:
        with open(path, "r+b") as f:
            f.seek(20)
            b = f.read(1)
            f.seek(20)
            f.write(bytes([b[0] ^ 0xFF]))
    kops.clear_compiled_cache()

    err0 = _c("disk_errors")
    out = pim.mul(x, y, width=8)
    assert _c("disk_errors") > err0
    assert np.array_equal(out, x.astype(np.uint64) * y)
    for path in files:                  # bad files unlinked or rewritten
        if os.path.exists(path):
            with open(path, "rb") as f:
                assert f.read(8) == _MAGIC

    # healed: a third cold start loads from disk again
    kops.clear_compiled_cache()
    lev0 = _c("levelized")
    assert np.array_equal(pim.mul(x, y, width=8), out)
    assert _c("levelized") == lev0


def test_version_mismatch_is_plain_miss(cache):
    """A future-format magic makes every load a miss (never a parse):
    execution recomputes via levelize and overwrites the stale entry."""
    rng = np.random.default_rng(2)
    x = rng.integers(0, 1 << 8, 64).astype(np.uint8)
    y = rng.integers(0, 1 << 8, 64).astype(np.uint8)
    out0 = pim.sub(x, y, width=8)
    for e in cache._files():
        with open(e.path, "r+b") as f:
            f.write(b"PIMART99")
    kops.clear_compiled_cache()
    lev0 = _c("levelized")
    out1 = pim.sub(x, y, width=8)
    assert _c("levelized") > lev0               # recomputed, no crash
    assert np.array_equal(out0, out1)


def test_size_cap_evicts_least_recently_used(tmp_path):
    """Writes past ``max_bytes`` evict oldest-mtime files first (loads
    refresh mtime, so the order is least-recently-used)."""
    from repro.core import pim_numerics
    prog = pim_numerics.program_for("int-serial", "add", 8)
    sched = kops.program_schedule(prog)
    big = ArtifactCache(tmp_path / "big")
    big.store_schedule(b"\x01" * 16, (6, 0, 0), "slots", sched)
    one = os.path.getsize(big._files()[0].path)

    c = ArtifactCache(tmp_path / "capped", max_bytes=int(one * 2.5))
    ev0 = _c("disk_evictions")
    paths = []
    for i, age in enumerate((100, 50)):
        key = bytes([i]) * 16
        c.store_schedule(key, (6, 0, 0), "slots", sched)
        p = c.sched_path(key, (6, 0, 0), "slots")
        t = os.path.getmtime(p) - age
        os.utime(p, (t, t))
        paths.append(p)
    c.store_schedule(b"\x10" * 16, (6, 0, 0), "slots", sched)
    assert not os.path.exists(paths[0]), "oldest entry survived the cap"
    assert os.path.exists(c.sched_path(b"\x10" * 16, (6, 0, 0), "slots"))
    assert _c("disk_evictions") > ev0
    assert c.total_bytes() <= c.max_bytes


def test_concurrent_multiprocess_writers(tmp_path):
    """Four processes race the same cache directory on the same programs:
    all succeed, and every surviving artifact is complete and loadable
    (atomic replace means no reader ever sees a torn file)."""
    cache_dir = tmp_path / "shared"
    script = (
        "import numpy as np\n"
        "from repro import pim_ufunc as pim\n"
        "pim.configure(cache_dir=%r)\n"
        "x = np.arange(64, dtype=np.uint8); y = (x * 3 + 1).astype(np.uint8)\n"
        "assert np.array_equal(pim.add(x, y, width=8),\n"
        "    x.astype(np.uint64) + y)\n"
        "assert np.array_equal(pim.mul(x, y, width=8),\n"
        "    x.astype(np.uint64) * y)\n"
        "print('OK')\n" % str(cache_dir))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    procs = [subprocess.Popen([sys.executable, "-c", script], cwd=REPO,
                              env=env, stdout=subprocess.PIPE,
                              stderr=subprocess.PIPE, text=True)
             for _ in range(4)]
    for p in procs:
        out, err = p.communicate(timeout=600)
        assert p.returncode == 0 and "OK" in out, err[-800:]
    c = ArtifactCache(cache_dir)
    headers = c.entries()               # _read verifies every checksum
    assert headers and all(h["kind"] in ("sched", "aot") for h in headers)


def test_warm_installs_schedules_and_executables(cache):
    """``warm()`` on a populated directory rebuilds programs from their
    recorded provenance and installs both tiers: the next call pays
    neither levelize nor XLA compile and stays bit-exact."""
    rng = np.random.default_rng(3)
    fx, fy = _fp16_operands(rng, 512)
    out0 = pim.fp_add(fx, fy)
    kops.clear_compiled_cache()

    counts = cache.warm()
    assert counts["schedules"] >= 1
    assert counts["executables"] >= 1
    lev0, miss0 = _c("levelized"), _c("disk_misses")
    out1 = pim.fp_add(fx, fy)
    assert _c("levelized") == lev0 and _c("disk_misses") == miss0
    assert np.array_equal(out0, out1)
    assert np.array_equal(out1, (fx + fy).astype(np.float16))


def _run_serve(reqs, cache_dir, metrics=None):
    args = [sys.executable, "-m", "repro.launch.serve", "--pim-serve",
            "--pim-window-ms", "20", "--pim-cache-dir", str(cache_dir)]
    if metrics is not None:
        args += ["--pim-metrics-file", str(metrics)]
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    proc = subprocess.run(args, input="\n".join(reqs) + "\n", cwd=REPO,
                          env=env, capture_output=True, text=True,
                          timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    jlines = [json.loads(l) for l in proc.stderr.splitlines()
              if l.startswith("{")]
    (summary,) = [l for l in jlines if l["type"] == "summary"]
    warm = [l for l in jlines if l["type"] == "warm_start"]
    return summary, warm


def test_second_server_warm_starts_with_zero_recompiles(tmp_path):
    """The ISSUE 10 acceptance path end-to-end: two ``--pim-serve``
    replicas share one ``--pim-cache-dir``.  The first compiles and
    persists; the second warm-starts -- its summary shows **zero** fresh
    levelizations, nonzero disk hits, and the disk counters ride the
    Prometheus exposition."""
    reqs = [json.dumps({"op": op, "dtype": "uint8",
                        "x": [1, 2, 3], "y": [3, 2, 1]})
            for op in ("add", "mul", "sub") for _ in range(2)]
    cache_dir = tmp_path / "cache"
    metrics = tmp_path / "metrics.prom"

    s1, _ = _run_serve(reqs, cache_dir)
    assert s1["served"] == 6 and s1["errors"] == 0
    assert s1["cache"]["levelized"] > 0
    assert s1["cache"]["disk_writes"] > 0

    s2, warm = _run_serve(reqs, cache_dir, metrics=metrics)
    assert s2["served"] == 6 and s2["errors"] == 0
    (w,) = warm
    assert w["schedules"] >= 3 and w["executables"] >= 0
    assert s2["cache"]["levelized"] == 0, \
        "second replica recompiled despite a populated artifact cache"
    assert s2["cache"]["disk_hits"] > 0
    assert s2["cache"]["disk_errors"] == 0

    # counters materialize on first touch: the warm replica never
    # levelizes, so the disk-hit counter is the one that must be exposed
    text = metrics.read_text()
    for name in ("pim_cache_disk_hits", "pim_cache_hits"):
        assert name in text, f"{name} missing from Prometheus exposition"
