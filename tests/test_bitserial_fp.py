"""Bit-serial floating point: exact IEEE-754 RNE vs the rational oracle
(paper §4: variable shift, variable normalization, first FP add)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import bitserial_fp as fp
from repro.core.floatfmt import BF16, FP16, FP32

_cache = {}


def _prog(key, builder):
    if key not in _cache:
        _cache[key] = builder()
    return _cache[key]


@given(st.integers(0, 2 ** 16 - 1), st.integers(0, 31))
@settings(max_examples=50, deadline=None)
def test_var_shift_property(x, t):
    p = _prog("vs", lambda: fp.build_var_shift(16, 5))
    assert p.exec_row({"x": x, "t": t})["z"] == (x >> t) & 0xFFFF


@given(st.integers(0, 2 ** 16 - 1))
@settings(max_examples=50, deadline=None)
def test_var_normalize_property(x):
    p = _prog("vn", lambda: fp.build_var_normalize(16))
    o = p.exec_row({"x": x})
    if x == 0:
        assert o["z"] == 0 and o["t"] == 15
    else:
        lz = 16 - x.bit_length()
        assert o["t"] == lz and o["z"] == (x << lz) & 0xFFFF


def test_var_norm_overhead_matches_paper():
    """§4.4: normalization costs ~7% over variable shift at Nx=24."""
    vs = fp.build_var_shift(24, 5).cost().nor_gates
    vn = fp.build_var_normalize(24).cost().nor_gates
    overhead = vn / vs - 1.0
    assert overhead < 0.25, overhead


def _check(fmt, prog, op, pairs):
    for xb, yb in pairs:
        try:
            want = fmt.op_exact(op, int(xb), int(yb))
        except (OverflowError, ZeroDivisionError):
            continue
        got = prog.exec_row({"x": int(xb), "y": int(yb)})["z"]
        assert got == want, (fmt, op, fmt.decode(int(xb)),
                             fmt.decode(int(yb)), fmt.decode(got),
                             fmt.decode(want))


def _pairs(fmt, n, rng, lo, hi):
    return list(zip(fmt.random_bits(rng, n, emin=lo, emax=hi),
                    fmt.random_bits(rng, n, emin=lo, emax=hi)))


@pytest.mark.parametrize("fmtname,lo,hi", [("fp16", 10, 20),
                                           ("bf16", 100, 150),
                                           ("fp32", 100, 150)])
def test_fp_add_signed(fmtname, lo, hi):
    fmt = {"fp16": FP16, "bf16": BF16, "fp32": FP32}[fmtname]
    p = _prog(("add", fmtname), lambda: fp.build_fp_add(fmt))
    rng = np.random.default_rng(42)
    pairs = _pairs(fmt, 60, rng, lo, hi)
    mid = (lo + hi) // 2
    # adversarial: cancellation + exact ties
    for _ in range(40):
        e = int(rng.integers(lo, hi))
        m = int(rng.integers(0, 1 << fmt.nm))
        m2 = max(0, min((1 << fmt.nm) - 1, m + int(rng.integers(-2, 3))))
        pairs.append((fmt.encode(0, e, m), fmt.encode(1, e, m2)))
        pairs.append((fmt.encode(0, mid, m),
                      fmt.encode(0, mid - fmt.nm - 1, m2)))
    pairs += [(0, fmt.encode(0, mid, 5)), (fmt.encode(1, mid, 5), 0), (0, 0),
              (fmt.encode(0, mid, 9), fmt.encode(1, mid, 9))]
    _check(fmt, p, "add", pairs)


def test_fp_add_unsigned():
    p = _prog("addu", lambda: fp.build_fp_add(FP16, signed=False))
    rng = np.random.default_rng(3)
    pairs = [(FP16.encode(0, int(rng.integers(10, 20)),
                          int(rng.integers(0, 1024))),
              FP16.encode(0, int(rng.integers(10, 20)),
                          int(rng.integers(0, 1024)))) for _ in range(60)]
    _check(FP16, p, "add", pairs)


def test_fp_sub():
    p = _prog("sub", lambda: fp.build_fp_sub(FP16))
    rng = np.random.default_rng(4)
    _check(FP16, p, "sub", _pairs(FP16, 60, rng, 10, 20))


@pytest.mark.parametrize("fmtname,lo,hi", [("fp16", 12, 18),
                                           ("bf16", 100, 150),
                                           ("fp32", 100, 150)])
def test_fp_mul(fmtname, lo, hi):
    fmt = {"fp16": FP16, "bf16": BF16, "fp32": FP32}[fmtname]
    p = _prog(("mul", fmtname), lambda: fp.build_fp_mul(fmt))
    rng = np.random.default_rng(5)
    pairs = _pairs(fmt, 50, rng, lo, hi) + [(0, fmt.encode(0, hi, 1))]
    _check(fmt, p, "mul", pairs)


@pytest.mark.parametrize("fmtname,lo,hi", [("fp16", 12, 18),
                                           ("bf16", 100, 150),
                                           ("fp32", 100, 150)])
def test_fp_div(fmtname, lo, hi):
    fmt = {"fp16": FP16, "bf16": BF16, "fp32": FP32}[fmtname]
    p = _prog(("div", fmtname), lambda: fp.build_fp_div(fmt))
    rng = np.random.default_rng(6)
    pairs = _pairs(fmt, 50, rng, lo, hi) + [(0, fmt.encode(1, hi, 3))]
    _check(fmt, p, "div", pairs)


def test_fp_latency_complexities():
    """add O(Nm log Nm + Ne) < mul O(Nm^1.58) < div O(Nm^2) (paper §4)."""
    add = fp.build_fp_add(FP32).cost().nor_gates
    mul = fp.build_fp_mul(FP32).cost().nor_gates
    div = fp.build_fp_div(FP32).cost().nor_gates
    assert add < mul < div
