"""Gate IR: NOR lowering equivalence, netlists, cost accounting."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.gates import Builder, G, Program


def test_fa_netlist_exhaustive():
    b = Builder()
    a = b.input("a", 1)
    x = b.input("b", 1)
    c = b.input("c", 1)
    s, co = b.fa(a[0], x[0], c[0])
    b.output("s", [s])
    b.output("co", [co])
    p = b.finish()
    pl = p.lower_to_nor()
    for av in (0, 1):
        for bv in (0, 1):
            for cv in (0, 1):
                for prog in (p, pl):
                    o = prog.exec_row({"a": av, "b": bv, "c": cv})
                    assert o["s"] == (av ^ bv ^ cv)
                    assert o["co"] == int(av + bv + cv >= 2)


@given(st.lists(st.sampled_from(list("noxam")), min_size=1, max_size=30),
       st.integers(0, 2 ** 16 - 1), st.integers(0, 2 ** 16 - 1))
@settings(max_examples=60, deadline=None)
def test_lowering_equivalence_random_programs(ops, xv, yv):
    """Random abstract gate DAGs produce identical results after NOR
    lowering (property over the compiler)."""
    b = Builder()
    x = b.input("x", 16)
    y = b.input("y", 16)
    avail = x + y
    rng = np.random.default_rng(len(ops) * 7 + xv)
    outs = []
    for o in ops:
        i, j, k = rng.integers(0, len(avail), 3)
        if o == "n":
            c = b.nor(avail[i], avail[j])
        elif o == "o":
            c = b.or_(avail[i], avail[j])
        elif o == "x":
            c = b.xor(avail[i], avail[j])
        elif o == "a":
            c = b.and_(avail[i], avail[j])
        else:
            c = b.mux(avail[i], avail[j], avail[k])
        avail.append(c)
        outs.append(c)
    b.output("z", outs[-8:])
    p = b.finish()
    got_abs = p.exec_row({"x": xv, "y": yv})["z"]
    got_nor = p.lower_to_nor().exec_row({"x": xv, "y": yv})["z"]
    assert got_abs == got_nor


def test_packed_matches_single_row():
    b = Builder()
    x = b.input("x", 8)
    y = b.input("y", 8)
    z = b.vec_xor(x, y)
    b.output("z", z)
    p = b.finish()
    rng = np.random.default_rng(0)
    xs = rng.integers(0, 256, 64)
    ys = rng.integers(0, 256, 64)
    state = np.zeros((2, p.n_cells), np.uint32)
    for r in range(64):
        for k, cell in enumerate(p.ports["x"]):
            state[r // 32, cell] |= np.uint32(((int(xs[r]) >> k) & 1) << (r % 32))
        for k, cell in enumerate(p.ports["y"]):
            state[r // 32, cell] |= np.uint32(((int(ys[r]) >> k) & 1) << (r % 32))
    p.exec_packed(state)
    for r in range(64):
        got = sum((int(state[r // 32, c]) >> (r % 32) & 1) << k
                  for k, c in enumerate(p.ports["z"]))
        assert got == int(xs[r]) ^ int(ys[r])


def test_cost_accounting():
    b = Builder()
    x = b.input("x", 4)
    y = b.input("y", 4)
    from repro.core.bitserial import ripple_add
    z, _ = ripple_add(b, x, y)
    b.output("z", z)
    p = b.finish()
    c = p.cost()
    assert c.abstract_steps == 4                  # 4 FACC steps
    assert c.nor_gates == 4 * 11                  # 11-NOR FACC netlist
    assert c.nor_gates_normalized == 4 * 9        # paper's 9-NOR convention
    low = p.lower_to_nor()
    assert low.cost().abstract_steps == c.nor_gates
