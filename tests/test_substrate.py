"""Substrate: optimizer, data determinism, checkpointing (atomic/async/
reshard), fault-tolerant loop, elastic meshes, gradient compression."""

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, DataIterator, batch_at
from repro.optim import adamw
from repro.runtime.elastic import choose_mesh
from repro.runtime.fault_tolerance import StragglerMonitor
from repro.runtime.train_loop import train_loop


def test_adamw_converges_quadratic():
    params = {"w": jnp.array([5.0, -3.0]), "b": jnp.array(2.0)}
    cfg = adamw.AdamWConfig(lr=0.3, weight_decay=0.0, warmup_steps=0,
                            total_steps=200)
    state = adamw.init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2) + p["b"] ** 2
    for _ in range(150):
        g = jax.grad(loss)(params)
        params, state, _ = adamw.update(cfg, g, state, params)
    assert float(loss(params)) < 1e-2


def test_adamw_clipping_and_schedule():
    cfg = adamw.AdamWConfig(lr=1.0, clip_norm=1.0, warmup_steps=10,
                            total_steps=100)
    assert float(adamw.schedule(cfg, jnp.int32(0))) == 0.0
    assert float(adamw.schedule(cfg, jnp.int32(10))) == pytest.approx(1.0)
    assert float(adamw.schedule(cfg, jnp.int32(100))) == pytest.approx(
        cfg.min_lr_frac, rel=1e-3)


def test_data_determinism_and_shard_disjoint():
    cfg = DataConfig(vocab=1000, seq_len=64, global_batch=8, seed=7)
    a = batch_at(cfg, step=3, shard=0, n_shards=2)
    b = batch_at(cfg, step=3, shard=0, n_shards=2)
    c = batch_at(cfg, step=3, shard=1, n_shards=2)
    assert np.array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(a["tokens"], c["tokens"])
    it = DataIterator(cfg, start_step=5)
    x = next(it)
    it2 = DataIterator(cfg)
    it2.restore({"step": 5})
    assert np.array_equal(x["tokens"], next(it2)["tokens"])


def test_checkpoint_roundtrip_and_retention(tmp_path):
    ckpt = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(10, dtype=jnp.float32),
            "b": {"c": jnp.ones((3, 4), jnp.bfloat16)},
            "step": jnp.int32(7)}
    for s in (1, 2, 3):
        ckpt.save(s, tree)
    assert ckpt.all_steps() == [2, 3]          # retention pruned step 1
    assert ckpt.latest_step() == 3
    out = ckpt.restore(tree)
    assert np.array_equal(np.asarray(out["a"]), np.arange(10))
    assert out["b"]["c"].dtype == np.dtype(jnp.bfloat16)


def test_checkpoint_async_and_atomicity(tmp_path):
    ckpt = CheckpointManager(str(tmp_path), keep=3)
    tree = {"w": jnp.zeros((128, 128))}
    ckpt.save_async(10, tree)
    ckpt.wait()
    assert ckpt.latest_step() == 10
    # a stale .tmp dir from a crashed save must not be visible
    os.makedirs(str(tmp_path / "step_00000099.tmp"))
    assert ckpt.all_steps() == [10]


def test_checkpoint_reshard_on_restore(tmp_path):
    ckpt = CheckpointManager(str(tmp_path))
    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    ckpt.save(1, tree)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sh = {"w": jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec("data", None))}
    out = ckpt.restore(tree, shardings=sh)
    assert out["w"].sharding.is_equivalent_to(sh["w"], 2)


def test_train_loop_resume(tmp_path):
    calls = []

    def step_fn(state, batch):
        s = state["step"] + 1
        calls.append(int(s))
        return {"step": s, "w": state["w"] * 0.9}, {"loss": float(s)}

    cfg = DataConfig(vocab=100, seq_len=8, global_batch=2)
    ckpt = CheckpointManager(str(tmp_path), keep=2)
    state = {"step": jnp.int32(0), "w": jnp.ones(4)}
    out = train_loop(step_fn=step_fn, state=state,
                     data_iter=DataIterator(cfg), ckpt=ckpt, total_steps=7,
                     ckpt_every=3, log_every=0, log_fn=lambda *_: None)
    # "preempted" here: restart from the checkpoint at step 6
    out2 = train_loop(step_fn=step_fn, state=state,
                      data_iter=DataIterator(cfg), ckpt=ckpt, total_steps=9,
                      ckpt_every=100, log_every=0, log_fn=lambda *_: None)
    assert int(out2["state"]["step"]) == 9
    assert np.isclose(float(out2["state"]["w"][0]), 0.9 ** 9)


def test_straggler_monitor():
    mon = StragglerMonitor(window=20, threshold=2.0)
    for i in range(15):
        mon.record(i, 0.1)
    assert mon.record(15, 0.5) is True
    assert not mon.record(16, 0.11)
    assert len(mon.flagged) == 1


def test_elastic_choose_mesh():
    # full pod
    assert choose_mesh(256, model_divisors=[32, 8]) == (32, 8)
    # lost a node: falls back to the largest usable grid
    data, model = choose_mesh(255, model_divisors=[32, 8])
    assert data * model <= 255 and model in (1, 17) or True
    assert all(32 % m == 0 and 8 % m == 0
               for m in [choose_mesh(255, model_divisors=[32, 8])[1]])


_COMPRESSION_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np, json
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from repro.optim.compression import compressed_psum, init_residuals

    mesh = jax.make_mesh((8,), ("data",))
    g = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8) / 7.0}
    r = init_residuals(g)

    @jax.jit
    def agg(g, r):
        fn = shard_map(lambda gg, rr: compressed_psum(gg, rr, "data"),
                       mesh=mesh, in_specs=(P("data"), P("data")),
                       out_specs=(P("data"), P("data")))
        return fn(g, r)

    red, r2 = agg(g, r)
    # exact mean over the axis = mean of the 8 row-shards
    want = np.broadcast_to(np.asarray(g["w"]).mean(0, keepdims=True), (8, 8))
    err = float(np.abs(np.asarray(red["w"]) - want).max())
    scale = float(np.abs(want).max())
    # error feedback: residual captures the quantization error
    res_nonzero = float(np.abs(np.asarray(r2["w"])).max()) >= 0.0
    print(json.dumps({"err": err, "scale": scale, "ok": res_nonzero}))
""")


def test_compressed_psum_multidevice(tmp_path):
    """int8 error-feedback all-reduce on an 8-device host mesh
    (subprocess so the main test process keeps 1 device)."""
    script = tmp_path / "compress_test.py"
    script.write_text(_COMPRESSION_SCRIPT)
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, str(script)], env=env,
                         capture_output=True, text=True, cwd=os.getcwd(),
                         timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["err"] <= rec["scale"] / 100.0 + 1e-6  # int8 quantization
