"""Levelized scheduling pass: hazard freedom, DCE/register-allocation
shrinkage, native-schedule expansion, cost-model invariance, bit-exactness
vs the abstract oracle."""

import zlib

import numpy as np
import pytest

from repro.core import bitparallel as bp
from repro.core import bitparallel_fp as bpf
from repro.core import bitserial as bs
from repro.core import bitserial_fp as bsf
from repro.core.floatfmt import FP16
from repro.core.gates import Builder, levelize

PROGRAMS = [
    ("add16", lambda: bs.build_add(16)),
    ("mul8", lambda: bs.build_mul(8)),
    ("div8", lambda: bs.build_div(8)),
    ("fp16_add", lambda: bsf.build_fp_add(FP16)),
    ("bp_add16", lambda: bp.build_bp_add(16)),
    ("bp_mul8", lambda: bp.build_bp_mul(8)),
    ("bp_fp16_add", lambda: bpf.build_bp_fp_add(FP16)),
]


def _exec_schedule(prog, sched, inputs):
    """Run a LevelSchedule with the numpy per-level executor and compare
    every output port against the single-row oracle."""
    rows = len(next(iter(inputs.values())))
    state = np.zeros((sched.n_cells, (rows + 31) // 32), np.uint32)
    if sched.one_cell is not None:
        state[sched.one_cell] = 0xFFFFFFFF
    for name, vals in inputs.items():
        for r, v in enumerate(vals):
            for k, c in enumerate(sched.pack_cells(name)):
                if (int(v) >> k) & 1:
                    state[c, r // 32] |= np.uint32(1 << (r % 32))
    sched.exec_packed(state)
    for r in range(rows):
        want = prog.exec_row({n: int(v[r]) for n, v in inputs.items()})
        for name in prog.out_ports:
            got = sum((int(state[c, r // 32]) >> (r % 32) & 1) << k
                      for k, c in enumerate(sched.ports[name]))
            assert got == want[name], (name, r)


def _rand_inputs(prog, rows, seed):
    rng = np.random.default_rng(seed)
    return {n: [int(x) for x in
                rng.integers(0, 1 << min(len(prog.ports[n]), 62), rows)]
            for n in prog.in_ports}


@pytest.mark.parametrize("name,build", PROGRAMS)
def test_schedule_bit_exact(name, build):
    prog = build()
    sched = levelize(prog)
    _exec_schedule(prog, sched, _rand_inputs(prog, 7, zlib.crc32(name.encode())))


@pytest.mark.parametrize("name,build", PROGRAMS)
def test_schedule_hazard_free_and_unique_writes(name, build):
    """Within a level no real gate reads a cell written by that level, and
    output indices are unique (incl. the distinct-sink padding lanes)."""
    sched = levelize(build())
    for l in range(sched.n_levels):
        outs = sched.out[l]
        assert len(set(outs.tolist())) == len(outs)
        w = sched.level_width[l]
        written = set(outs[:w].tolist())
        reads = set(sched.a[l, :w].tolist()) | set(sched.b[l, :w].tolist())
        assert not (written & reads)


@pytest.mark.parametrize("name,build", PROGRAMS)
def test_schedule_preserves_cost_model(name, build):
    """Levelization is an executor artifact: the paper-facing cost model of
    the Program must be byte-identical before and after scheduling."""
    prog = build()
    before = prog.cost().as_dict()
    pbefore = prog.parallel_cost()
    levelize(prog)
    levelize(prog, reuse_cells=False)
    assert prog.cost().as_dict() == before
    after = prog.parallel_cost()
    if pbefore is None:
        assert after is None
    else:
        assert after.as_dict() == pbefore.as_dict()


def test_schedule_shrinks_footprint_and_gates():
    """Register allocation shrinks the sparse k*cpk partition layouts by an
    order of magnitude; DCE drops unread gates."""
    prog = bp.build_bp_add(16)
    sched = levelize(prog)
    assert sched.n_cells < sched.source_cells // 4
    assert sched.n_gates <= sched.source_gates
    # serial builders already reuse temps aggressively; levelized execution
    # widens live ranges, so allow a bounded growth there
    serial = bs.build_add(32)
    s2 = levelize(serial)
    assert s2.n_cells <= 2 * s2.source_cells


def test_schedule_depth_beats_serial():
    """The whole point: level count is the critical path, far below the
    gate count for both serial and parallel builders."""
    for _, build in PROGRAMS:
        s = levelize(build())
        assert s.n_levels < s.n_gates or s.n_gates <= 2


def test_native_schedule_matches_parallel_steps():
    """Native mode consumes the builders' parallel_steps; it stays
    bit-exact and is never shallower than the hazard (ASAP) schedule."""
    for build in (lambda: bp.build_bp_add(16), lambda: bp.build_bp_mul(8),
                  lambda: bpf.build_bp_fp_add(FP16)):
        prog = build()
        asap = levelize(prog)
        native = levelize(prog, mode="native")
        assert asap.n_levels <= native.n_levels
        _exec_schedule(prog, native, _rand_inputs(prog, 5, 99))


def test_native_schedule_requires_parallel_steps():
    with pytest.raises(ValueError):
        levelize(bs.build_add(8), mode="native")


def test_max_width_split_is_exact():
    prog = bsf.build_fp_add(FP16)
    sched = levelize(prog, max_width=4)
    assert sched.width <= 4
    _exec_schedule(prog, sched, _rand_inputs(prog, 5, 3))


def test_schedule_without_reuse_is_exact():
    prog = bs.build_mul(8)
    sched = levelize(prog, reuse_cells=False)
    _exec_schedule(prog, sched, _rand_inputs(prog, 5, 4))


def test_passthrough_program_schedules():
    """A program with no gates (output aliases input) levelizes to zero
    levels and still round-trips through the executor bridge."""
    b = Builder()
    x = b.input("x", 8)
    b.output("z", x)
    prog = b.finish()
    sched = levelize(prog)
    assert sched.n_levels == 0
    from repro.kernels import ops as kops
    vals = np.arange(17, dtype=np.uint64) * 3 % 256
    out = kops.run_program(prog, {"x": vals}, 17, backend="ref")["z"]
    assert np.array_equal(np.asarray(out, np.uint64), vals)


def test_levelized_exec_with_overwritten_input_port():
    """A program that overwrites an input-port cell must still read the
    packed *initial* value (inputs pack at in_cells, not the final cells)."""
    b = Builder()
    x = b.input("x", 2)
    y = b.input("y", 2)
    for i in range(2):
        b.emit(2, (x[i],), (x[i],))      # G.NOT in place: x[i] <- ~x[i]
    b.output("z", x)
    prog = b.finish()
    sched = levelize(prog)
    assert sched.in_cells["x"] != sched.ports["x"]
    _exec_schedule(prog, sched, {"x": [1, 2, 3], "y": [0, 0, 0]})
    from repro.kernels import ops as kops
    import numpy as np
    xs = np.array([1, 2, 3], np.uint64)
    ys = np.zeros(3, np.uint64)
    want = kops.run_program(prog, {"x": xs, "y": ys}, 3, backend="numpy")["z"]
    for backend in ("ref", "pallas"):
        got = kops.run_program(prog, {"x": xs, "y": ys}, 3,
                               backend=backend)["z"]
        assert np.array_equal(np.asarray(got), np.asarray(want)), backend


def test_run_program_no_input_ports():
    """Constant-generator programs (no input ports) execute on the default
    levelized path instead of crashing in the fused bridge."""
    b = Builder()
    ones = [b.const(1) for _ in range(3)]
    zero = b.const(0)
    b.output("z", ones + [zero])
    prog = b.finish()
    from repro.kernels import ops as kops
    for backend in ("ref", "pallas", "numpy"):
        out = kops.run_program(prog, {}, 5, backend=backend)["z"]
        assert np.array_equal(np.asarray(out, np.uint64),
                              np.full(5, 0b0111, np.uint64)), backend


def test_handbuilt_program_packs_at_initial_cells():
    """Programs constructed without port directions (Program(...) directly)
    still pack inputs at initial-value cells on the levelized path, even
    when an instruction overwrites a port cell."""
    from repro.core.gates import G, Instr, Program
    from repro.kernels import ops as kops
    # z[c] <- ~x[c] written IN PLACE over the x/z shared cells
    instrs = [Instr(G.NOT, (c,), (c,)) for c in range(4)]
    prog = Program(4, instrs, {"x": [0, 1, 2, 3], "z": [0, 1, 2, 3]})
    xs = np.array([0b0101, 0b0011], np.uint64)
    want = kops.run_program(prog, {"x": xs}, 2, backend="ref",
                            levelized=False)["z"]
    got = kops.run_program(prog, {"x": xs}, 2, backend="ref")["z"]
    assert np.array_equal(np.asarray(got), np.asarray(want))
    assert np.array_equal(np.asarray(got, np.uint64), (~xs) & 0xF)
