"""Bit-serial fixed-point suite vs integer oracles (paper §3)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import bitserial as bs


@pytest.mark.parametrize("n", [4, 8, 16, 33, 64])
def test_add_random(n):
    p = bs.build_add(n)
    rng = np.random.default_rng(n)
    for _ in range(25):
        x = int(rng.integers(0, 2 ** n, dtype=np.uint64)) if n < 64 \
            else int(rng.integers(0, 2 ** 63))
        y = int(rng.integers(0, 2 ** n, dtype=np.uint64)) if n < 64 \
            else int(rng.integers(0, 2 ** 63))
        assert p.exec_row({"x": x, "y": y})["z"] == x + y


def test_add_exhaustive_6bit():
    p = bs.build_add(6)
    for x in range(64):
        for y in range(0, 64, 7):
            assert p.exec_row({"x": x, "y": y})["z"] == x + y


@given(st.integers(0, 2 ** 16 - 1), st.integers(0, 2 ** 16 - 1))
@settings(max_examples=40, deadline=None)
def test_sub_property(x, y):
    p = _sub16()
    o = p.exec_row({"x": x, "y": y})
    assert o["z"] == (x - y) % 2 ** 16
    assert o["ge"] == int(x >= y)


_cache = {}


def _sub16():
    if "sub16" not in _cache:
        _cache["sub16"] = bs.build_sub(16)
    return _cache["sub16"]


@pytest.mark.parametrize("n,kar", [(8, False), (8, True), (16, True),
                                   (32, True), (24, True)])
def test_mul(n, kar):
    p = bs.build_mul(n, karatsuba=kar, thresh=6 if kar else 20)
    rng = np.random.default_rng(n)
    for _ in range(15):
        x = int(rng.integers(0, 2 ** n, dtype=np.uint64))
        y = int(rng.integers(0, 2 ** n, dtype=np.uint64))
        assert p.exec_row({"x": x, "y": y})["z"] == x * y


def test_karatsuba_beats_shift_add_at_32():
    """paper §3.2: Karatsuba wins for N around/above the ~20 crossover."""
    naive = bs.build_mul(32, karatsuba=False).cost().nor_gates
    kar = bs.build_mul(32, karatsuba=True, thresh=20).cost().nor_gates
    assert kar < naive


@given(st.integers(1, 2 ** 16 - 1), st.integers(0, 2 ** 16 - 1),
       st.data())
@settings(max_examples=40, deadline=None)
def test_div_property(d, q, data):
    r = data.draw(st.integers(0, d - 1))
    p = _div16()
    o = p.exec_row({"z": q * d + r, "d": d})
    assert o["q"] == q and o["r"] == r


def _div16():
    if "div16" not in _cache:
        _cache["div16"] = bs.build_div(16)
    return _cache["div16"]


def test_div_edge_cases():
    p = bs.build_div(8)
    # precondition (documented): z >> N < d, so the quotient fits N bits
    for z, d in [(0, 1), (255, 1), (255, 255), (65279, 255), (254, 255),
                 (1, 2), (255 * 255 + 254, 255)]:
        assert (z >> 8) < d
        o = p.exec_row({"z": z, "d": d})
        assert o["q"] == z // d and o["r"] == z % d


def test_latency_scaling():
    """O(N) add, O(N^2)-ish shift-add mul, O(N^2) div (paper complexities)."""
    a8, a16 = (bs.build_add(n).cost().abstract_steps for n in (8, 16))
    assert a16 == 2 * a8
    m8 = bs.build_mul(8, karatsuba=False).cost().abstract_steps
    m16 = bs.build_mul(16, karatsuba=False).cost().abstract_steps
    assert 3.4 < m16 / m8 < 4.6
    d8 = bs.build_div(8).cost().abstract_steps
    d16 = bs.build_div(16).cost().abstract_steps
    assert 3.0 < d16 / d8 < 4.6
