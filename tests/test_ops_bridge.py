"""Executor bridge: pack/unpack round-trips (incl. the wide >63-cell port
path and non-multiple-of-32 row counts), cross-backend equivalence on
randomized programs, and the content-hash compiled-program cache."""

import numpy as np
import pytest

from repro.core import bitserial as bs
from repro.core.gates import Builder
from repro.kernels import ops as kops


def _ports(widths):
    off = 0
    ports = {}
    for i, w in enumerate(widths):
        ports[f"p{i}"] = list(range(off, off + w))
        off += w
    return ports, off


@pytest.mark.parametrize("rows", [1, 31, 32, 33, 257, 1000])
@pytest.mark.parametrize("widths", [(1,), (5, 32), (63,), (16, 7, 40)])
def test_pack_unpack_roundtrip_narrow(rows, widths):
    ports, n_cells = _ports(widths)
    rng = np.random.default_rng(rows * 31 + sum(widths))
    vals = {n: rng.integers(0, 1 << min(len(c), 62), rows).astype(np.uint64)
            for n, c in ports.items()}
    state = kops.pack_rows(vals, ports, rows, n_cells, pad_to=1)
    got = kops.unpack_rows(state, ports, rows)
    for n in ports:
        assert np.array_equal(got[n], vals[n]), n


@pytest.mark.parametrize("rows", [1, 33, 100])
@pytest.mark.parametrize("width", [64, 80, 128, 200])
def test_pack_unpack_roundtrip_wide(rows, width):
    """> 63-cell ports: arbitrary-precision values as object arrays."""
    ports, n_cells = _ports((width, 3))
    rng = np.random.default_rng(width + rows)
    wide = np.array([int.from_bytes(rng.bytes((width + 7) // 8), "little")
                     & ((1 << width) - 1) for _ in range(rows)], object)
    small = rng.integers(0, 8, rows).astype(np.uint64)
    vals = {"p0": wide, "p1": small}
    state = kops.pack_rows(vals, ports, rows, n_cells, pad_to=1)
    got = kops.unpack_rows(state, ports, rows)
    assert got["p0"].dtype == object
    assert all(int(a) == int(b) for a, b in zip(got["p0"], wide))
    assert np.array_equal(got["p1"], small)


def test_pack_one_cell_and_padding():
    ports, n_cells = _ports((4,))
    vals = {"p0": np.array([5, 9], np.uint64)}
    state = kops.pack_rows(vals, ports, 2, n_cells + 1, one_cell=n_cells,
                           pad_to=8)
    assert state.shape[1] == 8
    assert (state[n_cells] == 0xFFFFFFFF).all()
    got = kops.unpack_rows(state, ports, 2)
    assert np.array_equal(got["p0"], vals["p0"])


def _random_program(seed, n_gates=40):
    rng = np.random.default_rng(seed)
    b = Builder()
    x = b.input("x", 16)
    y = b.input("y", 16)
    avail = x + y
    fns = [b.nor, b.or_, b.and_, b.xor, b.xnor, b.nand]
    for _ in range(n_gates):
        f = fns[rng.integers(0, len(fns))]
        i, j = rng.integers(0, len(avail), 2)
        avail.append(f(avail[i], avail[j]))
    b.output("z", avail[-16:])
    return b.finish()


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_cross_backend_equivalence_random_programs(seed):
    """pallas == ref == numpy (and the gate-serial paths) on randomized
    gate DAGs -- levelization must be invisible to results."""
    p = _random_program(seed)
    rng = np.random.default_rng(seed + 100)
    rows = 77
    ins = {"x": rng.integers(0, 1 << 16, rows).astype(np.uint64),
           "y": rng.integers(0, 1 << 16, rows).astype(np.uint64)}
    want = kops.run_program(p, ins, rows, backend="numpy")["z"]
    for backend in ("ref", "pallas"):
        for levelized in (True, False):
            got = kops.run_program(p, ins, rows, backend=backend,
                                   levelized=levelized)["z"]
            assert np.array_equal(np.asarray(got), np.asarray(want)), \
                (backend, levelized)


def test_cross_backend_equivalence_wide_output():
    """The wide-port (non-fused) executor path agrees across backends."""
    p = bs.build_mul(48)            # z port is 96 cells -> object values
    rng = np.random.default_rng(7)
    rows = 19
    x = rng.integers(0, 1 << 48, rows).astype(np.uint64)
    y = rng.integers(0, 1 << 48, rows).astype(np.uint64)
    ins = {"x": x, "y": y}
    for backend in ("ref", "pallas"):
        got = kops.run_program(p, ins, rows, backend=backend)["z"]
        assert all(int(g) == int(a) * int(b)
                   for g, a, b in zip(got, x, y))


def test_content_hash_cache_is_structural():
    """Structurally identical programs share one compiled entry; different
    programs can never collide (the id()-reuse poisoning of the old cache)."""
    p1 = _random_program(5)
    p2 = _random_program(5)
    p3 = _random_program(6)
    assert p1 is not p2
    assert kops.content_key(p1) == kops.content_key(p2)
    assert kops.content_key(p1) != kops.content_key(p3)
    assert kops.program_schedule(p1) is kops.program_schedule(p2)
    a1 = kops.program_arrays(p1)
    assert kops.program_arrays(p2) is a1


def test_cache_survives_program_gc():
    """A dead program's recycled id must not poison the cache: results for
    a fresh program built at (potentially) the same address stay correct."""
    import gc
    for seed in range(4):
        p = _random_program(seed, n_gates=12)
        rng = np.random.default_rng(seed)
        ins = {"x": rng.integers(0, 1 << 16, 9).astype(np.uint64),
               "y": rng.integers(0, 1 << 16, 9).astype(np.uint64)}
        want = kops.run_program(p, ins, 9, backend="numpy")["z"]
        got = kops.run_program(p, ins, 9, backend="ref")["z"]
        assert np.array_equal(np.asarray(got), np.asarray(want))
        del p
        gc.collect()


def test_run_program_returns_output_ports_only():
    p = bs.build_add(8)
    out = kops.run_program(p, {"x": np.array([3], np.uint64),
                               "y": np.array([4], np.uint64)}, 1,
                           backend="ref")
    assert set(out) == {"z"}
    assert int(out["z"][0]) == 7


# ----------------------------------------------------- bridge edge cases

def _width_program(width, seed=0):
    """x, y -> (x NOR-mix y) with ports of exactly ``width`` cells."""
    b = Builder()
    x = b.input("x", width)
    y = b.input("y", width)
    b.output("z", b.vec_xor(x, y))
    return b.finish()


@pytest.mark.parametrize("rows", [0, 1, 31, 32, 33])
@pytest.mark.parametrize("width", [31, 32, 33, 63, 64, 65])
def test_bridge_edge_rows_and_widths(rows, width):
    """Word-boundary row counts x limb/object-boundary port widths, through
    pack/unpack and all levelized executor paths vs the numpy oracle."""
    p = _width_program(width)
    rng = np.random.default_rng(rows * 97 + width)
    if width > 62:
        x = np.array([int.from_bytes(rng.bytes(9), "little") % (1 << width)
                      for _ in range(rows)], object)
        y = np.array([int.from_bytes(rng.bytes(9), "little") % (1 << width)
                      for _ in range(rows)], object)
    else:
        x = rng.integers(0, 1 << width, rows).astype(np.uint64)
        y = rng.integers(0, 1 << width, rows).astype(np.uint64)
    ins = {"x": x, "y": y}
    want = kops.run_program(p, ins, rows, backend="numpy")["z"]
    for backend in ("ref", "pallas"):
        got = kops.run_program(p, ins, rows, backend=backend)["z"]
        assert len(got) == rows
        assert all(int(a) == int(b) for a, b in zip(got, want)), backend
        assert all(int(a) == (int(xx) ^ int(yy))
                   for a, xx, yy in zip(got, x, y)), backend


def test_fused_vs_padded_io_vs_numpy_same_seeds():
    """The fused (<= 32-cell ports, native dtype) and padded-io (forced via
    object-dtype inputs) executor paths must agree with each other and the
    numpy oracle on identical inputs."""
    p = _width_program(16)
    rng = np.random.default_rng(42)
    rows = 77
    x = rng.integers(0, 1 << 16, rows).astype(np.uint64)
    y = rng.integers(0, 1 << 16, rows).astype(np.uint64)
    want = kops.run_program(p, {"x": x, "y": y}, rows, backend="numpy")["z"]
    for backend in ("ref", "pallas"):
        fused = kops.run_program(p, {"x": x, "y": y}, rows,
                                 backend=backend)["z"]
        padded = kops.run_program(
            p, {"x": x.astype(object), "y": y.astype(object)}, rows,
            backend=backend)["z"]
        assert np.array_equal(np.asarray(fused, np.uint64), want)
        assert all(int(a) == int(b) for a, b in zip(padded, want))


def test_zero_input_program_all_backends():
    """Programs with no input ports (constant generators) run on every
    path and agree."""
    b = Builder()
    c1 = b.const(1)
    c0 = b.const(0)
    n1 = b.not_(c0)
    b.output("ones", [c1, n1, c1])
    b.output("mix", [c0, c1, c0, c1])
    p = b.finish()
    for rows in (0, 1, 33):
        for backend, lev in [("numpy", True), ("ref", True), ("ref", False),
                             ("pallas", True), ("pallas", False)]:
            out = kops.run_program(p, {}, rows, backend=backend,
                                   levelized=lev)
            assert set(out) == {"ones", "mix"}, (backend, lev)
            assert np.array_equal(out["ones"], np.full(rows, 7, np.uint64))
            assert np.array_equal(out["mix"], np.full(rows, 10, np.uint64))


def test_directionless_ports_identical_across_all_four_paths():
    """Acceptance: direction-less programs (no declared in_ports) must
    return identical port dictionaries from the numpy, gate-serial,
    levelized padded-io, and levelized fused paths."""
    b = Builder()
    x = [b.alloc() for _ in range(6)]
    y = [b.alloc() for _ in range(6)]
    b.output("x", x)
    b.output("y", y)
    b.output("z", b.vec_xor(x, y))
    p = b.finish()
    assert not p.in_ports
    rng = np.random.default_rng(5)
    rows = 40
    ins = {"x": rng.integers(0, 64, rows).astype(np.uint64),
           "y": rng.integers(0, 64, rows).astype(np.uint64)}
    results = {
        "numpy": kops.run_program(p, ins, rows, backend="numpy"),
        "gate-serial": kops.run_program(p, ins, rows, backend="ref",
                                        levelized=False),
        "levelized-fused": kops.run_program(p, ins, rows, backend="ref"),
        "levelized-padded-io": kops.run_program(
            p, {k: v.astype(object) for k, v in ins.items()}, rows,
            backend="ref"),
    }
    want = results["numpy"]
    assert set(want) == {"x", "y", "z"}       # all ports, not {}
    for path, got in results.items():
        assert set(got) == set(want), path
        for k in want:
            assert all(int(a) == int(b) for a, b in zip(got[k], want[k])), \
                (path, k)


def test_all_ports_declared_input_returns_all_ports():
    """The degenerate direction case (every port an input) must fall back
    to returning all ports, not {} -- on every backend."""
    b = Builder()
    x = b.input("x", 4)
    y = b.input("y", 4)
    b.vec_xor(x, y)               # compute something, expose no output port
    p = b.finish()
    assert set(p.ports) == p.in_ports == {"x", "y"}
    assert not p.out_ports        # raw declaration is empty ...
    ins = {"x": np.array([3, 9], np.uint64), "y": np.array([5, 12], np.uint64)}
    for backend, lev in [("numpy", True), ("ref", True), ("ref", False),
                         ("pallas", True), ("pallas", False)]:
        out = kops.run_program(p, ins, 2, backend=backend, levelized=lev)
        assert set(out) == {"x", "y"}, (backend, lev)   # ... but never {}
        assert np.array_equal(out["x"], ins["x"]), (backend, lev)
        assert np.array_equal(out["y"], ins["y"]), (backend, lev)
