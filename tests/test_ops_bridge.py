"""Executor bridge: pack/unpack round-trips (incl. the wide >63-cell port
path and non-multiple-of-32 row counts), cross-backend equivalence on
randomized programs, and the content-hash compiled-program cache."""

import numpy as np
import pytest

from repro.core import bitserial as bs
from repro.core.gates import Builder
from repro.kernels import ops as kops


def _ports(widths):
    off = 0
    ports = {}
    for i, w in enumerate(widths):
        ports[f"p{i}"] = list(range(off, off + w))
        off += w
    return ports, off


@pytest.mark.parametrize("rows", [1, 31, 32, 33, 257, 1000])
@pytest.mark.parametrize("widths", [(1,), (5, 32), (63,), (16, 7, 40)])
def test_pack_unpack_roundtrip_narrow(rows, widths):
    ports, n_cells = _ports(widths)
    rng = np.random.default_rng(rows * 31 + sum(widths))
    vals = {n: rng.integers(0, 1 << min(len(c), 62), rows).astype(np.uint64)
            for n, c in ports.items()}
    state = kops.pack_rows(vals, ports, rows, n_cells, pad_to=1)
    got = kops.unpack_rows(state, ports, rows)
    for n in ports:
        assert np.array_equal(got[n], vals[n]), n


@pytest.mark.parametrize("rows", [1, 33, 100])
@pytest.mark.parametrize("width", [64, 80, 128, 200])
def test_pack_unpack_roundtrip_wide(rows, width):
    """> 63-cell ports: arbitrary-precision values as object arrays."""
    ports, n_cells = _ports((width, 3))
    rng = np.random.default_rng(width + rows)
    wide = np.array([int.from_bytes(rng.bytes((width + 7) // 8), "little")
                     & ((1 << width) - 1) for _ in range(rows)], object)
    small = rng.integers(0, 8, rows).astype(np.uint64)
    vals = {"p0": wide, "p1": small}
    state = kops.pack_rows(vals, ports, rows, n_cells, pad_to=1)
    got = kops.unpack_rows(state, ports, rows)
    assert got["p0"].dtype == object
    assert all(int(a) == int(b) for a, b in zip(got["p0"], wide))
    assert np.array_equal(got["p1"], small)


def test_pack_one_cell_and_padding():
    ports, n_cells = _ports((4,))
    vals = {"p0": np.array([5, 9], np.uint64)}
    state = kops.pack_rows(vals, ports, 2, n_cells + 1, one_cell=n_cells,
                           pad_to=8)
    assert state.shape[1] == 8
    assert (state[n_cells] == 0xFFFFFFFF).all()
    got = kops.unpack_rows(state, ports, 2)
    assert np.array_equal(got["p0"], vals["p0"])


def _random_program(seed, n_gates=40):
    rng = np.random.default_rng(seed)
    b = Builder()
    x = b.input("x", 16)
    y = b.input("y", 16)
    avail = x + y
    fns = [b.nor, b.or_, b.and_, b.xor, b.xnor, b.nand]
    for _ in range(n_gates):
        f = fns[rng.integers(0, len(fns))]
        i, j = rng.integers(0, len(avail), 2)
        avail.append(f(avail[i], avail[j]))
    b.output("z", avail[-16:])
    return b.finish()


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_cross_backend_equivalence_random_programs(seed):
    """pallas == ref == numpy (and the gate-serial paths) on randomized
    gate DAGs -- levelization must be invisible to results."""
    p = _random_program(seed)
    rng = np.random.default_rng(seed + 100)
    rows = 77
    ins = {"x": rng.integers(0, 1 << 16, rows).astype(np.uint64),
           "y": rng.integers(0, 1 << 16, rows).astype(np.uint64)}
    want = kops.run_program(p, ins, rows, backend="numpy")["z"]
    for backend in ("ref", "pallas"):
        for levelized in (True, False):
            got = kops.run_program(p, ins, rows, backend=backend,
                                   levelized=levelized)["z"]
            assert np.array_equal(np.asarray(got), np.asarray(want)), \
                (backend, levelized)


def test_cross_backend_equivalence_wide_output():
    """The wide-port (non-fused) executor path agrees across backends."""
    p = bs.build_mul(48)            # z port is 96 cells -> object values
    rng = np.random.default_rng(7)
    rows = 19
    x = rng.integers(0, 1 << 48, rows).astype(np.uint64)
    y = rng.integers(0, 1 << 48, rows).astype(np.uint64)
    ins = {"x": x, "y": y}
    for backend in ("ref", "pallas"):
        got = kops.run_program(p, ins, rows, backend=backend)["z"]
        assert all(int(g) == int(a) * int(b)
                   for g, a, b in zip(got, x, y))


def test_content_hash_cache_is_structural():
    """Structurally identical programs share one compiled entry; different
    programs can never collide (the id()-reuse poisoning of the old cache)."""
    p1 = _random_program(5)
    p2 = _random_program(5)
    p3 = _random_program(6)
    assert p1 is not p2
    assert kops.content_key(p1) == kops.content_key(p2)
    assert kops.content_key(p1) != kops.content_key(p3)
    assert kops.program_schedule(p1) is kops.program_schedule(p2)
    a1 = kops.program_arrays(p1)
    assert kops.program_arrays(p2) is a1


def test_cache_survives_program_gc():
    """A dead program's recycled id must not poison the cache: results for
    a fresh program built at (potentially) the same address stay correct."""
    import gc
    for seed in range(4):
        p = _random_program(seed, n_gates=12)
        rng = np.random.default_rng(seed)
        ins = {"x": rng.integers(0, 1 << 16, 9).astype(np.uint64),
               "y": rng.integers(0, 1 << 16, 9).astype(np.uint64)}
        want = kops.run_program(p, ins, 9, backend="numpy")["z"]
        got = kops.run_program(p, ins, 9, backend="ref")["z"]
        assert np.array_equal(np.asarray(got), np.asarray(want))
        del p
        gc.collect()


def test_run_program_returns_output_ports_only():
    p = bs.build_add(8)
    out = kops.run_program(p, {"x": np.array([3], np.uint64),
                               "y": np.array([4], np.uint64)}, 1,
                           backend="ref")
    assert set(out) == {"z"}
    assert int(out["z"][0]) == 7
