"""End-to-end behaviour: tiny LM trains (loss drops), resume mid-run is
bit-identical, serve generates, PIM numerics plug into a model layer."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.configs.registry import ARCHS
from repro.data.pipeline import DataConfig, DataIterator
from repro.models import model as M
from repro.optim import adamw
from repro.runtime.train_loop import train_loop
from repro.launch.steps import make_train_step


def _mini_setup(tmp_path, steps, total):
    cfg = ARCHS["qwen3-8b"].reduced(vocab=64)
    params = M.init_model(cfg, jax.random.PRNGKey(1))
    opt = adamw.init(params)
    opt_cfg = adamw.AdamWConfig(lr=1e-3, total_steps=total, warmup_steps=2)
    jstep = jax.jit(make_train_step(cfg, 1, opt_cfg))
    dcfg = DataConfig(vocab=64, seq_len=32, global_batch=4, seed=0)

    def step_fn(state, batch):
        mb = {k: jnp.asarray(v)[None] for k, v in batch.items()}
        p, o, metrics = jstep(state["params"], state["opt"], mb)
        return {"params": p, "opt": o}, metrics

    ckpt = CheckpointManager(str(tmp_path), keep=2)
    return step_fn, {"params": params, "opt": opt}, dcfg, ckpt


def test_tiny_lm_loss_decreases(tmp_path):
    step_fn, state, dcfg, ckpt = _mini_setup(tmp_path, 30, 30)
    losses = []
    it = DataIterator(dcfg)
    for _ in range(30):
        state, m = step_fn(state, next(it))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.2, losses[::10]


def test_resume_is_bit_identical(tmp_path):
    # run 1: straight through 8 steps
    step_fn, state, dcfg, ckpt1 = _mini_setup(tmp_path / "a", 8, 8)
    out_a = train_loop(step_fn=step_fn, state=state,
                       data_iter=DataIterator(dcfg), ckpt=ckpt1,
                       total_steps=8, ckpt_every=0, log_every=0,
                       log_fn=lambda *_: None)
    # run 2: checkpoint at 4, new loop resumes and finishes
    step_fn, state, dcfg, ckpt2 = _mini_setup(tmp_path / "b", 8, 8)
    train_loop(step_fn=step_fn, state=state, data_iter=DataIterator(dcfg),
               ckpt=ckpt2, total_steps=4, ckpt_every=0, log_every=0,
               log_fn=lambda *_: None)
    # persist at step 4 (train_loop checkpoints periodically; force one)
    st4 = train_loop(step_fn=step_fn, state=state,
                     data_iter=DataIterator(dcfg), ckpt=ckpt2,
                     total_steps=4, ckpt_every=0, log_every=0,
                     log_fn=lambda *_: None)["state"]
    ckpt2.save(4, st4)
    out_b = train_loop(step_fn=step_fn, state=st4,
                       data_iter=DataIterator(dcfg), ckpt=ckpt2,
                       total_steps=8, ckpt_every=0, log_every=0,
                       log_fn=lambda *_: None)
    wa = np.asarray(out_a["state"]["params"]["embed"], np.float32)
    wb = np.asarray(out_b["state"]["params"]["embed"], np.float32)
    np.testing.assert_array_equal(wa, wb)


def test_serve_generates():
    from repro.launch import serve
    gen = serve.main(["--arch", "qwen3-8b", "--reduced", "--batch", "2",
                      "--prompt-len", "8", "--gen", "4"])
    assert gen.shape == (2, 12)
    assert (gen >= 0).all()


def test_pim_linear_layer_in_model():
    """AritPIM as a numerics backend: an int8 linear layer computed by the
    in-memory algorithms matches the float path to quantization error."""
    from repro.core.pim_numerics import PIMVectorUnit, pim_linear_i8
    rng = np.random.default_rng(0)
    x = rng.standard_normal((4, 16)).astype(np.float32)
    w = rng.standard_normal((16, 8)).astype(np.float32)
    sx = np.abs(x).max() / 127
    sw = np.abs(w).max() / 127
    xq = np.clip(np.round(x / sx), -127, 127).astype(np.int8)
    wq = np.clip(np.round(w / sw), -127, 127).astype(np.int8)
    unit = PIMVectorUnit(backend="pallas")
    y_pim = pim_linear_i8(unit, xq, wq).astype(np.float64) * sx * sw
    y_ref = x @ w
    rel = np.abs(y_pim - y_ref).max() / np.abs(y_ref).max()
    assert rel < 0.05, rel
    # and the integer GEMM itself is exact
    assert np.array_equal(pim_linear_i8(unit, xq, wq),
                          xq.astype(np.int64) @ wq.astype(np.int64))
