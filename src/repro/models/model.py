"""Model assembly: parameter init, forward (train/prefill), decode step.

Layers are organized as ``prefix`` (non-repeated, e.g. deepseek-v2's dense
first layer) plus ``n_groups`` repetitions of ``cfg.group``; the repeated
groups are *stacked* pytrees driven by ``jax.lax.scan`` so the HLO stays
O(group) rather than O(n_layers) -- essential for compiling 94-layer models
on the 512-device dry-run mesh.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import layers as L
from .config import ModelConfig

set_activation_sharder = L.set_activation_sharder
_shard = L._shard


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def init_layer(cfg: ModelConfig, kind: str, key) -> Dict[str, Any]:
    k1, k2, k3 = jax.random.split(key, 3)
    d = cfg.d_model
    p: Dict[str, Any] = {"ln1": jnp.zeros((d,), jnp.float32),
                         "ln2": jnp.zeros((d,), jnp.float32)}
    if kind in ("attn", "local", "cross"):
        if cfg.mla is not None and kind == "attn":
            p["attn"] = L.init_mla(cfg, k1)
        else:
            p["attn"] = L.init_attention(cfg, k1, cross=(kind == "cross"))
        p["ffn"] = L.init_ffn(k2, d, cfg.d_ff)
    elif kind == "moe":
        p["attn"] = (L.init_mla(cfg, k1) if cfg.mla is not None
                     else L.init_attention(cfg, k1))
        p["moe"] = L.init_moe(cfg, k2)
    elif kind == "moe_dense":
        p["attn"] = (L.init_mla(cfg, k1) if cfg.mla is not None
                     else L.init_attention(cfg, k1))
        p["ffn"] = L.init_ffn(k2, d, cfg.moe.d_ff_dense)
    elif kind == "recurrent":
        p["rnn"] = L.init_rglru(cfg, k1)
        p["ffn"] = L.init_ffn(k2, d, cfg.d_ff)
    elif kind == "rwkv":
        p["tmix"] = L.init_rwkv(cfg, k1)
    else:
        raise ValueError(kind)
    return p


def init_model(cfg: ModelConfig, key) -> Dict[str, Any]:
    keys = jax.random.split(key, 8)
    d = cfg.d_model
    params: Dict[str, Any] = {
        "embed": (jax.random.normal(keys[0], (cfg.vocab, d)) * 0.02
                  ).astype(jnp.bfloat16),
        "norm_f": jnp.zeros((d,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L._dense_init(keys[1], (d, cfg.vocab))
    if cfg.frontend != "none":
        params["frontend"] = L._dense_init(keys[2], (cfg.frontend_dim, d))
    params["prefix"] = [
        init_layer(cfg, kind, k)
        for kind, k in zip(cfg.prefix,
                           jax.random.split(keys[3], max(1, len(cfg.prefix))))
    ]
    gkeys = jax.random.split(keys[4], cfg.n_groups)
    params["groups"] = jax.vmap(
        lambda k: [init_layer(cfg, kind, kk)
                   for kind, kk in zip(cfg.group,
                                       jax.random.split(k, len(cfg.group)))]
    )(gkeys)
    return params


# --------------------------------------------------------------------------
# layer application
# --------------------------------------------------------------------------

def apply_layer(cfg: ModelConfig, kind: str, p, x, *, pos, cache=None,
                cross_kv=None):
    aux = jnp.zeros((), jnp.float32)
    if kind == "rwkv":
        h, tc = L.apply_rwkv_timemix(
            cfg, p["tmix"], L.rms_norm(x, p["ln1"], cfg.norm_eps),
            cache=None if cache is None else cache)
        x = x + h
        h, cc = L.apply_rwkv_channelmix(
            cfg, p["tmix"], L.rms_norm(x, p["ln2"], cfg.norm_eps),
            cache=None if cache is None else cache)
        x = x + h
        new_cache = None if cache is None else {**tc, **cc}
        return x, new_cache, aux

    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind == "recurrent":
        h, new_cache = L.apply_rglru(cfg, p["rnn"], h, cache=cache)
    elif cfg.mla is not None and kind in ("attn", "moe", "moe_dense"):
        h, new_cache = L.apply_mla(cfg, p["attn"], h, pos=pos, cache=cache)
    else:
        akind = {"moe": "attn", "moe_dense": "attn"}.get(kind, kind)
        h, new_cache = L.apply_attention(cfg, p["attn"], h, pos=pos,
                                         kind=akind, cache=cache,
                                         cross_kv=cross_kv)
    x = x + h
    h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    if kind == "moe":
        h, aux = L.apply_moe(cfg, p["moe"], h)
    else:
        h = L.apply_ffn(p["ffn"], h)
    x = x + h
    return x, new_cache, aux


def _cross_kv(cfg: ModelConfig, p_attn, xv):
    b, sv, _ = xv.shape
    k = (xv @ p_attn["wk"]).reshape(b, sv, cfg.n_kv_heads, cfg.hd)
    v = (xv @ p_attn["wv"]).reshape(b, sv, cfg.n_kv_heads, cfg.hd)
    return k, v


# --------------------------------------------------------------------------
# forward (train / prefill)
# --------------------------------------------------------------------------

def forward(cfg: ModelConfig, params, batch: Dict[str, jnp.ndarray], *,
            remat: bool = True):
    """Returns (logits, aux_loss_mean).  batch keys: tokens [B,S] (or
    frames [B,S,Df] for audio), optional vision [B,Sv,Df]."""
    if cfg.frontend == "audio":
        x = batch["frames"].astype(jnp.bfloat16) @ params["frontend"]
    else:
        x = params["embed"][batch["tokens"]]
    x = _shard("act", x)
    b, s, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    xv = None
    if cfg.frontend == "vision":
        xv = batch["vision"].astype(jnp.bfloat16) @ params["frontend"]

    aux_total = jnp.zeros((), jnp.float32)
    for kind, p in zip(cfg.prefix, params["prefix"]):
        x, _, aux = apply_layer(cfg, kind, p, x, pos=pos)
        aux_total += aux

    def group_body(x, gp):
        ax = jnp.zeros((), jnp.float32)
        for kind, p in zip(cfg.group, gp):
            ckv = _cross_kv(cfg, p["attn"], xv) if kind == "cross" else None
            x, _, aux = apply_layer(cfg, kind, p, x, pos=pos, cross_kv=ckv)
            ax += aux
        return _shard("act", x), ax

    body = jax.checkpoint(group_body,
                          policy=jax.checkpoint_policies.nothing_saveable) \
        if remat else group_body
    x, auxs = jax.lax.scan(body, x, params["groups"])
    aux_total += auxs.sum()

    x = L.rms_norm(x, params["norm_f"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = _shard("logits", x @ head)
    return logits, aux_total / max(cfg.n_layers, 1)


def prefill(cfg: ModelConfig, params, batch: Dict[str, jnp.ndarray]):
    """Inference prefill: full-sequence forward that also emits the decode
    caches (the realistic prefill workload: attention FLOPs + cache
    writes), returning last-position logits + caches."""
    if cfg.frontend == "audio":
        x = batch["frames"].astype(jnp.bfloat16) @ params["frontend"]
    else:
        x = params["embed"][batch["tokens"]]
    x = _shard("act", x)
    b, s, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    xv = None
    if cfg.frontend == "vision":
        xv = batch["vision"].astype(jnp.bfloat16) @ params["frontend"]

    new_prefix = []
    for kind, p in zip(cfg.prefix, params["prefix"]):
        x, nc, _ = apply_layer(cfg, kind, p, x, pos=pos, cache="collect")
        new_prefix.append(nc)

    def group_body(x, gp):
        ncs = []
        for kind, p in zip(cfg.group, gp):
            ckv = _cross_kv(cfg, p["attn"], xv) if kind == "cross" else None
            x, nc, _ = apply_layer(cfg, kind, p, x, pos=pos, cache="collect",
                                   cross_kv=ckv)
            ncs.append(nc)
        return _shard("act", x), ncs

    x, group_caches = jax.lax.scan(group_body, x, params["groups"])
    x = L.rms_norm(x[:, -1:], params["norm_f"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = _shard("logits", x @ head)[:, 0]
    return logits, {"prefix": new_prefix, "groups": group_caches}


def loss_fn(cfg: ModelConfig, params, batch, *, remat: bool = True):
    logits, aux = forward(cfg, params, batch, remat=remat)
    labels = batch["labels"]
    lf = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    nll = ((logz - ll) * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll + 0.01 * aux, {"nll": nll, "aux": aux}


# --------------------------------------------------------------------------
# decode
# --------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, kind: str, batch: int, max_seq: int):
    hd, kv = cfg.hd, cfg.n_kv_heads
    if kind in ("attn", "moe", "moe_dense"):
        if cfg.mla is not None:
            m = cfg.mla
            return {"c": jnp.zeros((batch, max_seq, m.kv_lora), jnp.bfloat16),
                    "r": jnp.zeros((batch, max_seq, m.rope_head_dim),
                                   jnp.bfloat16)}
        return {"k": jnp.zeros((batch, max_seq, kv, hd), jnp.bfloat16),
                "v": jnp.zeros((batch, max_seq, kv, hd), jnp.bfloat16)}
    if kind == "local":
        w = cfg.window
        return {"k": jnp.zeros((batch, w, kv, hd), jnp.bfloat16),
                "v": jnp.zeros((batch, w, kv, hd), jnp.bfloat16),
                "pos": jnp.full((batch, w), -10 ** 9, jnp.int32)}
    if kind == "cross":
        return {}
    if kind == "recurrent":
        dr = cfg.d_rnn or cfg.d_model
        return {"h": jnp.zeros((batch, dr), jnp.float32),
                "conv": jnp.zeros((batch, 3, dr), jnp.bfloat16)}
    if kind == "rwkv":
        h = cfg.n_heads
        hd2 = cfg.d_model // h
        return {"s": jnp.zeros((batch, h, hd2, hd2), jnp.float32),
                "xa": jnp.zeros((batch, cfg.d_model), jnp.bfloat16),
                "xc": jnp.zeros((batch, cfg.d_model), jnp.bfloat16)}
    raise ValueError(kind)


def init_caches(cfg: ModelConfig, batch: int, max_seq: int):
    prefix = [init_cache(cfg, kind, batch, max_seq) for kind in cfg.prefix]
    one_group = [init_cache(cfg, kind, batch, max_seq) for kind in cfg.group]
    groups = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (cfg.n_groups,) + x.shape).copy()
        if cfg.n_groups else x, one_group)
    return {"prefix": prefix, "groups": groups}


def decode_step(cfg: ModelConfig, params, caches, token, pos_idx,
                vision=None):
    """One decode step.  token [B], pos_idx [] int32; returns
    (logits [B,V], new caches)."""
    b = token.shape[0]
    x = params["embed"][token][:, None]
    pos = jnp.broadcast_to(pos_idx[None, None], (b, 1)).astype(jnp.int32)
    xv = None
    if cfg.frontend == "vision":
        xv = vision.astype(jnp.bfloat16) @ params["frontend"]

    new_prefix = []
    for kind, p, c in zip(cfg.prefix, params["prefix"], caches["prefix"]):
        x, nc, _ = apply_layer(cfg, kind, p, x, pos=pos, cache=c)
        new_prefix.append(nc)

    def group_body(x, gp_c):
        gp, gc = gp_c
        ncs = []
        for kind, p, c in zip(cfg.group, gp, gc):
            ckv = _cross_kv(cfg, p["attn"], xv) if kind == "cross" else None
            if kind == "cross":
                x, nc, _ = apply_layer(cfg, kind, p, x, pos=pos,
                                       cross_kv=ckv)
                nc = c
            else:
                x, nc, _ = apply_layer(cfg, kind, p, x, pos=pos, cache=c)
            ncs.append(nc)
        return x, ncs

    x, new_groups = jax.lax.scan(group_body, x,
                                 (params["groups"], caches["groups"]))
    x = L.rms_norm(x, params["norm_f"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = _shard("logits", (x @ head))[:, 0]
    return logits, {"prefix": new_prefix, "groups": new_groups}
