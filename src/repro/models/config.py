"""Model configuration covering all ten assigned architecture families."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int
    n_shared: int = 0
    capacity_factor: float = 1.25
    first_dense: int = 0          # leading dense layers (deepseek-v2)
    d_ff_dense: int = 0           # their ffn width


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 multi-head latent attention."""
    q_lora: int = 1536
    kv_lora: int = 512
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                       # 0 -> d_model // n_heads
    # layer-kind pattern: repeating group + optional non-repeated prefix
    group: Tuple[str, ...] = ("attn",)      # kinds: attn/local/recurrent/
    prefix: Tuple[str, ...] = ()            # rwkv/cross/moe/moe_dense
    # attention flavor
    qk_norm: bool = False
    qkv_bias: bool = False
    window: int = 0                         # local attention window
    rope_theta: float = 10000.0
    mla: Optional[MLAConfig] = None
    # mixture of experts
    moe: Optional[MoEConfig] = None
    # recurrent blocks
    d_rnn: int = 0                          # RG-LRU width (0 -> d_model)
    # modality frontend stubs
    frontend: str = "none"                  # none / audio / vision
    frontend_dim: int = 0                   # stub embedding dim
    vision_seq: int = 1601                  # image tokens (precomputed stub)
    encoder_only: bool = False
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_groups(self) -> int:
        n = self.n_layers - len(self.prefix)
        assert n % len(self.group) == 0, (self.name, n, self.group)
        return n // len(self.group)

    @property
    def sub_quadratic(self) -> bool:
        """True when no layer attends over unbounded context (long_500k ok).
        'moe'/'moe_dense' layers carry full attention too."""
        kinds = set(self.group) | set(self.prefix)
        return not (kinds & {"attn", "cross", "moe", "moe_dense"}) and "cross" not in kinds

    @property
    def n_params(self) -> int:
        """Total parameter count (embedding + layers), for 6ND."""
        d, v = self.d_model, self.vocab
        total = v * d * (1 if self.tie_embeddings else 2)
        if self.frontend != "none":
            total += self.frontend_dim * d
        kinds = list(self.prefix) + list(self.group) * self.n_groups
        for kind in kinds:
            total += self._layer_params(kind)
        return total

    @property
    def n_params_active(self) -> int:
        d, v = self.d_model, self.vocab
        total = v * d * (1 if self.tie_embeddings else 2)
        kinds = list(self.prefix) + list(self.group) * self.n_groups
        for kind in kinds:
            total += self._layer_params(kind, active=True)
        return total

    def _layer_params(self, kind: str, active: bool = False) -> int:
        d = self.d_model
        hd = self.hd
        if kind in ("attn", "local", "cross"):
            if self.mla is not None:
                m = self.mla
                qdim = self.n_heads * (m.nope_head_dim + m.rope_head_dim)
                attn = (d * m.q_lora + m.q_lora * qdim
                        + d * (m.kv_lora + m.rope_head_dim)
                        + m.kv_lora * self.n_heads
                        * (m.nope_head_dim + m.v_head_dim)
                        + self.n_heads * m.v_head_dim * d)
            else:
                attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
                    + self.n_heads * hd * d
            ffn = 3 * d * self.d_ff
            return attn + ffn + 2 * d
        if kind == "recurrent":
            dr = self.d_rnn or d
            return 2 * d * dr + dr * d + 2 * dr + 3 * d * self.d_ff + 2 * d
        if kind == "rwkv":
            return 4 * d * d + d * d + 2 * d * self.d_ff + 2 * d
        if kind in ("moe", "moe_dense"):
            m = self.moe
            if self.mla is not None:
                mm = self.mla
                qdim = self.n_heads * (mm.nope_head_dim + mm.rope_head_dim)
                attn = (d * mm.q_lora + mm.q_lora * qdim
                        + d * (mm.kv_lora + mm.rope_head_dim)
                        + mm.kv_lora * self.n_heads
                        * (mm.nope_head_dim + mm.v_head_dim)
                        + self.n_heads * mm.v_head_dim * d)
            else:
                attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
                    + self.n_heads * hd * d
            if kind == "moe_dense":
                return attn + 3 * d * m.d_ff_dense + 2 * d
            router = d * m.n_experts
            n_e = (m.top_k + m.n_shared) if active else \
                (m.n_experts + m.n_shared)
            return attn + router + n_e * 3 * d * m.d_expert + 2 * d
        raise ValueError(kind)

    def reduced(self, **overrides) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        base = dict(
            n_layers=len(self.prefix) + 2 * len(self.group),
            d_model=64, n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads
            else self.n_kv_heads,
            d_ff=128, vocab=256, head_dim=16, window=min(self.window, 32),
            d_rnn=32 if self.d_rnn else 0, frontend_dim=32
            if self.frontend != "none" else 0, vision_seq=8)
        if self.moe:
            base["moe"] = dataclasses.replace(
                self.moe, n_experts=4, top_k=2, d_expert=32,
                d_ff_dense=128 if self.moe.d_ff_dense else 0)
        if self.mla:
            base["mla"] = MLAConfig(q_lora=32, kv_lora=16, rope_head_dim=8,
                                    nope_head_dim=16, v_head_dim=16)
        base.update(overrides)
        return dataclasses.replace(self, **base)
