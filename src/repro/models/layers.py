"""Pure-JAX layer library for the assigned architecture families.

Functional style: ``init_*`` builds parameter pytrees (nested dicts of
jnp arrays), ``apply_*`` are pure functions.  Everything is scan-friendly
(shape-static) and sharding-annotation free -- sharding is applied by the
launcher via in/out shardings + a few with_sharding_constraint hooks.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig

Params = Dict[str, Any]
ATTN_CHUNK = 512          # query-chunked attention threshold / block

# activation-sharding hook installed by the launcher: (tag, array) -> array
_SHARDER = lambda tag, x: x


def set_activation_sharder(fn) -> None:
    global _SHARDER
    _SHARDER = fn


def _shard(tag, x):
    return _SHARDER(tag, x)


def _dense_init(key, shape, scale=None):
    scale = scale if scale is not None else 1.0 / np.sqrt(shape[0])
    return (jax.random.normal(key, shape, jnp.float32) * scale
            ).astype(jnp.bfloat16)


def rms_norm(x, w, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dt)


def rotary(x, pos, theta, rot_dim=None):
    """x: [..., S, H, hd]; pos: [..., S] int32."""
    hd = x.shape[-1]
    rd = rot_dim or hd
    freqs = 1.0 / (theta ** (np.arange(0, rd, 2, dtype=np.float32) / rd))
    ang = pos[..., None].astype(jnp.float32) * freqs        # [..., S, rd/2]
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    xr, rest = x[..., :rd], x[..., rd:]
    x1, x2 = xr[..., : rd // 2], xr[..., rd // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return jnp.concatenate([out.astype(x.dtype), rest], -1)


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------

def init_attention(cfg: ModelConfig, key, cross: bool = False) -> Params:
    d, hd = cfg.d_model, cfg.hd
    ks = jax.random.split(key, 8)
    p = {
        "wq": _dense_init(ks[0], (d, cfg.n_heads * hd)),
        "wk": _dense_init(ks[1], (d, cfg.n_kv_heads * hd)),
        "wv": _dense_init(ks[2], (d, cfg.n_kv_heads * hd)),
        "wo": _dense_init(ks[3], (cfg.n_heads * hd, d)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), jnp.bfloat16)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), jnp.bfloat16)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), jnp.bfloat16)
    if cfg.qk_norm:
        p["qnorm"] = jnp.zeros((hd,), jnp.float32)
        p["knorm"] = jnp.zeros((hd,), jnp.float32)
    if cross:
        p["gate"] = jnp.zeros((), jnp.float32)   # zero-init cross-attn gate
    return p


def _group_attn(q, k, v, mask):
    """Grouped-query attention core (no KV-head replication).
    q: [B,Sq,H,hd]; k,v: [B,Sk,K,hd]; mask broadcastable to [B,Sq,Sk]."""
    b, sq, h, hd = q.shape
    kh = k.shape[2]
    g = h // kh
    qg = q.reshape(b, sq, kh, g, hd)
    s = jnp.einsum("bqkgd,bskd->bqkgs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * (1.0 / np.sqrt(hd))
    s = jnp.where(mask[:, :, None, None, :], s, -1e30)
    a = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqkgs,bskd->bqkgd", a.astype(v.dtype), v)
    return o.reshape(b, sq, h, v.shape[-1])   # v head dim may differ (MLA)


def _sdpa(q, k, v, *, causal, window, q_offset=0):
    """Query-chunked attention: bounds the [chunk, Sk] score tile (the
    flash-style memory fix expressed in pure JAX; XLA fuses the softmax)."""
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    kpos = jnp.arange(sk)

    def attend(qc, qpos):
        m = jnp.ones((qc.shape[1], sk), bool)
        if causal:
            m &= kpos[None, :] <= qpos[:, None]
        if window:
            m &= kpos[None, :] > qpos[:, None] - window
        return _group_attn(qc, k, v, m[None])

    if sq <= ATTN_CHUNK:
        return attend(q, jnp.arange(sq) + q_offset)
    nc = sq // ATTN_CHUNK
    qs = q.reshape(b, nc, ATTN_CHUNK, h, hd).transpose(1, 0, 2, 3, 4)

    def body(_, qc_i):
        qc, i = qc_i
        qpos = i * ATTN_CHUNK + jnp.arange(ATTN_CHUNK) + q_offset
        return None, attend(qc, qpos)

    _, out = jax.lax.scan(body, None, (qs, jnp.arange(nc)))
    return out.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, -1)  # -1: MLA vhd


def apply_attention(cfg: ModelConfig, p: Params, x, *, pos, kind: str,
                    cache=None, cross_kv=None):
    """kind: attn | local | cross.  Returns (out, new_cache)."""
    b, s, d = x.shape
    hd = cfg.hd
    q = x @ p["wq"] + (p.get("bq", 0))
    q = q.reshape(b, s, cfg.n_heads, hd)
    if kind == "cross":
        k, v = cross_kv
    else:
        k = (x @ p["wk"] + p.get("bk", 0)).reshape(b, s, cfg.n_kv_heads, hd)
        v = (x @ p["wv"] + p.get("bv", 0)).reshape(b, s, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["qnorm"], cfg.norm_eps)
        if kind != "cross":
            k = rms_norm(k, p["knorm"], cfg.norm_eps)
    if kind != "cross":
        q = rotary(q, pos, cfg.rope_theta)
        k = rotary(k, pos, cfg.rope_theta)

    new_cache = None
    if isinstance(cache, dict) and kind != "cross":  # decode: append + read
        if kind == "local":
            w = cfg.window
            i = pos[0, 0] % w                       # ring-buffer slot
            ck = cache["k"].at[:, i].set(k[:, 0])
            cv = cache["v"].at[:, i].set(v[:, 0])
            kpos = cache["pos"].at[:, i].set(pos[:, 0])
            new_cache = {"k": ck, "v": cv, "pos": kpos}
            k, v = ck, cv
            valid = (kpos <= pos[:, :1]) & (kpos > pos[:, :1] - w)
        else:
            i = pos[0, 0]
            ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, i, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, i, axis=1)
            new_cache = {"k": ck, "v": cv}
            k, v = ck, cv
            valid = (jnp.arange(k.shape[1])[None] <= i) & \
                jnp.ones((b, 1), bool)
        out = _group_attn(q, k, v, valid[:, None, :])
    else:
        causal = not cfg.encoder_only and kind != "cross"
        out = _sdpa(q, k, v, causal=causal,
                    window=cfg.window if kind == "local" else 0)
        if cache == "collect":                  # prefill: emit decode cache
            if kind == "local":
                w = cfg.window
                n = min(s, w)
                pp = jnp.arange(s - n, s)
                slots = pp % w
                ring = lambda z: jnp.zeros(
                    (b, w) + z.shape[2:], z.dtype).at[:, slots].set(z[:, -n:])
                posbuf = jnp.full((w,), -10 ** 9, jnp.int32
                                  ).at[slots].set(pp.astype(jnp.int32))
                new_cache = {"k": ring(k), "v": ring(v),
                             "pos": jnp.broadcast_to(posbuf[None], (b, w))}
            elif kind == "cross":
                new_cache = {}
            else:
                new_cache = {"k": k, "v": v}
        elif isinstance(cache, dict) and kind == "cross":
            new_cache = {}
    out = out.reshape(b, s, cfg.n_heads * hd) @ p["wo"]
    if kind == "cross":
        out = out * jnp.tanh(p["gate"]).astype(out.dtype)
    return out, new_cache


# --------------------------------------------------------------------------
# MLA (DeepSeek-V2 multi-head latent attention)
# --------------------------------------------------------------------------

def init_mla(cfg: ModelConfig, key) -> Params:
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 6)
    qdim = h * (m.nope_head_dim + m.rope_head_dim)
    return {
        "wq_a": _dense_init(ks[0], (d, m.q_lora)),
        "q_norm": jnp.zeros((m.q_lora,), jnp.float32),
        "wq_b": _dense_init(ks[1], (m.q_lora, qdim)),
        "wkv_a": _dense_init(ks[2], (d, m.kv_lora + m.rope_head_dim)),
        "kv_norm": jnp.zeros((m.kv_lora,), jnp.float32),
        "wkv_b": _dense_init(
            ks[3], (m.kv_lora, h * (m.nope_head_dim + m.v_head_dim))),
        "wo": _dense_init(ks[4], (h * m.v_head_dim, d)),
    }


def apply_mla(cfg: ModelConfig, p: Params, x, *, pos, cache=None):
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    nhd, rhd, vhd = m.nope_head_dim, m.rope_head_dim, m.v_head_dim

    q = rms_norm(x @ p["wq_a"], p["q_norm"], cfg.norm_eps) @ p["wq_b"]
    q = q.reshape(b, s, h, nhd + rhd)
    q_nope, q_rope = q[..., :nhd], q[..., nhd:]
    q_rope = rotary(q_rope, pos, cfg.rope_theta)

    kv = x @ p["wkv_a"]
    c_kv = rms_norm(kv[..., : m.kv_lora], p["kv_norm"], cfg.norm_eps)
    k_rope = rotary(kv[..., m.kv_lora:][:, :, None, :], pos, cfg.rope_theta)

    wkv_b = p["wkv_b"].reshape(m.kv_lora, h, nhd + vhd)
    scale = 1.0 / np.sqrt(nhd + rhd)

    if isinstance(cache, dict):
        i = pos[0, 0]
        cc = jax.lax.dynamic_update_slice_in_dim(cache["c"], c_kv, i, axis=1)
        cr = jax.lax.dynamic_update_slice_in_dim(
            cache["r"], k_rope[:, :, 0], i, axis=1)
        new_cache = {"c": cc, "r": cr}
        # absorbed decode: score via the latent space (the MLA cache win)
        q_abs = jnp.einsum("bqhn,lhn->bqhl", q_nope.astype(jnp.float32),
                           wkv_b[..., :nhd].astype(jnp.float32))
        sc = jnp.einsum("bqhl,bkl->bhqk", q_abs, cc.astype(jnp.float32))
        sc += jnp.einsum("bqhr,bkr->bhqk", q_rope.astype(jnp.float32),
                         cr.astype(jnp.float32))
        sc = sc * scale
        valid = jnp.arange(cc.shape[1])[None] <= i
        sc = jnp.where(valid[:, None, None, :], sc, -1e30)
        a = jax.nn.softmax(sc, axis=-1)
        o_lat = jnp.einsum("bhqk,bkl->bqhl", a, cc.astype(jnp.float32))
        out = jnp.einsum("bqhl,lhv->bqhv", o_lat,
                         wkv_b[..., nhd:].astype(jnp.float32))
        out = out.astype(x.dtype)
    else:
        new_cache = {"c": c_kv, "r": k_rope[:, :, 0]} if cache == "collect" \
            else None
        kvu = jnp.einsum("bkl,lhx->bkhx", c_kv, wkv_b)
        k_nope, v = kvu[..., :nhd], kvu[..., nhd:]
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope, (b, s, h, rhd))], -1)
        qf = jnp.concatenate([q_nope, q_rope], -1)
        out = _sdpa(qf, k, v, causal=True, window=0)
    out = out.reshape(b, s, h * vhd) @ p["wo"]
    return out, new_cache


# --------------------------------------------------------------------------
# feed-forward / MoE
# --------------------------------------------------------------------------

def init_ffn(key, d, ff) -> Params:
    ks = jax.random.split(key, 3)
    return {"w1": _dense_init(ks[0], (d, ff)),
            "w3": _dense_init(ks[1], (d, ff)),
            "w2": _dense_init(ks[2], (ff, d))}


def apply_ffn(p: Params, x):
    return (jax.nn.silu(x @ p["w1"]) * (x @ p["w3"])) @ p["w2"]


def init_moe(cfg: ModelConfig, key) -> Params:
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    p = {
        "router": _dense_init(ks[0], (d, m.n_experts)).astype(jnp.float32),
        "w1": _dense_init(ks[1], (m.n_experts, d, m.d_expert)),
        "w3": _dense_init(ks[2], (m.n_experts, d, m.d_expert)),
        "w2": _dense_init(ks[3], (m.n_experts, m.d_expert, d)),
    }
    if m.n_shared:
        p["shared"] = init_ffn(ks[4], d, m.n_shared * m.d_expert)
    return p


# dispatch groups: set by the launcher to the DP shard count so the
# per-group sort/scatter is device-local (no cross-shard gathers)
_MOE_GROUPS = 1


def set_moe_groups(n: int) -> None:
    global _MOE_GROUPS
    _MOE_GROUPS = max(1, int(n))


def apply_moe(cfg: ModelConfig, p: Params, x) -> Tuple[jnp.ndarray,
                                                       jnp.ndarray]:
    """Grouped sort-based capacity MoE (drop on overflow).

    Tokens are split into G groups (G == DP shards): the argsort /
    position-cumsum / scatter are group-local, so under batch sharding the
    dispatch never leaves the device; only the expert einsums touch the
    'model'-sharded expert weights.  Returns (y, aux_loss)."""
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    g = _MOE_GROUPS if t % _MOE_GROUPS == 0 else 1
    tg = t // g
    xf = x.reshape(g, tg, d)
    logits = xf.astype(jnp.float32) @ p["router"]            # [G,Tg,E]
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, m.top_k)                   # [G,Tg,k]
    w = w / (w.sum(-1, keepdims=True) + 1e-9)
    cap = int(np.ceil(tg * m.top_k / m.n_experts * m.capacity_factor))

    def dispatch(xg, idxg, wg):
        e_flat = idxg.reshape(-1)                            # [Tg*k]
        src = jnp.repeat(jnp.arange(tg), m.top_k)
        perm = jnp.argsort(e_flat)
        se, ss = e_flat[perm], src[perm]
        counts = jnp.bincount(e_flat, length=m.n_experts)
        starts = jnp.cumsum(counts) - counts
        pos = jnp.arange(tg * m.top_k) - starts[se]
        keep = pos < cap
        pos = jnp.where(keep, pos, cap - 1)
        buf = jnp.zeros((m.n_experts, cap, d), x.dtype)
        buf = buf.at[se, pos].set(
            jnp.where(keep[:, None], xg[ss], jnp.zeros((), x.dtype)))
        return buf, (se, ss, pos, keep, wg.reshape(-1)[perm], counts)

    buf, (se, ss, pos, keep, wp, counts) = jax.vmap(dispatch)(xf, idx, w)
    buf = _shard("moe_buf", buf)
    # ZeRO-3 style: gather the (small) FSDP-sharded expert weights at use
    # instead of letting XLA psum the (large) expert activations (perf C2)
    w1 = _shard("moe_w", p["w1"])
    w3 = _shard("moe_w", p["w3"])
    w2 = _shard("moe_w", p["w2"])
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, w1)) * \
        jnp.einsum("gecd,edf->gecf", buf, w3)
    eo = jnp.einsum("gecf,efd->gecd", h, w2)
    # replicate expert outputs across the EP axis once (one all-gather of
    # [E,C,d]) so the token-indexed combine gather is shard-local -- beats
    # XLA's masked all-reduce per gather (perf C4)
    eo = _shard("moe_eo", eo)

    def combine(eog, se1, ss1, pos1, keep1, wp1):
        # bf16 end-to-end: the [tg*topk, d] gather payload crosses the EP
        # shards; keeping it bf16 halves the combine collective (perf C3)
        w16 = jnp.where(keep1, wp1, 0).astype(x.dtype)
        out = eog[se1, pos1] * w16[:, None]
        return jnp.zeros((tg, d), x.dtype).at[ss1].add(out)

    y = jax.vmap(combine)(eo, se, ss, pos, keep, wp)

    # load-balance aux loss (Switch-style), computed globally
    frac = counts.sum(0).astype(jnp.float32) / (t * m.top_k)
    imp = probs.mean((0, 1))
    aux = (frac * imp).sum() * m.n_experts

    y = y.reshape(b, s, d)
    if m.n_shared:
        y = y + apply_ffn(p["shared"], x)
    return y, aux


# --------------------------------------------------------------------------
# RG-LRU recurrent block (RecurrentGemma / Griffin)
# --------------------------------------------------------------------------

def init_rglru(cfg: ModelConfig, key) -> Params:
    d = cfg.d_model
    dr = cfg.d_rnn or d
    ks = jax.random.split(key, 7)
    return {
        "w_x": _dense_init(ks[0], (d, dr)),
        "w_gate": _dense_init(ks[1], (d, dr)),
        "conv": (jax.random.normal(ks[2], (4, dr)) * 0.1).astype(jnp.bfloat16),
        "w_in_gate": _dense_init(ks[3], (dr, dr), scale=0.01),
        "w_rec_gate": _dense_init(ks[4], (dr, dr), scale=0.01),
        "lam": jnp.full((dr,), 3.0, jnp.float32),   # a = sigmoid(lam)^(8 r)
        "w_out": _dense_init(ks[5], (dr, d)),
    }


def apply_rglru(cfg: ModelConfig, p: Params, x, *, cache=None):
    """Griffin recurrent block: conv1d(4) + RG-LRU, gated."""
    b, s, _ = x.shape
    u = x @ p["w_x"]                                   # [B,S,dr]
    gate = jax.nn.gelu((x @ p["w_gate"]).astype(jnp.float32))
    # causal depthwise conv width 4
    if isinstance(cache, dict):
        hist = jnp.concatenate([cache["conv"], u], axis=1)   # [B,3+S,dr]
        new_conv = hist[:, -3:]
    else:
        hist = jnp.pad(u, ((0, 0), (3, 0), (0, 0)))
        new_conv = hist[:, -3:]
    u = sum(hist[:, i: i + s] * p["conv"][i] for i in range(4))

    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf @ p["w_rec_gate"].astype(jnp.float32))
    i = jax.nn.sigmoid(uf @ p["w_in_gate"].astype(jnp.float32))
    log_a = -8.0 * r * jax.nn.softplus(-p["lam"])      # log sigmoid(lam)^(8r)
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-9)) * (i * uf)

    if isinstance(cache, dict):                        # single-step decode
        h0 = cache["h"]
        h = a[:, 0] * h0 + gated[:, 0]
        hs = h[:, None]
        new_cache = {"h": h, "conv": new_conv}
    else:
        def comb(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, a2 * b1 + b2
        aa, hs = jax.lax.associative_scan(comb, (a, gated), axis=1)
        new_cache = {"h": hs[:, -1], "conv": new_conv} \
            if cache == "collect" else None
    out = (hs * gate).astype(x.dtype) @ p["w_out"]
    return out, new_cache


# --------------------------------------------------------------------------
# RWKV6 (Finch): time mix with data-dependent decay + channel mix
# --------------------------------------------------------------------------

WKV_CHUNK = 64


def init_rwkv(cfg: ModelConfig, key) -> Params:
    d = cfg.d_model
    ks = jax.random.split(key, 10)
    lora = 64
    return {
        "mu": (jnp.full((5, d), 0.5, jnp.float32)),     # r,k,v,w,g mixes
        "wr": _dense_init(ks[0], (d, d)),
        "wk": _dense_init(ks[1], (d, d)),
        "wv": _dense_init(ks[2], (d, d)),
        "wg": _dense_init(ks[3], (d, d)),
        "w0": jnp.full((d,), -5.0, jnp.float32),
        "wA": _dense_init(ks[4], (d, lora), scale=0.01).astype(jnp.float32),
        "wB": _dense_init(ks[5], (lora, d), scale=0.01).astype(jnp.float32),
        "u": (jax.random.normal(ks[6], (d,)) * 0.1).astype(jnp.float32),
        "wo": _dense_init(ks[7], (d, d)),
        "mu_c": jnp.full((2, d), 0.5, jnp.float32),     # channel-mix mixes
        "ck": _dense_init(ks[8], (d, cfg.d_ff)),
        "cv": _dense_init(ks[9], (cfg.d_ff, d)),
        "cr": _dense_init(jax.random.split(ks[8])[0], (d, d)),
    }


def _wkv_chunked(r, k, v, w, u, s0):
    """Chunked WKV6 scan.  r,k,v: [B,H,T,hd]; w (decay in (0,1)): same;
    u: [H,hd]; s0: [B,H,hd,hd] initial state.  Returns (y, sT)."""
    b, h, t, hd = r.shape
    c = min(WKV_CHUNK, t)
    nc = t // c
    rs, ks_, vs, ws = (z.reshape(b, h, nc, c, hd).transpose(2, 0, 1, 3, 4)
                       for z in (r, k, v, w))
    lw = jnp.log(ws)                                   # [nc,B,H,C,hd] (<0)
    L = jnp.cumsum(lw, axis=3)                         # inclusive

    def step(s, inp):
        rc, kc, vc, lwc, Lc = inp                      # [B,H,C,hd]
        # cross-chunk: y_t += (r_t * P_{t-1}) @ s, P_{t-1} = exp(L_{t-1})
        Pprev = jnp.exp(Lc - lwc)                      # exp(L_{t-1})
        y = jnp.einsum("bhcd,bhde->bhce", rc * Pprev, s)
        # intra-chunk: A[t,tau] = sum_d r_t[d] k_tau[d] exp(L_{t-1}-L_tau)
        ratio = jnp.exp((Lc - lwc)[:, :, :, None, :] - Lc[:, :, None, :, :])
        am = jnp.tril(jnp.ones((c, c)), -1)[None, None, :, :, None]
        A = ((rc[:, :, :, None, :] * kc[:, :, None, :, :]) * ratio * am
             ).sum(-1)
        y += jnp.einsum("bhct,bhte->bhce", A, vc)
        # current-token bonus: y_t += (r_t . u . k_t) v_t
        y += (rc * u[None, :, None, :] * kc).sum(-1, keepdims=True) * vc
        # state update: s' = diag(exp(L_C)) s + sum_tau exp(L_C - L_tau) k v^T
        decay_all = jnp.exp(Lc[:, :, -1, :])            # [B,H,hd]
        kw = kc * jnp.exp(Lc[:, :, -1:, :] - Lc)
        s_new = decay_all[:, :, :, None] * s + \
            jnp.einsum("bhcd,bhce->bhde", kw, vc)
        return s_new, y

    sT, ys = jax.lax.scan(step, s0, (rs, ks_, vs, lw, L))
    y = ys.transpose(1, 2, 0, 3, 4).reshape(b, h, t, hd)
    return y, sT


def apply_rwkv_timemix(cfg: ModelConfig, p: Params, x, *, cache=None):
    b, s, d = x.shape
    h = cfg.n_heads
    hd = d // h
    if isinstance(cache, dict):
        xprev = jnp.concatenate([cache["xa"][:, None], x[:, :-1]], 1)
    else:
        xprev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    mixes = [x + (xprev - x) * p["mu"][i].astype(x.dtype) for i in range(5)]
    r = (mixes[0] @ p["wr"]).reshape(b, s, h, hd)
    k = (mixes[1] @ p["wk"]).reshape(b, s, h, hd)
    v = (mixes[2] @ p["wv"]).reshape(b, s, h, hd)
    g = jax.nn.silu(mixes[4] @ p["wg"])
    wlog = p["w0"] + jnp.tanh(mixes[3].astype(jnp.float32) @ p["wA"]) @ p["wB"]
    w = jnp.exp(-jnp.exp(wlog)).reshape(b, s, h, hd)   # decay in (0,1)

    tb = lambda z: z.transpose(0, 2, 1, 3)             # [B,H,S,hd]
    rf, kf, vf = (tb(z).astype(jnp.float32) for z in (r, k, v))
    wf = tb(w)
    u = p["u"].reshape(h, hd)
    s0 = cache["s"] if isinstance(cache, dict) else \
        jnp.zeros((b, h, hd, hd), jnp.float32)
    if s == 1 and isinstance(cache, dict):              # decode fast path
        y = ((rf * u[None, :, None]) * kf).sum(-1, keepdims=True) * vf + \
            jnp.einsum("bhcd,bhde->bhce", rf, s0)
        sT = wf[:, :, 0, :, None] * s0 + \
            jnp.einsum("bhd,bhe->bhde", kf[:, :, 0], vf[:, :, 0])
    else:
        y, sT = _wkv_chunked(rf, kf, vf, wf, u, s0)
    y = y.transpose(0, 2, 1, 3).reshape(b, s, d).astype(x.dtype)
    out = (y * g) @ p["wo"]
    new_cache = {"s": sT, "xa": x[:, -1]} if cache is not None else None
    return out, new_cache


def apply_rwkv_channelmix(cfg, p, x, *, cache=None):
    if isinstance(cache, dict):
        xprev = jnp.concatenate([cache["xc"][:, None], x[:, :-1]], 1)
    else:
        xprev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    mk = x + (xprev - x) * p["mu_c"][0].astype(x.dtype)
    mr = x + (xprev - x) * p["mu_c"][1].astype(x.dtype)
    kk = jnp.square(jax.nn.relu(mk @ p["ck"]))
    out = jax.nn.sigmoid(mr @ p["cr"]).astype(x.dtype) * (kk @ p["cv"])
    new_cache = {"xc": x[:, -1]} if cache is not None else None
    return out, new_cache
