"""Sharded checkpoint manager: atomic, async, reshard-on-restore.

Layout::

    <dir>/step_000123/
        manifest.json      tree structure, shapes, dtypes, data hash
        arr_000.npy ...    one file per leaf (host-gathered)
    <dir>/LATEST           atomic pointer file

Writes go to ``step_x.tmp`` and are renamed only after fsync -- a preempted
save can never corrupt LATEST.  ``save_async`` runs the host-side write in a
daemon thread (compute continues; the next save joins the previous).
Restore accepts a *target sharding tree*: arrays are ``jax.device_put`` onto
whatever mesh the restarted job has (elastic restart = restore on a new
mesh).  Retention keeps the newest k checkpoints.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

_EXOTIC = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
           "float8_e5m2": np.uint8}


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any) -> str:
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)
        return self._write(step, host_tree)

    def save_async(self, step: int, tree: Any) -> None:
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)
        self._thread = threading.Thread(
            target=self._write, args=(step, host_tree), daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree) -> str:
        leaves, tdef = jax.tree.flatten(host_tree)
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        manifest = {"step": step, "n_leaves": len(leaves),
                    "treedef": str(tdef),
                    "leaves": []}
        h = hashlib.sha256()
        for i, leaf in enumerate(leaves):
            arr = np.asarray(leaf)
            dtype_name = str(arr.dtype)
            if dtype_name in _EXOTIC:       # np.save can't roundtrip these
                arr = arr.view(_EXOTIC[dtype_name])
            path = os.path.join(tmp, f"arr_{i:04d}.npy")
            np.save(path, arr)
            h.update(arr.tobytes()[:4096])
            manifest["leaves"].append(
                {"shape": list(arr.shape), "dtype": dtype_name})
        manifest["digest"] = h.hexdigest()
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)
        self._update_latest(step)
        self._retain()
        return final

    def _update_latest(self, step: int) -> None:
        tmp = os.path.join(self.dir, "LATEST.tmp")
        with open(tmp, "w") as f:
            f.write(str(step))
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, os.path.join(self.dir, "LATEST"))

    def _retain(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        path = os.path.join(self.dir, "LATEST")
        if not os.path.exists(path):
            return None
        with open(path) as f:
            return int(f.read().strip())

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Any = None) -> Any:
        """Restore into the structure of ``template``; with ``shardings``
        (a matching tree of jax.sharding.Sharding) arrays are placed onto
        the *current* mesh -- resharding happens here, which is what makes
        restarts elastic across device counts."""
        step = self.latest_step() if step is None else step
        assert step is not None, "no checkpoint found"
        d = os.path.join(self.dir, f"step_{step:08d}")
        leaves, tdef = jax.tree.flatten(template)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        assert manifest["n_leaves"] == len(leaves), "tree mismatch"
        out = []
        for i, leaf in enumerate(leaves):
            arr = np.load(os.path.join(d, f"arr_{i:04d}.npy"))
            want = manifest["leaves"][i]["dtype"]
            if want in _EXOTIC:
                arr = arr.view(getattr(ml_dtypes, want))
            out.append(arr)
        tree = tdef.unflatten(out)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        return tree
