"""--arch <id> registry for every assigned architecture."""
from . import (deepseek_v2_236b, hubert_xlarge, llama_3_2_vision_90b,
               mistral_nemo_12b, qwen1_5_32b, qwen2_5_32b, qwen3_8b,
               qwen3_moe_235b, recurrentgemma_2b, rwkv6_1_6b)

ARCHS = {
    "qwen3-8b": qwen3_8b.CONFIG,
    "qwen1.5-32b": qwen1_5_32b.CONFIG,
    "qwen2.5-32b": qwen2_5_32b.CONFIG,
    "mistral-nemo-12b": mistral_nemo_12b.CONFIG,
    "recurrentgemma-2b": recurrentgemma_2b.CONFIG,
    "qwen3-moe-235b-a22b": qwen3_moe_235b.CONFIG,
    "deepseek-v2-236b": deepseek_v2_236b.CONFIG,
    "hubert-xlarge": hubert_xlarge.CONFIG,
    "rwkv6-1.6b": rwkv6_1_6b.CONFIG,
    "llama-3.2-vision-90b": llama_3_2_vision_90b.CONFIG,
}


def get(name: str):
    return ARCHS[name]
