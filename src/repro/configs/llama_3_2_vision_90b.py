"""llama-3.2-vision-90b [vlm] -- 100L d_model=8192 64H (GQA kv=8)
d_ff=28672 vocab=128256; cross-attention image layers every 5th layer;
the vision tower is a STUB (input_specs provides precomputed patch
embeddings).  [hf:meta-llama/Llama-3.2-90B-Vision family]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b", n_layers=100, d_model=8192, n_heads=64,
    n_kv_heads=8, d_ff=28672, vocab=128256, head_dim=128,
    group=("attn", "attn", "attn", "attn", "cross"),
    frontend="vision", frontend_dim=7680, vision_seq=1601,
    rope_theta=500_000.0)
