"""recurrentgemma-2b [hybrid] -- 26L d_model=2560 10H (MQA kv=1) d_ff=7680
vocab=256000; RG-LRU + local attention (2 recurrent : 1 local-attn
repeating; two leading recurrent layers make up 26).  [arXiv:2402.19427]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", n_layers=26, d_model=2560, n_heads=10,
    n_kv_heads=1, d_ff=7680, vocab=256000, head_dim=256,
    group=("recurrent", "recurrent", "local"),
    prefix=("recurrent", "recurrent"),
    window=2048, d_rnn=2560)
