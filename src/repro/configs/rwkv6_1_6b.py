"""rwkv6-1.6b (Finch) [ssm] -- 24L d_model=2048 (attention-free) d_ff=7168
vocab=65536; data-dependent decay WKV6.  [arXiv:2404.05892]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b", n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=7168, vocab=65536, head_dim=64, group=("rwkv",))
