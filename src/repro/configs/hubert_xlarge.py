"""hubert-xlarge [audio] -- 48L d_model=1280 16H (MHA kv=16) d_ff=5120
vocab=504; encoder-only; the conv waveform frontend is a STUB
(input_specs provides precomputed frame embeddings).  [arXiv:2106.07447]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", n_layers=48, d_model=1280, n_heads=16,
    n_kv_heads=16, d_ff=5120, vocab=504, head_dim=80, encoder_only=True,
    frontend="audio", frontend_dim=512)
