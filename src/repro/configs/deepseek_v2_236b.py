"""deepseek-v2-236b [moe] -- 60L d_model=5120 128H (MLA) d_ff(expert)=1536
vocab=102400; MLA kv_lora=512, 2 shared + 160 routed top-6, first layer
dense.  [arXiv:2405.04434]"""
from ..models.config import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b", n_layers=60, d_model=5120, n_heads=128,
    n_kv_heads=128, d_ff=1536, vocab=102400,
    head_dim=192,  # nope 128 + rope 64
    group=("moe",), prefix=("moe_dense",),
    mla=MLAConfig(q_lora=1536, kv_lora=512, rope_head_dim=64,
                  nope_head_dim=128, v_head_dim=128),
    moe=MoEConfig(n_experts=160, top_k=6, d_expert=1536, n_shared=2,
                  first_dense=1, d_ff_dense=12288))
