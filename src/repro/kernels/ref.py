"""Pure-jnp oracle for the PIM gate-program executor kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

_FULL = 0xFFFFFFFF


@functools.partial(jax.jit, static_argnames=())
def pim_exec_ref(state, ops, a, b, o):
    """Reference executor: state uint32[n_cells, n_words]; ops/a/b/o int32[n].
    Semantics identical to kernels.pim_exec (INIT0=0, INIT1=1, NOT=2, NOR=3;
    NOT encoded with b == a)."""

    def body(i, st):
        op = ops[i]
        av = jax.lax.dynamic_slice_in_dim(st, a[i], 1, axis=0)
        bv = jax.lax.dynamic_slice_in_dim(st, b[i], 1, axis=0)
        nor = ~(av | bv)
        init = jnp.where(op == 1, jnp.uint32(_FULL), jnp.uint32(0))
        res = jnp.where(op >= 2, nor, jnp.broadcast_to(init, nor.shape))
        return jax.lax.dynamic_update_slice_in_dim(st, res, o[i], axis=0)

    return jax.lax.fori_loop(0, ops.shape[0], body, state)
