"""Pure-jnp oracles for the PIM gate-program executor kernels.

Two execution strategies, matching kernels.pim_exec:

  * :func:`pim_exec_ref` -- gate-serial ``fori_loop`` over the lowered NOR
    stream (one row slice per gate), the original executor.
  * :func:`pim_exec_ref_level` -- levelized: one ``fori_loop`` iteration per
    *level* of independent gates, executed as a vectorized
    gather -> NOR -> scatter over (gates_in_level, n_words) blocks.  Depth
    is the critical path of the netlist instead of its gate count.

Shard invariance (DESIGN.md §8): every executor here is elementwise along
the trailing word axis -- gathers/scatters index only the *cell* axis, and
the schedule operands are word-invariant.  Splitting the word axis into
arbitrary contiguous blocks and running each block independently is
therefore bit-identical to one monolithic run, which is what licenses both
the chunked streaming executor and ``jax.shard_map`` row sharding in
``kernels.ops`` (replicated index operands, no collectives).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

_FULL = 0xFFFFFFFF


@functools.partial(jax.jit, donate_argnums=(0,))
def pim_exec_ref(state, ops, a, b, o):
    """Reference executor: state uint32[n_cells, n_words]; ops/a/b/o int32[n].
    Semantics identical to kernels.pim_exec (INIT0=0, INIT1=1, NOT=2, NOR=3;
    NOT encoded with b == a).  ``state`` is donated: the gate-serial path
    packs a fresh single-use staging buffer per call, so XLA runs the loop
    in that buffer instead of copying it."""

    def body(i, st):
        op = ops[i]
        av = jax.lax.dynamic_slice_in_dim(st, a[i], 1, axis=0)
        bv = jax.lax.dynamic_slice_in_dim(st, b[i], 1, axis=0)
        nor = ~(av | bv)
        init = jnp.where(op == 1, jnp.uint32(_FULL), jnp.uint32(0))
        res = jnp.where(op >= 2, nor, jnp.broadcast_to(init, nor.shape))
        return jax.lax.dynamic_update_slice_in_dim(st, res, o[i], axis=0)

    return jax.lax.fori_loop(0, ops.shape[0], body, state)


def _level_loop(st, la, lb, lo):
    """fori_loop over levels: one vectorized gather -> NOR -> scatter per
    iteration.  Every lane computes ``out <- ~(a | b)`` (NOT has b == a;
    INIT gates were folded into the initial state).  Padding lanes read the
    schedule's first sink cell and write *distinct* sink cells (out == sink
    + lane) -- that per-level output uniqueness is what licenses
    ``unique_indices=True`` below; real cells are untouched.  A leading
    plane axis (the rows64 paired layout) batches through untouched."""
    from .slots import at_cells, take_cells
    if la.shape[0] == 0:        # gate-free (passthrough) program
        return st

    def body(l, s):
        av = take_cells(s, la[l])
        bv = take_cells(s, lb[l])
        return at_cells(s, lo[l]).set(~(av | bv), mode="promise_in_bounds",
                                      unique_indices=True)

    return jax.lax.fori_loop(0, la.shape[0], body, st)


@functools.partial(jax.jit, donate_argnums=(0,))
def pim_exec_ref_level(state, la, lb, lo, out_idx=None):
    """Levelized executor.

    ``state``: uint32[n_cells, n_words]; ``la``/``lb``/``lo``: int32
    [n_levels, width] physical-cell index matrices (LevelSchedule dense
    form).  ``out_idx`` (optional int32[k]): return only these state rows
    -- the port cells -- so a fraction of the state crosses the device
    boundary.  ``state`` is donated (the packed state is a single-use
    staging buffer on every call path, so XLA updates it in place instead
    of copying).
    """
    final = _level_loop(state, la, lb, lo)
    return final if out_idx is None else final[out_idx]


def assemble_state(in_rows, in_idx, n_words, *, n_cells, one_cell):
    """Materialize the packed state device-side: zeros, the input port rows
    scattered at ``in_idx``, and the folded INIT1 constant cell.  Shared by
    every on-device-assembly executor (ref and Pallas, io and fused).  The
    word layout is inferred from ``in_rows``'s rank: 2-D rows32, 3-D
    planes-leading rows64."""
    from .slots import at_cells
    shape = (n_cells, n_words) if in_rows.ndim == 2 else \
        (in_rows.shape[0], n_cells, n_words)
    st = jnp.zeros(shape, jnp.uint32)
    if in_rows.shape[-2]:
        st = at_cells(st, in_idx).set(in_rows, mode="promise_in_bounds")
    if one_cell is not None:
        st = at_cells(st, one_cell).set(jnp.uint32(_FULL))
    return st


@functools.partial(jax.jit, static_argnames=("n_cells", "one_cell"))
def pim_exec_ref_level_io(in_rows, in_idx, la, lb, lo, out_idx, *,
                          n_cells, one_cell=None):
    """Levelized executor with on-device state assembly: only the input
    port rows (uint32[k_in, n_words], or the planes-leading rows64 form)
    are shipped in, the zero state and the folded INIT1 constant cell are
    materialized device-side, and only the output port rows come back."""
    from .slots import take_cells
    st = assemble_state(in_rows, in_idx, in_rows.shape[-1],
                        n_cells=n_cells, one_cell=one_cell)
    return take_cells(_level_loop(st, la, lb, lo), out_idx)


def pack_columns(in_vals, in_widths, planes=1):
    """In-jit bit transpose, row-major -> column-major: per-row port values
    (uint32[n_ports, n_words*32*planes]) to stacked port cell rows
    (uint32[sum(widths), n_words], planes-leading for rows64); ports of
    <= 32 cells.  Backed by the butterfly 32x32 bit transpose in
    ``kernels.slots`` (5 masked shift/xor steps per word block), which
    replaced the (width, n_words, 32) bit expansion -- ~10x less
    intermediate traffic for 16-bit ports."""
    from .slots import pack_values
    return pack_values(in_vals, in_widths, planes)


def unpack_columns(sub, out_widths, planes=1):
    """In-jit inverse of :func:`pack_columns`: stacked port cell rows to
    per-row port values (uint32[n_ports, n_words*32*planes])."""
    from .slots import unpack_values
    return unpack_values(sub, out_widths, planes)


@functools.partial(jax.jit, static_argnames=(
    "n_cells", "one_cell", "in_widths", "out_widths", "planes"))
def pim_exec_ref_level_fused(in_vals, in_idx, la, lb, lo, out_idx, *,
                             n_cells, one_cell, in_widths, out_widths,
                             planes=1):
    """Fully fused levelized executor for programs whose ports all fit in
    32 cells: bit-transposes the row-major port values on device, assembles
    the state, runs the level loop and transposes the outputs back -- one
    XLA executable, two (n_ports, n_rows)-sized transfers.  ``planes``
    selects the word layout (kernels.plan)."""
    from .slots import take_cells
    st = assemble_state(pack_columns(in_vals, in_widths, planes), in_idx,
                        in_vals.shape[1] // (32 * planes),
                        n_cells=n_cells, one_cell=one_cell)
    final = _level_loop(st, la, lb, lo)
    return unpack_columns(take_cells(final, out_idx), out_widths, planes)
