"""jit'd wrappers around the PIM executor kernels: compiled-program caching,
padding, row-major <-> packed-column bridging, and the scale layer --
chunked streaming execution and multi-device row sharding.

Pipeline (DESIGN.md §5): Program -> (content-hash cache) levelized schedule /
lowered arrays -> pack_rows -> kernel -> unpack_rows.  All host-side
bridging is fully vectorized: packing and unpacking move whole ports per
numpy call (one 32-bit limb loop for arbitrarily wide ports), never per cell
or per row.

Scale layer (DESIGN.md §8): :func:`run_program_streaming` tiles arbitrary
row counts into fixed-shape word-aligned chunks and overlaps host packing of
chunk ``k+1`` with device execution of chunk ``k`` (JAX async dispatch);
:func:`row_mesh` + the ``mesh=`` arguments shard the packed word axis over
multiple devices with ``jax.shard_map`` (the level loop is elementwise along
words, so sharding needs no communication).
"""

from __future__ import annotations

import collections
import dataclasses
import functools
import hashlib
import weakref
from typing import Callable, Dict, Iterable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from ..core.gates import LevelSchedule, levelize
from . import slots as kslots
from .pim_exec import (TILE_W, make_slots_static, pim_exec_level_fused,
                       pim_exec_level_padded_io, pim_exec_padded,
                       pim_exec_slots_fused, pim_exec_slots_io)
from .ref import (pim_exec_ref, pim_exec_ref_level_fused,
                  pim_exec_ref_level_io)
from .slots import (as_run, pim_exec_ref_slots_fused, pim_exec_ref_slots_io)

_FULL = np.uint32(0xFFFFFFFF)

# Default schedule compilation mode for the levelized jax backends:
#   'slots'        -- contiguous-slot schedule + scan executors (DESIGN.md
#                     §9): band slice writes instead of scatters, slice
#                     state assembly/extraction, butterfly bridges.  The
#                     fast path on CPU and the default.
#   'slots-static' -- slot schedule + the straight-line static-slice
#                     executors (segmented schedule-to-jaxpr chain on
#                     'ref', the Mosaic-lowerable unrolled kernel on
#                     'pallas').  The hardware-shaped emission; on CPU it
#                     pays per-op overhead for the unrolled form.
#   'dense'        -- the PR-1/2 dense index-matrix executors
#                     (gather -> NOR -> scatter per level).
DEFAULT_SCHEDULE = "slots"
SCHEDULES = ("slots", "slots-static", "dense")

# Streaming chunk size (rows).  262144 rows = 8192 packed words: big enough
# to amortize per-chunk dispatch (and to give each shard of a several-way
# mesh multiple Pallas tiles), small enough that two in-flight chunks stay
# cache-friendly and the pack/exec pipeline keeps overlapping.
DEFAULT_CHUNK_ROWS = 1 << 18


# --------------------------------------------------------------------------
# content-hash-keyed compiled-program cache (bounded LRU)
# --------------------------------------------------------------------------
#
# Programs are compiled (NOR-lowered to dense arrays, levelized, shipped to
# the device) once per *structure*, not per instance: the cache key is a
# content hash of the instruction stream + ports, so structurally identical
# programs share compiled artifacts and -- unlike the previous id()-keyed
# cache -- a dead program's recycled id can never poison the entry of a new
# one.  Keys are memoized per live instance via a WeakKeyDictionary.
#
# The cache is a bounded LRU: each entry pins device buffers (schedule index
# matrices, port gather vectors), so an unbounded dict would leak device
# memory under long-running serving that keeps minting new program
# structures.  Eviction is safe -- an evicted structure is simply recompiled
# on next use, bit-identically (compilation is a pure function of the key).

_COMPILED_CAP = 64

_key_memo: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()
_compiled: "collections.OrderedDict[bytes, _Compiled]" = \
    collections.OrderedDict()

# Pinned entries (content key -> pin refcount) are exempt from LRU
# eviction: the batched serving runtime pins its hot working set so mixed
# traffic that keeps minting cold program structures can never churn a hot
# program's schedule + device buffers out of the cache.  Pins are
# refcounted (several pin caches may share a program); a fully pinned
# cache may transiently exceed the cap -- unpinned entries still evict.
_pinned: Dict[bytes, int] = {}


def _evict_over_cap() -> None:
    """Drop least-recently-used *unpinned* entries down to the cap."""
    if len(_compiled) <= _COMPILED_CAP:
        return
    for key in list(_compiled):
        if len(_compiled) <= _COMPILED_CAP:
            break
        if key not in _pinned:
            del _compiled[key]


def set_compiled_cache_cap(cap: int) -> int:
    """Set the compiled-program LRU capacity (entries); returns the old cap.
    Shrinking evicts least-recently-used unpinned entries immediately."""
    global _COMPILED_CAP
    if cap < 1:
        raise ValueError(f"cache cap must be >= 1, got {cap}")
    old, _COMPILED_CAP = _COMPILED_CAP, cap
    _evict_over_cap()
    return old


def pin_program(program) -> bytes:
    """Pin ``program``'s compiled-cache entry against LRU eviction; returns
    the content key (the token :func:`unpin_program` takes).  Creates the
    entry if the program was never compiled, so artifacts built later land
    in the pinned slot.  Pins nest (refcounted)."""
    key = content_key(program)
    if key not in _compiled:
        _compiled[key] = _Compiled()
    _pinned[key] = _pinned.get(key, 0) + 1
    return key


def unpin_program(key: bytes) -> bool:
    """Release one pin on ``key``; returns True while pins remain.  The
    entry stays cached but becomes evictable again once fully unpinned."""
    n = _pinned.get(key, 0)
    if n > 1:
        _pinned[key] = n - 1
        return True
    _pinned.pop(key, None)
    _evict_over_cap()
    return False


def content_key(program) -> bytes:
    """Structural hash of a Program (instrs, ports, cells, schedule hints)."""
    try:
        return _key_memo[program]
    except (KeyError, TypeError):
        pass
    h = hashlib.blake2b(digest_size=16)
    h.update(int(program.n_cells).to_bytes(8, "little"))
    flat = []
    for ins in program.instrs:
        flat.extend((int(ins.op), len(ins.ins)))
        flat.extend(int(c) for c in ins.ins)
        flat.extend(int(c) for c in ins.outs)
        flat.append(-1)
    h.update(np.asarray(flat, np.int64).tobytes())
    for name in sorted(program.ports):
        h.update(name.encode())
        h.update(b"\x00i" if name in program.in_ports else b"\x00o")
        h.update(np.asarray(program.ports[name], np.int64).tobytes())
    if program.parallel_steps is not None:
        for idxs in program.parallel_steps:
            h.update(np.asarray(list(idxs) + [-1], np.int64).tobytes())
    key = h.digest()
    try:
        _key_memo[program] = key
    except TypeError:
        pass
    return key


def _stacked_cells(cell_lists) -> np.ndarray:
    """Concatenate per-port cell lists into one int32 index vector."""
    if not cell_lists:
        return np.zeros(0, np.int32)
    return np.concatenate(
        [np.asarray(c, np.int64) for c in cell_lists]).astype(np.int32)


def output_names(ports_owner) -> list:
    """The port names ``run_program`` returns, sorted: the declared output
    ports, falling back to *every* port for direction-less programs.

    Works on anything with ``ports`` and (optionally) ``out_ports`` --
    ``Program``, ``LevelSchedule`` -- and is the single source of truth for
    that fallback, so all executor backends agree.
    """
    return sorted(getattr(ports_owner, "out_ports", None)
                  or ports_owner.ports)


# Dense-schedule width cap: levels wider than this are split into several
# rows, trading a few extra fori_loop trips for much less sink padding (the
# sweet spot on CPU interpret mode; see ISSUE 1 / BENCH_1.json).
LEVEL_MAX_WIDTH = 8

# Slot-schedule width: the W-wide band granularity of the contiguous-slot
# allocator.  Narrower slots mean more scan iterations but a smaller state
# (slots turn over faster), and on XLA:CPU the level loop's cost tracks the
# carried state size much more than the iteration count -- W=6 won the
# sweep on the tracked row (BENCH_3) with W in 4..6 within noise of each
# other and W>=8 measurably slower.
SLOT_WIDTH = 6


@dataclasses.dataclass
class _Compiled:
    """Lazily-populated per-structure compilation artifacts (dense and slot
    schedules, device index buffers, and the static straight-line chains,
    all shared under one content-hash entry)."""
    arrays: Optional[tuple] = None              # (ops, a, b, o, n_cells)
    schedule: Optional[LevelSchedule] = None
    sched_dev: Optional[tuple] = None           # (la, lb, lo, out_idx, names)
    in_idx: Optional[dict] = None               # input-name tuple -> indices
    slot_schedule: Optional[LevelSchedule] = None
    slot_dev: Optional[tuple] = None
    slot_in: Optional[dict] = None              # name tuple -> (idx, base)
    static_chain: Optional[dict] = None         # statics key -> callable

    def get_arrays(self, program):
        if self.arrays is None:
            self.arrays = program.to_arrays()
        return self.arrays

    def get_schedule(self, program, schedule: str = "dense"
                     ) -> LevelSchedule:
        if schedule != "dense":
            if self.slot_schedule is None:
                self.slot_schedule = levelize(program, alloc="slots",
                                              max_width=SLOT_WIDTH)
            return self.slot_schedule
        if self.schedule is None:
            self.schedule = levelize(program, max_width=LEVEL_MAX_WIDTH)
        return self.schedule

    def get_sched_dev(self, program, schedule: str = "dense"):
        if schedule != "dense":
            if self.slot_dev is None:
                s = self.get_schedule(program, schedule)
                names = output_names(s)
                cells = _stacked_cells([s.ports[n] for n in names])
                self.slot_dev = (jnp.asarray(s.a), jnp.asarray(s.b),
                                 jnp.asarray(s.out), jnp.asarray(cells),
                                 names, as_run(cells))
            return self.slot_dev
        if self.sched_dev is None:
            s = self.get_schedule(program)
            names = output_names(s)
            cells = _stacked_cells([s.ports[n] for n in names])
            self.sched_dev = (jnp.asarray(s.a), jnp.asarray(s.b),
                              jnp.asarray(s.out), jnp.asarray(cells), names,
                              None)
        return self.sched_dev

    def get_in_idx(self, program, in_names, schedule: str = "dense"):
        memo = {}
        if schedule != "dense":
            if self.slot_in is None:
                self.slot_in = {}
            memo = self.slot_in
        else:
            if self.in_idx is None:
                self.in_idx = {}
            memo = self.in_idx
        key = tuple(in_names)
        if key not in memo:
            s = self.get_schedule(program, schedule)
            cells = _stacked_cells([s.pack_cells(n) for n in in_names])
            memo[key] = (jnp.asarray(cells), as_run(cells))
        return memo[key]

    def get_static_chain(self, program, in_names, fused, in_widths,
                         out_widths):
        if self.static_chain is None:
            self.static_chain = {}
        key = (tuple(in_names), fused, in_widths, out_widths)
        if key not in self.static_chain:
            s = self.get_schedule(program, "slots")
            cells = _stacked_cells([s.pack_cells(n) for n in in_names])
            self.static_chain[key] = kslots.build_static_chain(
                s, in_widths, out_widths, output_names(s), cells,
                fused=fused)
        return self.static_chain[key]

    def get_static_pallas(self, program, in_names, in_widths, out_widths):
        if self.static_chain is None:
            self.static_chain = {}
        key = ("pallas", tuple(in_names), in_widths, out_widths)
        if key not in self.static_chain:
            s = self.get_schedule(program, "slots")
            self.static_chain[key] = make_slots_static(
                s, in_widths, out_widths, output_names(s))
        return self.static_chain[key]


def compiled(program) -> _Compiled:
    key = content_key(program)
    entry = _compiled.get(key)
    if entry is None:
        entry = _compiled[key] = _Compiled()
    else:
        _compiled.move_to_end(key)
    _evict_over_cap()
    return entry


def is_compiled(program, schedule: str = DEFAULT_SCHEDULE) -> bool:
    """True when the compiled-program cache already holds ``program``'s
    lowered schedule artifacts for ``schedule`` -- i.e. the next execution
    pays no levelize/lowering cost.  A pure query: it never creates an
    entry and never touches LRU order (serving uses it to report honest
    ``cached`` flags without perturbing eviction)."""
    entry = _compiled.get(content_key(program))
    if entry is None:
        return False
    if schedule == "dense":
        return entry.sched_dev is not None
    return entry.slot_dev is not None


def program_arrays(program):
    """(ops, a, b, out, n_cells) of the NOR-lowered program, cached by
    structural content hash."""
    return compiled(program).get_arrays(program)


def program_schedule(program, schedule: str = DEFAULT_SCHEDULE
                     ) -> LevelSchedule:
    """The levelized execution schedule of ``program`` (slot or dense
    layout per ``schedule``), cached by structural content hash."""
    return compiled(program).get_schedule(program, schedule)


# --------------------------------------------------------------------------
# row-major <-> packed-column bridges (fully vectorized)
# --------------------------------------------------------------------------

def _ports_of(ports_or_program) -> Dict[str, list]:
    return getattr(ports_or_program, "ports", ports_or_program)


def _value_limbs(vals, n_limbs: int, pad_rows: int) -> np.ndarray:
    """uint32[pad_rows, n_limbs] little-endian 32-bit limbs of per-row
    integers.  Wide ports (> 64 bits) go through an object-dtype array so
    arbitrary-precision values split without any per-row Python loop."""
    vals = np.asarray(vals)
    n = len(vals)
    limbs = np.zeros((pad_rows, n_limbs), np.uint32)
    if n_limbs <= 2 and vals.dtype != object:
        v = np.zeros(pad_rows, np.uint64)
        v[:n] = vals.astype(np.uint64)
        for j in range(n_limbs):
            limbs[:, j] = ((v >> np.uint64(32 * j))
                           & np.uint64(0xFFFFFFFF)).astype(np.uint32)
    else:
        v = np.zeros(pad_rows, object)
        v[:n] = vals.astype(object)
        for j in range(n_limbs):
            limbs[:, j] = ((v >> (32 * j)) & 0xFFFFFFFF).astype(np.uint32)
    return limbs


def _le_bytes(arr: np.ndarray) -> np.ndarray:
    """Little-endian uint8 view of an integer array (copy only on BE hosts),
    so bit k of element e is bit k%8 of byte e*itemsize + k//8."""
    return np.ascontiguousarray(arr).astype(
        arr.dtype.newbyteorder("<"), copy=False).view(np.uint8)


def _n_words(n_rows: int, pad_to: int) -> int:
    return max(((n_rows + 31) // 32 + pad_to - 1) // pad_to * pad_to, pad_to)


def _pack_port_words(vals, nc: int, n_words: int) -> np.ndarray:
    """Column-major words (uint32[nc, n_words]) of one port's per-row
    integers; bit w of word i is row 32*i + w."""
    n_limbs = (nc + 31) // 32
    limbs = _value_limbs(vals, n_limbs, n_words * 32)
    # [pad_rows, 32 * n_limbs] -> cell-major [nc, pad_rows] bit matrix
    bits = np.unpackbits(_le_bytes(limbs), axis=1, bitorder="little")
    cols = np.ascontiguousarray(bits.T[:nc])
    words = np.packbits(cols.reshape(nc, n_words, 32), axis=2,
                        bitorder="little")                   # [nc, n_words, 4]
    return words.reshape(nc, -1).view("<u4")


def pack_rows(values: Dict[str, np.ndarray], ports, n_rows: int,
              n_cells: int, one_cell: Optional[int] = None,
              pad_to: int = TILE_W) -> np.ndarray:
    """Pack per-row port integers into column-major word state
    (uint32[n_cells, n_words]); bit w of state[c, i] = cell c of row
    32*i + w.  ``ports`` is a name -> cell-list mapping (or any object with
    a ``.ports`` attribute).  ``one_cell``, when given, is filled with ones
    (the LevelSchedule's folded INIT1 constant).

    Bit transposition runs entirely in C (unpackbits/packbits on
    little-endian byte views); the only Python loop is over 32-bit limbs of
    arbitrarily wide ports.
    """
    ports = _ports_of(ports)
    n_words = _n_words(n_rows, pad_to)
    state = np.zeros((n_cells, n_words), np.uint32)
    if one_cell is not None:
        state[one_cell] = _FULL
    for name, vals in values.items():
        cells = np.asarray(ports[name], np.int64)
        state[cells] = _pack_port_words(vals, len(cells), n_words)
    return state


def unpack_rows(state: np.ndarray, ports, n_rows: int,
                names: Optional[Iterable[str]] = None
                ) -> Dict[str, np.ndarray]:
    """Inverse of :func:`pack_rows` (row-major ints); ``names`` restricts
    which ports are unpacked (default: all).  Ports wider than 63 cells come
    back as object arrays of Python ints.

    ``state`` may be a device (jnp) array: the port rows are gathered with
    one indexed read and transferred once.
    """
    ports = _ports_of(ports)
    names = list(ports if names is None else names)
    all_cells = np.concatenate(
        [np.asarray(ports[n], np.int64) for n in names]) if names else \
        np.zeros(0, np.int64)
    sub = np.asarray(state[all_cells])        # one gather + host transfer
    return _unpack_sub(sub, [(n, len(ports[n])) for n in names], n_rows)


def _unpack_sub(sub: np.ndarray, name_widths, n_rows: int
                ) -> Dict[str, np.ndarray]:
    """Unpack pre-gathered port rows (stacked in ``name_widths`` order)."""
    out = {}
    off = 0
    for name, nc in name_widths:
        w = sub[off:off + nc]                                  # [nc, n_words]
        off += nc
        n_limbs = (nc + 31) // 32
        # word bits -> row-major bit matrix [n_rows, nc] -> limb matrix
        bits = np.unpackbits(_le_bytes(w), axis=1,
                             bitorder="little")[:, :n_rows]
        by = np.packbits(np.ascontiguousarray(bits.T), axis=1,
                         bitorder="little")                # [n_rows, ceil/8]
        if by.shape[1] != 4 * n_limbs:
            pad = np.zeros((n_rows, 4 * n_limbs), np.uint8)
            pad[:, :by.shape[1]] = by
            by = pad
        limbs = by.view("<u4")                             # [n_rows, n_limbs]
        if nc > 63:
            acc = np.zeros(n_rows, object)
            for j in range(n_limbs):
                acc |= limbs[:, j].astype(object) << (32 * j)
            out[name] = acc
        else:
            acc = limbs[:, 0].astype(np.uint64)
            if n_limbs > 1:
                acc |= limbs[:, 1].astype(np.uint64) << np.uint64(32)
            out[name] = acc
    return out


# --------------------------------------------------------------------------
# multi-device row sharding (word axis)
# --------------------------------------------------------------------------
#
# The packed word axis is embarrassingly parallel: every level executes
# ``out[cells] <- ~(a[cells] | b[cells])`` elementwise along words, and the
# schedule's index operands are word-invariant.  Sharding is therefore pure
# data parallelism -- input port rows split along words, index matrices
# replicate, output port rows split along words; no collective ever runs.

@functools.lru_cache(maxsize=None)
def row_mesh(n_devices: Optional[int] = None) -> Optional[Mesh]:
    """1-D device mesh over the packed word (row-block) axis, or ``None``
    when only one device is available / requested (the unsharded path).
    Run CPU hosts with ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
    to exercise N-way sharding without accelerators."""
    devs = jax.devices()
    n = len(devs) if n_devices is None else min(n_devices, len(devs))
    if n <= 1:
        return None
    return Mesh(np.array(devs[:n]), ("rows",))


# Every levelized executor entry point shares one signature --
# (in_block, in_idx, la, lb, lo, out_idx) -- with the data block sharded
# along its trailing word/row axis and the schedule operands replicated.
_SHARD_IN_SPECS = (P(None, "rows"), P(None),
                   P(None, None), P(None, None), P(None, None), P(None))

# Bounded like _compiled, and for the same reason: each wrapper pins
# compiled XLA executables keyed by per-program statics, so long-running
# serving that keeps minting program structures must evict here too.
_SHARD_CACHE_CAP = 64
_shard_cache: "collections.OrderedDict[tuple, Callable]" = \
    collections.OrderedDict()


def _sharded_exec(fn, mesh: Mesh, check_rep: bool, **static) -> Callable:
    """``jax.jit(shard_map(fn))`` over :data:`_SHARD_IN_SPECS`, cached per
    (executor, mesh, statics) so each chunk shape compiles once.  Pallas
    calls have no replication rule, hence ``check_rep=False`` there."""
    key = (fn, mesh, check_rep, tuple(sorted(static.items())))
    wrapped = _shard_cache.get(key)
    if wrapped is None:
        inner = functools.partial(fn, **static)
        wrapped = jax.jit(shard_map(
            inner, mesh=mesh, in_specs=_SHARD_IN_SPECS,
            out_specs=P(None, "rows"), check_rep=check_rep))
        _shard_cache[key] = wrapped
        while len(_shard_cache) > _SHARD_CACHE_CAP:
            _shard_cache.popitem(last=False)
    else:
        _shard_cache.move_to_end(key)
    return wrapped


# --------------------------------------------------------------------------
# execution
# --------------------------------------------------------------------------

def _dispatch_levelized(program, inputs: Dict[str, np.ndarray], n_rows: int,
                        backend: str, mesh: Optional[Mesh] = None,
                        pad_rows: Optional[int] = None,
                        schedule: str = DEFAULT_SCHEDULE) -> Callable:
    """Pack ``inputs`` and dispatch one levelized execution; returns a
    zero-arg ``finalize`` that blocks on the device result and unpacks it.

    Dispatch is asynchronous (JAX futures), so callers can overlap host
    packing of the next chunk with device execution of this one -- the
    streaming executor's pipeline.  ``pad_rows`` fixes the padded row count
    (>= n_rows) so every streaming chunk shares one compiled shape.
    ``schedule`` selects the compilation mode (see :data:`DEFAULT_SCHEDULE`).
    """
    if schedule not in SCHEDULES:
        raise ValueError(f"unknown schedule {schedule!r} "
                         f"(expected one of {SCHEDULES})")
    comp = compiled(program)
    sched = comp.get_schedule(program, schedule)
    shards = 1 if mesh is None else mesh.devices.size
    pad_to = (TILE_W if backend == "pallas" else 1) * shards
    n_words = _n_words(n_rows if pad_rows is None else pad_rows, pad_to)
    la, lb, lo, out_idx, names, out_base = \
        comp.get_sched_dev(program, schedule)
    in_names = sorted(inputs)
    in_idx, in_base = comp.get_in_idx(program, in_names, schedule)
    one_cell = None if sched.one_cell is None else int(sched.one_cell)
    in_widths = tuple(len(sched.pack_cells(n)) for n in in_names)
    out_widths = tuple(len(sched.ports[n]) for n in names)
    k_out = sum(out_widths)
    slots_ok = (schedule != "dense" and out_base is not None and k_out > 0)
    use_static = schedule == "slots-static" and slots_ok and mesh is None
    vals = [np.asarray(inputs[n]) for n in in_names]
    if backend == "pallas" and slots_ok and in_base is None:
        slots_ok = False        # aliased input ports: slice assembly
        #                         impossible, use the dense kernels
    if not slots_ok and schedule != "dense":
        # degenerate program for the slot layout: dense executors, which
        # handle every schedule shape
        sched = comp.get_schedule(program, "dense")
        la, lb, lo, out_idx, names, out_base = \
            comp.get_sched_dev(program, "dense")
        in_idx, in_base = comp.get_in_idx(program, in_names, "dense")
        one_cell = None if sched.one_cell is None else int(sched.one_cell)
        schedule = "dense"
        use_static = False
    if (vals and max(in_widths + out_widths, default=0) <= 32
            and all(v.dtype != object for v in vals)):
        # fused fast path: the bit transposes run inside the executor's
        # XLA program; only (n_ports, n_rows) uint32 cross the boundary
        in_vals = np.empty((len(vals), n_words * 32), np.uint32)
        for p, v in enumerate(vals):
            in_vals[p, :len(v)] = v           # same-kind cast in place
            in_vals[p, len(v):] = 0           # only the ragged tail zeroed
        if use_static and backend == "ref":
            run = comp.get_static_chain(program, in_names, True,
                                        in_widths, out_widths)
            outs = run(jnp.asarray(in_vals))
        elif use_static and in_base == 0:
            run = comp.get_static_pallas(program, in_names, in_widths,
                                         out_widths)
            outs = run(jnp.asarray(in_vals))
        else:
            if schedule != "dense":
                fn = (pim_exec_ref_slots_fused if backend == "ref"
                      else pim_exec_slots_fused)
                static = dict(n_cells=sched.n_cells, one_cell=one_cell,
                              in_widths=in_widths, out_widths=out_widths,
                              in_base=in_base, out_base=out_base)
            else:
                fn = (pim_exec_ref_level_fused if backend == "ref"
                      else pim_exec_level_fused)
                static = dict(n_cells=sched.n_cells, one_cell=one_cell,
                              in_widths=in_widths, out_widths=out_widths)
            if mesh is None:
                outs = fn(jnp.asarray(in_vals), in_idx, la, lb, lo,
                          out_idx, **static)
            else:
                outs = _sharded_exec(fn, mesh, backend != "pallas",
                                     **static)(
                    jnp.asarray(in_vals), in_idx, la, lb, lo, out_idx)

        def finalize() -> Dict[str, np.ndarray]:
            o = np.asarray(outs)                     # blocks until ready
            return {n: o[p, :n_rows].astype(np.uint64)
                    for p, n in enumerate(names)}
        return finalize
    in_rows = (np.vstack(
        [_pack_port_words(inputs[n], len(sched.pack_cells(n)), n_words)
         for n in in_names])
        if in_names else np.zeros((0, n_words), np.uint32))
    if use_static and backend == "ref":
        run = comp.get_static_chain(program, in_names, False,
                                    in_widths, out_widths)
        sub = run(jnp.asarray(in_rows))
    else:
        # (slots-static + pallas has no wide-port static kernel; the scan
        # slot executor is the closest hardware shape)
        if schedule != "dense":
            exec_fn = (pim_exec_ref_slots_io if backend == "ref"
                       else pim_exec_slots_io)
            static = dict(n_cells=sched.n_cells, one_cell=one_cell,
                          k_out=k_out, in_base=in_base, out_base=out_base)
        else:
            exec_fn = (pim_exec_ref_level_io if backend == "ref"
                       else pim_exec_level_padded_io)
            static = dict(n_cells=sched.n_cells, one_cell=one_cell)
        if mesh is None:
            sub = exec_fn(jnp.asarray(in_rows), in_idx, la, lb, lo,
                          out_idx, **static)
        else:
            sub = _sharded_exec(exec_fn, mesh, backend != "pallas",
                                **static)(
                jnp.asarray(in_rows), in_idx, la, lb, lo, out_idx)

    def finalize() -> Dict[str, np.ndarray]:
        return _unpack_sub(np.asarray(sub),
                           [(n, len(sched.ports[n])) for n in names], n_rows)
    return finalize


def run_program(program, inputs: Dict[str, np.ndarray], n_rows: int,
                backend: str = "pallas", levelized: bool = True,
                mesh: Optional[Mesh] = None,
                schedule: str = DEFAULT_SCHEDULE) -> Dict[str, np.ndarray]:
    """Element-parallel execution of a gate program over ``n_rows`` rows.

    backend: 'pallas' (interpret-mode kernel), 'ref' (jnp oracle) or
    'numpy' (the cycle-accurate simulator's packed executor, abstract IR).
    'pallas' and 'ref' consume the levelized schedule by default;
    ``levelized=False`` selects the original gate-serial executors.
    ``mesh`` (see :func:`row_mesh`) shards the packed word axis over
    devices; it requires a levelized jax backend.
    ``schedule`` picks the schedule compilation mode: 'slots' (contiguous
    bands + scan executors, the default), 'slots-static' (straight-line
    static-slice executors; single-device -- under ``mesh`` it degrades to
    the scan form), or 'dense' (the index-matrix executors).

    Returns the program's output ports -- all ports when the program does
    not declare port directions (the :func:`output_names` contract, which
    every backend path shares).
    """
    if mesh is not None and (backend == "numpy" or not levelized):
        raise ValueError(
            "mesh sharding requires a levelized jax backend "
            f"(got backend={backend!r}, levelized={levelized})")
    if backend == "numpy":
        state = pack_rows(inputs, program.ports, n_rows, program.n_cells,
                          pad_to=1)
        st = np.ascontiguousarray(state.T)
        program.exec_packed(st)
        return unpack_rows(st.T, program.ports, n_rows,
                           names=output_names(program))
    if backend not in ("pallas", "ref"):
        raise ValueError(backend)
    if levelized:
        return _dispatch_levelized(program, inputs, n_rows, backend, mesh,
                                   schedule=schedule)()
    comp = compiled(program)
    ops, a, b, o, n_cells = comp.get_arrays(program)
    pad_to = TILE_W if backend == "pallas" else 1
    state = pack_rows(inputs, program.ports, n_rows, n_cells, pad_to=pad_to)
    if backend == "ref":
        final = np.asarray(pim_exec_ref(
            jnp.asarray(state), jnp.asarray(ops), jnp.asarray(a),
            jnp.asarray(b), jnp.asarray(o)))
    else:
        final = np.asarray(pim_exec_padded(
            jnp.asarray(state), jnp.asarray(ops), jnp.asarray(a),
            jnp.asarray(b), jnp.asarray(o), n_cells=n_cells))
    return unpack_rows(final, program.ports, n_rows,
                       names=output_names(program))


def run_program_streaming(program, inputs: Dict[str, np.ndarray],
                          n_rows: int, backend: str = "ref",
                          chunk_rows: int = DEFAULT_CHUNK_ROWS,
                          mesh: Optional[Mesh] = None,
                          schedule: str = DEFAULT_SCHEDULE
                          ) -> Dict[str, np.ndarray]:
    """Chunked, pipelined, optionally sharded execution over ``n_rows``.

    Rows are tiled into word-aligned chunks of ``chunk_rows``; the loop
    dispatches chunk ``k`` to the device, packs chunk ``k+1`` on the host
    while ``k`` executes (JAX async dispatch), then blocks on ``k``'s
    result -- so host bridging and device execution overlap instead of one
    monolithic pack -> exec -> unpack.  Every chunk (including the ragged
    last one) is padded to the same shape, so the executor compiles once.

    Levelized jax backends only ('ref'/'pallas'); ``mesh`` additionally
    shards each chunk's word axis over devices (:func:`row_mesh`).
    """
    if backend not in ("pallas", "ref"):
        raise ValueError(
            f"streaming requires a levelized jax backend, got {backend!r}")
    chunk_rows = max(32, (int(chunk_rows) + 31) // 32 * 32)  # word-aligned
    if n_rows <= chunk_rows:
        return run_program(program, inputs, n_rows, backend, mesh=mesh,
                           schedule=schedule)
    inputs = {n: np.asarray(v) for n, v in inputs.items()}
    for n, v in inputs.items():
        if len(v) != n_rows:
            raise ValueError(
                f"input {n!r} has {len(v)} rows, expected {n_rows}")
    parts = []
    pending = None
    for start in range(0, n_rows, chunk_rows):
        rows_k = min(chunk_rows, n_rows - start)
        chunk = {n: v[start:start + rows_k] for n, v in inputs.items()}
        fin = _dispatch_levelized(program, chunk, rows_k, backend, mesh,
                                  pad_rows=chunk_rows, schedule=schedule)
        if pending is not None:
            parts.append(pending())     # blocks on k-1 while k executes
        pending = fin
    parts.append(pending())
    return {name: np.concatenate([p[name] for p in parts])
            for name in parts[0]}


def dispatch_program(program, inputs: Dict[str, np.ndarray], n_rows: int,
                     backend: str = "ref", mesh: Optional[Mesh] = None,
                     pad_rows: Optional[int] = None,
                     schedule: str = DEFAULT_SCHEDULE) -> Callable:
    """Asynchronously dispatch one levelized execution; returns a zero-arg
    ``finalize`` that blocks on the device result and unpacks the output
    ports.  The pipelining primitive behind :func:`run_program_streaming`
    and :func:`run_program_groups`: callers overlap host packing of the
    next unit of work with device execution of this one."""
    if backend not in ("pallas", "ref"):
        raise ValueError(
            f"dispatch requires a levelized jax backend, got {backend!r}")
    return _dispatch_levelized(program, inputs, n_rows, backend, mesh,
                               pad_rows=pad_rows, schedule=schedule)


def run_program_groups(groups: Iterable[dict]) -> list:
    """Execute several coalesced program groups back to back with
    cross-group pipelining; returns their output dicts in input order.

    Each group is a dict: ``program``, ``inputs`` (port name -> row
    values), ``n_rows``, plus optional ``backend`` ('ref'), ``chunk_rows``,
    ``mesh`` and ``schedule``.  The loop dispatches group ``k`` (JAX async)
    and packs group ``k+1`` on the host while ``k`` executes -- the
    streaming pipeline generalized across *heterogeneous* programs, which
    is what lets the batched serving runtime keep the device busy across a
    mixed-traffic plan.  Groups larger than ``chunk_rows`` tile into
    word-aligned fixed-shape chunks inside the same pipeline (so one giant
    group cannot stall its successors' packing).  A ``numpy``-backend
    group is a synchronization point (the oracle is host-synchronous).
    """
    groups = list(groups)
    parts: list = [[] for _ in groups]
    pending: "collections.deque" = collections.deque()

    def drain(limit: int) -> None:
        while len(pending) > limit:
            gi, fin = pending.popleft()
            parts[gi].append(fin())

    for gi, g in enumerate(groups):
        program, n_rows = g["program"], int(g["n_rows"])
        backend = g.get("backend") or "ref"
        schedule = g.get("schedule") or DEFAULT_SCHEDULE
        mesh = g.get("mesh")
        inputs = {n: np.asarray(v) for n, v in g["inputs"].items()}
        for n, v in inputs.items():
            if len(v) != n_rows:
                raise ValueError(
                    f"group {gi}: input {n!r} has {len(v)} rows, "
                    f"expected {n_rows}")
        if backend == "numpy":
            drain(0)
            parts[gi].append(run_program(program, inputs, n_rows, "numpy"))
            continue
        chunk_rows = max(32, (int(g.get("chunk_rows") or DEFAULT_CHUNK_ROWS)
                              + 31) // 32 * 32)
        if n_rows <= chunk_rows:
            pending.append((gi, _dispatch_levelized(
                program, inputs, n_rows, backend, mesh, schedule=schedule)))
            drain(1)
            continue
        for start in range(0, n_rows, chunk_rows):
            rows_k = min(chunk_rows, n_rows - start)
            chunk = {n: v[start:start + rows_k] for n, v in inputs.items()}
            pending.append((gi, _dispatch_levelized(
                program, chunk, rows_k, backend, mesh, pad_rows=chunk_rows,
                schedule=schedule)))
            drain(1)
    drain(0)
    return [ps[0] if len(ps) == 1 else
            {k: np.concatenate([p[k] for p in ps]) for k in ps[0]}
            for ps in parts]
