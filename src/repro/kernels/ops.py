"""jit'd wrappers around the PIM executor kernel: padding, program-array
caching, and row-major <-> packed-column bridging."""

from __future__ import annotations

from typing import Dict

import jax.numpy as jnp
import numpy as np

from .pim_exec import TILE_W, pim_exec_padded
from .ref import pim_exec_ref

_prog_cache: Dict[int, tuple] = {}


def program_arrays(program):
    """(ops, a, b, out, n_cells) of the NOR-lowered program, cached."""
    key = id(program)
    if key not in _prog_cache:
        _prog_cache[key] = program.to_arrays()
    return _prog_cache[key]


def _pad_words(n: int) -> int:
    return max(TILE_W, ((n + TILE_W - 1) // TILE_W) * TILE_W)


def _port_bits(cells, vals, pad_rows):
    """bit matrix [pad_rows, len(cells)] for one port."""
    wide = len(cells) > 63
    out = np.zeros((pad_rows, len(cells)), np.uint32)
    if wide:
        for r, v in enumerate(vals):
            v = int(v)
            for k in range(len(cells)):
                out[r, k] = (v >> k) & 1
    else:
        vv = np.zeros(pad_rows, np.uint64)
        vv[: len(vals)] = np.asarray(vals, np.uint64)
        ks = np.arange(len(cells), dtype=np.uint64)
        out[:] = ((vv[:, None] >> ks[None, :]) & np.uint64(1)).astype(np.uint32)
    return out


def pack_rows(values: Dict[str, np.ndarray], program, n_rows: int,
              n_cells: int) -> np.ndarray:
    """Pack per-row port integers into column-major word state
    (uint32[n_cells, n_words_padded]); bit w of state[c, i] = cell c of
    row 32*i + w."""
    n_words = _pad_words((n_rows + 31) // 32)
    state = np.zeros((n_cells, n_words), np.uint32)
    shifts = np.arange(32, dtype=np.uint32)
    for name, vals in values.items():
        cells = program.ports[name]
        bits = _port_bits(cells, vals, n_words * 32)
        for k, cell in enumerate(cells):
            w = (bits[:, k].reshape(-1, 32) << shifts).sum(axis=1,
                                                           dtype=np.uint32)
            state[cell] = w
    return state


def unpack_rows(state: np.ndarray, program, n_rows: int
                ) -> Dict[str, np.ndarray]:
    """Inverse of :func:`pack_rows` for every port (row-major ints)."""
    out = {}
    for name, cells in program.ports.items():
        wide = len(cells) > 63
        acc = [0] * n_rows if wide else np.zeros(n_rows, np.uint64)
        for k, cell in enumerate(cells):
            w = np.asarray(state[cell])
            bits = ((w[:, None] >> np.arange(32, dtype=np.uint32)) & 1
                    ).reshape(-1)[:n_rows]
            if wide:
                for r in np.nonzero(bits)[0]:
                    acc[r] |= 1 << k
            else:
                acc |= bits.astype(np.uint64) << np.uint64(k)
        out[name] = np.array(acc, object) if wide else acc
    return out


def run_program(program, inputs: Dict[str, np.ndarray], n_rows: int,
                backend: str = "pallas") -> Dict[str, np.ndarray]:
    """Element-parallel execution of a gate program over ``n_rows`` rows.

    backend: 'pallas' (interpret-mode kernel), 'ref' (jnp oracle) or
    'numpy' (the cycle-accurate simulator's packed executor, abstract IR).
    """
    if backend == "numpy":
        state = pack_rows(inputs, program, n_rows, program.n_cells)
        st = np.ascontiguousarray(state.T)
        program.exec_packed(st)
        return unpack_rows(st.T, program, n_rows)
    ops, a, b, o, n_cells = program_arrays(program)
    state = pack_rows(inputs, program, n_rows, n_cells)
    if backend == "ref":
        final = np.asarray(pim_exec_ref(
            jnp.asarray(state), jnp.asarray(ops), jnp.asarray(a),
            jnp.asarray(b), jnp.asarray(o)))
    elif backend == "pallas":
        final = np.asarray(pim_exec_padded(
            jnp.asarray(state), jnp.asarray(ops), jnp.asarray(a),
            jnp.asarray(b), jnp.asarray(o), n_cells=n_cells))
    else:
        raise ValueError(backend)
    return unpack_rows(final, program, n_rows)
