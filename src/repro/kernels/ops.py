"""jit'd wrappers around the PIM executor kernels: the compile->execute
pipeline behind every entry point.

Pipeline (DESIGN.md §5, §11): ``Program`` -> :func:`levelize` schedule ->
:class:`~repro.kernels.plan.ExecPlan` (schedule kind x backend x
:class:`~repro.kernels.plan.WordLayout` x mesh/chunking) -> resolved
executor + packed bridges -> kernel -> unpack.  All host-side bridging is
fully vectorized: packing and unpacking move whole ports per numpy call
(one 32-bit limb loop for arbitrarily wide ports), never per cell or per
row.

Execution configuration is an :class:`ExecPlan` (``kernels.plan``):
every public entry point here accepts either a plan or the historical
convenience strings, normalizes them **once** via :func:`plan.as_plan`,
and threads only the plan below that point.  The compiled-program cache,
the pin API and the resolved-executor memo all key on the plan, and the
dense-fallback decision for degenerate slot layouts happens once at plan
resolution -- not per call site.

Scale layer (DESIGN.md §8): :func:`run_program_streaming` tiles arbitrary
row counts into fixed-shape word-aligned chunks and overlaps host packing of
chunk ``k+1`` with device execution of chunk ``k`` (JAX async dispatch);
:func:`row_mesh` + the plan's ``mesh`` shard the packed word axis over
multiple devices with ``jax.shard_map`` (the level loop is elementwise along
words, so sharding needs no communication).
"""

from __future__ import annotations

import collections
import dataclasses
import functools
import hashlib
import time
import weakref
from typing import Callable, Dict, Iterable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from ..core.gates import LevelSchedule, levelize
from ..runtime import telemetry
from ..runtime.faults import (DeadlineExceeded, FaultError,  # noqa: F401
                              FaultModel, VerifyPolicy, note_quarantine,
                              record_wear)
from . import slots as kslots
from .plan import (BACKENDS, DEFAULT_LAYOUT, DEFAULT_PLAN, DEFAULT_SCHEDULE,
                   LAYOUTS, ROWS32, ROWS64, SCHEDULES, TILE_W, Backend,
                   ExecPlan, WordLayout, as_plan)
from .pim_exec import (check_words, make_slots_static, pim_exec_level_fused,
                       pim_exec_level_padded_io, pim_exec_padded,
                       pim_exec_slots_fused, pim_exec_slots_io)
from .ref import (pim_exec_ref, pim_exec_ref_level_fused,
                  pim_exec_ref_level_io)
from .slots import (as_run, pim_exec_ref_slots_fused, pim_exec_ref_slots_io)

_FULL = np.uint32(0xFFFFFFFF)

# Historical tunable names, re-exported from their canonical home on
# kernels.plan (the Backend descriptors read the same values) for callers
# that import them from here.
from .plan import (DEFAULT_CHUNK_ROWS, LEVEL_MAX_WIDTH,  # noqa: F401
                   SLOT_WIDTH)


def make_plan(**kw) -> ExecPlan:
    """Build an :class:`ExecPlan` from convenience keywords
    (``backend=``, ``schedule=``, ``layout=``, ``mesh=``, ``chunk_rows=``,
    or a ready plan via ``plan=``).  The exemplary entry for callers that
    want to name their execution config once and reuse it."""
    return as_plan(kw.pop("plan", None), **kw)


# --------------------------------------------------------------------------
# plan-keyed compiled-program cache (bounded LRU)
# --------------------------------------------------------------------------
#
# Programs are compiled (NOR-lowered to dense arrays, levelized, shipped to
# the device) once per (*structure*, *plan*): the cache key pairs a content
# hash of the instruction stream + ports with the plan's ``compile_key`` --
# the plan fields that determine compiled artifacts (schedule kind, word
# layout, allocator widths, static segmentation).  Structurally identical
# programs under the same plan share compiled artifacts, and -- unlike an
# id()-keyed cache -- a dead program's recycled id can never poison the
# entry of a new one.  Content keys are memoized per live instance via a
# WeakKeyDictionary.
#
# The cache is a bounded LRU: each entry pins device buffers (schedule index
# matrices, port gather vectors), so an unbounded dict would leak device
# memory under long-running serving that keeps minting new program
# structures.  Eviction is safe -- an evicted structure is simply recompiled
# on next use, bit-identically (compilation is a pure function of the key).

_COMPILED_CAP = 64

# Fused compound programs (expression chains, GEMV stages) are often orders
# of magnitude larger than single-op programs, so the cache is bounded by
# total *schedule weight* -- sum over entries of levels x slot width, a
# proxy for the device buffers an entry pins -- as well as by entry count.
# Without the weight bound, one fused GEMV whose entry counts as "1" could
# silently displace the entire hot set of small programs.
_COMPILED_WEIGHT_CAP = 8 << 20

# Weight-triggered eviction never shrinks the cache below this many
# unpinned entries: when a single entry's weight exceeds the whole cap, the
# most recently used entries (including that entry) stay resident instead
# of thrashing on every call.
_COMPILED_MIN_RESIDENT = 4

_key_memo: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()
_compiled: "collections.OrderedDict[tuple, _Compiled]" = \
    collections.OrderedDict()

# Serial-order modeled costs for paths that never build a compiled entry
# (the numpy oracle).  Weak-keyed so it does not pin programs, and kept
# out of ``_compiled`` so oracle runs cannot churn the weighted LRU.
_serial_model_memo: "weakref.WeakKeyDictionary" = \
    weakref.WeakKeyDictionary()


def _serial_model(program) -> "telemetry.ModeledCost":
    m = _serial_model_memo.get(program)
    if m is None:
        m = telemetry.COST_MODEL.program_cost(program.cost())
        _serial_model_memo[program] = m
    return m

#: Compiled-program LRU lifecycle counters (``pim.cache.hits`` /
#: ``misses`` / ``evictions`` on the global registry) -- what serving's
#: periodic stats lines derive the cache hit rate from.  The disk tier
#: (``runtime.artifact_cache``) adds ``disk_hits``/``disk_misses``/
#: ``disk_writes``/``disk_errors``/``disk_evictions`` to the same group,
#: and ``levelized`` below counts *fresh* levelizations -- the signal a
#: warm-started replica drives to zero.
_CACHE = telemetry.REGISTRY.group("pim.cache")

# --------------------------------------------------------------------------
# optional on-disk artifact tier (DESIGN.md §16)
# --------------------------------------------------------------------------
#
# When installed, the disk cache sits *below* the in-memory LRU: an
# in-memory schedule miss first tries ``load_schedule`` before paying
# levelize, every fresh levelize writes through, and the levelized-
# executor dispatcher AOT-compiles + serializes XLA executables per call
# signature so a later process deserializes (~20ms) instead of tracing and
# compiling (~700ms on the tracked fp16-add row).

_artifacts = None       # Optional[runtime.artifact_cache.ArtifactCache]

# Program build provenance -- how ``core.pim_numerics`` constructed each
# program (the ``program_for``/``fused_program_for`` argument triple).
# Written into on-disk schedule headers so ``ArtifactCache.warm()`` can
# rebuild the program in a fresh process and verify its content hash.
# Weak-keyed: provenance never pins a program alive.
_provenance: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()


def set_artifact_cache(cache) -> None:
    """Install (or, with None, remove) the process-wide on-disk artifact
    cache consulted by the compiled-program machinery."""
    global _artifacts
    _artifacts = cache


def artifact_cache():
    """The installed on-disk artifact tier, or None."""
    return _artifacts


def note_provenance(program, tag: tuple) -> None:
    """Record how ``program`` was built (a plain-data tag the artifact
    cache persists and ``warm()`` replays)."""
    try:
        _provenance.setdefault(program, tag)
    except TypeError:
        pass


def provenance_of(program):
    return _provenance.get(program)


def clear_compiled_cache() -> int:
    """Drop every *unpinned* compiled-program entry (tests use this to
    force cold in-memory state against a warm disk cache); returns the
    number dropped."""
    victims = [k for k in _compiled if k not in _pinned]
    for k in victims:
        del _compiled[k]
    return len(victims)

# Pinned entries (cache key -> pin refcount) are exempt from LRU
# eviction: the batched serving runtime pins its hot working set so mixed
# traffic that keeps minting cold program structures can never churn a hot
# program's schedule + device buffers out of the cache.  Pins are
# refcounted (several pin caches may share a program); a fully pinned
# cache may transiently exceed the cap -- unpinned entries still evict.
_pinned: Dict[tuple, int] = {}


def _evict_over_cap(protect: Optional[tuple] = None) -> None:
    """Drop least-recently-used *unpinned* entries while over either cap:
    entry count (``_COMPILED_CAP``) or total schedule weight
    (``_COMPILED_WEIGHT_CAP``, sum of per-entry levels x slot width).

    ``protect`` exempts one key -- the entry a caller just created or
    touched.  Without it, a cache whose cap is saturated by pinned entries
    would evict the entry it is in the middle of handing out: the caller
    would keep building artifacts on an orphaned object that the next
    lookup (or a later ``pin_program``) silently replaces, so the work is
    lost and a pin can land on an empty twin.  (The pinned-vs-cap audit of
    ISSUE 5; regression-tested in tests/test_plan.py.)

    Weight-only pressure (count under cap, weight over) stops once at most
    ``_COMPILED_MIN_RESIDENT`` unpinned entries would remain, so a single
    oversized fused program can never purge the whole hot set -- and stays
    resident itself rather than recompiling on every call."""
    weight = sum(e.weight for e in _compiled.values())
    for key in list(_compiled):
        over_n = len(_compiled) > _COMPILED_CAP
        over_w = weight > _COMPILED_WEIGHT_CAP
        if not (over_n or over_w):
            break
        if key in _pinned or key == protect:
            continue
        if not over_n:      # weight pressure only: respect the floor
            unpinned = sum(1 for k in _compiled
                           if k not in _pinned and k != protect)
            if unpinned <= _COMPILED_MIN_RESIDENT:
                break
        weight -= _compiled[key].weight
        del _compiled[key]
        _CACHE.add("evictions")


def set_compiled_cache_cap(cap: int, weight_cap: Optional[int] = None) -> int:
    """Set the compiled-program LRU capacity (entries) and, optionally, the
    total schedule-weight cap (levels x slots summed over entries); returns
    the old entry cap.  Shrinking evicts least-recently-used unpinned
    entries immediately; pinned entries always survive, even when the new
    cap is smaller than the pinned count (the cache then runs over cap
    until pins release)."""
    global _COMPILED_CAP, _COMPILED_WEIGHT_CAP
    if cap < 1:
        raise ValueError(f"cache cap must be >= 1, got {cap}")
    old, _COMPILED_CAP = _COMPILED_CAP, cap
    if weight_cap is not None:
        if weight_cap < 1:
            raise ValueError(f"weight cap must be >= 1, got {weight_cap}")
        _COMPILED_WEIGHT_CAP = weight_cap
    _evict_over_cap()
    return old


def cache_key(program, plan: Optional[ExecPlan] = None) -> tuple:
    """The compiled-program cache key: (program content hash,
    plan.compile_key).  The plan defaults to :data:`plan.DEFAULT_PLAN`."""
    plan = DEFAULT_PLAN if plan is None else plan
    return (content_key(program), plan.compile_key)


def pin_program(program, plan: Optional[ExecPlan] = None) -> tuple:
    """Pin ``program``'s compiled-cache entry (under ``plan``, default the
    default plan) against LRU eviction; returns the cache key (the token
    :func:`unpin_program` takes).  Creates the entry if the program was
    never compiled, so artifacts built later land in the pinned slot.
    Pins nest (refcounted)."""
    key = cache_key(program, plan)
    if key not in _compiled:
        _compiled[key] = _Compiled()
        _CACHE.add("misses")     # a pin-created entry is a cold program
        _evict_over_cap(protect=key)
    _pinned[key] = _pinned.get(key, 0) + 1
    return key


def unpin_program(key: tuple) -> bool:
    """Release one pin on ``key``; returns True while pins remain.  The
    entry stays cached but becomes evictable again once fully unpinned."""
    n = _pinned.get(key, 0)
    if n > 1:
        _pinned[key] = n - 1
        return True
    _pinned.pop(key, None)
    _evict_over_cap()
    return False


def content_key(program) -> bytes:
    """Structural hash of a Program (instrs, ports, cells, schedule hints)."""
    try:
        return _key_memo[program]
    except (KeyError, TypeError):
        pass
    h = hashlib.blake2b(digest_size=16)
    h.update(int(program.n_cells).to_bytes(8, "little"))
    flat = []
    for ins in program.instrs:
        flat.extend((int(ins.op), len(ins.ins)))
        flat.extend(int(c) for c in ins.ins)
        flat.extend(int(c) for c in ins.outs)
        flat.append(-1)
    h.update(np.asarray(flat, np.int64).tobytes())
    for name in sorted(program.ports):
        h.update(name.encode())
        h.update(b"\x00i" if name in program.in_ports else b"\x00o")
        h.update(np.asarray(program.ports[name], np.int64).tobytes())
    if program.parallel_steps is not None:
        for idxs in program.parallel_steps:
            h.update(np.asarray(list(idxs) + [-1], np.int64).tobytes())
    key = h.digest()
    try:
        _key_memo[program] = key
    except TypeError:
        pass
    return key


def _stacked_cells(cell_lists) -> np.ndarray:
    """Concatenate per-port cell lists into one int32 index vector."""
    if not cell_lists:
        return np.zeros(0, np.int32)
    return np.concatenate(
        [np.asarray(c, np.int64) for c in cell_lists]).astype(np.int32)


def output_names(ports_owner) -> list:
    """The port names ``run_program`` returns, sorted: the declared output
    ports, falling back to *every* port for direction-less programs.

    Works on anything with ``ports`` and (optionally) ``out_ports`` --
    ``Program``, ``LevelSchedule`` -- and is the single source of truth for
    that fallback, so all executor backends agree.
    """
    return sorted(getattr(ports_owner, "out_ports", None)
                  or ports_owner.ports)


# --------------------------------------------------------------------------
# per-(structure, plan) compilation artifacts
# --------------------------------------------------------------------------

@dataclasses.dataclass
class _Resolved:
    """One plan+program+input-set binding, resolved exactly once: the
    *effective* schedule kind (the dense fallback for degenerate slot
    layouts is decided here, not per call site), the device-resident
    schedule operands, the bridge index vectors, and the static widths the
    executors take as compile-time constants."""
    kind: str                        # effective schedule after fallback
    sched: LevelSchedule
    la: object
    lb: object
    lo: object
    out_idx: object
    names: list
    out_base: Optional[int]
    in_idx: object
    in_base: Optional[int]
    one_cell: Optional[int]
    in_widths: tuple
    out_widths: tuple
    k_out: int
    fused_ok: bool                   # every port fits a 32-bit transpose
    use_static: bool                 # the straight-line emission applies
    model: Optional["telemetry.ModeledCost"] = None  # analytical cost gauge


@dataclasses.dataclass
class _Compiled:
    """Lazily-populated compilation artifacts for one (program structure,
    plan compile-key) cache entry: the plan's own levelized schedule, the
    dense-fallback artifacts for degenerate slot layouts, device index
    buffers, resolved executor bindings and the static straight-line
    chains."""
    arrays: Optional[tuple] = None              # (ops, a, b, o, n_cells)
    scheds: Dict[str, LevelSchedule] = dataclasses.field(default_factory=dict)
    devs: Dict[str, tuple] = dataclasses.field(default_factory=dict)
    in_idx: Dict[tuple, tuple] = dataclasses.field(default_factory=dict)
    resolved: Dict[tuple, _Resolved] = dataclasses.field(default_factory=dict)
    static_chain: Dict[tuple, Callable] = dataclasses.field(
        default_factory=dict)
    serial_model: Optional["telemetry.ModeledCost"] = None
    # AOT-compiled executables keyed by call-signature memo string
    # (executor name + arg shapes/dtypes + static kwargs).  Populated from
    # the disk tier (deserialize) or by lower().compile() write-through;
    # ``aot_failed`` remembers signatures XLA could not AOT so the jit
    # path is used without re-attempting every call.
    aot: Dict[str, Callable] = dataclasses.field(default_factory=dict)
    aot_failed: set = dataclasses.field(default_factory=set)

    @property
    def weight(self) -> int:
        """Schedule size this entry holds resident: levels x slot width,
        summed over its levelized allocations -- the proxy the LRU's
        weight cap (``_COMPILED_WEIGHT_CAP``) bounds."""
        return sum(int(s.n_levels) * int(s.width)
                   for s in self.scheds.values())

    def get_arrays(self, program):
        if self.arrays is None:
            self.arrays = program.to_arrays()
        return self.arrays

    def get_serial_model(self, program) -> "telemetry.ModeledCost":
        """Modeled cost of the *gate-serial* execution order (numpy oracle
        and un-levelized executors), memoized per cache entry."""
        if self.serial_model is None:
            self.serial_model = telemetry.COST_MODEL.program_cost(
                program.cost())
        return self.serial_model

    def get_schedule(self, program, plan: ExecPlan, kind: Optional[str] = None
                     ) -> LevelSchedule:
        kind = plan.schedule if kind is None else kind
        alloc = "dense" if kind == "dense" else "slots"
        s = self.scheds.get(alloc)
        if s is None:
            content = content_key(program)
            if _artifacts is not None:
                s = _artifacts.load_schedule(content, plan, alloc)
                if s is not None and \
                        set(s.ports) != set(program.ports):
                    # key-collision / stale-entry guard: never trust a
                    # disk schedule whose ports disagree with the program
                    _CACHE.add("disk_errors")
                    s = None
            if s is None:
                if alloc == "dense":
                    s = levelize(program,
                                 max_width=plan.backend.level_max_width)
                else:
                    s = levelize(program, alloc="slots",
                                 max_width=plan.backend.slot_width)
                _CACHE.add("levelized")
                if _artifacts is not None:
                    _artifacts.store_schedule(
                        content, plan, alloc, s,
                        provenance=provenance_of(program))
            self.scheds[alloc] = s
        return s

    def get_sched_dev(self, program, plan: ExecPlan, kind: str):
        alloc = "dense" if kind == "dense" else "slots"
        dev = self.devs.get(alloc)
        if dev is None:
            s = self.get_schedule(program, plan, kind)
            names = output_names(s)
            cells = _stacked_cells([s.ports[n] for n in names])
            dev = (jnp.asarray(s.a), jnp.asarray(s.b), jnp.asarray(s.out),
                   jnp.asarray(cells), names,
                   as_run(cells) if alloc == "slots" else None)
            self.devs[alloc] = dev
        return dev

    def get_in_idx(self, program, plan: ExecPlan, kind: str, in_names):
        alloc = "dense" if kind == "dense" else "slots"
        key = (alloc, tuple(in_names))
        if key not in self.in_idx:
            s = self.get_schedule(program, plan, kind)
            cells = _stacked_cells([s.pack_cells(n) for n in in_names])
            self.in_idx[key] = (jnp.asarray(cells), as_run(cells))
        return self.in_idx[key]

    def resolve(self, program, plan: ExecPlan, in_names: tuple) -> _Resolved:
        """Bind ``plan`` to this program for one input-name set: pick the
        effective schedule (dense fallback for layouts the slot executors
        cannot assemble), materialize the device operands, and freeze the
        static widths.  Memoized -- the per-call dispatcher only reads."""
        memo_key = (plan.schedule, plan.backend.name, plan.mesh is None,
                    in_names)
        r = self.resolved.get(memo_key)
        if r is not None:
            return r
        kind = plan.schedule
        sched = self.get_schedule(program, plan, kind)
        la, lb, lo, out_idx, names, out_base = \
            self.get_sched_dev(program, plan, kind)
        in_idx, in_base = self.get_in_idx(program, plan, kind, in_names)
        k_out = sum(len(sched.ports[n]) for n in names)
        slots_ok = (kind != "dense" and out_base is not None and k_out > 0)
        if plan.backend.name == "pallas" and slots_ok and in_base is None:
            slots_ok = False    # aliased input ports: slice assembly
            #                     impossible, use the dense kernels
        if not slots_ok and kind != "dense":
            # degenerate program for the slot layout: dense executors,
            # which handle every schedule shape
            kind = "dense"
            sched = self.get_schedule(program, plan, kind)
            la, lb, lo, out_idx, names, out_base = \
                self.get_sched_dev(program, plan, kind)
            in_idx, in_base = self.get_in_idx(program, plan, kind, in_names)
        in_widths = tuple(len(sched.pack_cells(n)) for n in in_names)
        out_widths = tuple(len(sched.ports[n]) for n in names)
        r = _Resolved(
            kind=kind, sched=sched, la=la, lb=lb, lo=lo, out_idx=out_idx,
            names=names, out_base=out_base, in_idx=in_idx, in_base=in_base,
            one_cell=None if sched.one_cell is None else int(sched.one_cell),
            in_widths=in_widths, out_widths=out_widths,
            k_out=sum(out_widths),
            fused_ok=bool(in_names) and
            max(in_widths + out_widths, default=0) <= 32,
            use_static=(plan.schedule == "slots-static" and slots_ok
                        and plan.mesh is None),
            model=telemetry.COST_MODEL.schedule_cost(sched))
        self.resolved[memo_key] = r
        return r

    def get_static_chain(self, program, plan: ExecPlan, in_names, fused,
                         in_widths, out_widths):
        key = (tuple(in_names), fused, in_widths, out_widths,
               plan.layout.planes)
        if key not in self.static_chain:
            s = self.get_schedule(program, plan, "slots")
            cells = _stacked_cells([s.pack_cells(n) for n in in_names])
            self.static_chain[key] = kslots.build_static_chain(
                s, in_widths, out_widths, output_names(s), cells,
                seg_levels=plan.backend.seg_levels, fused=fused,
                planes=plan.layout.planes)
        return self.static_chain[key]

    def get_static_pallas(self, program, plan: ExecPlan, in_names,
                          in_widths, out_widths):
        key = ("pallas", tuple(in_names), in_widths, out_widths,
               plan.layout.planes)
        if key not in self.static_chain:
            s = self.get_schedule(program, plan, "slots")
            self.static_chain[key] = make_slots_static(
                s, in_widths, out_widths, output_names(s),
                planes=plan.layout.planes)
        return self.static_chain[key]


def compiled(program, plan: Optional[ExecPlan] = None) -> _Compiled:
    key = cache_key(program, plan)
    entry = _compiled.get(key)
    if entry is None:
        entry = _compiled[key] = _Compiled()
        _CACHE.add("misses")
    else:
        _compiled.move_to_end(key)
        _CACHE.add("hits")
    _evict_over_cap(protect=key)
    return entry


def is_compiled(program, plan=None) -> bool:
    """True when the compiled-program cache already holds ``program``'s
    lowered schedule artifacts for ``plan`` -- i.e. the next execution
    pays no levelize/lowering cost.  ``plan`` accepts an ExecPlan or a
    schedule-name string (the historical signature).  A pure query: it
    never creates an entry and never touches LRU order (serving uses it to
    report honest ``cached`` flags without perturbing eviction)."""
    if isinstance(plan, str):
        plan = as_plan(schedule=plan)
    entry = _compiled.get(cache_key(program, plan))
    if entry is None:
        return False
    kind = (plan or DEFAULT_PLAN).schedule
    return ("dense" if kind == "dense" else "slots") in entry.devs


def program_arrays(program):
    """(ops, a, b, out, n_cells) of the NOR-lowered program, cached by
    structural content hash (under the default plan's cache entry)."""
    return compiled(program).get_arrays(program)


def program_schedule(program, plan=None) -> LevelSchedule:
    """The levelized execution schedule of ``program`` (slot or dense
    layout per the plan's schedule kind), cached per (structure, plan).
    ``plan`` accepts an ExecPlan or a schedule-name string."""
    if isinstance(plan, str):
        plan = as_plan(schedule=plan)
    plan = DEFAULT_PLAN if plan is None else plan
    return compiled(program, plan).get_schedule(program, plan)


# --------------------------------------------------------------------------
# row-major <-> packed-column bridges (fully vectorized)
# --------------------------------------------------------------------------

def _ports_of(ports_or_program) -> Dict[str, list]:
    return getattr(ports_or_program, "ports", ports_or_program)


def _value_limbs(vals, n_limbs: int, pad_rows: int) -> np.ndarray:
    """uint32[pad_rows, n_limbs] little-endian 32-bit limbs of per-row
    integers.  Wide ports (> 64 bits) go through an object-dtype array so
    arbitrary-precision values split without any per-row Python loop."""
    vals = np.asarray(vals)
    n = len(vals)
    limbs = np.zeros((pad_rows, n_limbs), np.uint32)
    if n_limbs <= 2 and vals.dtype != object:
        v = np.zeros(pad_rows, np.uint64)
        v[:n] = vals.astype(np.uint64)
        for j in range(n_limbs):
            limbs[:, j] = ((v >> np.uint64(32 * j))
                           & np.uint64(0xFFFFFFFF)).astype(np.uint32)
    else:
        v = np.zeros(pad_rows, object)
        v[:n] = vals.astype(object)
        for j in range(n_limbs):
            limbs[:, j] = ((v >> (32 * j)) & 0xFFFFFFFF).astype(np.uint32)
    return limbs


def _le_bytes(arr: np.ndarray) -> np.ndarray:
    """Little-endian uint8 view of an integer array (copy only on BE hosts),
    so bit k of element e is bit k%8 of byte e*itemsize + k//8."""
    return np.ascontiguousarray(arr).astype(
        arr.dtype.newbyteorder("<"), copy=False).view(np.uint8)


def _pack_port_words(vals, nc: int, n_words: int,
                     layout: WordLayout = ROWS32) -> np.ndarray:
    """Packed words of one port's per-row integers: uint32[nc, n_words]
    under rows32 (bit w of word i is row 32*i + w), or the planes-leading
    uint32[planes, nc, n_words] under rows64 (plane h of word i covers
    rows ``64*i + 32*h + w`` -- the little-endian uint64 halves)."""
    n_limbs = (nc + 31) // 32
    n32 = n_words * layout.planes
    limbs = _value_limbs(vals, n_limbs, n32 * 32)
    # [pad_rows, 32 * n_limbs] -> cell-major [nc, pad_rows] bit matrix
    bits = np.unpackbits(_le_bytes(limbs), axis=1, bitorder="little")
    cols = np.ascontiguousarray(bits.T[:nc])
    words = np.packbits(cols.reshape(nc, n32, 32), axis=2,
                        bitorder="little")                    # [nc, n32, 4]
    w32 = words.reshape(nc, -1).view("<u4")
    if layout.planes == 1:
        return w32
    # uint32 word 2i+h of rows32 is plane h of logical word i
    return np.ascontiguousarray(
        np.moveaxis(w32.reshape(nc, n_words, layout.planes), -1, 0))


def _sub_to_rows32(sub: np.ndarray) -> np.ndarray:
    """Collapse a planes-leading packed block back to the rows32 word
    order: (planes, k, n_words) -> (k, n_words * planes)."""
    if sub.ndim == 2:
        return sub
    planes, k, n_words = sub.shape
    return np.ascontiguousarray(
        np.moveaxis(sub, 0, -1).reshape(k, n_words * planes))


def pack_rows(values: Dict[str, np.ndarray], ports, n_rows: int,
              n_cells: int, one_cell: Optional[int] = None,
              pad_to: int = TILE_W,
              layout: WordLayout = ROWS32) -> np.ndarray:
    """Pack per-row port integers into column-major word state --
    uint32[n_cells, n_words] (rows32; bit w of state[c, i] = cell c of row
    32*i + w) or the planes-leading uint32[planes, n_cells, n_words]
    (rows64).  ``ports`` is a name -> cell-list mapping (or any object with
    a ``.ports`` attribute).  ``one_cell``, when given, is filled with ones
    (the LevelSchedule's folded INIT1 constant).

    Bit transposition runs entirely in C (unpackbits/packbits on
    little-endian byte views); the only Python loop is over 32-bit limbs of
    arbitrarily wide ports.
    """
    ports = _ports_of(ports)
    n_words = layout.n_words(n_rows, pad_to)
    state = np.zeros(layout.state_shape(n_cells, n_words), np.uint32)
    if one_cell is not None:
        state[..., one_cell, :] = _FULL
    for name, vals in values.items():
        cells = np.asarray(ports[name], np.int64)
        state[..., cells, :] = _pack_port_words(vals, len(cells), n_words,
                                                layout)
    return state


def unpack_rows(state: np.ndarray, ports, n_rows: int,
                names: Optional[Iterable[str]] = None
                ) -> Dict[str, np.ndarray]:
    """Inverse of :func:`pack_rows` (row-major ints); ``names`` restricts
    which ports are unpacked (default: all).  The word layout is inferred
    from the state rank.  Ports wider than 63 cells come back as object
    arrays of Python ints.

    ``state`` may be a device (jnp) array: the port rows are gathered with
    one indexed read and transferred once.
    """
    ports = _ports_of(ports)
    names = list(ports if names is None else names)
    all_cells = np.concatenate(
        [np.asarray(ports[n], np.int64) for n in names]) if names else \
        np.zeros(0, np.int64)
    sub = np.asarray(state[all_cells] if state.ndim == 2
                     else state[:, all_cells])   # one gather + host transfer
    return _unpack_sub(sub, [(n, len(ports[n])) for n in names], n_rows)


def _unpack_sub(sub: np.ndarray, name_widths, n_rows: int
                ) -> Dict[str, np.ndarray]:
    """Unpack pre-gathered port rows (stacked in ``name_widths`` order;
    rows32 2-D or planes-leading 3-D)."""
    sub = _sub_to_rows32(np.asarray(sub))
    out = {}
    off = 0
    for name, nc in name_widths:
        w = sub[off:off + nc]                                  # [nc, n_words]
        off += nc
        n_limbs = (nc + 31) // 32
        # word bits -> row-major bit matrix [n_rows, nc] -> limb matrix
        bits = np.unpackbits(_le_bytes(w), axis=1,
                             bitorder="little")[:, :n_rows]
        by = np.packbits(np.ascontiguousarray(bits.T), axis=1,
                         bitorder="little")                # [n_rows, ceil/8]
        if by.shape[1] != 4 * n_limbs:
            pad = np.zeros((n_rows, 4 * n_limbs), np.uint8)
            pad[:, :by.shape[1]] = by
            by = pad
        limbs = by.view("<u4")                             # [n_rows, n_limbs]
        if nc > 63:
            acc = np.zeros(n_rows, object)
            for j in range(n_limbs):
                acc |= limbs[:, j].astype(object) << (32 * j)
            out[name] = acc
        else:
            acc = limbs[:, 0].astype(np.uint64)
            if n_limbs > 1:
                acc |= limbs[:, 1].astype(np.uint64) << np.uint64(32)
            out[name] = acc
    return out


# --------------------------------------------------------------------------
# multi-device row sharding (word axis)
# --------------------------------------------------------------------------
#
# The packed word axis is embarrassingly parallel: every level executes
# ``out[cells] <- ~(a[cells] | b[cells])`` elementwise along words, and the
# schedule's index operands are word-invariant.  Sharding is therefore pure
# data parallelism -- input port rows split along words, index matrices
# replicate, output port rows split along words; no collective ever runs.

@functools.lru_cache(maxsize=None)
def row_mesh(n_devices: Optional[int] = None) -> Optional[Mesh]:
    """1-D device mesh over the packed word (row-block) axis, or ``None``
    when only one device is available / requested (the unsharded path).
    Run CPU hosts with ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
    to exercise N-way sharding without accelerators."""
    devs = jax.devices()
    n = len(devs) if n_devices is None else min(n_devices, len(devs))
    if n <= 1:
        return None
    return Mesh(np.array(devs[:n]), ("rows",))


# Every levelized executor entry point shares one signature --
# (in_block, in_idx, la, lb, lo, out_idx) -- with the data block sharded
# along its trailing word/row axis and the schedule operands replicated.
# The data block is rank 2 (fused values, rows32 port rows) or rank 3
# (rows64 port rows with the leading plane axis); specs follow the rank.

def _shard_specs(data_rank: int) -> Tuple[tuple, P]:
    data = P(*([None] * (data_rank - 1) + ["rows"]))
    return ((data, P(None), P(None, None), P(None, None), P(None, None),
             P(None)), data)


# Bounded like _compiled, and for the same reason: each wrapper pins
# compiled XLA executables keyed by per-program statics, so long-running
# serving that keeps minting program structures must evict here too.
_SHARD_CACHE_CAP = 64
_shard_cache: "collections.OrderedDict[tuple, Callable]" = \
    collections.OrderedDict()


def _sharded_exec(fn, mesh: Mesh, check_rep: bool, data_rank: int = 2,
                  **static) -> Callable:
    """``jax.jit(shard_map(fn))`` over the rank-matched specs, cached per
    (executor, mesh, statics) so each chunk shape compiles once.  Pallas
    calls have no replication rule, hence ``check_rep=False`` there."""
    key = (fn, mesh, check_rep, data_rank, tuple(sorted(static.items())))
    wrapped = _shard_cache.get(key)
    if wrapped is None:
        inner = functools.partial(fn, **static)
        in_specs, out_spec = _shard_specs(data_rank)
        wrapped = jax.jit(shard_map(
            inner, mesh=mesh, in_specs=in_specs,
            out_specs=out_spec, check_rep=check_rep))
        _shard_cache[key] = wrapped
        while len(_shard_cache) > _SHARD_CACHE_CAP:
            _shard_cache.popitem(last=False)
    else:
        _shard_cache.move_to_end(key)
    return wrapped


# --------------------------------------------------------------------------
# fault-tolerant execution: inject -> detect -> retry -> remap (DESIGN §12,
# §14)
# --------------------------------------------------------------------------
#
# The plan's FaultModel corrupts each chunk's *output readback* (the
# layout-polymorphic post-level hook: transient per-level flips plus the
# persistent dead rows / stuck word columns of the physical span the chunk
# landed on), and its VerifyPolicy turns on detection: a per-word XOR check
# plane emitted *on the device* right behind the executor
# (``pim_exec.check_words`` -- the parity real hardware would generate in
# the array), refolded on the host only after injection -- any single
# corrupted bit per word position mismatches -- plus amortized numpy-oracle
# spot checks.  On mismatch the chunk retries with exponential backoff
# (transients re-roll per attempt); persistent failures re-home the chunk
# onto a spare physical span that the simulated BIST media scan certifies
# clean (abandoned spans go to runtime.faults' quarantine for the
# background scrubber; every dispatch attempt books endurance wear there
# too).  All of it wraps ``_dispatch_levelized`` from the outside -- both
# the row-value form and the packed-domain stage form behind
# ``dispatch_packed`` -- so every schedule kind x word layout x backend x
# output representation inherits the machinery and the compiled artifacts
# stay byte-identical (plan.compile_key excludes faults/verify).

#: Cumulative health counters (faults_injected/detected/corrected,
#: retries, remapped_rows, spot_checks, spot_mismatches) -- a
#: Counter-shaped view over the global telemetry registry's
#: ``pim.health.*`` names, so executor threads and the media scrubber
#: increment under one lock (the bare ``Counter`` this used to be lost
#: concurrent updates: ``c[k] += 1`` is a get-then-set pair).  Hot sites
#: use the atomic :meth:`~repro.runtime.telemetry.CounterGroup.add`;
#: :func:`drain_health` snapshots-and-resets (the serving runtime drains
#: per batch into its Stats).
HEALTH: "telemetry.CounterGroup" = telemetry.REGISTRY.group("pim.health")


def drain_health() -> dict:
    """Snapshot and reset :data:`HEALTH`; returns the non-zero counters.
    (Compatibility shim over ``HEALTH.drain()`` -- the historical API.)"""
    return HEALTH.drain()


class _Corrupt(Exception):
    """Internal: a chunk's verification failed (check-word mismatch or
    oracle spot-check miss); drives the retry loop, never escapes it."""


def _check_deadline(deadline: Optional[float]) -> None:
    """Raise :class:`DeadlineExceeded` when the absolute ``time.monotonic``
    deadline has passed (checked at dispatch and between chunks)."""
    if deadline is not None and time.monotonic() > deadline:
        raise DeadlineExceeded("deadline exceeded between chunks")


def _state_span(plan: ExecPlan, rows: int) -> int:
    """Physical rows covered by one chunk's packed state (incl. word/tile
    padding) -- the span the media scan certifies and the injectors
    corrupt; mirrors ``_dispatch_levelized``'s word-count computation."""
    shards = 1 if plan.mesh is None else plan.mesh.devices.size
    n_words = plan.layout.n_words(rows, plan.backend.pad_to * shards)
    return n_words * 32 * plan.layout.planes


def _chunk_salt(pkey: bytes, start: int) -> int:
    """Deterministic per-(program, chunk) transient-sampling salt."""
    return (int.from_bytes(pkey[:8], "little")
            ^ (start * 0x9E3779B97F4A7C15)) & ((1 << 64) - 1)


@dataclasses.dataclass
class _FaultCtx:
    """One dispatch attempt's injection + verification context, threaded
    into ``_dispatch_levelized``; the finalize closures call the
    ``process_*`` hook matching their output representation."""
    faults: Optional[FaultModel]
    verify: Optional[VerifyPolicy]
    row_base: int
    salt: int
    attempt: int

    def _checked(self, clean_chk, data, axis: int, injected: int):
        if injected:
            HEALTH.add("faults_injected", injected)
        # with no FaultModel nothing can have mutated the readback, so the
        # refold-and-compare is a guaranteed no-op: the clean fold above
        # models the hardware's parity generation cost, the compare only
        # runs when there is simulated media to distrust
        if clean_chk is not None and self.faults is not None:
            if not np.array_equal(np.bitwise_xor.reduce(data, axis=axis),
                                  clean_chk):
                HEALTH.add("faults_detected")
                raise _Corrupt("check-word mismatch")
        return data

    def process_values(self, o: np.ndarray, out_widths, n_levels: int,
                       clean_chk: Optional[np.ndarray]) -> np.ndarray:
        """Fused fast path: ``o`` is uint32[n_ports, padded_rows]."""
        if self.faults is not None and self.verify is not None \
                and clean_chk is None:
            clean_chk = np.bitwise_xor.reduce(o, axis=0)  # clean-copy fold
        injected = 0
        if self.faults is not None:
            o, injected = self.faults.inject_values(
                o, out_widths, row_base=self.row_base, salt=self.salt,
                attempt=self.attempt, n_levels=n_levels)
        return self._checked(clean_chk, o, 0, injected)

    def process_packed(self, sub: np.ndarray, n_levels: int,
                       clean_chk: Optional[np.ndarray]) -> np.ndarray:
        """Padded-io path: ``sub`` is the packed output block (cell axis
        -2, rows32 2-D or planes-leading 3-D)."""
        if self.faults is not None and self.verify is not None \
                and clean_chk is None:
            clean_chk = np.bitwise_xor.reduce(sub, axis=sub.ndim - 2)
        injected = 0
        if self.faults is not None:
            sub, injected = self.faults.inject_packed(
                sub, row_base=self.row_base, salt=self.salt,
                attempt=self.attempt, n_levels=n_levels)
        return self._checked(clean_chk, sub, sub.ndim - 2, injected)


# Rows verified since the last oracle spot check, shared across calls so
# the oracle cost amortizes per *row served*, not per call (a hot 8k-row
# array must not pay an exec_packed per invocation).  Starts saturated so
# the first verified execution in a process is always spot-checked.
_spot_debt = 1 << 62


class _VerifyRun:
    """Per-execution (one streaming run / one group) retry + remap state:
    the logical-start -> spare-span remap table and the spare allocator.
    The HEALTH counters aggregate across runs; this object holds only what
    must be consistent *within* one run (a remapped chunk stays remapped
    for its retries)."""

    def __init__(self, plan: ExecPlan):
        self.plan = plan
        self.faults = plan.faults
        self.policy = plan.verify
        self.spare_next = None if self.faults is None \
            else int(self.faults.spare_base)
        self.remap: Dict[int, int] = {}

    def _alloc(self, span: int) -> int:
        base = self.spare_next
        self.spare_next += (span + 63) // 64 * 64
        return base

    def _clean_spare(self, span: int, limit: int) -> int:
        base = self._alloc(span)
        tries = 0
        while self.faults.span_bad(base, span):
            tries += 1
            if tries > limit:
                raise FaultError(
                    f"media scan found no clean {span}-row spare span "
                    f"after {limit} candidates",
                    span_rows=span, scan_limit=limit)
            base = self._alloc(span)
        return base

    def place(self, start: int, span: int) -> int:
        """Physical base for the chunk at logical row ``start``: the
        existing remap target, or -- when the media scan flags the span's
        persistent faults -- a freshly scanned clean spare."""
        base = self.remap.get(start, start)
        if self.faults is None or self.policy is None:
            return base
        if self.faults.span_bad(base, span):
            note_quarantine(base, span)       # scrubber's work queue
            base = self._clean_spare(span, self.policy.scan_limit)
            self.remap[start] = base
            HEALTH.add("remapped_rows", span)
        return base

    def rehome(self, start: int, span: int) -> int:
        """Force a fresh spare placement (retry policy escalation: the
        current span keeps failing verification even though the scan
        called it clean -- treat it as marginal and move off it)."""
        if self.faults is None:
            return self.remap.get(start, start)
        note_quarantine(self.remap.get(start, start), span)
        base = self._clean_spare(span, self.policy.scan_limit)
        self.remap[start] = base
        HEALTH.add("remapped_rows", span)
        return base

    def maybe_spot(self, program, inputs, n_rows: int, out: dict) -> None:
        """Amortized numpy-oracle spot check: every ``spot_interval_rows``
        verified rows, recompute ``spot_rows`` sampled rows on the
        cycle-accurate oracle and compare bit-exactly (catches what the
        per-word parity cannot -- e.g. paired flips of one bit position).
        Raises :class:`_Corrupt` on mismatch so the chunk retries."""
        global _spot_debt
        pol = self.policy
        if pol is None or pol.spot_rows <= 0 or n_rows <= 0:
            return
        _spot_debt += n_rows
        if _spot_debt < pol.spot_interval_rows:
            return
        _spot_debt = 0
        HEALTH.add("spot_checks")
        k = min(pol.spot_rows, n_rows)
        idx = np.unique(np.linspace(0, n_rows - 1, num=k, dtype=np.int64))
        sub_in = {n: np.asarray(v)[idx] for n, v in inputs.items()}
        oplan = dataclasses.replace(
            self.plan, backend=BACKENDS["numpy"], mesh=None, layout=ROWS32,
            chunk_rows=None, faults=None, verify=None)
        want = run_program(program, sub_in, int(idx.size), oplan)
        for name, w in want.items():
            if not np.array_equal(np.asarray(out[name])[idx], w):
                HEALTH.add("spot_mismatches")
                HEALTH.add("faults_detected")
                raise _Corrupt(f"oracle spot check mismatch on {name!r}")


def _verified_dispatch(program, inputs: Dict[str, np.ndarray], n_rows: int,
                       plan: ExecPlan, pad_rows: Optional[int],
                       vrun: _VerifyRun, start: int) -> Callable:
    """Dispatch one chunk under the plan's fault model / verify policy;
    returns a ``finalize`` that runs the detect -> retry -> remap loop.

    The initial attempt dispatches asynchronously exactly like the plain
    path (pipelining is preserved when nothing is corrupted -- the common
    case); retries are synchronous re-dispatches inside finalize."""
    span = _state_span(plan, n_rows if pad_rows is None else pad_rows)
    base = vrun.place(start, span)
    pkey = content_key(program)
    salt = _chunk_salt(pkey, start)

    def dispatch(attempt: int, row_base: int) -> Callable:
        fctx = _FaultCtx(plan.faults, plan.verify, row_base, salt, attempt)
        record_wear(row_base, span)           # every attempt writes media
        return _dispatch_levelized(program, inputs, n_rows, plan,
                                   pad_rows=pad_rows, fctx=fctx)

    first = dispatch(0, base)

    def finalize() -> Dict[str, np.ndarray]:
        pol = plan.verify
        attempt, row_base, fin = 0, base, first
        while True:
            try:
                out = fin()
                vrun.maybe_spot(program, inputs, n_rows, out)
                break
            except _Corrupt:
                attempt += 1
                if pol is None or attempt > pol.max_retries:
                    raise FaultError(
                        f"rows [{start}, {start + n_rows}): verification "
                        f"still failing after {attempt - 1} retries",
                        program_key=pkey[:8].hex(), chunk_start=start,
                        rows=n_rows, attempts=attempt,
                        remapped_base=vrun.remap.get(start))
                HEALTH.add("retries")
                time.sleep(min(pol.backoff_s * (1 << (attempt - 1)), 0.05))
                if attempt >= pol.remap_after and plan.faults is not None:
                    row_base = vrun.rehome(start, span)
                fin = dispatch(attempt, row_base)
        if attempt:
            HEALTH.add("faults_corrected")
        return out

    return finalize


def _verified_dispatch_packed(program, n_rows: int, plan: ExecPlan,
                              vrun: _VerifyRun, stage: int, *,
                              inputs=None, packed_in=None, in_names=None,
                              deadline: Optional[float] = None) -> Callable:
    """Packed-domain stage under the plan's fault model / verify policy
    (the reduction-tree analog of :func:`_verified_dispatch`).

    Every packed stage is its own verify cut-point: the per-stage XOR
    check plane folds over the whole packed block (zero pad rows included
    -- they are the additive identity, so a corrupted pad still flips the
    parity and is caught), and because the stage's *input* block lives on
    the host between stages, a detected corruption re-runs only this
    stage, not the reduction levels already verified below it.  The whole
    tree shares one :class:`_VerifyRun` keyed at logical row 0 (each level
    physically reuses the same span, shrinking as the tree narrows), so a
    remap sticks for every later level; ``stage`` salts the transient
    stream so levels of one program don't roll identical flips."""
    span = _state_span(plan, n_rows)
    base = vrun.place(0, span)
    pkey = content_key(program)
    salt = _chunk_salt(pkey, stage)
    names = inputs if packed_in is None else {n: None for n in in_names}

    def dispatch(attempt: int, row_base: int) -> Callable:
        fctx = _FaultCtx(plan.faults, plan.verify, row_base, salt, attempt)
        record_wear(row_base, span)
        return _dispatch_levelized(program, names, n_rows, plan, fctx=fctx,
                                   packed_in=packed_in, packed_out=True)

    first = dispatch(0, base)

    def finalize() -> np.ndarray:
        pol = plan.verify
        attempt, row_base, fin = 0, base, first
        while True:
            try:
                out = fin()
                break
            except _Corrupt:
                attempt += 1
                if pol is None or attempt > pol.max_retries:
                    raise FaultError(
                        f"packed stage {stage} ({n_rows} rows): "
                        f"verification still failing after "
                        f"{attempt - 1} retries",
                        program_key=pkey[:8].hex(), stage=stage,
                        rows=n_rows, attempts=attempt,
                        remapped_base=vrun.remap.get(0))
                HEALTH.add("retries")
                _check_deadline(deadline)
                time.sleep(min(pol.backoff_s * (1 << (attempt - 1)), 0.05))
                if attempt >= pol.remap_after and plan.faults is not None:
                    row_base = vrun.rehome(0, span)
                fin = dispatch(attempt, row_base)
        if attempt:
            HEALTH.add("faults_corrected")
        return out

    return finalize


def _needs_ft(plan: ExecPlan) -> bool:
    return plan.faults is not None or plan.verify is not None


# --------------------------------------------------------------------------
# execution
# --------------------------------------------------------------------------

def _fit_packed(block: np.ndarray, n_words: int) -> np.ndarray:
    """Fit a pre-packed word block to the dispatch's padded word count:
    zero-pad the trailing word axis (pad rows are all-zero by the packing
    contract) or reject a block wider than the padded shape."""
    have = block.shape[-1]
    if have == n_words:
        return block
    if have > n_words:
        raise ValueError(
            f"packed input has {have} words, dispatch shape allows "
            f"{n_words}")
    pad = np.zeros(block.shape[:-1] + (n_words - have,), np.uint32)
    return np.concatenate([block, pad], axis=-1)


def _aot_call(comp, program, plan: ExecPlan, fn, args: tuple, static: dict):
    """Invoke a jitted executor, routing through the AOT-executable tier
    when a disk artifact cache is installed.

    Per exact call signature (executor name + operand shapes/dtypes +
    static kwargs), the first process pays ``lower().compile()`` once and
    serializes the XLA executable to disk; later processes (or a
    ``warm()``-ed replica) deserialize it in milliseconds and skip tracing
    entirely.  Any failure -- XLA refusing to serialize, version skew, a
    deserialized executable rejecting the operands -- permanently marks
    the signature failed for this entry and falls back to the plain jit
    path, so AOT is strictly an optimization, never a correctness risk.
    Mesh-sharded and trace-time-static paths never come through here."""
    if _artifacts is None or not getattr(_artifacts, "aot", False):
        return fn(*args, **static)
    memo = "|".join((
        fn.__name__,
        ";".join(f"{tuple(a.shape)}:{a.dtype}" for a in args),
        ";".join(f"{k}={static[k]!r}" for k in sorted(static))))
    loaded = comp.aot.get(memo)
    if loaded is not None:
        try:
            return loaded(*args)
        except Exception:
            del comp.aot[memo]
            comp.aot_failed.add(memo)
            return fn(*args, **static)
    if memo in comp.aot_failed:
        return fn(*args, **static)
    content = content_key(program)
    try:
        loaded = _artifacts.load_executable(content, plan, memo)
        if loaded is None:
            loaded = fn.lower(*args, **static).compile()
            _artifacts.store_executable(content, plan, memo, loaded,
                                        provenance=provenance_of(program))
        out = loaded(*args)
    except Exception:
        comp.aot_failed.add(memo)
        return fn(*args, **static)
    comp.aot[memo] = loaded
    return out


def _dispatch_levelized(program, inputs: Dict[str, np.ndarray], n_rows: int,
                        plan: ExecPlan,
                        pad_rows: Optional[int] = None, *,
                        fctx: Optional[_FaultCtx] = None,
                        packed_in: Optional[np.ndarray] = None,
                        packed_out: bool = False) -> Callable:
    """Pack ``inputs`` and dispatch one levelized execution under ``plan``;
    returns a zero-arg ``finalize`` that blocks on the device result and
    unpacks it.

    Dispatch is asynchronous (JAX futures), so callers can overlap host
    packing of the next chunk with device execution of this one -- the
    streaming executor's pipeline.  ``pad_rows`` fixes the padded row count
    (>= n_rows) so every streaming chunk shares one compiled shape.

    ``packed_in``/``packed_out`` keep the data in the packed word domain
    (the in-memory composition contract behind :func:`dispatch_packed`):
    ``packed_in`` replaces host packing with a caller-supplied word block
    whose cell axis stacks the in-ports' cells in sorted-name order
    (``inputs`` then only names the ports), and ``packed_out`` makes
    ``finalize`` return the raw packed output block (out-ports stacked in
    ``output_names`` order) instead of unpacked row values.
    """
    comp = compiled(program, plan)
    in_names = sorted(inputs)
    r = comp.resolve(program, plan, tuple(in_names))
    # one O(1) registry fold per dispatch: exec counters + the modeled
    # cycle/energy gauges precomputed at resolve time (DESIGN.md §15) --
    # the telemetry cost is a handful of dict ops, independent of rows
    # and schedule size, so the tracked-kernel overhead stays <2%
    telemetry.record_dispatch(n_rows, r.model)
    tracer = telemetry.TRACER
    t_disp = time.perf_counter() if tracer.enabled else 0.0

    def _traced(fin: Callable) -> Callable:
        if not tracer.enabled:
            return fin
        def wrapped():
            out = fin()
            tracer.event("exec", t_disp, time.perf_counter(),
                         cat="pim.exec", rows=n_rows,
                         levels=int(r.sched.n_levels), kind=r.kind)
            return out
        return wrapped

    layout, backend, mesh = plan.layout, plan.backend, plan.mesh
    planes = layout.planes
    shards = 1 if mesh is None else mesh.devices.size
    pad_to = backend.pad_to * shards
    n_words = layout.n_words(n_rows if pad_rows is None else pad_rows,
                             pad_to)
    is_pallas = backend.name == "pallas"
    use_fused = r.fused_ok and packed_in is None and not packed_out
    if use_fused:
        vals = [np.asarray(inputs[n]) for n in in_names]
        use_fused = all(v.dtype != object for v in vals)
    if use_fused:
        # fused fast path: the bit transposes run inside the executor's
        # XLA program; only (n_ports, n_rows) uint32 cross the boundary
        pad_rows_total = n_words * 32 * planes
        in_vals = np.empty((len(vals), pad_rows_total), np.uint32)
        for p, v in enumerate(vals):
            in_vals[p, :len(v)] = v           # same-kind cast in place
            in_vals[p, len(v):] = 0           # only the ragged tail zeroed
        if r.use_static and not is_pallas:
            run = comp.get_static_chain(program, plan, in_names, True,
                                        r.in_widths, r.out_widths)
            outs = run(jnp.asarray(in_vals))
        elif r.use_static and r.in_base == 0:
            run = comp.get_static_pallas(program, plan, in_names,
                                         r.in_widths, r.out_widths)
            outs = run(jnp.asarray(in_vals))
        else:
            if r.kind != "dense":
                fn = (pim_exec_slots_fused if is_pallas
                      else pim_exec_ref_slots_fused)
                static = dict(n_cells=r.sched.n_cells, one_cell=r.one_cell,
                              in_widths=r.in_widths, out_widths=r.out_widths,
                              in_base=r.in_base, out_base=r.out_base,
                              planes=planes)
            else:
                fn = (pim_exec_level_fused if is_pallas
                      else pim_exec_ref_level_fused)
                static = dict(n_cells=r.sched.n_cells, one_cell=r.one_cell,
                              in_widths=r.in_widths, out_widths=r.out_widths,
                              planes=planes)
            if mesh is None:
                outs = _aot_call(comp, program, plan, fn,
                                 (jnp.asarray(in_vals), r.in_idx, r.la,
                                  r.lb, r.lo, r.out_idx), static)
            else:
                outs = _sharded_exec(fn, mesh, not is_pallas, 2, **static)(
                    jnp.asarray(in_vals), r.in_idx, r.la, r.lb, r.lo,
                    r.out_idx)

        # verified-under-fault plans emit the XOR check plane *on the
        # device* (pim_exec.check_words), dispatched asynchronously right
        # behind the executor: the parity generation rides the same device
        # pass, and the host only refolds after injection, when there is
        # simulated media to distrust AND a VerifyPolicy to act on a
        # mismatch -- verify-only and faults-only plans skip the fold
        # entirely (DESIGN.md §14)
        chk = check_words(outs, axis=0) if fctx is not None \
            and fctx.faults is not None and fctx.verify is not None \
            else None

        def finalize() -> Dict[str, np.ndarray]:
            o = np.asarray(outs)                     # blocks until ready
            if fctx is not None:
                o = fctx.process_values(o, r.out_widths, r.sched.n_levels,
                                        None if chk is None
                                        else np.asarray(chk))
            return {n: o[p, :n_rows].astype(np.uint64)
                    for p, n in enumerate(r.names)}
        return _traced(finalize)
    if packed_in is not None:
        k_in = sum(len(r.sched.pack_cells(n)) for n in in_names)
        if packed_in.shape[-2] != k_in:
            raise ValueError(
                f"packed input stacks {packed_in.shape[-2]} cells, "
                f"in-ports {in_names} need {k_in}")
        in_rows = _fit_packed(packed_in, n_words)
    elif in_names:
        in_rows = np.concatenate(
            [_pack_port_words(inputs[n], len(r.sched.pack_cells(n)),
                              n_words, layout) for n in in_names], axis=-2)
    else:
        in_rows = np.zeros(layout.state_shape(0, n_words), np.uint32)
    if r.use_static and not is_pallas:
        run = comp.get_static_chain(program, plan, in_names, False,
                                    r.in_widths, r.out_widths)
        sub = run(jnp.asarray(in_rows))
    else:
        # (slots-static + pallas has no wide-port static kernel; the scan
        # slot executor is the closest hardware shape)
        if r.kind != "dense":
            exec_fn = (pim_exec_slots_io if is_pallas
                       else pim_exec_ref_slots_io)
            static = dict(n_cells=r.sched.n_cells, one_cell=r.one_cell,
                          k_out=r.k_out, in_base=r.in_base,
                          out_base=r.out_base)
        else:
            exec_fn = (pim_exec_level_padded_io if is_pallas
                       else pim_exec_ref_level_io)
            static = dict(n_cells=r.sched.n_cells, one_cell=r.one_cell)
        if mesh is None:
            sub = _aot_call(comp, program, plan, exec_fn,
                            (jnp.asarray(in_rows), r.in_idx, r.la, r.lb,
                             r.lo, r.out_idx), static)
        else:
            sub = _sharded_exec(exec_fn, mesh, not is_pallas,
                                in_rows.ndim, **static)(
                jnp.asarray(in_rows), r.in_idx, r.la, r.lb, r.lo, r.out_idx)

    # on-device check plane for the packed/padded-io path too: the fold
    # runs over the cell axis (-2) of the packed output block
    chk = check_words(sub, axis=sub.ndim - 2) if fctx is not None \
        and fctx.faults is not None and fctx.verify is not None else None

    def finalize():
        s = np.asarray(sub)
        if fctx is not None:
            s = fctx.process_packed(s, r.sched.n_levels,
                                    None if chk is None else np.asarray(chk))
        if packed_out:
            return s
        return _unpack_sub(s,
                           [(n, len(r.sched.ports[n])) for n in r.names],
                           n_rows)
    return _traced(finalize)


def run_program(program, inputs: Dict[str, np.ndarray], n_rows: int,
                plan=None, levelized: bool = True, *,
                backend=None, mesh=None, schedule=None, layout=None
                ) -> Dict[str, np.ndarray]:
    """Element-parallel execution of a gate program over ``n_rows`` rows.

    ``plan`` is an :class:`ExecPlan` -- or, for convenience, a backend
    name ('pallas' interpret-mode kernels, 'ref' jnp oracle, 'numpy' the
    cycle-accurate simulator's packed executor); the keyword strings
    (``backend=``/``schedule=``/``layout=``/``mesh=``) build a plan at
    this boundary.  'pallas' and 'ref' consume the levelized schedule by
    default; ``levelized=False`` selects the original gate-serial
    executors (rows32 only).  The plan's mesh (see :func:`row_mesh`)
    shards the packed word axis over devices; its layout picks the packed
    word form ('rows32' uint32 words, 'rows64' the paired 64-row layout).

    Returns the program's output ports -- all ports when the program does
    not declare port directions (the :func:`output_names` contract, which
    every backend path shares).
    """
    plan = as_plan(plan, backend=backend, mesh=mesh, schedule=schedule,
                   layout=layout, default_backend="pallas")
    if not levelized and (plan.mesh is not None or plan.layout.planes > 1):
        raise ValueError(
            "mesh sharding requires a levelized jax backend "
            f"(got backend={plan.backend.name!r}, levelized={levelized})"
            if plan.mesh is not None else
            f"layout {plan.layout.name!r} requires the levelized executors")
    if not levelized and _needs_ft(plan):
        raise ValueError("fault injection / verified execution require "
                         "the levelized executors")
    if plan.backend.name == "numpy":
        if plan.mesh is not None:       # unreachable (plan validates) --
            raise ValueError("mesh sharding requires a jax backend")
        telemetry.record_dispatch(n_rows, _serial_model(program))
        state = pack_rows(inputs, program.ports, n_rows, program.n_cells,
                          pad_to=1)
        st = np.ascontiguousarray(state.T)
        program.exec_packed(st)
        return unpack_rows(st.T, program.ports, n_rows,
                           names=output_names(program))
    if levelized:
        if _needs_ft(plan):
            return _verified_dispatch(program, inputs, n_rows, plan, None,
                                      _VerifyRun(plan), 0)()
        return _dispatch_levelized(program, inputs, n_rows, plan)()
    comp = compiled(program, plan)
    telemetry.record_dispatch(n_rows, comp.get_serial_model(program))
    ops, a, b, o, n_cells = comp.get_arrays(program)
    state = pack_rows(inputs, program.ports, n_rows, n_cells,
                      pad_to=plan.backend.pad_to)
    if plan.backend.name == "ref":
        final = np.asarray(pim_exec_ref(
            jnp.asarray(state), jnp.asarray(ops), jnp.asarray(a),
            jnp.asarray(b), jnp.asarray(o)))
    else:
        final = np.asarray(pim_exec_padded(
            jnp.asarray(state), jnp.asarray(ops), jnp.asarray(a),
            jnp.asarray(b), jnp.asarray(o), n_cells=n_cells))
    return unpack_rows(final, program.ports, n_rows,
                       names=output_names(program))


def run_program_streaming(program, inputs: Dict[str, np.ndarray],
                          n_rows: int, plan=None, *,
                          backend=None, chunk_rows=None, mesh=None,
                          schedule=None, layout=None,
                          deadline: Optional[float] = None
                          ) -> Dict[str, np.ndarray]:
    """Chunked, pipelined, optionally sharded execution over ``n_rows``.

    Rows are tiled into word-aligned chunks of the plan's chunk size; the
    loop dispatches chunk ``k`` to the device, packs chunk ``k+1`` on the
    host while ``k`` executes (JAX async dispatch), then blocks on ``k``'s
    result -- so host bridging and device execution overlap instead of one
    monolithic pack -> exec -> unpack.  Every chunk (including the ragged
    last one) is padded to the same shape, so the executor compiles once.

    Levelized jax backends only ('ref'/'pallas'); the plan's mesh
    additionally shards each chunk's word axis over devices
    (:func:`row_mesh`).

    ``deadline`` is an absolute ``time.monotonic()`` bound checked before
    dispatch and between chunks (:class:`DeadlineExceeded` on expiry) --
    the serving layer's per-request deadline hook.  A plan carrying a
    fault model / verify policy routes every chunk through the
    detect -> retry -> remap loop (DESIGN.md §12).
    """
    plan = as_plan(plan, backend=backend, chunk_rows=chunk_rows, mesh=mesh,
                   schedule=schedule, layout=layout)
    if not plan.backend.is_jax:
        raise ValueError("streaming requires a levelized jax backend, "
                         f"got {plan.backend.name!r}")
    chunk = plan.effective_chunk_rows
    _check_deadline(deadline)
    vrun = _VerifyRun(plan) if _needs_ft(plan) else None
    if n_rows <= chunk:
        if vrun is None:
            return run_program(program, inputs, n_rows, plan)
        return _verified_dispatch(program, inputs, n_rows, plan, None,
                                  vrun, 0)()
    inputs = {n: np.asarray(v) for n, v in inputs.items()}
    for n, v in inputs.items():
        if len(v) != n_rows:
            raise ValueError(
                f"input {n!r} has {len(v)} rows, expected {n_rows}")
    parts = []
    pending = None
    for start in range(0, n_rows, chunk):
        _check_deadline(deadline)
        rows_k = min(chunk, n_rows - start)
        chunk_in = {n: v[start:start + rows_k] for n, v in inputs.items()}
        if vrun is None:
            fin = _dispatch_levelized(program, chunk_in, rows_k, plan,
                                      pad_rows=chunk)
        else:
            fin = _verified_dispatch(program, chunk_in, rows_k, plan,
                                     chunk, vrun, start)
        if pending is not None:
            parts.append(pending())     # blocks on k-1 while k executes
        pending = fin
    parts.append(pending())
    return {name: np.concatenate([p[name] for p in parts])
            for name in parts[0]}


def dispatch_program(program, inputs: Dict[str, np.ndarray], n_rows: int,
                     plan=None, *, backend=None, mesh=None, schedule=None,
                     layout=None, pad_rows: Optional[int] = None) -> Callable:
    """Asynchronously dispatch one levelized execution; returns a zero-arg
    ``finalize`` that blocks on the device result and unpacks the output
    ports.  The pipelining primitive behind :func:`run_program_streaming`
    and :func:`run_program_groups`: callers overlap host packing of the
    next unit of work with device execution of this one."""
    plan = as_plan(plan, backend=backend, mesh=mesh, schedule=schedule,
                   layout=layout)
    if not plan.backend.is_jax:
        raise ValueError("dispatch requires a levelized jax backend, "
                         f"got {plan.backend.name!r}")
    if _needs_ft(plan):
        return _verified_dispatch(program, inputs, n_rows, plan, pad_rows,
                                  _VerifyRun(plan), 0)
    return _dispatch_levelized(program, inputs, n_rows, plan,
                               pad_rows=pad_rows)


def dispatch_packed(program, n_rows: int, plan=None, *,
                    inputs: Optional[Dict[str, np.ndarray]] = None,
                    in_block: Optional[np.ndarray] = None,
                    in_names: Optional[Tuple[str, ...]] = None,
                    vrun: Optional[_VerifyRun] = None, stage: int = 0,
                    deadline: Optional[float] = None) -> Callable:
    """Dispatch one levelized execution that stays in the packed word
    domain; returns a zero-arg ``finalize`` yielding the packed output
    block (uint32, out-ports' cells stacked in ``output_names`` order,
    rows packed 32 per word along the trailing axis -- rows64 plans keep
    the planes-leading 3-D state shape).

    Feed it either ``inputs`` (row-value dict, packed once on the way in)
    or ``in_block`` + ``in_names`` (a block from a previous packed
    dispatch, cell axis stacking the named in-ports in sorted order) --
    the primitive behind the in-memory reduction trees of ``pim.dot``/
    ``pim.gemv``, where intermediate values never unpack between stages.

    Levelized jax backends only.  A plan carrying a fault model / verify
    policy routes the stage through the packed detect -> retry -> remap
    loop: pass one shared ``vrun`` across a tree's stages (so a remap
    sticks for later levels and a failed stage retries from the last
    verified level, not the leaves) and a distinct ``stage`` ordinal to
    salt each level's transient stream.  ``deadline`` (absolute
    ``time.monotonic()``) is checked before dispatch and between retry
    attempts -- what lets a deep GEMV reduction cancel mid-tree.
    """
    plan = as_plan(plan)
    if not plan.backend.is_jax:
        raise ValueError("packed dispatch requires a levelized jax "
                         f"backend, got {plan.backend.name!r}")
    if (in_block is None) == (inputs is None):
        raise ValueError("pass exactly one of inputs= or in_block=")
    _check_deadline(deadline)
    if in_block is not None:
        if not in_names:
            raise ValueError("in_block requires in_names")
        block = np.ascontiguousarray(np.asarray(in_block, np.uint32))
        if _needs_ft(plan):
            return _verified_dispatch_packed(
                program, n_rows, plan, vrun or _VerifyRun(plan), stage,
                packed_in=block, in_names=in_names, deadline=deadline)
        names = {n: None for n in in_names}
        return _dispatch_levelized(program, names, n_rows, plan,
                                   packed_in=block, packed_out=True)
    if _needs_ft(plan):
        return _verified_dispatch_packed(
            program, n_rows, plan, vrun or _VerifyRun(plan), stage,
            inputs=inputs, deadline=deadline)
    return _dispatch_levelized(program, inputs, n_rows, plan,
                               packed_out=True)


def run_program_groups(groups: Iterable[dict]) -> list:
    """Execute several coalesced program groups back to back with
    cross-group pipelining; returns their output dicts in input order.

    Each group is a dict: ``program``, ``inputs`` (port name -> row
    values), ``n_rows``, plus a ``plan`` (:class:`ExecPlan`; the legacy
    ``backend``/``schedule``/``chunk_rows``/``mesh`` keys still normalize
    into one here, at the boundary).  The loop dispatches group ``k`` (JAX
    async) and packs group ``k+1`` on the host while ``k`` executes -- the
    streaming pipeline generalized across *heterogeneous* programs, which
    is what lets the batched serving runtime keep the device busy across a
    mixed-traffic plan.  Groups larger than the plan's chunk size tile
    into word-aligned fixed-shape chunks inside the same pipeline (so one
    giant group cannot stall its successors' packing).  A numpy-backend
    group is a synchronization point (the oracle is host-synchronous).

    A group may carry a ``deadline`` (absolute ``time.monotonic()``),
    checked before each of its chunks dispatches; a plan with a fault
    model / verify policy runs its group's chunks through the verified
    detect -> retry -> remap loop (one :class:`_VerifyRun` per group, so a
    remapped chunk stays remapped for its retries).
    """
    groups = list(groups)
    parts: list = [[] for _ in groups]
    pending: "collections.deque" = collections.deque()

    def drain(limit: int) -> None:
        while len(pending) > limit:
            gi, fin = pending.popleft()
            parts[gi].append(fin())

    for gi, g in enumerate(groups):
        program, n_rows = g["program"], int(g["n_rows"])
        plan = as_plan(g.get("plan"), backend=g.get("backend"),
                       schedule=g.get("schedule"), layout=g.get("layout"),
                       mesh=g.get("mesh"), chunk_rows=g.get("chunk_rows"))
        deadline = g.get("deadline")
        inputs = {n: np.asarray(v) for n, v in g["inputs"].items()}
        for n, v in inputs.items():
            if len(v) != n_rows:
                raise ValueError(
                    f"group {gi}: input {n!r} has {len(v)} rows, "
                    f"expected {n_rows}")
        if plan.backend.name == "numpy":
            drain(0)
            _check_deadline(deadline)
            parts[gi].append(run_program(program, inputs, n_rows, plan))
            continue
        vrun = _VerifyRun(plan) if _needs_ft(plan) else None
        chunk = plan.effective_chunk_rows
        if n_rows <= chunk:
            _check_deadline(deadline)
            pending.append((gi, _dispatch_levelized(
                program, inputs, n_rows, plan) if vrun is None
                else _verified_dispatch(program, inputs, n_rows, plan,
                                        None, vrun, 0)))
            drain(1)
            continue
        for start in range(0, n_rows, chunk):
            _check_deadline(deadline)
            rows_k = min(chunk, n_rows - start)
            chunk_in = {n: v[start:start + rows_k]
                        for n, v in inputs.items()}
            pending.append((gi, _dispatch_levelized(
                program, chunk_in, rows_k, plan, pad_rows=chunk)
                if vrun is None
                else _verified_dispatch(program, chunk_in, rows_k, plan,
                                        chunk, vrun, start)))
            drain(1)
    drain(0)
    return [ps[0] if len(ps) == 1 else
            {k: np.concatenate([p[k] for p in ps]) for k in ps[0]}
            for ps in parts]
