"""Slot-schedule executors: contiguous-band scan kernels and the
schedule-to-jaxpr straight-line compiler (DESIGN.md §9).

Consumes :class:`~repro.core.gates.LevelSchedule` in ``alloc="slots"`` form,
whose layout contract turns the executor's per-level work from dynamic
gather -> NOR -> scatter into static-offset slices:

  * every level writes one contiguous band (``out[l] == out[l, 0] + lane``),
    so the write side is a single ``dynamic_update_slice`` -- the scatter,
    the op XLA:CPU handles worst and Mosaic cannot lower, is gone;
  * input ports occupy one run at cell 0 (state assembly is a slice update);
  * output-port finals occupy one run (extraction is a slice).

Two emission strategies:

  * **scan** (:func:`pim_exec_ref_slots_fused` / ``_io``): a
    ``lax.scan`` over levels -- reads stay vector gathers, writes are
    band slice updates.  The loop keeps the state buffer in place, which on
    XLA:CPU beats any unrolled form (unrolled full-state updates copy the
    whole state per level); this is the fast CPU path and the default.
  * **static** (:func:`build_static_chain`): the straight-line compiler.
    Levels unroll at trace time into pure dataflow over per-level *band
    values* -- reads are ``lax.slice`` at Python-constant offsets (merged
    into maximal contiguous runs), writes don't exist (a band is an SSA
    value), and no monolithic state array is ever updated.  XLA
    constant-folds the offsets and fuses across levels; compile time is
    bounded by segmenting into fixed-size level chunks, each its own jitted
    function.  This emission is also what the Mosaic-lowerable Pallas
    kernel consumes (``kernels.pim_exec``): zero dynamic indexing of any
    kind, hence hardware-legal.

Bridges here are the butterfly bit-transposes (:func:`pack_values` /
:func:`unpack_values`): a 32x32 bit-matrix transpose in 5 masked
shift/xor steps per word block, replacing the (width, n_words, 32) bit
expansion of the previous in-jit transposes -- ~10x less intermediate
traffic, shared by the dense executors in ``kernels.ref`` too.
"""

from __future__ import annotations

import functools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

# The canonical level-chunk size of the straight-line compiler (bounds
# per-segment jaxpr size, and therefore XLA compile time) lives on
# kernels.plan, where the Backend descriptor reads it too; re-exported
# here as the function default for direct build_static_chain callers.
from .plan import SLOT_SEG_LEVELS

_FULL = 0xFFFFFFFF

# Default scan unroll for the slot level loop (bodies per while-loop trip);
# small unrolls amortize loop overhead without breaking XLA's in-place
# carry updates.
SLOT_UNROLL = 2


# --------------------------------------------------------------------------
# layout-polymorphic state access
# --------------------------------------------------------------------------
#
# Executor state is uint32[n_cells, n_words] under the rows32 layout and
# uint32[planes, n_cells, n_words] under rows64 (kernels.plan.WordLayout):
# the cell axis is always axis -2, the word axis always trailing, and any
# leading plane axis is a pure batch dim the gates vectorize over.  These
# four helpers are the only place executors touch state indexing, which is
# what lets every executor family run both layouts from one body.

def take_cells(st, idx):
    """Gather state rows along the cell axis (axis -2)."""
    return st[idx] if st.ndim == 2 else st[:, idx]


def at_cells(st, idx):
    """``.at`` view addressing the cell axis (for scatter updates)."""
    return st.at[idx] if st.ndim == 2 else st.at[:, idx]


def band_update(st, val, off):
    """Write a contiguous cell band at (traced) offset ``off``."""
    starts = (off, 0) if st.ndim == 2 else (0, off, 0)
    return lax.dynamic_update_slice(st, val, starts)


def band_slice(st, off, k):
    """Read a contiguous cell band of ``k`` rows at (traced) ``off``."""
    if st.ndim == 2:
        return lax.dynamic_slice(st, (off, 0), (k, st.shape[1]))
    return lax.dynamic_slice(st, (0, off, 0),
                             (st.shape[0], k, st.shape[2]))


def plane_shape(planes: int, k: int, n_words: int) -> tuple:
    """Packed-block shape for ``k`` cell rows under a ``planes``-plane
    layout: 2-D under rows32, planes-leading 3-D otherwise (the jnp-side
    twin of ``kernels.plan.WordLayout.state_shape``)."""
    return (k, n_words) if planes == 1 else (planes, k, n_words)


# Row -> (plane, word, bit) coordinate map of the same layouts -- canonical
# in runtime.faults (the fault injectors address physical rows with it;
# re-exported here, next to its block-shape twin, for layout-code callers
# and the coordinate cross-checks in tests).
from ..runtime.faults import word_coords  # noqa: E402,F401


# --------------------------------------------------------------------------
# butterfly bit-transpose bridges (in-jit, ports of <= 32 cells)
# --------------------------------------------------------------------------

def transpose32(x):
    """Bit-transpose 32x32 blocks: ``y[..., i]`` bit ``j`` == ``x[..., j]``
    bit ``i``.  Five butterfly steps of masked shift/xor (Hacker's Delight
    7-3, vectorized over leading axes; the double flip converts HD's
    bit-reversed convention to the straight transpose)."""
    x = x[..., ::-1]
    j = 16
    m = jnp.uint32(0x0000FFFF)
    s = x.shape[:-1]
    while j:
        xr = x.reshape(s + (32 // (2 * j), 2, j))
        lo, hi = xr[..., 0, :], xr[..., 1, :]
        t = (lo ^ (hi >> j)) & m
        x = jnp.stack([lo ^ t, hi ^ (t << j)], axis=-2).reshape(s + (32,))
        j >>= 1
        if j:
            m = m ^ jnp.uint32(m << j)
    return x[..., ::-1]


def pack_values(in_vals, widths: Sequence[int], planes: int = 1):
    """Row-major -> column-major bit transpose: per-row port values
    (uint32[n_ports, n_words*32*planes]) to stacked port cell rows --
    (uint32[sum(widths), n_words]) under rows32 (``planes=1``; bit w of
    row word i is row 32*i+w) or (uint32[planes, sum(widths), n_words])
    under the paired layout (plane h of word i covers rows
    ``32*planes*i + 32*h + w``, the little-endian uint64 halves)."""
    n32 = in_vals.shape[1] // 32          # 32-row groups (uint32 words)
    n_words = n32 // planes
    rows = []
    for p, wp in enumerate(widths):
        t = transpose32(in_vals[p].reshape(n32, 32)).T        # (32, n32)
        if planes == 1:
            rows.append(t[:wp])
        else:
            # t[c, planes*i + h] is plane h of logical word i
            t = jnp.moveaxis(t.reshape(32, n_words, planes), -1, 0)
            rows.append(t[:, :wp])
    if rows:
        return jnp.concatenate(rows, axis=0 if planes == 1 else 1)
    return jnp.zeros(plane_shape(planes, 0, n_words), jnp.uint32)


def unpack_values(sub, widths: Sequence[int], planes: int = 1):
    """Inverse of :func:`pack_values`: stacked port cell rows (2-D rows32
    or planes-leading 3-D) to per-row values
    (uint32[n_ports, n_words*32*planes])."""
    n_words = sub.shape[-1]
    outs = []
    off = 0
    for wp in widths:
        blk = sub[..., off:off + wp, :]
        off += wp
        if wp < 32:
            pad_shape = sub.shape[:-2] + (32 - wp, n_words)
            blk = jnp.concatenate(
                [blk, jnp.zeros(pad_shape, jnp.uint32)], axis=-2)
        if planes > 1:                    # (planes, 32, n_words) -> (32, n32)
            blk = jnp.moveaxis(blk, 0, -1).reshape(32, n_words * planes)
        outs.append(transpose32(blk.T).reshape(-1))
    return jnp.stack(outs)


# --------------------------------------------------------------------------
# run helpers
# --------------------------------------------------------------------------

def as_run(idx) -> Optional[int]:
    """Start of the single contiguous ascending run ``idx`` forms, or None.
    Slot schedules guarantee runs for stacked input cells and output-port
    finals; detection keeps the executors correct for any schedule."""
    idx = np.asarray(idx)
    if idx.size == 0:
        return 0
    start = int(idx[0])
    if np.array_equal(idx, np.arange(start, start + idx.size)):
        return start
    return None


# --------------------------------------------------------------------------
# scan emission: the fast CPU executors
# --------------------------------------------------------------------------

def _slot_levels(st, la, lb, lo, unroll):
    """Level loop over a slot schedule: per level one vectorized gather of
    both operand sides (stacked into a single (2*width,) index row -- one
    gather op instead of two) and one contiguous band write
    (``dynamic_update_slice`` at ``lo[l, 0]``) -- scatter-free.  Any
    leading plane axis of ``st`` (the rows64 layout) rides along as a
    batch dim; the schedule operands are layout-invariant."""
    if la.shape[0] == 0:
        return st
    W = la.shape[1]
    lab = jnp.concatenate([la, lb], axis=1)
    off = lo[:, 0]

    def body(s, idx):
        ab, o = idx
        g = take_cells(s, ab)
        return band_update(s, ~(g[..., :W, :] | g[..., W:, :]), o), None

    st, _ = lax.scan(body, st, (lab, off), unroll=unroll)
    return st


def _assemble_slots(packed, in_idx, n_words, *, n_cells, one_cell, in_base,
                    planes=1):
    """Zero state + input rows (slice update when the input cells form a
    run, else scatter) + the folded INIT1 constant row."""
    st = jnp.zeros(plane_shape(planes, n_cells, n_words), jnp.uint32)
    if packed.shape[-2]:
        if in_base is not None:
            st = band_update(st, packed, in_base)
        else:
            st = at_cells(st, in_idx).set(packed, mode="promise_in_bounds")
    if one_cell is not None:
        st = at_cells(st, one_cell).set(jnp.uint32(_FULL))
    return st


def _extract(st, out_idx, k_out, out_base):
    return (band_slice(st, out_base, k_out)
            if out_base is not None else take_cells(st, out_idx))


@functools.partial(jax.jit, static_argnames=(
    "n_cells", "one_cell", "in_widths", "out_widths", "in_base", "out_base",
    "unroll", "planes"))
def pim_exec_ref_slots_fused(in_vals, in_idx, la, lb, lo, out_idx, *,
                             n_cells, one_cell, in_widths, out_widths,
                             in_base=None, out_base=None,
                             unroll=SLOT_UNROLL, planes=1):
    """Fused slot executor (ports of <= 32 cells): butterfly transposes,
    state assembly, the scan level loop and the output transpose in one XLA
    executable; only (n_ports, n_rows) uint32 cross the boundary.  Shares
    the 6-array levelized signature, so the shard_map plumbing in
    ``kernels.ops`` applies unchanged.  ``planes`` selects the word layout
    (1 = rows32, 2 = the paired rows64 state)."""
    st = _assemble_slots(pack_values(in_vals, in_widths, planes), in_idx,
                         in_vals.shape[1] // (32 * planes),
                         n_cells=n_cells, one_cell=one_cell, in_base=in_base,
                         planes=planes)
    st = _slot_levels(st, la, lb, lo, unroll)
    return unpack_values(_extract(st, out_idx, sum(out_widths), out_base),
                         out_widths, planes)


@functools.partial(jax.jit, static_argnames=(
    "n_cells", "one_cell", "k_out", "in_base", "out_base", "unroll"))
def pim_exec_ref_slots_io(in_rows, in_idx, la, lb, lo, out_idx, *,
                          n_cells, one_cell, k_out,
                          in_base=None, out_base=None, unroll=SLOT_UNROLL):
    """Slot executor over pre-packed port rows (arbitrary port widths):
    ships in uint32[k_in, n_words] (or the planes-leading rows64 form --
    the layout is inferred from the input rank), returns the output port
    rows in the same layout."""
    st = _assemble_slots(in_rows, in_idx, in_rows.shape[-1],
                         n_cells=n_cells, one_cell=one_cell, in_base=in_base,
                         planes=1 if in_rows.ndim == 2 else in_rows.shape[0])
    st = _slot_levels(st, la, lb, lo, unroll)
    return _extract(st, out_idx, k_out, out_base)


# --------------------------------------------------------------------------
# static emission: the schedule-to-jaxpr straight-line compiler
# --------------------------------------------------------------------------

Source = Tuple[object, int]          # ("i", init cell) or (row, lane)


def static_plan(sched):
    """Resolve every read of a slot schedule to its defining band at
    compile time: returns ``(reads, out_srcs, n_init)`` where ``reads[l]``
    is the pair of per-lane source lists of level ``l``, ``out_srcs`` maps
    each port to its per-cell sources, and ``n_init`` is the size of the
    initial (non-slot) region.  A source is
    ``("i", cell)`` for the initial region or ``(row, lane)`` for the
    band written by a dense row -- slot reuse is dissolved here, exactly
    like the value numbering that built the schedule."""
    if sched.alloc != "slots":
        raise ValueError("static emission requires a slot schedule "
                         f"(got alloc={sched.alloc!r})")
    D = sched.n_levels
    n_init = int(sched.out[:, 0].min()) if D else sched.n_cells
    owner: Dict[int, Source] = {}

    def src(c) -> Source:
        c = int(c)
        return owner.get(c, ("i", c))

    reads: List[Tuple[List[Source], List[Source]]] = []
    for l in range(D):
        w = int(sched.level_width[l])
        reads.append(([src(c) for c in sched.a[l, :w]],
                      [src(c) for c in sched.b[l, :w]]))
        off = int(sched.out[l, 0])
        for k in range(w):
            owner[off + k] = (l, k)
    out_srcs = {name: [src(c) for c in cells]
                for name, cells in sched.ports.items()}
    return reads, out_srcs, n_init


def read_concat(init_block, bands, srcs: List[Source]):
    """Gather the source rows as a concatenation of static slices along the
    cell axis, merging consecutive lanes of the same source array into one
    slice.  Rank-polymorphic: a leading plane axis (rows64) passes
    through untouched."""
    parts = []
    i = 0
    while i < len(srcs):
        kind, pos = srcs[i]
        j = i + 1
        while (j < len(srcs) and srcs[j][0] == kind
               and srcs[j][1] == srcs[j - 1][1] + 1):
            j += 1
        arr = init_block if kind == "i" else bands[kind]
        parts.append(lax.slice_in_dim(arr, pos, srcs[j - 1][1] + 1, axis=-2))
        i = j
    if not parts:
        shape = init_block.shape[:-2] + (0, init_block.shape[-1])
        return jnp.zeros(shape, jnp.uint32)
    return parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=-2)


def emit_levels(reads, lo_row: int, hi_row: int, init_block,
                bands: Dict[int, object]) -> Dict[int, object]:
    """Trace levels ``[lo_row, hi_row)`` as straight-line dataflow: each
    level's band becomes one SSA value ``~(A | B)`` with A/B read by static
    slices.  Shared by the ref segments and the Pallas static kernel."""
    bands = dict(bands)
    for l in range(lo_row, hi_row):
        ra, rb = reads[l]
        bands[l] = ~(read_concat(init_block, bands, ra)
                     | read_concat(init_block, bands, rb))
    return bands


def _band_liveness(reads, out_srcs, D: int):
    """last_row[r] = last row (or D for outputs) whose reads touch band r."""
    last: Dict[int, int] = {}
    for l in range(D):
        for side in reads[l]:
            for kind, _ in side:
                if kind != "i":
                    last[kind] = l
    for srcs in out_srcs.values():
        for kind, _ in srcs:
            if kind != "i":
                last[kind] = D
    return last


def _init_tail(n_init: int, k_in: int, one_cell: Optional[int], n_words,
               planes: int = 1):
    """Constant rows of the initial region past the packed inputs: zeros,
    with the folded INIT1 row at ``one_cell``.  Built from broadcasts so
    the Pallas kernel stays constant-and-elementwise only."""
    n_tail = n_init - k_in
    if n_tail <= 0:
        return None
    shape = plane_shape(planes, n_tail, n_words)
    if one_cell is None or not (k_in <= one_cell < n_init):
        return jnp.zeros(shape, jnp.uint32)
    rows = jnp.arange(n_tail, dtype=jnp.int32)[:, None]
    tail = jnp.where(rows == (one_cell - k_in),
                     jnp.uint32(_FULL), jnp.uint32(0)) * \
        jnp.ones((1, n_words), jnp.uint32)
    return tail if planes == 1 else jnp.broadcast_to(tail, shape)


def build_init_block(packed, n_init: int, one_cell: Optional[int]):
    """Initial region from the packed input rows: inputs occupy the leading
    run (slot layout), constants and uninitialized cells follow.  Falls
    back to a scatter only when the inputs are not the leading run."""
    planes = 1 if packed.ndim == 2 else packed.shape[0]
    k_in = packed.shape[-2]
    n_words = packed.shape[-1]
    tail = _init_tail(n_init, k_in, one_cell, n_words, planes)
    if tail is None:
        return packed[..., :n_init, :]
    return jnp.concatenate([packed, tail], axis=-2) if k_in else tail


def build_static_chain(sched, in_widths, out_widths, out_names,
                       in_cells: Sequence[int],
                       seg_levels: int = SLOT_SEG_LEVELS,
                       fused: bool = True, planes: int = 1):
    """Compile a slot schedule into a chain of jitted straight-line
    segments (the bounded-compile-time form of the static emission).

    Returns ``run(in_arr) -> out`` where ``in_arr`` is the fused row-major
    value block (uint32[n_ports, n_words*32*planes]) when ``fused`` else
    pre-packed port rows (uint32[k_in, n_words], planes-leading under
    rows64); ``out`` mirrors the corresponding slot executor.
    ``in_cells`` is the stacked cell list of the ports the caller actually
    provides (a subset of the schedule's input ports is fine; missing
    ports stay zero).  Segment boundaries pass only the live bands (a dict
    pytree of (width, n_words) values) -- no monolithic state array
    exists at any point, so XLA never copies one.  ``planes`` is the word
    layout (kernels.plan.WordLayout.planes); bands simply grow a leading
    batch axis.
    """
    reads, out_srcs, n_init = static_plan(sched)
    D = sched.n_levels
    last = _band_liveness(reads, out_srcs, D)
    one_cell = None if sched.one_cell is None else int(sched.one_cell)
    stacked_out = [s for name in out_names for s in out_srcs[name]]
    in_cells = list(in_cells)
    leading_run = as_run(in_cells) == 0   # inputs are the leading run
    in_idx_arr = None
    if not leading_run:               # partial/aliased inputs: scatter
        in_idx_arr = jnp.asarray(np.asarray(in_cells, np.int32))

    def sched_words(in_arr):
        return in_arr.shape[1] // (32 * planes) if fused \
            else in_arr.shape[-1]

    def assemble(in_arr):
        packed = pack_values(in_arr, in_widths, planes) if fused else in_arr
        if leading_run:
            return build_init_block(packed, n_init, one_cell)
        init = jnp.zeros(plane_shape(planes, n_init, sched_words(in_arr)),
                         jnp.uint32)
        if packed.shape[-2]:
            init = at_cells(init, in_idx_arr).set(
                packed, mode="promise_in_bounds")
        if one_cell is not None:
            init = at_cells(init, one_cell).set(jnp.uint32(_FULL))
        return init

    bounds = list(range(0, D, max(int(seg_levels), 1))) + [D]

    def make_seg(lo_row, hi_row):
        keep = sorted(r for r in range(hi_row)
                      if r in last and last[r] >= hi_row)

        def seg(init_block, bands):
            bands = emit_levels(reads, lo_row, hi_row, init_block, bands)
            return {r: bands[r] for r in keep}

        return jax.jit(seg)

    segs = [make_seg(lo_row, hi_row)
            for lo_row, hi_row in zip(bounds, bounds[1:]) if hi_row > lo_row]

    @jax.jit
    def post(init_block, bands):
        sub = read_concat(init_block, bands, stacked_out)
        return unpack_values(sub, out_widths, planes) if fused else sub

    pre = jax.jit(assemble)

    def run(in_arr):
        init_block = pre(in_arr)
        bands: Dict[int, object] = {}
        for seg in segs:
            bands = seg(init_block, bands)
        return post(init_block, bands)

    return run
