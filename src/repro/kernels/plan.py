"""The compile->execute pipeline's configuration IR (DESIGN.md §11).

Execution used to be configured by loose ``backend=``/``schedule=`` strings
threaded ad hoc through five modules, with the packed row axis hard-coded
as uint32 words.  This module makes those choices first-class compile-time
objects:

* :class:`WordLayout` -- how rows pack into the trailing word axis of the
  executor state.  ``rows32`` is the classic layout (32 rows per uint32
  word, state ``uint32[n_cells, n_words]``).  ``rows64`` packs 64 rows per
  *word pair*: the state grows a leading plane axis of 2
  (``uint32[2, n_cells, n_words]``) whose planes hold rows ``64i..64i+31``
  and ``64i+32..64i+63`` of logical word ``i`` -- the little-endian halves
  of a uint64 word.  The trailing word axis *halves* for every executor
  while the schedule's index operands stay untouched (gates vectorize over
  the plane axis like a batch dim), which is the uint64 packing the ROADMAP
  wanted without ever enabling ``jax_enable_x64``.
* :class:`Backend` -- the executor family plus its tunables.  Per-backend
  knobs that used to be module globals (``SLOT_WIDTH``, ``SLOT_SEG_LEVELS``,
  chunk rows, tile padding) live on the descriptor, so hardware retuning is
  a new ``Backend`` value, not an edit to five call sites.
* :class:`ExecPlan` -- one immutable object capturing everything about *how*
  a program executes: schedule kind, backend, word layout, device mesh and
  streaming chunk size.  Every executor entry point consumes a plan;
  ``plan.key`` is the serving planner's group key (requests differing in
  any execution dimension never coalesce) and ``plan.compile_key`` is the
  compiled-program cache's per-plan identity (the LRU and the pin
  refcounts key on it).

:func:`as_plan` is the boundary normalizer: public entry points still
accept the convenience strings and convert them to a plan exactly once, so
no loose string ever travels further than its entry point.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from ..runtime.faults import FaultModel, VerifyPolicy

# Lane-dim words per Pallas block (multiple of 128).  Defined here (the
# config layer) so plan validation and padding logic need no kernel import;
# ``kernels.pim_exec`` re-exports it for compatibility.
TILE_W = 256

# Schedule compilation modes for the levelized jax backends:
#   'slots'        -- contiguous-slot schedule + scan executors (DESIGN.md
#                     §9): band slice writes instead of scatters.  The fast
#                     path on CPU and the default.
#   'slots-static' -- slot schedule + straight-line static-slice executors
#                     (segmented schedule-to-jaxpr chain on 'ref', the
#                     Mosaic-lowerable unrolled kernel on 'pallas').
#   'dense'        -- the dense index-matrix executors
#                     (gather -> NOR -> scatter per level).
DEFAULT_SCHEDULE = "slots"
SCHEDULES = ("slots", "slots-static", "dense")


@dataclasses.dataclass(frozen=True)
class WordLayout:
    """How per-row bits pack into the executor's word state.

    ``planes`` is the leading batch axis of the state: 1 keeps the classic
    2-D ``uint32[n_cells, n_words]`` state, 2 is the paired-uint32 layout
    ``uint32[2, n_cells, n_words]`` where word ``i``'s planes are the low
    and high uint32 halves of one 64-row word.  ``rows_per_word`` rows map
    onto each trailing-axis position, so chunking, padding and sharding all
    align at that granularity.
    """
    name: str
    planes: int

    @property
    def rows_per_word(self) -> int:
        return 32 * self.planes

    def n_words(self, n_rows: int, pad_to: int = 1) -> int:
        """Trailing word-axis length covering ``n_rows``, padded up to a
        multiple of ``pad_to`` (and at least ``pad_to``)."""
        rpw = self.rows_per_word
        words = (n_rows + rpw - 1) // rpw
        return max((words + pad_to - 1) // pad_to * pad_to, pad_to)

    def state_shape(self, n_cells: int, n_words: int) -> tuple:
        """Executor state shape: 2-D for one plane, planes-leading 3-D."""
        if self.planes == 1:
            return (n_cells, n_words)
        return (self.planes, n_cells, n_words)

    def __str__(self) -> str:
        return self.name


ROWS32 = WordLayout("rows32", 1)
ROWS64 = WordLayout("rows64", 2)
LAYOUTS = {"rows32": ROWS32, "rows64": ROWS64}
DEFAULT_LAYOUT = ROWS32


# Canonical tunable defaults.  These seed the Backend descriptors below
# (the live values every plan reads) and the executor modules' own
# function defaults (kernels.slots imports SLOT_SEG_LEVELS from here), so
# a retune edits exactly one number.
#
# SLOT_WIDTH: W of the contiguous-slot allocator -- narrower slots mean
# more scan iterations but smaller carried state; W=6 won the XLA:CPU
# sweep (BENCH_3).  SLOT_SEG_LEVELS: level-chunk size of the straight-line
# static compiler (bounds per-segment jaxpr size).  LEVEL_MAX_WIDTH:
# dense-schedule width cap (levels wider than this split into several
# rows -- the PR-1 sweet spot).  DEFAULT_CHUNK_ROWS: streaming chunk
# (rows) -- big enough to amortize per-chunk dispatch, small enough that
# two in-flight chunks stay cache-friendly.
SLOT_WIDTH = 6
SLOT_SEG_LEVELS = 128
LEVEL_MAX_WIDTH = 8
DEFAULT_CHUNK_ROWS = 1 << 18


@dataclasses.dataclass(frozen=True)
class Backend:
    """Executor family descriptor with its per-backend tunables (see the
    canonical defaults above for what each knob does).  ``pad_to`` is the
    trailing word-axis alignment the executors require (Pallas tiles at
    TILE_W; jnp needs none).  Hardware retuning is a new Backend value,
    not an edit to call sites."""
    name: str
    pad_to: int = 1
    slot_width: int = SLOT_WIDTH
    seg_levels: int = SLOT_SEG_LEVELS
    chunk_rows: int = DEFAULT_CHUNK_ROWS
    level_max_width: int = LEVEL_MAX_WIDTH

    @property
    def is_jax(self) -> bool:
        return self.name in ("ref", "pallas")

    def __str__(self) -> str:
        return self.name


BACKENDS = {
    "ref": Backend("ref"),
    "pallas": Backend("pallas", pad_to=TILE_W),
    # the cycle-accurate numpy oracle: levelized schedules/layouts don't
    # apply; present so one descriptor type covers every entry point
    "numpy": Backend("numpy"),
}


@dataclasses.dataclass(frozen=True)
class ExecPlan:
    """One immutable description of *how* a gate program executes: the
    schedule compilation mode, the backend descriptor, the packed word
    layout, the device mesh for row sharding, and the streaming chunk
    size.  Built once at an entry point (see :func:`as_plan`) and consumed
    by every layer below -- the dispatcher, the compiled-program cache, the
    serving planner's group keys and the benchmark harness all read the
    same object instead of re-deciding from loose strings."""
    backend: Backend = BACKENDS["ref"]
    schedule: str = DEFAULT_SCHEDULE
    layout: WordLayout = ROWS32
    mesh: Optional[object] = None        # jax.sharding.Mesh or None
    chunk_rows: Optional[int] = None     # None -> backend.chunk_rows
    # Fault-tolerance layer (DESIGN.md §12): a seeded substrate fault model
    # to inject (None = perfect substrate) and the verified-execution
    # policy (None = no checking).  They ride the plan because they are
    # execution semantics: two requests differing in either must never
    # coalesce into one packed state (plan.key separates them), while the
    # compiled artifacts are identical (compile_key excludes them).
    faults: Optional[FaultModel] = None
    verify: Optional[VerifyPolicy] = None

    def __post_init__(self):
        if self.schedule not in SCHEDULES:
            raise ValueError(f"unknown schedule {self.schedule!r} "
                             f"(expected one of {SCHEDULES})")
        if self.layout.planes > 1 and not self.backend.is_jax:
            raise ValueError(
                f"layout {self.layout.name!r} requires a levelized jax "
                f"backend (got backend={self.backend.name!r})")
        if self.mesh is not None and not self.backend.is_jax:
            raise ValueError(
                "mesh sharding requires a levelized jax backend "
                f"(got backend={self.backend.name!r})")
        if (self.faults is not None or self.verify is not None) \
                and not self.backend.is_jax:
            raise ValueError(
                "fault injection / verified execution require a levelized "
                "jax backend (the numpy oracle is the fault-free "
                f"reference; got backend={self.backend.name!r})")

    # ------------------------------------------------------------- identity

    @property
    def effective_chunk_rows(self) -> int:
        """Streaming chunk size, word-aligned for this layout."""
        rpw = self.layout.rows_per_word
        chunk = int(self.chunk_rows if self.chunk_rows is not None
                    else self.backend.chunk_rows)
        return max(rpw, (chunk + rpw - 1) // rpw * rpw)

    @property
    def key(self) -> tuple:
        """Full execution identity -- the serving planner's group key.
        Two requests whose plans differ in *any* field (backend incl.
        every tunable, schedule, layout, chunking, mesh) must never
        coalesce into one packed state; this tuple is what makes that
        exact (the whole Backend descriptor is flattened in, so a custom
        retuned Backend separates too)."""
        return (dataclasses.astuple(self.backend), self.schedule,
                self.layout.name, self.effective_chunk_rows,
                None if self.mesh is None else id(self.mesh),
                None if self.faults is None
                else dataclasses.astuple(self.faults),
                None if self.verify is None
                else dataclasses.astuple(self.verify))

    @property
    def compile_key(self) -> tuple:
        """The plan fields that determine the cache entry's compiled
        artifact *universe* (levelized schedules, device index buffers,
        static chains) -- the compiled-program LRU's per-plan key.  Only
        the allocator/segmentation tunables belong here: backend *name*,
        word *layout* and *schedule kind* are all excluded on purpose --
        'ref' and 'pallas' consume identical schedule arrays, the
        schedule operands are layout-invariant, and one entry lazily
        holds every schedule kind's artifacts (``_Compiled`` sub-keys by
        alloc and by ``planes``), so a program served under slots,
        slots-static and dense shares one entry, one levelize per alloc,
        and one pin.  Keying on any of those would duplicate entries and
        device buffers for no artifact change.  ``faults``/``verify`` are
        likewise excluded: fault injection and result checking wrap the
        executor at dispatch time and share its compiled artifacts
        bit-for-bit."""
        return (self.backend.slot_width, self.backend.level_max_width,
                self.backend.seg_levels)

    # ------------------------------------------------------------- variants

    def with_backend(self, name: str) -> "ExecPlan":
        """Same plan on a different backend family (tunables reset to the
        target backend's defaults)."""
        return dataclasses.replace(self, backend=_backend_of(name))


def _backend_of(backend) -> Backend:
    if isinstance(backend, Backend):
        return backend
    try:
        return BACKENDS[backend]
    except KeyError:
        raise ValueError(f"unknown backend {backend!r} "
                         f"(expected one of {sorted(BACKENDS)})") from None


def _layout_of(layout) -> WordLayout:
    if isinstance(layout, WordLayout):
        return layout
    try:
        return LAYOUTS[layout]
    except KeyError:
        raise ValueError(f"unknown layout {layout!r} "
                         f"(expected one of {sorted(LAYOUTS)})") from None


def _verify_of(verify) -> Optional[VerifyPolicy]:
    """Normalize the ``verify=`` convenience surface: True means "check
    with the default policy", a VerifyPolicy passes through, False/None
    disable."""
    if verify is None or verify is False:
        return None
    if verify is True:
        return VerifyPolicy()
    if isinstance(verify, VerifyPolicy):
        return verify
    raise TypeError(f"verify must be a bool or VerifyPolicy, "
                    f"got {type(verify).__name__}")


def _faults_of(faults) -> Optional[FaultModel]:
    if faults is None or isinstance(faults, FaultModel):
        return faults
    raise TypeError(f"faults must be a FaultModel or None, "
                    f"got {type(faults).__name__}")


def as_plan(plan=None, *, backend=None, schedule=None, layout=None,
            mesh=None, chunk_rows=None, faults=None, verify=None,
            default_backend: str = "ref") -> ExecPlan:
    """Normalize entry-point arguments into an :class:`ExecPlan`.

    ``plan`` may already be an ExecPlan (returned as-is when no override is
    given, else rebuilt with the overrides), a backend name string (the
    historical positional-``backend`` convention), or None.  The keyword
    strings are the public convenience surface; they are converted here,
    exactly once, at the boundary -- nothing below an entry point ever
    sees a loose string again.  ``faults=`` takes a
    :class:`~repro.runtime.faults.FaultModel`; ``verify=`` takes True (the
    default :class:`~repro.runtime.faults.VerifyPolicy`) or a policy.
    """
    if isinstance(plan, ExecPlan):
        if backend is None and schedule is None and layout is None \
                and mesh is None and chunk_rows is None \
                and faults is None and verify is None:
            return plan
        return dataclasses.replace(
            plan,
            backend=plan.backend if backend is None else _backend_of(backend),
            schedule=plan.schedule if schedule is None else schedule,
            layout=plan.layout if layout is None else _layout_of(layout),
            mesh=plan.mesh if mesh is None else mesh,
            chunk_rows=plan.chunk_rows if chunk_rows is None else chunk_rows,
            faults=plan.faults if faults is None else _faults_of(faults),
            verify=plan.verify if verify is None else _verify_of(verify))
    if isinstance(plan, str):            # run_program(p, ins, n, "ref")
        if backend is not None and backend != plan:
            raise ValueError(
                f"conflicting backends: positional {plan!r} vs "
                f"keyword {backend!r}")
        backend = plan
    elif plan is not None:
        raise TypeError(
            f"plan must be an ExecPlan, a backend name or None, "
            f"got {type(plan).__name__}")
    return ExecPlan(
        backend=_backend_of(default_backend if backend is None else backend),
        schedule=DEFAULT_SCHEDULE if schedule is None else schedule,
        layout=_layout_of(DEFAULT_LAYOUT if layout is None else layout),
        mesh=mesh, chunk_rows=chunk_rows,
        faults=_faults_of(faults), verify=_verify_of(verify))


#: The default plan: ref backend, slot schedule, rows32 layout.  The pin
#: API and ``is_compiled`` use it when callers don't name a plan.
DEFAULT_PLAN = ExecPlan()


# --------------------------------------------------------------------------
# tuned defaults (runtime.tune, DESIGN.md §16)
# --------------------------------------------------------------------------
#
# The autotuner sweeps Backend tunables + schedule choice per (program
# family, layout, backend) on the *current* device target and registers
# winners here.  ``apply_tuned`` overlays them onto a plan at ufunc
# resolution time -- but only onto fields still at their hand defaults, so
# an explicit user choice (schedule=, a custom Backend, chunk_rows=)
# always wins over the tuner.

#: (family, layout_name, backend_name) -> override dict.  Families are
#: "op:param" strings ("add:16", "fp_add:fp16"); override keys: schedule,
#: slot_width, seg_levels, level_max_width, chunk_rows.
_tuned: dict = {}

#: Backend fields the tuner may override.
TUNABLE_FIELDS = ("slot_width", "seg_levels", "level_max_width",
                  "chunk_rows")


def register_tuned(family: str, layout: str, backend: str,
                   overrides: dict) -> None:
    """Record tuned defaults for one (family, layout, backend) slot.
    Unknown keys are rejected loudly -- a corrupt tuned.json should fail
    install, not silently mistune."""
    bad = set(overrides) - set(TUNABLE_FIELDS) - {"schedule"}
    if bad:
        raise ValueError(f"unknown tuned override keys {sorted(bad)}")
    if "schedule" in overrides and overrides["schedule"] not in SCHEDULES:
        raise ValueError(f"unknown tuned schedule "
                         f"{overrides['schedule']!r}")
    _tuned[(family, layout, backend)] = dict(overrides)


def clear_tuned() -> None:
    _tuned.clear()


def tuned_overrides(family: str, layout: str, backend: str
                    ) -> Optional[dict]:
    return _tuned.get((family, layout, backend))


def apply_tuned(plan: ExecPlan, family: Optional[str]) -> ExecPlan:
    """Overlay registered tuned defaults for ``family`` onto ``plan``.

    Conservative by construction: each override lands only when the
    corresponding plan field still holds the hand default (the stock
    ``BACKENDS`` descriptor value, ``DEFAULT_SCHEDULE``, unset
    ``chunk_rows``), so anything the caller chose explicitly -- a custom
    Backend, ``schedule=``, ``chunk_rows=`` -- is never overridden."""
    if family is None or not _tuned:
        return plan
    ov = _tuned.get((family, plan.layout.name, plan.backend.name))
    if not ov:
        return plan
    stock = BACKENDS.get(plan.backend.name)
    if stock is None:
        return plan
    bk_changes = {}
    for f in TUNABLE_FIELDS:
        if f in ov and getattr(plan.backend, f) == getattr(stock, f):
            bk_changes[f] = int(ov[f])
    changes = {}
    if bk_changes:
        changes["backend"] = dataclasses.replace(plan.backend, **bk_changes)
    if "schedule" in ov and plan.schedule == DEFAULT_SCHEDULE:
        changes["schedule"] = ov["schedule"]
    # plan-level chunk_rows (the ufunc frontend always populates it from
    # its config default) only yields when still at the hand default
    if "chunk_rows" in ov and plan.chunk_rows in (None, DEFAULT_CHUNK_ROWS):
        changes["chunk_rows"] = int(ov["chunk_rows"])
    return dataclasses.replace(plan, **changes) if changes else plan
