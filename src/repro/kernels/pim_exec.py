"""Pallas TPU kernel: PIM gate-program executor.

TPU adaptation of the paper's core insight (DESIGN.md §2): a PIM column of r
row-bits is a dense bitvector, and an arithmetic algorithm is a straight-line
NOR program over columns.  Executing the *entire program* while a row-tile's
cells are resident in VMEM pays HBM traffic once per tile instead of once per
gate, lifting arithmetic intensity from ~1 bit-op/byte to ~program-length
bit-ops/byte -- the memory-wall argument of the paper, restated for the
TPU memory hierarchy (HBM -> VMEM -> VREG).

Layout: ``state[cell, word]`` (uint32), 32 rows packed per word along the
lane dimension; one grid step owns a ``(n_cells, TILE_W)`` VMEM block.  The
lowered program (ops/a/b/out int32 arrays, ops in {INIT0=0, INIT1=1, NOT=2,
NOR=3}) arrives via scalar prefetch and drives a ``fori_loop``; NOT is NOR
with b==a, so the compute is a single branchless select per gate.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

TILE_W = 256          # lane-dim words per block (multiple of 128)
_FULL = 0xFFFFFFFF


def _pim_kernel(ops_ref, a_ref, b_ref, o_ref, state_ref, out_ref):
    # bring the tile into the output buffer once; all gates run in-place
    out_ref[...] = state_ref[...]
    n = ops_ref.shape[0]

    def body(i, carry):
        op = ops_ref[i]
        av = pl.load(out_ref, (pl.ds(a_ref[i], 1), slice(None)))
        bv = pl.load(out_ref, (pl.ds(b_ref[i], 1), slice(None)))
        nor = ~(av | bv)                      # NOT == NOR with b == a
        init = jnp.where(op == 1, jnp.uint32(_FULL), jnp.uint32(0))
        res = jnp.where(op >= 2, nor, jnp.broadcast_to(init, nor.shape))
        pl.store(out_ref, (pl.ds(o_ref[i], 1), slice(None)), res)
        return carry

    jax.lax.fori_loop(0, n, body, 0)


@functools.partial(jax.jit,
                   static_argnames=("n_cells", "interpret"))
def pim_exec_padded(state, ops, a, b, o, *, n_cells, interpret=True):
    """Run a lowered NOR program over ``state`` (uint32[n_cells, n_words]),
    n_words a multiple of TILE_W.  Returns the final state."""
    n_words = state.shape[1]
    assert state.shape[0] == n_cells and n_words % TILE_W == 0
    grid = (n_words // TILE_W,)
    return pl.pallas_call(
        _pim_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=grid,
            in_specs=[pl.BlockSpec((n_cells, TILE_W), lambda i, *_: (0, i))],
            out_specs=pl.BlockSpec((n_cells, TILE_W), lambda i, *_: (0, i)),
        ),
        out_shape=jax.ShapeDtypeStruct(state.shape, jnp.uint32),
        interpret=interpret,
    )(ops, a, b, o, state)
