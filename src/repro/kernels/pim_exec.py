"""Pallas TPU kernels: PIM gate-program executors.

TPU adaptation of the paper's core insight (DESIGN.md §2): a PIM column of r
row-bits is a dense bitvector, and an arithmetic algorithm is a straight-line
NOR program over columns.  Executing the *entire program* while a row-tile's
cells are resident in VMEM pays HBM traffic once per tile instead of once per
gate, lifting arithmetic intensity from ~1 bit-op/byte to ~program-length
bit-ops/byte -- the memory-wall argument of the paper, restated for the
TPU memory hierarchy (HBM -> VMEM -> VREG).

Layout: ``state[cell, word]`` (uint32), 32 rows packed per word along the
lane dimension; one grid step owns a ``(n_cells, TILE_W)`` VMEM block.

Two executors (DESIGN.md §5):

  * :func:`pim_exec_padded` -- gate-serial.  The lowered program (ops/a/b/out
    int32 arrays, ops in {INIT0=0, INIT1=1, NOT=2, NOR=3}) arrives via scalar
    prefetch and drives a ``fori_loop``; NOT is NOR with b==a, so the compute
    is a single branchless select per gate.  One dynamic row slice per gate:
    this lowers on real TPU hardware today.
  * :func:`pim_exec_level_padded` -- levelized.  The LevelSchedule's dense
    (n_levels, width) index matrices drive a ``fori_loop`` over *levels*;
    each iteration gathers the level's operand rows, NORs them as one
    (width, TILE_W) block and scatters the results.  The gather/scatter use
    vector indices, which Mosaic does not lower for uint32 row gathers yet,
    so this path requires ``interpret=True`` (the mode every CPU test and
    benchmark here runs) -- on hardware, fall back to the gate-serial kernel
    or precompile per-level static slices.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

TILE_W = 256          # lane-dim words per block (multiple of 128)
_FULL = 0xFFFFFFFF


def _check_state_shape(where: str, state, n_cells: int) -> None:
    """Trace-time shape validation.  Explicit raises, not ``assert``: these
    guard grid construction and block specs, and must survive ``python -O``
    (asserts are stripped there, turning shape bugs into silent garbage)."""
    if state.ndim != 2 or state.shape[0] != n_cells:
        raise ValueError(
            f"{where}: state must be (n_cells={n_cells}, n_words), "
            f"got shape {tuple(state.shape)}")
    if state.shape[1] % TILE_W != 0:
        raise ValueError(
            f"{where}: n_words={state.shape[1]} must be a multiple of "
            f"TILE_W={TILE_W}")


def _pim_kernel(ops_ref, a_ref, b_ref, o_ref, state_ref, out_ref):
    # bring the tile into the output buffer once; all gates run in-place
    out_ref[...] = state_ref[...]
    n = ops_ref.shape[0]

    def body(i, carry):
        op = ops_ref[i]
        av = pl.load(out_ref, (pl.ds(a_ref[i], 1), slice(None)))
        bv = pl.load(out_ref, (pl.ds(b_ref[i], 1), slice(None)))
        nor = ~(av | bv)                      # NOT == NOR with b == a
        init = jnp.where(op == 1, jnp.uint32(_FULL), jnp.uint32(0))
        res = jnp.where(op >= 2, nor, jnp.broadcast_to(init, nor.shape))
        pl.store(out_ref, (pl.ds(o_ref[i], 1), slice(None)), res)
        return carry

    jax.lax.fori_loop(0, n, body, 0)


@functools.partial(jax.jit,
                   static_argnames=("n_cells", "interpret"))
def pim_exec_padded(state, ops, a, b, o, *, n_cells, interpret=True):
    """Run a lowered NOR program over ``state`` (uint32[n_cells, n_words]),
    n_words a multiple of TILE_W.  Returns the final state."""
    n_words = state.shape[1]
    _check_state_shape("pim_exec_padded", state, n_cells)
    grid = (n_words // TILE_W,)
    return pl.pallas_call(
        _pim_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=grid,
            in_specs=[pl.BlockSpec((n_cells, TILE_W), lambda i, *_: (0, i))],
            out_specs=pl.BlockSpec((n_cells, TILE_W), lambda i, *_: (0, i)),
        ),
        out_shape=jax.ShapeDtypeStruct(state.shape, jnp.uint32),
        interpret=interpret,
    )(ops, a, b, o, state)


def _pim_level_kernel(la_ref, lb_ref, lo_ref, state_ref, out_ref):
    n_levels = la_ref.shape[0]
    st0 = state_ref[...]
    if n_levels == 0:           # gate-free (passthrough) program
        out_ref[...] = st0
        return

    def body(l, st):
        av = jnp.take(st, la_ref[l], axis=0)      # (width, TILE_W)
        bv = jnp.take(st, lb_ref[l], axis=0)
        return st.at[lo_ref[l]].set(~(av | bv), mode="promise_in_bounds",
                                    unique_indices=True)

    out_ref[...] = jax.lax.fori_loop(0, n_levels, body, st0)


@functools.partial(jax.jit, static_argnames=("n_cells", "interpret"))
def pim_exec_level_padded(state, la, lb, lo, out_idx=None, *, n_cells,
                          interpret=True):
    """Run a levelized NOR schedule over ``state`` (uint32[n_cells,
    n_words]), n_words a multiple of TILE_W.  ``la``/``lb``/``lo`` are the
    LevelSchedule's dense int32[n_levels, width] index matrices (padding
    lanes write distinct sink cells, keeping scatter indices unique).
    Returns the final state, or only the rows in ``out_idx`` (the port
    cells) when given."""
    n_words = state.shape[1]
    _check_state_shape("pim_exec_level_padded", state, n_cells)
    grid = (n_words // TILE_W,)
    final = pl.pallas_call(
        _pim_level_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=[pl.BlockSpec((n_cells, TILE_W), lambda i, *_: (0, i))],
            out_specs=pl.BlockSpec((n_cells, TILE_W), lambda i, *_: (0, i)),
        ),
        out_shape=jax.ShapeDtypeStruct(state.shape, jnp.uint32),
        interpret=interpret,
    )(la, lb, lo, state)
    return final if out_idx is None else final[out_idx]


@functools.partial(jax.jit, static_argnames=(
    "n_cells", "one_cell", "in_widths", "out_widths", "interpret"))
def pim_exec_level_fused(in_vals, in_idx, la, lb, lo, out_idx, *,
                         n_cells, one_cell, in_widths, out_widths,
                         interpret=True):
    """Fully fused levelized Pallas executor (ports of <= 32 cells): the
    row-major <-> column-major bit transposes run on device around the
    kernel, so only (n_ports, n_rows) uint32 values cross the boundary."""
    from .ref import assemble_state, pack_columns, unpack_columns
    st = assemble_state(pack_columns(in_vals, in_widths), in_idx,
                        in_vals.shape[1] // 32,
                        n_cells=n_cells, one_cell=one_cell)
    final = pim_exec_level_padded(st, la, lb, lo, n_cells=n_cells,
                                  interpret=interpret)
    return unpack_columns(final[out_idx], out_widths)


@functools.partial(jax.jit,
                   static_argnames=("n_cells", "one_cell", "interpret"))
def pim_exec_level_padded_io(in_rows, in_idx, la, lb, lo, out_idx, *,
                             n_cells, one_cell=None, interpret=True):
    """Levelized Pallas executor with on-device state assembly: ships in
    only the input port rows (uint32[k_in, n_words]), materializes the zero
    state and the folded INIT1 constant device-side, and returns only the
    output port rows."""
    from .ref import assemble_state
    st = assemble_state(in_rows, in_idx, in_rows.shape[1],
                        n_cells=n_cells, one_cell=one_cell)
    final = pim_exec_level_padded(st, la, lb, lo, n_cells=n_cells,
                                  interpret=interpret)
    return final[out_idx]
