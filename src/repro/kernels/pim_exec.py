"""Pallas TPU kernels: PIM gate-program executors.

TPU adaptation of the paper's core insight (DESIGN.md §2): a PIM column of r
row-bits is a dense bitvector, and an arithmetic algorithm is a straight-line
NOR program over columns.  Executing the *entire program* while a row-tile's
cells are resident in VMEM pays HBM traffic once per tile instead of once per
gate, lifting arithmetic intensity from ~1 bit-op/byte to ~program-length
bit-ops/byte -- the memory-wall argument of the paper, restated for the
TPU memory hierarchy (HBM -> VMEM -> VREG).

Layout: ``state[cell, word]`` (uint32), 32 rows packed per word along the
lane dimension; one grid step owns a ``(n_cells, TILE_W)`` VMEM block.

Two executors (DESIGN.md §5):

  * :func:`pim_exec_padded` -- gate-serial.  The lowered program (ops/a/b/out
    int32 arrays, ops in {INIT0=0, INIT1=1, NOT=2, NOR=3}) arrives via scalar
    prefetch and drives a ``fori_loop``; NOT is NOR with b==a, so the compute
    is a single branchless select per gate.  One dynamic row slice per gate:
    this lowers on real TPU hardware today.
  * :func:`pim_exec_level_padded` -- levelized, dense ("scan"-alloc)
    schedules.  The LevelSchedule's (n_levels, width) index matrices drive
    a ``fori_loop`` over *levels*; each iteration gathers the level's
    operand rows, NORs them as one (width, TILE_W) block and scatters the
    results.  The gather/scatter use vector indices, which Mosaic does not
    lower for uint32 row gathers, so this legacy path requires
    ``interpret=True``.

Slot-schedule kernels (DESIGN.md §9), consuming ``alloc="slots"``
schedules from ``core.gates.levelize``:

  * :func:`pim_exec_slots_fused` / :func:`pim_exec_slots_io` -- the fused
    fast path: the kernel assembles the state from the input port rows
    (one slice update; inputs are a contiguous run by construction), runs a
    ``lax.scan`` over levels whose *write* side is a contiguous band
    ``dynamic_update_slice`` (the scatter is gone), and emits the output
    band as one slice.  The remaining vector gather on the operand read
    side keeps this kernel interpret-only, but it is the structurally
    leanest form and beats the jnp reference on the tracked benchmark row.
  * :func:`pim_exec_slots_static` -- the rewritten levelized kernel
    (:func:`_pim_level_kernel`): the straight-line static-slice emission
    shared with ``kernels.slots``.  The level loop is unrolled at trace
    time, every read is a ``lax.slice`` at a Python-constant offset (merged
    into maximal runs), every band is an SSA value, and the output block is
    a static concatenation -- **zero dynamic indexing**, so the kernel body
    is Mosaic-lowerable on hardware.  ``interpret=True`` stays the CPU test
    default; on CPU the unrolled form trades the loop for per-op interpret
    overhead, which is why the scan kernel above is the CPU benchmark path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .plan import TILE_W            # lane-dim words per block (re-export)
from .slots import (SLOT_UNROLL, at_cells, band_slice, band_update,
                    build_init_block, emit_levels, pack_values, plane_shape,
                    read_concat, static_plan, take_cells, unpack_values)

_FULL = 0xFFFFFFFF


def _check_state_shape(where: str, state, n_cells: int) -> None:
    """Trace-time shape validation.  Explicit raises, not ``assert``: these
    guard grid construction and block specs, and must survive ``python -O``
    (asserts are stripped there, turning shape bugs into silent garbage).
    State is (n_cells, n_words) under rows32 or (planes, n_cells, n_words)
    under the paired rows64 layout."""
    if state.ndim not in (2, 3) or state.shape[-2] != n_cells:
        raise ValueError(
            f"{where}: state must be ([planes,] n_cells={n_cells}, "
            f"n_words), got shape {tuple(state.shape)}")
    if state.shape[-1] % TILE_W != 0:
        raise ValueError(
            f"{where}: n_words={state.shape[-1]} must be a multiple of "
            f"TILE_W={TILE_W}")


def _state_block(state, n_cells: int):
    """(BlockSpec shape, index_map) tiling the trailing word axis of a 2-D
    or planes-leading 3-D state."""
    if state.ndim == 2:
        return (n_cells, TILE_W), lambda i, *_: (0, i)
    return (state.shape[0], n_cells, TILE_W), lambda i, *_: (0, 0, i)


def _pim_kernel(ops_ref, a_ref, b_ref, o_ref, state_ref, out_ref):
    # bring the tile into the output buffer once; all gates run in-place
    out_ref[...] = state_ref[...]
    n = ops_ref.shape[0]

    def body(i, carry):
        op = ops_ref[i]
        av = pl.load(out_ref, (pl.ds(a_ref[i], 1), slice(None)))
        bv = pl.load(out_ref, (pl.ds(b_ref[i], 1), slice(None)))
        nor = ~(av | bv)                      # NOT == NOR with b == a
        init = jnp.where(op == 1, jnp.uint32(_FULL), jnp.uint32(0))
        res = jnp.where(op >= 2, nor, jnp.broadcast_to(init, nor.shape))
        pl.store(out_ref, (pl.ds(o_ref[i], 1), slice(None)), res)
        return carry

    jax.lax.fori_loop(0, n, body, 0)


@functools.partial(jax.jit,
                   static_argnames=("n_cells", "interpret"),
                   donate_argnums=(0,))
def pim_exec_padded(state, ops, a, b, o, *, n_cells, interpret=True):
    """Run a lowered NOR program over ``state`` (uint32[n_cells, n_words]),
    n_words a multiple of TILE_W.  Returns the final state.  ``state`` is
    donated (single-use staging buffer on the gate-serial path)."""
    n_words = state.shape[1]
    _check_state_shape("pim_exec_padded", state, n_cells)
    grid = (n_words // TILE_W,)
    return pl.pallas_call(
        _pim_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=grid,
            in_specs=[pl.BlockSpec((n_cells, TILE_W), lambda i, *_: (0, i))],
            out_specs=pl.BlockSpec((n_cells, TILE_W), lambda i, *_: (0, i)),
        ),
        out_shape=jax.ShapeDtypeStruct(state.shape, jnp.uint32),
        interpret=interpret,
    )(ops, a, b, o, state)


def _pim_level_gather_kernel(la_ref, lb_ref, lo_ref, state_ref, out_ref):
    """Legacy levelized kernel for dense ("scan"-alloc) schedules: vector
    gathers and scatters per level, which Mosaic does not lower -- retained
    for ``schedule="dense"`` compatibility, interpret mode only.  Any
    leading plane axis (rows64) batches through the gather/scatter."""
    n_levels = la_ref.shape[0]
    st0 = state_ref[...]
    if n_levels == 0:           # gate-free (passthrough) program
        out_ref[...] = st0
        return

    def body(l, st):
        av = take_cells(st, la_ref[l])            # (..., width, TILE_W)
        bv = take_cells(st, lb_ref[l])
        return at_cells(st, lo_ref[l]).set(
            ~(av | bv), mode="promise_in_bounds", unique_indices=True)

    out_ref[...] = jax.lax.fori_loop(0, n_levels, body, st0)


@functools.partial(jax.jit, static_argnames=("n_cells", "interpret"),
                   donate_argnums=(0,))
def pim_exec_level_padded(state, la, lb, lo, out_idx=None, *, n_cells,
                          interpret=True):
    """Run a levelized NOR schedule over ``state`` (uint32[n_cells,
    n_words] or the planes-leading rows64 form), n_words a multiple of
    TILE_W.  ``la``/``lb``/``lo`` are the LevelSchedule's dense
    int32[n_levels, width] index matrices (padding lanes write distinct
    sink cells, keeping scatter indices unique).  Returns the final state,
    or only the rows in ``out_idx`` (the port cells) when given.
    ``state`` is donated: the caller's buffer is consumed (the padded
    paths materialize it purely as kernel input, so the donation kills the
    defensive copy)."""
    n_words = state.shape[-1]
    _check_state_shape("pim_exec_level_padded", state, n_cells)
    grid = (n_words // TILE_W,)
    block, index_map = _state_block(state, n_cells)
    final = pl.pallas_call(
        _pim_level_gather_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=[pl.BlockSpec(block, index_map)],
            out_specs=pl.BlockSpec(block, index_map),
        ),
        out_shape=jax.ShapeDtypeStruct(state.shape, jnp.uint32),
        interpret=interpret,
    )(la, lb, lo, state)
    return final if out_idx is None else take_cells(final, out_idx)


@functools.partial(jax.jit, static_argnames=(
    "n_cells", "one_cell", "in_widths", "out_widths", "interpret",
    "planes"))
def pim_exec_level_fused(in_vals, in_idx, la, lb, lo, out_idx, *,
                         n_cells, one_cell, in_widths, out_widths,
                         interpret=True, planes=1):
    """Fully fused levelized Pallas executor (ports of <= 32 cells): the
    row-major <-> column-major bit transposes run on device around the
    kernel, so only (n_ports, n_rows) uint32 values cross the boundary.
    ``planes`` selects the word layout (kernels.plan)."""
    from .ref import assemble_state, pack_columns, unpack_columns
    st = assemble_state(pack_columns(in_vals, in_widths, planes), in_idx,
                        in_vals.shape[1] // (32 * planes),
                        n_cells=n_cells, one_cell=one_cell)
    final = pim_exec_level_padded(st, la, lb, lo, n_cells=n_cells,
                                  interpret=interpret)
    return unpack_columns(take_cells(final, out_idx), out_widths, planes)


@functools.partial(jax.jit,
                   static_argnames=("n_cells", "one_cell", "interpret"))
def pim_exec_level_padded_io(in_rows, in_idx, la, lb, lo, out_idx, *,
                             n_cells, one_cell=None, interpret=True):
    """Levelized Pallas executor with on-device state assembly: ships in
    only the input port rows (uint32[k_in, n_words], planes-leading under
    rows64), materializes the zero state and the folded INIT1 constant
    device-side, and returns only the output port rows."""
    from .ref import assemble_state
    st = assemble_state(in_rows, in_idx, in_rows.shape[-1],
                        n_cells=n_cells, one_cell=one_cell)
    final = pim_exec_level_padded(st, la, lb, lo, n_cells=n_cells,
                                  interpret=interpret)
    return take_cells(final, out_idx)


# --------------------------------------------------------------------------
# slot-schedule kernels (DESIGN.md §9)
# --------------------------------------------------------------------------

def _slot_scan_kernel(la_ref, lb_ref, lo_ref, in_ref, out_ref, *,
                      n_cells, one_cell, k_in, in_base, out_base, k_out,
                      unroll, has_levels=True, planes=1):
    """Scan-form slot kernel: state assembly, the level loop and the output
    band extraction all happen on kernel-resident values.  Writes are
    contiguous band slice updates (no scatter); the operand read remains a
    vector gather, so this kernel is the interpret-mode fast path while
    :func:`_pim_level_kernel` is the hardware-legal form.  ``has_levels``
    is False for gate-free (passthrough) programs, whose index operands are
    dummy 1x1 blocks (gridless pallas rejects 0-sized blocks).  ``planes``
    is the word layout: the rows64 state keeps its leading pair axis as a
    batch dim through every op."""
    n_words = in_ref.shape[-1]
    st = jnp.zeros(plane_shape(planes, n_cells, n_words), jnp.uint32)
    if k_in:                    # inputs are the leading contiguous run
        st = band_update(st, in_ref[...][..., :k_in, :], in_base)
    if one_cell is not None:
        st = at_cells(st, one_cell).set(jnp.uint32(_FULL))
    if has_levels:
        W = la_ref.shape[1]
        lab = jnp.concatenate([la_ref[...], lb_ref[...]], axis=1)
        off = lo_ref[...][:, 0]

        def body(s, idx):
            ab, o = idx
            g = take_cells(s, ab)
            return band_update(s, ~(g[..., :W, :] | g[..., W:, :]), o), None

        st, _ = lax.scan(body, st, (lab, off), unroll=unroll)
    out_ref[...] = band_slice(st, out_base, out_ref.shape[-2])


def _nonempty_levels(la, lb, lo):
    """Replace 0-sized schedule operands (gate-free programs) with dummy
    1x1 blocks; returns (la, lb, lo, has_levels)."""
    if la.shape[0] and la.shape[1]:
        return la, lb, lo, True
    dummy = jnp.zeros((1, 1), jnp.int32)
    return dummy, dummy, dummy, False


def _slots_call(kernel, k_out, n_words, interpret, la, lb, lo,
                in_rows, planes=1):
    """Single whole-array ``pallas_call`` for the scan-form slot kernel.

    Gridless on purpose: the kernel is interpret-only (its operand read is
    a vector gather), and under interpretation every block boundary is a
    real buffer copy -- a word-tiled grid would re-copy the schedule
    operands per tile for no benefit.  The hardware-shaped, word-tiled
    TILE_W grid lives on the static-slice kernel
    (:func:`make_slots_static`), which is the Mosaic-lowerable form."""
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(
            plane_shape(planes, max(k_out, 1), n_words), jnp.uint32),
        interpret=interpret,
    )(la, lb, lo, in_rows)


@functools.partial(jax.jit, static_argnames=(
    "n_cells", "one_cell", "in_widths", "out_widths", "in_base", "out_base",
    "unroll", "interpret", "planes"))
def pim_exec_slots_fused(in_vals, in_idx, la, lb, lo, out_idx, *,
                         n_cells, one_cell, in_widths, out_widths,
                         in_base, out_base, unroll=SLOT_UNROLL,
                         interpret=True, planes=1):
    """Fused slot executor, Pallas backend: butterfly bit transposes wrap a
    single scan-form kernel; only (n_ports, n_rows) uint32 values cross the
    host/device boundary.  Requires the slot layout's contiguous input and
    output runs (``in_base``/``out_base``)."""
    n_words = in_vals.shape[1] // (32 * planes)
    packed = pack_values(in_vals, in_widths, planes)
    k_in, k_out = packed.shape[-2], sum(out_widths)
    if not k_in:        # constant-generator program: dummy zero block
        packed = jnp.zeros(plane_shape(planes, 1, n_words), jnp.uint32)
    la, lb, lo, has_levels = _nonempty_levels(la, lb, lo)
    kern = functools.partial(
        _slot_scan_kernel, n_cells=n_cells, one_cell=one_cell,
        k_in=k_in, in_base=in_base if k_in else 0, out_base=out_base,
        k_out=k_out, unroll=unroll, has_levels=has_levels, planes=planes)
    sub = _slots_call(kern, k_out, n_words, interpret, la, lb, lo,
                      packed, planes)
    return unpack_values(sub[..., :k_out, :], out_widths, planes)


@functools.partial(jax.jit, static_argnames=(
    "n_cells", "one_cell", "k_out", "in_base", "out_base", "unroll",
    "interpret"))
def pim_exec_slots_io(in_rows, in_idx, la, lb, lo, out_idx, *,
                      n_cells, one_cell, k_out, in_base, out_base,
                      unroll=SLOT_UNROLL, interpret=True):
    """Slot executor over pre-packed port rows, Pallas backend (arbitrary
    port widths; the word layout is inferred from the input rank)."""
    planes = 1 if in_rows.ndim == 2 else in_rows.shape[0]
    n_words = in_rows.shape[-1]
    k_in = in_rows.shape[-2]
    if not k_in:
        in_rows = jnp.zeros(plane_shape(planes, 1, n_words), jnp.uint32)
    la, lb, lo, has_levels = _nonempty_levels(la, lb, lo)
    kern = functools.partial(
        _slot_scan_kernel, n_cells=n_cells, one_cell=one_cell,
        k_in=k_in, in_base=in_base if k_in else 0, out_base=out_base,
        k_out=k_out, unroll=unroll, has_levels=has_levels, planes=planes)
    sub = _slots_call(kern, k_out, n_words, interpret, la, lb, lo,
                      in_rows, planes)
    return sub[..., :k_out, :]


def _pim_level_kernel(sched, in_widths, out_names):
    """The rewritten levelized kernel: build the static-slice straight-line
    body for a slot schedule.  The returned kernel reads the packed input
    block, reconstructs the initial region by concatenation (inputs are the
    leading run; constants are broadcast rows), unrolls every level into
    ``band = ~(A | B)`` with A/B as static-offset slice concatenations, and
    stores the contiguous output band.  No gather, no scatter, no dynamic
    offset anywhere: every index is a Python constant at trace time, which
    is what makes the body Mosaic-lowerable."""
    reads, out_srcs, n_init = static_plan(sched)
    one_cell = None if sched.one_cell is None else int(sched.one_cell)
    stacked_out = [s for name in out_names for s in out_srcs[name]]

    def kernel(in_ref, out_ref):
        packed = in_ref[...][..., :sum(in_widths), :]
        init_block = build_init_block(packed, n_init, one_cell)
        bands = emit_levels(reads, 0, sched.n_levels, init_block, {})
        sub = read_concat(init_block, bands, stacked_out)
        if sub.shape[-2] < out_ref.shape[-2]:   # k_out == 0 pad block
            pad_shape = sub.shape[:-2] + (
                out_ref.shape[-2] - sub.shape[-2], out_ref.shape[-1])
            sub = jnp.concatenate([sub, jnp.zeros(pad_shape, jnp.uint32)],
                                  axis=-2)
        out_ref[...] = sub

    return kernel


def make_slots_static(sched, in_widths, out_widths, out_names,
                      interpret=True, planes=1):
    """Hardware-legal levelized Pallas executor factory: returns a jitted
    ``run(in_vals) -> out_vals`` wrapping one ``pallas_call`` whose body is
    the fully static-slice form of ``sched`` (see
    :func:`_pim_level_kernel`).  Fused bridges; ports of <= 32 cells.
    Interpret mode pays per-op cost for the unrolled body on CPU -- this
    entry exists for hardware lowering and bit-exactness testing, and is
    benchmarked as its own row.  Callers cache the returned function (the
    kernel closure embeds the whole unrolled program; rebuilding it per
    call would retrace).  ``planes`` is the word layout: under rows64 the
    blocks grow the leading pair axis (still zero dynamic indexing)."""
    kernel = _pim_level_kernel(sched, in_widths, out_names)
    k_out = sum(out_widths)

    def block(k):
        index_map = (lambda i: (0, i)) if planes == 1 else \
            (lambda i: (0, 0, i))
        return pl.BlockSpec(plane_shape(planes, max(k, 1), TILE_W),
                            index_map)

    @jax.jit
    def run(in_vals):
        n_words = in_vals.shape[1] // (32 * planes)
        packed = pack_values(in_vals, in_widths, planes)
        k_in = packed.shape[-2]
        if not k_in:
            packed = jnp.zeros(plane_shape(planes, 1, n_words), jnp.uint32)
        sub = pl.pallas_call(
            kernel,
            grid=(n_words // TILE_W,),
            in_specs=[block(k_in)],
            out_specs=block(k_out),
            out_shape=jax.ShapeDtypeStruct(
                plane_shape(planes, max(k_out, 1), n_words), jnp.uint32),
            interpret=interpret,
        )(packed)
        return unpack_values(sub[..., :k_out, :], out_widths, planes)

    return run


# --------------------------------------------------------------------------
# verified execution: device-side check-word generation (DESIGN.md §12)
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("axis",))
def check_words(block, axis: int):
    """Per-word XOR check fold of an output block over its cell (or port)
    axis -- verified execution's "on-device ECC generation": the fold is
    computed while the result is still device-resident, *before* the
    fault-prone readback, so a host-side refold of the transferred data
    detects any single corrupted bit per word position (two corruptions of
    the same bit position in different cells cancel -- the classic parity
    limit; the sampled oracle spot checks in ``kernels.ops`` backstop it).
    Works on both output representations: fused per-port row values
    ``(n_ports, rows)`` with ``axis=0`` and packed word blocks
    ``(..., k, n_words)`` with ``axis=ndim-2``."""
    return lax.reduce(block, jnp.uint32(0), lax.bitwise_xor, (axis,))
