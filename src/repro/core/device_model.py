"""Analytic device models for the paper's §7 case study.

Memristive PIM (RACER-derived parameters, as in the paper): an 8 GB memory
built from 1024x1024 crossbars -> 64 Mi rows operating in lockstep; one
NOT/NOR column operation per cycle per array.  The GPU baseline is modeled at
its memory-bandwidth roofline -- the paper *measured* an RTX 3070 and found
throughput indistinguishable from that bound, which is what makes the model
transferable to this GPU-less container (DESIGN.md §7).
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class PIMDevice:
    name: str = "memristive-racer"
    rows: int = 1024
    cols: int = 1024
    total_bytes: int = 8 * 1024 ** 3
    cycle_ns: float = 10.0          # conservative RRAM switching + periphery
    gate_energy_fj: float = 24.3    # energy per column op per row (switching)
    init_counted: bool = True       # count output-init cycles

    @property
    def n_arrays(self) -> int:
        return self.total_bytes * 8 // (self.rows * self.cols)

    @property
    def parallel_rows(self) -> int:
        return self.n_arrays * self.rows          # 64 Mi for the defaults

    def cycles(self, cost) -> int:
        c = cost.nor_gates
        if self.init_counted:
            c += cost.init_cycles
        return c

    def latency_s(self, cost) -> float:
        return self.cycles(cost) * self.cycle_ns * 1e-9

    def throughput_ops(self, cost) -> float:
        """element ops / second at full memory occupancy (vector length ==
        parallel_rows; longer vectors batch with identical throughput)."""
        return self.parallel_rows / self.latency_s(cost)

    def energy_per_op_j(self, cost) -> float:
        return self.cycles(cost) * self.gate_energy_fj * 1e-15

    def throughput_per_watt(self, cost) -> float:
        return 1.0 / self.energy_per_op_j(cost)


@dataclasses.dataclass(frozen=True)
class GPUDevice:
    """Bandwidth-roofline GPU model (paper §7.2: measured == bound)."""
    name: str = "rtx3070"
    mem_bw: float = 448e9           # B/s
    tdp_w: float = 220.0

    def throughput_ops(self, elem_bytes: int, n_operands: int = 3) -> float:
        return self.mem_bw / (elem_bytes * n_operands)

    def throughput_per_watt(self, elem_bytes: int,
                            n_operands: int = 3) -> float:
        return self.throughput_ops(elem_bytes, n_operands) / self.tdp_w


@dataclasses.dataclass(frozen=True)
class TPUChip:
    """TPU v5e-class constants (per assignment) for the roofline analysis."""
    name: str = "tpu-v5e"
    peak_bf16_flops: float = 197e12
    hbm_bw: float = 819e9
    ici_bw: float = 50e9            # per link
    hbm_bytes: int = 16 * 1024 ** 3


PIM_DEFAULT = PIMDevice()
GPU_DEFAULT = GPUDevice()
TPU_DEFAULT = TPUChip()
