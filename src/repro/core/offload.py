"""Analytic PIM-offload planner: where would AritPIM beat the accelerator?

For an (arch x shape) serving cell, enumerates the elementwise/vector ops a
decode step performs and compares, per op:

  * TPU/GPU time  = bytes_moved / mem_bw       (these ops are bandwidth-bound
                    on any von-Neumann device -- the paper's §7 observation)
  * PIM time      = cycles(op) * cycle_time    (independent of vector length
                    up to 64 Mi rows -- element-parallel execution)

The planner answers the deployment question the paper poses: data-intensive,
memory-bound arithmetic belongs *in* the memory.  GEMM-shaped work stays on
the MXU (PIM multiply throughput is per-element, not per-MAC-array).
"""

from __future__ import annotations

import dataclasses
from typing import List

from . import bitserial, bitserial_fp
from .device_model import GPU_DEFAULT, PIM_DEFAULT, TPU_DEFAULT
from .floatfmt import BF16
from ..models.config import ModelConfig


@dataclasses.dataclass
class OpPlan:
    name: str
    n_elems: int
    tpu_us: float
    pim_us: float
    offload: bool
    note: str = ""


def _pim_cost(kind: str):
    if kind == "add":
        return bitserial_fp.build_fp_add(BF16).cost()
    if kind == "mul":
        return bitserial_fp.build_fp_mul(BF16).cost()
    return bitserial.build_add(32).cost()


def decode_step_plan(cfg: ModelConfig, batch: int, seq: int) -> List[OpPlan]:
    """Elementwise work in one decode step (per layer aggregated)."""
    d = cfg.d_model
    L = cfg.n_layers
    plans = []
    pim = PIM_DEFAULT
    tpu = TPU_DEFAULT

    def add(name, kind, n, note=""):
        # bandwidth-bound elementwise op on TPU: 3 operands x 2 bytes
        tpu_us = n * 6 / tpu.hbm_bw * 1e6
        c = _pim_cost(kind)
        pim_us = pim.latency_s(c) * 1e6 if n <= pim.parallel_rows else \
            pim.latency_s(c) * 1e6 * (n / pim.parallel_rows)
        plans.append(OpPlan(name, n, round(tpu_us, 3), round(pim_us, 3),
                            offload=pim_us < tpu_us, note=note))

    add("residual adds", "add", 2 * L * batch * d)
    add("rmsnorm scale/shift", "mul", 2 * L * batch * d)
    add("swiglu gate mul", "mul", L * batch * cfg.d_ff)
    if "rwkv" in cfg.group:
        add("wkv decay/gate elementwise", "mul",
            L * batch * d * 4, "decay, bonus, gates")
    if "recurrent" in cfg.group:
        add("rg-lru gating", "mul", L * batch * (cfg.d_rnn or d) * 3)
    add("kv-cache append", "add", L * batch * 2 * cfg.n_kv_heads * cfg.hd,
        "write-only; PIM native")
    return plans


def report(cfg: ModelConfig, batch: int = 128, seq: int = 32768) -> str:
    rows = decode_step_plan(cfg, batch, seq)
    out = [f"PIM offload plan: {cfg.name}, decode batch={batch} seq={seq}",
           f"{'op':28s} {'elems':>12s} {'tpu_us':>9s} {'pim_us':>9s} off?"]
    for r in rows:
        out.append(f"{r.name:28s} {r.n_elems:12d} {r.tpu_us:9.3f} "
                   f"{r.pim_us:9.3f} {'YES' if r.offload else 'no '}"
                   f"  {r.note}")
    n_off = sum(r.offload for r in rows)
    out.append(f"-> {n_off}/{len(rows)} op classes clear the PIM bar "
               f"(small vectors lose: latency is cycle-bound; the win is "
               f"throughput at >= Mi-scale element counts)")
    return "\n".join(out)


if __name__ == "__main__":
    from ..configs import registry
    print(report(registry.get("rwkv6-1.6b")))
