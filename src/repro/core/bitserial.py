"""Bit-serial element-parallel fixed-point arithmetic (paper §3).

All routines take a :class:`~repro.core.gates.Builder` plus little-endian cell
vectors and append pure data-flow gate sequences -- no reads, no branches --
exactly as the abstract model requires (every row executes the same program).

  * :func:`ripple_add`      -- Algorithm 3.1 (state of the art, FACC chain)
  * :func:`negate` / :func:`sub`
  * :func:`mul_shift_add`   -- Algorithm 3.2 base case (Haj-Ali et al.)
  * :func:`mul_karatsuba`   -- Algorithm 3.2 (proposed; crossover N≈20)
  * :func:`divide`          -- Algorithm 3.4 (proposed non-restoring divider)

Top-level ``build_*`` functions wrap each routine into a named-port
:class:`Program` for the simulator / Pallas executor.
"""

from __future__ import annotations

from typing import List, Optional, Tuple

from .gates import Builder, G, Program, memoize_build

KARATSUBA_THRESHOLD = 20  # paper fn. 3


# --------------------------------------------------------------------------
# addition / subtraction (Alg 3.1)
# --------------------------------------------------------------------------

def ripple_add(b: Builder, x: List[int], y: List[int],
               cin: Optional[Tuple[int, int]] = None,
               ) -> Tuple[List[int], Tuple[int, int]]:
    """z = x + y (+ cin).  Returns (sum bits, (carry, ~carry)).

    Maintains both the carry and its complement through the FACC chain --
    the paper's noted optimization of storing carry and NOT-carry.
    ``cin`` is an optional (c, ~c) cell pair.
    """
    assert len(x) == len(y)
    if cin is None:
        c, nc = b.const(0), b.const(1)
    else:
        c, nc = cin
    z = []
    for xi, yi in zip(x, y):
        s, c, nc = b.facc(xi, yi, c, nc)
        z.append(s)
    return z, (c, nc)


def add_into(b: Builder, z: List[int], addend: List[int], offset: int = 0,
             drop_carry: bool = False) -> Optional[int]:
    """z[offset:] += addend, rippling the carry through the remaining high
    bits of ``z`` (half-adder tail).  Rebinds cells inside ``z`` in place.
    Returns the final carry cell (or None when ``drop_carry``)."""
    c, nc = b.const(0), b.const(1)
    n = len(addend)
    assert offset + n <= len(z)
    for j in range(n):
        i = offset + j
        s, c, nc = b.facc(z[i], addend[j], c, nc)
        b.free(z[i])
        z[i] = s
    # propagate carry through remaining bits: half-adder = XOR + AND
    for i in range(offset + n, len(z)):
        s = b.xor(z[i], c)
        c2 = b.and_(z[i], c)
        nc2 = b.not_(c2)
        b.free([z[i], c, nc])
        z[i], c, nc = s, c2, nc2
    if drop_carry:
        b.free([c, nc])
        return None
    b.free(nc)
    return c


def negate(b: Builder, x: List[int]) -> List[int]:
    """two's-complement -x over len(x) bits."""
    nx = b.vec_not(x)
    z, (c, nc) = ripple_add(b, nx, b.vec_const(1, len(x)))
    b.free(nx + [c, nc])
    return z


def sub(b: Builder, x: List[int], y: List[int]) -> Tuple[List[int], int]:
    """z = x - y over N bits; returns (z, borrow') where borrow'=1 iff x>=y."""
    ny = b.vec_not(y)
    z, (c, nc) = ripple_add(b, x, ny, cin=(b.const(1), b.const(0)))
    b.free(ny + [nc])
    return z, c


# --------------------------------------------------------------------------
# multiplication (Alg 3.2)
# --------------------------------------------------------------------------

def mul_shift_add(b: Builder, x: List[int], y: List[int]) -> List[int]:
    """2N-bit product via shift-and-add [Haj-Ali et al.]; the shift is
    *simulated* by indexing (no gates), only an N-bit adder per iteration."""
    n = len(x)
    assert len(y) == n
    z = b.vec_const(0, 2 * n)
    for i in range(n):
        p = b.vec_and_bit(x, y[i])                      # partial product
        # z_{i:i+N+1} <- z_{i:i+N} + p  (carry lands in z_{i+N}, known zero)
        c, nc = b.const(0), b.const(1)
        for j in range(n):
            s, c, nc = b.facc(z[i + j], p[j], c, nc)
            b.free([z[i + j], p[j]])
            z[i + j] = s
        b.free(z[i + n])
        z[i + n] = c
        b.free(nc)
    return z


def _split(x: List[int], h: int):
    return x[:h], x[h:]


def mul_karatsuba(b: Builder, x: List[int], y: List[int],
                  thresh: int = KARATSUBA_THRESHOLD) -> List[int]:
    """Algorithm 3.2: Karatsuba recursion over the bit-serial substrate.

    Unique PIM consideration (paper §3.2): latency is *total gate count*, and
    bit-level indexed access is free, so the crossover drops from thousands of
    digits to N≈20.
    """
    n = len(x)
    assert len(y) == n
    if n <= thresh or n < 4:
        return mul_shift_add(b, x, y)
    orig_n = n
    if n % 2:  # pad to even width with a zero MSB
        z0 = b.const(0)
        x = x + [z0]
        y = y + [z0]
        n += 1
    h = n // 2
    x0, x1 = _split(x, h)
    y0, y1 = _split(y, h)

    # t1' = (x0+x1)(y0+y1), computed first so its operand cells can be reused
    # (paper fn. 2).
    sx, (cx, ncx) = ripple_add(b, x0, x1)
    sy, (cy, ncy) = ripple_add(b, y0, y1)
    b.free([ncx, ncy])
    t1p = mul_karatsuba(b, sx + [cx], sy + [cy], thresh)   # 2(h+1) bits
    b.free(sx + sy + [cx, cy])

    t0 = mul_karatsuba(b, x0, y0, thresh)                  # n bits
    t2 = mul_karatsuba(b, x1, y1, thresh)                  # n bits

    # t1 = t1' - t0 - t2  (fits in n+1 bits; compute over len(t1p) bits)
    w = len(t1p)
    t0e = t0 + [b.const(0)] * (w - len(t0))
    t2e = t2 + [b.const(0)] * (w - len(t2))
    d1, bo1 = sub(b, t1p, t0e)
    b.free(t1p + [bo1])
    t1, bo2 = sub(b, d1, t2e)
    b.free(d1 + [bo2])

    # z = (t2|t0); z_{h:2n} += t1  (carry bounded: product < 2^{2n})
    z = t0 + t2
    add_into(b, z, t1[: n + 1], offset=h, drop_carry=True)
    b.free(t1)
    return z[: 2 * orig_n]  # top pad bits (if any) are provably zero


# --------------------------------------------------------------------------
# division (Alg 3.4)
# --------------------------------------------------------------------------

def divide(b: Builder, z: List[int], d: List[int]
           ) -> Tuple[List[int], List[int]]:
    """Non-restoring 2N/N division (proposed, paper §3.3).

    Inputs: 2N-bit dividend ``z``, N-bit divisor ``d``; outputs N-bit
    quotient ``q`` and remainder ``r`` with z = q*d + r, 0 <= r < d.
    Precondition (standard for 2N/N dividers): z >> N < d, so q fits N bits.

    All of Alg 3.3's control flow is data flow here: the conditional
    add/sub is XOR(d, q_prev) with carry-in q_prev (two's complement),
    remainder shifts are simulated by indexing, and the final correction
    adds AND(d, sign) (Alg 3.4 line 7).
    """
    n = len(d)
    assert len(z) == 2 * n
    w = n + 2                               # |R| < 2d < 2^{n+1}
    zero = b.const(0)
    R = list(z[n:]) + [zero, zero]          # R = z >> n, zero-extended
    qprev, nqprev = b.const(1), b.const(0)  # first op is a subtraction
    qs = []
    for i in reversed(range(n)):
        # R <- (R << 1) | z_i : simulated shift (index bookkeeping, no gates)
        R = [z[i]] + R[: w - 1]
        # addend = +-d: XOR with q_prev, sign-extended by q_prev cells
        xd = [b.xor(dj, qprev) for dj in d] + [qprev] * (w - n)
        Rn, (c, nc) = ripple_add(b, R, xd, cin=(qprev, nqprev))
        b.free([c, nc] + xd[:n])
        for cell in R:
            if cell not in z and cell != zero:
                b.free(cell)
        R = Rn
        sign = R[w - 1]
        qi = b.not_(sign)
        qs.append(qi)
        qprev, nqprev = qi, sign
    # final correction: r <- R + AND(d, sign)   [sign of R == ~q_0]
    sign = nqprev
    corr = b.vec_and_bit(d, sign) + [zero, zero]
    Rf, (c, nc) = ripple_add(b, R, corr)
    b.free([c, nc] + corr[:n])
    q = list(reversed(qs))
    r = Rf[:n]
    return q, r


# --------------------------------------------------------------------------
# packaged programs
# --------------------------------------------------------------------------

@memoize_build
def build_add(n: int) -> Program:
    b = Builder()
    x = b.input("x", n)
    y = b.input("y", n)
    z, (c, _nc) = ripple_add(b, x, y)
    b.output("z", z + [c])
    return b.finish()


@memoize_build
def build_sub(n: int) -> Program:
    b = Builder()
    x = b.input("x", n)
    y = b.input("y", n)
    z, ge = sub(b, x, y)
    b.output("z", z)
    b.output("ge", [ge])
    return b.finish()


@memoize_build
def build_mul(n: int, karatsuba: bool = True,
              thresh: int = KARATSUBA_THRESHOLD) -> Program:
    b = Builder()
    x = b.input("x", n)
    y = b.input("y", n)
    z = mul_karatsuba(b, x, y, thresh) if karatsuba else mul_shift_add(b, x, y)
    b.output("z", z)
    return b.finish()


@memoize_build
def build_div(n: int) -> Program:
    b = Builder()
    z = b.input("z", 2 * n)
    d = b.input("d", n)
    q, r = divide(b, z, d)
    b.output("q", q)
    b.output("r", r)
    return b.finish()
