"""Bit-serial element-parallel floating-point arithmetic (paper §4).

The novel routines:

  * :func:`var_shift_right` / :func:`var_shift_left` -- Algorithm 4.1, the
    first in-memory *variable* shifter: every row shifts its own value by its
    own amount, via simulated in-memory multiplexers + a logarithmic shifter.
  * :func:`var_normalize` -- Algorithm 4.3, left-normalize with unknown shift
    amount via a binary search over the OR-prefix.
  * :func:`fp_add_unsigned` -- Algorithm 4.2 (first in-memory FP addition).
  * :func:`fp_add_signed` -- §4.5 (adds negation + variable normalization).
  * :func:`fp_mul` / :func:`fp_div` -- §4.6 (fixed-point cores + 1-bit
    normalization).

All results are *exactly* IEEE-754 round-to-nearest-ties-even (verified
against the rational oracle in :mod:`repro.core.floatfmt`); NaN/Inf/
subnormals/overflow are excluded as in the paper.  Zero (e=0, m=0) is
handled.
"""

from __future__ import annotations

from typing import List, Tuple

from .bitserial import ripple_add, sub
from .bitserial import mul_karatsuba, divide
from .floatfmt import FloatFormat
from .gates import Builder, Program, memoize_build


def _clog2(n: int) -> int:
    return max(1, (n - 1).bit_length())


# --------------------------------------------------------------------------
# small vector helpers
# --------------------------------------------------------------------------

def ext(b: Builder, v: List[int], n: int) -> List[int]:
    """zero-extend (shares the const-0 cell; reads only)."""
    return v + [b.const(0)] * (n - len(v))


def add_bit(b: Builder, v: List[int], bit: int, nbit=None) -> List[int]:
    """v + bit over len(v) bits (carry dropped)."""
    nb = b.not_(bit) if nbit is None else nbit
    z, (c, nc) = ripple_add(b, v, [b.const(0)] * len(v), cin=(bit, nb))
    b.free([c, nc] + ([nb] if nbit is None else []))
    return z


def abs_val(b: Builder, v: List[int]) -> Tuple[List[int], int]:
    """two's-complement |v|; returns (|v|, sign).  §4.3: XOR with the sign
    then add the sign."""
    s = v[-1]
    x = [b.xor(vi, s) for vi in v]
    out = add_bit(b, x, s)
    b.free(x)
    return out, s


def clamp_unsigned(b: Builder, t: List[int], tmax: int) -> List[int]:
    """min(t, tmax) for unsigned t (tmax a compile-time constant)."""
    cvec = b.vec_const(tmax, len(t))
    _, ge = sub(b, t, cvec)                 # ge = (t >= tmax)
    out = b.vec_mux(ge, cvec, t)
    b.free(ge)
    return out


# --------------------------------------------------------------------------
# Algorithm 4.1: variable shift
# --------------------------------------------------------------------------

def var_shift_right(b: Builder, x: List[int], t: List[int],
                    handle_overflow: bool = False):
    """z = x >> t, per-row shift amounts (Algorithm 4.1).

    Faithful to the paper: ``log2(Nx)`` iterations, iteration j selecting
    ``mux_{t_j}(z >> 2^j, z)`` with the multiplexer's ~t_j hoisted once and
    the zero-fill upper cells computed as AND(~t_j, z_i) rather than muxes.

    With ``handle_overflow`` the result is additionally masked to zero when
    any bit of t above the covered range is set (t >= Nx rounded up to a
    power of two); returns (z, t_high_flag) in that case.
    """
    nx = len(x)
    lg = _clog2(nx)
    z = list(x)
    for j in range(min(len(t), lg)):
        s = t[j]
        ns = b.not_(s)
        step = 1 << j
        nz = []
        for i in range(nx):
            if i + step < nx:
                nz.append(b.muxn(s, ns, z[i + step], z[i]))
            else:
                nz.append(b.and_(ns, z[i]))
        for c in z:
            if c not in x:
                b.free(c)
        b.free(ns)
        z = nz
    if not handle_overflow:
        return z
    if len(t) > lg:
        th = b.or_reduce(t[lg:])
        nth = b.not_(th)
        z2 = [b.and_(nth, zi) for zi in z]
        b.free(z + [nth])
        return z2, th
    return z, b.const(0)


def var_shift_left(b: Builder, x: List[int], t: List[int],
                   handle_overflow: bool = False):
    """z = x << t (symmetric to :func:`var_shift_right`, paper fn. 6)."""
    nx = len(x)
    lg = _clog2(nx)
    z = list(x)
    for j in range(min(len(t), lg)):
        s = t[j]
        ns = b.not_(s)
        step = 1 << j
        nz = []
        for i in range(nx):
            if i - step >= 0:
                nz.append(b.muxn(s, ns, z[i - step], z[i]))
            else:
                nz.append(b.and_(ns, z[i]))
        for c in z:
            if c not in x:
                b.free(c)
        b.free(ns)
        z = nz
    if not handle_overflow:
        return z
    if len(t) > lg:
        th = b.or_reduce(t[lg:])
        nth = b.not_(th)
        z2 = [b.and_(nth, zi) for zi in z]
        b.free(z + [nth])
        return z2, th
    return z, b.const(0)


# --------------------------------------------------------------------------
# Algorithm 4.3: variable normalization
# --------------------------------------------------------------------------

def var_normalize(b: Builder, x: List[int]) -> Tuple[List[int], List[int]]:
    """Left-shift x until its MSB is one; also output the shift amount.

    Binary search over the OR-prefix (paper §4.4): iteration j (high to low)
    sets t_j = NOR of the top 2^j bits, then z = mux_{t_j}(z << 2^j, z).
    The only overhead over variable shift is the O(Nx) total OR chain
    (the paper's ~7% figure).  For x == 0: z = 0 and t = all-ones.
    Works for any Nx (no power-of-two padding): the window test guarantees
    the shift never exceeds the remaining leading zeros.

    Returns (z over len(x) bits, t little-endian of ceil(log2(Nx)) bits).
    """
    nx = len(x)
    lg = _clog2(nx)
    z = list(x)
    tbits = [None] * lg
    for j in reversed(range(lg)):
        step = 1 << j
        window = z[nx - step:]
        acc = b.or_reduce(window)
        tj = b.not_(acc)
        b.free(acc)
        ntj = b.not_(tj)
        nz = []
        for i in range(nx):
            if i - step >= 0:
                nz.append(b.muxn(tj, ntj, z[i - step], z[i]))
            else:
                nz.append(b.and_(ntj, z[i]))
        for c in z:
            if c not in x:
                b.free(c)
        b.free(ntj)
        z = nz
        tbits[j] = tj
    return z, tbits


# --------------------------------------------------------------------------
# floating-point helpers
# --------------------------------------------------------------------------

def _unpack(b: Builder, fmt: FloatFormat, v: List[int]):
    """(sign, exponent bits, mantissa-with-hidden bits) from a packed port.
    The hidden bit is OR(e) so that e=0 encodes zero."""
    nm, ne = fmt.nm, fmt.ne
    m = v[:nm]
    e = v[nm:nm + ne]
    s = v[nm + ne]
    hid = b.or_reduce(e)
    return s, e, m + [hid]


def _round_rne(b: Builder, field: List[int], rnd: int, sticky: int):
    """RNE increment.  ``field`` includes the hidden bit.  Returns
    (stored mantissa bits, exponent-increment bit)."""
    lsb = field[0]
    up = b.and_(rnd, b.or_(sticky, lsb))
    nup = b.not_(up)
    inc, (c, nc) = ripple_add(b, field, [b.const(0)] * len(field),
                              cin=(up, nup))
    b.free([nup, nc])
    # on carry the field was all ones -> inc bits are all zero, which is
    # exactly the stored mantissa of the next binade.
    return inc[:len(field) - 1], c


def _mask_zero(b: Builder, nz: int, bits: List[int]) -> List[int]:
    return [b.and_(nz, x) for x in bits]


# --------------------------------------------------------------------------
# Algorithm 4.2 (+ §4.5): floating-point addition
# --------------------------------------------------------------------------

def fp_add(b: Builder, fmt: FloatFormat, x: List[int], y: List[int],
           signed: bool = True) -> List[int]:
    nm, ne = fmt.nm, fmt.ne
    sx, ex, Mx = _unpack(b, fmt, x)
    sy, ey, My = _unpack(b, fmt, y)

    # --- exponent difference and conditional swap (Alg 4.2 lines 1-4)
    de, _ = sub(b, ext(b, ex, ne + 1), ext(b, ey, ne + 1))
    swap = de[ne]                                  # 1 iff ey > ex
    e_big = b.vec_mux(swap, ey, ex)
    M_big = b.vec_mux(swap, My, Mx)
    M_small = b.vec_mux(swap, Mx, My)
    s_big = b.mux(swap, sy, sx)

    # --- |de|, clamped to the exact-alignment bound nm+4 (any larger shift
    #     lands entirely in the sticky region)
    t, _ = abs_val(b, de)
    tc = clamp_unsigned(b, t, nm + 4)
    b.free(t)

    # --- alignment (Alg 4.2 line 5): wide register keeps every shifted-out
    #     bit so G/R/S are exact.  X = M_small << (nm+4), width 2nm+5.
    V = nm + 4                                     # [1.m | G R S] register
    X = [b.const(0)] * (nm + 4) + M_small
    Y = var_shift_right(b, X, tc)
    A = Y[nm + 1:]                                 # aligned small operand
    tail = b.or_reduce(Y[: nm + 1])                # bits below S -> sticky
    A = [b.or_(A[0], tail)] + A[1:]
    b.free(tail)
    B = [b.const(0)] * 3 + M_big                   # big operand, GRS zero

    # --- add / effective-subtract (Alg 4.2 line 6), two's complement
    if signed:
        eop = b.xor(sx, sy)
        neop = b.not_(eop)
        Axor = [b.xor(ai, eop) for ai in A]
        R, (cout, ncout) = ripple_add(b, B + [b.const(0)], Axor + [eop],
                                      cin=(eop, neop))
        b.free(Axor + [cout, ncout, neop])
        neg = b.and_(R[V], eop)
        Rx = [b.xor(ri, neg) for ri in R]
        Rn = add_bit(b, Rx, neg)
        b.free(Rx + list(R))
    else:
        Rn, (cout, ncout) = ripple_add(b, B + [b.const(0)], A + [b.const(0)])
        b.free([cout, ncout])
        neg = b.const(0)

    if signed:
        # --- variable normalization (Alg 4.3) covers every case uniformly:
        #     lz=0 (carry-out), lz=1 (aligned), lz>1 (cancellation)
        Z, lz = var_normalize(b, Rn)
        field = Z[4: V + 1]
        rnd = Z[3]
        sticky = b.or_reduce(Z[:3])
        m_stored, cr = _round_rne(b, field, rnd, sticky)
        # e_out = e_big + 1 + cr - lz
        e1 = add_bit(b, ext(b, e_big, ne + 2), cr)
        e2, _ = sub(b, e1, ext(b, lz, ne + 2))
        e3 = add_bit(b, e2, b.const(1), nbit=b.const(0))
        b.free(e1 + e2)
        nz = b.or_reduce(Z)
        s_out = b.and_(b.xor(s_big, neg), nz)
    else:
        # --- single-bit normalization via Alg 4.1 with Nt=1 (carry bit)
        ovf = Rn[V]
        novf = b.not_(ovf)
        Z = [b.muxn(ovf, novf, b.or_(Rn[1], Rn[0]), Rn[0])]
        Z += [b.muxn(ovf, novf, Rn[i + 1], Rn[i]) for i in range(1, V)]
        b.free(novf)
        field = Z[3:V]
        rnd = Z[2]
        sticky = b.or_reduce(Z[:2])
        m_stored, cr = _round_rne(b, field, rnd, sticky)
        e1 = add_bit(b, ext(b, e_big, ne + 2), ovf)
        e3 = add_bit(b, e1, cr)
        b.free(e1)
        nz = b.or_reduce(Z)
        s_out = b.and_(s_big, nz)

    e_out = _mask_zero(b, nz, e3[:ne])
    m_out = _mask_zero(b, nz, m_stored)
    return m_out + e_out + [s_out]


# --------------------------------------------------------------------------
# §4.6: floating-point multiplication / division
# --------------------------------------------------------------------------

def fp_mul(b: Builder, fmt: FloatFormat, x: List[int], y: List[int],
           karatsuba: bool = True) -> List[int]:
    nm, ne = fmt.nm, fmt.ne
    sx, ex, Mx = _unpack(b, fmt, x)
    sy, ey, My = _unpack(b, fmt, y)

    from .bitserial import mul_shift_add
    P = (mul_karatsuba(b, Mx, My) if karatsuba else mul_shift_add(b, Mx, My))
    ovf = P[2 * nm + 1]                       # product in [2,4)
    # 1-bit normalization (var shift with Nt=1): align MSB to top
    Ps = b.vec_mux(ovf, P, [b.const(0)] + P[:-1])
    field = Ps[nm + 1:]
    rnd = Ps[nm]
    sticky = b.or_reduce(Ps[:nm])
    m_stored, cr = _round_rne(b, field, rnd, sticky)

    # e = ex + ey - bias + ovf + cr
    e1, (c1, nc1) = ripple_add(b, ext(b, ex, ne + 2), ext(b, ey, ne + 2))
    b.free([c1, nc1])
    e2 = add_bit(b, e1, ovf)
    e3 = add_bit(b, e2, cr)
    e4, _ = sub(b, e3, b.vec_const(fmt.bias, ne + 2))
    b.free(e1 + e2 + e3)

    nz = b.and_(Mx[-1], My[-1])               # zero iff an input is zero
    s_out = b.and_(b.xor(sx, sy), nz)
    return _mask_zero(b, nz, m_stored) + _mask_zero(b, nz, e4[:ne]) + [s_out]


def fp_div(b: Builder, fmt: FloatFormat, x: List[int], y: List[int]
           ) -> List[int]:
    nm, ne = fmt.nm, fmt.ne
    sx, ex, Mx = _unpack(b, fmt, x)
    sy, ey, My = _unpack(b, fmt, y)

    _, ge = sub(b, Mx, My)
    lt = b.not_(ge)                            # 1 iff Mx < My (ratio < 1)
    z0 = b.const(0)
    cand0 = [z0] * (nm + 1) + Mx + [z0] * 2    # Mx << (nm+1)
    cand1 = [z0] * (nm + 2) + Mx + [z0]        # Mx << (nm+2)
    D = b.vec_mux(lt, cand1, cand0)            # width 2nm+4 = 2(nm+2)
    q, r = divide(b, D, My + [z0])             # N' = nm+2
    sticky = b.or_reduce(r)
    field = q[1:]
    rnd = q[0]
    m_stored, cr = _round_rne(b, field, rnd, sticky)

    # e = ex - ey + bias - lt + cr
    e1, _ = sub(b, ext(b, ex, ne + 2), ext(b, ey, ne + 2))
    e2, (c2, nc2) = ripple_add(b, e1, b.vec_const(fmt.bias, ne + 2))
    b.free([c2, nc2])
    e3, _ = sub(b, e2, ext(b, [lt], ne + 2))
    e4 = add_bit(b, e3, cr)
    b.free(e1 + e2 + e3)

    nz = Mx[-1]                                # x == 0 -> result 0
    s_out = b.and_(b.xor(sx, sy), nz)
    return _mask_zero(b, nz, m_stored) + _mask_zero(b, nz, e4[:ne]) + [s_out]


# --------------------------------------------------------------------------
# packaged programs
# --------------------------------------------------------------------------

@memoize_build
def build_var_shift(nx: int, nt: int, left: bool = False) -> Program:
    b = Builder()
    x = b.input("x", nx)
    t = b.input("t", nt)
    fn = var_shift_left if left else var_shift_right
    z, _ = fn(b, x, t, handle_overflow=True)
    b.output("z", z)
    return b.finish()


@memoize_build
def build_var_normalize(nx: int) -> Program:
    b = Builder()
    x = b.input("x", nx)
    z, t = var_normalize(b, x)
    b.output("z", z)
    b.output("t", t)
    return b.finish()


def _build_fp2(fn, fmt: FloatFormat, **kw) -> Program:
    b = Builder()
    x = b.input("x", fmt.nbits)
    y = b.input("y", fmt.nbits)
    z = fn(b, fmt, x, y, **kw)
    b.output("z", z)
    return b.finish()


@memoize_build
def build_fp_add(fmt: FloatFormat, signed: bool = True) -> Program:
    return _build_fp2(fp_add, fmt, signed=signed)


@memoize_build
def build_fp_mul(fmt: FloatFormat, karatsuba: bool = True) -> Program:
    return _build_fp2(fp_mul, fmt, karatsuba=karatsuba)


@memoize_build
def build_fp_div(fmt: FloatFormat) -> Program:
    return _build_fp2(fp_div, fmt)


@memoize_build
def build_fp_sub(fmt: FloatFormat) -> Program:
    """x - y == x + (-y): flip y's sign bit then signed add (paper §4.5)."""
    b = Builder()
    x = b.input("x", fmt.nbits)
    y = b.input("y", fmt.nbits)
    yneg = y[:-1] + [b.not_(y[-1])]
    z = fp_add(b, fmt, x, yneg, signed=True)
    b.output("z", z)
    return b.finish()
