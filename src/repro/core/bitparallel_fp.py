"""Bit-parallel element-parallel floating-point arithmetic (paper §6).

Merges §4 (bit-serial FP) with §5 (bit-parallel fixed point): the same
exactly-rounded FP skeletons with every sub-routine swapped for its
partition-parallel counterpart:

  * :func:`bp_var_shift_right` -- Algorithm 6.1: generalized shift technique
    (2^j + 1 cycles) + broadcast of t_j + a parallel 1-bit multiplexer per
    partition; O(Nx + log^2 Nx) cycles.
  * :func:`bp_var_normalize` -- adds the reduction technique for
    t_j = NOR(top 2^j bits).
  * :func:`bp_fp_add` / :func:`bp_fp_mul` / :func:`bp_fp_div`.

Floats are stored strided (bit i in partition i, paper §6); internal wide
registers use k >= 2nm+5 partitions (k > N is trivially supported, paper
fn. 9).  Slot-relocation moves (pshift) keep interacting registers
partition-co-located; their cycle cost is charged honestly.  Results remain
exactly IEEE-754 RNE.
"""

from __future__ import annotations

from typing import List, Tuple

from .bitparallel import bp_add, bp_div, bp_mul, bp_sub
from .floatfmt import FloatFormat
from .gates import Program, memoize_build
from .partitions import PartitionedBuilder, broadcast, pshift, reduce_tree


def _clog2(n: int) -> int:
    return max(1, (n - 1).bit_length())


# --------------------------------------------------------------------------
# parallel vector helpers
# --------------------------------------------------------------------------

def bp_vec_mux(pb, sel, a, b):
    """per-slot (a if sel else b); sel broadcast once, then 2 cycles."""
    bb = broadcast(pb, sel)
    parts = [pb.part(c) for c in a]
    with pb.cycle():
        ns = [pb.not_(bb[p], p_out=p) for p in parts]
    with pb.cycle():
        out = [pb.muxn_(bb[parts[i]], ns[i], a[i], b[i], p_out=parts[i])
               for i in range(len(a))]
    pb.pfree(ns + list(set(bb)))
    return out


def bp_bit_op(pb, op, bits, sel):
    """per-slot op(bit, sel) with sel broadcast (op in {and, or, xor})."""
    fn = {"and": pb.and_, "or": pb.or_, "xor": pb.xor_}[op]
    bb = broadcast(pb, sel)
    parts = [pb.part(c) for c in bits]
    with pb.cycle():
        out = [fn(bits[i], bb[parts[i]], p_out=parts[i])
               for i in range(len(bits))]
    pb.pfree(list(set(bb)))
    return out


def bp_add_bit(pb, v, bit) -> Tuple[List[int], int]:
    """v + bit via the prefix adder; returns (sum, carry-out)."""
    zeros = [pb.const(0, pb.part(c)) for c in v]
    return bp_add(pb, v, zeros, cin=bit)


def bp_abs(pb, v) -> Tuple[List[int], int]:
    s = v[-1]
    x = bp_bit_op(pb, "xor", v, s)
    out, _ = bp_add_bit(pb, x, s)
    pb.pfree(x)
    return out, s


def bp_clamp(pb, t, tmax: int) -> List[int]:
    cvec = [pb.const((tmax >> i) & 1, pb.part(c)) for i, c in enumerate(t)]
    _, ge = bp_sub(pb, t, cvec)
    return bp_vec_mux(pb, ge, cvec, t)


def _move(pb, cell, p):
    return cell if pb.part(cell) == p else pb.id_(cell, p_out=p)


def relocate(pb, reg, delta):
    """Move a contiguous register's bits by ``delta`` partitions (+ = up),
    preserving length.  |delta|+1 cycles (generalized shift technique)."""
    if delta == 0:
        return list(reg)
    base = pb.part(reg[0])
    n = len(reg)
    if delta > 0:
        top = pb.part(reg[-1])
        padded = list(reg) + [pb.const(0, top + 1 + i) for i in range(delta)]
        return pshift(pb, padded, +delta, fill=None)[delta:]
    d = -delta
    padded = [pb.const(0, base - d + i) for i in range(d)] + list(reg)
    return pshift(pb, padded, delta, fill=None)[:n]


def _econst(pb, val, slots):
    return [pb.const((val >> i) & 1, pb.part(c)) for i, c in enumerate(slots)]


# --------------------------------------------------------------------------
# Algorithm 6.1 (+ normalization)
# --------------------------------------------------------------------------

def bp_var_shift_right(pb, x, t):
    nx = len(x)
    lg = _clog2(nx)
    z = list(x)
    for j in range(min(len(t), lg)):
        zs = pshift(pb, z, -(1 << j), fill=0)     # generalized shift
        bb = broadcast(pb, t[j])                  # t_j to all partitions
        parts = [pb.part(c) for c in z]
        with pb.cycle():
            ns = [pb.not_(bb[p], p_out=p) for p in parts]
        oldz = z
        with pb.cycle():
            z = [pb.muxn_(bb[parts[i]], ns[i], zs[i], z[i], p_out=parts[i])
                 for i in range(nx)]
        pb.pfree(ns + zs + [c for c in oldz if c not in x] + list(set(bb)))
    return z


def bp_var_normalize(pb, x):
    """z = x << lz(x); also returns the lz bits (partition of computation).
    t_j = NOR of the top 2^j slots via the reduction technique."""
    nx = len(x)
    lg = _clog2(nx)
    z = list(x)
    tbits = [None] * lg
    for j in reversed(range(lg)):
        step = 1 << j
        window = z[nx - step:]
        red = reduce_tree(pb, list(window), "or") if len(window) > 1 \
            else pb.id_(window[0], p_out=pb.part(window[0]))
        tj = pb.not_(red, p_out=pb.part(red))
        pb.pfree(red)
        zs = pshift(pb, z, +step, fill=0)
        bb = broadcast(pb, tj)
        parts = [pb.part(c) for c in z]
        with pb.cycle():
            ns = [pb.not_(bb[p], p_out=p) for p in parts]
        oldz = z
        with pb.cycle():
            z = [pb.muxn_(bb[parts[i]], ns[i], zs[i], z[i], p_out=parts[i])
                 for i in range(nx)]
        pb.pfree(ns + zs + [c for c in oldz if c not in x] + list(set(bb)))
        tbits[j] = tj
    return z, tbits


# --------------------------------------------------------------------------
# floating-point helpers
# --------------------------------------------------------------------------

def _bp_unpack(pb, fmt: FloatFormat, v):
    nm, ne = fmt.nm, fmt.ne
    m, e, s = v[:nm], v[nm:nm + ne], v[nm + ne]
    hid = reduce_tree(pb, list(e), "or")          # nonzero exponent
    hid = _move(pb, hid, nm)                      # hidden bit at slot nm
    return s, e, m + [hid]


def _bp_round_rne(pb, field, rnd, sticky) -> Tuple[List[int], int]:
    """RNE increment; returns (stored mantissa bits, exponent carry)."""
    p0 = pb.part(field[0])
    sticky = _move(pb, sticky, p0)
    rnd = _move(pb, rnd, p0)
    t = pb.or_(sticky, field[0], p_out=p0)
    up = pb.and_(rnd, t, p_out=p0)
    pb.pfree(t)
    inc, cr = bp_add_bit(pb, field, up)
    return inc[: len(field) - 1], cr


def _bp_mask(pb, nz, bits):
    return bp_bit_op(pb, "and", bits, nz)


# --------------------------------------------------------------------------
# bit-parallel FP add / mul / div
# --------------------------------------------------------------------------

def bp_fp_add(pb, fmt: FloatFormat, x, y) -> List[int]:
    """Signed bit-parallel FP addition: Alg 4.2/§4.5 skeleton over the §5
    toolbox + Alg 6.1 shift/normalize."""
    nm, ne = fmt.nm, fmt.ne
    sx, ex, Mx = _bp_unpack(pb, fmt, x)
    sy, ey, My = _bp_unpack(pb, fmt, y)
    V = nm + 4
    etop = nm + ne  # exponent slots nm..nm+ne-1; extensions at nm+ne, +1

    # exponent difference + conditional swap
    de, _ = bp_sub(pb, ex + [pb.const(0, etop)], ey + [pb.const(0, etop)])
    swap = de[ne]
    e_big = bp_vec_mux(pb, swap, ey, ex)
    M_big = bp_vec_mux(pb, swap, My, Mx)
    M_small = bp_vec_mux(pb, swap, Mx, My)
    nswap = pb.not_(swap, p_out=pb.part(swap))
    s_big = pb.muxn_(swap, nswap, sy, sx, p_out=pb.part(sx))
    pb.pfree(nswap)

    # |de| clamped to nm+4 (larger shifts land entirely in the sticky tail)
    tmag, _ = bp_abs(pb, de)
    tc = bp_clamp(pb, tmag, nm + 4)
    pb.pfree(tmag + de)

    # alignment: place M_small at slots nm+4..2nm+4 of a 2nm+5-slot register
    # (keeps every shifted-out bit), variable-shift right by t, then pull the
    # V-slot window back down so it is co-located with the big operand.
    wide = M_small + [pb.const(0, nm + 1 + i) for i in range(nm + 4)]
    up = pshift(pb, wide, +(nm + 4), fill=0)
    Y = bp_var_shift_right(pb, up, tc)
    tail = reduce_tree(pb, Y[: nm + 1], "or")     # bits below S -> sticky
    A = pshift(pb, Y, -(nm + 1), fill=None)[: V]  # window to slots 0..V-1
    tail = _move(pb, tail, pb.part(A[0]))
    A[0] = pb.or_(A[0], tail, p_out=pb.part(A[0]))
    pb.pfree([tail] + Y + up)
    # big operand: [1.m | G R S] -> mantissa relocated up 3 slots
    Bm = pshift(pb, M_big + [pb.const(0, nm + 1 + i) for i in range(3)],
                +3, fill=None)
    B = [pb.const(0, j) for j in range(3)] + Bm[3:]

    # effective add/subtract over V+1 slots (two's complement)
    eop = pb.xor_(sx, sy, p_out=pb.part(sx))
    Ax = bp_bit_op(pb, "xor", A + [pb.const(0, V)], eop)
    R, _ = bp_add(pb, B + [pb.const(0, V)], Ax, cin=eop)
    eV = _move(pb, eop, pb.part(R[V]))
    neg = pb.and_(R[V], eV, p_out=pb.part(R[V]))
    Rx = bp_bit_op(pb, "xor", R, neg)
    Rn, _ = bp_add_bit(pb, Rx, neg)
    pb.pfree(Rx + R + Ax + [eV] + A)

    # uniform normalization: lz=0 carry-out, lz=1 aligned, lz>1 cancellation
    Z, lz = bp_var_normalize(pb, Rn)
    pb.pfree(Rn)
    field = Z[4: V + 1]
    rnd = Z[3]
    sticky = reduce_tree(pb, Z[:3], "or")
    m_hi, cr = _bp_round_rne(pb, field, rnd, sticky)
    m_stored = relocate(pb, m_hi, -4)               # canonical slots 0..nm-1

    # e_out = e_big + 1 + cr - lz   (exponent slots)
    eslots = e_big + [pb.const(0, etop), pb.const(0, etop + 1)]
    lzs = [_move(pb, t, nm + i) for i, t in enumerate(lz)]
    lze = lzs + [pb.const(0, pb.part(c)) for c in eslots[len(lzs):]]
    e1, _ = bp_add(pb, eslots, _econst(pb, 1, eslots), cin=cr)
    e2, _ = bp_sub(pb, e1, lze)
    pb.pfree(e1)

    nz = reduce_tree(pb, list(Z), "or")
    nzs = _move(pb, nz, pb.part(s_big))
    negs = _move(pb, neg, pb.part(s_big))
    sg = pb.xor_(s_big, negs, p_out=pb.part(s_big))
    s_out = pb.and_(sg, nzs, p_out=pb.part(s_big))
    m_out = _bp_mask(pb, nz, m_stored)
    e_out = _bp_mask(pb, nz, e2[:ne])
    return m_out + e_out + [s_out]


def bp_fp_mul(pb, fmt: FloatFormat, x, y) -> List[int]:
    nm, ne = fmt.nm, fmt.ne
    sx, ex, Mx = _bp_unpack(pb, fmt, x)
    sy, ey, My = _bp_unpack(pb, fmt, y)
    n = nm + 1

    w, zlo = bp_mul(pb, Mx, My)                 # (w|zlo), both at slots 0..n-1
    wr = pshift(pb, w + [pb.const(0, n + i) for i in range(n)], +n, fill=None)
    P = zlo + wr[n:]                            # 2n slots, partitions 0..2n-1
    pb.pfree(w)
    ovf = P[2 * nm + 1]
    Ps = bp_vec_mux(pb, ovf, P, pshift(pb, P, +1, fill=0))
    field = Ps[nm + 1:]
    rnd = Ps[nm]
    sticky = reduce_tree(pb, Ps[:nm], "or")
    m_hi, cr = _bp_round_rne(pb, field, rnd, sticky)
    m_stored = relocate(pb, m_hi, -(nm + 1))        # to slots 0..nm-1

    # e = ex + ey - bias + ovf + cr
    eslots = [pb.const(0, nm + ne), pb.const(0, nm + ne + 1)]
    ovfe = _move(pb, ovf, nm)
    e1, _ = bp_add(pb, ex + eslots[:1] + eslots[1:],
                   ey + [pb.const(0, nm + ne), pb.const(0, nm + ne + 1)],
                   cin=ovfe)
    e2, _ = bp_add_bit(pb, e1, cr)
    e3, _ = bp_sub(pb, e2, _econst(pb, fmt.bias, e2))
    pb.pfree(e1 + e2)

    hx, hy = Mx[-1], My[-1]
    hye = _move(pb, hy, pb.part(hx))
    nz = pb.and_(hx, hye, p_out=pb.part(hx))
    sye = _move(pb, sy, pb.part(sx))
    sg = pb.xor_(sx, sye, p_out=pb.part(sx))
    nzs = _move(pb, nz, pb.part(sg))
    s_out = pb.and_(sg, nzs, p_out=pb.part(sg))
    return _bp_mask(pb, nz, m_stored) + _bp_mask(pb, nz, e3[:ne]) + [s_out]


def bp_fp_div(pb, fmt: FloatFormat, x, y) -> List[int]:
    nm, ne = fmt.nm, fmt.ne
    sx, ex, Mx = _bp_unpack(pb, fmt, x)
    sy, ey, My = _bp_unpack(pb, fmt, y)
    npr = nm + 2                                  # divider width N'

    _, ge = bp_sub(pb, Mx, My)
    lt = pb.not_(ge, p_out=pb.part(ge))
    # dividend D = Mx << (nm+1+lt) as (z_lo | z_hi), N' bits each:
    #   lt=0: z_hi[j-1]=Mx[j] (shift down 1), z_lo[nm+1]=Mx[0]
    #   lt=1: z_hi[j]  =Mx[j],                z_lo = 0
    mx_dn = pshift(pb, Mx + [pb.const(0, nm + 1)], -1, fill=0)
    cand1 = Mx + [pb.const(0, nm + 1)]
    z_hi = bp_vec_mux(pb, lt, cand1, mx_dn)
    nlt = pb.not_(lt, p_out=pb.part(lt))
    mx0 = _move(pb, Mx[0], nm + 1)
    nlt1 = _move(pb, nlt, nm + 1)
    z_top = pb.and_(mx0, nlt1, p_out=nm + 1)
    z_lo = [pb.const(0, j) for j in range(nm + 1)] + [z_top]
    q, r = bp_div(pb, z_lo + z_hi, My + [pb.const(0, nm + 1)])

    sticky = reduce_tree(pb, r, "or")
    field = q[1:]                                  # slots 1..nm+1
    rnd = q[0]
    m_hi, cr = _bp_round_rne(pb, field, rnd, sticky)
    m_stored = relocate(pb, m_hi, -1)

    # e = ex - ey + bias - lt + cr
    ez = lambda: [pb.const(0, nm + ne), pb.const(0, nm + ne + 1)]
    e1, _ = bp_sub(pb, ex + ez(), ey + ez())
    e2, _ = bp_add(pb, e1, _econst(pb, fmt.bias, e1), cin=cr)
    lte = [_move(pb, lt, nm)] + [pb.const(0, pb.part(c)) for c in e2[1:]]
    e3, _ = bp_sub(pb, e2, lte)
    pb.pfree(e1 + e2)

    nz = Mx[-1]
    sye = _move(pb, sy, pb.part(sx))
    sg = pb.xor_(sx, sye, p_out=pb.part(sx))
    nzs = _move(pb, nz, pb.part(sg))
    s_out = pb.and_(sg, nzs, p_out=pb.part(sg))
    return _bp_mask(pb, nz, m_stored) + _bp_mask(pb, nz, e3[:ne]) + [s_out]


# --------------------------------------------------------------------------
# packaged programs
# --------------------------------------------------------------------------

def _k_for(fmt: FloatFormat, op: str) -> int:
    if op == "add":
        return 2 * fmt.nm + 5
    if op == "mul":
        return max(2 * fmt.nm + 2, fmt.nm + fmt.ne + 2)
    return max(fmt.nm + 4 + 2, fmt.nm + fmt.ne + 2)   # div: k >= N'+2


@memoize_build
def build_bp_var_shift(nx: int, nt: int, cpk: int = 128) -> Program:
    pb = PartitionedBuilder(nx, cpk)
    x = pb.input("x", range(nx))
    t = pb.input("t", range(min(nt, nx)))
    z = bp_var_shift_right(pb, x, t)
    pb.output("z", z)
    return pb.finish()


@memoize_build
def build_bp_var_normalize(nx: int, cpk: int = 128) -> Program:
    pb = PartitionedBuilder(nx, cpk)
    x = pb.input("x", range(nx))
    z, t = bp_var_normalize(pb, x)
    pb.output("z", z)
    pb.output("t", t)
    return pb.finish()


def _build_bp_fp(fn, fmt: FloatFormat, op: str, cpk: int) -> Program:
    pb = PartitionedBuilder(_k_for(fmt, op), cpk)
    x = pb.input("x", range(fmt.nbits))
    y = pb.input("y", range(fmt.nbits))
    z = fn(pb, fmt, x, y)
    pb.output("z", z)
    return pb.finish()


@memoize_build
def build_bp_fp_add(fmt: FloatFormat, cpk: int = 256) -> Program:
    return _build_bp_fp(bp_fp_add, fmt, "add", cpk)


@memoize_build
def build_bp_fp_mul(fmt: FloatFormat, cpk: int = 384) -> Program:
    return _build_bp_fp(bp_fp_mul, fmt, "mul", cpk)


@memoize_build
def build_bp_fp_div(fmt: FloatFormat, cpk: int = 512) -> Program:
    return _build_bp_fp(bp_fp_div, fmt, "div", cpk)
