"""Gate-level IR for the AritPIM abstract machine.

The paper's abstract model (Fig. 1e): memory is a collection of arrays of
``r x c`` bits; one bitwise *column* operation (e.g. NOR of two columns into a
third) executes per cycle, in parallel over all rows and all arrays.  An
arithmetic algorithm is therefore a straight-line *gate program* over cell
(column) indices of a single row; element parallelism is the trivial
replication of that program over rows.

Two levels of IR:

* **abstract programs** -- instructions drawn from ``G`` (NOT/NOR/AND/OR/XOR/
  XNOR/MUX/FA/...).  One instruction == one "step" in the paper's terminology.
* **NOR programs** -- the same program lowered to the stateful-logic gate set
  {INIT0, INIT1, NOT, NOR} actually supported by memristive PIM (MAGIC) and,
  with trivial substitutions, DRAM PIM.  One instruction == one cycle.

``Program`` carries named ports (cell ranges) so callers can write inputs /
read outputs without knowing the internal allocation, and a cost model
(abstract steps, NOR gates, init cycles, cell footprint == area).
"""

from __future__ import annotations

import dataclasses
from enum import IntEnum
from typing import Dict, List, Optional, Sequence

import numpy as np


class G(IntEnum):
    INIT0 = 0   # out <- 0                     (memristive output init)
    INIT1 = 1   # out <- 1
    NOT = 2     # out <- ~a
    NOR = 3     # out <- ~(a | b)
    OR = 4      # out <- a | b
    AND = 5     # out <- a & b
    NAND = 6    # out <- ~(a & b)
    XOR = 7     # out <- a ^ b
    XNOR = 8    # out <- ~(a ^ b)
    MUX = 9     # out <- a if s else b     ins = (s, a, b)
    MUXN = 10   # mux with precomputed ~s  ins = (s, ns, a, b)
    FA = 11     # out,out2 <- sum,carry    ins = (a, b, c)
    FACC = 12   # carry-complement FA      ins = (a, b, c, nc) outs = (sum, cout, ncout)
    ID = 13     # out <- a                 (copy)


# NOR-lowering cost (gates) per abstract op; INIT cycles equal the number of
# *written* cells (output init) per lowered NOR/NOT gate and are reported
# separately -- see CostModel.
_NOR_GATES = {
    G.INIT0: 0, G.INIT1: 0, G.NOT: 1, G.NOR: 1, G.OR: 2, G.AND: 3,
    G.NAND: 4, G.XOR: 5, G.XNOR: 4, G.MUX: 4, G.MUXN: 3, G.FA: 12,
    G.FACC: 11, G.ID: 2,
}

# Paper fn. 14 normalizes every compared algorithm to a 9-NOR full adder; we
# report both our concrete netlist cost and the normalized cost.
FA_NORS_NORMALIZED = 9


@dataclasses.dataclass
class Instr:
    op: int
    ins: tuple        # cell ids (length depends on op)
    outs: tuple       # cell ids


@dataclasses.dataclass
class Cost:
    abstract_steps: int
    nor_gates: int
    nor_gates_normalized: int   # FAs counted at 9 NORs (paper's convention)
    init_cycles: int
    cells: int                  # peak cell footprint (area proxy)

    def as_dict(self):
        return dataclasses.asdict(self)


class Program:
    """A straight-line gate program over cells of one row."""

    def __init__(self, n_cells: int, instrs: List[Instr],
                 ports: Dict[str, List[int]], parallel_steps=None):
        self.n_cells = n_cells
        self.instrs = instrs
        self.ports = ports          # name -> list of cell ids (LSB first)
        # bit-parallel programs: list of (list of instr indices) per cycle,
        # None for purely serial programs.
        self.parallel_steps = parallel_steps

    # ------------------------------------------------------------------ cost
    def cost(self) -> Cost:
        steps = 0
        nor = 0
        nor_norm = 0
        init = 0
        for ins in self.instrs:
            op = ins.op
            if op in (G.INIT0, G.INIT1):
                init += 1
                continue
            steps += 1
            g = _NOR_GATES[op]
            nor += g
            nor_norm += FA_NORS_NORMALIZED if op in (G.FA, G.FACC) else g
            init += g  # each lowered NOR/NOT writes one freshly-initialized cell
        return Cost(steps, nor, nor_norm, init, self.n_cells)

    def parallel_cost(self) -> Optional[Cost]:
        """Latency when executed under the partition schedule: per cycle the
        *maximum* NOR depth among concurrent gates (sections run in parallel,
        each section serially evaluating its gate's NOR decomposition)."""
        if self.parallel_steps is None:
            return None
        steps = len(self.parallel_steps)
        nor = 0
        nor_norm = 0
        init = 0
        for idxs in self.parallel_steps:
            ops = [self.instrs[i].op for i in idxs]
            ops = [o for o in ops if o not in (G.INIT0, G.INIT1)]
            if not ops:
                init += 1
                continue
            nor += max(_NOR_GATES[o] for o in ops)
            nor_norm += max(
                FA_NORS_NORMALIZED if o in (G.FA, G.FACC) else _NOR_GATES[o]
                for o in ops)
            init += max(_NOR_GATES[o] for o in ops)
        return Cost(steps, nor, nor_norm, init, self.n_cells)

    # ----------------------------------------------------------------- exec
    def exec_row(self, inputs: Dict[str, int]) -> Dict[str, int]:
        """Reference single-row execution; integers in/out per port."""
        state = np.zeros(self.n_cells, dtype=bool)
        for name, val in inputs.items():
            for k, cell in enumerate(self.ports[name]):
                state[cell] = (val >> k) & 1
        _exec_bool(self.instrs, state)
        out = {}
        for name, cells in self.ports.items():
            out[name] = sum(int(state[c]) << k for k, c in enumerate(cells))
        return out

    def exec_packed(self, state: np.ndarray) -> np.ndarray:
        """Element-parallel execution over bit-packed rows.

        ``state``: uint32[n_words, n_cells]; bit ``w`` of ``state[i, c]`` is
        cell ``c`` of row ``32*i + w``.  Mutated in place and returned.
        """
        assert state.dtype == np.uint32 and state.shape[1] == self.n_cells
        _exec_packed(self.instrs, state)
        return state

    # ------------------------------------------------------------- lowering
    def lower_to_nor(self) -> "Program":
        """Lower to the {INIT0, INIT1, NOT, NOR} gate set."""
        b = Builder(reserve=self.n_cells)
        for ins in self.instrs:
            _lower_instr(b, ins)
        return Program(b.n_cells, b.instrs, dict(self.ports))

    def to_arrays(self):
        """Dense (op, a, b, out) int32 arrays of the NOR-lowered program, the
        transport format consumed by the Pallas executor."""
        low = self.lower_to_nor()
        ops, aa, bb, oo = [], [], [], []
        for ins in low.instrs:
            op = ins.op
            if op in (G.INIT0, G.INIT1):
                ops.append(int(op)); aa.append(0); bb.append(0)
            elif op == G.NOT:
                ops.append(int(op)); aa.append(ins.ins[0]); bb.append(ins.ins[0])
            else:
                assert op == G.NOR, op
                ops.append(int(op)); aa.append(ins.ins[0]); bb.append(ins.ins[1])
            oo.append(ins.outs[0])
        return (np.asarray(ops, np.int32), np.asarray(aa, np.int32),
                np.asarray(bb, np.int32), np.asarray(oo, np.int32),
                low.n_cells)


# --------------------------------------------------------------------------
# execution helpers
# --------------------------------------------------------------------------

def _gate_eval(op, vals):
    if op == G.NOT:
        return ~vals[0]
    if op == G.NOR:
        return ~(vals[0] | vals[1])
    if op == G.OR:
        return vals[0] | vals[1]
    if op == G.AND:
        return vals[0] & vals[1]
    if op == G.NAND:
        return ~(vals[0] & vals[1])
    if op == G.XOR:
        return vals[0] ^ vals[1]
    if op == G.XNOR:
        return ~(vals[0] ^ vals[1])
    if op == G.MUX:
        s, a, b = vals
        return (s & a) | (~s & b)
    if op == G.MUXN:
        s, ns, a, b = vals
        return (s & a) | (ns & b)
    if op == G.ID:
        return vals[0]
    raise ValueError(op)


def _exec_generic(instrs, state, zero, one):
    for ins in instrs:
        op = ins.op
        if op == G.INIT0:
            state[ins.outs[0]] = zero
        elif op == G.INIT1:
            state[ins.outs[0]] = one
        elif op == G.FA:
            a, b, c = (state[i] for i in ins.ins)
            state[ins.outs[0]] = a ^ b ^ c
            state[ins.outs[1]] = (a & b) | (a & c) | (b & c)
        elif op == G.FACC:
            a, b, c, _nc = (state[i] for i in ins.ins)
            s = a ^ b ^ c
            co = (a & b) | (a & c) | (b & c)
            state[ins.outs[0]] = s
            state[ins.outs[1]] = co
            state[ins.outs[2]] = ~co
        else:
            state[ins.outs[0]] = _gate_eval(op, [state[i] for i in ins.ins])


def _exec_bool(instrs, state):
    _exec_generic(instrs, state, False, True)


def _exec_packed(instrs, state):
    # state: uint32[n_words, n_cells]; operate on columns state[:, c].
    cols = state.T  # view: [n_cells, n_words]
    zero = np.uint32(0)
    one = np.uint32(0xFFFFFFFF)
    full = np.full(state.shape[0], one, np.uint32)
    _exec_generic(instrs, cols, zero, full)


# --------------------------------------------------------------------------
# builder
# --------------------------------------------------------------------------

class Builder:
    """Allocates cells and appends instructions.

    Cells are integers; ``free`` returns intermediates to a free list so the
    peak footprint (area) stays honest.  ``vec`` helpers treat ``list[int]``
    as little-endian bit vectors.
    """

    def __init__(self, reserve: int = 0):
        self.n_cells = reserve
        self.instrs: List[Instr] = []
        self._free: List[int] = []
        self._const = {}
        self.ports: Dict[str, List[int]] = {}
        self._steps: Optional[List[List[int]]] = None  # parallel schedule

    # --------------------------------------------------------- cell mgmt
    def alloc(self, n: int = 1):
        out = []
        for _ in range(n):
            if self._free:
                out.append(self._free.pop())
            else:
                out.append(self.n_cells)
                self.n_cells += 1
        return out if n != 1 else out[0]

    def free(self, cells):
        if isinstance(cells, int):
            cells = [cells]
        port_cells = {c for v in self.ports.values() for c in v}
        for c in set(cells):
            if c in self._const.values() or c in port_cells \
                    or c in self._free:
                continue
            self._free.append(c)

    def input(self, name: str, n: int) -> List[int]:
        v = [self.alloc() for _ in range(n)]
        self.ports[name] = v
        return v

    def output(self, name: str, cells: Sequence[int]):
        self.ports[name] = list(cells)

    # ---------------------------------------------------------- emission
    def emit(self, op, ins, outs):
        self.instrs.append(Instr(op, tuple(ins), tuple(outs)))
        if self._steps is not None:
            self._steps.append([len(self.instrs) - 1])
        return outs[0] if len(outs) == 1 else outs

    def const(self, bit: int) -> int:
        if bit not in self._const:
            c = self.alloc()
            self.emit(G.INIT1 if bit else G.INIT0, (), (c,))
            self._const[bit] = c
        return self._const[bit]

    def _unary(self, op, a):
        return self.emit(op, (a,), (self.alloc(),))

    def _binary(self, op, a, b):
        return self.emit(op, (a, b), (self.alloc(),))

    def not_(self, a): return self._unary(G.NOT, a)
    def id_(self, a): return self._unary(G.ID, a)
    def nor(self, a, b): return self._binary(G.NOR, a, b)
    def or_(self, a, b): return self._binary(G.OR, a, b)
    def and_(self, a, b): return self._binary(G.AND, a, b)
    def nand(self, a, b): return self._binary(G.NAND, a, b)
    def xor(self, a, b): return self._binary(G.XOR, a, b)
    def xnor(self, a, b): return self._binary(G.XNOR, a, b)

    def mux(self, s, a, b):
        """out <- a if s else b."""
        return self.emit(G.MUX, (s, a, b), (self.alloc(),))

    def muxn(self, s, ns, a, b):
        """mux with hoisted ~s (3 NORs instead of 4; Alg 4.1 amortization)."""
        return self.emit(G.MUXN, (s, ns, a, b), (self.alloc(),))

    def fa(self, a, b, c):
        s, co = self.alloc(), self.alloc()
        self.emit(G.FA, (a, b, c), (s, co))
        return s, co

    def facc(self, a, b, c, nc):
        s, co, nco = self.alloc(), self.alloc(), self.alloc()
        self.emit(G.FACC, (a, b, c, nc), (s, co, nco))
        return s, co, nco

    # ------------------------------------------------------- vector ops
    def vec_input(self, name, n):
        return self.input(name, n)

    def vec_const(self, value: int, n: int) -> List[int]:
        return [self.const((value >> k) & 1) for k in range(n)]

    def vec_map(self, fn, *vecs):
        n = len(vecs[0])
        assert all(len(v) == n for v in vecs)
        return [fn(*(v[i] for v in vecs)) for i in range(n)]

    def vec_xor(self, x, y): return self.vec_map(self.xor, x, y)
    def vec_and(self, x, y): return self.vec_map(self.and_, x, y)
    def vec_or(self, x, y): return self.vec_map(self.or_, x, y)
    def vec_not(self, x): return self.vec_map(self.not_, x)
    def vec_id(self, x): return self.vec_map(self.id_, x)

    def vec_and_bit(self, x, bit):
        return [self.and_(xi, bit) for xi in x]

    def vec_mux(self, s, a, b):
        """elementwise a if s else b, with ~s hoisted once."""
        ns = self.not_(s)
        out = [self.muxn(s, ns, ai, bi) for ai, bi in zip(a, b)]
        self.free(ns)
        return out

    def or_reduce(self, bits):
        acc = bits[0]
        first = True
        for b in bits[1:]:
            nxt = self.or_(acc, b)
            if not first:
                self.free(acc)
            acc, first = nxt, False
        return acc if not first else self.id_(acc)

    # ------------------------------------------------------ finalization
    def finish(self) -> Program:
        return Program(self.n_cells, self.instrs, dict(self.ports),
                       parallel_steps=self._steps)


# --------------------------------------------------------------------------
# NOR lowering
# --------------------------------------------------------------------------

def _lower_instr(b: Builder, ins: Instr):
    """Append the NOR/NOT/INIT expansion of ``ins`` to builder ``b`` writing
    results into the *original* output cells (cells ids are preserved because
    the builder was reserved with the abstract program's cell count)."""
    op = ins.op
    I, O = ins.ins, ins.outs

    def nor(a, bb, out=None):
        out = b.alloc() if out is None else out
        b.emit(G.NOR, (a, bb), (out,))
        return out

    def not_(a, out=None):
        out = b.alloc() if out is None else out
        b.emit(G.NOT, (a,), (out,))
        return out

    if op in (G.INIT0, G.INIT1):
        b.emit(op, (), O)
    elif op == G.NOT:
        not_(I[0], O[0])
    elif op == G.NOR:
        nor(I[0], I[1], O[0])
    elif op == G.OR:
        t = nor(I[0], I[1]); not_(t, O[0]); b.free(t)
    elif op == G.AND:
        na, nb = not_(I[0]), not_(I[1])
        nor(na, nb, O[0]); b.free([na, nb])
    elif op == G.NAND:
        na, nb = not_(I[0]), not_(I[1])
        t = nor(na, nb); not_(t, O[0]); b.free([na, nb, t])
    elif op == G.XNOR:
        n1 = nor(I[0], I[1]); n2 = nor(I[0], n1); n3 = nor(I[1], n1)
        nor(n2, n3, O[0]); b.free([n1, n2, n3])
    elif op == G.XOR:
        n1 = nor(I[0], I[1]); n2 = nor(I[0], n1); n3 = nor(I[1], n1)
        n4 = nor(n2, n3); not_(n4, O[0]); b.free([n1, n2, n3, n4])
    elif op in (G.MUX, G.MUXN):
        if op == G.MUX:
            s, a, c = I
            ns = not_(s); tmp_ns = True
        else:
            s, ns, a, c = I
            tmp_ns = False
        # out = (s&a)|(~s&c) = NOR(NOR(a, ns), NOR(c, s))
        t1 = nor(a, ns); t2 = nor(c, s)
        nor(t1, t2, O[0])
        b.free([t1, t2] + ([ns] if tmp_ns else []))
    elif op == G.ID:
        t = not_(I[0]); not_(t, O[0]); b.free(t)
    elif op in (G.FA, G.FACC):
        if op == G.FACC:
            a, x, c, ncin = I
            s_out, co_out, nco_out = O
        else:
            a, x, c = I
            s_out, co_out = O
            nco_out = None
            ncin = not_(c)
        # 11-gate carry-complement netlist (see DESIGN.md §7):
        n1 = nor(a, x)          # ~a~b
        n2 = nor(a, n1)         # ~a b
        n3 = nor(x, n1)         # a ~b
        n4 = nor(n2, n3)        # xnor
        xo = not_(n4)           # xor
        t1 = nor(n4, ncin)      # xor & c
        t2 = nor(xo, c)         # ~xor & ~c
        ab = nor(n1, xo)        # a & b
        nco = nor(ab, t1, out=nco_out)  # ~cout (fresh cell if nco_out is None)
        not_(nco, co_out)
        nor(t1, t2, s_out)      # sum = ~(xor&c | ~xor&~c) = xor ^ c
        b.free([n1, n2, n3, n4, xo, t1, t2, ab])
        if nco_out is None:
            b.free([nco, ncin])
    else:
        raise ValueError(op)
