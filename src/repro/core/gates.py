"""Gate-level IR for the AritPIM abstract machine.

The paper's abstract model (Fig. 1e): memory is a collection of arrays of
``r x c`` bits; one bitwise *column* operation (e.g. NOR of two columns into a
third) executes per cycle, in parallel over all rows and all arrays.  An
arithmetic algorithm is therefore a straight-line *gate program* over cell
(column) indices of a single row; element parallelism is the trivial
replication of that program over rows.

Two levels of IR:

* **abstract programs** -- instructions drawn from ``G`` (NOT/NOR/AND/OR/XOR/
  XNOR/MUX/FA/...).  One instruction == one "step" in the paper's terminology.
* **NOR programs** -- the same program lowered to the stateful-logic gate set
  {INIT0, INIT1, NOT, NOR} actually supported by memristive PIM (MAGIC) and,
  with trivial substitutions, DRAM PIM.  One instruction == one cycle.

``Program`` carries named ports (cell ranges) so callers can write inputs /
read outputs without knowing the internal allocation, and a cost model
(abstract steps, NOR gates, init cycles, cell footprint == area).
"""

from __future__ import annotations

import dataclasses
import functools
import heapq
from enum import IntEnum
from typing import Dict, List, Optional, Sequence

import numpy as np


class G(IntEnum):
    INIT0 = 0   # out <- 0                     (memristive output init)
    INIT1 = 1   # out <- 1
    NOT = 2     # out <- ~a
    NOR = 3     # out <- ~(a | b)
    OR = 4      # out <- a | b
    AND = 5     # out <- a & b
    NAND = 6    # out <- ~(a & b)
    XOR = 7     # out <- a ^ b
    XNOR = 8    # out <- ~(a ^ b)
    MUX = 9     # out <- a if s else b     ins = (s, a, b)
    MUXN = 10   # mux with precomputed ~s  ins = (s, ns, a, b)
    FA = 11     # out,out2 <- sum,carry    ins = (a, b, c)
    FACC = 12   # carry-complement FA      ins = (a, b, c, nc) outs = (sum, cout, ncout)
    ID = 13     # out <- a                 (copy)


# NOR-lowering cost (gates) per abstract op; INIT cycles equal the number of
# *written* cells (output init) per lowered NOR/NOT gate and are reported
# separately -- see CostModel.
_NOR_GATES = {
    G.INIT0: 0, G.INIT1: 0, G.NOT: 1, G.NOR: 1, G.OR: 2, G.AND: 3,
    G.NAND: 4, G.XOR: 5, G.XNOR: 4, G.MUX: 4, G.MUXN: 3, G.FA: 12,
    G.FACC: 11, G.ID: 2,
}

# Paper fn. 14 normalizes every compared algorithm to a 9-NOR full adder; we
# report both our concrete netlist cost and the normalized cost.
FA_NORS_NORMALIZED = 9


@dataclasses.dataclass
class Instr:
    op: int
    ins: tuple        # cell ids (length depends on op)
    outs: tuple       # cell ids


@dataclasses.dataclass
class Cost:
    abstract_steps: int
    nor_gates: int
    nor_gates_normalized: int   # FAs counted at 9 NORs (paper's convention)
    init_cycles: int
    cells: int                  # peak cell footprint (area proxy)

    def as_dict(self):
        return dataclasses.asdict(self)


class Program:
    """A straight-line gate program over cells of one row."""

    def __init__(self, n_cells: int, instrs: List[Instr],
                 ports: Dict[str, List[int]], parallel_steps=None,
                 in_ports=None):
        self.n_cells = n_cells
        self.instrs = instrs
        self.ports = ports          # name -> list of cell ids (LSB first)
        # bit-parallel programs: list of (list of instr indices) per cycle,
        # None for purely serial programs.
        self.parallel_steps = parallel_steps
        # names of ports declared as inputs (the rest are outputs); empty
        # when direction is unknown (hand-built programs).
        self.in_ports = frozenset(in_ports or ())
        # abstract-instr -> [start, end) span in the lowered instr stream;
        # populated by lower_to_nor() on the *lowered* program.
        self.lowered_spans = None

    @property
    def out_ports(self) -> frozenset:
        """Names of the declared result ports; empty when the program is
        direction-less (no ``in_ports``).  Executors resolve the
        all-ports fallback for direction-less programs in exactly one
        place -- ``kernels.ops.output_names`` -- so every backend agrees."""
        return frozenset(n for n in self.ports if n not in self.in_ports)

    # ------------------------------------------------------------------ cost
    def cost(self) -> Cost:
        steps = 0
        nor = 0
        nor_norm = 0
        init = 0
        for ins in self.instrs:
            op = ins.op
            if op in (G.INIT0, G.INIT1):
                init += 1
                continue
            steps += 1
            g = _NOR_GATES[op]
            nor += g
            nor_norm += FA_NORS_NORMALIZED if op in (G.FA, G.FACC) else g
            init += g  # each lowered NOR/NOT writes one freshly-initialized cell
        return Cost(steps, nor, nor_norm, init, self.n_cells)

    def parallel_cost(self) -> Optional[Cost]:
        """Latency when executed under the partition schedule: per cycle the
        *maximum* NOR depth among concurrent gates (sections run in parallel,
        each section serially evaluating its gate's NOR decomposition)."""
        if self.parallel_steps is None:
            return None
        steps = len(self.parallel_steps)
        nor = 0
        nor_norm = 0
        init = 0
        for idxs in self.parallel_steps:
            ops = [self.instrs[i].op for i in idxs]
            ops = [o for o in ops if o not in (G.INIT0, G.INIT1)]
            if not ops:
                init += 1
                continue
            nor += max(_NOR_GATES[o] for o in ops)
            nor_norm += max(
                FA_NORS_NORMALIZED if o in (G.FA, G.FACC) else _NOR_GATES[o]
                for o in ops)
            init += max(_NOR_GATES[o] for o in ops)
        return Cost(steps, nor, nor_norm, init, self.n_cells)

    # ----------------------------------------------------------------- exec
    def exec_row(self, inputs: Dict[str, int]) -> Dict[str, int]:
        """Reference single-row execution; integers in/out per port."""
        state = np.zeros(self.n_cells, dtype=bool)
        for name, val in inputs.items():
            for k, cell in enumerate(self.ports[name]):
                state[cell] = (val >> k) & 1
        _exec_bool(self.instrs, state)
        out = {}
        for name, cells in self.ports.items():
            out[name] = sum(int(state[c]) << k for k, c in enumerate(cells))
        return out

    def exec_packed(self, state: np.ndarray) -> np.ndarray:
        """Element-parallel execution over bit-packed rows.

        ``state``: uint32[n_words, n_cells]; bit ``w`` of ``state[i, c]`` is
        cell ``c`` of row ``32*i + w``.  Mutated in place and returned.
        """
        assert state.dtype == np.uint32 and state.shape[1] == self.n_cells
        _exec_packed(self.instrs, state)
        return state

    # ------------------------------------------------------------- lowering
    def lower_to_nor(self) -> "Program":
        """Lower to the {INIT0, INIT1, NOT, NOR} gate set.

        The result records ``lowered_spans`` (abstract instr -> lowered
        range) so schedulers can map the builder's native ``parallel_steps``
        onto lowered gates.
        """
        b = Builder(reserve=self.n_cells)
        spans = []
        for ins in self.instrs:
            start = len(b.instrs)
            _lower_instr(b, ins)
            spans.append((start, len(b.instrs)))
        low = Program(b.n_cells, b.instrs, dict(self.ports),
                      in_ports=self.in_ports)
        low.lowered_spans = spans
        return low

    def schedule(self, mode: str = "asap", reuse_cells: bool = True,
                 max_width: Optional[int] = None) -> "LevelSchedule":
        """Levelized execution schedule of the NOR-lowered program (see
        :func:`levelize`)."""
        return levelize(self, mode=mode, reuse_cells=reuse_cells,
                        max_width=max_width)

    def to_arrays(self):
        """Dense (op, a, b, out) int32 arrays of the NOR-lowered program, the
        transport format consumed by the Pallas executor."""
        low = self.lower_to_nor()
        ops, aa, bb, oo = [], [], [], []
        for ins in low.instrs:
            op = ins.op
            if op in (G.INIT0, G.INIT1):
                ops.append(int(op)); aa.append(0); bb.append(0)
            elif op == G.NOT:
                ops.append(int(op)); aa.append(ins.ins[0]); bb.append(ins.ins[0])
            else:
                assert op == G.NOR, op
                ops.append(int(op)); aa.append(ins.ins[0]); bb.append(ins.ins[1])
            oo.append(ins.outs[0])
        return (np.asarray(ops, np.int32), np.asarray(aa, np.int32),
                np.asarray(bb, np.int32), np.asarray(oo, np.int32),
                low.n_cells)


# --------------------------------------------------------------------------
# execution helpers
# --------------------------------------------------------------------------

def _gate_eval(op, vals):
    if op == G.NOT:
        return ~vals[0]
    if op == G.NOR:
        return ~(vals[0] | vals[1])
    if op == G.OR:
        return vals[0] | vals[1]
    if op == G.AND:
        return vals[0] & vals[1]
    if op == G.NAND:
        return ~(vals[0] & vals[1])
    if op == G.XOR:
        return vals[0] ^ vals[1]
    if op == G.XNOR:
        return ~(vals[0] ^ vals[1])
    if op == G.MUX:
        s, a, b = vals
        return (s & a) | (~s & b)
    if op == G.MUXN:
        s, ns, a, b = vals
        return (s & a) | (ns & b)
    if op == G.ID:
        return vals[0]
    raise ValueError(op)


def _exec_generic(instrs, state, zero, one):
    for ins in instrs:
        op = ins.op
        if op == G.INIT0:
            state[ins.outs[0]] = zero
        elif op == G.INIT1:
            state[ins.outs[0]] = one
        elif op == G.FA:
            a, b, c = (state[i] for i in ins.ins)
            state[ins.outs[0]] = a ^ b ^ c
            state[ins.outs[1]] = (a & b) | (a & c) | (b & c)
        elif op == G.FACC:
            a, b, c, _nc = (state[i] for i in ins.ins)
            s = a ^ b ^ c
            co = (a & b) | (a & c) | (b & c)
            state[ins.outs[0]] = s
            state[ins.outs[1]] = co
            state[ins.outs[2]] = ~co
        else:
            state[ins.outs[0]] = _gate_eval(op, [state[i] for i in ins.ins])


def _exec_bool(instrs, state):
    _exec_generic(instrs, state, False, True)


def _exec_packed(instrs, state):
    # state: uint32[n_words, n_cells]; operate on columns state[:, c].
    cols = state.T  # view: [n_cells, n_words]
    zero = np.uint32(0)
    one = np.uint32(0xFFFFFFFF)
    full = np.full(state.shape[0], one, np.uint32)
    _exec_generic(instrs, cols, zero, full)


# --------------------------------------------------------------------------
# builder
# --------------------------------------------------------------------------

class Builder:
    """Allocates cells and appends instructions.

    Cells are integers; ``free`` returns intermediates to a free list so the
    peak footprint (area) stays honest.  ``vec`` helpers treat ``list[int]``
    as little-endian bit vectors.
    """

    def __init__(self, reserve: int = 0):
        self.n_cells = reserve
        self.instrs: List[Instr] = []
        self._free: List[int] = []
        self._const = {}
        self.ports: Dict[str, List[int]] = {}
        self.in_port_names: set = set()
        self._steps: Optional[List[List[int]]] = None  # parallel schedule

    # --------------------------------------------------------- cell mgmt
    def alloc(self, n: int = 1):
        out = []
        for _ in range(n):
            if self._free:
                out.append(self._free.pop())
            else:
                out.append(self.n_cells)
                self.n_cells += 1
        return out if n != 1 else out[0]

    def free(self, cells):
        if isinstance(cells, int):
            cells = [cells]
        port_cells = {c for v in self.ports.values() for c in v}
        for c in set(cells):
            if c in self._const.values() or c in port_cells \
                    or c in self._free:
                continue
            self._free.append(c)

    def input(self, name: str, n: int) -> List[int]:
        v = [self.alloc() for _ in range(n)]
        self.ports[name] = v
        self.in_port_names.add(name)
        return v

    def output(self, name: str, cells: Sequence[int]):
        self.ports[name] = list(cells)

    # ---------------------------------------------------------- emission
    def emit(self, op, ins, outs):
        self.instrs.append(Instr(op, tuple(ins), tuple(outs)))
        if self._steps is not None:
            self._steps.append([len(self.instrs) - 1])
        return outs[0] if len(outs) == 1 else outs

    def const(self, bit: int) -> int:
        if bit not in self._const:
            c = self.alloc()
            self.emit(G.INIT1 if bit else G.INIT0, (), (c,))
            self._const[bit] = c
        return self._const[bit]

    def _unary(self, op, a):
        return self.emit(op, (a,), (self.alloc(),))

    def _binary(self, op, a, b):
        return self.emit(op, (a, b), (self.alloc(),))

    def not_(self, a): return self._unary(G.NOT, a)
    def id_(self, a): return self._unary(G.ID, a)
    def nor(self, a, b): return self._binary(G.NOR, a, b)
    def or_(self, a, b): return self._binary(G.OR, a, b)
    def and_(self, a, b): return self._binary(G.AND, a, b)
    def nand(self, a, b): return self._binary(G.NAND, a, b)
    def xor(self, a, b): return self._binary(G.XOR, a, b)
    def xnor(self, a, b): return self._binary(G.XNOR, a, b)

    def mux(self, s, a, b):
        """out <- a if s else b."""
        return self.emit(G.MUX, (s, a, b), (self.alloc(),))

    def muxn(self, s, ns, a, b):
        """mux with hoisted ~s (3 NORs instead of 4; Alg 4.1 amortization)."""
        return self.emit(G.MUXN, (s, ns, a, b), (self.alloc(),))

    def fa(self, a, b, c):
        s, co = self.alloc(), self.alloc()
        self.emit(G.FA, (a, b, c), (s, co))
        return s, co

    def facc(self, a, b, c, nc):
        s, co, nco = self.alloc(), self.alloc(), self.alloc()
        self.emit(G.FACC, (a, b, c, nc), (s, co, nco))
        return s, co, nco

    # ------------------------------------------------------- vector ops
    def vec_input(self, name, n):
        return self.input(name, n)

    def vec_const(self, value: int, n: int) -> List[int]:
        return [self.const((value >> k) & 1) for k in range(n)]

    def vec_map(self, fn, *vecs):
        n = len(vecs[0])
        assert all(len(v) == n for v in vecs)
        return [fn(*(v[i] for v in vecs)) for i in range(n)]

    def vec_xor(self, x, y): return self.vec_map(self.xor, x, y)
    def vec_and(self, x, y): return self.vec_map(self.and_, x, y)
    def vec_or(self, x, y): return self.vec_map(self.or_, x, y)
    def vec_not(self, x): return self.vec_map(self.not_, x)
    def vec_id(self, x): return self.vec_map(self.id_, x)

    def vec_and_bit(self, x, bit):
        return [self.and_(xi, bit) for xi in x]

    def vec_mux(self, s, a, b):
        """elementwise a if s else b, with ~s hoisted once."""
        ns = self.not_(s)
        out = [self.muxn(s, ns, ai, bi) for ai, bi in zip(a, b)]
        self.free(ns)
        return out

    def or_reduce(self, bits):
        acc = bits[0]
        first = True
        for b in bits[1:]:
            nxt = self.or_(acc, b)
            if not first:
                self.free(acc)
            acc, first = nxt, False
        return acc if not first else self.id_(acc)

    # ------------------------------------------------------ finalization
    def finish(self) -> Program:
        return Program(self.n_cells, self.instrs, dict(self.ports),
                       parallel_steps=self._steps,
                       in_ports=self.in_port_names)


# --------------------------------------------------------------------------
# NOR lowering
# --------------------------------------------------------------------------

def _lower_instr(b: Builder, ins: Instr):
    """Append the NOR/NOT/INIT expansion of ``ins`` to builder ``b`` writing
    results into the *original* output cells (cells ids are preserved because
    the builder was reserved with the abstract program's cell count)."""
    op = ins.op
    I, O = ins.ins, ins.outs

    def nor(a, bb, out=None):
        out = b.alloc() if out is None else out
        b.emit(G.NOR, (a, bb), (out,))
        return out

    def not_(a, out=None):
        out = b.alloc() if out is None else out
        b.emit(G.NOT, (a,), (out,))
        return out

    if op in (G.INIT0, G.INIT1):
        b.emit(op, (), O)
    elif op == G.NOT:
        not_(I[0], O[0])
    elif op == G.NOR:
        nor(I[0], I[1], O[0])
    elif op == G.OR:
        t = nor(I[0], I[1]); not_(t, O[0]); b.free(t)
    elif op == G.AND:
        na, nb = not_(I[0]), not_(I[1])
        nor(na, nb, O[0]); b.free([na, nb])
    elif op == G.NAND:
        na, nb = not_(I[0]), not_(I[1])
        t = nor(na, nb); not_(t, O[0]); b.free([na, nb, t])
    elif op == G.XNOR:
        n1 = nor(I[0], I[1]); n2 = nor(I[0], n1); n3 = nor(I[1], n1)
        nor(n2, n3, O[0]); b.free([n1, n2, n3])
    elif op == G.XOR:
        n1 = nor(I[0], I[1]); n2 = nor(I[0], n1); n3 = nor(I[1], n1)
        n4 = nor(n2, n3); not_(n4, O[0]); b.free([n1, n2, n3, n4])
    elif op in (G.MUX, G.MUXN):
        if op == G.MUX:
            s, a, c = I
            ns = not_(s); tmp_ns = True
        else:
            s, ns, a, c = I
            tmp_ns = False
        # out = (s&a)|(~s&c) = NOR(NOR(a, ns), NOR(c, s))
        t1 = nor(a, ns); t2 = nor(c, s)
        nor(t1, t2, O[0])
        b.free([t1, t2] + ([ns] if tmp_ns else []))
    elif op == G.ID:
        t = not_(I[0]); not_(t, O[0]); b.free(t)
    elif op in (G.FA, G.FACC):
        if op == G.FACC:
            a, x, c, ncin = I
            s_out, co_out, nco_out = O
        else:
            a, x, c = I
            s_out, co_out = O
            nco_out = None
            ncin = not_(c)
        # 11-gate carry-complement netlist (see DESIGN.md §7):
        n1 = nor(a, x)          # ~a~b
        n2 = nor(a, n1)         # ~a b
        n3 = nor(x, n1)         # a ~b
        n4 = nor(n2, n3)        # xnor
        xo = not_(n4)           # xor
        t1 = nor(n4, ncin)      # xor & c
        t2 = nor(xo, c)         # ~xor & ~c
        ab = nor(n1, xo)        # a & b
        nco = nor(ab, t1, out=nco_out)  # ~cout (fresh cell if nco_out is None)
        not_(nco, co_out)
        nor(t1, t2, s_out)      # sum = ~(xor&c | ~xor&~c) = xor ^ c
        b.free([n1, n2, n3, n4, xo, t1, t2, ab])
        if nco_out is None:
            b.free([nco, ncin])
    else:
        raise ValueError(op)


# --------------------------------------------------------------------------
# levelized scheduling (executor pipeline stage 2: IR -> levelize)
# --------------------------------------------------------------------------
#
# The executor consumes programs as *levels*: maximal sets of NOR/NOT gates
# with no read-after-write dependency between them, so each level runs as one
# vectorized gather -> NOR -> scatter over all rows.  The pass is a classic
# mini-backend:
#
#   1. value numbering (SSA renaming) of the NOR-lowered stream -- every
#      write defines a fresh value, which dissolves the WAR/WAW hazards the
#      lowering's temp-cell free list introduces;
#   2. constant folding of INIT0/INIT1 into two shared values (the packed
#      state starts zeroed; a single always-one cell is set at pack time), so
#      scheduled gates are NOR/NOT only;
#   3. dead-code elimination backward from the final value of every port;
#   4. level assignment -- either ASAP over true dependencies ("asap") or
#      the builder's native partition schedule ("native", wave-lockstep
#      expansion of ``parallel_steps``);
#   5. register allocation: values are mapped back onto physical cells with
#      a free-list scan over live ranges, shrinking the state footprint
#      (often drastically for partitioned programs, whose k*cpk layouts are
#      sparse).
#
# The pass is purely an executor artifact: it never mutates the Program, and
# the paper-facing cost model (``Program.cost`` / ``parallel_cost``) is
# computed from the original instruction stream, never from the schedule.

_VZERO = -1     # value id: constant 0 (the zeroed packed state)
_VONE = -2      # value id: constant 1 (one shared cell set at pack time)
_INF = 1 << 60


@dataclasses.dataclass
class LevelSchedule:
    """Dense levelized form of a NOR-lowered program.

    ``a``/``b``/``out`` are int32 ``(n_levels, width)`` physical-cell index
    matrices, padded so that every level has the same width *and* unique
    per-level output indices; ``level_width[l]`` is the number of real gates
    in level ``l``.  NOT is encoded as NOR with b == a; INIT gates are
    folded away, so every lane computes ``out <- ~(a | b)``.

    Two register-allocation layouts (``alloc``):

    * ``"scan"`` -- per-cell free-list reuse; pad lanes read a dedicated
      sink cell and write distinct sink cells (``out == sink + lane``).
    * ``"slots"`` -- contiguous-slot allocation (DESIGN.md §9): each level's
      outputs occupy one contiguous band of a ``slot_width``-wide slot, so
      ``out[l] == out[l, 0] + lane`` for every lane and the level's write is
      a single slice at offset ``level_off[l]``.  Input ports pack into one
      contiguous run starting at cell 0; when the stacked output-port finals
      are not naturally contiguous, explicit double-NOT copy levels
      (``copy_gates``, reported separately from ``n_gates``) move them into
      one contiguous band.  Pad lanes read cell 0 and write the slot's own
      tail, keeping per-level output indices unique.
    """
    n_cells: int                    # physical cells incl. the sink region
    sink: int                       # first scratch cell absorbing pad lanes
    #                                 (scan alloc only; -1 for slots)
    one_cell: Optional[int]         # cell pack_rows must fill with ones
    ports: Dict[str, List[int]]     # port name -> physical cells (final
    #                                 values: where outputs are unpacked)
    in_cells: Dict[str, List[int]]  # input port -> physical cells of the
    #                                 *initial* values (where inputs are
    #                                 packed; differs from ports when a
    #                                 program overwrites an input cell)
    in_ports: frozenset
    out_ports: frozenset
    a: np.ndarray
    b: np.ndarray
    out: np.ndarray
    level_width: np.ndarray         # int32 (n_levels,)
    n_gates: int                    # live gates after DCE
    source_gates: int               # lowered NOR/NOT gates before DCE
    source_cells: int               # lowered cell count before reuse
    alloc: str = "scan"             # register-allocation layout (see above)
    slot_width: Optional[int] = None    # slot granularity ("slots" only)
    copy_gates: int = 0             # inserted output-copy gates ("slots"
    #                                 only; executor artifact, never part of
    #                                 the Program cost model)

    @property
    def n_levels(self) -> int:
        return self.a.shape[0]

    @property
    def width(self) -> int:
        return self.a.shape[1]

    @property
    def level_off(self) -> np.ndarray:
        """Per-level output-band base offsets (``alloc == "slots"`` only):
        level ``l`` writes exactly cells ``[level_off[l], level_off[l] +
        width)``, its band plus the slot's own pad tail."""
        if self.alloc != "slots":
            raise ValueError("level_off is defined for slot schedules only")
        return (self.out[:, 0] if self.n_levels
                else np.zeros(0, np.int32))

    def pack_cells(self, name: str) -> List[int]:
        """Physical cells where ``name``'s per-row values must be packed
        (inputs go to their initial-value cells, outputs read back from
        their final-value cells)."""
        return self.in_cells.get(name, self.ports[name])

    def exec_packed(self, state: np.ndarray) -> np.ndarray:
        """Vectorized numpy execution over bit-packed column state
        (uint32[n_cells, n_words]); one gather/NOR/scatter per level."""
        assert state.shape[0] == self.n_cells
        for l in range(self.n_levels):
            w = self.level_width[l]
            ia, ib, io = self.a[l, :w], self.b[l, :w], self.out[l, :w]
            state[io] = ~(state[ia] | state[ib])
        return state


def _rename(low: Program):
    """Value-number the lowered stream.  Returns (va, vb, is_gate, out_val)
    where gate i defines value ``n0 + i`` and reads values va[i]/vb[i]
    (sentinels _VZERO/_VONE for folded constants), and ``out_val`` maps each
    port cell position to its final value."""
    n0 = low.n_cells
    cur = list(range(n0))
    ni = len(low.instrs)
    va = np.full(ni, _VZERO, np.int64)
    vb = np.full(ni, _VZERO, np.int64)
    is_gate = np.zeros(ni, bool)
    for i, ins in enumerate(low.instrs):
        op = ins.op
        if op == G.INIT0:
            cur[ins.outs[0]] = _VZERO
            continue
        if op == G.INIT1:
            cur[ins.outs[0]] = _VONE
            continue
        assert op in (G.NOT, G.NOR), op
        is_gate[i] = True
        va[i] = cur[ins.ins[0]]
        vb[i] = cur[ins.ins[1]] if op == G.NOR else va[i]
        cur[ins.outs[0]] = n0 + i
    out_val = {name: [cur[c] for c in cells]
               for name, cells in low.ports.items()}
    return va, vb, is_gate, out_val


def _dce(n0, ni, va, vb, out_val):
    """Mark gates reachable (backward) from any port's final value."""
    keep = np.zeros(ni, bool)
    stack = [v for vals in out_val.values() for v in vals if v >= n0]
    while stack:
        g = stack.pop() - n0
        if keep[g]:
            continue
        keep[g] = True
        for o in (int(va[g]), int(vb[g])):
            if o >= n0 and not keep[o - n0]:
                stack.append(o)
    return keep


def _asap_levels(n0, kept, va, vb):
    """Minimal-depth level per kept gate: 1 + max(level of operand defs)."""
    lvl = {}

    def vlevel(v):
        return lvl.get(v, 0) if v >= n0 else 0

    out = {}
    for i in kept:      # program order: defs precede uses
        L = 1 + max(vlevel(int(va[i])), vlevel(int(vb[i])))
        lvl[n0 + i] = L
        out[i] = L
    return out


def _native_levels(program: Program, low: Program, kept_set):
    """Wave-lockstep levels from the builder's native ``parallel_steps``:
    abstract step s starts at base[s]; the j-th lowered gate of each of its
    abstract instrs lands in wave base[s] + j (paper §5.1 semantics: sections
    advance concurrently, each serially evaluating its gate's NOR netlist)."""
    steps = program.parallel_steps
    if steps is None:
        raise ValueError("program has no native parallel schedule")
    spans = low.lowered_spans
    covered = set()
    for idxs in steps:
        covered.update(idxs)
    for j, ins in enumerate(program.instrs):
        if j not in covered and ins.op not in (G.INIT0, G.INIT1):
            raise ValueError(
                f"abstract instr {j} ({G(ins.op).name}) is outside the "
                "native parallel schedule")
    levels = {}
    base = 1
    for idxs in steps:
        longest = 0
        for j in idxs:
            s, e = spans[j]
            for k in range(s, e):
                if k in kept_set:
                    levels[k] = base + (k - s)
            longest = max(longest, e - s)
        base += max(longest, 1)
    return levels


def levelize(program: Program, mode: str = "asap",
             reuse_cells: bool = True,
             max_width: Optional[int] = None,
             alloc: str = "scan") -> LevelSchedule:
    """Levelize ``program``'s NOR lowering into a :class:`LevelSchedule`.

    mode:  'asap'   -- minimal-depth hazard levelization (default);
           'native' -- the builder's own ``parallel_steps``, expanded to
                       NOR waves (bit-parallel programs only).
    reuse_cells: run the register-allocation pass (cells reused once their
    last reader has executed); disable for a direct cell-per-value layout.
    max_width: split levels wider than this into consecutive rows, bounding
    the padding of the dense form.  Safe because register allocation is
    strict (a cell written at level L is never read at level L), so any
    partition of a level into ordered chunks executes identically.
    alloc:  'scan'  -- per-cell free-list register allocation (default);
            'slots' -- contiguous-slot allocation: inputs pack into one
                       run at cell 0, every level's outputs land in one
                       contiguous band of a ``max_width``-wide slot (slots
                       reused at band granularity), and output-port finals
                       are moved into one contiguous band by explicit
                       double-NOT copy levels when needed.  This is the
                       static-offset form the slot executors
                       (``kernels.slots``) consume.

    Levelization never mutates ``program``; the paper-facing cost model
    (``cost()``/``parallel_cost()``) is computed from the original
    instruction stream only, and slot-mode copy gates are an executor
    artifact reported separately (``copy_gates``).
    """
    if alloc not in ("scan", "slots"):
        raise ValueError(f"unknown alloc mode {alloc!r}")
    low = program.lower_to_nor()
    n0 = low.n_cells
    ni = len(low.instrs)
    va, vb, is_gate, out_val = _rename(low)
    keep = _dce(n0, ni, va, vb, out_val)
    kept = [i for i in range(ni) if keep[i]]
    if mode == "asap":
        raw = _asap_levels(n0, kept, va, vb)
    elif mode == "native":
        raw = _native_levels(program, low, set(kept))
    else:
        raise ValueError(mode)
    # compress level ids to consecutive 1..D
    uniq = sorted(set(raw.values()))
    remap = {L: k + 1 for k, L in enumerate(uniq)}
    glevel = {i: remap[raw[i]] for i in kept}
    depth = len(uniq)

    # ---- liveness: last level each value is read at; port finals live out
    last_use: Dict[int, int] = {}
    for i in kept:
        for v in (int(va[i]), int(vb[i])):
            L = glevel[i]
            if last_use.get(v, -1) < L:
                last_use[v] = L
    for vals in out_val.values():
        for v in vals:
            last_use[v] = _INF
    # input ports pack at their *initial* values' cells (a program may
    # overwrite an input cell; its final value then differs).  Keep those
    # initial values allocatable even when never read.  Hand-built programs
    # declare no directions; treat every port as packable there.
    pack_names = low.in_ports if low.in_ports else low.ports.keys()
    in_port_cells = {name: list(low.ports[name])
                     for name in pack_names if name in low.ports}
    for cells in in_port_cells.values():
        for c in cells:
            last_use.setdefault(c, 0)

    by_level: Dict[int, List[int]] = {}
    for i in kept:
        by_level.setdefault(glevel[i], []).append(i)

    if alloc == "slots":
        return _alloc_slots(low, n0, va, vb, out_val, kept, glevel, depth,
                            last_use, in_port_cells, by_level, max_width,
                            is_gate)

    # ---- register allocation over live ranges
    phys: Dict[int, int] = {}
    free: List[int] = []
    n_phys = 0

    def alloc_cell():
        nonlocal n_phys
        if reuse_cells and free:
            return heapq.heappop(free)
        n_phys += 1
        return n_phys - 1

    expiry: Dict[int, List[int]] = {}

    def place(v, cell):
        phys[v] = cell
        lu = last_use[v]
        if lu < _INF:
            expiry.setdefault(lu, []).append(cell)

    one_cell = None
    if _VONE in last_use:
        one_cell = alloc_cell()
        place(_VONE, one_cell)
    if _VZERO in last_use:
        place(_VZERO, alloc_cell())
    for v in sorted(v for v in last_use if 0 <= v < n0):
        place(v, alloc_cell())

    rows_a, rows_b, rows_o = [], [], []
    for L in range(1, depth + 1):
        if reuse_cells:
            for cell in expiry.pop(L - 1, ()):
                heapq.heappush(free, cell)
        ra, rb, ro = [], [], []
        for i in by_level.get(L, ()):
            ra.append(phys[int(va[i])])
            rb.append(phys[int(vb[i])])
            place(n0 + i, alloc_cell())
            ro.append(phys[n0 + i])
        if max_width is not None and len(ra) > max_width:
            for s in range(0, len(ra), max_width):
                rows_a.append(ra[s:s + max_width])
                rows_b.append(rb[s:s + max_width])
                rows_o.append(ro[s:s + max_width])
        else:
            rows_a.append(ra)
            rows_b.append(rb)
            rows_o.append(ro)
    sink = n_phys
    width = max((len(r) for r in rows_a), default=0)
    # padding lanes write *distinct* sink cells so every level's scatter has
    # unique output indices (lets the executors use unique-scatter codegen)
    n_phys += max(width, 1)
    D = len(rows_a)
    a = np.full((D, width), sink, np.int32)
    b = np.full((D, width), sink, np.int32)
    o = np.tile(sink + np.arange(width, dtype=np.int32), (D, 1))
    lw = np.zeros(D, np.int32)
    for l in range(D):
        w = len(rows_a[l])
        lw[l] = w
        a[l, :w] = rows_a[l]
        b[l, :w] = rows_b[l]
        o[l, :w] = rows_o[l]
    ports = {name: [phys[v] for v in vals] for name, vals in out_val.items()}
    in_cells = {name: [phys[c] for c in cells]
                for name, cells in in_port_cells.items()}
    return LevelSchedule(
        n_cells=n_phys, sink=sink, one_cell=one_cell, ports=ports,
        in_cells=in_cells,
        in_ports=low.in_ports, out_ports=low.out_ports,
        a=a, b=b, out=o, level_width=lw,
        n_gates=len(kept), source_gates=int(is_gate.sum()),
        source_cells=n0)


def _alloc_slots(low, n0, va, vb, out_val, kept, glevel, depth, last_use,
                 in_port_cells, by_level, max_width, is_gate):
    """Contiguous-slot register allocation (DESIGN.md §9).

    Layout contract consumed by the slot executors (``kernels.slots``):

    * input-port initial values occupy one contiguous run starting at cell
      0, stacked in sorted-port-name order -- state assembly is a single
      slice update instead of a scatter;
    * every dense level writes one contiguous band: the level's outputs are
      ``off + lane`` for ``off = out[l, 0]``, and the pad lanes fill the
      slot's own tail, so the whole level is one ``max_width``-wide slice
      write with unique output indices;
    * slots (bands of ``max_width`` cells) are reused once every value of
      their current occupancy is dead, keeping the state footprint close to
      the scan allocator's instead of one-cell-per-gate;
    * the stacked output-port finals end in one contiguous ascending run --
      naturally when possible, otherwise via appended double-NOT copy
      levels (2 gates per copied cell, reported in ``copy_gates``, never in
      the Program's cost model).

    Pad lanes read cell 0 (an always-present initial cell, never written by
    any level), so the dense form stays executable by every generic
    backend, and the hazard invariant (no level reads a cell it writes)
    holds for real and pad lanes alike.
    """
    W = max_width
    if W is None:
        W = max((len(g) for g in by_level.values()), default=1)
    W = max(int(W), 1)

    # ---- placement: initial values first, inputs contiguous at cell 0
    phys: Dict[int, int] = {}
    n_phys = 0

    def place_init(v):
        nonlocal n_phys
        if v not in phys:
            phys[v] = n_phys
            n_phys += 1

    for name in sorted(in_port_cells):
        for c in in_port_cells[name]:
            place_init(c)
    one_cell = None
    if _VONE in last_use:
        place_init(_VONE)
        one_cell = phys[_VONE]
    if _VZERO in last_use:
        place_init(_VZERO)
    for v in sorted(v for v in last_use if 0 <= v < n0):
        place_init(v)
    n_init = max(n_phys, 1)     # pad lanes read cell 0; reserve it
    n_phys = n_init

    # ---- slot allocation: one W-wide slot per dense row, band reuse
    free_slots: List[int] = []
    expiry: Dict[int, List[int]] = {}

    def alloc_slot():
        nonlocal n_phys
        if free_slots:
            return heapq.heappop(free_slots)
        base = n_phys
        n_phys += W
        return base

    rows_a, rows_b, rows_off, rows_w = [], [], [], []

    def emit_row(ra, rb, outs_last_use):
        """Allocate one W-slot band for a row of <= W gates; returns the
        band base.  ``outs_last_use[k]`` is the last-read level of the k-th
        output (``_INF`` pins the slot forever)."""
        base = alloc_slot()
        lu = max(outs_last_use, default=0)
        if lu < _INF:
            expiry.setdefault(lu, []).append(base)
        rows_a.append(ra)
        rows_b.append(rb)
        rows_off.append(base)
        rows_w.append(len(ra))
        return base

    for L in range(1, depth + 1):
        for base in expiry.pop(L - 1, ()):
            heapq.heappush(free_slots, base)
        gates = by_level.get(L, ())
        for s in range(0, len(gates), W):
            chunk = gates[s:s + W]
            ra = [phys[int(va[i])] for i in chunk]
            rb = [phys[int(vb[i])] for i in chunk]
            base = emit_row(ra, rb,
                            [last_use.get(n0 + i, L) for i in chunk])
            for k, i in enumerate(chunk):
                phys[n0 + i] = base + k

    # ---- output copy stage: force the stacked output finals contiguous
    out_names = sorted(low.out_ports or low.ports)
    finals = [phys[v] for name in out_names for v in out_val[name]]
    copy_gates = 0
    if finals and finals != list(range(finals[0], finals[0] + len(finals))):
        k = len(finals)
        n_chunks = (k + W - 1) // W
        # stage 1: t <- NOT(final), into per-chunk staging slots
        stage = []
        copy_level = depth + 1
        for s in range(0, k, W):
            chunk = finals[s:s + W]
            base = emit_row(list(chunk), list(chunk),
                            [copy_level + 1] * len(chunk))
            stage.extend(base + j for j in range(len(chunk)))
        # stage 2: out <- NOT(t), into one fresh contiguous band (chunk
        # slots allocated back to back at the top of the state)
        out_base = n_phys
        n_phys += n_chunks * W
        for ci, s in enumerate(range(0, k, W)):
            chunk = stage[s:s + W]
            rows_a.append(list(chunk))
            rows_b.append(list(chunk))
            rows_off.append(out_base + ci * W)
            rows_w.append(len(chunk))
        # remap the output ports onto the copy band, in stacked order
        new_cells = iter(range(out_base, out_base + k))
        remapped = {name: [next(new_cells) for _ in out_val[name]]
                    for name in out_names}
        copy_gates = 2 * k
    else:
        remapped = {}

    # ---- dense matrices
    D = len(rows_a)
    a = np.zeros((D, W), np.int32)
    b = np.zeros((D, W), np.int32)
    o = np.zeros((D, W), np.int32)
    lw = np.asarray(rows_w, np.int32) if D else np.zeros(0, np.int32)
    for l in range(D):
        w = rows_w[l]
        a[l, :w] = rows_a[l]
        b[l, :w] = rows_b[l]
        o[l] = rows_off[l] + np.arange(W, dtype=np.int32)
    ports = {name: remapped.get(name) or [phys[v] for v in vals]
             for name, vals in out_val.items()}
    in_cells = {name: [phys[c] for c in cells]
                for name, cells in in_port_cells.items()}
    return LevelSchedule(
        n_cells=n_phys, sink=-1, one_cell=one_cell, ports=ports,
        in_cells=in_cells,
        in_ports=low.in_ports, out_ports=low.out_ports,
        a=a, b=b, out=o, level_width=lw,
        n_gates=len(kept), source_gates=int(is_gate.sum()),
        source_cells=n0, alloc="slots", slot_width=W,
        copy_gates=copy_gates)


def compose(nodes, outputs) -> Program:
    """Stitch per-op gate programs into one fused netlist (cross-op fusion).

    ``nodes`` is a sequence of ``(program, bindings)``; ``bindings`` maps
    every declared in-port of that program to a source:

    * ``("ext", name, width)`` -- an external input port of the composite
      (allocated on first use; later references share the same cells);
    * ``("node", idx, port)``  -- out-port ``port`` of an earlier node.

    ``outputs`` maps composite out-port names to ``(node_idx, port_name)``.

    Producer out-cells are wired *directly* onto consumer in-cells in one
    shared cell space; :func:`levelize`'s SSA value numbering then dissolves
    the WAW/WAR hazards of the concatenated instruction streams and its DCE
    removes every intermediate value not reachable from a declared output --
    fused intermediates never materialize as port unpacks.  When a consumer
    port is wider than its source, the high bits read a shared constant-0
    cell (zero extension); when narrower, the source truncates.  A node that
    writes any of its own input-port cells gets isolation copies (``G.ID``)
    on that port so the shared producer cells stay intact for other readers.
    """
    b = Builder()
    ext_cells: Dict[str, List[int]] = {}
    node_ports: List[Dict[str, List[int]]] = []
    for prog, bindings in nodes:
        if not prog.in_ports:
            raise ValueError(
                "compose() requires programs with declared in_ports")
        missing = prog.in_ports - set(bindings)
        if missing:
            raise ValueError(f"unbound in-ports: {sorted(missing)}")
        written = {c for ins in prog.instrs for c in ins.outs}
        cmap: Dict[int, int] = {}
        for pname in sorted(prog.in_ports):
            src_spec = bindings[pname]
            if src_spec[0] == "ext":
                _, ename, ewidth = src_spec
                if ename not in ext_cells:
                    ext_cells[ename] = b.input(ename, ewidth)
                src = list(ext_cells[ename])
            elif src_spec[0] == "node":
                _, nidx, oport = src_spec
                src = list(node_ports[nidx][oport])
            else:
                raise ValueError(f"unknown binding {src_spec!r}")
            pcells = prog.ports[pname]
            if len(src) < len(pcells):          # zero-extend
                src = src + [b.const(0)] * (len(pcells) - len(src))
            else:                               # truncate
                src = src[:len(pcells)]
            if any(c in written for c in pcells):
                src = [b.id_(s) for s in src]   # isolation copies
            for c, s in zip(pcells, src):
                cmap[c] = s

        def m(c, _cmap=cmap):
            s = _cmap.get(c)
            if s is None:
                s = _cmap[c] = b.alloc()
            return s

        for ins in prog.instrs:
            b.emit(ins.op, tuple(m(c) for c in ins.ins),
                   tuple(m(c) for c in ins.outs))
        node_ports.append({p: [m(c) for c in prog.ports[p]]
                           for p in prog.ports if p not in prog.in_ports})
    for oname, (nidx, pname) in sorted(outputs.items()):
        b.output(oname, node_ports[nidx][pname])
    return b.finish()


def memoize_build(fn):
    """Memoize a ``build_*`` program constructor by its arguments.

    Program construction is pure but slow; sharing one Program instance per
    parameterization also lets the executor's content-hash compiled-program
    cache hit without rehashing (kernels.ops memoizes keys per instance).
    """
    return functools.lru_cache(maxsize=None)(fn)
