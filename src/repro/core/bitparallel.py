"""Bit-parallel element-parallel fixed-point arithmetic (paper §5).

Numbers are stored in *strided* format: bit i of every operand lives in
partition i.  The algorithms:

  * :func:`bp_add` -- Algorithm 5.1, the first bit-parallel in-memory adder:
    parallel-prefix (Brent-Kung via the prefix technique), O(log N) steps.
  * :func:`bp_sub` -- two's complement on top of bp_add.
  * :func:`bp_mul` -- Algorithm 5.2: MultPIM's CSAS loop with the final
    addition replaced by the proposed bp_add (O(N log N + log N)).
  * :func:`bp_div` -- Algorithm 5.3, the first bit-parallel divider:
    carry-save carry-lookahead (CSCL); the remainder stays in carry-save
    form and only its *sign* is resolved per iteration via a (G,A)
    reduction.  O(N log N).
"""

from __future__ import annotations

from typing import List, Optional, Tuple

from .gates import Program, memoize_build
from .partitions import (PartitionedBuilder, broadcast, prefix_scan, pshift,
                         reduce_pairs, reduce_tree)


# --------------------------------------------------------------------------
# Algorithm 5.1: parallel-prefix addition
# --------------------------------------------------------------------------

def _combine_ga(pb, left, cur, p_out):
    """(g,a) ∘ (g̃,ã) = (g + a·g̃, a·ã)  -- 3 gate-waves."""
    g, a = cur
    gl, al = left
    t = pb.and_(a, gl, p_out=p_out)
    g2 = pb.or_(g, t, p_out=p_out)
    a2 = pb.and_(a, al, p_out=p_out)
    pb.pfree(t)
    return (g2, a2)


def bp_add(pb: PartitionedBuilder, x: List[int], y: List[int],
           cin: Optional[int] = None) -> Tuple[List[int], int]:
    """z = x + y (+ cin); strided operands.  Returns (z bits, carry-out)."""
    n = len(x)
    assert len(y) == n
    parts = [pb.part(c) for c in x]
    with pb.cycle():
        A = [pb.or_(x[i], y[i], p_out=parts[i]) for i in range(n)]
    with pb.cycle():
        Gb = [pb.and_(x[i], y[i], p_out=parts[i]) for i in range(n)]
    if cin is not None:
        # fold the carry-in into bit 0's (g, a)
        if pb.part(cin) != parts[0]:
            cin = pb.id_(cin, p_out=parts[0])
        t = pb.and_(A[0], cin, p_out=parts[0])
        Gb[0] = pb.or_(Gb[0], t, p_out=parts[0])
        pb.pfree(t)
    st = prefix_scan(pb, list(zip(Gb, A)), _combine_ga)
    GG = [s[0] for s in st]
    c = pshift(pb, GG, +1, fill=None)
    c[0] = cin if cin is not None else pb.const(0, parts[0])
    with pb.cycle():
        u = [pb.xor_(x[i], y[i], p_out=parts[i]) for i in range(n)]
    with pb.cycle():
        z = [pb.xor_(u[i], c[i], p_out=parts[i]) for i in range(n)]
    pb.pfree(u + [a for a in A])
    return z, GG[n - 1]


def bp_sub(pb: PartitionedBuilder, x: List[int], y: List[int]
           ) -> Tuple[List[int], int]:
    """z = x - y; returns (z, ge) with ge = 1 iff x >= y."""
    with pb.cycle():
        ny = [pb.not_(y[i], p_out=pb.part(y[i])) for i in range(len(y))]
    one = pb.const(1, 0)
    return bp_add(pb, x, ny, cin=one)


# --------------------------------------------------------------------------
# Algorithm 5.2: CSAS multiplication + proposed final adder
# --------------------------------------------------------------------------

def bp_mul(pb: PartitionedBuilder, x: List[int], y: List[int]
           ) -> Tuple[List[int], List[int]]:
    """(w|z) = x * y; strided.  Lower half z, upper half w."""
    n = len(x)
    s = [pb.const(0, j) for j in range(n)]
    c = [pb.const(0, j) for j in range(n)]
    z = [None] * n
    for i in range(n):
        bb = broadcast(pb, y[i])                       # b_i to all partitions
        with pb.cycle():
            ab = [pb.and_(x[j], bb[j], p_out=j) for j in range(n)]
        olds, oldc = s, c
        with pb.cycle():                               # carry-save addition
            sc = [pb.fa_(s[j], c[j], ab[j], p_out=j) for j in range(n)]
        s = [t[0] for t in sc]
        c = [t[1] for t in sc]
        z[i] = pb.id_(s[0], p_out=i)                   # output LSB
        news = pshift(pb, s, -1, fill=0)               # sum shifts right
        pb.pfree(ab + olds + oldc + s + list(set(bb)))
        s = news
    # final addition (proposed): w = s + c via Alg 5.1 instead of N more
    # CSAS iterations -- O(N) -> O(log N)
    w, _ = bp_add(pb, s, c)
    return w, z


# --------------------------------------------------------------------------
# Algorithm 5.3: CSCL division
# --------------------------------------------------------------------------

def bp_div(pb: PartitionedBuilder, z: List[int], d: List[int]
           ) -> Tuple[List[int], List[int]]:
    """Non-restoring 2N/N division with the remainder in carry-save form.

    Layout: k >= N+2 partitions; z (2N bits) provides z_hi (initial R) and
    z_lo (bits injected per iteration); precondition z >> N < d.
    Per iteration, only the *sign* of R = S + C is resolved, via a
    carry-lookahead reduction (cheaper than a prefix: paper fn. 12).
    """
    n = len(d)
    w = n + 2
    assert pb.k >= w and len(z) == 2 * n
    z_lo, z_hi = z[:n], z[n:]
    s = list(z_hi) + [pb.const(0, n), pb.const(0, n + 1)]
    c = [pb.const(0, j) for j in range(w)]
    qprev = pb.const(1, w - 1)
    qs = [None] * n
    for i in reversed(range(n)):
        bb = broadcast(pb, qprev)
        # conditional +-d: xd = XOR(d, q'); upper bits are the q' copies
        # themselves (sign extension of the two's complement of d)
        with pb.cycle():
            xd = [pb.xor_(d[j], bb[j], p_out=j) for j in range(n)]
        xd += [bb[n], bb[n + 1]]
        # R <- (R << 1) | z_i : shift both s and c up one partition
        olds, oldc = s, c
        s = pshift(pb, s, +1, fill=None)   # top bit drops (|R|<2^{w-1})
        s[0] = pb.id_(z_lo[i], p_out=0)
        c = pshift(pb, c, +1, fill=None)
        c[0] = bb[0]                      # carry-in q' (the +1 of -d)
        pb.pfree(olds + oldc)
        # carry-save add
        with pb.cycle():
            sc = [pb.fa_(s[j], c[j], xd[j], p_out=j) for j in range(w)]
        pb.pfree(s + c + xd[:n] + list(set(bb)))
        s = [t[0] for t in sc]
        carries = [t[1] for t in sc]
        c = pshift(pb, carries, +1, fill=None)  # carry weight realign
        c[0] = pb.const(0, 0)
        pb.pfree(carries)
        # sign of S + C via carry-lookahead *reduction* over bits 0..w-2
        with pb.cycle():
            Gb = [pb.and_(s[j], c[j], p_out=j) for j in range(w - 1)]
        with pb.cycle():
            A = [pb.or_(s[j], c[j], p_out=j) for j in range(w - 1)]
        carry = reduce_pairs(pb, list(zip(Gb, A)), _combine_ga)[0]
        t = pb.xor_(s[w - 1], c[w - 1], p_out=w - 1)
        sign_n = pb.xnor_(t, carry, p_out=w - 1)       # = NOT sign = q_i
        pb.pfree(Gb + A)
        qs[i] = pb.id_(sign_n, p_out=i)                # strided quotient
        qprev = qs[i]
    # final correction: r = S + C + AND(d, ~q_0)
    nq0 = pb.not_(qs[0], p_out=0)
    bb = broadcast(pb, nq0)
    zero_cells = [pb.const(0, j) for j in range(n, w)]
    with pb.cycle():
        m = [pb.and_(d[j], bb[j], p_out=j) for j in range(n)]
    m += zero_cells
    with pb.cycle():
        sc = [pb.fa_(s[j], c[j], m[j], p_out=j) for j in range(w)]
    s = [t[0] for t in sc]
    c = pshift(pb, [t[1] for t in sc], +1, fill=None)
    c[0] = pb.const(0, 0)
    r, _ = bp_add(pb, s, c)
    return qs, r[:n]


# --------------------------------------------------------------------------
# packaged programs
# --------------------------------------------------------------------------

@memoize_build
def build_bp_add(n: int, cpk: int = 128) -> Program:
    pb = PartitionedBuilder(n, cpk)
    x = pb.input("x", range(n))
    y = pb.input("y", range(n))
    z, cout = bp_add(pb, x, y)
    pb.output("z", z + [cout])
    return pb.finish()


@memoize_build
def build_bp_sub(n: int, cpk: int = 128) -> Program:
    pb = PartitionedBuilder(n, cpk)
    x = pb.input("x", range(n))
    y = pb.input("y", range(n))
    z, ge = bp_sub(pb, x, y)
    pb.output("z", z)
    pb.output("ge", [ge])
    return pb.finish()


@memoize_build
def build_bp_mul(n: int, cpk: int = 160) -> Program:
    pb = PartitionedBuilder(n, cpk)
    x = pb.input("x", range(n))
    y = pb.input("y", range(n))
    w, z = bp_mul(pb, x, y)
    pb.output("z", z + w)
    return pb.finish()


@memoize_build
def build_bp_div(n: int, cpk: int = 256) -> Program:
    pb = PartitionedBuilder(n + 2, cpk)
    z = pb.input("z", list(range(n)) + list(range(n)))
    d = pb.input("d", range(n))
    q, r = bp_div(pb, z, d)
    pb.output("q", q)
    pb.output("r", r)
    return pb.finish()
