"""Vectorized PIM arithmetic as a numerics backend (AritPIM as a feature).

``PIMVectorUnit`` exposes the paper's suite as elementwise vector ops over
numpy arrays: each element occupies one memory row and the whole vector
executes one shared gate program (the element-parallel model).  Backends:
'pallas' (the VMEM-fused executor), 'ref' (jnp) and 'numpy' (cycle-accurate
simulator).  ``pim_linear_i8`` demonstrates an integer GEMM lowered onto the
unit -- the building block of the ``PIMLinear`` example layer.
"""

from __future__ import annotations

import functools
from typing import Dict

import numpy as np

from . import bitparallel, bitserial, bitparallel_fp, bitserial_fp, gates
from .floatfmt import FORMATS, FloatFormat
from ..kernels import ops as kops


@functools.lru_cache(maxsize=None)
def program_for(kind: str, op: str, width_or_fmt):
    """The memoized ``build_*`` Program for (kind, op, parameterization).

    kind: 'int-serial' | 'int-parallel' | 'fp-serial' | 'fp-parallel';
    width_or_fmt: bit width for int kinds, FORMATS name for fp kinds.
    Shared dispatch table of the ufunc frontend (``repro.pim_ufunc``) and
    :class:`PIMVectorUnit`.  Every program built here carries its build
    triple as provenance (``kops.note_provenance``) so the on-disk
    artifact cache can rebuild + verify it when warming a fresh process.
    """
    if kind == "int-serial":
        prog = {
            "add": lambda n: bitserial.build_add(n),
            "sub": lambda n: bitserial.build_sub(n),
            "mul": lambda n: bitserial.build_mul(n),
            "div": lambda n: bitserial.build_div(n),
        }[op](width_or_fmt)
    elif kind == "int-parallel":
        prog = {
            "add": lambda n: bitparallel.build_bp_add(n),
            "sub": lambda n: bitparallel.build_bp_sub(n),
            "mul": lambda n: bitparallel.build_bp_mul(n),
            "div": lambda n: bitparallel.build_bp_div(n, cpk=384),
        }[op](width_or_fmt)
    elif kind in ("fp-serial", "fp-parallel"):
        fmt = FORMATS[width_or_fmt]
        if kind == "fp-serial":
            prog = {
                "add": lambda f: bitserial_fp.build_fp_add(f),
                "sub": lambda f: bitserial_fp.build_fp_sub(f),
                "mul": lambda f: bitserial_fp.build_fp_mul(f),
                "div": lambda f: bitserial_fp.build_fp_div(f),
            }[op](fmt)
        else:
            prog = {
                "add": lambda f: bitparallel_fp.build_bp_fp_add(f),
                "mul": lambda f: bitparallel_fp.build_bp_fp_mul(f),
                "div": lambda f: bitparallel_fp.build_bp_fp_div(f),
            }[op](fmt)
    else:
        raise ValueError(kind)
    kops.note_provenance(prog, ("program_for", kind, op, width_or_fmt))
    return prog


@gates.memoize_build
def build_identity(n: int):
    """``z <- x``, an ``n``-bit copy program: the degenerate row stage of a
    reduction over raw values (``pim.reduce_sum`` on a plain array)."""
    b = gates.Builder()
    x = b.input("x", n)
    b.output("z", b.vec_id(x))
    return b.finish()


# Output width of a fused int node at operand width ``W`` (both operands
# zero-extended to W): the same conventions as the per-op programs.
_INT_OUT_WIDTH = {"add": lambda w: w + 1, "sub": lambda w: w,
                  "mul": lambda w: 2 * w}

#: Ops the cross-op composer fuses.  Division (data-dependent iteration
#: structure, two result ports) and the bit-parallel builders (partition
#: schedules are per-program artifacts that do not concatenate) fall back
#: to per-op execution -- see DESIGN.md §13.
FUSABLE_OPS = frozenset(_INT_OUT_WIDTH)


@functools.lru_cache(maxsize=None)
def fused_program_for(kind: str, graph: tuple, fmt: str = None):
    """One fused Program for a canonical expression graph (cross-op SSA).

    ``graph`` is a topological tuple of entries: ``("in", name, width)``
    declares a leaf input port (``width`` is ignored for fp kinds -- every
    fp value is an ``fmt`` bit pattern), and ``(op, i, j)`` applies a
    binary op to earlier entries ``i``/``j``.  The last entry is the
    result, exposed as out-port ``"z"``.

    kind: 'int-serial' (operands zero-extend to the wider width; add grows
    one bit, mul doubles, sub wraps) or 'fp-serial' (all values are
    ``fmt`` bit patterns).  The per-op programs are stitched into one
    netlist by :func:`repro.core.gates.compose`; ``levelize`` then
    value-numbers and DCEs across the op boundaries, so intermediates
    never materialize as port unpacks.  Memoized, like :func:`program_for`.
    """
    if kind not in ("int-serial", "fp-serial"):
        raise ValueError(f"unfusable kind {kind!r}")
    is_fp = kind == "fp-serial"
    nbits = FORMATS[fmt].nbits if is_fp else None
    nodes = []
    info = []       # per graph entry: ("ext", name, width) | ("node", idx,
    #                 port, width) -- a compose() binding plus its width
    for e in graph:
        if e[0] == "in":
            _, name, width = e
            info.append(("ext", name, nbits if is_fp else int(width)))
            continue
        op, i, j = e
        if op not in FUSABLE_OPS:
            raise ValueError(f"op {op!r} does not fuse")
        bi, bj = info[i], info[j]
        if is_fp:
            prog = program_for("fp-serial", op, fmt)
            w_out = nbits
        else:
            w = max(bi[-1], bj[-1])
            prog = program_for("int-serial", op, w)
            w_out = _INT_OUT_WIDTH[op](w)
        nodes.append((prog, {"x": bi[:3], "y": bj[:3]}))
        info.append(("node", len(nodes) - 1, "z", w_out))
    last = info[-1]
    if last[0] == "ext":        # bare leaf: route through an identity copy
        nodes.append((build_identity(last[2]), {"x": last}))
        last = ("node", len(nodes) - 1, "z", last[2])
    prog = gates.compose(nodes, {"z": (last[1], last[2])})
    kops.note_provenance(prog, ("fused_program_for", kind, graph, fmt))
    return prog


def fused_out_width(kind: str, graph: tuple, fmt: str = None) -> int:
    """Bit width of the fused graph's ``z`` port (without building it)."""
    if kind == "fp-serial":
        return FORMATS[fmt].nbits
    widths = []
    for e in graph:
        if e[0] == "in":
            widths.append(int(e[2]))
        else:
            op, i, j = e
            widths.append(_INT_OUT_WIDTH[op](max(widths[i], widths[j])))
    return widths[-1]


# ---------------------------------------------------------------------------
# log-depth in-memory tree reduction across the row axis
# ---------------------------------------------------------------------------

def tree_reduce_rows(row_program, inputs: Dict[str, np.ndarray],
                     total_rows: int, group: int, *, kind: str,
                     fmt: str = None, plan=None, fused: bool = True,
                     deadline: float = None) -> np.ndarray:
    """Sum ``row_program``'s per-row ``z`` outputs down the row axis in
    log2(total_rows/group) in-memory adder levels; returns the ``group``
    reduced row values (uint64, or object ints for wide accumulators).

    Row ``r`` belongs to reduction lane ``r % group`` (callers lay out
    GEMV operands as ``r = j*group + m``); lane sums accumulate pairwise:
    level at span R adds rows [0, R/2) to rows [R/2, R).  ``total_rows``
    must be ``group`` times a power of two and ``group`` either a power of
    two (< 32) or a multiple of 32 -- exactly the alignments under which a
    tree level is a word slice (or an in-word bit shift) of the packed
    block, so intermediate sums never leave the packed domain: one pack on
    the way in, one unpack of the final ``group`` rows on the way out
    (``kernels.ops.dispatch_packed``).

    kind/'fmt' select the adder ('int-serial' grows one carry bit per
    level; 'fp-serial' adds ``fmt`` bit patterns under RNE -- the result
    is the *tree order* sum, bit-exact vs the same-shaped host tree, not
    vs a sequential accumulation).  Zero rows are the padding identity:
    int adds propagate 0 exactly and ``fp_add(x, +0) == x`` / ``fp_mul(
    +0, +0) == +0`` under RNE, so lanes padded to the power of two read
    back their true sums.

    ``fused=False`` (or a non-jax backend) runs the same pairing through
    per-op ``run_program`` round trips -- the bit-identical reference the
    fused path is benchmarked against.

    A plan carrying a fault model / verify policy runs the packed tree
    under verified execution: every level is a verify cut-point (one
    shared ``_VerifyRun`` across the tree, per-level XOR check planes over
    the whole packed block -- zero pad rows are the additive identity
    *and* parity-covered, so a corrupted pad is caught too), and a
    detected corruption retries from the last verified level, never the
    leaves.  ``deadline`` (absolute ``time.monotonic()``) is checked
    between levels, so a deep reduction can be cancelled mid-tree.
    """
    plan = kops.make_plan(plan=plan)
    R = int(total_rows)
    group = int(group)
    spans = R // group
    if group <= 0 or R != group * spans or spans & (spans - 1):
        raise ValueError(
            f"total_rows ({R}) must be group ({group}) x a power of two")
    if group >= 32 and group % 32:
        raise ValueError(f"group {group} must be a power of two or a "
                         "multiple of 32")
    if group < 32 and group & (group - 1):
        raise ValueError(f"group {group} must be a power of two below 32")
    if kind not in ("int-serial", "fp-serial"):
        raise ValueError(f"unreducible kind {kind!r}")
    is_fp = kind == "fp-serial"
    w = len(row_program.ports["z"])

    def adder(width):
        return (program_for("fp-serial", "add", fmt) if is_fp
                else program_for("int-serial", "add", width))

    if not fused or not plan.backend.is_jax:
        # value-domain reference: same pairing, per-op round trips
        vals = kops.run_program(row_program, inputs, R, plan)["z"]
        while R > group:
            kops._check_deadline(deadline)
            half = R // 2
            out = kops.run_program(adder(w), {"x": vals[:half],
                                              "y": vals[half:R]},
                                   half, plan)
            vals = out["z"]
            if not is_fp:
                w += 1
            R = half
        return vals[:group]

    if set(kops.output_names(row_program)) != {"z"}:
        raise ValueError("tree_reduce_rows needs a row program with the "
                         "single out-port 'z'")
    # one shared verify run across the whole tree: every level is a verify
    # cut-point (the level's input block stays on the host), a remap at any
    # level sticks for the shrinking spans above it, and the stage ordinal
    # salts each level's transient stream
    ft = plan.faults is not None or plan.verify is not None
    vrun = kops._VerifyRun(plan) if ft else None
    stage = 0
    block = kops.dispatch_packed(row_program, R, plan, inputs=inputs,
                                 vrun=vrun, deadline=deadline)()
    rpw = 32 * plan.layout.planes
    while R > group:
        kops._check_deadline(deadline)
        half = R // 2
        if half % rpw == 0:
            hw = half // rpw
            x, y = block[..., :hw], block[..., hw:2 * hw]
        elif half % 32 == 0:
            # rows64 split at an odd multiple of 32: the cut lands on the
            # plane boundary inside word m, so the halves re-seam across
            # planes (x keeps plane 0 of word m, y starts at plane 1)
            m = half // 64
            lo, hi = block[0], block[1]
            zw = np.zeros_like(lo[:, :1])
            x = np.stack([lo[:, :m + 1],
                          np.concatenate([hi[:, :m], zw], axis=1)])
            y = np.stack([hi[:, m:2 * m + 1],
                          np.concatenate([lo[:, m + 1:2 * m + 1], zw],
                                         axis=1)])
        else:               # whole span fits one word: lanes shift in-word
            x, y = block, block >> np.uint32(half)
        stage += 1
        block = kops.dispatch_packed(
            adder(w), half, plan, in_names=("x", "y"),
            in_block=np.concatenate([x, y], axis=-2),
            vrun=vrun, stage=stage, deadline=deadline)()
        if not is_fp:
            w += 1
        R = half
    return kops._unpack_sub(block, [("z", w)], group)["z"]


def reduce_group(n_out: int) -> int:
    """The packed-domain lane count for ``n_out`` reduction outputs: the
    next power of two below 32, a multiple of 32 above (the alignments
    :func:`tree_reduce_rows` accepts)."""
    n = int(n_out)
    if n < 1:
        raise ValueError(f"n_out must be >= 1, got {n}")
    if n >= 32:
        return (n + 31) // 32 * 32
    p = 1
    while p < n:
        p <<= 1
    return p


_NP_FMT = {np.dtype(np.float16): "fp16", np.dtype(np.float32): "fp32"}


class PIMVectorUnit:
    """Elementwise vector arithmetic on the PIM abstract machine."""

    def __init__(self, backend: str = "pallas", parallel: bool = False):
        self.backend = backend
        self.mode = "parallel" if parallel else "serial"

    # ---------------------------------------------------------------- int
    def _int_op(self, op: str, x: np.ndarray, y: np.ndarray) -> np.ndarray:
        assert x.dtype in (np.uint8, np.uint16, np.uint32, np.uint64)
        width = x.dtype.itemsize * 8
        prog = program_for(f"int-{self.mode}", op, width)
        n = x.size
        if op == "div":
            out = kops.run_program(
                prog, {"z": x.ravel().astype(np.uint64), "d": y.ravel()},
                n, self.backend)
            return (out["q"].astype(x.dtype).reshape(x.shape),
                    out["r"].astype(x.dtype).reshape(x.shape))
        out = kops.run_program(
            prog, {"x": x.ravel(), "y": y.ravel()}, n, self.backend)["z"]
        if op == "mul":
            return out.reshape(x.shape)       # double-width product
        return out.astype(np.uint64).reshape(x.shape)

    def add(self, x, y):
        return self._dispatch("add", x, y)

    def sub(self, x, y):
        return self._dispatch("sub", x, y)

    def mul(self, x, y):
        return self._dispatch("mul", x, y)

    def div(self, x, y):
        return self._dispatch("div", x, y)

    # --------------------------------------------------------------- float
    def _fp_op(self, op: str, x: np.ndarray, y: np.ndarray) -> np.ndarray:
        fmt_name = _NP_FMT[x.dtype]
        fmt = FORMATS[fmt_name]
        kind = f"fp-{self.mode}"
        if self.mode == "parallel" and op == "sub":
            # bp sub = bp add with flipped sign bit
            y = (-y).astype(x.dtype)
            op = "add"
        prog = program_for(kind, op, fmt_name)
        xb = _bits(x)
        yb = _bits(y)
        out = kops.run_program(prog, {"x": xb, "y": yb}, x.size,
                               self.backend)["z"]
        return _from_bits(np.asarray(out, np.uint64), x.dtype, x.shape)

    def _dispatch(self, op, x, y):
        x = np.asarray(x)
        y = np.asarray(y)
        if x.dtype.kind == "f":
            return self._fp_op(op, x, y)
        return self._int_op(op, x, y)


def _bits(x: np.ndarray) -> np.ndarray:
    view = {np.dtype(np.float16): np.uint16,
            np.dtype(np.float32): np.uint32}[x.dtype]
    return x.ravel().view(view).astype(np.uint64)


def _from_bits(bits: np.ndarray, dtype, shape) -> np.ndarray:
    view = {np.dtype(np.float16): np.uint16,
            np.dtype(np.float32): np.uint32}[np.dtype(dtype)]
    return bits.astype(view).view(dtype).reshape(shape)


def pim_linear_i8(unit: PIMVectorUnit, x: np.ndarray, w: np.ndarray
                  ) -> np.ndarray:
    """int8 GEMM on the PIM unit: y[m,n] = sum_k x[m,k] w[k,n].

    Lowered onto the fused reduction tree (:func:`tree_reduce_rows`): each
    output (m, n) is a packed-domain lane, the K products land at rows
    ``j*group + lane``, one element-parallel 16-bit multiply computes all
    M*N*K products at once, and log2(K) in-memory adder levels fold them --
    the intermediate sums never leave the packed word domain (the per-op
    multiply+accumulate round-trip loop this replaces paid the host bridge
    K times).  Inputs int8 as offset-binary uint16; the 32-bit products
    grow one carry bit per tree level.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2
    xo = (x.astype(np.int32) + 128).astype(np.uint16)   # offset binary
    wo = (w.astype(np.int32) + 128).astype(np.uint16)
    group = reduce_group(m * n)
    kp = 1
    while kp < k:
        kp <<= 1
    xa = np.zeros((kp, group), np.uint64)
    xb = np.zeros((kp, group), np.uint64)
    xa[:k, :m * n] = np.repeat(xo.T, n, axis=1)         # lane m*n + j -> x[m,k]
    xb[:k, :m * n] = np.tile(wo, (1, m))
    acc = tree_reduce_rows(
        program_for("int-serial", "mul", 16),
        {"x": xa.ravel(), "y": xb.ravel()}, kp * group, group,
        kind="int-serial", plan=kops.make_plan(backend=unit.backend))
    acc = np.asarray(acc[:m * n], np.uint64).reshape(m, n)
    # undo the offset: sum (x+128)(w+128) = xw + 128*sx + 128*sw + K*128^2
    sx = x.astype(np.int64).sum(1, keepdims=True)
    sw = w.astype(np.int64).sum(0, keepdims=True)
    return (acc.astype(np.int64) - 128 * sx - 128 * sw - k * 128 * 128)
