"""Vectorized PIM arithmetic as a numerics backend (AritPIM as a feature).

``PIMVectorUnit`` exposes the paper's suite as elementwise vector ops over
numpy arrays: each element occupies one memory row and the whole vector
executes one shared gate program (the element-parallel model).  Backends:
'pallas' (the VMEM-fused executor), 'ref' (jnp) and 'numpy' (cycle-accurate
simulator).  ``pim_linear_i8`` demonstrates an integer GEMM lowered onto the
unit -- the building block of the ``PIMLinear`` example layer.
"""

from __future__ import annotations

import functools
from typing import Dict

import numpy as np

from . import bitparallel, bitserial, bitparallel_fp, bitserial_fp
from .floatfmt import FORMATS, FloatFormat
from ..kernels import ops as kops


@functools.lru_cache(maxsize=None)
def program_for(kind: str, op: str, width_or_fmt):
    """The memoized ``build_*`` Program for (kind, op, parameterization).

    kind: 'int-serial' | 'int-parallel' | 'fp-serial' | 'fp-parallel';
    width_or_fmt: bit width for int kinds, FORMATS name for fp kinds.
    Shared dispatch table of the ufunc frontend (``repro.pim_ufunc``) and
    :class:`PIMVectorUnit`.
    """
    if kind == "int-serial":
        return {
            "add": lambda n: bitserial.build_add(n),
            "sub": lambda n: bitserial.build_sub(n),
            "mul": lambda n: bitserial.build_mul(n),
            "div": lambda n: bitserial.build_div(n),
        }[op](width_or_fmt)
    if kind == "int-parallel":
        return {
            "add": lambda n: bitparallel.build_bp_add(n),
            "sub": lambda n: bitparallel.build_bp_sub(n),
            "mul": lambda n: bitparallel.build_bp_mul(n),
            "div": lambda n: bitparallel.build_bp_div(n, cpk=384),
        }[op](width_or_fmt)
    fmt = FORMATS[width_or_fmt]
    if kind == "fp-serial":
        return {
            "add": lambda f: bitserial_fp.build_fp_add(f),
            "sub": lambda f: bitserial_fp.build_fp_sub(f),
            "mul": lambda f: bitserial_fp.build_fp_mul(f),
            "div": lambda f: bitserial_fp.build_fp_div(f),
        }[op](fmt)
    if kind == "fp-parallel":
        return {
            "add": lambda f: bitparallel_fp.build_bp_fp_add(f),
            "mul": lambda f: bitparallel_fp.build_bp_fp_mul(f),
            "div": lambda f: bitparallel_fp.build_bp_fp_div(f),
        }[op](fmt)
    raise ValueError(kind)


_NP_FMT = {np.dtype(np.float16): "fp16", np.dtype(np.float32): "fp32"}


class PIMVectorUnit:
    """Elementwise vector arithmetic on the PIM abstract machine."""

    def __init__(self, backend: str = "pallas", parallel: bool = False):
        self.backend = backend
        self.mode = "parallel" if parallel else "serial"

    # ---------------------------------------------------------------- int
    def _int_op(self, op: str, x: np.ndarray, y: np.ndarray) -> np.ndarray:
        assert x.dtype in (np.uint8, np.uint16, np.uint32, np.uint64)
        width = x.dtype.itemsize * 8
        prog = program_for(f"int-{self.mode}", op, width)
        n = x.size
        if op == "div":
            out = kops.run_program(
                prog, {"z": x.ravel().astype(np.uint64), "d": y.ravel()},
                n, self.backend)
            return (out["q"].astype(x.dtype).reshape(x.shape),
                    out["r"].astype(x.dtype).reshape(x.shape))
        out = kops.run_program(
            prog, {"x": x.ravel(), "y": y.ravel()}, n, self.backend)["z"]
        if op == "mul":
            return out.reshape(x.shape)       # double-width product
        return out.astype(np.uint64).reshape(x.shape)

    def add(self, x, y):
        return self._dispatch("add", x, y)

    def sub(self, x, y):
        return self._dispatch("sub", x, y)

    def mul(self, x, y):
        return self._dispatch("mul", x, y)

    def div(self, x, y):
        return self._dispatch("div", x, y)

    # --------------------------------------------------------------- float
    def _fp_op(self, op: str, x: np.ndarray, y: np.ndarray) -> np.ndarray:
        fmt_name = _NP_FMT[x.dtype]
        fmt = FORMATS[fmt_name]
        kind = f"fp-{self.mode}"
        if self.mode == "parallel" and op == "sub":
            # bp sub = bp add with flipped sign bit
            y = (-y).astype(x.dtype)
            op = "add"
        prog = program_for(kind, op, fmt_name)
        xb = _bits(x)
        yb = _bits(y)
        out = kops.run_program(prog, {"x": xb, "y": yb}, x.size,
                               self.backend)["z"]
        return _from_bits(np.asarray(out, np.uint64), x.dtype, x.shape)

    def _dispatch(self, op, x, y):
        x = np.asarray(x)
        y = np.asarray(y)
        if x.dtype.kind == "f":
            return self._fp_op(op, x, y)
        return self._int_op(op, x, y)


def _bits(x: np.ndarray) -> np.ndarray:
    view = {np.dtype(np.float16): np.uint16,
            np.dtype(np.float32): np.uint32}[x.dtype]
    return x.ravel().view(view).astype(np.uint64)


def _from_bits(bits: np.ndarray, dtype, shape) -> np.ndarray:
    view = {np.dtype(np.float16): np.uint16,
            np.dtype(np.float32): np.uint32}[np.dtype(dtype)]
    return bits.astype(view).view(dtype).reshape(shape)


def pim_linear_i8(unit: PIMVectorUnit, x: np.ndarray, w: np.ndarray
                  ) -> np.ndarray:
    """int8 GEMM on the PIM unit: y[m,n] = sum_k x[m,k] w[k,n].

    Lowered as K element-parallel multiply+accumulate sweeps over M*N rows
    (zero data movement between steps in a real PIM: the accumulator column
    stays in place).  Inputs int8 as offset-binary uint16; accumulation in
    uint32 (wide enough for K*2^16).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2
    xo = (x.astype(np.int32) + 128).astype(np.uint16)   # offset binary
    wo = (w.astype(np.int32) + 128).astype(np.uint16)
    acc = np.zeros((m, n), np.uint64)
    for j in range(k):
        xi = np.broadcast_to(xo[:, j:j + 1], (m, n)).copy()
        wj = np.broadcast_to(wo[j:j + 1, :], (m, n)).copy()
        prod = unit.mul(xi, wj).astype(np.uint64)       # exact 32-bit products
        acc32 = unit.add(acc.astype(np.uint32), prod.astype(np.uint32))
        acc = acc32.astype(np.uint64)
    # undo the offset: sum (x+128)(w+128) = xw + 128*sx + 128*sw + K*128^2
    sx = x.astype(np.int64).sum(1, keepdims=True)
    sw = w.astype(np.int64).sum(0, keepdims=True)
    return (acc.astype(np.int64) - 128 * sx - 128 * sw - k * 128 * 128)
