"""Partition model for bit-parallel element-parallel computing (paper §5.1).

Arrays are divided into ``k`` partitions connected by switches.  With
switches open, each partition (or contiguous *section* of merged partitions)
executes one gate per cycle, concurrently with every other section.  We model
the *minimal* PartitionPIM semantics at the granularity the paper's
algorithms need:

  * a **cycle** is a set of gates whose partition spans are pairwise
    disjoint contiguous ranges (the implied switch configuration);
  * a gate's operands/outputs must all lie within its section.

:class:`PartitionedBuilder` wraps the serial :class:`~repro.core.gates.Builder`
with per-partition cell allocation and cycle grouping + legality validation.
The resulting :class:`Program` is functionally identical to a serial program
(the simulator ignores partitioning) while ``parallel_cost()`` reports the
partition-parallel latency.
"""

from __future__ import annotations

import contextlib
from typing import List, Sequence

from .gates import Builder, G, Program


class PartitionedBuilder:
    def __init__(self, k: int, cpk: int = 128):
        self.k = k
        self.cpk = cpk
        self.b = Builder(reserve=k * cpk)
        self._next = [0] * k
        self._freep: List[List[int]] = [[] for _ in range(k)]
        self._steps: List[List[int]] = []
        self._open = False
        self._consts = {}

    # ------------------------------------------------------------ cells
    def palloc(self, p: int) -> int:
        if self._freep[p]:
            return self._freep[p].pop()
        off = self._next[p]
        if off >= self.cpk:
            raise RuntimeError(f"partition {p} exceeded {self.cpk} cells")
        self._next[p] += 1
        return p * self.cpk + off

    def pfree(self, cells):
        if isinstance(cells, int):
            cells = [cells]
        ports = {c for v in self.b.ports.values() for c in v}
        for c in set(cells):
            p = c // self.cpk
            if c in ports or c in self._consts.values() \
                    or c in self._freep[p]:
                continue
            self._freep[p].append(c)

    def part(self, cell: int) -> int:
        assert cell < self.k * self.cpk
        return cell // self.cpk

    def const(self, bit: int, p: int) -> int:
        """Partition-local constant (INIT emitted in the setup phase)."""
        key = (bit, p)
        if key not in self._consts:
            assert not self._open, "create consts outside cycles"
            c = self.palloc(p)
            self.b.emit(G.INIT1 if bit else G.INIT0, (), (c,))
            self._consts[key] = c
        return self._consts[key]

    def input(self, name: str, partitions: Sequence[int]) -> List[int]:
        cells = [self.palloc(p) for p in partitions]
        self.b.ports[name] = cells
        self.b.in_port_names.add(name)
        return cells

    def output(self, name: str, cells):
        self.b.ports[name] = list(cells)

    # ------------------------------------------------------------ cycles
    @contextlib.contextmanager
    def cycle(self):
        """All gates emitted inside run in ONE parallel cycle; validated."""
        assert not self._open
        self._open = True
        start = len(self.b.instrs)
        yield self
        self._open = False
        idxs = list(range(start, len(self.b.instrs)))
        self._validate(idxs)
        self._steps.append(idxs)

    @contextlib.contextmanager
    def waves(self):
        """Lane-grouped emission: every :meth:`lane` inside marks one
        independent section's gate sequence; on exit, the g-th gate of every
        lane is grouped into cycle g (all lanes advance in lockstep waves).
        Legal because each lane touches only its own section."""
        assert not self._open and not getattr(self, "_lanes", None)
        self._lanes = []
        self._open = True  # reuse the no-auto-cycle path of _emit1
        yield self
        self._open = False
        lanes, self._lanes = self._lanes, None
        n = max((len(l) for l in lanes), default=0)
        for g in range(n):
            idxs = [l[g] for l in lanes if g < len(l)]
            self._validate(idxs)
            self._steps.append(idxs)

    @contextlib.contextmanager
    def lane(self):
        self._lanes.append([])
        self._cur_lane = self._lanes[-1]
        yield self
        self._cur_lane = None

    def _validate(self, idxs):
        spans = []
        for i in idxs:
            ins = self.b.instrs[i]
            cells = [c for c in ins.ins + ins.outs]
            ps = [c // self.cpk for c in cells]
            spans.append((min(ps), max(ps)))
        spans.sort()
        for (l1, h1), (l2, h2) in zip(spans, spans[1:]):
            if l2 <= h1:
                raise RuntimeError(
                    f"illegal cycle: sections [{l1},{h1}] and [{l2},{h2}] overlap")

    # gate helpers usable inside (or outside -> own cycle) a cycle()
    def _emit1(self, fn, *args, p_out: int):
        if self._open:
            out = self.palloc(p_out)
            op, ins = fn(*args)
            self.b.emit(op, ins, (out,))
            if getattr(self, "_lanes", None) is not None and \
                    getattr(self, "_cur_lane", None) is not None:
                self._cur_lane.append(len(self.b.instrs) - 1)
            return out
        with self.cycle():
            return self._emit1(fn, *args, p_out=p_out)

    def id_(self, a, p_out):
        return self._emit1(lambda a: (G.ID, (a,)), a, p_out=p_out)

    def not_(self, a, p_out):
        return self._emit1(lambda a: (G.NOT, (a,)), a, p_out=p_out)

    def and_(self, a, b, p_out):
        return self._emit1(lambda a, b: (G.AND, (a, b)), a, b, p_out=p_out)

    def or_(self, a, b, p_out):
        return self._emit1(lambda a, b: (G.OR, (a, b)), a, b, p_out=p_out)

    def xor_(self, a, b, p_out):
        return self._emit1(lambda a, b: (G.XOR, (a, b)), a, b, p_out=p_out)

    def xnor_(self, a, b, p_out):
        return self._emit1(lambda a, b: (G.XNOR, (a, b)), a, b, p_out=p_out)

    def nor_(self, a, b, p_out):
        return self._emit1(lambda a, b: (G.NOR, (a, b)), a, b, p_out=p_out)

    def mux_(self, s, a, b, p_out):
        return self._emit1(lambda s, a, b: (G.MUX, (s, a, b)), s, a, b,
                           p_out=p_out)

    def muxn_(self, s, ns, a, b, p_out):
        return self._emit1(
            lambda s, ns, a, b: (G.MUXN, (s, ns, a, b)), s, ns, a, b,
            p_out=p_out)

    def fa_(self, a, b, c, p_out):
        """full adder; sum and carry cells in partition ``p_out``."""
        if self._open:
            s, co = self.palloc(p_out), self.palloc(p_out)
            self.b.emit(G.FA, (a, b, c), (s, co))
            if getattr(self, "_lanes", None) is not None and \
                    getattr(self, "_cur_lane", None) is not None:
                self._cur_lane.append(len(self.b.instrs) - 1)
            return s, co
        with self.cycle():
            return self.fa_(a, b, c, p_out=p_out)

    def finish(self) -> Program:
        return Program(self.b.n_cells, self.b.instrs, dict(self.b.ports),
                       parallel_steps=self._steps,
                       in_ports=self.b.in_port_names)


# --------------------------------------------------------------------------
# §5.2 partition toolbox
# --------------------------------------------------------------------------

def pshift(pb: PartitionedBuilder, bits: List[int], delta: int,
           fill=None) -> List[int]:
    """Shift technique (generalized): bit of partition i moves to partition
    i+delta.  |delta|+1 cycles, grouping sources by i mod (|delta|+1) so the
    spanned sections are disjoint.  ``fill``: cells (or const value) for the
    vacated positions."""
    k = len(bits)
    d = delta
    parts = [pb.part(c) for c in bits]     # the slot->partition map
    out: List[int] = [None] * k
    groups = abs(d) + 1
    for g in range(groups):
        with pb.cycle():
            for i in range(k):
                if i % groups != g:
                    continue
                j = i + d
                if 0 <= j < k:
                    out[j] = pb.id_(bits[i], p_out=parts[j])
    for j in range(k):
        if out[j] is None and fill is not None:
            out[j] = pb.const(int(fill), parts[j])
    return out


def broadcast(pb: PartitionedBuilder, src: int, lo: int = 0,
              hi: int = None) -> List[int]:
    """Broadcast technique: copy a single bit to all partitions [lo, hi) in
    ceil(log2(n)) cycles by recursive halving (paper Fig. 6).  If the source
    lives outside partition ``lo`` it is first pulled there (1 cycle)."""
    hi = pb.k if hi is None else hi
    # Always copy the source (even when already at ``lo``) so every returned
    # cell is fresh -- callers may free the whole result without aliasing
    # the (possibly still-live) source.
    src = pb.id_(src, p_out=lo)            # 1 semi-parallel long-range copy
    # ranges: (lo, hi, cell located at partition lo)
    ranges = [(lo, hi, src)]
    while any(h - l > 1 for l, h, _ in ranges):
        with pb.cycle():
            new = []
            for l, h, cell in ranges:
                if h - l <= 1:
                    new.append((l, h, cell))
                    continue
                mid = (l + h) // 2
                c2 = pb.id_(cell, p_out=mid)
                new.append((l, mid, cell))
                new.append((mid, h, c2))
            ranges = new
    out = [None] * pb.k
    for l, _h, cell in ranges:
        out[l] = cell
    return out


def reduce_tree(pb: PartitionedBuilder, bits: List[int], op: str) -> int:
    """Reduction technique: associative ``op`` over all partitions' bits in
    ceil(log2(k)) cycles; result lands in the last partition."""
    fn = {"and": pb.and_, "or": pb.or_, "xor": pb.xor_}[op]
    cur = list(bits)
    idx = [pb.part(c) for c in bits]
    while len(cur) > 1:
        with pb.cycle():
            nxt, nidx = [], []
            for i in range(0, len(cur) - 1, 2):
                nxt.append(fn(cur[i], cur[i + 1], p_out=idx[i + 1]))
                nidx.append(idx[i + 1])
            if len(cur) % 2:
                nxt.append(cur[-1])
                nidx.append(idx[-1])
        cur, idx = nxt, nidx
    return cur[0]


def prefix_scan(pb: PartitionedBuilder, state: List[tuple],
                combine) -> List[tuple]:
    """Prefix technique (Brent-Kung, paper Fig. 6): partition i ends with
    state_0 ∘ ... ∘ state_i in 2*ceil(log2(k)) - 1 waves.

    ``combine(pb, left_state, cur_state, p_out) -> new_state`` emits the ∘
    gates; it runs inside a :meth:`PartitionedBuilder.lane`, so concurrent
    combines advance in lockstep waves (gate g of every pair shares cycle g).
    """
    k = len(state)
    st = list(state)
    lg = max(1, (k - 1).bit_length())

    def run(pairs):
        res = {}
        with pb.waves():
            for l, i in pairs:
                with pb.lane():
                    res[i] = combine(pb, st[l], st[i], pb.part(st[i][0]))
        for _, i in pairs:
            st[i] = res[i]

    for d in range(lg):                       # up-sweep (reduction)
        stride = 1 << d
        run([(i - stride, i)
             for i in range(2 * stride - 1, k, 2 * stride)])
    for d in reversed(range(lg - 1)):         # down-sweep (fill the holes)
        stride = 1 << d
        run([(i, i + stride)
             for i in range(2 * stride - 1, k - stride, 2 * stride)])
    return st


def reduce_pairs(pb: PartitionedBuilder, states: List[tuple],
                 combine) -> tuple:
    """Reduction over multi-cell states (e.g. (generate, alive) pairs for the
    divider's carry-lookahead, paper §5.5): logarithmic tree of ``combine``
    waves; the fold is right-to-left so combine(left, cur) composes in index
    order.  Returns the final state (in the last involved partition)."""
    cur = list(states)
    while len(cur) > 1:
        nxt = []
        with pb.waves():
            res = {}
            for i in range(0, len(cur) - 1, 2):
                with pb.lane():
                    p_out = pb.part(cur[i + 1][0])
                    res[i] = combine(pb, cur[i], cur[i + 1], p_out)
        for i in range(0, len(cur) - 1, 2):
            nxt.append(res[i])
            pb.pfree(list(cur[i]) + list(cur[i + 1]))  # consumed pair states
        if len(cur) % 2:
            nxt.append(cur[-1])
        cur = nxt
    return cur[0]
