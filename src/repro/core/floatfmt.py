"""Floating-point formats and an exact IEEE-754 round-to-nearest-ties-even
oracle used to verify the in-memory algorithms (paper §7.1 verifies against
IEEE-adherent host arithmetic; we use exact rational arithmetic so the oracle
is bit-exact for *every* (ne, nm), including bf16 whose division is not exact
in float64).

Per the paper we exclude NaN/Inf/subnormals/overflow; encoded exponent 0 with
mantissa 0 represents zero.
"""

from __future__ import annotations

import dataclasses
from fractions import Fraction

import numpy as np


@dataclasses.dataclass(frozen=True)
class FloatFormat:
    ne: int
    nm: int

    @property
    def bias(self) -> int:
        return (1 << (self.ne - 1)) - 1

    @property
    def nbits(self) -> int:
        return 1 + self.ne + self.nm

    # ---------------------------------------------------------------- codec
    def encode(self, s: int, e: int, m: int) -> int:
        return (s << (self.ne + self.nm)) | (e << self.nm) | m

    def decode(self, bits: int):
        m = bits & ((1 << self.nm) - 1)
        e = (bits >> self.nm) & ((1 << self.ne) - 1)
        s = bits >> (self.ne + self.nm)
        return s, e, m

    def to_fraction(self, bits: int) -> Fraction:
        s, e, m = self.decode(bits)
        if e == 0:
            return Fraction(0)
        v = Fraction((1 << self.nm) + m, 1 << self.nm) * Fraction(2) ** (e - self.bias)
        return -v if s else v

    def from_fraction(self, v: Fraction) -> int:
        """Round ``v`` to this format with round-to-nearest, ties-to-even.

        Raises if the result over/underflows the normal range (the paper's
        excluded cases; tests avoid generating them).
        """
        if v == 0:
            return 0
        s = 1 if v < 0 else 0
        a = abs(v)
        # find e with 2^e <= a < 2^{e+1}
        e = a.numerator.bit_length() - a.denominator.bit_length()
        if Fraction(2) ** e > a:
            e -= 1
        assert Fraction(2) ** e <= a < Fraction(2) ** (e + 1)
        # mantissa = a / 2^e in [1,2); scaled = a * 2^{nm - e}
        scaled = a * Fraction(2) ** (self.nm - e)
        m_floor = scaled.numerator // scaled.denominator
        rem = scaled - m_floor
        if rem > Fraction(1, 2) or (rem == Fraction(1, 2) and (m_floor & 1)):
            m_floor += 1
        if m_floor == (1 << (self.nm + 1)):   # rounded up to next binade
            m_floor >>= 1
            e += 1
        ebits = e + self.bias
        if not (1 <= ebits <= (1 << self.ne) - 2):
            raise OverflowError(f"exponent {ebits} out of normal range")
        return self.encode(s, ebits, m_floor - (1 << self.nm))

    # ------------------------------------------------------------- operators
    def op_exact(self, op: str, xb: int, yb: int) -> int:
        x, y = self.to_fraction(xb), self.to_fraction(yb)
        if op == "add":
            r = x + y
        elif op == "sub":
            r = x - y
        elif op == "mul":
            r = x * y
        elif op == "div":
            r = x / y
        else:
            raise ValueError(op)
        if r == 0:
            return 0
        return self.from_fraction(r)

    # ------------------------------------------------------- numpy bridges
    def random_bits(self, rng: np.random.Generator, n: int,
                    emin=None, emax=None) -> np.ndarray:
        """Random normal-range encodings with exponents in [emin, emax]
        (biased); keeping exponents near the middle avoids the excluded
        overflow/underflow cases under arithmetic."""
        lo = emin if emin is not None else 1
        hi = emax if emax is not None else (1 << self.ne) - 2
        s = rng.integers(0, 2, n, dtype=np.int64)
        e = rng.integers(lo, hi + 1, n, dtype=np.int64)
        m = rng.integers(0, 1 << self.nm, n, dtype=np.int64)
        return (s << (self.ne + self.nm)) | (e << self.nm) | m


FP16 = FloatFormat(ne=5, nm=10)
BF16 = FloatFormat(ne=8, nm=7)
FP32 = FloatFormat(ne=8, nm=23)
FP64 = FloatFormat(ne=11, nm=52)

FORMATS = {"fp16": FP16, "bf16": BF16, "fp32": FP32, "fp64": FP64}


def np_bits(fmt: FloatFormat, arr: np.ndarray) -> np.ndarray:
    """Bit pattern of a numpy float array in ``fmt`` (fp16/fp32/fp64 only)."""
    if fmt is FP16:
        return arr.astype(np.float16).view(np.uint16).astype(np.uint64)
    if fmt is FP32:
        return arr.astype(np.float32).view(np.uint32).astype(np.uint64)
    if fmt is FP64:
        return arr.astype(np.float64).view(np.uint64)
    raise ValueError("no native numpy dtype for this format")
