"""NumPy-style ufunc frontend for the AritPIM machine (DESIGN.md §8).

The paper's suite as array-in / array-out elementwise operations: every
element occupies one PIM row, the whole array executes one shared, memoized
gate program, and execution flows through the chunked streaming executor
(``kernels.ops.run_program_streaming``) with optional multi-device row
sharding -- the scale path the throughput case study (Fig. 9) models.

    from repro import pim_ufunc as pim

    pim.add(x, y)              # uint8/16/32/64 -> full (w+1)-bit sums
    pim.mul(x, y, width=24)    # explicit width; double-width products
    pim.fp_add(a, b)           # float16/float32, exact IEEE RNE
    pim.fp_mul(xb, yb, fmt="bf16")   # bf16 as uint16 bit patterns

Dispatch: unsigned dtypes infer the bit width; ``width=`` overrides (and is
required for object/signed arrays).  Floats dispatch on dtype; formats with
no native numpy dtype (bf16) take ``fmt=`` plus bit-pattern arrays and
return bit patterns.  Inputs broadcast like numpy ufuncs.  Execution
config -- ``backend=``/``schedule=``/``layout=``/``shards=``/
``chunk_rows=``, or a ready ``plan=`` (``kernels.plan.ExecPlan``) -- is
normalized into one ExecPlan per call (DESIGN.md §11).

Per the paper, FP operands must be normal-range or zero: NaN/Inf and
subnormals are rejected up front (``check=False`` skips the scan).
"""

from __future__ import annotations

import contextlib
import dataclasses
import os
from typing import Callable, Dict, Optional

import numpy as np

from .core.floatfmt import FORMATS
from .core.pim_numerics import program_for
from .kernels import ops as kops
from .kernels import plan as kplan

__all__ = ["add", "sub", "mul", "div",
           "fp_add", "fp_sub", "fp_mul", "fp_div",
           "lazy", "LazyExpr", "fuse", "reduce_sum", "dot", "gemv",
           "prepare", "Prepared",
           "config", "configure", "options"]

INT_OPS = ("add", "sub", "mul", "div")
FP_OPS = ("fp_add", "fp_sub", "fp_mul", "fp_div")

#: Binary ops the lazy expression graph records (division does not fuse:
#: data-dependent iteration and a two-port result -- see DESIGN.md §13).
LAZY_OPS = ("add", "sub", "mul", "fp_add", "fp_sub", "fp_mul")


@dataclasses.dataclass
class Config:
    """Module-wide execution defaults; every ufunc takes keyword overrides.

    backend: 'ref' (jnp levelized; fastest under CPU interpret), 'pallas'
    (the TPU-shaped kernel), or 'numpy' (cycle-accurate oracle).
    chunk_rows: streaming chunk size; arrays larger than this stream through
    the pipelined executor.  shards: device count for row sharding (None =
    all available; 1 disables).  parallel: use the bit-parallel
    (partition-parallel) builders instead of bit-serial.  schedule: the
    executor's schedule compilation mode ('slots' contiguous-band scan
    executors, the default; 'slots-static' straight-line static-slice
    executors; 'dense' index-matrix executors).  layout: the packed word
    layout ('rows32' uint32 words, 'rows64' the paired 64-row layout) --
    see ``kernels.plan``.  faults: a ``runtime.faults.FaultModel`` (or
    None) injected into jax-backed execution.  verify: verified execution
    -- True / a ``runtime.faults.VerifyPolicy`` turns on per-chunk result
    checking with retry + row remap (DESIGN.md §12).

    These string fields are the convenience surface; :func:`_resolve`
    normalizes them into one ``kernels.plan.ExecPlan`` per call, and only
    the plan travels below this module.
    """
    backend: str = "ref"
    chunk_rows: int = kops.DEFAULT_CHUNK_ROWS
    shards: Optional[int] = None
    parallel: bool = False
    schedule: str = kops.DEFAULT_SCHEDULE
    layout: str = "rows32"
    faults: Optional[object] = None      # runtime.faults.FaultModel
    verify: Optional[object] = None      # bool | runtime.faults.VerifyPolicy
    # Persistent-artifact tier (DESIGN.md §16): a directory for the on-disk
    # compiled-artifact cache (None = disabled).  Setting it installs the
    # cache process-wide on the next ufunc resolution and auto-loads any
    # tuned.json the autotuner left beside it.
    cache_dir: Optional[str] = None
    # Apply autotuner-registered Backend/schedule defaults per program
    # family (runtime.tune).  Explicit per-call choices always win; set
    # False (or ``options(tuned=False)``) to force hand defaults.
    tuned: bool = True


config = Config()


def configure(**kw) -> Config:
    """Update module defaults (``configure(backend='pallas', shards=1)``);
    returns the live :data:`config`.  All keys are validated before any is
    applied, so a bad call never leaves the config half-mutated.  Prefer
    :func:`options` when the change should only cover a scope."""
    unknown = [k for k in kw if k not in Config.__dataclass_fields__]
    if unknown:
        raise TypeError(f"unknown config field(s) {sorted(unknown)}")
    for k, v in kw.items():
        setattr(config, k, v)
    return config


@contextlib.contextmanager
def options(**kw):
    """Scoped :data:`config` overrides::

        with pim.options(schedule="dense", backend="pallas"):
            pim.add(x, y)            # runs under the overrides
        # previous defaults restored, even on exception

    The batched serving runtime uses this to pin a per-group schedule or
    backend without leaking the choice into the process-wide defaults the
    way a raw :func:`configure` call would.  Yields the live config."""
    saved = {k: getattr(config, k) for k in Config.__dataclass_fields__}
    try:
        yield configure(**kw)
    finally:
        for k, v in saved.items():
            setattr(config, k, v)


_installed_cache_dir = None


def _ensure_artifact_cache() -> None:
    """Install (or drop) the process-wide on-disk artifact cache to match
    ``config.cache_dir``, loading any tuned.json the autotuner persisted
    beside it.  Idempotent per directory; runs at every ufunc resolution
    so a ``configure(cache_dir=...)`` takes effect on the next call."""
    global _installed_cache_dir
    cd = config.cache_dir
    if cd == _installed_cache_dir:
        return
    if cd is None:
        kops.set_artifact_cache(None)
        _installed_cache_dir = None
        return
    from .runtime.artifact_cache import ArtifactCache
    cache = ArtifactCache(cd)
    kops.set_artifact_cache(cache)
    tuned_path = cache.tuned_path()
    if os.path.exists(tuned_path):
        from .runtime import tune
        try:
            tune.install(tuned_path)
        except Exception:
            pass        # a corrupt tuned.json never blocks execution
    _installed_cache_dir = cd


def _resolve(kw, family: Optional[str] = None):
    """Normalize ufunc keywords + module defaults into one ExecPlan (the
    boundary where convenience strings stop existing); returns
    ``(plan, parallel)``.

    ``family`` is the program-family tag ("add:16", "fp_mul:fp16") the
    tuned-defaults overlay keys on: when the autotuner has registered
    winners for (family, layout, backend) and the caller left the
    corresponding knobs at their defaults, the tuned values apply
    transparently (``kernels.plan.apply_tuned``).  An explicit ``plan=``
    bypasses the overlay entirely."""
    _ensure_artifact_cache()

    def opt(name, default):
        v = kw.pop(name, None)
        return default if v is None else v

    if "plan" in kw:
        plan = kw.pop("plan")
        for k in ("backend", "schedule", "layout", "chunk_rows", "mesh",
                  "shards", "faults", "verify", "tuned"):
            if kw.pop(k, None) is not None:
                raise TypeError(
                    f"plan= is exclusive with the {k}= convenience keyword")
        parallel = opt("parallel", config.parallel)
        if kw:
            raise TypeError(f"unknown keyword arguments {sorted(kw)}")
        return kops.as_plan(plan), parallel
    backend = opt("backend", config.backend)
    if backend not in ("ref", "pallas", "numpy"):
        raise ValueError(f"unknown backend {backend!r}")
    chunk_rows = opt("chunk_rows", config.chunk_rows)
    parallel = opt("parallel", config.parallel)
    tuned = opt("tuned", config.tuned)
    schedule = opt("schedule", config.schedule)
    if schedule not in kops.SCHEDULES:
        raise ValueError(f"unknown schedule {schedule!r} "
                         f"(expected one of {kops.SCHEDULES})")
    layout = opt("layout", config.layout)
    faults = opt("faults", config.faults)
    verify = opt("verify", config.verify)
    if "mesh" in kw:
        mesh = kw.pop("mesh")
        kw.pop("shards", None)
    elif backend == "numpy":
        kw.pop("shards", None)
        mesh = None
    else:
        mesh = kops.row_mesh(opt("shards", config.shards))
    if backend == "numpy":
        # the oracle is the fault-free reference; faults/verify are
        # jax-backend concepts (like shards/mesh) and drop away here
        faults = verify = None
    if kw:
        raise TypeError(f"unknown keyword arguments {sorted(kw)}")
    plan = kops.as_plan(backend=backend, schedule=schedule, layout=layout,
                        mesh=mesh, chunk_rows=chunk_rows,
                        faults=faults, verify=verify)
    if tuned and family is not None:
        plan = kplan.apply_tuned(plan, family)
    return plan, parallel


@dataclasses.dataclass
class Prepared:
    """A parsed, validated ufunc request bound to its gate program -- the
    *program handle* the batched serving runtime plans over.

    ``prepare(op, x, y, ...)`` performs everything a ufunc call does except
    execution: broadcasting, width/format dispatch, operand validation, and
    program lookup.  The handle exposes the pieces a batching layer needs:
    the shared ``program`` (and its content-hash ``key``, the coalescing
    group key), the row-major ``inputs``, the resolved ``plan``
    (:class:`~repro.kernels.plan.ExecPlan` -- the full execution config as
    one object; ``plan.key`` is what the serving planner groups on), and
    ``finish`` -- the splitter hook that turns this request's slice of a
    coalesced output back into the user-facing result (reshape, fp bit
    decode, div's ``(q, r)`` pair).  ``run()`` executes standalone and is
    exactly equivalent to the one-shot ufunc call.
    """
    op: str
    program: object
    inputs: Dict[str, np.ndarray]
    n_rows: int
    plan: object                 # kernels.plan.ExecPlan
    _finish: Callable
    # compound-program provenance: how many primitive ufunc ops the fused
    # program subsumes (1 for plain ufunc requests) and the per-op
    # composition record -- ((op, width_or_fmt), ...) in topological order
    # for ``op == "expr"`` handles from :func:`fuse`.
    fused_ops: int = 1
    provenance: tuple = ()

    # convenience views of the plan (the historical string surface)
    @property
    def backend(self) -> str:
        return self.plan.backend.name

    @property
    def schedule(self) -> str:
        return self.plan.schedule

    @property
    def layout(self) -> str:
        return self.plan.layout.name

    @property
    def chunk_rows(self) -> int:
        return self.plan.effective_chunk_rows

    @property
    def mesh(self):
        return self.plan.mesh

    @property
    def key(self) -> bytes:
        """Content hash of the program -- structurally identical requests
        share it, which is what makes coalescing trivial."""
        return kops.content_key(self.program)

    @property
    def cached(self) -> bool:
        """True when the compiled-program cache already holds this
        program's schedule artifacts (execution pays no compile)."""
        if self.plan.backend.name == "numpy":
            return True                     # the oracle never compiles
        return kops.is_compiled(self.program, self.plan)

    def finish(self, outs: Dict[str, np.ndarray]):
        """Decode raw output-port rows (this request's rows only) into the
        user-facing result."""
        return self._finish(outs)

    def run(self):
        """Execute standalone through the streaming executor (identical to
        the plain ufunc call)."""
        return self._finish(_run(self.program, self.inputs, self.n_rows,
                                 self.plan))

    def warm(self, rows: int = 1) -> None:
        """Compile without serving: run ``rows`` leading rows (discarded)
        so levelize/lowering/jit happen outside any timed request."""
        rows = min(self.n_rows, max(1, rows))
        if rows < 1:
            return
        head = {n: v[:rows] for n, v in self.inputs.items()}
        plan = self.plan.with_backend("ref") \
            if self.plan.backend.name == "numpy" else self.plan
        kops.run_program(self.program, head, rows, plan)


def prepare(op: str, x, y, *, width=None, fmt=None, **kw) -> Prepared:
    """Parse + validate one elementwise request and bind it to its program
    without executing (see :class:`Prepared`).  ``op`` is the public ufunc
    name (``add``..``div``, ``fp_add``..``fp_div``); keywords are exactly
    the matching ufunc's."""
    if op in INT_OPS:
        if fmt is not None:
            raise TypeError(f"pim.{op} takes no fmt= (fixed point)")
        return _prepare_int(op, x, y, width, kw)
    if op in FP_OPS:
        if width is not None:
            raise TypeError(f"pim.{op} takes no width= (format-implied)")
        return _prepare_fp(op[3:], x, y, dict(kw, fmt=fmt))
    raise ValueError(f"pim.prepare: unknown op {op!r} "
                     f"(expected one of {INT_OPS + FP_OPS})")


def _run(prog, inputs, n_rows, plan):
    if plan.backend.name == "numpy":
        return kops.run_program(prog, inputs, n_rows, plan)
    # streaming falls back to one-shot run_program below chunk_rows itself
    return kops.run_program_streaming(prog, inputs, n_rows, plan)


# --------------------------------------------------------------------------
# fixed point
# --------------------------------------------------------------------------

_DTYPE_WIDTHS = {np.dtype(np.uint8): 8, np.dtype(np.uint16): 16,
                 np.dtype(np.uint32): 32, np.dtype(np.uint64): 64}


def _int_operands(op, x, y, width):
    """Broadcast, infer/validate the bit width, and flatten to rows."""
    x, y = np.broadcast_arrays(np.asarray(x), np.asarray(y))
    if width is None:
        wx = _DTYPE_WIDTHS.get(x.dtype)
        wy = _DTYPE_WIDTHS.get(y.dtype)
        if wx is None or wy is None:
            raise TypeError(
                f"pim.{op}: cannot infer width from dtypes "
                f"({x.dtype}, {y.dtype}); pass unsigned integer arrays or "
                "an explicit width=")
        if wx != wy:
            raise TypeError(
                f"pim.{op}: mixed operand widths {wx} and {wy}; cast to a "
                "common dtype or pass width=")
        width = wx
    else:
        width = int(width)
        if width < 1:
            raise ValueError(f"pim.{op}: width must be >= 1, got {width}")
        for name, v in (("x", x), ("y", y)):
            if v.dtype.kind not in "uiO":
                raise TypeError(
                    f"pim.{op}: operand {name} must be an integer array, "
                    f"got dtype {v.dtype}")
            if v.size and (_vmin(v) < 0 or _vmax(v) >> width):
                raise ValueError(
                    f"pim.{op}: operand {name} has values outside "
                    f"[0, 2**{width})")
    return x.ravel(), y.ravel(), x.shape, width


def _vmin(v):
    return min(v.flat) if v.dtype == object else int(v.min())


def _vmax(v):
    return max(v.flat) if v.dtype == object else int(v.max())


def _prepare_int(op, x, y, width, kw) -> Prepared:
    xr, yr, shape, w = _int_operands(op, x, y, width)
    plan, parallel = _resolve(kw, family=f"{op}:{w}")
    prog = program_for("int-parallel" if parallel else "int-serial", op, w)
    if op == "div":
        if xr.size and _vmin(yr) == 0:
            raise ValueError("pim.div: zero divisor")
        # the divider takes a double-width dividend port z and divisor d
        inputs = {"z": xr.astype(np.uint64) if xr.dtype != object else xr,
                  "d": yr}
        finish = lambda outs: (outs["q"].reshape(shape),
                               outs["r"].reshape(shape))
    else:
        inputs = {"x": xr, "y": yr}
        finish = lambda outs: outs["z"].reshape(shape)
    return Prepared(op, prog, inputs, xr.size, plan, finish)


def add(x, y, *, width=None, **kw):
    """Elementwise ``x + y`` with the full carry: (width+1)-bit sums as
    uint64 (object array beyond 63 bits).  Lazy operands record a fusable
    expression node instead of executing (see :func:`lazy`)."""
    if _is_lazy(x, y):
        return _lazy_node("add", x, y, width=width, kw=kw)
    return _prepare_int("add", x, y, width, kw).run()


def sub(x, y, *, width=None, **kw):
    """Elementwise ``x - y`` modulo 2**width (two's-complement wraparound),
    as uint64 (object array beyond 63 bits)."""
    if _is_lazy(x, y):
        return _lazy_node("sub", x, y, width=width, kw=kw)
    return _prepare_int("sub", x, y, width, kw).run()


def mul(x, y, *, width=None, **kw):
    """Elementwise ``x * y``: exact double-width (2*width-bit) products as
    uint64, or an object array when 2*width exceeds 63 bits."""
    if _is_lazy(x, y):
        return _lazy_node("mul", x, y, width=width, kw=kw)
    return _prepare_int("mul", x, y, width, kw).run()


def div(x, y, *, width=None, **kw):
    """Elementwise unsigned division: ``(x // y, x % y)`` as uint64 arrays
    (object beyond 63 bits).  Zero divisors are rejected."""
    if _is_lazy(x, y):
        raise TypeError("pim.div does not fuse (see DESIGN.md §13); "
                        "run it eagerly on materialized arrays")
    return _prepare_int("div", x, y, width, kw).run()


# --------------------------------------------------------------------------
# floating point
# --------------------------------------------------------------------------

_NP_FMT = {np.dtype(np.float16): "fp16", np.dtype(np.float32): "fp32"}
_FMT_VIEW = {"fp16": np.uint16, "fp32": np.uint32}


def _check_fp_bits(op, name, bits, fmt, reject_zero=False):
    """Reject the paper's excluded encodings: NaN/Inf (exponent all-ones)
    and subnormals (exponent 0, mantissa != 0).  Zero is a valid encoding
    except as a divisor."""
    b = bits if bits.dtype == object else bits.astype(np.uint64)
    e = np.array([(int(v) >> fmt.nm) & ((1 << fmt.ne) - 1) for v in b.flat],
                 np.int64) if b.dtype == object else \
        ((b >> np.uint64(fmt.nm)) & np.uint64((1 << fmt.ne) - 1)
         ).astype(np.int64)
    m = np.array([int(v) & ((1 << fmt.nm) - 1) for v in b.flat], np.int64) \
        if b.dtype == object else \
        (b & np.uint64((1 << fmt.nm) - 1)).astype(np.int64)
    emax = (1 << fmt.ne) - 1
    if (e == emax).any():
        raise ValueError(f"pim.{op}: operand {name} contains NaN/Inf "
                         "(excluded by the PIM suite)")
    if ((e == 0) & (m != 0)).any():
        raise ValueError(f"pim.{op}: operand {name} contains subnormals "
                         "(excluded by the PIM suite)")
    if reject_zero and ((e == 0) & (m == 0)).any():
        raise ValueError(f"pim.{op}: zero divisor")


def _prepare_fp(op, x, y, kw) -> Prepared:
    fmt = kw.pop("fmt", None)
    check = kw.pop("check", True)
    x, y = np.broadcast_arrays(np.asarray(x), np.asarray(y))
    if fmt is None:
        if x.dtype != y.dtype or x.dtype not in _NP_FMT:
            raise TypeError(
                f"pim.fp_{op}: operands must share a float16/float32 dtype "
                f"(got {x.dtype}, {y.dtype}); other formats take fmt= with "
                "bit-pattern arrays")
        fmt_name = _NP_FMT[x.dtype]
        view = _FMT_VIEW[fmt_name]
        xb = x.ravel().view(view).astype(np.uint64)
        yb = y.ravel().view(view).astype(np.uint64)
        decode = lambda bits: bits.astype(view).view(x.dtype).reshape(x.shape)
    else:
        if fmt not in FORMATS:
            raise ValueError(f"pim.fp_{op}: unknown format {fmt!r} "
                             f"(known: {sorted(FORMATS)})")
        fmt_name = fmt
        nbits = FORMATS[fmt].nbits
        for name, v in (("x", x), ("y", y)):
            if v.dtype.kind not in "uiO":
                raise TypeError(
                    f"pim.fp_{op}: fmt={fmt!r} takes bit-pattern integer "
                    f"arrays, got dtype {v.dtype}")
            if v.size and (_vmin(v) < 0 or _vmax(v) >> nbits):
                raise ValueError(
                    f"pim.fp_{op}: operand {name} has bit patterns outside "
                    f"[0, 2**{nbits})")
        xb = x.ravel().astype(np.uint64)
        yb = y.ravel().astype(np.uint64)
        decode = lambda bits: bits.reshape(x.shape)
    plan, parallel = _resolve(kw, family=f"fp_{op}:{fmt_name}")
    f = FORMATS[fmt_name]
    if check and xb.size:
        _check_fp_bits(f"fp_{op}", "x", xb, f)
        _check_fp_bits(f"fp_{op}", "y", yb, f, reject_zero=(op == "div"))
    if parallel and op == "sub":
        # the bit-parallel suite has no subtractor: flip y's sign, add
        yb = yb ^ np.uint64(1 << (f.nbits - 1))
        op = "add"
    prog = program_for("fp-parallel" if parallel else "fp-serial",
                       op, fmt_name)
    finish = lambda outs: decode(np.asarray(outs["z"], np.uint64))
    return Prepared(f"fp_{op}", prog, {"x": xb, "y": yb}, xb.size, plan,
                    finish)


def fp_add(x, y, *, fmt=None, **kw):
    """Elementwise FP addition, exactly rounded (IEEE RNE).  float16 /
    float32 arrays, or ``fmt='bf16'`` etc. with bit-pattern arrays.
    Lazy operands record a fusable expression node (see :func:`lazy`)."""
    if _is_lazy(x, y):
        return _lazy_node("fp_add", x, y, fmt=fmt, kw=kw)
    return _prepare_fp("add", x, y, dict(kw, fmt=fmt)).run()


def fp_sub(x, y, *, fmt=None, **kw):
    """Elementwise FP subtraction, exactly rounded (IEEE RNE)."""
    if _is_lazy(x, y):
        return _lazy_node("fp_sub", x, y, fmt=fmt, kw=kw)
    return _prepare_fp("sub", x, y, dict(kw, fmt=fmt)).run()


def fp_mul(x, y, *, fmt=None, **kw):
    """Elementwise FP multiplication, exactly rounded (IEEE RNE)."""
    if _is_lazy(x, y):
        return _lazy_node("fp_mul", x, y, fmt=fmt, kw=kw)
    return _prepare_fp("mul", x, y, dict(kw, fmt=fmt)).run()


def fp_div(x, y, *, fmt=None, **kw):
    """Elementwise FP division, exactly rounded (IEEE RNE).  Zero divisors
    are rejected."""
    if _is_lazy(x, y):
        raise TypeError("pim.fp_div does not fuse (see DESIGN.md §13); "
                        "run it eagerly on materialized arrays")
    return _prepare_fp("div", x, y, dict(kw, fmt=fmt)).run()


# --------------------------------------------------------------------------
# lazy expression graphs -> one fused program (DESIGN.md §13)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class LazyExpr:
    """A recorded (unexecuted) expression DAG node.

    Leaves hold a validated operand array (``value``: raw ints for fixed
    point, uint64 bit patterns for fp) plus its width or format; interior
    nodes hold a :data:`LAZY_OPS` op and two children.  Ufuncs called with
    a lazy operand return nodes instead of executing; :func:`fuse` (or
    ``expr.run()``) lowers the whole DAG into **one** levelized program --
    one pack, one execution, one unpack, intermediates never leaving the
    array.  ``+``/``-``/``*`` build nodes too, dispatching on ``kind``.
    """
    kind: str                            # 'int' | 'fp'
    op: Optional[str] = None             # None for leaves
    args: tuple = ()                     # child LazyExprs (nodes)
    value: Optional[np.ndarray] = None   # operand array (leaves)
    width: Optional[int] = None          # int leaves
    fmt: Optional[str] = None            # fp leaves/nodes
    dtype: Optional[object] = None       # native float dtype (fp leaves
    #                                      built from float16/float32)

    def _binop(self, op, other, reflect=False):
        if self.kind == "fp":
            op = "fp_" + op
        x, y = (other, self) if reflect else (self, other)
        return globals()[op](x, y)

    def __add__(self, other): return self._binop("add", other)
    def __radd__(self, other): return self._binop("add", other, True)
    def __sub__(self, other): return self._binop("sub", other)
    def __rsub__(self, other): return self._binop("sub", other, True)
    def __mul__(self, other): return self._binop("mul", other)
    def __rmul__(self, other): return self._binop("mul", other, True)

    def fuse(self, **kw) -> "Prepared":
        """Lower the DAG to one fused program handle (see :func:`fuse`)."""
        return fuse(self, **kw)

    def run(self, **kw):
        """Fuse and execute; equivalent to ``fuse(expr, **kw).run()``."""
        return fuse(self, **kw).run()


def lazy(x, *, width=None, fmt=None, check=True) -> LazyExpr:
    """Wrap an operand array as a lazy leaf.  Dispatch mirrors the eager
    ufuncs: float16/float32 arrays (or ``fmt=`` with bit patterns) become
    fp leaves, unsigned integer arrays (or ``width=``) fixed-point leaves.
    Validation (range, NaN/Inf/subnormal rejection) happens here, so a
    recorded graph is always executable.  Idempotent on LazyExpr."""
    if isinstance(x, LazyExpr):
        return x
    x = np.asarray(x)
    if fmt is None and x.dtype in _NP_FMT:
        fmt = _NP_FMT[x.dtype]
        bits = x.view(_FMT_VIEW[fmt]).astype(np.uint64)
        if check and bits.size:
            _check_fp_bits("lazy", "x", bits, FORMATS[fmt])
        return LazyExpr("fp", value=bits, fmt=fmt, dtype=x.dtype)
    if fmt is not None:
        if fmt not in FORMATS:
            raise ValueError(f"pim.lazy: unknown format {fmt!r} "
                             f"(known: {sorted(FORMATS)})")
        nbits = FORMATS[fmt].nbits
        if x.dtype.kind not in "uiO":
            raise TypeError(f"pim.lazy: fmt={fmt!r} takes bit-pattern "
                            f"integer arrays, got dtype {x.dtype}")
        if x.size and (_vmin(x) < 0 or _vmax(x) >> nbits):
            raise ValueError(f"pim.lazy: bit patterns outside "
                             f"[0, 2**{nbits})")
        bits = x.astype(np.uint64)
        if check and bits.size:
            _check_fp_bits("lazy", "x", bits, FORMATS[fmt])
        return LazyExpr("fp", value=bits, fmt=fmt)
    if width is None:
        width = _DTYPE_WIDTHS.get(x.dtype)
        if width is None:
            raise TypeError(
                f"pim.lazy: cannot infer width from dtype {x.dtype}; pass "
                "an unsigned integer array or an explicit width=")
    else:
        width = int(width)
        if width < 1:
            raise ValueError(f"pim.lazy: width must be >= 1, got {width}")
        if x.dtype.kind not in "uiO":
            raise TypeError(f"pim.lazy: operand must be an integer array, "
                            f"got dtype {x.dtype}")
        if x.size and (_vmin(x) < 0 or _vmax(x) >> width):
            raise ValueError(
                f"pim.lazy: operand has values outside [0, 2**{width})")
    return LazyExpr("int", value=x, width=width)


def _is_lazy(*vals) -> bool:
    return any(isinstance(v, LazyExpr) for v in vals)


def _lazy_node(op, x, y, width=None, fmt=None, kw=None) -> LazyExpr:
    """Record one binary node (ufunc lazy branch).  Execution keywords are
    rejected here -- they belong to fuse()/run(), where the whole graph's
    plan is resolved once."""
    if kw:
        raise TypeError(
            f"pim.{op}: execution keywords {sorted(kw)} do not apply to "
            "lazy operands; pass them to fuse()/run()")
    if op not in LAZY_OPS:
        raise TypeError(f"pim.{op} does not fuse (see DESIGN.md §13)")
    kind = "fp" if op.startswith("fp_") else "int"
    x = x if isinstance(x, LazyExpr) else lazy(x, width=width, fmt=fmt)
    y = y if isinstance(y, LazyExpr) else lazy(y, width=width, fmt=fmt)
    if x.kind != kind or y.kind != kind:
        raise TypeError(
            f"pim.{op}: operand kinds ({x.kind}, {y.kind}) do not match "
            "the op")
    if kind == "fp":
        if x.fmt != y.fmt:
            raise TypeError(f"pim.{op}: mixed fp formats "
                            f"({x.fmt}, {y.fmt})")
        return LazyExpr("fp", op=op, args=(x, y), fmt=x.fmt)
    return LazyExpr("int", op=op, args=(x, y))


def _graph_of(expr: LazyExpr):
    """Canonicalize a DAG into the hashable topological tuple
    ``pim_numerics.fused_program_for`` consumes; returns ``(graph,
    leaves)`` with leaves named ``i0, i1, ...`` in discovery order (shared
    subtrees canonicalize once -- the SSA sharing survives into the fused
    netlist)."""
    entries = []
    index: Dict[int, int] = {}
    leaves = []

    def visit(e: LazyExpr) -> int:
        idx = index.get(id(e))
        if idx is not None:
            return idx
        if e.op is None:
            name = f"i{len(leaves)}"
            leaves.append(e)
            entries.append(("in", name, e.width))
        else:
            i = visit(e.args[0])
            j = visit(e.args[1])
            op = e.op[3:] if e.op.startswith("fp_") else e.op
            entries.append((op, i, j))
        idx = index[id(e)] = len(entries) - 1
        return idx

    visit(expr)
    return tuple(entries), leaves


def _expr_pieces(expr: LazyExpr):
    """Lower a DAG to its execution pieces: (program, inputs, n_rows,
    shape, kind, fmt, decode, fused_ops, provenance)."""
    from .core.pim_numerics import fused_program_for
    graph, leaves = _graph_of(expr)
    is_fp = expr.kind == "fp"
    fmt = expr.fmt
    arrs = np.broadcast_arrays(*[l.value for l in leaves])
    shape = arrs[0].shape
    inputs = {f"i{k}": a.ravel() for k, a in enumerate(arrs)}
    n_rows = int(arrs[0].size)
    kind = "fp-serial" if is_fp else "int-serial"
    prog = fused_program_for(kind, graph, fmt)
    if is_fp:
        dts = {l.dtype for l in leaves}
        if len(dts) == 1 and None not in dts:
            dt = dts.pop()
            view = _FMT_VIEW[fmt]
            decode = lambda b: np.asarray(b, np.uint64).astype(view) \
                .view(dt).reshape(shape)
        else:
            decode = lambda b: np.asarray(b).reshape(shape)
    else:
        decode = lambda b: np.asarray(b).reshape(shape)
    widths, prov = [], []
    for e in graph:
        if e[0] == "in":
            widths.append(e[2])
        else:
            op, i, j = e
            if is_fp:
                widths.append(None)
                prov.append((f"fp_{op}", fmt))
            else:
                w = max(widths[i], widths[j])
                from .core.pim_numerics import _INT_OUT_WIDTH
                widths.append(_INT_OUT_WIDTH[op](w))
                prov.append((op, w))
    return (prog, inputs, n_rows, shape, kind, fmt, decode,
            max(1, len(prov)), tuple(prov))


def fuse(expr: LazyExpr, **kw) -> Prepared:
    """Lower a lazy expression DAG into **one** fused program handle.

    The per-op gate programs are stitched into a single netlist
    (``gates.compose``) and levelized as a whole -- shared SSA across op
    boundaries, DCE of intermediate port unpacks -- so the chain executes
    with one pack, one compiled program, one unpack, and flows through
    every downstream path (streaming, sharding, serving coalescing) like
    any other :class:`Prepared`.  Keywords are the ufunc execution
    keywords; the handle's ``op`` is ``"expr"``, its ``fused_ops``/
    ``provenance`` record the composition.
    """
    if not isinstance(expr, LazyExpr):
        raise TypeError("pim.fuse takes a LazyExpr (build one with "
                        "pim.lazy / lazy ufunc calls)")
    plan, parallel = _resolve(kw)
    if parallel:
        raise ValueError("expression fusion is bit-serial only (the "
                         "partition schedules of the bit-parallel "
                         "builders do not concatenate)")
    prog, inputs, n_rows, shape, kind, fmt, decode, n_ops, prov = \
        _expr_pieces(expr)
    finish = lambda outs: decode(outs["z"])
    return Prepared("expr", prog, inputs, n_rows, plan, finish,
                    fused_ops=n_ops, provenance=prov)


# --------------------------------------------------------------------------
# in-memory reductions: reduce_sum / dot / gemv
# --------------------------------------------------------------------------

def _pow2_at_least(n: int) -> int:
    p = 1
    while p < n:
        p <<= 1
    return p


def _pad_rows(vals: np.ndarray, total: int) -> np.ndarray:
    out = np.zeros(total, object if vals.dtype == object else np.uint64)
    out[:len(vals)] = vals
    return out


def reduce_sum(x, *, width=None, fmt=None, fused=True, deadline=None, **kw):
    """Sum every element of ``x`` (an array or a lazy expression) with a
    log-depth in-memory adder tree; returns a scalar.

    The elementwise stage (the fused expression program, or an identity
    copy for a plain array) and all reduction levels stay in the packed
    word domain -- one pack in, one single-row unpack out
    (``pim_numerics.tree_reduce_rows``).  Fixed point sums exactly (the
    accumulator grows one bit per level); fp sums in *tree order* under
    RNE, bit-exact against the same-shaped host tree.  ``fused=False``
    runs the identical pairing through per-op round trips (the unfused
    reference).  ``deadline`` (absolute ``time.monotonic()``) cancels the
    reduction between tree levels; a configured fault model / verify
    policy runs every level under verified execution (DESIGN.md §14)."""
    from .core import pim_numerics as pn
    e = lazy(x, width=width, fmt=fmt)
    plan, parallel = _resolve(kw)
    if parallel:
        raise ValueError("reductions are bit-serial only")
    prog, inputs, n_rows, shape, kind, efmt, decode, _, _ = _expr_pieces(e)
    if n_rows < 1:
        raise ValueError("pim.reduce_sum: empty reduction")
    total = _pow2_at_least(n_rows)
    padded = {n: _pad_rows(v, total) for n, v in inputs.items()}
    out = pn.tree_reduce_rows(prog, padded, total, 1, kind=kind, fmt=efmt,
                              plan=plan, fused=fused, deadline=deadline)
    if e.kind == "fp":
        leaves = _graph_of(e)[1]
        dts = {l.dtype for l in leaves}
        if len(dts) == 1 and None not in dts:
            view = _FMT_VIEW[efmt]
            return np.asarray(out, np.uint64).astype(view).view(
                dts.pop())[0]
        return np.asarray(out)[0]
    return np.asarray(out)[0]


def dot(x, y, *, width=None, fmt=None, fused=True, deadline=None, **kw):
    """In-memory dot product ``sum_k x[k] * y[k]``: one element-parallel
    multiply feeding a log-depth adder tree, intermediates never leaving
    the packed array (DESIGN.md §13).  Operands follow ufunc dispatch
    (unsigned ints / ``width=``; float16/float32 / ``fmt=`` bit
    patterns).  Fixed point is exact; fp is the tree-order RNE sum."""
    ex = lazy(x, width=width, fmt=fmt)
    ey = lazy(y, width=width, fmt=fmt)
    return reduce_sum(ex * ey, fused=fused, deadline=deadline, **kw)


def gemv(a, x, *, width=None, fmt=None, fused=True, deadline=None, **kw):
    """In-memory GEMV ``y[m] = sum_k a[m, k] * x[k]``.

    Each output ``m`` is a packed-domain reduction lane: products land at
    rows ``j*group + m`` (one multiply over all M*K products at once) and
    log2(K) in-memory adder levels fold the K axis -- the GEMV executes in
    ``1 + log2(K)`` program dispatches with no host round trip between
    them.  Semantics per element match :func:`dot`."""
    from .core import pim_numerics as pn
    ea = lazy(a, width=width, fmt=fmt)
    ex = lazy(x, width=width, fmt=fmt)
    if ea.op is not None or ex.op is not None:
        raise TypeError("pim.gemv takes operand arrays (compose lazy "
                        "expressions with reduce_sum instead)")
    if ea.kind != ex.kind or (ea.kind == "fp" and ea.fmt != ex.fmt):
        raise TypeError(f"pim.gemv: operand kinds/formats do not match "
                        f"({ea.kind}/{ea.fmt} vs {ex.kind}/{ex.fmt})")
    av, xv = ea.value, ex.value
    if av.ndim != 2 or xv.ndim != 1 or av.shape[1] != xv.shape[0]:
        raise ValueError(f"pim.gemv: need a (M, K) matrix and a (K,) "
                         f"vector, got {av.shape} and {xv.shape}")
    m, k = av.shape
    if k < 1 or m < 1:
        raise ValueError("pim.gemv: empty operands")
    plan, parallel = _resolve(kw)
    if parallel:
        raise ValueError("reductions are bit-serial only")
    group = pn.reduce_group(m)
    kp = _pow2_at_least(k)
    is_fp = ea.kind == "fp"
    w = None if is_fp else max(ea.width, ex.width)
    graph = (("in", "i0", w), ("in", "i1", w), ("mul", 0, 1))
    kind = "fp-serial" if is_fp else "int-serial"
    prog = pn.fused_program_for(kind, graph, ea.fmt)
    odt = object if (av.dtype == object or xv.dtype == object) else \
        np.uint64
    xa = np.zeros((kp, group), odt)
    xb = np.zeros((kp, group), odt)
    xa[:k, :m] = av.T                    # row j*group + m  <-  a[m, j]
    xb[:k, :m] = np.asarray(xv)[:, None]
    out = pn.tree_reduce_rows(prog, {"i0": xa.ravel(), "i1": xb.ravel()},
                              kp * group, group, kind=kind, fmt=ea.fmt,
                              plan=plan, fused=fused, deadline=deadline)
    out = np.asarray(out)[:m]
    if is_fp and ea.dtype is not None and ea.dtype == ex.dtype:
        return np.asarray(out, np.uint64).astype(
            _FMT_VIEW[ea.fmt]).view(ea.dtype)
    return out
