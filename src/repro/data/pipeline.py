"""Deterministic synthetic data pipeline.

Production posture: the iterator is a pure function of (seed, step,
shard_index) so restarts and elastic re-sharding resume exactly -- the
checkpoint only needs the step counter.  Token streams are Zipf-distributed
with document structure (BOS-delimited, packed); audio/vision batches carry
synthetic frontier embeddings (the modality frontends are stubs per the
assignment).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    mean_doc_len: int = 512
    frontend_dim: int = 0
    vision_seq: int = 0
    kind: str = "lm"          # lm / audio / vlm


def _rng(cfg: DataConfig, step: int, shard: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, shard]))


def batch_at(cfg: DataConfig, step: int, shard: int = 0, n_shards: int = 1):
    """The (step, shard)-th batch; deterministic and shard-disjoint."""
    b = cfg.global_batch // n_shards
    rng = _rng(cfg, step, shard)
    out = {}
    if cfg.kind == "audio":
        frames = rng.standard_normal(
            (b, cfg.seq_len, cfg.frontend_dim)).astype(np.float32)
        out["frames"] = frames
        out["labels"] = rng.integers(0, cfg.vocab,
                                     (b, cfg.seq_len)).astype(np.int32)
        return out
    # zipf-ish token stream with BOS-packed documents
    toks = rng.zipf(1.2, size=(b, cfg.seq_len)).astype(np.int64)
    toks = np.clip(toks, 1, cfg.vocab - 1).astype(np.int32)
    doc_ends = rng.random((b, cfg.seq_len)) < (1.0 / cfg.mean_doc_len)
    toks[doc_ends] = 0                       # BOS
    out["tokens"] = toks
    labels = np.roll(toks, -1, axis=1).astype(np.int32)
    labels[:, -1] = -1                       # no target for the final pos
    out["labels"] = labels
    if cfg.kind == "vlm":
        out["vision"] = rng.standard_normal(
            (b, cfg.vision_seq, cfg.frontend_dim)).astype(np.float32)
    return out


class DataIterator:
    """Stateful wrapper; state == step (restores exactly from checkpoints)."""

    def __init__(self, cfg: DataConfig, start_step: int = 0,
                 shard: int = 0, n_shards: int = 1):
        self.cfg = cfg
        self.step = start_step
        self.shard = shard
        self.n_shards = n_shards

    def __next__(self):
        b = batch_at(self.cfg, self.step, self.shard, self.n_shards)
        self.step += 1
        return b

    def state(self):
        return {"step": self.step}

    def restore(self, state):
        self.step = int(state["step"])
