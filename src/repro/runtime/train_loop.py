"""Quarantined fault-tolerant *training* loop.

The original training-side runtime (resume-from-latest, periodic async
checkpoints, preemption-safe exit).  It lives apart from
:mod:`~repro.runtime.fault_tolerance` so the serving path can reuse
:class:`~repro.runtime.fault_tolerance.Heartbeat` /
:class:`~repro.runtime.fault_tolerance.StragglerMonitor` without pulling
in signal handling or checkpoint machinery.
"""

from __future__ import annotations

import os
import signal
import time
from typing import Dict

from .fault_tolerance import Heartbeat, StragglerMonitor

__all__ = ["PreemptionGuard", "train_loop"]


class PreemptionGuard:
    """Converts SIGTERM/SIGINT into a cooperative "checkpoint now, then
    exit" signal (cloud preemption handling)."""

    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self.requested = False
        self._prev = {}
        for s in signals:
            try:
                self._prev[s] = signal.signal(s, self._handler)
            except ValueError:            # not in main thread (tests)
                pass

    def _handler(self, signum, frame):
        self.requested = True

    def restore(self):
        for s, h in self._prev.items():
            signal.signal(s, h)


def train_loop(*, step_fn, state, data_iter, ckpt, total_steps: int,
               ckpt_every: int = 100, log_every: int = 10,
               log_fn=print) -> Dict:
    """Generic fault-tolerant loop.

    step_fn(state, batch) -> (state, metrics);  state must contain 'step'.
    Resumes from the newest checkpoint if one exists; checkpoints
    asynchronously; a preemption request forces a final checkpoint.
    """
    guard = PreemptionGuard()
    mon = StragglerMonitor()
    hb = Heartbeat(os.path.join(ckpt.dir, "HEARTBEAT"), interval_s=5)
    latest = ckpt.latest_step()
    if latest is not None:
        state = ckpt.restore(state, step=latest)
        data_iter.restore({"step": latest})
        start = latest
        log_fn(f"[resume] restored step {latest}")
    else:
        start = 0
    metrics = {}
    for step in range(start, total_steps):
        t0 = time.time()
        batch = next(data_iter)
        state, metrics = step_fn(state, batch)
        dt = time.time() - t0
        mon.record(step, dt)
        hb.beat(step)
        if log_every and step % log_every == 0:
            log_fn(f"[step {step}] "
                   + " ".join(f"{k}={float(v):.4f}"
                              for k, v in metrics.items()) + f" dt={dt:.3f}s")
        if ckpt_every and step and step % ckpt_every == 0:
            ckpt.save_async(step + 1, state)      # tag = steps completed
        if guard.requested:
            log_fn(f"[preempt] checkpointing at step {step} and exiting")
            ckpt.wait()
            ckpt.save(step + 1, state)
            break
    ckpt.wait()
    guard.restore()
    return {"state": state, "metrics": metrics,
            "stragglers": mon.flagged}
