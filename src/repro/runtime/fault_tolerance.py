"""Liveness primitives shared by the serving loop (DESIGN.md §12).

* :class:`Heartbeat` -- liveness file an external supervisor can watch;
  ``--pim-heartbeat PATH`` makes the batched server beat it once per
  batch so a dead or wedged server is detectable from outside.
* :class:`StragglerMonitor` -- wall-time spike detection over a trailing
  median; the server records per-batch execution time and surfaces the
  spike count in its stats line.  In a multi-host deployment each host
  reports a heartbeat and the policy hook decides (log / re-shard /
  evict).  Single-process here, same API.

The training-side loop that historically lived here (PreemptionGuard +
train_loop) is quarantined in :mod:`~repro.runtime.train_loop`, which
imports these two classes back.
"""

from __future__ import annotations

import collections
import os
import time
from typing import Callable, Optional

import numpy as np

__all__ = ["Heartbeat", "StragglerMonitor"]


class StragglerMonitor:
    """Flags steps slower than ``threshold`` x the trailing median."""

    def __init__(self, window: int = 50, threshold: float = 2.0,
                 policy: Optional[Callable[[int, float, float], None]] = None):
        self.times = collections.deque(maxlen=window)
        self.threshold = threshold
        self.policy = policy
        self.flagged = []

    def record(self, step: int, dt: float) -> bool:
        is_straggler = False
        if len(self.times) >= 10:
            med = float(np.median(self.times))
            if dt > self.threshold * med:
                is_straggler = True
                self.flagged.append((step, dt, med))
                if self.policy:
                    self.policy(step, dt, med)
        self.times.append(dt)
        return is_straggler


class Heartbeat:
    def __init__(self, path: str, interval_s: float = 10.0):
        self.path = path
        self.interval = interval_s
        self._last = 0.0

    def beat(self, step: int) -> None:
        now = time.time()
        if now - self._last >= self.interval:
            tmp = self.path + ".tmp"
            with open(tmp, "w") as f:
                f.write(f"{step} {now}")
            os.replace(tmp, self.path)
            self._last = now
