"""Fault-tolerant training runtime.

* :class:`PreemptionGuard` -- converts SIGTERM/SIGINT into a cooperative
  "checkpoint now, then exit" signal (cloud preemption handling).
* :class:`StragglerMonitor` -- per-step wall-time EMA + spike detection;
  in a multi-host deployment each host reports a heartbeat and the policy
  hook decides (log / re-shard / evict).  Single-process here, same API.
* :class:`Heartbeat` -- liveness file an external supervisor can watch.
* :func:`train_loop` -- resume-from-latest, periodic async checkpoints,
  preemption-safe exit; the actual step function is injected.
"""

from __future__ import annotations

import collections
import os
import signal
import time
from typing import Callable, Dict, Optional

import numpy as np


class PreemptionGuard:
    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self.requested = False
        self._prev = {}
        for s in signals:
            try:
                self._prev[s] = signal.signal(s, self._handler)
            except ValueError:            # not in main thread (tests)
                pass

    def _handler(self, signum, frame):
        self.requested = True

    def restore(self):
        for s, h in self._prev.items():
            signal.signal(s, h)


class StragglerMonitor:
    """Flags steps slower than ``threshold`` x the trailing median."""

    def __init__(self, window: int = 50, threshold: float = 2.0,
                 policy: Optional[Callable[[int, float, float], None]] = None):
        self.times = collections.deque(maxlen=window)
        self.threshold = threshold
        self.policy = policy
        self.flagged = []

    def record(self, step: int, dt: float) -> bool:
        is_straggler = False
        if len(self.times) >= 10:
            med = float(np.median(self.times))
            if dt > self.threshold * med:
                is_straggler = True
                self.flagged.append((step, dt, med))
                if self.policy:
                    self.policy(step, dt, med)
        self.times.append(dt)
        return is_straggler


class Heartbeat:
    def __init__(self, path: str, interval_s: float = 10.0):
        self.path = path
        self.interval = interval_s
        self._last = 0.0

    def beat(self, step: int) -> None:
        now = time.time()
        if now - self._last >= self.interval:
            tmp = self.path + ".tmp"
            with open(tmp, "w") as f:
                f.write(f"{step} {now}")
            os.replace(tmp, self.path)
            self._last = now


def train_loop(*, step_fn, state, data_iter, ckpt, total_steps: int,
               ckpt_every: int = 100, log_every: int = 10,
               log_fn=print) -> Dict:
    """Generic fault-tolerant loop.

    step_fn(state, batch) -> (state, metrics);  state must contain 'step'.
    Resumes from the newest checkpoint if one exists; checkpoints
    asynchronously; a preemption request forces a final checkpoint.
    """
    guard = PreemptionGuard()
    mon = StragglerMonitor()
    hb = Heartbeat(os.path.join(ckpt.dir, "HEARTBEAT"), interval_s=5)
    latest = ckpt.latest_step()
    if latest is not None:
        state = ckpt.restore(state, step=latest)
        data_iter.restore({"step": latest})
        start = latest
        log_fn(f"[resume] restored step {latest}")
    else:
        start = 0
    metrics = {}
    for step in range(start, total_steps):
        t0 = time.time()
        batch = next(data_iter)
        state, metrics = step_fn(state, batch)
        dt = time.time() - t0
        mon.record(step, dt)
        hb.beat(step)
        if log_every and step % log_every == 0:
            log_fn(f"[step {step}] "
                   + " ".join(f"{k}={float(v):.4f}"
                              for k, v in metrics.items()) + f" dt={dt:.3f}s")
        if ckpt_every and step and step % ckpt_every == 0:
            ckpt.save_async(step + 1, state)      # tag = steps completed
        if guard.requested:
            log_fn(f"[preempt] checkpointing at step {step} and exiting")
            ckpt.wait()
            ckpt.save(step + 1, state)
            break
    ckpt.wait()
    guard.restore()
    return {"state": state, "metrics": metrics,
            "stragglers": mon.flagged}
