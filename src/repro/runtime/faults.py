"""Deterministic fault models for the PIM substrate (DESIGN.md §12).

AritPIM's case study targets memristive crossbars, where stuck-at cells,
transient disturb flips and worn-out rows are first-class hardware
realities.  This module is the *model* half of the fault-tolerance layer:
a frozen, seeded :class:`FaultModel` that maps physical coordinates (rows,
packed word columns) to persistent faults, and samples per-level transient
flips -- all counter-based (splitmix64 over absolute coordinates), so any
span can be queried in any order, any number of times, with identical
answers and zero mutable state.  The *mechanism* half (check words, chunk
retry, row remapping) lives in ``kernels.ops``; the knobs that govern it
are :class:`VerifyPolicy` here.

Fault semantics, chosen to be layout-polymorphic (identical observable
effect under rows32 and rows64, fused-value and packed-word output paths):

* **dead row** -- an endurance-failed physical row: every cell of that row
  reads 0.  Persistent: the same absolute row is dead forever.
* **stuck word column** -- one aligned 32-row group (absolute uint32 word
  column ``j`` covers physical rows ``32j .. 32j+31``) whose readback is
  stuck all-0 or all-1 across every cell.  Models a failed sense-amp /
  driver stripe.  Persistent.
* **transient flip** -- per executed level, with probability ``p_flip``,
  one random output-cell bit of the chunk flips.  Re-sampled per attempt
  (``attempt`` feeds the hash), so a retry re-rolls the dice -- the
  defining property of a transient.

Persistent faults are discoverable *before* execution (the simulated BIST
scan :meth:`FaultModel.span_bad` -- how the remapper steers chunks onto
clean spare rows); transients are only observable *after*, which is what
the check-word + spot-check machinery in ``kernels.ops`` is for.

This module imports only ``runtime.telemetry`` (itself stdlib +
``core.device_model`` only) from the package: ``kernels.plan`` hangs a
FaultModel off every ExecPlan, so anything heavier imported here would
cycle.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
from typing import Dict, Optional, Tuple

import numpy as np

from . import telemetry

__all__ = ["FaultModel", "VerifyPolicy", "FaultError", "DeadlineExceeded",
           "word_coords", "Scrubber", "record_wear", "note_quarantine",
           "quarantined_spans", "release_span", "wear_snapshot",
           "drain_media_health"]


class FaultError(RuntimeError):
    """Verified execution exhausted its retry/remap budget (or no clean
    physical span exists): the result could not be produced bit-exactly.

    ``context`` carries the structured failure coordinates the serving
    error taxonomy surfaces to operators (``classify_error`` folds it into
    the response's error payload): the failing program's content-key
    prefix, the chunk/stage that died, how many attempts were burned and
    where the remapper last placed it.  Only non-None fields are kept, and
    a bare ``FaultError("msg")`` stays valid (``context == {}``)."""

    def __init__(self, message: str = "", **context):
        super().__init__(message)
        self.context = {k: v for k, v in context.items() if v is not None}


class DeadlineExceeded(RuntimeError):
    """A per-request deadline expired before (or between) chunks."""


# ------------------------------------------------------------ hashing
#
# Counter-based randomness: splitmix64 over absolute coordinates.  numpy
# uint64 arithmetic wraps silently (unlike Python ints), which is exactly
# the mod-2^64 semantics splitmix wants.

_GOLD = np.uint64(0x9E3779B97F4A7C15)
_MIX1 = np.uint64(0xBF58476D1CE4E5B9)
_MIX2 = np.uint64(0x94D049BB133111EB)
_MASK64 = (1 << 64) - 1


def _mix64(x: np.ndarray) -> np.ndarray:
    x = np.asarray(x, np.uint64)
    with np.errstate(over="ignore"):        # mod-2^64 wrap is the point
        x = (x ^ (x >> np.uint64(30))) * _MIX1
        x = (x ^ (x >> np.uint64(27))) * _MIX2
    return x ^ (x >> np.uint64(31))


def _h(key: int, idx) -> np.ndarray:
    """Uniform uint64 hash of each element of ``idx`` under ``key``."""
    idx = np.asarray(idx, np.uint64)
    with np.errstate(over="ignore"):
        seeded = idx * _GOLD + np.uint64(key & _MASK64)
    return _mix64(_mix64(seeded) ^ _GOLD)


def _u01(h: np.ndarray) -> np.ndarray:
    """Map uint64 hashes to uniform floats in [0, 1)."""
    return (h >> np.uint64(11)).astype(np.float64) * (2.0 ** -53)


# Domain-separation tags for the per-fault-kind hash streams.
_T_DEAD, _T_STUCK, _T_STUCKV, _T_FLIP, _T_FLIPPOS = 1, 2, 3, 4, 5


def word_coords(rows, planes: int) -> tuple:
    """Map chunk-relative row indices to packed-state coordinates
    ``(plane, word, bit)`` for a ``planes``-layout state: rows32 puts row
    ``r`` at bit ``r % 32`` of word ``r // 32`` (plane always 0); rows64
    puts it at plane ``(r % 64) // 32`` of word ``r // 64`` -- the
    little-endian uint32 halves of one 64-row word.  The single source of
    truth for fault-injection coordinates (``kernels.slots`` re-exports it
    next to its band helpers)."""
    r = np.asarray(rows, np.int64)
    rpw = 32 * planes
    return (r % rpw) // 32, r // rpw, r % 32


@dataclasses.dataclass(frozen=True)
class FaultModel:
    """Seeded, deterministic fault map for the simulated PIM substrate.

    Probabilistic fields sample faults by hashed absolute coordinate;
    ``force_*`` fields plant specific faults for tests:

    * ``force_dead_rows`` -- absolute physical row indices.
    * ``force_stuck`` -- ``(word_col, bit_value)`` pairs: absolute uint32
      word column stuck at all-0 (``0``) or all-1 (``1``).
    * ``force_flips`` -- ``(out_cell, row)`` pairs injected only on a
      chunk's *first* attempt (transients re-roll on retry; a forced flip
      that persisted would be a stuck fault, not a transient).

    ``spare_base`` is the first physical row of the spare region the
    remapper allocates from; keep it far above any real traffic.  All
    fields are hashable scalars/tuples so the model can live on a frozen
    ``ExecPlan`` and inside ``plan.key``.
    """
    seed: int = 0
    p_flip: float = 0.0          # per level, per chunk attempt
    p_stuck: float = 0.0         # per aligned 32-row word column
    p_dead_row: float = 0.0      # per physical row
    spare_base: int = 1 << 34
    force_flips: Tuple = ()
    force_dead_rows: Tuple = ()
    force_stuck: Tuple = ()

    def __post_init__(self):
        for name in ("p_flip", "p_stuck", "p_dead_row"):
            p = getattr(self, name)
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {p}")
        if self.spare_base % 64:
            raise ValueError("spare_base must be 64-row aligned "
                             f"(got {self.spare_base})")
        for attr in ("force_flips", "force_dead_rows", "force_stuck"):
            object.__setattr__(self, attr,
                               tuple(tuple(v) if isinstance(v, (list, tuple))
                                     else int(v)
                                     for v in getattr(self, attr)))

    def _key(self, tag: int) -> int:
        return (int(self.seed) * 0x100000001B3 + tag) & _MASK64

    # ------------------------------------------------- persistent faults

    def dead_rows(self, lo: int, hi: int) -> np.ndarray:
        """Absolute dead physical rows in ``[lo, hi)``, sorted."""
        parts = [np.asarray([r for r in self.force_dead_rows
                             if lo <= r < hi], np.int64)]
        if self.p_dead_row > 0.0 and hi > lo:
            rows = np.arange(lo, hi, dtype=np.int64)
            parts.append(rows[_u01(_h(self._key(_T_DEAD), rows))
                              < self.p_dead_row])
        return np.unique(np.concatenate(parts)) if len(parts) > 1 or \
            parts[0].size else parts[0]

    def stuck_cols(self, wlo: int, whi: int
                   ) -> Tuple[np.ndarray, np.ndarray]:
        """Stuck word columns in absolute uint32-word range ``[wlo, whi)``:
        ``(word indices int64[], fill words uint32[])`` where each fill is
        0x00000000 (stuck-at-0) or 0xFFFFFFFF (stuck-at-1).  Forced
        entries override sampled ones on the same column."""
        stuck = {}
        if self.p_stuck > 0.0 and whi > wlo:
            words = np.arange(wlo, whi, dtype=np.int64)
            sel = _u01(_h(self._key(_T_STUCK), words)) < self.p_stuck
            words = words[sel]
            ones = (_h(self._key(_T_STUCKV), words)
                    & np.uint64(1)).astype(bool)
            for w, o in zip(words, ones):
                stuck[int(w)] = np.uint32(0xFFFFFFFF) if o else np.uint32(0)
        for w, v in self.force_stuck:
            if wlo <= w < whi:
                stuck[int(w)] = np.uint32(0xFFFFFFFF) if v else np.uint32(0)
        if not stuck:
            return np.zeros(0, np.int64), np.zeros(0, np.uint32)
        ws = np.asarray(sorted(stuck), np.int64)
        return ws, np.asarray([stuck[int(w)] for w in ws], np.uint32)

    def span_bad(self, row_base: int, n_rows: int) -> bool:
        """Simulated BIST media scan: does the physical span
        ``[row_base, row_base + n_rows)`` contain any persistent fault
        (dead row or stuck word column)?  This is the pre-placement check
        the remapper uses to steer chunks onto clean spare spans -- it
        reads the *model*, standing in for a write/readback march test."""
        if self.dead_rows(row_base, row_base + n_rows).size:
            return True
        w, _ = self.stuck_cols(row_base // 32, (row_base + n_rows + 31) // 32)
        return bool(w.size)

    # ------------------------------------------------- transient faults

    def sample_flips(self, salt: int, attempt: int, n_levels: int,
                     k_out: int, n_rows: int
                     ) -> Tuple[np.ndarray, np.ndarray]:
        """Transient output-bit flips for one chunk attempt: arrays
        ``(cells, rows)`` -- flipped output-cell index (of the ``k_out``
        stacked output cells) and chunk-relative row.  Each of the chunk's
        ``n_levels`` executed levels flips one uniformly random output bit
        with probability ``p_flip``; ``salt`` carries the chunk identity
        and ``attempt`` re-rolls on retry.  Forced flips apply on attempt
        0 only."""
        cells = [np.asarray([t for t, r in self.force_flips
                             if 0 <= t < k_out and 0 <= r < n_rows],
                            np.int64)] if attempt == 0 else []
        rows = [np.asarray([r for t, r in self.force_flips
                            if 0 <= t < k_out and 0 <= r < n_rows],
                           np.int64)] if attempt == 0 else []
        if self.p_flip > 0.0 and n_levels > 0 and k_out > 0 and n_rows > 0:
            key = int(_mix64(np.uint64(
                (self._key(_T_FLIP) ^ (salt & _MASK64)
                 ^ (attempt * 0x9E3779B97F4A7C15)) & _MASK64)))
            lv = np.arange(n_levels, dtype=np.int64)
            hit = lv[_u01(_h(key, lv)) < self.p_flip]
            if hit.size:
                pos = _h((key + _T_FLIPPOS) & _MASK64, hit)
                cells.append((pos % np.uint64(k_out)).astype(np.int64))
                rows.append(((pos >> np.uint64(20))
                             % np.uint64(n_rows)).astype(np.int64))
        if not cells:
            return np.zeros(0, np.int64), np.zeros(0, np.int64)
        return np.concatenate(cells), np.concatenate(rows)

    # ------------------------------------------------- injection appliers
    #
    # Both output representations of the levelized dispatcher get a
    # fault-applier with identical observable semantics, so detection and
    # recovery are representation-agnostic:
    #   * packed word blocks (the padded-io path): (k, W) rows32 or
    #     (planes, k, W) rows64, cell axis -2;
    #   * fused per-port row values (the fused fast path): (P, R) uint32.

    def inject_packed(self, sub: np.ndarray, *, row_base: int, salt: int,
                      attempt: int, n_levels: int
                      ) -> Tuple[np.ndarray, int]:
        """Apply this model's faults to a packed output block covering
        physical rows ``[row_base, row_base + span)``; returns
        ``(corrupted copy, number of faults applied)``."""
        sub = np.array(sub, copy=True)
        if sub.ndim == 2:
            planes, (k, n_words) = 1, sub.shape
        else:
            planes, k, n_words = sub.shape
        span = n_words * 32 * planes
        n = 0
        dead = self.dead_rows(row_base, row_base + span)
        if dead.size:
            pl, w, b = word_coords(dead - row_base, planes)
            clear = np.zeros((planes, n_words), np.uint32)
            np.bitwise_or.at(clear, (pl, w),
                             np.uint32(1) << b.astype(np.uint32))
            sub &= ~clear[0][None, :] if sub.ndim == 2 \
                else ~clear[:, None, :]
            n += int(dead.size)
        wcols, fills = self.stuck_cols(row_base // 32,
                                       (row_base + span) // 32)
        if wcols.size:
            pl, w, _ = word_coords(wcols * 32 - row_base, planes)
            if sub.ndim == 2:
                sub[:, w] = fills[None, :]
            else:
                sub[pl, :, w] = fills[:, None]
            n += int(wcols.size)
        cells, rows = self.sample_flips(salt, attempt, n_levels, k, span)
        if cells.size:
            pl, w, b = word_coords(rows, planes)
            bit = np.uint32(1) << b.astype(np.uint32)
            if sub.ndim == 2:
                np.bitwise_xor.at(sub, (cells, w), bit)
            else:
                np.bitwise_xor.at(sub, (pl, cells, w), bit)
            n += int(cells.size)
        return sub, n

    def inject_values(self, vals: np.ndarray, out_widths, *, row_base: int,
                      salt: int, attempt: int, n_levels: int
                      ) -> Tuple[np.ndarray, int]:
        """Apply this model's faults to fused per-port row values
        ``uint32[n_ports, span]`` (port ``p``'s row ``r`` is the packed
        value of its ``out_widths[p]`` cells); same observable semantics
        as :meth:`inject_packed` on the corresponding packed block."""
        vals = np.array(vals, copy=True)
        n_ports, span = vals.shape
        masks = np.asarray([(np.uint32(1) << np.uint32(w)) - np.uint32(1)
                            if w < 32 else np.uint32(0xFFFFFFFF)
                            for w in out_widths], np.uint32)
        n = 0
        dead = self.dead_rows(row_base, row_base + span)
        if dead.size:
            vals[:, dead - row_base] = 0
            n += int(dead.size)
        wcols, fills = self.stuck_cols(row_base // 32,
                                       (row_base + span) // 32)
        if wcols.size:
            starts = wcols * 32 - row_base
            idx = (starts[:, None] + np.arange(32)).ravel()
            fill_rows = np.repeat(fills != 0, 32)
            vals[:, idx[~fill_rows]] = 0
            if fill_rows.any():
                vals[:, idx[fill_rows]] = masks[:, None]
            n += int(wcols.size)
        k_out = int(sum(out_widths))
        cells, rows = self.sample_flips(salt, attempt, n_levels, k_out, span)
        if cells.size:
            bounds = np.cumsum(np.asarray(out_widths, np.int64))
            port = np.searchsorted(bounds, cells, side="right")
            bit = cells - (bounds[port] - np.asarray(out_widths,
                                                     np.int64)[port])
            np.bitwise_xor.at(vals, (port, rows),
                              np.uint32(1) << bit.astype(np.uint32))
            n += int(cells.size)
        return vals, n


@dataclasses.dataclass(frozen=True)
class VerifyPolicy:
    """Knobs of verified execution's detect -> retry -> remap machinery
    (the state machine itself lives in ``kernels.ops``; DESIGN.md §12).

    * ``max_retries`` -- chunk re-executions before giving up with
      :class:`FaultError`.
    * ``remap_after`` -- failed attempts at one physical placement before
      the chunk is re-homed onto a fresh spare span (attempts below this
      assume a transient and just re-run in place).
    * ``backoff_s`` -- base of the exponential inter-retry backoff
      (``backoff_s * 2**(attempt-1)``, capped at 50 ms).
    * ``spot_rows`` / ``spot_interval_rows`` -- numpy-oracle spot checks:
      every ``spot_interval_rows`` verified rows, ``spot_rows`` sampled
      rows of the next chunk are recomputed on the cycle-accurate oracle
      and compared bit-exactly.  Amortized per *row*, not per chunk, so
      small hot arrays don't oracle-check every call; 0 interval checks
      every chunk (tests), ``spot_rows=0`` disables.
    * ``scan_limit`` -- spare spans the media scan may reject while
      placing one chunk before :class:`FaultError`.
    """
    max_retries: int = 4
    remap_after: int = 2
    backoff_s: float = 5e-4
    spot_rows: int = 2
    spot_interval_rows: int = 1 << 20
    scan_limit: int = 16

    def __post_init__(self):
        if self.max_retries < 0 or self.remap_after < 1 \
                or self.scan_limit < 1:
            raise ValueError("max_retries >= 0, remap_after >= 1 and "
                             "scan_limit >= 1 required")


# --------------------------------------------------------------------------
# media lifecycle: wear counters + quarantined-span scrubbing
# --------------------------------------------------------------------------
#
# Verified execution (kernels.ops) reports two media events here: every
# dispatch attempt *writes* a physical span (wear -- memristive endurance
# is finite, so operators need per-span write counts), and every remap
# *abandons* a physical span (quarantine -- the span either holds a real
# persistent fault or merely looked marginal during a transient storm).
# The :class:`Scrubber` is the background half: it periodically re-scans
# quarantined spans with the same simulated BIST used for placement,
# reclaiming the ones that scan clean and keeping genuinely bad media out
# of circulation.  Everything is module-level (one physical substrate per
# process, like ops.HEALTH) and lock-guarded, because the scrubber thread
# runs concurrently with the serving executor.

_MEDIA_LOCK = threading.Lock()

#: Per physical span (keyed by base row): verified dispatch attempts that
#: wrote it.  The endurance ledger -- memristive cells wear out, and a
#: span that absorbs orders of magnitude more writes than its peers is the
#: next dead row.
WEAR: "collections.Counter" = collections.Counter()

#: Spans the remapper abandoned, base row -> span rows; the scrubber's
#: work queue.
_QUARANTINE: Dict[int, int] = {}

#: Cumulative scrub/wear health counters (scrub_passes, spans_scrubbed,
#: spans_reclaimed, spans_still_bad, quarantined_spans, wear_writes) --
#: a Counter-shaped view over the global telemetry registry's
#: ``pim.media.*`` names, guarded by the registry's lock (``_MEDIA_LOCK``
#: keeps guarding the WEAR/_QUARANTINE structures above);
#: :func:`drain_media_health` snapshots-and-resets (the serving stats
#: absorb them next to ops.drain_health()).
MEDIA: "telemetry.CounterGroup" = telemetry.REGISTRY.group("pim.media")


def record_wear(row_base: int, n_rows: int, attempts: int = 1) -> None:
    """Count ``attempts`` write cycles against the span at ``row_base``."""
    with _MEDIA_LOCK:
        WEAR[int(row_base)] += int(attempts)
    MEDIA.add("wear_writes", int(attempts))


def note_quarantine(row_base: int, n_rows: int) -> None:
    """Hand an abandoned span to the scrubber's work queue."""
    with _MEDIA_LOCK:
        prev = _QUARANTINE.get(int(row_base), 0)
        if int(n_rows) > prev:
            _QUARANTINE[int(row_base)] = int(n_rows)
    if not prev:
        MEDIA.add("quarantined_spans")


def quarantined_spans() -> Dict[int, int]:
    """Snapshot of the quarantine queue (base row -> span rows)."""
    with _MEDIA_LOCK:
        return dict(_QUARANTINE)


def release_span(row_base: int) -> bool:
    """Drop a span from quarantine (it scanned clean); True if present."""
    with _MEDIA_LOCK:
        return _QUARANTINE.pop(int(row_base), None) is not None


def wear_snapshot(top: int = 8) -> Dict[int, int]:
    """The ``top`` most-written spans (base row -> write count)."""
    with _MEDIA_LOCK:
        return dict(sorted(WEAR.items(), key=lambda kv: -kv[1])[:top])


def drain_media_health() -> dict:
    """Snapshot and reset :data:`MEDIA`; returns the non-zero counters.
    (Compatibility shim over ``MEDIA.drain()`` -- the historical API.)"""
    return MEDIA.drain()


class Scrubber:
    """Background spare-span scrubber (DESIGN.md §14).

    Re-scans every quarantined span against ``model``'s simulated BIST:
    spans that scan clean were quarantined by a transient storm (the
    remapper treats "keeps failing verification" as "marginal media") and
    are *reclaimed* -- released from quarantine so the physical rows
    return to the usable pool; spans with persistent faults stay
    quarantined and are re-checked next pass.  ``scrub_once`` is the
    synchronous unit of work (tests drive it directly);
    ``start``/``stop`` run it on a daemon thread at ``interval_s`` --
    the serving loop's background media hygiene.
    """

    def __init__(self, model: "FaultModel", *, interval_s: float = 0.25):
        self.model = model
        self.interval_s = float(interval_s)
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def scrub_once(self) -> dict:
        """One scrub pass; returns ``{"scrubbed", "reclaimed",
        "still_bad"}`` counts and updates :data:`MEDIA`."""
        reclaimed = still_bad = 0
        for base, rows in quarantined_spans().items():
            if self.model.span_bad(base, rows):
                still_bad += 1
            elif release_span(base):
                reclaimed += 1
        MEDIA.add("scrub_passes")
        MEDIA.add("spans_scrubbed", reclaimed + still_bad)
        MEDIA.add("spans_reclaimed", reclaimed)
        MEDIA["spans_still_bad"] = still_bad   # gauge, not cumulative
        return {"scrubbed": reclaimed + still_bad,
                "reclaimed": reclaimed, "still_bad": still_bad}

    def start(self) -> "Scrubber":
        if self._thread is not None:
            return self
        self._stop.clear()

        def loop():
            while not self._stop.wait(self.interval_s):
                self.scrub_once()

        self._thread = threading.Thread(target=loop, daemon=True,
                                        name="pim-scrubber")
        self._thread.start()
        return self

    def stop(self) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join(timeout=5.0)
        self._thread = None
