"""Unified telemetry for the PIM stack (DESIGN.md §15).

One module owns every observable signal the pipeline produces:

* :class:`MetricsRegistry` -- a thread-safe registry of **counters**,
  **gauges** and **log-bucketed histograms** (p50/p95/p99 summaries) with
  snapshot/drain semantics.  A single re-entrant lock guards all mutation,
  so executor threads, the serving reader thread and the media scrubber
  can increment concurrently without losing updates -- the fix for the
  historically unguarded ``ops.HEALTH`` Counter.
* :class:`CounterGroup` -- a ``collections.Counter``-shaped *view* over a
  name prefix of a registry.  ``ops.HEALTH`` and ``faults.MEDIA`` are now
  such views (``pim.health.*`` / ``pim.media.*``); their historical
  ``drain_health()`` / ``drain_media_health()`` entry points are thin
  shims over :meth:`CounterGroup.drain`.
* :class:`Tracer` -- lightweight nested trace spans with per-stage wall
  timing through the whole pipeline (prepare -> enqueue -> coalesce/pack
  -> dispatch -> exec -> unpack -> finish), exportable as Chrome-trace /
  Perfetto-compatible JSON (``chrome://tracing``, ``ui.perfetto.dev``).
  Disabled by default: a disabled span is one attribute read, which is
  what keeps the tracer inside the <2% tracked-kernel overhead budget.
* :class:`PimCostModel` -- the analytical cost gauge: per executed
  program, modeled PIM cycles (gate count + output-copy stage + INIT,
  one column op per cycle -- the paper's §7 execution model) and energy
  (per-command pJ from :data:`ENERGY_PJ`), recorded next to wall clock so
  schedule choices can be judged on the hardware they target ("The
  Bitlet Model", arXiv:1910.10234; PrIM methodology, arXiv:2110.01709).

Metric naming scheme (dots group, Prometheus rendering maps to ``_``):

====================  ====================================================
``pim.health.*``      fault-tolerance counters (ops.HEALTH view)
``pim.media.*``       media lifecycle counters (faults.MEDIA view)
``pim.serve.*``       serving runtime counters + latency histograms
``pim.batch.*``       per-batch histograms (exec_us, occupancy, groups)
``pim.cache.*``       compiled-program LRU hit/miss/eviction counters
``pim.exec.*``        dispatch counters (dispatches, rows, levels)
``pim.model.*``       analytical cost gauges (cycles, energy_pj)
====================  ====================================================

This module sits at the bottom of the package's import graph: it imports
only the stdlib and ``core.device_model`` (which imports nothing), so
``runtime.faults`` -- itself imported by ``kernels.plan`` -- can depend
on it without a cycle.
"""

from __future__ import annotations

import collections
import contextlib
import dataclasses
import json
import math
import threading
import time
from typing import Callable, Dict, Iterator, List, Optional, Tuple

from ..core.device_model import PIM_DEFAULT, PIMDevice

__all__ = ["MetricsRegistry", "CounterGroup", "Histogram", "Tracer",
           "PimCostModel", "ModeledCost", "ENERGY_PJ", "REGISTRY",
           "TRACER", "COST_MODEL", "render_prometheus"]


# --------------------------------------------------------------------------
# histograms: log-bucketed, mergeable, percentile summaries
# --------------------------------------------------------------------------

# Buckets per octave: bucket ``i`` covers ``(2**((i-1)/4), 2**(i/4)]``, so
# neighbouring bucket edges differ by 2**(1/4) ~ 1.19x -- percentile
# estimates are exact at bucket edges and within ~9% relative error inside
# a bucket (linear interpolation over a <=19% wide bucket).  Indices are
# computed in O(1) from log2 and stored sparsely, so the value range is
# unbounded in both directions (microseconds to hours).
_SUB = 4


def _bucket_index(v: float) -> int:
    """Index of the log bucket containing ``v`` (> 0): the smallest ``i``
    with ``v <= 2**(i/_SUB)``.  Exact powers of ``2**(1/_SUB)`` land on
    their own upper edge (upper-inclusive buckets)."""
    return math.ceil(_SUB * math.log2(v))


def _bucket_hi(i: int) -> float:
    return 2.0 ** (i / _SUB)


def _bucket_lo(i: int) -> float:
    return 2.0 ** ((i - 1) / _SUB)


class Histogram:
    """Log-bucketed histogram of nonnegative observations.

    Tracks exact ``count``/``sum``/``min``/``max`` plus sparse per-bucket
    counts; values <= 0 land in a dedicated underflow bucket pinned at 0.
    Not internally locked -- the owning :class:`MetricsRegistry` serializes
    all access under its lock.
    """

    __slots__ = ("count", "total", "vmin", "vmax", "zeros", "buckets")

    def __init__(self):
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf
        self.zeros = 0                     # observations <= 0
        self.buckets: Dict[int, int] = {}

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v
        if v <= 0.0:
            self.zeros += 1
        else:
            i = _bucket_index(v)
            self.buckets[i] = self.buckets.get(i, 0) + 1

    def percentile(self, q: float) -> float:
        """Estimated ``q``-quantile (``q`` in [0, 1]): cumulative bucket
        walk with linear interpolation inside the landing bucket, clamped
        to the exactly-tracked [min, max] envelope -- a single-valued
        histogram therefore reports that value for every quantile."""
        if self.count == 0:
            return math.nan
        target = q * self.count
        cum = self.zeros
        if cum >= target and self.zeros:
            return max(0.0, self.vmin)
        v = self.vmax
        for i in sorted(self.buckets):
            n = self.buckets[i]
            if cum + n >= target:
                frac = (target - cum) / n
                lo, hi = _bucket_lo(i), _bucket_hi(i)
                v = lo + frac * (hi - lo)
                break
            cum += n
        return min(max(v, self.vmin), self.vmax)

    def summary(self) -> dict:
        """``{count, sum, min, max, mean, p50, p95, p99}`` of what was
        observed so far (empty histogram: count 0, the rest NaN-free
        zeros so JSON stays clean)."""
        if self.count == 0:
            return {"count": 0, "sum": 0.0, "min": 0.0, "max": 0.0,
                    "mean": 0.0, "p50": 0.0, "p95": 0.0, "p99": 0.0}
        r = lambda x: round(float(x), 3)
        return {"count": self.count, "sum": r(self.total),
                "min": r(self.vmin), "max": r(self.vmax),
                "mean": r(self.total / self.count),
                "p50": r(self.percentile(0.50)),
                "p95": r(self.percentile(0.95)),
                "p99": r(self.percentile(0.99))}


# --------------------------------------------------------------------------
# the registry
# --------------------------------------------------------------------------

class MetricsRegistry:
    """Thread-safe registry of counters, gauges and histograms.

    All mutation happens under one re-entrant lock; reads return plain
    copies, never live references.  ``drain`` (snapshot-and-reset) is the
    contract the serving stats and the ``drain_health()`` /
    ``drain_media_health()`` shims ride on: a drain observes-and-clears
    atomically, so two racing drainers can never double-count and
    concurrent increments can never be lost between the read and the
    reset."""

    def __init__(self):
        self._lock = threading.RLock()
        self._counters: Dict[str, float] = {}
        self._gauges: Dict[str, float] = {}
        self._hists: Dict[str, Histogram] = {}

    # ------------------------------------------------------------ counters

    def inc(self, name: str, n: float = 1) -> None:
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + n

    def add_many(self, deltas: Dict[str, float]) -> None:
        """Fold a dict of counter deltas in under ONE lock acquisition --
        the hot-path form (per-dispatch recording is a single call)."""
        with self._lock:
            c = self._counters
            for name, n in deltas.items():
                c[name] = c.get(name, 0) + n

    def set_counter(self, name: str, value: float) -> None:
        """Absolute set (the Counter-compat ``group[k] = v`` form, used by
        gauge-like counters such as ``media.spans_still_bad``)."""
        with self._lock:
            self._counters[name] = value

    def counter(self, name: str, default: float = 0) -> float:
        with self._lock:
            return self._counters.get(name, default)

    def group(self, prefix: str) -> "CounterGroup":
        """A Counter-shaped view over ``prefix``-named counters."""
        return CounterGroup(self, prefix)

    # ------------------------------------------------------------ gauges

    def set_gauge(self, name: str, value: float) -> None:
        with self._lock:
            self._gauges[name] = float(value)

    def gauge(self, name: str, default: float = 0.0) -> float:
        with self._lock:
            return self._gauges.get(name, default)

    # ------------------------------------------------------------ histograms

    def observe(self, name: str, value: float) -> None:
        with self._lock:
            h = self._hists.get(name)
            if h is None:
                h = self._hists[name] = Histogram()
            h.observe(value)

    def observe_many(self, values: Dict[str, float]) -> None:
        """Several single observations under one lock acquisition."""
        with self._lock:
            for name, v in values.items():
                h = self._hists.get(name)
                if h is None:
                    h = self._hists[name] = Histogram()
                h.observe(v)

    def summary(self, name: str) -> Optional[dict]:
        """One histogram's summary dict, or None if never observed."""
        with self._lock:
            h = self._hists.get(name)
            return h.summary() if h is not None else None

    # ------------------------------------------------------------ snapshot /
    # drain

    def snapshot(self) -> dict:
        """Point-in-time copy: ``{"counters": {...}, "gauges": {...},
        "histograms": {name: summary}}``.  Zero-valued counters are kept
        (they exist because someone incremented them past zero and back
        via drain -- snapshot never filters)."""
        with self._lock:
            return {"counters": dict(self._counters),
                    "gauges": dict(self._gauges),
                    "histograms": {n: h.summary()
                                   for n, h in self._hists.items()}}

    def drain(self, prefix: str = "") -> Dict[str, float]:
        """Snapshot-and-reset every counter whose name starts with
        ``prefix`` (all of them for ""); returns the non-zero removed
        values.  Histograms and gauges are untouched -- they are windowed
        by :meth:`drain_histograms` / overwritten in place."""
        with self._lock:
            out = {}
            for name in [n for n in self._counters
                         if n.startswith(prefix)]:
                v = self._counters.pop(name)
                if v:
                    out[name] = int(v) if float(v).is_integer() else v
            return out

    def drain_histograms(self, prefix: str = "") -> Dict[str, dict]:
        """Snapshot-and-reset matching histograms (their summaries)."""
        with self._lock:
            out = {}
            for name in [n for n in self._hists if n.startswith(prefix)]:
                out[name] = self._hists.pop(name).summary()
            return out


class CounterGroup:
    """A ``collections.Counter``-shaped view over one name prefix of a
    :class:`MetricsRegistry` -- the migration vehicle for the historical
    module-global Counters (``ops.HEALTH``, ``faults.MEDIA``).

    Supports the Counter surface those call sites used (``[]``/``get``/
    ``items``/``clear``/truthiness) plus :meth:`add`, the *atomic*
    increment (``g[k] += 1`` expands to a get-then-set pair, which is not
    atomic across threads; hot increment sites use ``add``).  ``drain()``
    is the snapshot-and-reset behind ``drain_health()``."""

    __slots__ = ("_reg", "_prefix")

    def __init__(self, registry: MetricsRegistry, prefix: str):
        self._reg = registry
        self._prefix = prefix.rstrip(".") + "."

    @property
    def registry(self) -> MetricsRegistry:
        return self._reg

    def _full(self, key: str) -> str:
        return self._prefix + key

    def add(self, key: str, n: float = 1) -> None:
        self._reg.inc(self._full(key), n)

    def __getitem__(self, key: str) -> float:
        v = self._reg.counter(self._full(key))
        return int(v) if float(v).is_integer() else v

    def __setitem__(self, key: str, value: float) -> None:
        self._reg.set_counter(self._full(key), value)

    def get(self, key: str, default: float = 0) -> float:
        v = self._reg.counter(self._full(key), default)
        return int(v) if float(v).is_integer() else v

    def items(self) -> List[Tuple[str, float]]:
        p = self._prefix
        with self._reg._lock:
            return [(n[len(p):], v) for n, v in self._reg._counters.items()
                    if n.startswith(p)]

    def keys(self) -> List[str]:
        return [k for k, _ in self.items()]

    def __iter__(self):
        return iter(self.keys())

    def __len__(self) -> int:
        return len(self.items())

    def __contains__(self, key: str) -> bool:
        with self._reg._lock:
            return self._full(key) in self._reg._counters

    def __bool__(self) -> bool:
        return any(v for _, v in self.items())

    def clear(self) -> None:
        self._reg.drain(self._prefix)

    def drain(self) -> Dict[str, int]:
        """Atomic snapshot-and-reset; returns the non-zero counters with
        the prefix stripped (the historical ``drain_health()`` shape)."""
        p = self._prefix
        return {n[len(p):]: int(v)
                for n, v in self._reg.drain(p).items()}


# --------------------------------------------------------------------------
# trace spans (Chrome-trace / Perfetto "X" complete events)
# --------------------------------------------------------------------------

class _Span:
    """One open span: a context manager that emits a complete ("X") event
    on exit.  Cheap on purpose -- two perf_counter reads and one deque
    append."""

    __slots__ = ("_tracer", "name", "cat", "args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, cat: str, args: dict):
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.args = args

    def __enter__(self) -> "_Span":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        self._tracer.event(self.name, self._t0, time.perf_counter(),
                           cat=self.cat, **self.args)


_NULL_SPAN = contextlib.nullcontext()


class Tracer:
    """Nested trace spans with per-stage wall timing, exportable as
    Chrome-trace JSON (the ``{"traceEvents": [...]}`` envelope both
    ``chrome://tracing`` and Perfetto load directly).

    Spans nest naturally: events carry real thread ids and microsecond
    ``ts``/``dur``, which is all the Chrome trace model needs to stack
    them.  The buffer is a bounded ring (``capacity`` events, oldest
    dropped), so a long-running server can leave tracing on without
    unbounded growth.  ``enabled`` defaults to False and a disabled
    :meth:`span` returns a shared null context -- one attribute read on
    the hot path, nothing allocated."""

    def __init__(self, capacity: int = 1 << 16):
        self.enabled = False
        self._lock = threading.Lock()
        self._events: "collections.deque" = collections.deque(
            maxlen=capacity)
        self._epoch = time.perf_counter()

    def span(self, name: str, cat: str = "pim", **args):
        """Context manager timing one pipeline stage; no-op when the
        tracer is disabled."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, cat, args)

    def event(self, name: str, t0: float, t1: float, cat: str = "pim",
              **args) -> None:
        """Record a retroactive span from ``perf_counter`` stamps --
        how queue-wait (admission -> dequeue) is traced: the waiting
        thread never blocks on instrumentation; the dequeuer back-fills
        the span."""
        if not self.enabled:
            return
        ev = {"name": name, "cat": cat, "ph": "X",
              "ts": round((t0 - self._epoch) * 1e6, 1),
              "dur": round((t1 - t0) * 1e6, 1),
              "pid": 1, "tid": threading.get_ident() & 0xFFFF}
        if args:
            ev["args"] = args
        with self._lock:
            self._events.append(ev)

    def instant(self, name: str, cat: str = "pim", **args) -> None:
        """Zero-duration instant event (batch boundaries, trips)."""
        if not self.enabled:
            return
        now = time.perf_counter()
        self.event(name, now, now, cat=cat, **args)

    def drain(self) -> List[dict]:
        with self._lock:
            out = list(self._events)
            self._events.clear()
        return out

    def write_chrome_trace(self, path: str) -> int:
        """Drain the buffer into a Chrome-trace JSON file; returns the
        event count written."""
        events = self.drain()
        with open(path, "w") as f:
            json.dump({"traceEvents": events,
                       "displayTimeUnit": "ms"}, f)
            f.write("\n")
        return len(events)


# --------------------------------------------------------------------------
# analytical cost model (the paper's §7 substrate, Bitlet-style)
# --------------------------------------------------------------------------

#: Command-energy table, pJ.  ``nor``/``init`` are per column op per row
#: (one crossbar column switch: the device model's 24.3 fJ RRAM figure);
#: ``read``/``write`` are per bit moved across the array periphery
#: (sense-amp readout / write-driver programming of the IO ports --
#: order-of-magnitude ReRAM periphery figures, dominated by the gate term
#: for compute-heavy programs, and exactly the knob to retune when a real
#: device datasheet lands).
ENERGY_PJ: Dict[str, float] = {
    "nor": PIM_DEFAULT.gate_energy_fj * 1e-3,     # 0.0243
    "init": PIM_DEFAULT.gate_energy_fj * 1e-3,    # INIT1 is a column op
    "read": 0.05,                                 # per IO bit out
    "write": 0.10,                                # per IO bit in
}


@dataclasses.dataclass(frozen=True)
class ModeledCost:
    """One program's modeled execution on the PIM substrate.

    ``cycles`` counts one column op per cycle (every crossbar in lockstep
    -- the paper's execution model): live NOR gates + the slot
    allocator's output-copy stage + one INIT1 broadcast when the schedule
    folds a constant-one cell.  ``levels`` is the parallel depth (what a
    multi-issue array would bound latency by); both are reported so
    schedule choices can be judged under either model.  Energy splits
    into the gate term (``cycles`` column ops x rows) and the IO term
    (port bits read/written per row)."""
    levels: int
    gates: int
    init_cycles: int
    cycles: int
    io_bits: int                     # port bits moved per row (in + out)
    latency_us: float                # cycles x cycle_ns (row-independent)
    energy_pj_per_row: float

    def energy_pj(self, n_rows: int) -> float:
        return self.energy_pj_per_row * n_rows


@dataclasses.dataclass(frozen=True)
class PimCostModel:
    """Analytical cycles/energy model over levelized schedules, seeded
    from :data:`~repro.core.device_model.PIM_DEFAULT` (RACER-derived
    memristive parameters, as in the paper's §7 case study)."""
    device: PIMDevice = PIM_DEFAULT
    energy_pj: Tuple[Tuple[str, float], ...] = tuple(
        sorted(ENERGY_PJ.items()))

    def _e(self, cmd: str) -> float:
        return dict(self.energy_pj)[cmd]

    def cost(self, *, gates: int, levels: int = 0, init_cycles: int = 0,
             io_bits: int = 0) -> ModeledCost:
        cycles = int(gates) + int(init_cycles)
        e_row = (cycles * self._e("nor")
                 + io_bits * (self._e("read") + self._e("write")) / 2.0)
        return ModeledCost(
            levels=int(levels), gates=int(gates),
            init_cycles=int(init_cycles), cycles=cycles,
            io_bits=int(io_bits),
            latency_us=cycles * self.device.cycle_ns * 1e-3,
            energy_pj_per_row=e_row)

    def schedule_cost(self, sched) -> ModeledCost:
        """Modeled cost of one :class:`~repro.core.gates.LevelSchedule`:
        gate cycles = live gates after DCE + the contiguous-output copy
        stage (``copy_gates`` -- real column ops on the device), INIT1
        counted once when folded, IO bits = every port cell crossing the
        periphery once."""
        return self.cost(
            gates=int(sched.n_gates) + int(getattr(sched, "copy_gates", 0)),
            levels=int(sched.n_levels),
            init_cycles=1 if getattr(sched, "one_cell", None) is not None
            else 0,
            io_bits=sum(len(c) for c in sched.ports.values()))

    def program_cost(self, cost) -> ModeledCost:
        """Modeled cost from a gate-serial :class:`~repro.core.gates.Cost`
        (the un-levelized executors and the closed-form benchmark rows)."""
        return self.cost(gates=int(cost.nor_gates),
                         levels=int(cost.abstract_steps),
                         init_cycles=int(cost.init_cycles))


# --------------------------------------------------------------------------
# process-global instances + hot-path recording helpers
# --------------------------------------------------------------------------

#: The default process-wide registry: module-global counter stores
#: (``ops.HEALTH``, ``faults.MEDIA``, the compiled-cache and dispatch
#: counters) live here.  Serving runtimes own *separate* registries for
#: their per-instance stats so tests stay isolated.
REGISTRY = MetricsRegistry()

#: The default tracer (disabled until ``--pim-trace-file`` or a test
#: flips ``TRACER.enabled``).
TRACER = Tracer()

#: The default analytical cost model.
COST_MODEL = PimCostModel()


def record_dispatch(n_rows: int, model: Optional[ModeledCost]) -> None:
    """Fold one levelized dispatch into the global registry: dispatch /
    row / level counters plus the modeled cycle+energy gauges.  ONE lock
    acquisition with a prebuilt dict -- the per-dispatch overhead is a
    handful of dict ops, independent of ``n_rows`` and schedule size
    (pinned by tests/test_telemetry.py)."""
    if model is None:
        REGISTRY.add_many({"pim.exec.dispatches": 1,
                           "pim.exec.rows": n_rows})
        return
    REGISTRY.add_many({
        "pim.exec.dispatches": 1,
        "pim.exec.rows": n_rows,
        "pim.exec.levels": model.levels,
        "pim.model.cycles": model.cycles,
        "pim.model.energy_pj": model.energy_pj_per_row * n_rows,
    })


def drain_model_counters() -> Dict[str, float]:
    """Snapshot-and-reset the ``pim.exec.*`` + ``pim.model.*`` counters
    (what ``benchmarks/run.py`` windows around one measured call)."""
    out = REGISTRY.drain("pim.exec.")
    out.update(REGISTRY.drain("pim.model."))
    return out


# --------------------------------------------------------------------------
# Prometheus text exposition
# --------------------------------------------------------------------------

def _prom_name(name: str) -> str:
    return "".join(c if c.isalnum() or c == "_" else "_" for c in name)


def render_prometheus(*registries: MetricsRegistry) -> str:
    """Prometheus-style text exposition of one or more registries:
    counters/gauges as single samples, histograms as summaries
    (``{quantile="0.5|0.95|0.99"}`` + ``_count``/``_sum``).  Written by
    ``serve.py --pim-metrics-file`` so any textfile-collector style
    scraper can pick serving metrics up without a wire protocol."""
    lines: List[str] = []
    for reg in (registries or (REGISTRY,)):
        snap = reg.snapshot()
        for name in sorted(snap["counters"]):
            pn = _prom_name(name)
            lines.append(f"# TYPE {pn} counter")
            lines.append(f"{pn} {snap['counters'][name]:g}")
        for name in sorted(snap["gauges"]):
            pn = _prom_name(name)
            lines.append(f"# TYPE {pn} gauge")
            lines.append(f"{pn} {snap['gauges'][name]:g}")
        for name in sorted(snap["histograms"]):
            pn = _prom_name(name)
            s = snap["histograms"][name]
            lines.append(f"# TYPE {pn} summary")
            for q, key in (("0.5", "p50"), ("0.95", "p95"),
                           ("0.99", "p99")):
                lines.append(f'{pn}{{quantile="{q}"}} {s[key]:g}')
            lines.append(f"{pn}_count {s['count']:g}")
            lines.append(f"{pn}_sum {s['sum']:g}")
    return "\n".join(lines) + "\n"
