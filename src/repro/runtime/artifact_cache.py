"""Persistent compiled-artifact cache: the on-disk tier below the
compiled-program LRU (DESIGN.md §16).

Every process cold-starts with an empty in-memory LRU, so a serving fleet
of N replicas pays N x trace+compile for the same hot programs.  This
module persists the two expensive compilation artifacts to disk, keyed on
the PR-5 cache identity ``(content_key(program), plan.compile_key)``:

* **Levelized schedules** -- the ``levelize()`` output (SSA, DCE, level
  scheduling, slot allocation) serialized as an explicit header+arrays
  format.  Loading one is a file read plus ``np.frombuffer``, tens of
  microseconds against tens of milliseconds of levelization.
* **AOT executables** -- where XLA allows it (``jax.experimental.
  serialize_executable``), the jitted executor compiled for one exact
  (arg-shapes, static-args) signature is serialized whole.  A warm replica
  then *deserializes* the XLA executable (~20ms) instead of re-tracing and
  re-compiling it (~700ms on the tracked fp16-add row).  Entries carry the
  jax version and device target in their header; any mismatch is a plain
  miss, never an error.

Robustness contract (the properties tests/test_artifact_cache.py pins):

* **Atomic writes** -- artifacts are written to a same-directory temp file
  (fsync'd) and ``os.replace``'d into place, so concurrent writers on one
  cache directory can interleave freely and a reader never observes a torn
  file.  Writers racing on the same key are idempotent: both produce
  byte-identical artifacts (compilation is a pure function of the key).
* **Integrity checksums** -- every file ends in a blake2b digest over its
  header+payload; corruption (or a bad magic / truncated file) makes the
  load return None and execution silently recomputes, overwriting the bad
  entry on the way out.
* **Versioned format** -- the magic string carries the format version; a
  reader never parses a future or past format, it just recomputes.
* **Size cap with LRU eviction** -- after each write the cache evicts
  oldest-``mtime`` files until under ``max_bytes`` (loads refresh mtime,
  so eviction order is least-recently-*used*, not written).

Counters land on the shared ``pim.cache.*`` telemetry group next to the
in-memory LRU's hits/misses/evictions: ``disk_hits`` / ``disk_misses`` /
``disk_writes`` / ``disk_errors`` / ``disk_evictions`` -- surfaced by
``serve.py``'s stats/summary lines and the Prometheus exposition.

The :meth:`ArtifactCache.warm` API makes a replica hot at startup without
any traffic: schedule entries record their program's *provenance* (the
``core.pim_numerics.program_for`` build triple) when known, so warm() can
rebuild each program, verify its content hash against the stored key, and
install the disk schedule plus any matching AOT executables straight into
the in-memory compiled cache.
"""

from __future__ import annotations

import dataclasses
import hashlib
import io
import json
import os
import pickle
import tempfile
import time
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

from ..core.gates import LevelSchedule
from . import telemetry

#: Format version: bump on any change to the header schema or payload
#: layout.  Baked into the magic so a version mismatch is detected before
#: any parsing happens.
FORMAT_VERSION = 1
_MAGIC = b"PIMART%02d" % FORMAT_VERSION        # 8 bytes
_DIGEST = 16                                   # blake2b digest size (bytes)
_SUFFIX = ".pim"

#: Default on-disk size cap (bytes).  Schedules are tens of KB and AOT
#: executables ~100KB, so the default holds hundreds of hot programs.
DEFAULT_MAX_BYTES = 256 << 20

#: Shared counter group with the in-memory compiled-program LRU
#: (``kernels.ops._CACHE``): one ``pim.cache.*`` namespace for both tiers.
_CACHE = telemetry.REGISTRY.group("pim.cache")

#: Basename of the autotuner's persisted winners, stored beside the
#: artifacts (``runtime.tune`` reads/writes it; serve.py auto-installs it).
TUNED_BASENAME = "tuned.json"


def _compile_key_of(plan) -> Tuple[int, ...]:
    """The plan's compile identity as a plain int tuple (accepts an
    ExecPlan or an already-extracted tuple)."""
    ck = getattr(plan, "compile_key", plan)
    return tuple(int(v) for v in ck)


def device_target() -> str:
    """The XLA target AOT executables are valid for: platform + device
    kind.  Part of every AOT entry's identity -- an executable compiled
    for one target never loads on another."""
    import jax
    dev = jax.devices()[0]
    return f"{jax.default_backend()}:{getattr(dev, 'device_kind', '?')}"


def _jax_version() -> str:
    import jax
    return jax.__version__


# --------------------------------------------------------------------------
# container format: MAGIC | u32 header_len | header JSON | payload | digest
# --------------------------------------------------------------------------

def _frame(header: dict, payload: bytes) -> bytes:
    hb = json.dumps(header, sort_keys=True).encode()
    buf = io.BytesIO()
    buf.write(_MAGIC)
    buf.write(len(hb).to_bytes(4, "little"))
    buf.write(hb)
    buf.write(payload)
    buf.write(hashlib.blake2b(hb + payload, digest_size=_DIGEST).digest())
    return buf.getvalue()


def _unframe(blob: bytes) -> Optional[Tuple[dict, bytes]]:
    """Parse one artifact file; None on any mismatch (magic, length,
    checksum, JSON) -- the caller treats that as corruption/version skew
    and recomputes."""
    if len(blob) < len(_MAGIC) + 4 + _DIGEST or \
            not blob.startswith(_MAGIC):
        return None
    hlen = int.from_bytes(blob[8:12], "little")
    body_end = len(blob) - _DIGEST
    if 12 + hlen > body_end:
        return None
    hb = blob[12:12 + hlen]
    payload = blob[12 + hlen:body_end]
    if hashlib.blake2b(hb + payload, digest_size=_DIGEST).digest() \
            != blob[body_end:]:
        return None
    try:
        header = json.loads(hb.decode())
    except (UnicodeDecodeError, json.JSONDecodeError):
        return None
    return header, payload


# --------------------------------------------------------------------------
# LevelSchedule <-> bytes
# --------------------------------------------------------------------------

_SCHED_ARRAYS = ("a", "b", "out", "level_width")


def _sched_to_parts(sched: LevelSchedule) -> Tuple[dict, bytes]:
    meta = {
        "n_cells": int(sched.n_cells),
        "sink": int(sched.sink),
        "one_cell": None if sched.one_cell is None else int(sched.one_cell),
        "ports": {k: [int(c) for c in v] for k, v in sched.ports.items()},
        "in_cells": {k: [int(c) for c in v]
                     for k, v in sched.in_cells.items()},
        "in_ports": sorted(sched.in_ports),
        "out_ports": sorted(sched.out_ports),
        "n_gates": int(sched.n_gates),
        "source_gates": int(sched.source_gates),
        "source_cells": int(sched.source_cells),
        "alloc": sched.alloc,
        "slot_width": None if sched.slot_width is None
        else int(sched.slot_width),
        "copy_gates": int(sched.copy_gates),
    }
    specs, chunks = [], []
    for name in _SCHED_ARRAYS:
        arr = np.ascontiguousarray(getattr(sched, name))
        specs.append([name, arr.dtype.str, list(arr.shape)])
        chunks.append(arr.tobytes())
    return {"meta": meta, "arrays": specs}, b"".join(chunks)


def _sched_from_parts(header: dict, payload: bytes
                      ) -> Optional[LevelSchedule]:
    try:
        meta = header["meta"]
        arrays = {}
        off = 0
        for name, dtype, shape in header["arrays"]:
            n = int(np.prod(shape, dtype=np.int64)) * np.dtype(dtype).itemsize
            arrays[name] = np.frombuffer(
                payload[off:off + n], dtype=dtype).reshape(shape).copy()
            off += n
        if off != len(payload) or set(arrays) != set(_SCHED_ARRAYS):
            return None
        sched = LevelSchedule(
            n_cells=int(meta["n_cells"]), sink=int(meta["sink"]),
            one_cell=None if meta["one_cell"] is None
            else int(meta["one_cell"]),
            ports={k: [int(c) for c in v]
                   for k, v in meta["ports"].items()},
            in_cells={k: [int(c) for c in v]
                      for k, v in meta["in_cells"].items()},
            in_ports=frozenset(meta["in_ports"]),
            out_ports=frozenset(meta["out_ports"]),
            a=arrays["a"], b=arrays["b"], out=arrays["out"],
            level_width=arrays["level_width"],
            n_gates=int(meta["n_gates"]),
            source_gates=int(meta["source_gates"]),
            source_cells=int(meta["source_cells"]),
            alloc=meta["alloc"],
            slot_width=None if meta["slot_width"] is None
            else int(meta["slot_width"]),
            copy_gates=int(meta["copy_gates"]))
        if sched.a.shape != sched.b.shape or \
                sched.a.shape != sched.out.shape or \
                sched.level_width.shape != (sched.a.shape[0],):
            return None
        return sched
    except (KeyError, TypeError, ValueError):
        return None


# --------------------------------------------------------------------------
# provenance: how to rebuild a program from a cache entry alone (warm())
# --------------------------------------------------------------------------

def _prov_to_json(tag) -> Optional[list]:
    """Provenance tuples nest plain scalars/tuples; JSON round-trips them
    as nested lists (re-tupled on the way back)."""
    if tag is None:
        return None

    def enc(v):
        return [enc(x) for x in v] if isinstance(v, (tuple, list)) else v
    return enc(tag)


def _prov_from_json(v):
    if isinstance(v, list):
        return tuple(_prov_from_json(x) for x in v)
    return v


def _program_from_provenance(prov):
    """Rebuild (via the memoized builders) the Program a provenance tag
    names; None when the tag is unknown or the build fails."""
    try:
        from ..core import pim_numerics
        if prov and prov[0] == "program_for":
            return pim_numerics.program_for(prov[1], prov[2], prov[3])
        if prov and prov[0] == "fused_program_for":
            return pim_numerics.fused_program_for(prov[1], prov[2], prov[3])
    except Exception:
        return None
    return None


# --------------------------------------------------------------------------
# the cache
# --------------------------------------------------------------------------

class ArtifactCache:
    """On-disk, versioned, atomic-write cache of compiled PIM artifacts.

    One instance manages one directory (created on demand).  Installed
    process-wide via ``kernels.ops.set_artifact_cache``; the compiled-
    program machinery then consults it on every in-memory miss and
    writes through on every fresh compile.  See the module docstring for
    the format and robustness contract.
    """

    def __init__(self, root, *, max_bytes: int = DEFAULT_MAX_BYTES,
                 aot: bool = True):
        self.root = os.fspath(root)
        self.max_bytes = int(max_bytes)
        #: AOT executable tier enabled (schedule caching is always on).
        self.aot = bool(aot)
        os.makedirs(self.root, exist_ok=True)

    # ------------------------------------------------------------- keys

    def _path(self, kind: str, *material) -> str:
        digest = hashlib.blake2b(
            repr((FORMAT_VERSION, kind) + material).encode(),
            digest_size=_DIGEST).hexdigest()
        return os.path.join(self.root, f"{kind}-{digest}{_SUFFIX}")

    def sched_path(self, content: bytes, plan, alloc: str) -> str:
        return self._path("sched", content.hex(), _compile_key_of(plan),
                          alloc)

    def aot_path(self, content: bytes, plan, memo: str) -> str:
        return self._path("aot", content.hex(), _compile_key_of(plan),
                          memo, _jax_version(), device_target())

    # ------------------------------------------------------------- io

    def _read(self, path: str) -> Optional[Tuple[dict, bytes]]:
        """Read + verify one artifact file.  Missing file -> plain miss
        (None, no counter); unreadable/corrupt -> ``disk_errors`` and the
        bad file is unlinked so it cannot poison future loads."""
        try:
            with open(path, "rb") as f:
                blob = f.read()
        except FileNotFoundError:
            return None
        except OSError:
            _CACHE.add("disk_errors")
            return None
        parsed = _unframe(blob)
        if parsed is None:
            _CACHE.add("disk_errors")
            try:
                os.unlink(path)
            except OSError:
                pass
            return None
        try:                        # refresh mtime: eviction is LRU-by-use
            os.utime(path)
        except OSError:
            pass
        return parsed

    def _write(self, path: str, header: dict, payload: bytes) -> None:
        """Atomic publish: temp file in the same directory, fsync, then
        ``os.replace`` -- a reader sees the old file, no file, or the
        complete new file, never a torn write."""
        blob = _frame(header, payload)
        fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(blob)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, path)
        except OSError:
            _CACHE.add("disk_errors")
            try:
                os.unlink(tmp)
            except OSError:
                pass
            return
        _CACHE.add("disk_writes")
        self._evict()

    # ------------------------------------------------------------- sched

    def load_schedule(self, content: bytes, plan, alloc: str
                      ) -> Optional[LevelSchedule]:
        """The disk tier's schedule lookup; None on miss or corruption
        (the caller then levelizes and stores)."""
        parsed = self._read(self.sched_path(content, plan, alloc))
        if parsed is None:
            _CACHE.add("disk_misses")
            return None
        sched = _sched_from_parts(*parsed)
        if sched is None:
            _CACHE.add("disk_errors")
            _CACHE.add("disk_misses")
            return None
        _CACHE.add("disk_hits")
        return sched

    def store_schedule(self, content: bytes, plan, alloc: str,
                       sched: LevelSchedule, provenance=None) -> None:
        header, payload = _sched_to_parts(sched)
        header.update(kind="sched", content=content.hex(),
                      compile_key=list(_compile_key_of(plan)), alloc=alloc,
                      provenance=_prov_to_json(provenance))
        self._write(self.sched_path(content, plan, alloc), header, payload)

    # ------------------------------------------------------------- aot

    def load_executable(self, content: bytes, plan, memo: str):
        """Deserialize + load a cached XLA executable for one exact call
        signature; None on miss, corruption, or any deserialization
        failure (callers fall back to the plain jit path)."""
        if not self.aot:
            return None
        parsed = self._read(self.aot_path(content, plan, memo))
        if parsed is None:
            _CACHE.add("disk_misses")
            return None
        header, payload = parsed
        if header.get("jax") != _jax_version() or \
                header.get("target") != device_target():
            _CACHE.add("disk_misses")       # version skew: miss, not error
            return None
        try:
            from jax.experimental import serialize_executable as se
            loaded = se.deserialize_and_load(*pickle.loads(payload))
        except Exception:
            _CACHE.add("disk_errors")
            _CACHE.add("disk_misses")
            return None
        _CACHE.add("disk_hits")
        return loaded

    def store_executable(self, content: bytes, plan, memo: str,
                         compiled_exec, provenance=None) -> bool:
        """Serialize one AOT-compiled executable; False when XLA cannot
        serialize it (callers keep the in-memory executable and move on)."""
        if not self.aot:
            return False
        try:
            from jax.experimental import serialize_executable as se
            payload = pickle.dumps(se.serialize(compiled_exec))
        except Exception:
            return False
        header = {"kind": "aot", "content": content.hex(),
                  "compile_key": list(_compile_key_of(plan)), "memo": memo,
                  "jax": _jax_version(), "target": device_target(),
                  "provenance": _prov_to_json(provenance)}
        self._write(self.aot_path(content, plan, memo), header, payload)
        return True

    # ------------------------------------------------------------- upkeep

    def _files(self) -> List[os.DirEntry]:
        try:
            with os.scandir(self.root) as it:
                return [e for e in it
                        if e.is_file() and e.name.endswith(_SUFFIX)]
        except OSError:
            return []

    def total_bytes(self) -> int:
        total = 0
        for e in self._files():
            try:
                total += e.stat().st_size
            except OSError:
                pass
        return total

    def _evict(self) -> None:
        """Oldest-mtime-first eviction until under ``max_bytes``.  Races
        with concurrent writers are benign: a vanished file is skipped,
        and an evicted-then-needed artifact is simply recomputed."""
        entries = []
        total = 0
        for e in self._files():
            try:
                st = e.stat()
            except OSError:
                continue
            entries.append((st.st_mtime, st.st_size, e.path))
            total += st.st_size
        if total <= self.max_bytes:
            return
        for _, size, path in sorted(entries):
            if total <= self.max_bytes:
                break
            try:
                os.unlink(path)
            except OSError:
                continue
            total -= size
            _CACHE.add("disk_evictions")

    def entries(self) -> List[dict]:
        """Parsed headers of every valid artifact on disk (diagnostics and
        the warm scan)."""
        out = []
        for e in self._files():
            parsed = self._read(e.path)
            if parsed is not None:
                out.append(parsed[0])
        return out

    def clear(self) -> int:
        n = 0
        for e in self._files():
            try:
                os.unlink(e.path)
                n += 1
            except OSError:
                pass
        return n

    # ------------------------------------------------------------- warm

    def warm(self) -> Dict[str, int]:
        """Preload every provenance-bearing artifact into the in-memory
        compiled cache: rebuild each program from its recorded build
        triple, verify the content hash matches the stored key (a
        provenance/program mismatch is skipped, never trusted), install
        the disk schedule + device operands, and attach any AOT
        executables for this jax version/target.  Returns counts --
        ``{"schedules": .., "executables": .., "skipped": ..}``.

        This is the replica warm-start path: after ``warm()`` the first
        request for a cached program pays neither levelize nor XLA
        compile."""
        from ..kernels import ops as kops
        from ..kernels.plan import BACKENDS, ExecPlan

        counts = {"schedules": 0, "executables": 0, "skipped": 0}
        aot_headers = []
        comp_of: Dict[tuple, tuple] = {}    # (content, ck) -> (prog, plan)
        for e in self._files():
            parsed = self._read(e.path)
            if parsed is None:
                continue
            header, payload = parsed
            kind = header.get("kind")
            if kind == "aot":
                aot_headers.append((header, payload))
                continue
            if kind != "sched":
                continue
            prov = _prov_from_json(header.get("provenance"))
            if prov is None:
                counts["skipped"] += 1
                continue
            prog = _program_from_provenance(prov)
            if prog is None or \
                    kops.content_key(prog).hex() != header.get("content"):
                counts["skipped"] += 1
                continue
            sched = _sched_from_parts(header, payload)
            if sched is None:
                counts["skipped"] += 1
                continue
            ck = tuple(int(v) for v in header["compile_key"])
            plan = ExecPlan(backend=dataclasses.replace(
                BACKENDS["ref"], slot_width=ck[0], level_max_width=ck[1],
                seg_levels=ck[2]))
            comp = kops.compiled(prog, plan)
            comp.scheds.setdefault(header["alloc"], sched)
            # materialize the device operands too, so ``is_compiled`` is
            # True and the first dispatch only runs the executor
            kind_name = "dense" if header["alloc"] == "dense" else "slots"
            comp.get_sched_dev(prog, plan, kind_name)
            comp_of[(header["content"], ck)] = (prog, plan)
            counts["schedules"] += 1
            _CACHE.add("disk_hits")
        if self.aot:
            for header, payload in aot_headers:
                if header.get("jax") != _jax_version() or \
                        header.get("target") != device_target():
                    counts["skipped"] += 1
                    continue
                ck = tuple(int(v) for v in header["compile_key"])
                progplan = comp_of.get((header.get("content"), ck))
                if progplan is None:
                    counts["skipped"] += 1
                    continue
                prog, plan = progplan
                try:
                    from jax.experimental import serialize_executable as se
                    loaded = se.deserialize_and_load(*pickle.loads(payload))
                except Exception:
                    _CACHE.add("disk_errors")
                    continue
                kops.compiled(prog, plan).aot.setdefault(
                    header["memo"], loaded)
                counts["executables"] += 1
                _CACHE.add("disk_hits")
        return counts

    def tuned_path(self) -> str:
        """Where the autotuner's winners live for this cache directory."""
        return os.path.join(self.root, TUNED_BASENAME)
