# Runtime subsystems: training fault tolerance / elastic re-meshing, and
# the PIM batched serving runtime (queue -> planner -> coalescer ->
# splitter; DESIGN.md §10).  ``pim_batch`` is imported lazily so the
# training-side modules stay importable without pulling in the kernels.

_PIM_BATCH = ("BatchQueue", "BatchRuntime", "Group", "PinnedSchedules",
              "RequestResult", "Stats", "classify_error", "coalesce",
              "group_key", "plan_groups")

__all__ = list(_PIM_BATCH) + ["pim_batch"]


def __getattr__(name):
    if name == "pim_batch" or name in _PIM_BATCH:
        import importlib
        mod = importlib.import_module(".pim_batch", __name__)
        return mod if name == "pim_batch" else getattr(mod, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
