"""Batched serving runtime for the PIM ufunc API (DESIGN.md §10).

``launch/serve.py --pim-stdin`` executes one gate program per request,
which leaves the machine's row axis -- the dimension the paper's
throughput case (Fig. 9) banks on -- mostly idle under heavy mixed
traffic.  This module sits between the JSON request layer and
``kernels/ops.py`` and fills that axis:

* :class:`BatchQueue` -- thread-safe admission with a micro-batching
  window: block for the first request, then keep admitting until the
  window closes, the row cap fills, or the stream ends.
* :func:`plan_groups` -- the planner: group a batch's prepared requests by
  compiled-program content hash + execution config (``Prepared.key`` makes
  structurally identical requests trivially groupable).
* :func:`coalesce` -- concatenate each group's per-port rows, in arrival
  order, into one packed input set.
* :meth:`BatchRuntime.execute` -- run the whole plan through
  ``kernels.ops.run_program_groups`` (group ``k+1`` packs on the host
  while group ``k`` executes on the device -- the streaming pipeline
  generalized across programs), then *split*: scatter each group's output
  rows back to its member requests via ``Prepared.finish`` with
  per-request accounting.
* :class:`PinnedSchedules` -- an LRU-pinned working set of compiled slot
  schedules: hot programs stay resident in the bounded compiled-program
  cache even when cold traffic churns it, so they never recompile
  mid-serving.

Everything operates on :class:`repro.pim_ufunc.Prepared` handles, so the
runtime is equally usable programmatically (benchmarks, tests) and from
the ``--pim-serve`` JSON-lines loop.
"""

from __future__ import annotations

import collections
import dataclasses
import queue
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from ..kernels import ops as kops
from ..pim_ufunc import Prepared

DEFAULT_WINDOW_MS = 2.0
DEFAULT_MAX_BATCH_ROWS = 1 << 16
DEFAULT_PIN_CAP = 32


# --------------------------------------------------------------------------
# planner
# --------------------------------------------------------------------------

def group_key(prep: Prepared) -> tuple:
    """The coalescing key: program content hash plus the full execution
    config -- ``ExecPlan.key`` covers every dimension that makes two
    executions non-mergeable (backend, schedule, *word layout*, chunking,
    mesh, per-backend tunables), so requests differing in any of them --
    e.g. only in word layout -- never coalesce into one packed state.  Two
    requests with equal keys run bit-identically as one packed state."""
    return (prep.key, prep.plan.key)


@dataclasses.dataclass
class Group:
    """One plan entry: the member requests (by batch index, arrival order)
    that share a program structure and execution config."""
    key: tuple
    members: List[int]
    preps: List[Prepared]
    n_rows: int = 0
    cached: bool = False        # were schedule artifacts already compiled?


def plan_groups(preps: Sequence[Prepared]) -> List[Group]:
    """Group a batch of prepared requests by :func:`group_key`.  Stable:
    groups are ordered by first arrival and members keep arrival order, so
    coalesced row offsets are reproducible."""
    by_key: Dict[tuple, Group] = {}
    plan: List[Group] = []
    for i, p in enumerate(preps):
        k = group_key(p)
        g = by_key.get(k)
        if g is None:
            g = by_key[k] = Group(k, [], [])
            plan.append(g)
        g.members.append(i)
        g.preps.append(p)
        g.n_rows += p.n_rows
    return plan


def coalesce(group: Group) -> Dict[str, np.ndarray]:
    """One packed input set for a group: per port, the members' rows
    concatenated in arrival order (the splitter reverses this by offset).
    Mixed member dtypes (e.g. uint16 and object rows of one width) promote
    under numpy's rules; the bridges take either."""
    first = group.preps[0]
    out = {}
    for name in first.inputs:
        parts = [p.inputs[name] for p in group.preps]
        out[name] = parts[0] if len(parts) == 1 else np.concatenate(parts)
    return out


# --------------------------------------------------------------------------
# pinned schedule working set
# --------------------------------------------------------------------------

class PinnedSchedules:
    """LRU working set of pinned compiled schedules (``cap`` programs).

    ``touch`` pins a program's compiled-cache entry in ``kernels.ops`` (see
    ``pin_program``) and refreshes its recency; when the working set
    overflows, the least-recently-served program is unpinned (it stays
    cached but becomes evictable).  Under mixed traffic this keeps the hot
    programs' levelized schedules and device index buffers resident no
    matter how many cold structures stream past.  ``cap=0`` disables
    pinning entirely."""

    def __init__(self, cap: int = DEFAULT_PIN_CAP):
        if cap < 0:
            raise ValueError(f"pin cap must be >= 0, got {cap}")
        self.cap = int(cap)
        self._lru: "collections.OrderedDict[bytes, bool]" = \
            collections.OrderedDict()

    def __len__(self) -> int:
        return len(self._lru)

    def __contains__(self, key: bytes) -> bool:
        return key in self._lru

    def touch(self, program, plan=None) -> Optional[tuple]:
        """Pin ``program``'s compiled entry under ``plan`` (default: the
        default plan; or refresh its recency); returns the cache key, or
        None when pinning is disabled."""
        if not self.cap:
            return None
        key = kops.cache_key(program, plan)
        if key in self._lru:
            self._lru.move_to_end(key)
            return key
        kops.pin_program(program, plan)
        self._lru[key] = True
        while len(self._lru) > self.cap:
            old, _ = self._lru.popitem(last=False)
            kops.unpin_program(old)
        return key

    def clear(self) -> None:
        """Release every pin (used on server shutdown and in tests)."""
        while self._lru:
            key, _ = self._lru.popitem(last=False)
            kops.unpin_program(key)


# --------------------------------------------------------------------------
# admission queue
# --------------------------------------------------------------------------

class BatchQueue:
    """Thread-safe admission queue with a micro-batching window.

    Producers :meth:`put` items (with a row weight) and finally
    :meth:`close`; one consumer calls :meth:`collect`, which blocks for the
    first item, then keeps admitting until (a) ``window_ms`` elapses from
    that first admission, (b) admitted rows reach ``max_batch_rows`` (the
    request that crosses the cap is still admitted -- requests are never
    split), or (c) the stream ends.  Returns None once the stream is
    exhausted.  ``window_ms=0`` degenerates to "whatever is already
    queued", which keeps single-request latency at its floor."""

    _EOF = object()

    def __init__(self, window_ms: float = DEFAULT_WINDOW_MS,
                 max_batch_rows: int = DEFAULT_MAX_BATCH_ROWS):
        if max_batch_rows < 1:
            raise ValueError(
                f"max_batch_rows must be >= 1, got {max_batch_rows}")
        self.window_s = max(0.0, float(window_ms)) * 1e-3
        self.max_batch_rows = int(max_batch_rows)
        self._q: "queue.SimpleQueue" = queue.SimpleQueue()
        self._eof = False

    def put(self, item, n_rows: int = 0) -> None:
        self._q.put((item, int(n_rows)))

    def close(self) -> None:
        """Signal end of stream (producer side)."""
        self._q.put((self._EOF, 0))

    def collect(self) -> Optional[list]:
        """The next admission batch (arrival order), or None at end."""
        if self._eof:
            return None
        item, rows = self._q.get()
        if item is self._EOF:
            self._eof = True
            return None
        batch = [item]
        total = rows
        deadline = time.monotonic() + self.window_s
        while total < self.max_batch_rows:
            remaining = deadline - time.monotonic()
            try:
                item, rows = self._q.get(timeout=max(0.0, remaining)) \
                    if remaining > 0 else self._q.get_nowait()
            except queue.Empty:
                break
            if item is self._EOF:
                self._eof = True
                break
            batch.append(item)
            total += rows
        return batch


# --------------------------------------------------------------------------
# execution engine: coalesce -> pipelined group run -> split
# --------------------------------------------------------------------------

@dataclasses.dataclass
class RequestResult:
    """One request's share of a batch execution.  ``exec_us`` is the whole
    batch's pipelined execution wall time -- groups overlap on the device,
    so per-group times are not separable; the shared figure is the honest
    one.  ``cached`` reports whether the request's program had compiled
    schedule artifacts *before* this batch ran."""
    value: object
    group_rows: int
    group_size: int
    batch_rows: int
    exec_us: float
    cached: bool


@dataclasses.dataclass
class Stats:
    """Cumulative serving counters (one line at server shutdown)."""
    requests: int = 0
    batches: int = 0
    groups: int = 0
    rows: int = 0
    errors: int = 0
    exec_s: float = 0.0

    def rows_per_s(self) -> float:
        return self.rows / self.exec_s if self.exec_s > 0 else float("nan")

    def summary(self, pinned: int = 0) -> str:
        gsz = self.requests / self.groups if self.groups else 0.0
        return (f"pim-serve: {self.requests} requests in {self.batches} "
                f"batches / {self.groups} groups (mean {gsz:.1f} req/group), "
                f"{self.rows} rows @ {self.rows_per_s():,.0f} rows/s, "
                f"errors={self.errors}, pinned={pinned}")


class BatchRuntime:
    """Planner + coalescer + splitter over ``kernels.ops`` group execution,
    with an LRU-pinned schedule working set and cumulative :class:`Stats`.

    One instance per server; :meth:`execute` is also directly usable on a
    list of :class:`Prepared` handles (the benchmark and the property tests
    drive it that way, bypassing the queue)."""

    def __init__(self, pin_cap: int = DEFAULT_PIN_CAP):
        self.pins = PinnedSchedules(pin_cap)
        self.stats = Stats()

    def close(self) -> None:
        self.pins.clear()

    def execute(self, preps: Sequence[Prepared]) -> List[RequestResult]:
        """Execute one admission batch; per-request results in input order.

        Plans groups, pins their programs into the working set, runs all
        groups through the pipelined group executor, and splits each
        group's output rows back to its members (each request's
        ``finish`` decodes its own slice -- including div's ``(q, r)``
        pair and fp bit-pattern decode)."""
        results: List[Optional[RequestResult]] = [None] * len(preps)
        if not preps:
            return []
        plan = plan_groups(preps)
        specs = []
        for g in plan:
            p0 = g.preps[0]
            g.cached = p0.cached
            self.pins.touch(p0.program, p0.plan)
            specs.append(dict(program=p0.program, inputs=coalesce(g),
                              n_rows=g.n_rows, plan=p0.plan))
        t0 = time.perf_counter()
        outs = kops.run_program_groups(specs)
        exec_s = time.perf_counter() - t0
        batch_rows = sum(g.n_rows for g in plan)
        exec_us = exec_s * 1e6
        for g, out in zip(plan, outs):
            off = 0
            for i, p in zip(g.members, g.preps):
                sub = {k: v[off:off + p.n_rows] for k, v in out.items()}
                off += p.n_rows
                results[i] = RequestResult(
                    value=p.finish(sub), group_rows=g.n_rows,
                    group_size=len(g.preps), batch_rows=batch_rows,
                    exec_us=exec_us, cached=g.cached)
        self.stats.requests += len(preps)
        self.stats.batches += 1
        self.stats.groups += len(plan)
        self.stats.rows += batch_rows
        self.stats.exec_s += exec_s
        return results  # type: ignore[return-value]
