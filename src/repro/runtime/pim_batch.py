"""Batched serving runtime for the PIM ufunc API (DESIGN.md §10).

``launch/serve.py --pim-stdin`` executes one gate program per request,
which leaves the machine's row axis -- the dimension the paper's
throughput case (Fig. 9) banks on -- mostly idle under heavy mixed
traffic.  This module sits between the JSON request layer and
``kernels/ops.py`` and fills that axis:

* :class:`BatchQueue` -- thread-safe admission with a micro-batching
  window: block for the first request, then keep admitting until the
  window closes, the row cap fills, or the stream ends.  An optional
  queue-depth cap turns :meth:`BatchQueue.offer` into backpressure: past
  the cap a request is rejected (structured, retriable) instead of
  growing the queue without bound -- the producer never blocks and the
  consumer never deadlocks (DESIGN.md §12).
* :func:`plan_groups` -- the planner: group a batch's prepared requests by
  compiled-program content hash + execution config (``Prepared.key`` makes
  structurally identical requests trivially groupable).
* :func:`coalesce` -- concatenate each group's per-port rows, in arrival
  order, into one packed input set.
* :meth:`BatchRuntime.execute` -- run the whole plan through
  ``kernels.ops.run_program_groups`` (group ``k+1`` packs on the host
  while group ``k`` executes on the device -- the streaming pipeline
  generalized across programs), then *split*: scatter each group's output
  rows back to its member requests via ``Prepared.finish`` with
  per-request accounting.
* :class:`PinnedSchedules` -- an LRU-pinned working set of compiled slot
  schedules: hot programs stay resident in the bounded compiled-program
  cache even when cold traffic churns it, so they never recompile
  mid-serving.

Everything operates on :class:`repro.pim_ufunc.Prepared` handles, so the
runtime is equally usable programmatically (benchmarks, tests) and from
the ``--pim-serve`` JSON-lines loop.
"""

from __future__ import annotations

import collections
import dataclasses
import queue
import threading
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from ..kernels import ops as kops
from ..pim_ufunc import Prepared
from . import telemetry
from .faults import DeadlineExceeded, FaultError

DEFAULT_WINDOW_MS = 2.0
DEFAULT_MAX_BATCH_ROWS = 1 << 16
DEFAULT_PIN_CAP = 32


# --------------------------------------------------------------------------
# planner
# --------------------------------------------------------------------------

def group_key(prep: Prepared) -> tuple:
    """The coalescing key: program content hash plus the full execution
    config -- ``ExecPlan.key`` covers every dimension that makes two
    executions non-mergeable (backend, schedule, *word layout*, chunking,
    mesh, per-backend tunables), so requests differing in any of them --
    e.g. only in word layout -- never coalesce into one packed state.  Two
    requests with equal keys run bit-identically as one packed state."""
    return (prep.key, prep.plan.key)


@dataclasses.dataclass
class Group:
    """One plan entry: the member requests (by batch index, arrival order)
    that share a program structure and execution config."""
    key: tuple
    members: List[int]
    preps: List[Prepared]
    n_rows: int = 0
    cached: bool = False        # were schedule artifacts already compiled?


def plan_groups(preps: Sequence[Prepared]) -> List[Group]:
    """Group a batch of prepared requests by :func:`group_key`.  Stable:
    groups are ordered by first arrival and members keep arrival order, so
    coalesced row offsets are reproducible."""
    by_key: Dict[tuple, Group] = {}
    plan: List[Group] = []
    for i, p in enumerate(preps):
        k = group_key(p)
        g = by_key.get(k)
        if g is None:
            g = by_key[k] = Group(k, [], [])
            plan.append(g)
        g.members.append(i)
        g.preps.append(p)
        g.n_rows += p.n_rows
    return plan


def coalesce(group: Group) -> Dict[str, np.ndarray]:
    """One packed input set for a group: per port, the members' rows
    concatenated in arrival order (the splitter reverses this by offset).
    Mixed member dtypes (e.g. uint16 and object rows of one width) promote
    under numpy's rules; the bridges take either."""
    first = group.preps[0]
    out = {}
    for name in first.inputs:
        parts = [p.inputs[name] for p in group.preps]
        out[name] = parts[0] if len(parts) == 1 else np.concatenate(parts)
    return out


# --------------------------------------------------------------------------
# pinned schedule working set
# --------------------------------------------------------------------------

class PinnedSchedules:
    """LRU working set of pinned compiled schedules (``cap`` programs).

    ``touch`` pins a program's compiled-cache entry in ``kernels.ops`` (see
    ``pin_program``) and refreshes its recency; when the working set
    overflows, the least-recently-served program is unpinned (it stays
    cached but becomes evictable).  Under mixed traffic this keeps the hot
    programs' levelized schedules and device index buffers resident no
    matter how many cold structures stream past.  ``cap=0`` disables
    pinning entirely."""

    def __init__(self, cap: int = DEFAULT_PIN_CAP):
        if cap < 0:
            raise ValueError(f"pin cap must be >= 0, got {cap}")
        self.cap = int(cap)
        self._lru: "collections.OrderedDict[bytes, bool]" = \
            collections.OrderedDict()

    def __len__(self) -> int:
        return len(self._lru)

    def __contains__(self, key: bytes) -> bool:
        return key in self._lru

    def touch(self, program, plan=None) -> Optional[tuple]:
        """Pin ``program``'s compiled entry under ``plan`` (default: the
        default plan; or refresh its recency); returns the cache key, or
        None when pinning is disabled."""
        if not self.cap:
            return None
        key = kops.cache_key(program, plan)
        if key in self._lru:
            self._lru.move_to_end(key)
            return key
        kops.pin_program(program, plan)
        self._lru[key] = True
        while len(self._lru) > self.cap:
            old, _ = self._lru.popitem(last=False)
            kops.unpin_program(old)
        return key

    def clear(self) -> None:
        """Release every pin (used on server shutdown and in tests)."""
        while self._lru:
            key, _ = self._lru.popitem(last=False)
            kops.unpin_program(key)


# --------------------------------------------------------------------------
# admission queue
# --------------------------------------------------------------------------

class BatchQueue:
    """Thread-safe admission queue with a micro-batching window.

    Producers :meth:`put` items (with a row weight) and finally
    :meth:`close`; one consumer calls :meth:`collect`, which blocks for the
    first item, then keeps admitting until (a) ``window_ms`` elapses from
    that first admission, (b) admitted rows reach ``max_batch_rows`` (the
    request that crosses the cap is still admitted -- requests are never
    split), or (c) the stream ends.  Returns None once the stream is
    exhausted.  ``window_ms=0`` degenerates to "whatever is already
    queued", which keeps single-request latency at its floor.

    ``max_queue_rows`` bounds admission: when set, :meth:`offer` rejects a
    request whose rows would push the queued backlog past the cap --
    *unless* the queue is empty, so an oversized single request is still
    servable (it would never fit otherwise).  Rejection is a return value,
    not an exception, and nothing ever blocks the producer: the server
    turns a False into a structured retriable "overloaded" response."""

    _EOF = object()

    def __init__(self, window_ms: float = DEFAULT_WINDOW_MS,
                 max_batch_rows: int = DEFAULT_MAX_BATCH_ROWS,
                 max_queue_rows: Optional[int] = None):
        if max_batch_rows < 1:
            raise ValueError(
                f"max_batch_rows must be >= 1, got {max_batch_rows}")
        if max_queue_rows is not None and max_queue_rows < 1:
            raise ValueError(
                f"max_queue_rows must be >= 1 or None, got {max_queue_rows}")
        self.window_s = max(0.0, float(window_ms)) * 1e-3
        self.max_batch_rows = int(max_batch_rows)
        self.max_queue_rows = None if max_queue_rows is None \
            else int(max_queue_rows)
        self._q: "queue.SimpleQueue" = queue.SimpleQueue()
        self._eof = False
        self._lock = threading.Lock()
        self._pending_rows = 0

    def put(self, item, n_rows: int = 0) -> None:
        with self._lock:
            self._pending_rows += int(n_rows)
        self._q.put((item, int(n_rows)))

    def offer(self, item, n_rows: int = 0) -> bool:
        """Admit ``item`` unless the backlog cap would be exceeded; returns
        False (rejection) instead of blocking.  With no cap, equivalent to
        :meth:`put`."""
        n = int(n_rows)
        with self._lock:
            if (self.max_queue_rows is not None and n > 0
                    and self._pending_rows > 0
                    and self._pending_rows + n > self.max_queue_rows):
                return False
            self._pending_rows += n
        self._q.put((item, n))
        return True

    def close(self) -> None:
        """Signal end of stream (producer side)."""
        self._q.put((self._EOF, 0))

    def collect(self) -> Optional[list]:
        """The next admission batch (arrival order), or None at end."""
        if self._eof:
            return None
        item, rows = self._q.get()
        if item is self._EOF:
            self._eof = True
            return None
        self._drain(rows)
        batch = [item]
        total = rows
        deadline = time.monotonic() + self.window_s
        while total < self.max_batch_rows:
            remaining = deadline - time.monotonic()
            try:
                item, rows = self._q.get(timeout=max(0.0, remaining)) \
                    if remaining > 0 else self._q.get_nowait()
            except queue.Empty:
                break
            if item is self._EOF:
                self._eof = True
                break
            self._drain(rows)
            batch.append(item)
            total += rows
        return batch

    def _drain(self, rows: int) -> None:
        with self._lock:
            self._pending_rows = max(0, self._pending_rows - rows)


# --------------------------------------------------------------------------
# error taxonomy (DESIGN.md §12)
# --------------------------------------------------------------------------

_BAD_REQUEST = (KeyError, TypeError, ValueError, OverflowError)


def classify_error(e: BaseException) -> dict:
    """Map an exception to the structured wire-format error body: a
    machine-readable ``code``, the human message, and whether a retry of
    the *same* request could succeed (bad requests never will; transient
    execution faults, deadline misses, and overload might).  A
    :class:`FaultError` carrying structured context (program-key prefix,
    chunk/stage, attempts, remap target -- DESIGN.md §14) surfaces it
    under ``error.fault`` so operators can tell *which* program family is
    failing, not just that retries happened."""
    if isinstance(e, DeadlineExceeded):
        code, retriable = "deadline_exceeded", True
    elif isinstance(e, FaultError):
        code, retriable = "exec_failed", True
    elif isinstance(e, _BAD_REQUEST):
        code, retriable = "bad_request", False
    else:
        code, retriable = "internal", True
    body = {"code": code, "message": f"{type(e).__name__}: {e}",
            "retriable": retriable}
    ctx = getattr(e, "context", None)
    if ctx:
        body["fault"] = dict(ctx)
    return {"error": body}


# --------------------------------------------------------------------------
# per-program-family circuit breakers (DESIGN.md §14)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BreakerPolicy:
    """Knobs of the per-(program family) circuit breaker.

    * ``window`` -- recent request outcomes tracked per family.
    * ``trip_failures`` -- retriable failures (fault / retry-exhaustion /
      deadline) within the window that trip the breaker open.
    * ``cooldown_s`` -- how long an open breaker sheds before it goes
      half-open and lets probes through.
    * ``probes`` -- half-open probe requests; that many consecutive probe
      successes close the breaker, any probe failure re-trips it.
    """
    window: int = 16
    trip_failures: int = 4
    cooldown_s: float = 1.0
    probes: int = 2

    def __post_init__(self):
        if self.window < 1 or self.trip_failures < 1 or self.probes < 1 \
                or self.cooldown_s < 0:
            raise ValueError("window/trip_failures/probes >= 1 and "
                             "cooldown_s >= 0 required")


class CircuitBreaker:
    """One program family's breaker state machine:
    closed -> (sustained failures) -> open -> (cooldown) -> half-open ->
    (probe successes) -> closed, or (probe failure) -> open again.

    ``admit`` decides how the family's next unit of work runs ("run"
    normally, "probe" normally-but-watched, or "shed" to the fallback
    path); ``record`` feeds an outcome back and returns the transition
    event (``"trip"`` / ``"close"`` / None) for the caller's stats."""

    def __init__(self, policy: BreakerPolicy):
        self.policy = policy
        self.state = "closed"
        self._outcomes: "collections.deque" = collections.deque(
            maxlen=policy.window)
        self._opened_at = 0.0
        self._probe_budget = 0
        self._probe_successes = 0

    def admit(self, now: float) -> str:
        if self.state == "open":
            if now - self._opened_at < self.policy.cooldown_s:
                return "shed"
            self.state = "half-open"
            self._probe_budget = self.policy.probes
            self._probe_successes = 0
        if self.state == "half-open":
            if self._probe_budget > 0:
                self._probe_budget -= 1
                return "probe"
            return "shed"
        return "run"

    def _trip(self, now: float) -> str:
        self.state = "open"
        self._opened_at = now
        self._outcomes.clear()
        return "trip"

    def record(self, ok: bool, now: float, probe: bool = False
               ) -> Optional[str]:
        if self.state == "half-open" and probe:
            if not ok:
                return self._trip(now)
            self._probe_successes += 1
            if self._probe_successes >= self.policy.probes:
                self.state = "closed"
                self._outcomes.clear()
                return "close"
            return None
        if self.state != "closed":
            return None        # stale outcome from before the transition
        self._outcomes.append(bool(ok))
        if sum(1 for o in self._outcomes if not o) \
                >= self.policy.trip_failures:
            return self._trip(now)
        return None


# --------------------------------------------------------------------------
# execution engine: coalesce -> pipelined group run -> split
# --------------------------------------------------------------------------

@dataclasses.dataclass
class RequestResult:
    """One request's share of a batch execution.  ``exec_us`` is the whole
    batch's pipelined execution wall time -- groups overlap on the device,
    so per-group times are not separable; the shared figure is the honest
    one.  ``cached`` reports whether the request's program had compiled
    schedule artifacts *before* this batch ran.  ``degraded`` marks a
    request that fell out of group execution and ran (or failed)
    standalone; ``error`` is the structured error body (value is None)
    when even standalone execution failed; ``health`` carries the batch's
    drained fault-tolerance counters (shared across the batch's
    results)."""
    value: object
    group_rows: int
    group_size: int
    batch_rows: int
    exec_us: float
    cached: bool
    degraded: bool = False
    shed: bool = False          # family breaker open -> served on fallback
    error: Optional[dict] = None
    health: Optional[dict] = None


class Stats:
    """Cumulative serving counters (one line at server shutdown).

    Registry-backed (DESIGN.md §15): every field lives as a
    ``pim.serve.<field>`` counter on the runtime's per-instance
    :class:`~repro.runtime.telemetry.MetricsRegistry`, so the serving
    reader thread (``rejected``/``expired``) and the execute loop mutate
    under one lock and the Prometheus exposition sees the same numbers
    the shutdown summary prints.  The historical dataclass attribute API
    is preserved -- ``stats.requests`` reads, ``stats.requests += 1``
    writes -- but cross-thread increments should use the atomic
    :meth:`add` (``+=`` expands to a get-then-set pair)."""

    _FIELDS = dict(
        requests=0, batches=0, groups=0, rows=0, errors=0, exec_s=0.0,
        fused_programs=0,        # requests served by a fused expr program
        # fault-tolerance / admission health (DESIGN.md §12)
        rejected=0,              # admission backpressure rejections
        expired=0,               # requests past deadline at dequeue
        degraded_groups=0,       # groups that fell back to per-request
        retries=0,               # chunk retries after detected corruption
        faults_detected=0,
        faults_corrected=0,
        remapped_rows=0,
        stragglers=0,            # batch exec-time spikes (StragglerMonitor)
        # circuit breakers (DESIGN.md §14)
        breaker_trips=0,         # family breakers tripped open
        breaker_probes=0,        # half-open probe admissions
        breaker_closes=0,        # breakers closed after probe successes
        shed_requests=0)         # requests served on the shed fallback

    PREFIX = "pim.serve."

    def __init__(self, registry: Optional[telemetry.MetricsRegistry] = None):
        object.__setattr__(self, "registry",
                           registry if registry is not None
                           else telemetry.MetricsRegistry())

    def __getattr__(self, name):        # only called on non-instance attrs
        if name not in Stats._FIELDS:
            raise AttributeError(name)
        default = Stats._FIELDS[name]
        v = self.registry.counter(Stats.PREFIX + name, default)
        return v if isinstance(default, float) else int(v)

    def __setattr__(self, name, value):
        if name in Stats._FIELDS:
            self.registry.set_counter(Stats.PREFIX + name, value)
        else:
            object.__setattr__(self, name, value)

    def add(self, name: str, n=1) -> None:
        """Atomic increment of one field (thread-safe, unlike ``+=``)."""
        self.registry.inc(Stats.PREFIX + name, n)

    def as_dict(self) -> Dict[str, float]:
        """All fields as a plain dict (the JSON summary line's core)."""
        return {name: getattr(self, name) for name in Stats._FIELDS}

    def rows_per_s(self) -> float:
        return self.rows / self.exec_s if self.exec_s > 0 else float("nan")

    def absorb_health(self, health: Dict[str, int]) -> None:
        """Fold one batch's drained ``kernels.ops`` HEALTH counters in
        (one lock acquisition)."""
        self.registry.add_many({
            Stats.PREFIX + k: health.get(k, 0)
            for k in ("retries", "faults_detected", "faults_corrected",
                      "remapped_rows")})

    def summary(self, pinned: int = 0) -> str:
        gsz = self.requests / self.groups if self.groups else 0.0
        return (f"pim-serve: {self.requests} requests in {self.batches} "
                f"batches / {self.groups} groups (mean {gsz:.1f} req/group), "
                f"{self.rows} rows @ {self.rows_per_s():,.0f} rows/s, "
                f"errors={self.errors}, fused={self.fused_programs}, "
                f"pinned={pinned}, "
                f"rejected={self.rejected}, expired={self.expired}, "
                f"degraded_groups={self.degraded_groups}, "
                f"faults={self.faults_detected}/{self.faults_corrected} "
                f"(detected/corrected), retries={self.retries}, "
                f"remapped_rows={self.remapped_rows}, "
                f"stragglers={self.stragglers}, "
                f"breaker={self.breaker_trips}/{self.breaker_probes}/"
                f"{self.breaker_closes} (trips/probes/closes), "
                f"shed={self.shed_requests}")


class BatchRuntime:
    """Planner + coalescer + splitter over ``kernels.ops`` group execution,
    with an LRU-pinned schedule working set and cumulative :class:`Stats`.

    One instance per server; :meth:`execute` is also directly usable on a
    list of :class:`Prepared` handles (the benchmark and the property tests
    drive it that way, bypassing the queue).

    ``breaker`` (a :class:`BreakerPolicy`, default on) arms per-program-
    family circuit breakers: a family -- keyed by ``Prepared.key``, the
    program content hash, so recovery traffic for a structure shares its
    breaker across plans -- whose requests keep failing retriably
    (faults exhausting retries, deadline misses) trips open and its
    subsequent requests are *shed*: served standalone on the numpy oracle
    plan (correct, slower, ``degraded+shed``), never dropped.  After a
    cooldown, half-open probes run on the primary path; enough successes
    close the breaker.  ``breaker=None`` disables the layer."""

    _SHED = object()

    def __init__(self, pin_cap: int = DEFAULT_PIN_CAP,
                 breaker: Optional[BreakerPolicy] = BreakerPolicy(),
                 metrics: Optional[telemetry.MetricsRegistry] = None):
        # per-instance registry: Stats counters plus the batch histograms
        # (pim.batch.exec_us / occupancy_rows / group_size) land here, so
        # concurrent runtimes (tests!) never share windows; the serving
        # layer adds its queue/request latency histograms to the same
        # registry and renders all of it in one Prometheus exposition
        self.metrics = metrics if metrics is not None \
            else telemetry.MetricsRegistry()
        self.pins = PinnedSchedules(pin_cap)
        self.stats = Stats(self.metrics)
        self.breaker = breaker
        self.breakers: Dict[bytes, CircuitBreaker] = {}

    def close(self) -> None:
        self.pins.clear()

    def _breaker_for(self, prep: Prepared) -> CircuitBreaker:
        br = self.breakers.get(prep.key)
        if br is None:
            br = self.breakers[prep.key] = CircuitBreaker(self.breaker)
        return br

    def _note_breaker_event(self, event: Optional[str]) -> None:
        if event == "trip":
            self.stats.add("breaker_trips")
            telemetry.TRACER.instant("breaker.trip", cat="pim.serve")
        elif event == "close":
            self.stats.add("breaker_closes")
            telemetry.TRACER.instant("breaker.close", cat="pim.serve")

    def record_expired(self, prep: Prepared) -> None:
        """Feed one dequeue-time deadline expiry into the request's family
        breaker: requests of a family that keep dying in the queue are as
        much a sustained-failure signal as ones that fail on the device."""
        if self.breaker is None:
            return
        self._note_breaker_event(
            self._breaker_for(prep).record(False, time.monotonic()))

    def execute(self, preps: Sequence[Prepared],
                deadlines: Optional[Sequence[Optional[float]]] = None,
                ) -> List[RequestResult]:
        """Execute one admission batch; per-request results in input order.

        Plans groups, pins their programs into the working set, runs all
        groups through the pipelined group executor, and splits each
        group's output rows back to its members (each request's
        ``finish`` decodes its own slice -- including div's ``(q, r)``
        pair and fp bit-pattern decode).

        ``deadlines`` (optional, aligned with ``preps``) are absolute
        ``time.monotonic()`` values: a group inherits the *tightest* member
        deadline and the executor checks it between chunks.

        Degradation ladder (DESIGN.md §12): if the pipelined whole-batch
        run raises, each group re-runs alone; a group that still fails
        falls back to per-request execution, so one poisoned request (or a
        row span whose faults exhaust retries) costs its own response --
        never the batch.  Per-request failures surface as structured
        ``error`` bodies on their own :class:`RequestResult`."""
        results: List[Optional[RequestResult]] = [None] * len(preps)
        if not preps:
            return []
        tracer = telemetry.TRACER
        t_coal = time.perf_counter()
        dls = list(deadlines) if deadlines is not None else [None] * len(preps)
        plan = plan_groups(preps)
        now = time.monotonic()
        modes = []
        for g in plan:
            mode = "run"
            if self.breaker is not None:
                mode = self._breaker_for(g.preps[0]).admit(now)
                if mode == "probe":
                    self.stats.add("breaker_probes")
            modes.append(mode)
        specs = []
        for g, mode in zip(plan, modes):
            if mode == "shed":
                specs.append(self._SHED)
                continue
            p0 = g.preps[0]
            g.cached = p0.cached
            self.pins.touch(p0.program, p0.plan)
            member_dls = [dls[i] for i in g.members if dls[i] is not None]
            try:
                inputs = coalesce(g)
            except Exception:
                # a malformed member poisons only its own group: degrade the
                # group to per-request, where the healthy members still run
                specs.append(None)
                continue
            specs.append(dict(program=p0.program, inputs=inputs,
                              n_rows=g.n_rows, plan=p0.plan,
                              deadline=min(member_dls) if member_dls
                              else None))
        t0 = time.perf_counter()
        tracer.event("coalesce", t_coal, t0, cat="pim.serve",
                     requests=len(preps), groups=len(plan))
        live = [s for s in specs if isinstance(s, dict)]
        try:
            live_outs = iter(kops.run_program_groups(live) if live else ())
            outs = [s if s is None or s is self._SHED else next(live_outs)
                    for s in specs]
        except Exception:
            # retry each group alone: a healthy group must not pay for a
            # poisoned neighbour sharing its batch
            outs = []
            for spec in specs:
                if spec is None or spec is self._SHED:
                    outs.append(spec)
                    continue
                try:
                    outs.append(kops.run_program_groups([spec])[0])
                except Exception:
                    outs.append(None)       # degrade to per-request below
        exec_s = time.perf_counter() - t0
        batch_rows = sum(g.n_rows for g in plan)
        exec_us = exec_s * 1e6
        tracer.event("exec", t0, t0 + exec_s, cat="pim.serve",
                     rows=batch_rows, groups=len(live))
        # per-batch latency/occupancy histograms (DESIGN.md §15): exec
        # wall time, row occupancy, and per-group member counts -- what
        # the serving layer's periodic stats lines summarize as p50/p99
        self.metrics.observe_many({"pim.batch.exec_us": exec_us,
                                   "pim.batch.occupancy_rows": batch_rows})
        for g in plan:
            self.metrics.observe("pim.batch.group_size", len(g.preps))
        t_split = time.perf_counter()
        for g, out in zip(plan, outs):
            if out is self._SHED:
                self.stats.add("shed_requests", len(g.preps))
                for i, p in zip(g.members, g.preps):
                    results[i] = self._run_shed(p, dls[i], g, batch_rows,
                                                exec_us)
                continue
            if out is None:
                self.stats.add("degraded_groups")
                for i, p in zip(g.members, g.preps):
                    results[i] = self._run_degraded(p, dls[i], g, batch_rows,
                                                    exec_us)
                continue
            off = 0
            for i, p in zip(g.members, g.preps):
                sub = {k: v[off:off + p.n_rows] for k, v in out.items()}
                off += p.n_rows
                results[i] = RequestResult(
                    value=p.finish(sub), group_rows=g.n_rows,
                    group_size=len(g.preps), batch_rows=batch_rows,
                    exec_us=exec_us, cached=g.cached)
        tracer.event("unpack", t_split, time.perf_counter(),
                     cat="pim.serve", requests=len(preps))
        if self.breaker is not None:
            # feed primary-path outcomes back; shed results never count --
            # they carry no evidence about the primary path's health
            tr = time.monotonic()
            for g, mode in zip(plan, modes):
                if mode == "shed":
                    continue
                br = self._breaker_for(g.preps[0])
                for i in g.members:
                    r = results[i]
                    failed = r is None or (
                        r.error is not None
                        and r.error.get("retriable", False))
                    self._note_breaker_event(
                        br.record(not failed, tr, probe=(mode == "probe")))
        health = kops.drain_health()
        if health:
            self.stats.absorb_health(health)
            for r in results:
                if r is not None:
                    r.health = dict(health)
        self.metrics.add_many({       # one lock: the whole batch's deltas
            Stats.PREFIX + "requests": len(preps),
            Stats.PREFIX + "fused_programs": sum(
                1 for p in preps if getattr(p, "fused_ops", 1) > 1),
            Stats.PREFIX + "batches": 1,
            Stats.PREFIX + "groups": len(plan),
            Stats.PREFIX + "rows": batch_rows,
            Stats.PREFIX + "exec_s": exec_s})
        return results  # type: ignore[return-value]

    def _run_degraded(self, p: Prepared, dl: Optional[float], g: Group,
                      batch_rows: int, exec_us: float) -> RequestResult:
        """Standalone execution of one member of a failed group."""
        try:
            if dl is not None and time.monotonic() > dl:
                raise DeadlineExceeded(
                    f"request expired before degraded execution "
                    f"({p.n_rows} rows)")
            if p.plan.backend.name == "numpy":
                value = p.run()
            else:
                value = p.finish(kops.run_program_streaming(
                    p.program, p.inputs, p.n_rows, p.plan, deadline=dl))
            return RequestResult(
                value=value, group_rows=g.n_rows, group_size=len(g.preps),
                batch_rows=batch_rows, exec_us=exec_us, cached=g.cached,
                degraded=True)
        except Exception as e:
            return RequestResult(
                value=None, group_rows=g.n_rows, group_size=len(g.preps),
                batch_rows=batch_rows, exec_us=exec_us, cached=g.cached,
                degraded=True, error=classify_error(e)["error"])

    def _run_shed(self, p: Prepared, dl: Optional[float], g: Group,
                  batch_rows: int, exec_us: float) -> RequestResult:
        """Serve one member of a tripped family on the numpy oracle plan:
        correct but slow, marked ``degraded+shed`` -- shedding degrades a
        family's service, it never loses its requests."""
        try:
            if dl is not None and time.monotonic() > dl:
                raise DeadlineExceeded(
                    f"request expired before shed execution ({p.n_rows} "
                    f"rows)")
            oplan = dataclasses.replace(
                p.plan, backend=kops.BACKENDS["numpy"], mesh=None,
                layout=kops.ROWS32, chunk_rows=None, faults=None,
                verify=None)
            value = p.finish(
                kops.run_program(p.program, p.inputs, p.n_rows, oplan))
            return RequestResult(
                value=value, group_rows=g.n_rows, group_size=len(g.preps),
                batch_rows=batch_rows, exec_us=exec_us, cached=g.cached,
                degraded=True, shed=True)
        except Exception as e:
            return RequestResult(
                value=None, group_rows=g.n_rows, group_size=len(g.preps),
                batch_rows=batch_rows, exec_us=exec_us, cached=g.cached,
                degraded=True, shed=True, error=classify_error(e)["error"])
