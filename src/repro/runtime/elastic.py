"""Elastic scaling: re-mesh on a changed device count.

A failed node shrinks the healthy pool; ``choose_mesh`` picks the largest
(data, model) grid the survivors support (model axis must divide head/expert
counts), and ``reshard_plan`` pairs a checkpoint restore with the new mesh's
shardings -- the checkpoint manager's device_put-on-restore does the actual
movement.  Growth works identically.
"""

from __future__ import annotations

from typing import List, Tuple

import jax
import numpy as np


def choose_mesh(n_devices: int, *, model_divisors: List[int],
                max_model: int = 64) -> Tuple[int, int]:
    """Largest usable (data, model) for ``n_devices``.

    model must divide every entry of ``model_divisors`` (head counts, expert
    counts, ffn tiling); prefer the largest model axis that keeps data >= 1
    and uses every device (drops stragglers to a power-of-two pool if the
    count is awkward)."""
    usable = n_devices
    while usable > 0:
        for model in sorted({d for d in range(1, max_model + 1)
                             if usable % d == 0 and
                             all(m % d == 0 for m in model_divisors)},
                            reverse=True):
            data = usable // model
            if data >= 1:
                return data, model
        usable -= 1
    raise ValueError("no usable mesh")


def mesh_for(n_devices: int, model_divisors: List[int]):
    data, model = choose_mesh(n_devices, model_divisors=model_divisors)
    devs = np.array(jax.devices()[: data * model]).reshape(data, model)
    return jax.sharding.Mesh(devs, ("data", "model"))


def reshard_plan(ckpt, template, new_mesh, sharding_fn):
    """Restore the latest checkpoint onto ``new_mesh``.

    sharding_fn(template) -> tree of NamedSharding for the new mesh."""
    shardings = sharding_fn(new_mesh, template)
    return ckpt.restore(template, shardings=shardings)
