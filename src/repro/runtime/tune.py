"""Backend autotuner: measured, per-target tuned execution defaults
(DESIGN.md §16).

The ``Backend`` tunables (``slot_width``, ``seg_levels``, ``chunk_rows``)
and the schedule/emission choice are hand-set globals, but BENCH_3/BENCH_9
show the optimum shifts with program family, word layout, and device
target -- the Bitlet and PrIM lesson that winning PIM configurations must
be *measured*, not assumed.  This module sweeps those knobs per
``(program family, layout, backend)`` on the current device target,
measures wall time through the real execution path (``pim.prepare`` with
an explicit plan, warmed, min-of-reps) alongside the analytical
``telemetry.PimCostModel`` cycles, and persists winners as ``tuned.json``
beside the artifact cache.

Safety property the tests pin: **a tuned configuration can never lose to
the hand defaults** -- the default configuration is always swept first and
a candidate only wins by beating it on measured wall time, so installing
tuned.json is monotone on every tracked benchmark row.

Winners are applied through ``kernels.plan.register_tuned`` +
``apply_tuned``: the ufunc frontend overlays them at plan-resolution time
onto knobs the caller left at hand defaults, so explicit choices
(``schedule=``, a custom ``Backend``, ``plan=``) always win, and
``options(tuned=False)`` disables the overlay wholesale.

CLI::

    python -m repro.runtime.tune --quick --out /var/cache/pim
    python -m repro.runtime.tune --families add:16,fp_add:fp16 \
        --rows 8192 --reps 5 --out /var/cache/pim/tuned.json
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from .artifact_cache import TUNED_BASENAME, device_target
from ..core.floatfmt import FORMATS

#: Families swept by default: the tracked benchmark families (uint16 +
#: fp16 serial suite -- the mixed 8-op serving traffic).
DEFAULT_FAMILIES = ("add:16", "mul:16", "fp_add:fp16", "fp_mul:fp16")

#: tuned.json format version.
DOC_VERSION = 1


# --------------------------------------------------------------------------
# sweep space
# --------------------------------------------------------------------------

def candidates(quick: bool = False) -> List[dict]:
    """Candidate override sets, hand-default first (the baseline every
    winner must beat).  ``quick`` is the tiny CI sweep; the full sweep
    crosses schedule kind x slot width (+ static segmentation, which only
    the straight-line emission reads)."""
    cands: List[dict] = [{}]
    if quick:
        cands += [{"slot_width": 4}, {"schedule": "dense"}]
        return cands
    for schedule in ("slots", "slots-static", "dense"):
        for slot_width in (4, 6, 8):
            ov: dict = {}
            if schedule != "slots":
                ov["schedule"] = schedule
            if slot_width != 6:
                ov["slot_width"] = slot_width
            if schedule == "dense" and slot_width != 4:
                continue        # dense ignores the slot allocator: one
                #                 representative sweep point is enough
            if ov and ov not in cands:
                cands.append(ov)
    for seg_levels in (64, 256):
        cands.append({"schedule": "slots-static", "seg_levels": seg_levels})
    return cands


def parse_family(family: str):
    """Split a family spec "op:param" into (op, prepare kwargs): int
    families carry a bit width ("mul:16"), fp families a format name
    ("fp_add:fp16")."""
    op, _, param = family.partition(":")
    if not param:
        raise ValueError(f"family spec {family!r} is not 'op:param'")
    if op.startswith("fp_"):
        if param not in FORMATS:
            raise ValueError(f"unknown fp format {param!r} in {family!r}")
        return op, {"fmt": param}
    return op, {"width": int(param)}


def _operands(family: str, rows: int, seed: int = 0):
    """Deterministic valid operands for one family: full-range unsigned
    ints, or normal-range fp bit patterns (never zero/NaN/Inf/subnormal,
    so every op including div accepts them)."""
    op, kw = parse_family(family)
    rng = np.random.default_rng(seed)
    if "width" in kw:
        w = kw["width"]
        hi = 1 << min(w, 63)
        x = rng.integers(0, hi, rows, dtype=np.uint64)
        y = rng.integers(1, hi, rows, dtype=np.uint64)     # div-safe
        return op, x, y, kw
    fmt = FORMATS[kw["fmt"]]
    ne, nm = fmt.ne, fmt.nm

    def patterns():
        e = rng.integers(1, (1 << ne) - 1, rows, dtype=np.uint64)
        m = rng.integers(0, 1 << nm, rows, dtype=np.uint64)
        s = rng.integers(0, 2, rows, dtype=np.uint64)
        return (s << np.uint64(ne + nm)) | (e << np.uint64(nm)) | m
    return op, patterns(), patterns(), kw


# --------------------------------------------------------------------------
# measurement
# --------------------------------------------------------------------------

def _plan_for(overrides: dict, layout: str, backend: str):
    from ..kernels.plan import BACKENDS, DEFAULT_SCHEDULE, ExecPlan, \
        LAYOUTS, TUNABLE_FIELDS
    bk_over = {k: int(v) for k, v in overrides.items()
               if k in TUNABLE_FIELDS}
    bk = dataclasses.replace(BACKENDS[backend], **bk_over) if bk_over \
        else BACKENDS[backend]
    return ExecPlan(backend=bk,
                    schedule=overrides.get("schedule", DEFAULT_SCHEDULE),
                    layout=LAYOUTS[layout])


def measure(family: str, overrides: dict, *, layout: str = "rows32",
            backend: str = "ref", rows: int = 4096, reps: int = 3) -> dict:
    """Wall time + modeled cycles for one (family, candidate) point,
    through the real ufunc execution path: prepare with an explicit plan
    (which bypasses the tuned overlay by construction), one untimed
    warm-up run covering levelize + jit, then min-of-``reps`` timed runs.
    """
    from .. import pim_ufunc as pim
    from ..kernels import ops as kops

    op, x, y, kw = _operands(family, rows)
    plan = _plan_for(overrides, layout, backend)
    prep = pim.prepare(op, x, y, plan=plan, **kw)
    prep.run()                                  # untimed: compile + trace
    best = float("inf")
    for _ in range(max(1, reps)):
        t0 = time.perf_counter()
        prep.run()
        best = min(best, (time.perf_counter() - t0) * 1e6)
    r = kops.compiled(prep.program, plan).resolve(
        prep.program, plan, tuple(sorted(prep.inputs)))
    return {"overrides": dict(overrides), "us": best,
            "rows_per_s": rows / best * 1e6,
            "model_cycles": int(r.model.cycles) if r.model else 0}


def tune_family(family: str, *, layout: str = "rows32",
                backend: str = "ref", rows: int = 4096, reps: int = 3,
                quick: bool = False, log=None) -> dict:
    """Sweep one family; returns the entry dict for tuned.json.  The
    hand-default candidate is measured first and a non-default candidate
    wins only by strictly beating it, so the tuned choice is >= defaults
    on the metric that gates the tracked benchmark rows."""
    results = []
    for ov in candidates(quick):
        res = measure(family, ov, layout=layout, backend=backend,
                      rows=rows, reps=reps)
        results.append(res)
        if log:
            log(f"  {family} {ov or '(default)'}: "
                f"{res['us']:.0f}us  {res['model_cycles']} cycles")
    default = results[0]
    best = min(results, key=lambda r: r["us"])
    if best["us"] >= default["us"]:
        best = default                           # never regress defaults
    return {"family": family, "layout": layout, "backend": backend,
            "overrides": best["overrides"], "us": best["us"],
            "default_us": default["us"],
            "model_cycles": best["model_cycles"],
            "candidates": results}


def tune(families: Sequence[str] = DEFAULT_FAMILIES, *,
         layout: str = "rows32", backend: str = "ref", rows: int = 4096,
         reps: int = 3, quick: bool = False, log=None) -> dict:
    """Sweep several families into one tuned.json document."""
    entries = [tune_family(f, layout=layout, backend=backend, rows=rows,
                           reps=reps, quick=quick, log=log)
               for f in families]
    return {"version": DOC_VERSION, "target": device_target(),
            "entries": entries}


# --------------------------------------------------------------------------
# persistence + install
# --------------------------------------------------------------------------

def _resolve_out(out: str) -> str:
    """An ``--out`` that names a directory (e.g. the cache dir) means its
    ``tuned.json``."""
    if os.path.isdir(out) or not out.endswith(".json"):
        return os.path.join(out, TUNED_BASENAME)
    return out


def save(doc: dict, out: str) -> str:
    """Merge-save ``doc`` into ``out`` (atomic replace).  An existing file
    for the same target keeps entries for slots this sweep did not touch;
    a different target's file is replaced wholesale (its entries are
    meaningless here)."""
    path = _resolve_out(out)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    merged = dict(doc)
    try:
        with open(path) as f:
            old = json.load(f)
        if old.get("version") == DOC_VERSION and \
                old.get("target") == doc.get("target"):
            new_keys = {(e["family"], e["layout"], e["backend"])
                        for e in doc["entries"]}
            keep = [e for e in old.get("entries", [])
                    if (e["family"], e["layout"], e["backend"])
                    not in new_keys]
            merged["entries"] = keep + list(doc["entries"])
    except (OSError, ValueError, KeyError, TypeError):
        pass
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(merged, f, indent=1, sort_keys=True)
    os.replace(tmp, path)
    return path


def install(path_or_doc) -> int:
    """Register a tuned.json's winners into the live plan-resolution
    overlay (``kernels.plan.register_tuned``); returns how many entries
    were installed.  Entries for a *different* device target -- or with
    empty overrides (defaults won) -- are skipped; a wrong-version doc
    installs nothing."""
    from ..kernels import plan as kplan
    doc = path_or_doc
    if not isinstance(doc, dict):
        with open(path_or_doc) as f:
            doc = json.load(f)
    if doc.get("version") != DOC_VERSION or \
            doc.get("target") != device_target():
        return 0
    n = 0
    for e in doc.get("entries", []):
        ov = e.get("overrides") or {}
        if not ov:
            continue
        kplan.register_tuned(e["family"], e["layout"], e["backend"], ov)
        n += 1
    return n


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="Sweep PIM Backend tunables per program family and "
                    "persist per-target winners (tuned.json)")
    ap.add_argument("--families", default=",".join(DEFAULT_FAMILIES),
                    help="comma-separated op:param specs "
                         "(default: %(default)s)")
    ap.add_argument("--layout", default="rows32",
                    choices=("rows32", "rows64"))
    ap.add_argument("--backend", default="ref",
                    choices=("ref", "pallas"))
    ap.add_argument("--rows", type=int, default=4096)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--quick", action="store_true",
                    help="tiny sweep (default + 2 candidates) for CI")
    ap.add_argument("--out", default=None,
                    help="tuned.json path, or a cache directory "
                         "(writes its tuned.json)")
    args = ap.parse_args(argv)

    families = [f.strip() for f in args.families.split(",") if f.strip()]
    doc = tune(families, layout=args.layout, backend=args.backend,
               rows=args.rows, reps=args.reps, quick=args.quick,
               log=lambda m: print(m, file=sys.stderr))
    for e in doc["entries"]:
        win = e["overrides"] or "(defaults kept)"
        print(f"{e['family']} [{e['layout']}/{e['backend']}]: {win}  "
              f"{e['us']:.0f}us vs default {e['default_us']:.0f}us")
    if args.out:
        path = save(doc, args.out)
        print(f"wrote {path}")
    else:
        json.dump(doc, sys.stdout, indent=1, sort_keys=True)
        print()
    return 0


if __name__ == "__main__":
    sys.exit(main())
