"""Gradient compression for the data-parallel all-reduce.

Error-feedback int8 quantization (1-bit-Adam family): each worker quantizes
(grad + residual) to int8 with a per-tensor scale, all-reduces the int8
payload (8x less ICI traffic than fp32 / 2x less than bf16), dequantizes,
and keeps the quantization error as residual for the next step.  Exposed as
a shard_map-compatible collective; used by the DDP-mode train step and unit
tested on a multi-device host mesh.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def init_residuals(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compressed_psum(grads, residuals, axis_name):
    """int8 error-feedback all-reduce over ``axis_name`` (inside shard_map).

    Protocol per tensor: (1) pmax the local absmax -> one shared fp32 scale
    (negligible traffic); (2) quantize (grad + residual) to int8 with that
    scale; (3) psum the int8 payload (int32 accumulation; wire traffic is
    the int8 tensor, 4x less than fp32); (4) dequantize the sum; residual
    keeps the local quantization error (error feedback preserves
    convergence).  Returns (mean-reduced grads, new residuals)."""
    n = jax.lax.psum(1, axis_name)

    def one(g, r):
        gf = g.astype(jnp.float32) + r
        scale = jax.lax.pmax(jnp.max(jnp.abs(gf)), axis_name) / 127.0 + 1e-12
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        new_r = gf - q.astype(jnp.float32) * scale      # error feedback
        s = jax.lax.psum(q.astype(jnp.int32), axis_name)
        red = s.astype(jnp.float32) * scale / n
        return red.astype(g.dtype), new_r

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = tdef.flatten_up_to(residuals)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return tdef.unflatten([o[0] for o in out]), \
        tdef.unflatten([o[1] for o in out])
