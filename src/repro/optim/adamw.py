"""AdamW from scratch (bf16 params, fp32 moments), cosine schedule,
global-norm clipping, decoupled weight decay."""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 \
        * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def init(params) -> Any:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def update(cfg: AdamWConfig, grads, state, params):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gn + 1e-9))
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * g * g
        u = (m2 / b1c) / (jnp.sqrt(v2 / b2c) + cfg.eps)
        decay = cfg.weight_decay if p.ndim >= 2 else 0.0
        p2 = p.astype(jnp.float32) * (1 - lr * decay) - lr * u
        return p2.astype(p.dtype), m2, v2

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(g, m, v, p)
           for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, \
        {"grad_norm": gn, "lr": lr}
