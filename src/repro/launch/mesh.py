"""Production mesh builders (functions, never module-level constants, so
importing this module never touches jax device state)."""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def dp_axes(mesh) -> tuple:
    """The batch / FSDP axes of a mesh ('pod' composes with 'data')."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def make_smoke_mesh():
    """1-device mesh for CPU smoke tests."""
    return jax.make_mesh((1, 1), ("data", "model"))
