"""Sharding rules: parameter FSDP x TP, batch DP, cache layouts.

Conventions (DESIGN.md §5):
  * ``model`` axis: tensor parallel -- attention heads / ffn hidden /
    experts / vocab.
  * ``data`` axis: batch + ZeRO-style full sharding of params & optimizer.
  * ``pod`` axis: extra data parallelism (params replicated across pods,
    gradients all-reduced over pod+data).
Rules are name-based over the parameter tree; stacked group params get a
leading replicated (scan) dimension automatically.
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from .mesh import dp_axes

FSDP = "data"       # params are fully sharded over the in-pod data axis


def _divisible(dim: int, mesh, axis) -> bool:
    if axis is None:
        return True
    size = int(np.prod([mesh.shape[a] for a in
                        (axis if isinstance(axis, tuple) else (axis,))]))
    return dim % size == 0


def _spec_for(path: str, shape, mesh, serve: bool = False,
              embed_d: bool = True) -> P:
    """TP/FSDP rule table, keyed on parameter path substrings.

    ``serve``: inference shardings -- TP only, weights replicated over the
    data axis (no per-token FSDP all-gathers; perf iteration A1).
    ``embed_d``: shard the embedding on d_model over (data, model) instead
    of vocab-sharding -- token gathers become device-local (iteration C3,
    fixes XLA "involuntary full rematerialization" on the vocab gather).
    """
    fsdp = None if serve else FSDP

    def pick(*axes):
        # drop axes that don't divide; keep rank aligned with shape
        out = []
        for dim, ax in zip(shape, axes):
            out.append(ax if _divisible(dim, mesh, ax) else None)
        return P(*out)

    if "embed" in path:
        if embed_d:
            return pick(None, (FSDP, "model") if not serve else "model")
        return pick("model", fsdp)
    if "lm_head" in path or "frontend" in path:
        return pick(fsdp, "model")
    if any(k in path for k in ("wq", "wk", "wv", "wg", "w_x", "w_gate",
                               "wq_a", "wq_b", "wkv_a", "wkv_b", "w1", "w3",
                               "ck", "cr", "wA", "w_in_gate", "w_rec_gate",
                               "router")):
        if len(shape) == 3:                       # MoE expert stacks [E,d,f]
            return pick("model", fsdp, None)
        if len(shape) == 2:
            return pick(fsdp, "model")
        return pick("model")                      # bias vectors
    if any(k in path for k in ("wo", "w2", "w_out", "cv", "wB")):
        if len(shape) == 3:
            return pick("model", None, fsdp)
        if len(shape) == 2:
            return pick("model", fsdp)
        return pick(fsdp)
    if "conv" in path:
        return pick(None, "model")
    # norms, scalars, gates, mu, lam, u, w0 ...
    return P(*([None] * len(shape)))


def param_shardings(mesh, params_shape: Any, *, serve: bool = False,
                    embed_d: bool = True):
    """Tree of NamedSharding matching a params (shape) tree."""
    def visit(path, leaf):
        pathstr = jax.tree_util.keystr(path)
        shape = leaf.shape
        stacked = "groups" in pathstr
        if stacked:
            spec = _spec_for(pathstr, shape[1:], mesh, serve, embed_d)
            spec = P(None, *spec)
        else:
            spec = _spec_for(pathstr, shape, mesh, serve, embed_d)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(visit, params_shape)


def opt_shardings(mesh, opt_shape: Any, pshard):
    """Optimizer state: m/v follow params; step replicated."""
    def visit(path, leaf):
        pathstr = jax.tree_util.keystr(path)
        if leaf.ndim == 0 or "step" in pathstr:
            return NamedSharding(mesh, P())
        stacked = "groups" in pathstr
        spec = _spec_for(pathstr, leaf.shape[1:] if stacked else leaf.shape,
                         mesh)
        if stacked:
            spec = P(None, *spec)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(visit, opt_shape)


def batch_shardings(mesh, batch_shape: Any, *, accum: bool = False):
    """Batch sharding over the DP axes.  With ``accum`` the leading dim is
    the (unsharded) gradient-accumulation axis and the batch dim is dim 1."""
    dp = dp_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))

    def visit(leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        bdim = 1 if (accum and leaf.ndim >= 2) else 0
        dims = [None] * leaf.ndim
        if leaf.shape[bdim] % dp_size == 0:
            dims[bdim] = dp
        return NamedSharding(mesh, P(*dims))

    return jax.tree.map(visit, batch_shape)


def cache_shardings(mesh, cache_shape: Any, *, batch: int,
                    seq_shard: bool = False):
    """KV/state caches: batch over DP when divisible, plus one dim over
    'model'.

    ``seq_shard`` (perf iteration A2): prefer the *sequence* dim (dim 1)
    for the model axis -- flash-decoding-style distributed attention where
    score partials are exchanged (MBs) instead of the cache being
    all-gathered (GBs).  Default/baseline: last divisible dim (head_dim /
    lora)."""
    dp = dp_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    msize = mesh.shape["model"]

    def visit(path, leaf):
        pathstr = jax.tree_util.keystr(path)
        shape = leaf.shape
        stacked = "groups" in pathstr
        core = shape[1:] if stacked else shape
        dims = [None] * len(core)
        if core and core[0] % dp_size == 0 and batch % dp_size == 0:
            dims[0] = dp
        cands = ([1] + list(reversed(range(2, len(core))))) if seq_shard             else list(reversed(range(1, len(core))))
        for cand in cands:
            if cand < len(core) and core[cand] % msize == 0:
                dims[cand] = "model"
                break
        spec = P(*([None] + dims if stacked else dims))
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(visit, cache_shape)


def install_activation_sharder(mesh):
    """Hook the model's with_sharding_constraint points to this mesh."""
    from ..models import layers as L
    from ..models import model as M
    dp = dp_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    L.set_moe_groups(dp_size)

    def sharder(tag, x):
        if tag == "moe_eo":
            dims = [None] * x.ndim
            if x.shape[0] % dp_size == 0:
                dims[0] = dp
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(*dims)))
        if tag == "moe_w":
            # experts stay EP-sharded on 'model'; FSDP axis gathered
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P("model", None, None)))
        if tag == "moe_buf":
            dims = [None] * x.ndim
            if x.shape[0] % dp_size == 0:
                dims[0] = dp
            if x.shape[1] % mesh.shape["model"] == 0:
                dims[1] = "model"
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(*dims)))
        if tag == "act" and x.shape[0] % int(
                np.prod([mesh.shape[a] for a in dp])) == 0:
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(dp, *([None] * (x.ndim - 1)))))
        if tag == "logits" and x.shape[-1] % mesh.shape["model"] == 0:
            dims = [None] * x.ndim
            if x.shape[0] % int(np.prod([mesh.shape[a] for a in dp])) == 0:
                dims[0] = dp
            dims[-1] = "model"
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(*dims)))
        return x

    M.set_activation_sharder(sharder)
