"""Batched serving driver: prefill + decode with KV caches.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --reduced \
        --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import registry
from ..models import model as M
from . import sharding as SH
from .steps import make_decode_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = registry.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    assert not cfg.encoder_only, "encoder-only archs have no decode"

    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev, 1), ("data", "model"))
    SH.install_activation_sharder(mesh)

    key = jax.random.PRNGKey(args.seed)
    params = M.init_model(cfg, key)
    max_seq = args.prompt_len + args.gen
    b = args.batch

    toks = jax.random.randint(key, (b, args.prompt_len), 0, cfg.vocab)
    vis = None
    batch = {"tokens": toks}
    if cfg.frontend == "vision":
        vis = jax.random.normal(key, (b, cfg.vision_seq, cfg.frontend_dim))
        batch["vision"] = vis

    # prefill: teacher-forced pass builds the caches at size prompt_len;
    # decode caches are pre-sized to max_seq, so we re-init + write
    caches = M.init_caches(cfg, b, max_seq)
    t0 = time.time()
    jdecode = jax.jit(make_decode_step(cfg))
    cur = toks[:, 0]
    out_toks = [cur]
    # teacher-force the prompt, then free-run
    for t in range(max_seq - 1):
        step_batch = {"token": cur, "pos": jnp.int32(t)}
        if vis is not None:
            step_batch["vision"] = vis
        nxt, logits, caches = jdecode(params, caches, step_batch)
        cur = toks[:, t + 1] if t + 1 < args.prompt_len else nxt
        out_toks.append(cur)
    dt = time.time() - t0
    gen = np.stack([np.asarray(t) for t in out_toks], axis=1)
    print(f"generated {b}x{max_seq} tokens in {dt:.2f}s "
          f"({b * max_seq / dt:.1f} tok/s incl. compile)")
    print("sample row:", gen[0].tolist())
    return gen


if __name__ == "__main__":
    main()
