"""Serving drivers.

Two services share this entry point:

* **LLM decode** (default): batched prefill + decode with KV caches.

      PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --reduced \
          --batch 4 --prompt-len 32 --gen 16

* **PIM ufunc API** (``--pim``): elementwise arithmetic requests served by
  the AritPIM machine through ``repro.pim_ufunc`` -- the chunked streaming
  executor with multi-device row sharding (DESIGN.md §8).  One-shot
  synthetic load:

      PYTHONPATH=src python -m repro.launch.serve --pim add \
          --pim-dtype uint32 --pim-rows 500000 --pim-requests 4

  or a JSON-lines request loop on stdin/stdout (one request object per
  line, one response per line):

      echo '{"op":"add","dtype":"uint16","x":[3,5],"y":[4,6]}' | \
          PYTHONPATH=src python -m repro.launch.serve --pim-stdin
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import registry
from ..models import model as M
from . import sharding as SH
from .steps import make_decode_step

# ---------------------------------------------------------------- PIM ufunc

_PIM_INT_OPS = ("add", "sub", "mul", "div")
_PIM_FP_OPS = ("fp_add", "fp_sub", "fp_mul", "fp_div")
_PIM_DTYPES = {"uint8": np.uint8, "uint16": np.uint16,
               "uint32": np.uint32, "uint64": np.uint64,
               "float16": np.float16, "float32": np.float32}


def _pim_encode(arr) -> list:
    """JSON-safe row list (Python ints/floats; object arrays of big ints)."""
    if arr.dtype.kind == "f":
        return [float(v) for v in arr]
    return [int(v) for v in arr]


def pim_request(req: dict) -> dict:
    """Serve one ufunc request.

    Request: ``{"op": add|sub|mul|div|fp_add|fp_sub|fp_mul|fp_div,
    "x": [...], "y": [...]}`` plus either ``"dtype"`` (uint8..64 /
    float16/float32) or ``"fmt"`` (bf16 etc., bit-pattern payloads), and
    optional ``"width"`` for explicit fixed-point widths.

    Response: ``{"op", "rows", "us"}`` with ``"result"`` (or ``"q"``/``"r"``
    for division).  Validation failures come back as ``{"error": ...}``.
    """
    from .. import pim_ufunc as pim
    try:
        op = req["op"]
        if op not in _PIM_INT_OPS + _PIM_FP_OPS:
            raise ValueError(f"unknown op {op!r}")
        fn = getattr(pim, op)
        kw = {}
        if req.get("fmt") is not None:
            kw["fmt"] = req["fmt"]
            dtype = None
        else:
            dtype = _PIM_DTYPES[req.get("dtype", "uint32")]
        if req.get("width") is not None:
            kw["width"] = int(req["width"])
        if req.get("schedule") is not None:
            kw["schedule"] = req["schedule"]    # slots / slots-static / dense
        x = np.asarray(req["x"], dtype)
        y = np.asarray(req["y"], dtype)
        t0 = time.perf_counter()
        out = fn(x, y, **kw)
        dt = time.perf_counter() - t0
        resp = {"op": op, "rows": int(x.size),
                "us": round(dt * 1e6, 1)}
        if op == "div":
            resp["q"], resp["r"] = _pim_encode(out[0]), _pim_encode(out[1])
        else:
            resp["result"] = _pim_encode(out)
        return resp
    except (KeyError, TypeError, ValueError, OverflowError) as e:
        return {"error": f"{type(e).__name__}: {e}"}


def serve_pim_stdin(inp=None, outp=None) -> int:
    """JSON-lines loop: one request per input line, one response per output
    line.  Blank lines are skipped; malformed JSON yields an error line."""
    inp = sys.stdin if inp is None else inp
    outp = sys.stdout if outp is None else outp
    served = 0
    for line in inp:
        line = line.strip()
        if not line:
            continue
        try:
            req = json.loads(line)
        except json.JSONDecodeError as e:
            resp = {"error": f"JSONDecodeError: {e}"}
        else:
            resp = pim_request(req)
        print(json.dumps(resp, sort_keys=True), file=outp, flush=True)
        served += 1
    return served


def serve_pim_synthetic(args) -> dict:
    """One-shot synthetic load: ``--pim-requests`` rounds of ``--pim-rows``
    random rows through the streaming/sharded executor; prints rows/s."""
    from .. import pim_ufunc as pim
    op = args.pim
    rng = np.random.default_rng(args.seed)
    n = args.pim_rows
    dtype = _PIM_DTYPES[args.pim_dtype]
    is_float = np.dtype(dtype).kind == "f"
    if (op in _PIM_FP_OPS) != is_float:
        sys.exit(f"error: --pim {op} requires --pim-dtype "
                 f"{'float16/float32' if op in _PIM_FP_OPS else 'uint8..64'}"
                 f" (got {args.pim_dtype})")
    if op in _PIM_FP_OPS:
        from ..core.floatfmt import FORMATS
        fmt = {np.float16: FORMATS["fp16"],
               np.float32: FORMATS["fp32"]}[dtype]
        mid = fmt.bias
        x = fmt.random_bits(rng, n, emin=mid - 2, emax=mid + 2)
        y = fmt.random_bits(rng, n, emin=mid - 2, emax=mid + 2)
        vw = {np.float16: np.uint16, np.float32: np.uint32}[dtype]
        x = x.astype(vw).view(dtype)
        y = y.astype(vw).view(dtype)
    else:
        width = np.dtype(dtype).itemsize * 8
        hi = 1 << min(width, 63)
        x = rng.integers(0, hi, n).astype(dtype)
        lo = 1 if op == "div" else 0
        y = rng.integers(lo, hi, n).astype(dtype)
    fn = getattr(pim, op)
    fn(x[:256], y[:256])                     # compile outside the timing
    t0 = time.perf_counter()
    for _ in range(args.pim_requests):
        fn(x, y)
    dt = time.perf_counter() - t0
    total = n * args.pim_requests
    rate = total / dt if dt > 0 else float("nan")
    n_dev = len(jax.devices())
    print(f"pim.{op} [{args.pim_dtype}]: {args.pim_requests} requests x "
          f"{n} rows on {n_dev} device(s) in {dt:.3f}s = {rate:,.0f} rows/s")
    return {"op": op, "rows": total, "seconds": dt, "rows_per_s": rate}


# ---------------------------------------------------------------- LLM decode

def serve_llm(args):
    cfg = registry.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    assert not cfg.encoder_only, "encoder-only archs have no decode"

    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev, 1), ("data", "model"))
    SH.install_activation_sharder(mesh)

    key = jax.random.PRNGKey(args.seed)
    params = M.init_model(cfg, key)
    max_seq = args.prompt_len + args.gen
    b = args.batch

    toks = jax.random.randint(key, (b, args.prompt_len), 0, cfg.vocab)
    vis = None
    batch = {"tokens": toks}
    if cfg.frontend == "vision":
        vis = jax.random.normal(key, (b, cfg.vision_seq, cfg.frontend_dim))
        batch["vision"] = vis

    # prefill: teacher-forced pass builds the caches at size prompt_len;
    # decode caches are pre-sized to max_seq, so we re-init + write
    caches = M.init_caches(cfg, b, max_seq)
    t0 = time.perf_counter()
    jdecode = jax.jit(make_decode_step(cfg))
    cur = toks[:, 0]
    out_toks = [cur]
    # teacher-force the prompt, then free-run
    for t in range(max_seq - 1):
        step_batch = {"token": cur, "pos": jnp.int32(t)}
        if vis is not None:
            step_batch["vision"] = vis
        nxt, logits, caches = jdecode(params, caches, step_batch)
        cur = toks[:, t + 1] if t + 1 < args.prompt_len else nxt
        out_toks.append(cur)
    dt = time.perf_counter() - t0
    gen = np.stack([np.asarray(t) for t in out_toks], axis=1)
    print(f"generated {b}x{max_seq} tokens in {dt:.2f}s "
          f"({b * max_seq / dt:.1f} tok/s incl. compile)")
    print("sample row:", gen[0].tolist())
    return gen


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--pim", metavar="OP", choices=_PIM_INT_OPS + _PIM_FP_OPS,
                    help="serve the PIM ufunc API with synthetic load "
                         "instead of LLM decode")
    ap.add_argument("--pim-stdin", action="store_true",
                    help="serve PIM ufunc requests as JSON lines on stdin")
    ap.add_argument("--pim-rows", type=int, default=1 << 20)
    ap.add_argument("--pim-requests", type=int, default=4)
    ap.add_argument("--pim-dtype", default="uint32",
                    choices=sorted(_PIM_DTYPES))
    from ..kernels.ops import SCHEDULES
    ap.add_argument("--pim-schedule", default=None, choices=SCHEDULES,
                    help="executor schedule mode (default: the ufunc "
                         "config default, i.e. the contiguous-slot scan "
                         "executors)")
    args = ap.parse_args(argv)

    if args.pim_schedule:
        from .. import pim_ufunc as pim
        pim.configure(schedule=args.pim_schedule)
    if args.pim_stdin:
        return serve_pim_stdin()
    if args.pim:
        return serve_pim_synthetic(args)
    return serve_llm(args)


if __name__ == "__main__":
    main()
