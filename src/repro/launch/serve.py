"""Serving drivers.

Two services share this entry point:

* **LLM decode** (default): batched prefill + decode with KV caches.

      PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --reduced \
          --batch 4 --prompt-len 32 --gen 16

* **PIM ufunc API** (``--pim``): elementwise arithmetic requests served by
  the AritPIM machine through ``repro.pim_ufunc`` -- the chunked streaming
  executor with multi-device row sharding (DESIGN.md §8).  One-shot
  synthetic load:

      PYTHONPATH=src python -m repro.launch.serve --pim add \
          --pim-dtype uint32 --pim-rows 500000 --pim-requests 4

  or a JSON-lines request loop on stdin/stdout (one request object per
  line, one response per line):

      echo '{"op":"add","dtype":"uint16","x":[3,5],"y":[4,6]}' | \
          PYTHONPATH=src python -m repro.launch.serve --pim-stdin

  ``--pim-serve`` is the batched variant of the same protocol: requests
  admitted within a micro-batching window (``--pim-window-ms``, row cap
  ``--pim-max-batch-rows``) are grouped by compiled-program structure and
  each group executes as one packed state (``runtime/pim_batch.py``,
  DESIGN.md §10).  Responses keep input order; a stats line goes to
  stderr at end of stream.
"""

from __future__ import annotations

import argparse
import json
import sys
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import registry
from ..models import model as M
from . import sharding as SH
from .steps import make_decode_step

# ---------------------------------------------------------------- PIM ufunc

_PIM_INT_OPS = ("add", "sub", "mul", "div")
_PIM_FP_OPS = ("fp_add", "fp_sub", "fp_mul", "fp_div")
_PIM_DTYPES = {"uint8": np.uint8, "uint16": np.uint16,
               "uint32": np.uint32, "uint64": np.uint64,
               "float16": np.float16, "float32": np.float32}


def _pim_encode(arr) -> list:
    """JSON-safe row list (Python ints/floats; object arrays of big ints)."""
    if arr.dtype.kind == "f":
        return [float(v) for v in arr]
    return [int(v) for v in arr]


# Parse/validation failures a request line can produce (anything else is a
# server bug and should propagate).
_PIM_REQ_ERRORS = (KeyError, TypeError, ValueError, OverflowError)


def _err(code: str, message: str, retriable: bool) -> dict:
    """Structured wire-format error body (DESIGN.md §12): every failed
    request gets a machine-readable ``code``, the human message, and
    whether retrying the same request could succeed.  Codes: ``bad_json``
    and ``bad_request`` (non-retriable -- the request itself is broken),
    ``overloaded`` (admission backpressure), ``deadline_exceeded``,
    ``exec_failed`` (faults exhausted retries), ``internal``."""
    return {"error": {"code": code, "message": message,
                      "retriable": retriable}}


def _pim_prepare_request(req: dict):
    """Parse + validate one JSON request into a ``pim_ufunc.Prepared``
    program handle (raises on malformed requests).

    Request: ``{"op": add|sub|mul|div|fp_add|fp_sub|fp_mul|fp_div,
    "x": [...], "y": [...]}`` plus either ``"dtype"`` (uint8..64 /
    float16/float32) or ``"fmt"`` (bf16 etc., bit-pattern payloads),
    optional ``"width"`` for explicit fixed-point widths, ``"schedule"``
    (slots / slots-static / dense) and ``"layout"`` (rows32 / rows64 --
    the packed word layout; all exec-config keys land in the request's
    ExecPlan, so mixed-config traffic never coalesces wrongly).

    Compound requests (DESIGN.md §13): ``{"op": "expr", "expr":
    ["add", ["mul", "a", "b"], "c"], "inputs": {"a": [...], ...}}`` --
    the nested-list expression (leaves are input names, interior nodes
    ``[op, lhs, rhs]`` over the fusable ops) lowers through
    ``pim_ufunc.fuse`` into **one** compiled program; one ``dtype`` /
    ``fmt`` / ``width`` applies to every leaf.
    """
    from .. import pim_ufunc as pim
    op = req["op"]
    if op != "expr" and op not in _PIM_INT_OPS + _PIM_FP_OPS:
        raise ValueError(f"unknown op {op!r}")
    kw = {}
    if req.get("fmt") is not None:
        kw["fmt"] = req["fmt"]
        dtype = None
    else:
        dtype = _PIM_DTYPES[req.get("dtype", "uint32")]
    if req.get("width") is not None:
        kw["width"] = int(req["width"])
    for key in ("schedule", "layout"):
        if req.get(key) is not None:
            kw[key] = req[key]
    if op == "expr":
        return _pim_prepare_expr(req, dtype, kw)
    x = np.asarray(req["x"], dtype)
    y = np.asarray(req["y"], dtype)
    return pim.prepare(op, x, y, **kw)


def _pim_prepare_expr(req: dict, dtype, kw: dict):
    """Lower an ``"expr"`` request into one fused ``Prepared`` handle."""
    from .. import pim_ufunc as pim
    inputs = req["inputs"]
    if not isinstance(inputs, dict) or not inputs:
        raise ValueError('"expr" requests need a non-empty "inputs" map')
    width = kw.pop("width", None)
    fmt = kw.pop("fmt", None)
    leaves: dict = {}

    def build(node):
        if isinstance(node, str):
            leaf = leaves.get(node)
            if leaf is None:
                if node not in inputs:
                    raise KeyError(f'expr leaf {node!r} not in "inputs"')
                leaf = leaves[node] = pim.lazy(
                    np.asarray(inputs[node], dtype), width=width, fmt=fmt)
            return leaf
        if (not isinstance(node, (list, tuple)) or len(node) != 3
                or not isinstance(node[0], str)):
            raise ValueError(
                f"expr nodes are [op, lhs, rhs] or input names, got "
                f"{node!r}")
        nop = node[0]
        if nop not in pim.LAZY_OPS:
            raise ValueError(f"op {nop!r} does not fuse "
                             f"(fusable: {', '.join(pim.LAZY_OPS)})")
        return getattr(pim, nop)(build(node[1]), build(node[2]))

    return pim.fuse(build(req["expr"]), **kw)


def _pim_attach_result(resp: dict, op: str, out) -> dict:
    if op == "div":
        resp["q"], resp["r"] = _pim_encode(out[0]), _pim_encode(out[1])
    else:
        resp["result"] = _pim_encode(out)
    return resp


def pim_request(req: dict) -> dict:
    """Serve one ufunc request (see :func:`_pim_prepare_request` for the
    request schema).

    Response: ``{"op", "rows", "us", "cached"}`` with ``"result"`` (or
    ``"q"``/``"r"`` for division).  ``us`` is the execution latency only:
    when the program structure was not yet compiled (``cached: false``),
    first-call compilation -- levelize, schedule lowering, executor jit,
    measured by a discarded warm-up row -- is reported separately as
    ``compile_us``, so serving latency numbers stay honest.  Failures come
    back as structured ``{"error": {"code", "message", "retriable"}}``
    bodies (see :func:`_err`).
    """
    from ..runtime.pim_batch import classify_error
    try:
        prep = _pim_prepare_request(req)
    except _PIM_REQ_ERRORS as e:
        return _err("bad_request", f"{type(e).__name__}: {e}", False)
    try:
        cached = prep.cached
        resp = {"op": prep.op, "rows": int(prep.n_rows),
                "cached": bool(cached)}
        if getattr(prep, "fused_ops", 1) > 1:
            resp["fused_ops"] = int(prep.fused_ops)
        if not cached and prep.n_rows:
            t0 = time.perf_counter()
            prep.warm()
            resp["compile_us"] = round((time.perf_counter() - t0) * 1e6, 1)
        t0 = time.perf_counter()
        out = prep.run()
        resp["us"] = round((time.perf_counter() - t0) * 1e6, 1)
        return _pim_attach_result(resp, prep.op, out)
    except Exception as e:                  # noqa: BLE001 -- keep serving
        return classify_error(e)


def serve_pim_stdin(inp=None, outp=None) -> int:
    """JSON-lines loop: one request per input line, one response per output
    line.  Blank lines are skipped; malformed JSON yields a structured
    ``bad_json`` error line."""
    inp = sys.stdin if inp is None else inp
    outp = sys.stdout if outp is None else outp
    served = 0
    for line in inp:
        line = line.strip()
        if not line:
            continue
        try:
            req = json.loads(line)
        except json.JSONDecodeError as e:
            resp = _err("bad_json", f"JSONDecodeError: {e}", False)
        else:
            resp = pim_request(req)
        print(json.dumps(resp, sort_keys=True), file=outp, flush=True)
        served += 1
    return served


def serve_pim_batched(inp=None, outp=None, *, window_ms: float = 2.0,
                      max_batch_rows: int = 1 << 16, pin_cap: int = 32,
                      max_queue_rows=None, deadline_ms=None,
                      heartbeat=None, stats: bool = True,
                      breaker="default",
                      scrub_interval_ms: float = 250.0,
                      stats_interval_ms: float = 0.0,
                      metrics_file=None, trace_file=None,
                      cache_dir=None) -> dict:
    """Batched JSON-lines loop (``--pim-serve``): same request/response
    protocol as :func:`serve_pim_stdin`, but requests admitted within one
    micro-batching window coalesce by compiled-program structure and each
    group executes as one packed state (``runtime/pim_batch.py``).

    A reader thread parses and validates lines into program handles while
    the main loop executes the previous batch, so admission overlaps
    execution.  Responses keep input order (batches are consecutive spans
    of the input).  Per-request accounting: ``us`` (admission to response,
    the end-to-end latency), ``queue_us`` (time spent waiting for the
    window), ``exec_us`` (the batch's shared pipelined execution time),
    ``batched`` (requests coalesced into this request's group), and
    ``cached``.  At end of stream a stats summary line goes to stderr.

    Hardening (DESIGN.md §12): ``max_queue_rows`` bounds the admission
    backlog -- a request past the cap gets a retriable ``overloaded``
    error instead of growing the queue (the reader never blocks, the
    executor never deadlocks).  ``deadline_ms`` (per-request override:
    ``"deadline_ms"`` in the request) expires requests still queued or
    mid-execution past their budget.  Every failure is a structured
    ``{"error": {"code", "message", "retriable"}}``; a request that fell
    out of group execution carries ``"degraded": true``; a batch that saw
    fault-tolerance activity attaches its drained ``"health"`` counters.
    ``heartbeat`` names a liveness file beaten once per batch.

    Circuit breakers (DESIGN.md §14): per-program-family breakers in the
    runtime trip on sustained retriable failures (faults exhausting
    retries, deadline misses -- including expiry in the queue); tripped
    families are shed to the numpy oracle (correct, ``degraded+shed``,
    never dropped) until half-open probes succeed.  ``breaker`` is a
    ``runtime.pim_batch.BreakerPolicy``, None to disable, or ``"default"``.
    Trip/probe/close counts land in the stats line and the returned dict.
    When the active ufunc config injects faults (``pim.options(faults=...)``
    around this call, e.g. the ``--pim-fault-*`` flags), a background
    :class:`~repro.runtime.faults.Scrubber` re-scans quarantined spans
    every ``scrub_interval_ms`` for the lifetime of the loop; its media
    counters come back under ``"media"``.

    Telemetry (DESIGN.md §15): ``stats_interval_ms > 0`` emits a periodic
    ``{"type": "stats", ...}`` JSON line to stderr (at most once per
    interval, evaluated per batch) with p50/p99 queue-wait and batch-exec
    latency, batch row occupancy, and the compiled-program cache hit
    rate.  ``metrics_file`` keeps a Prometheus-style text exposition of
    the runtime's metrics (plus the process-global health/cache/model
    counters) refreshed at the same cadence and at shutdown.
    ``trace_file`` enables the pipeline tracer for the lifetime of the
    loop and writes the span buffer as Chrome-trace JSON at shutdown.
    With ``stats=True`` the shutdown stats also emit as one
    machine-parseable ``{"type": "summary", ...}`` JSON stderr line next
    to the historical human one.

    Warm starts (DESIGN.md §16): ``cache_dir`` installs the persistent
    compiled-artifact cache (``runtime.artifact_cache``) for the lifetime
    of the process, preloads every provenance-bearing schedule + AOT
    executable from disk before the first request (a ``{"type":
    "warm_start", ...}`` stderr line reports what loaded and how long it
    took), and auto-installs any ``tuned.json`` the autotuner persisted
    beside it.  A replica restarted against a populated cache directory
    then serves its hot programs with zero recompiles -- the
    ``cache.levelized`` counter in the summary line stays 0.
    """
    from ..runtime import pim_batch, telemetry
    from ..runtime.fault_tolerance import Heartbeat, StragglerMonitor
    from ..runtime.faults import FaultModel, Scrubber, drain_media_health
    inp = sys.stdin if inp is None else inp
    outp = sys.stdout if outp is None else outp
    if cache_dir:
        from .. import pim_ufunc as pim
        t_warm = time.perf_counter()
        pim.configure(cache_dir=str(cache_dir))
        pim._ensure_artifact_cache()        # install + tuned.json now
        counts = pim.kops.artifact_cache().warm()
        print(json.dumps(
            {"type": "warm_start", "dir": str(cache_dir), **counts,
             "us": round((time.perf_counter() - t_warm) * 1e6, 1)},
            sort_keys=True), file=sys.stderr, flush=True)
    q = pim_batch.BatchQueue(window_ms=window_ms,
                             max_batch_rows=max_batch_rows,
                             max_queue_rows=max_queue_rows)
    # Bound before the reader thread starts -- its closure reads `tracer`.
    tracer = telemetry.TRACER
    trace_prev = None
    if trace_file:
        trace_prev, tracer.enabled = tracer.enabled, True

    def _admit():
        try:
            for line in inp:
                line = line.strip()
                if not line:
                    continue
                t_admit = time.perf_counter()
                try:
                    req = json.loads(line)
                except json.JSONDecodeError as e:
                    q.put((_err("bad_json", f"JSONDecodeError: {e}", False),
                           None, t_admit, None))
                    continue
                try:
                    prep = _pim_prepare_request(req)
                    dl_ms = req.get("deadline_ms", deadline_ms) \
                        if isinstance(req, dict) else deadline_ms
                    dl = None if dl_ms is None \
                        else time.monotonic() + float(dl_ms) * 1e-3
                except _PIM_REQ_ERRORS as e:
                    q.put((_err("bad_request", f"{type(e).__name__}: {e}",
                                False), None, t_admit, None))
                except Exception as e:      # noqa: BLE001 -- keep serving
                    q.put((_err("internal", f"{type(e).__name__}: {e}",
                                True), None, t_admit, None))
                else:
                    tracer.event("prepare", t_admit, time.perf_counter(),
                                 cat="pim.serve", rows=int(prep.n_rows))
                    if not q.offer((None, prep, t_admit, dl),
                                   n_rows=prep.n_rows):
                        # backpressure: ordered, structured, retriable --
                        # the rejection itself rides the queue rowless
                        q.put((_err(
                            "overloaded",
                            f"admission queue full ({prep.n_rows} rows "
                            f"would exceed max_queue_rows="
                            f"{q.max_queue_rows})", True),
                            None, t_admit, None))
        except Exception:   # noqa: BLE001 -- input stream died mid-read:
            pass            # treat as EOF; admitted requests still serve
        finally:
            q.close()

    threading.Thread(target=_admit, daemon=True).start()
    if breaker == "default":
        runtime = pim_batch.BatchRuntime(pin_cap=pin_cap)
    else:
        runtime = pim_batch.BatchRuntime(pin_cap=pin_cap, breaker=breaker)

    def _rps() -> float:
        v = runtime.stats.rows_per_s()
        return round(v, 1) if v == v else 0.0   # NaN-free strict JSON

    def _cache_section() -> dict:
        reg = telemetry.REGISTRY
        hits = int(reg.counter("pim.cache.hits"))
        misses = int(reg.counter("pim.cache.misses"))
        total = hits + misses
        return {"hits": hits, "misses": misses,
                "evictions": int(reg.counter("pim.cache.evictions")),
                "hit_rate": round(hits / total, 4) if total else 0.0,
                # disk tier (DESIGN.md §16): artifact loads/stores plus
                # the fresh-levelize count a warm start drives to zero
                "levelized": int(reg.counter("pim.cache.levelized")),
                "disk_hits": int(reg.counter("pim.cache.disk_hits")),
                "disk_misses": int(reg.counter("pim.cache.disk_misses")),
                "disk_writes": int(reg.counter("pim.cache.disk_writes")),
                "disk_errors": int(reg.counter("pim.cache.disk_errors")),
                "disk_evictions":
                    int(reg.counter("pim.cache.disk_evictions"))}

    def _hist_section() -> dict:
        out = {}
        for short, name in (("queue_us", "pim.serve.queue_us"),
                            ("request_us", "pim.serve.request_us"),
                            ("exec_us", "pim.batch.exec_us"),
                            ("occupancy_rows", "pim.batch.occupancy_rows"),
                            ("group_size", "pim.batch.group_size")):
            s = runtime.metrics.summary(name)
            if s is not None:
                out[short] = s
        return out

    def _write_metrics_file() -> None:
        if not metrics_file:
            return
        with open(metrics_file, "w") as f:
            f.write(telemetry.render_prometheus(telemetry.REGISTRY,
                                                runtime.metrics))

    mon = StragglerMonitor(window=64, threshold=4.0)
    hb = Heartbeat(heartbeat, interval_s=0.0) if heartbeat else None
    if hb:
        hb.beat(0)                          # liveness from startup
    from .. import pim_ufunc as pim
    scrubber = None
    if isinstance(pim.config.faults, FaultModel) and scrub_interval_ms > 0:
        scrubber = Scrubber(pim.config.faults,
                            interval_s=scrub_interval_ms * 1e-3).start()
    served = 0
    last_emit = 0.0             # first qualifying batch always emits
    try:
        while (batch := q.collect()) is not None:
            t_plan = time.perf_counter()
            now = time.monotonic()
            responses: dict = {}
            live = []
            for i, (err, prep, t_admit, dl) in enumerate(batch):
                if err is not None:
                    responses[i] = err
                    if err["error"]["code"] == "overloaded":
                        runtime.stats.add("rejected")
                elif dl is not None and now > dl:
                    responses[i] = _err(
                        "deadline_exceeded",
                        f"request expired in queue ({prep.n_rows} rows)",
                        True)
                    runtime.stats.add("expired")
                    runtime.record_expired(prep)
                else:
                    tracer.event("enqueue", t_admit, t_plan,
                                 cat="pim.serve", rows=int(prep.n_rows))
                    live.append((i, prep, t_admit, dl))
            try:
                results = runtime.execute(
                    [p for _, p, _, _ in live],
                    deadlines=[dl for _, _, _, dl in live])
            except Exception as e:          # noqa: BLE001 -- server bug:
                body = pim_batch.classify_error(e)  # answer, keep serving
                results = None
                for i, prep, t_admit, dl in live:
                    responses[i] = body
            t_done = time.perf_counter()
            if results is not None:
                for (i, prep, t_admit, dl), r in zip(live, results):
                    # per-request latency histograms: queue wait (admit ->
                    # batch start) and end-to-end (admit -> response) --
                    # the p50/p99 the periodic stats lines summarize
                    runtime.metrics.observe_many({
                        "pim.serve.queue_us": (t_plan - t_admit) * 1e6,
                        "pim.serve.request_us": (t_done - t_admit) * 1e6})
                    if r.error is not None:
                        responses[i] = {"error": r.error}
                        continue
                    resp = {"op": prep.op, "rows": int(prep.n_rows),
                            "us": round((t_done - t_admit) * 1e6, 1),
                            "queue_us": round((t_plan - t_admit) * 1e6, 1),
                            "exec_us": round(r.exec_us, 1),
                            "batched": r.group_size, "cached": bool(r.cached)}
                    if getattr(prep, "fused_ops", 1) > 1:
                        resp["fused_ops"] = int(prep.fused_ops)
                    if r.degraded:
                        resp["degraded"] = True
                    if r.shed:
                        resp["shed"] = True
                    if r.health:
                        resp["health"] = r.health
                    responses[i] = _pim_attach_result(resp, prep.op, r.value)
            if mon.record(runtime.stats.batches, t_done - t_plan):
                runtime.stats.add("stragglers")
            if hb:
                hb.beat(runtime.stats.batches)
            runtime.stats.add("errors", sum(
                1 for r in responses.values() if "error" in r))
            for i in range(len(batch)):
                print(json.dumps(responses[i], sort_keys=True), file=outp,
                      flush=True)
            served += len(batch)
            if stats_interval_ms > 0 and \
                    (t_done - last_emit) * 1e3 >= stats_interval_ms:
                last_emit = t_done
                st = runtime.stats
                print(json.dumps(
                    {"type": "stats", "served": served,
                     "requests": st.requests, "batches": st.batches,
                     "groups": st.groups, "rows": st.rows,
                     "errors": st.errors, "shed": st.shed_requests,
                     "rows_per_s": _rps(),
                     "latency": _hist_section(),
                     "cache": _cache_section()},
                    sort_keys=True), file=sys.stderr, flush=True)
                _write_metrics_file()
    finally:
        pinned = len(runtime.pins)
        runtime.close()
        if scrubber is not None:
            scrubber.stop()
        if trace_file:
            tracer.write_chrome_trace(trace_file)
            tracer.enabled = trace_prev
    st = runtime.stats
    media = drain_media_health()
    if stats:
        line = st.summary(pinned=pinned)
        if media:
            line += (f", media={media.get('scrub_passes', 0)} scrubs/"
                     f"{media.get('spans_reclaimed', 0)} reclaimed/"
                     f"{media.get('spans_still_bad', 0)} still-bad")
        print(line, file=sys.stderr)
        # the machine-parseable twin of the human line: every Stats field
        # plus the histogram summaries and the media/cache sections
        print(json.dumps(
            {"type": "summary", "served": served, "pinned": pinned,
             **st.as_dict(), "rows_per_s": _rps(),
             "latency": _hist_section(), "cache": _cache_section(),
             "media": media}, sort_keys=True), file=sys.stderr, flush=True)
    _write_metrics_file()
    return {"served": served, "batches": st.batches, "groups": st.groups,
            "rows": st.rows, "errors": st.errors, "pinned": pinned,
            "fused_programs": st.fused_programs,
            "rows_per_s": st.rows_per_s(), "rejected": st.rejected,
            "expired": st.expired, "degraded_groups": st.degraded_groups,
            "faults_detected": st.faults_detected,
            "faults_corrected": st.faults_corrected,
            "retries": st.retries, "remapped_rows": st.remapped_rows,
            "stragglers": st.stragglers,
            "breaker_trips": st.breaker_trips,
            "breaker_probes": st.breaker_probes,
            "breaker_closes": st.breaker_closes,
            "shed_requests": st.shed_requests,
            "media": media}


def serve_pim_synthetic(args) -> dict:
    """One-shot synthetic load: ``--pim-requests`` rounds of ``--pim-rows``
    random rows through the streaming/sharded executor; prints rows/s."""
    from .. import pim_ufunc as pim
    op = args.pim
    rng = np.random.default_rng(args.seed)
    n = args.pim_rows
    dtype = _PIM_DTYPES[args.pim_dtype]
    is_float = np.dtype(dtype).kind == "f"
    if (op in _PIM_FP_OPS) != is_float:
        sys.exit(f"error: --pim {op} requires --pim-dtype "
                 f"{'float16/float32' if op in _PIM_FP_OPS else 'uint8..64'}"
                 f" (got {args.pim_dtype})")
    if op in _PIM_FP_OPS:
        from ..core.floatfmt import FORMATS
        fmt = {np.float16: FORMATS["fp16"],
               np.float32: FORMATS["fp32"]}[dtype]
        mid = fmt.bias
        x = fmt.random_bits(rng, n, emin=mid - 2, emax=mid + 2)
        y = fmt.random_bits(rng, n, emin=mid - 2, emax=mid + 2)
        vw = {np.float16: np.uint16, np.float32: np.uint32}[dtype]
        x = x.astype(vw).view(dtype)
        y = y.astype(vw).view(dtype)
    else:
        width = np.dtype(dtype).itemsize * 8
        hi = 1 << min(width, 63)
        x = rng.integers(0, hi, n).astype(dtype)
        lo = 1 if op == "div" else 0
        y = rng.integers(lo, hi, n).astype(dtype)
    fn = getattr(pim, op)
    fn(x[:256], y[:256])                     # compile outside the timing
    t0 = time.perf_counter()
    for _ in range(args.pim_requests):
        fn(x, y)
    dt = time.perf_counter() - t0
    total = n * args.pim_requests
    rate = total / dt if dt > 0 else float("nan")
    n_dev = len(jax.devices())
    print(f"pim.{op} [{args.pim_dtype}]: {args.pim_requests} requests x "
          f"{n} rows on {n_dev} device(s) in {dt:.3f}s = {rate:,.0f} rows/s")
    if getattr(args, "json", None):
        # one row in the benchmarks/run.py --json / --compare format, so
        # serving runs participate in the perf-regression gate
        doc = {"meta": {"suite": "aritpim-repro",
                        "tier1": "repro.launch.serve"},
               "rows": [{"name": f"serve/{op}_{args.pim_dtype}_synthetic",
                         "us_per_call": round(dt * 1e6 / args.pim_requests,
                                              3),
                         "rows_per_s": round(rate),
                         "rows": n, "requests": args.pim_requests,
                         "n_devices": n_dev}]}
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
            f.write("\n")
    return {"op": op, "rows": total, "seconds": dt, "rows_per_s": rate}


# ---------------------------------------------------------------- LLM decode

def serve_llm(args):
    cfg = registry.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    assert not cfg.encoder_only, "encoder-only archs have no decode"

    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev, 1), ("data", "model"))
    SH.install_activation_sharder(mesh)

    key = jax.random.PRNGKey(args.seed)
    params = M.init_model(cfg, key)
    max_seq = args.prompt_len + args.gen
    b = args.batch

    toks = jax.random.randint(key, (b, args.prompt_len), 0, cfg.vocab)
    vis = None
    batch = {"tokens": toks}
    if cfg.frontend == "vision":
        vis = jax.random.normal(key, (b, cfg.vision_seq, cfg.frontend_dim))
        batch["vision"] = vis

    # prefill: teacher-forced pass builds the caches at size prompt_len;
    # decode caches are pre-sized to max_seq, so we re-init + write
    caches = M.init_caches(cfg, b, max_seq)
    t0 = time.perf_counter()
    jdecode = jax.jit(make_decode_step(cfg))
    cur = toks[:, 0]
    out_toks = [cur]
    # teacher-force the prompt, then free-run
    for t in range(max_seq - 1):
        step_batch = {"token": cur, "pos": jnp.int32(t)}
        if vis is not None:
            step_batch["vision"] = vis
        nxt, logits, caches = jdecode(params, caches, step_batch)
        cur = toks[:, t + 1] if t + 1 < args.prompt_len else nxt
        out_toks.append(cur)
    dt = time.perf_counter() - t0
    gen = np.stack([np.asarray(t) for t in out_toks], axis=1)
    print(f"generated {b}x{max_seq} tokens in {dt:.2f}s "
          f"({b * max_seq / dt:.1f} tok/s incl. compile)")
    print("sample row:", gen[0].tolist())
    return gen


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--pim", metavar="OP", choices=_PIM_INT_OPS + _PIM_FP_OPS,
                    help="serve the PIM ufunc API with synthetic load "
                         "instead of LLM decode")
    ap.add_argument("--pim-stdin", action="store_true",
                    help="serve PIM ufunc requests as JSON lines on stdin "
                         "(one program execution per request)")
    ap.add_argument("--pim-serve", action="store_true",
                    help="batched JSON-lines serving: coalesce requests "
                         "that share a program structure inside a "
                         "micro-batching window (runtime/pim_batch)")
    ap.add_argument("--pim-window-ms", type=float, default=2.0,
                    help="batching window after the first admitted "
                         "request (--pim-serve; 0 = only what is queued)")
    ap.add_argument("--pim-max-batch-rows", type=int, default=1 << 16,
                    help="row cap per admission batch (--pim-serve)")
    ap.add_argument("--pim-pin-cap", type=int, default=32,
                    help="LRU-pinned working set of compiled schedules "
                         "(--pim-serve; 0 disables pinning)")
    ap.add_argument("--pim-max-queue-rows", type=int, default=0,
                    help="admission backlog cap in rows (--pim-serve); "
                         "past it requests get a retriable 'overloaded' "
                         "error (0 = unbounded)")
    ap.add_argument("--pim-deadline-ms", type=float, default=None,
                    help="default per-request deadline (--pim-serve); a "
                         "request's own 'deadline_ms' key overrides")
    ap.add_argument("--pim-heartbeat", metavar="PATH", default=None,
                    help="liveness file beaten once per batch "
                         "(--pim-serve; runtime/fault_tolerance.Heartbeat)")
    ap.add_argument("--pim-no-breaker", action="store_true",
                    help="disable the per-program-family circuit breakers "
                         "(--pim-serve; DESIGN.md §14)")
    ap.add_argument("--pim-breaker-failures", type=int, default=None,
                    help="retriable failures in the window that trip a "
                         "family's breaker (--pim-serve; default 4)")
    ap.add_argument("--pim-breaker-cooldown-ms", type=float, default=None,
                    help="open-state cooldown before half-open probes "
                         "(--pim-serve; default 1000)")
    ap.add_argument("--pim-breaker-probes", type=int, default=None,
                    help="half-open probe successes required to close a "
                         "breaker (--pim-serve; default 2)")
    ap.add_argument("--pim-scrub-interval-ms", type=float, default=250.0,
                    help="background quarantined-span scrub period when "
                         "fault injection is on (--pim-serve; 0 disables)")
    ap.add_argument("--pim-stats-interval-ms", type=float, default=0.0,
                    help="emit a periodic {\"type\": \"stats\"} JSON line "
                         "to stderr with p50/p99 queue+exec latency, batch "
                         "occupancy and cache hit rate (--pim-serve; "
                         "0 disables)")
    ap.add_argument("--pim-metrics-file", metavar="PATH", default=None,
                    help="keep a Prometheus-style text exposition of the "
                         "serving metrics refreshed at the stats cadence "
                         "and at shutdown (--pim-serve)")
    ap.add_argument("--pim-cache-dir", metavar="DIR", default=None,
                    help="persistent compiled-artifact cache directory "
                         "(--pim-serve): schedules + AOT executables "
                         "persist across processes and the server warms "
                         "from disk at startup; a tuned.json beside it "
                         "auto-installs tuned Backend defaults "
                         "(DESIGN.md §16)")
    ap.add_argument("--pim-trace-file", metavar="PATH", default=None,
                    help="enable pipeline trace spans and write them as "
                         "Chrome-trace/Perfetto JSON at shutdown "
                         "(--pim-serve)")
    ap.add_argument("--pim-verify", action="store_true",
                    help="verified execution: per-chunk result checking "
                         "with retry + row remap (DESIGN.md §12)")
    ap.add_argument("--pim-fault-flip", type=float, default=0.0,
                    help="injected per-level transient bit-flip rate "
                         "(fault-injection harness; DESIGN.md §12)")
    ap.add_argument("--pim-fault-dead", type=float, default=0.0,
                    help="injected dead-row rate")
    ap.add_argument("--pim-fault-stuck", type=float, default=0.0,
                    help="injected stuck-at word-column rate")
    ap.add_argument("--pim-fault-seed", type=int, default=0,
                    help="fault-map seed (deterministic injection)")
    ap.add_argument("--pim-rows", type=int, default=1 << 20)
    ap.add_argument("--pim-requests", type=int, default=4)
    ap.add_argument("--pim-dtype", default="uint32",
                    choices=sorted(_PIM_DTYPES))
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="with --pim: write the synthetic-load result as a "
                         "benchmarks/run.py-compatible row")
    from ..kernels.plan import LAYOUTS, SCHEDULES
    ap.add_argument("--pim-schedule", default=None, choices=SCHEDULES,
                    help="executor schedule mode (default: the ufunc "
                         "config default, i.e. the contiguous-slot scan "
                         "executors)")
    ap.add_argument("--pim-layout", default=None, choices=sorted(LAYOUTS),
                    help="packed word layout: rows32 (uint32 words) or "
                         "rows64 (the paired 64-row layout; halves the "
                         "executor word axis) -- lands in every request's "
                         "ExecPlan")
    args = ap.parse_args(argv)

    import contextlib
    ctx = contextlib.nullcontext()
    overrides = {}
    if args.pim_schedule:
        overrides["schedule"] = args.pim_schedule
    if args.pim_layout:
        overrides["layout"] = args.pim_layout
    if args.pim_verify:
        overrides["verify"] = True
    if args.pim_fault_flip or args.pim_fault_dead or args.pim_fault_stuck:
        from ..runtime.faults import FaultModel
        overrides["faults"] = FaultModel(seed=args.pim_fault_seed,
                                         p_flip=args.pim_fault_flip,
                                         p_dead_row=args.pim_fault_dead,
                                         p_stuck=args.pim_fault_stuck)
    if overrides:
        # scoped override (not configure): the CLI choice must not leak
        # into library defaults when serve is driven programmatically
        from .. import pim_ufunc as pim
        ctx = pim.options(**overrides)
    breaker = "default"
    if args.pim_no_breaker:
        breaker = None
    elif (args.pim_breaker_failures is not None
          or args.pim_breaker_cooldown_ms is not None
          or args.pim_breaker_probes is not None):
        from ..runtime.pim_batch import BreakerPolicy
        dflt = BreakerPolicy()
        breaker = BreakerPolicy(
            trip_failures=args.pim_breaker_failures
            if args.pim_breaker_failures is not None else dflt.trip_failures,
            cooldown_s=args.pim_breaker_cooldown_ms * 1e-3
            if args.pim_breaker_cooldown_ms is not None else dflt.cooldown_s,
            probes=args.pim_breaker_probes
            if args.pim_breaker_probes is not None else dflt.probes)
    with ctx:
        if args.pim_serve:
            return serve_pim_batched(
                window_ms=args.pim_window_ms,
                max_batch_rows=args.pim_max_batch_rows,
                pin_cap=args.pim_pin_cap,
                max_queue_rows=args.pim_max_queue_rows or None,
                deadline_ms=args.pim_deadline_ms,
                heartbeat=args.pim_heartbeat,
                breaker=breaker,
                scrub_interval_ms=args.pim_scrub_interval_ms,
                stats_interval_ms=args.pim_stats_interval_ms,
                metrics_file=args.pim_metrics_file,
                trace_file=args.pim_trace_file,
                cache_dir=args.pim_cache_dir)
        if args.pim_stdin:
            return serve_pim_stdin()
        if args.pim:
            return serve_pim_synthetic(args)
        return serve_llm(args)


if __name__ == "__main__":
    main()
