"""Loop-aware HLO census: exact per-device dot-FLOPs and collective bytes.

XLA's ``cost_analysis()`` counts each ``while`` body once; our steps are
scan-heavy (layer groups, grad accumulation, attention chunks), so raw
numbers undercount by the trip product.  The post-SPMD HLO text annotates
every while with ``backend_config={"known_trip_count":{"n":...}}`` and names
its body computation -- so we recover exact totals by walking the call graph
from ENTRY and multiplying by enclosing trip counts.

Census per cell:
  * dot FLOPs (2 * prod(out_dims) * contraction), per-device (post-SPMD
    shapes are shard shapes);
  * collective payload bytes by kind, with ring wire-cost multipliers
    (all-reduce 2x, others 1x).
"""

from __future__ import annotations

import json
import re
from typing import Dict

import numpy as np

DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
               "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "pred": 1, "c64": 8, "c128": 16}
WIRE = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
        "all-to-all": 1.0, "collective-permute": 1.0}

_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\([^=]*\)|"
                     r"(?:f64|f32|bf16|f16|s64|u64|s32|u32|s16|u16|s8|u8|"
                     r"pred|c64|c128|token)\[[0-9,]*\]\S*)\s+([\w\-]+)\(")
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s64|u64|s32|u32|s16|u16|s8|u8|"
                       r"pred)\[([0-9,]*)\]")
_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\{\s*$")
_WHILE_RE = re.compile(r"body=%([\w\.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"calls=%([\w\.\-]+)")
_OPERANDS_RE = re.compile(r"\(([^)]*)\)")
_LHS_C_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_NO_MEM_OPS = {"get-tuple-element", "tuple", "bitcast", "constant",
               "parameter", "after-all", "partition-id", "replica-id"}


def _shape_info(txt):
    """dims + bytes of the first shape literal(s) in ``txt``."""
    total_bytes = 0
    first_dims = None
    for m in _SHAPE_RE.finditer(txt):
        dims = [int(x) for x in m.group(2).split(",") if x]
        total_bytes += int(np.prod(dims)) * DTYPE_BYTES[m.group(1)]
        if first_dims is None:
            first_dims = dims
    return first_dims, total_bytes


class HloCensus:
    def __init__(self, hlo_text: str):
        self.defs: Dict[str, list] = {}     # op name -> dims
        self.comps: Dict[str, dict] = {}
        self.entry = None
        self._parse(hlo_text)

    def _parse(self, txt):
        cur = None
        for line in txt.splitlines():
            h = _HDR_RE.match(line)
            if h and not line.startswith(" "):
                cur = h.group(2)
                self.comps[cur] = {"colls": [], "whiles": [], "dots": [],
                                   "calls": [], "mem_bytes": 0.0}
                if h.group(1):
                    self.entry = cur
                continue
            if cur is None:
                continue
            c = self.comps[cur]
            d = _DEF_RE.match(line)
            if d:                       # non-tuple defs: shape map for dots
                name, shape_txt, op = d.groups()
                dims, _ = _shape_info(shape_txt)
                self.defs[name] = dims
                if op not in _NO_MEM_OPS:
                    # loop-aware HBM-traffic proxy: output + operand bytes
                    # (fusions are the natural memory-traffic units)
                    _, obytes = _shape_info(shape_txt)
                    a = line.find("(")
                    ops_bytes = 0
                    if a > 0:
                        import re as _re
                        for om in _re.finditer(r"%([\w\.\-]+)",
                                               line[a:line.find(")", a) + 1]):
                            od = self.defs.get(om.group(1))
                            if od is not None:
                                ops_bytes += int(np.prod(od or [1])) * 4
                    c["mem_bytes"] += obytes + ops_bytes
                if op == "dot":
                    ops_m = _OPERANDS_RE.search(line[line.index("dot("):])
                    lhs = ops_m.group(1).split(",")[0].strip().lstrip("%")
                    lc = _LHS_C_RE.search(line)
                    cdims = [int(x) for x in lc.group(1).split(",") if x] \
                        if lc else []
                    lhs_dims = self.defs.get(lhs) or []
                    k = int(np.prod([lhs_dims[i] for i in cdims
                                     if i < len(lhs_dims)])) if cdims else 1
                    flops = 2.0 * float(np.prod(dims or [1])) * k
                    c["dots"].append(flops)
                    continue
            if " while(" in line:
                bm = _WHILE_RE.search(line)
                tm = _TRIP_RE.search(line)
                if bm:
                    c["whiles"].append(
                        (bm.group(1), int(tm.group(1)) if tm else 1))
                continue
            cm = re.search(
                r"=\s+((?:\([^;]*?\)|\S+))\s+"
                r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
                r"collective-permute)(?:-start)?\(", line)
            if cm:
                _, nbytes = _shape_info(cm.group(1))
                c["colls"].append((cm.group(2), nbytes))
                continue
            if "calls=" in line:
                for k2 in _CALLS_RE.finditer(line):
                    c["calls"].append(k2.group(1))

    def totals(self):
        flops = 0.0
        mem = 0.0
        coll = {k: {"count": 0.0, "bytes": 0.0} for k in WIRE}
        seen_stack = []

        def walk(name, mult):
            if name not in self.comps or name in seen_stack:
                return
            seen_stack.append(name)
            c = self.comps[name]
            nonlocal flops, mem
            flops += mult * sum(c["dots"])
            mem += mult * c["mem_bytes"]
            for kind, nbytes in c["colls"]:
                coll[kind]["count"] += mult
                coll[kind]["bytes"] += mult * nbytes * WIRE[kind]
            for body, trips in c["whiles"]:
                walk(body, mult * trips)
            for callee in c["calls"]:
                walk(callee, mult)
            seen_stack.pop()

        walk(self.entry, 1.0)
        return {"dot_flops": flops, "mem_bytes": mem, "collectives": coll}


def census(hlo_text: str) -> dict:
    return HloCensus(hlo_text).totals()


if __name__ == "__main__":
    import sys
    print(json.dumps(census(open(sys.argv[1]).read()), indent=1))
