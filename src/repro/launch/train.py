"""End-to-end training driver (example-scale and production-shaped).

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --reduced \
        --steps 300 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt

Production posture on a laptop: same code path as the dry-run (pjit +
sharding rules on whatever mesh exists), fault-tolerant loop (resume,
async checkpoints, preemption-safe), deterministic data.
"""

from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint.manager import CheckpointManager
from ..configs import registry
from ..data.pipeline import DataConfig, DataIterator
from ..models import model as M
from ..optim import adamw
from ..runtime.train_loop import train_loop
from . import sharding as SH
from .steps import make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU-scale)")
    ap.add_argument("--d-model", type=int, default=None)
    ap.add_argument("--layers", type=int, default=None)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = registry.get(args.arch)
    if args.reduced:
        over = {}
        if args.d_model:
            over["d_model"] = args.d_model
        if args.layers:
            over["n_layers"] = args.layers
        cfg = cfg.reduced(**over)

    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev, 1), ("data", "model"))
    SH.install_activation_sharder(mesh)

    params = M.init_model(cfg, jax.random.PRNGKey(args.seed))
    opt = adamw.init(params)
    opt_cfg = adamw.AdamWConfig(lr=args.lr, total_steps=args.steps,
                                warmup_steps=max(args.steps // 20, 1))
    step_fn_core = make_train_step(cfg, args.accum, opt_cfg)

    pshard = SH.param_shardings(mesh, jax.eval_shape(lambda: params))
    oshard = SH.opt_shardings(mesh, jax.eval_shape(lambda: opt), pshard)
    jstep = jax.jit(step_fn_core, in_shardings=(pshard, oshard, None),
                    donate_argnums=(0, 1))

    dcfg = DataConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch,
        seed=args.seed,
        frontend_dim=cfg.frontend_dim, vision_seq=cfg.vision_seq,
        kind={"audio": "audio", "vision": "vlm"}.get(cfg.frontend, "lm"))
    it = DataIterator(dcfg)
    ckpt = CheckpointManager(args.ckpt_dir, keep=2)

    def step_fn(state, batch):
        p, o = state["params"], state["opt"]
        mb = {k: jnp.asarray(v).reshape((args.accum,
                                         args.batch // args.accum)
                                        + v.shape[1:])
              for k, v in batch.items()}
        p, o, metrics = jstep(p, o, mb)
        return {"params": p, "opt": o}, metrics

    state = {"params": params, "opt": opt}
    out = train_loop(step_fn=step_fn, state=state, data_iter=it, ckpt=ckpt,
                     total_steps=args.steps, ckpt_every=args.ckpt_every)
    print("final:", {k: float(v) for k, v in out["metrics"].items()})
    return out


if __name__ == "__main__":
    main()
