"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Three terms per (arch x shape) cell on the single-pod mesh (v5e constants:
197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI):

  compute    = HLO dot FLOPs / chip / 197e12          (loop-aware census)
  memory     = analytic HBM traffic / chip / 819e9    (weights + optimizer +
               activations + KV; the CPU-backend HLO 'bytes accessed' is not
               fusion-faithful for TPU, so traffic is modeled and the HLO
               number is reported as a diagnostic)
  collective = census wire bytes / chip / 50e9        (loop-aware census)

plus MODEL_FLOPS = 6*N*D (train, N total for dense / N_active for MoE) or
2*N_active*D (forward-only), and the usefulness ratio MODEL_FLOPS /
(HLO_FLOPs x chips).
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict

import numpy as np

from ..configs import registry
from ..core.device_model import TPU_DEFAULT as TPU
from .steps import SHAPES, accum_for

CHIPS = {"16x16": 256, "2x16x16": 512}
TP = 16  # model-axis size on both meshes


def kv_cache_bytes(cfg, seq: int, batch: int) -> float:
    """Total decode-cache bytes for the whole model (bf16)."""
    per_tok = 0.0
    kinds = list(cfg.prefix) + list(cfg.group) * cfg.n_groups
    for kind in kinds:
        if kind in ("attn", "moe", "moe_dense"):
            if cfg.mla is not None:
                per_tok += (cfg.mla.kv_lora + cfg.mla.rope_head_dim) * 2
            else:
                per_tok += 2 * cfg.n_kv_heads * cfg.hd * 2
        elif kind == "local":
            per_tok += 0  # bounded window accounted below
    fixed = 0.0
    for kind in kinds:
        if kind == "local":
            fixed += 2 * cfg.window * cfg.n_kv_heads * cfg.hd * 2
        elif kind == "recurrent":
            fixed += (cfg.d_rnn or cfg.d_model) * 4 * 4
        elif kind == "rwkv":
            hd = cfg.d_model // cfg.n_heads
            fixed += cfg.n_heads * hd * hd * 4 + 2 * cfg.d_model * 2
    return batch * (seq * per_tok + fixed)


def traffic_model(cfg, plan, chips: int) -> Dict[str, float]:
    """Analytic per-device HBM bytes for one step."""
    pb = cfg.n_params * 2                      # bf16 weights
    pa = cfg.n_params_active * 2
    dp = chips // TP
    toks_local = plan.seq * plan.global_batch / max(dp, 1)
    d = cfg.d_model
    L = cfg.n_layers
    act_unit = toks_local * d * 2              # one activation tensor
    if plan.kind == "train":
        a = accum_for(cfg, plan)
        w = 4 * (pb / TP) * a                  # fwd + remat + 2 bwd passes
        opt = 24 * cfg.n_params / chips        # m,v,p fp32 r/w + grads
        acts = 12 * act_unit * L
        return {"weights": w, "optimizer": opt, "activations": acts,
                "kv": 0.0, "total": w + opt + acts}
    if plan.kind == "prefill":
        w = pa / TP
        acts = 8 * act_unit * L
        kv = kv_cache_bytes(cfg, plan.seq, plan.global_batch) / chips
        return {"weights": w, "optimizer": 0.0, "activations": acts,
                "kv": kv, "total": w + acts + kv}
    # decode: one token; whole active model + cache read per step
    w = pa / TP
    kv = kv_cache_bytes(cfg, plan.seq, plan.global_batch) / chips
    acts = 4 * plan.global_batch / max(dp, 1) * d * L * 2
    return {"weights": w, "optimizer": 0.0, "activations": acts,
            "kv": kv, "total": w + kv + acts}


def model_flops(cfg, plan) -> float:
    toks = plan.seq * plan.global_batch if plan.kind != "decode" \
        else plan.global_batch
    n = cfg.n_params_active if cfg.moe else cfg.n_params
    return (6 if plan.kind == "train" else 2) * n * toks


def analyze(rec: dict) -> dict:
    cfg = registry.get(rec["arch"])
    plan = SHAPES[rec["shape"]]
    chips = CHIPS[rec["mesh"]]
    t_c = rec["hlo_dot_flops"] / TPU.peak_bf16_flops
    traffic = traffic_model(cfg, plan, chips)
    t_m = traffic["total"] / TPU.hbm_bw
    coll_bytes = sum(v["bytes"] for v in rec["collectives"].values())
    t_n = coll_bytes / TPU.ici_bw
    mf = model_flops(cfg, plan)
    hlo_total = rec["hlo_dot_flops"] * chips
    dominant = max(("compute", t_c), ("memory", t_m),
                   ("collective", t_n), key=lambda kv: kv[1])[0]
    bound = max(t_c, t_m, t_n)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_n,
        "dominant": dominant,
        "model_flops": mf, "hlo_flops_total": hlo_total,
        "useful_ratio": mf / hlo_total if hlo_total else 0.0,
        "roofline_frac": (mf / TPU.peak_bf16_flops / chips) / bound
        if bound else 0.0,
        "coll_bytes": coll_bytes,
        "traffic": traffic,
        "hbm_gb": rec.get("memory", {}).get("argument_size_in_bytes", 0)
        / 2 ** 30 + rec.get("memory", {}).get("temp_size_in_bytes", 0)
        / 2 ** 30,
    }


def load(out_dir="results/dryrun", mesh="16x16"):
    rows = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        rec = json.load(open(path))
        if rec.get("skip") or rec["mesh"] != mesh:
            continue
        if rec.get("variant", "baseline") != "baseline":
            continue            # perf-iteration records live in §Perf
        rows.append(analyze(rec))
    return rows


def markdown(out_dir="results/dryrun") -> str:
    lines = []
    lines.append("| arch | shape | compute s | memory s | collective s |"
                 " dominant | MODEL_FLOPS | useful ratio | roofline frac |")
    lines.append("|---|---|---|---|---|---|---|---|---|")
    for r in load(out_dir):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3g} |"
            f" {r['memory_s']:.3g} | {r['collective_s']:.3g} |"
            f" **{r['dominant']}** | {r['model_flops']:.3g} |"
            f" {r['useful_ratio']:.2f} | {r['roofline_frac']:.3f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    print(markdown())
