"""Step builders + (architecture x input-shape) cell definitions.

The assigned LM shape grid:
  train_4k     seq 4096,   global_batch 256   -> train_step
  prefill_32k  seq 32768,  global_batch 32    -> prefill (logits + caches)
  decode_32k   seq 32768,  global_batch 128   -> serve_step (1 new token)
  long_500k    seq 524288, global_batch 1     -> serve_step; sub-quadratic
                                                 archs only

Skips (recorded, per assignment): long_500k for full-attention archs;
decode shapes for encoder-only archs.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import model as M
from ..models.config import ModelConfig
from ..optim import adamw


@dataclasses.dataclass(frozen=True)
class ShapePlan:
    name: str
    kind: str            # train / prefill / decode
    seq: int
    global_batch: int


SHAPES = {
    "train_4k": ShapePlan("train_4k", "train", 4096, 256),
    "prefill_32k": ShapePlan("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapePlan("decode_32k", "decode", 32768, 128),
    "long_500k": ShapePlan("long_500k", "decode", 524288, 1),
}


def cell_skip_reason(cfg: ModelConfig, shape: ShapePlan) -> Optional[str]:
    if cfg.encoder_only and shape.kind == "decode":
        return "encoder-only: no autoregressive step"
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return "full attention is quadratic at 500k ctx (DESIGN.md)"
    return None


def accum_for(cfg: ModelConfig, shape: ShapePlan) -> int:
    """Gradient-accumulation depth: keep the dispatched/activation working
    set of a microbatch inside HBM (MoE dispatch inflates by top_k)."""
    if shape.kind != "train":
        return 1
    if cfg.moe is not None or cfg.n_layers >= 90 or cfg.d_model >= 8192:
        return 16
    if cfg.n_params > 2e10:
        return 8
    return 4


# --------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; no allocation)
# --------------------------------------------------------------------------

def batch_specs(cfg: ModelConfig, shape: ShapePlan) -> Dict:
    sd = jax.ShapeDtypeStruct
    if shape.kind == "train":
        a = accum_for(cfg, shape)
        mb = shape.global_batch // a
        assert mb >= 1, (cfg.name, shape.name)
        out = {}
        if cfg.frontend == "audio":
            out["frames"] = sd((a, mb, shape.seq, cfg.frontend_dim),
                               jnp.float32)
        else:
            out["tokens"] = sd((a, mb, shape.seq), jnp.int32)
        out["labels"] = sd((a, mb, shape.seq), jnp.int32)
        if cfg.frontend == "vision":
            out["vision"] = sd((a, mb, cfg.vision_seq, cfg.frontend_dim),
                               jnp.float32)
        return out
    if shape.kind == "prefill":
        b = shape.global_batch
        out = {}
        if cfg.frontend == "audio":
            out["frames"] = sd((b, shape.seq, cfg.frontend_dim), jnp.float32)
        else:
            out["tokens"] = sd((b, shape.seq), jnp.int32)
        if cfg.frontend == "vision":
            out["vision"] = sd((b, cfg.vision_seq, cfg.frontend_dim),
                               jnp.float32)
        return out
    # decode
    out = {"token": sd((shape.global_batch,), jnp.int32),
           "pos": sd((), jnp.int32)}
    if cfg.frontend == "vision":
        out["vision"] = sd((shape.global_batch, cfg.vision_seq,
                            cfg.frontend_dim), jnp.float32)
    return out


def input_specs(cfg: ModelConfig, shape: ShapePlan) -> Dict:
    """All abstract inputs for the cell: batch + params (+opt/caches)."""
    params = jax.eval_shape(
        lambda: M.init_model(cfg, jax.random.PRNGKey(0)))
    specs = {"params": params, "batch": batch_specs(cfg, shape)}
    if shape.kind == "train":
        specs["opt"] = jax.eval_shape(lambda: adamw.init(params))
    if shape.kind == "decode":
        specs["caches"] = jax.eval_shape(
            lambda: M.init_caches(cfg, shape.global_batch, shape.seq))
    return specs


# --------------------------------------------------------------------------
# step functions
# --------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, accum: int,
                    opt_cfg: adamw.AdamWConfig = adamw.AdamWConfig(),
                    acc_dtype=jnp.float32, fused_accum: bool = False):
    """Gradient-accumulated train step.

    ``fused_accum`` (perf iteration C1): microbatch accumulation happens
    *inside* autodiff -- grad of a scan over microbatches -- so the gradient
    reduce-to-sharded-layout collective fires once per step instead of once
    per microbatch (accum-x less gradient all-reduce traffic).
    """
    def train_step(params, opt_state, batch):
        if fused_accum:
            def total_loss(p):
                def body(c, mb):
                    return c + M.loss_fn(cfg, p, mb)[0], None
                s, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), batch)
                return s / accum
            loss, gacc = jax.value_and_grad(total_loss)(params)
            losses = loss[None]
        else:
            def micro(gacc, mb):
                (loss, metrics), grads = jax.value_and_grad(
                    lambda p: M.loss_fn(cfg, p, mb), has_aux=True)(params)
                gacc = jax.tree.map(
                    lambda a, g: a + g.astype(a.dtype), gacc, grads)
                return gacc, loss

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dtype), params)
            gacc, losses = jax.lax.scan(micro, zeros, batch)
            gacc = jax.tree.map(lambda g: g / accum, gacc)
        new_p, new_opt, om = adamw.update(opt_cfg, gacc, opt_state, params)
        return new_p, new_opt, {"loss": losses.mean(), **om}

    return train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        return M.prefill(cfg, params, batch)
    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def serve_step(params, caches, batch):
        logits, new_caches = M.decode_step(
            cfg, params, caches, batch["token"], batch["pos"],
            vision=batch.get("vision"))
        # greedy next token (sampling is host-side policy)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, logits, new_caches
    return serve_step
