import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: sharding
mismatches, compile-time OOM, and unsupported collectives all surface here.
Emits one JSON record per cell (memory analysis, cost analysis, collective
byte census parsed from the post-SPMD HLO) consumed by launch.roofline and
EXPERIMENTS.md.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b \
        --shape train_4k [--multi-pod] [--all] [--out results/dryrun]
"""

import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import numpy as np   # noqa: E402

from ..configs import registry                              # noqa: E402
from ..optim import adamw                                   # noqa: E402
from . import sharding as SH                                # noqa: E402
from .mesh import make_production_mesh                      # noqa: E402
from .steps import (SHAPES, accum_for, batch_specs, cell_skip_reason,
                    input_specs, make_decode_step, make_prefill_step,
                    make_train_step)                        # noqa: E402

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16}

_COLL_RE = re.compile(
    r"=\s+((?:\([^)]*\)|\S+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s64|u64|s32|u32|s16|u16|s8|u8|"
                       r"pred|c64|c128)\[([0-9,]*)\]")

# wire-cost multiplier per collective (ring algorithms, large N limit)
_WIRE = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
         "all-to-all": 1.0, "collective-permute": 1.0}


def collective_census(hlo_text: str):
    """Sum collective payload bytes (per device) by op kind."""
    out = {k: {"count": 0, "bytes": 0.0} for k in _WIRE}
    for m in _COLL_RE.finditer(hlo_text):
        shapes, kind = m.group(1), m.group(2)
        nbytes = 0
        for dm in _SHAPE_RE.finditer(shapes):
            dims = [int(x) for x in dm.group(2).split(",") if x]
            nbytes += int(np.prod(dims)) * _DTYPE_BYTES[dm.group(1)] \
                if dims else _DTYPE_BYTES[dm.group(1)]
        out[kind]["count"] += 1
        out[kind]["bytes"] += nbytes * _WIRE[kind]
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             force: bool = False, serve_tp: bool = False,
             embed_d: bool = False, fused_accum: bool = False,
             accum_override: int = 0, variant: str = "",
             seq_cache: bool = False):
    tag = f"{arch}__{shape_name}__{'pod2' if multi_pod else 'pod1'}"
    if variant:
        tag += f"__{variant}"
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, tag + ".json")
    if os.path.exists(path) and not force:
        print(f"[skip-cached] {tag}")
        return json.load(open(path))

    cfg = registry.get(arch)
    plan = SHAPES[shape_name]
    skip = cell_skip_reason(cfg, plan)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "kind": plan.kind, "skip": skip}
    if skip:
        json.dump(rec, open(path, "w"), indent=1)
        print(f"[skip] {tag}: {skip}")
        return rec

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    SH.install_activation_sharder(mesh)
    import repro.launch.steps as steps_mod
    if accum_override:
        orig_accum = steps_mod.accum_for
        steps_mod.accum_for = lambda c, s: (accum_override
                                            if s.kind == "train"
                                            else orig_accum(c, s))
    specs = input_specs(cfg, plan)
    pshard = SH.param_shardings(
        mesh, specs["params"],
        serve=serve_tp and plan.kind != "train", embed_d=embed_d)
    bshard = SH.batch_shardings(mesh, specs["batch"],
                                accum=(plan.kind == "train"))
    repl = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())

    if plan.kind == "train":
        oshard = SH.opt_shardings(mesh, specs["opt"], pshard)
        import jax.numpy as jnp
        fn = make_train_step(cfg, accum_for(cfg, plan),
                             fused_accum=fused_accum,
                             acc_dtype=jnp.bfloat16 if os.environ.get(
                                 "REPRO_BF16_ACC") else jnp.float32)
        jfn = jax.jit(fn, in_shardings=(pshard, oshard, bshard),
                      out_shardings=(pshard, oshard,
                                     jax.tree.map(lambda _: repl,
                                                  {"loss": 0, "grad_norm": 0,
                                                   "lr": 0})),
                      donate_argnums=(0, 1))
        args = (specs["params"], specs["opt"], specs["batch"])
    elif plan.kind == "prefill":
        fn = make_prefill_step(cfg)
        jfn = jax.jit(fn, in_shardings=(pshard, bshard))
        args = (specs["params"], specs["batch"])
    else:
        cshard = SH.cache_shardings(mesh, specs["caches"],
                                    batch=plan.global_batch,
                                    seq_shard=seq_cache)
        fn = make_decode_step(cfg)
        jfn = jax.jit(fn, in_shardings=(pshard, cshard, bshard),
                      donate_argnums=(1,))
        args = (specs["params"], specs["caches"], specs["batch"])

    with mesh:
        lowered = jfn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            if hasattr(ma, k):
                mem[k] = int(getattr(ma, k))
        print(ma)
    except Exception as e:                      # CPU backend gaps
        mem["error"] = str(e)
    cost = {}
    try:
        ca = compiled.cost_analysis()
        ca = ca[0] if isinstance(ca, (list, tuple)) else ca
        cost = {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float)) and np.isfinite(v)}
        print({k: cost[k] for k in ("flops", "bytes accessed")
               if k in cost})
    except Exception as e:
        cost["error"] = str(e)
    from .hlo_census import census
    cen = census(compiled.as_text())

    if accum_override:
        steps_mod.accum_for = orig_accum
    rec.update({
        "variant": variant or "baseline",
        "accum": accum_override or accum_for(cfg, plan),
        "n_params": cfg.n_params, "n_params_active": cfg.n_params_active,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": mem, "cost": cost,
        "hlo_dot_flops": cen["dot_flops"],          # loop-aware, per device
        "hlo_mem_bytes": cen["mem_bytes"],          # proxy (CPU fusion != TPU)
        "collectives": cen["collectives"],          # loop-aware, per device
    })
    json.dump(rec, open(path, "w"), indent=1)
    print(f"[ok] {tag} lower={t_lower:.0f}s compile={t_compile:.0f}s "
          f"flops={cost.get('flops', 0):.3g}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--serve-tp", action="store_true")
    ap.add_argument("--embed-d", action="store_true")
    ap.add_argument("--fused-accum", action="store_true")
    ap.add_argument("--accum", type=int, default=0)
    ap.add_argument("--variant", default="")
    ap.add_argument("--seq-cache", action="store_true")
    args = ap.parse_args()

    archs = list(registry.ARCHS) if args.all or not args.arch \
        else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = [False, True] if (args.both_meshes or args.all) \
        else [args.multi_pod]
    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    run_cell(arch, shape, mp, args.out, force=args.force,
                             serve_tp=args.serve_tp, embed_d=args.embed_d,
                             fused_accum=args.fused_accum,
                             accum_override=args.accum,
                             variant=args.variant,
                             seq_cache=args.seq_cache)
                except Exception:
                    failures.append((arch, shape, mp))
                    traceback.print_exc()
    if failures:
        print("FAILURES:", failures)
        raise SystemExit(1)
    print("dry-run complete")


if __name__ == "__main__":
    main()
