"""Quickstart: the AritPIM suite end to end.

Runs every arithmetic family on the element-parallel PIM machine (one
shared gate program, thousands of rows), via the Pallas executor, and
prints latency/energy from the memristive device model.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import bitserial, bitserial_fp, bitparallel
from repro.core.device_model import GPU_DEFAULT, PIM_DEFAULT
from repro.core.floatfmt import FP32
from repro.core.pim_numerics import PIMVectorUnit

rng = np.random.default_rng(0)
unit = PIMVectorUnit(backend="pallas")

# --- integer vectors, one program, element-parallel
x = rng.integers(0, 2**16, 1000).astype(np.uint16)
y = rng.integers(0, 2**16, 1000).astype(np.uint16)
assert np.array_equal(unit.add(x, y), x.astype(np.uint64) + y)
print("int16 add: 1000 rows, bit-exact")

# --- fp32, exact IEEE RNE
a = rng.standard_normal(512).astype(np.float32)
b = rng.standard_normal(512).astype(np.float32)
for op in ("add", "mul", "div"):
    got = getattr(unit, op)(a, b)
    want = {"add": a + b, "mul": a * b, "div": a / b}[op]
    assert np.array_equal(got, want.astype(np.float32))
    print(f"fp32 {op}: 512 rows, bit-exact vs numpy (IEEE RNE)")

# --- latency & throughput on the memristive case study (paper Fig. 9)
pim = PIM_DEFAULT
for name, prog in [("int32 add", bitserial.build_add(32)),
                   ("fp32 add", bitserial_fp.build_fp_add(FP32)),
                   ("int32 add (bit-parallel)",
                    bitparallel.build_bp_add(32))]:
    cost = prog.parallel_cost() or prog.cost()
    thr = pim.throughput_ops(cost)
    print(f"{name:26s}: {pim.cycles(cost):6d} cycles "
          f"= {pim.latency_s(cost)*1e6:7.2f} us, "
          f"{thr/1e9:9.1f} GOPS over {pim.parallel_rows/2**20:.0f} Mi rows "
          f"({thr / GPU_DEFAULT.throughput_ops(4):6.1f}x the GPU roofline)")
