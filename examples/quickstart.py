"""Quickstart: the AritPIM suite end to end, through the ufunc frontend.

Every arithmetic family runs on the element-parallel PIM machine (one
shared gate program, thousands of rows) via ``repro.pim_ufunc`` -- arrays
in, arrays out, streamed through the chunked executor -- then latency and
energy are reported from the memristive device model.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro import pim_ufunc as pim
from repro.core import bitserial, bitserial_fp, bitparallel
from repro.core.device_model import GPU_DEFAULT, PIM_DEFAULT
from repro.core.floatfmt import BF16, FP32

rng = np.random.default_rng(0)

# --- integer vectors, one program, element-parallel
x = rng.integers(0, 2**16, 1000).astype(np.uint16)
y = rng.integers(0, 2**16, 1000).astype(np.uint16)
assert np.array_equal(pim.add(x, y), x.astype(np.uint64) + y)
assert np.array_equal(pim.mul(x, y), x.astype(np.uint64) * y)
d = rng.integers(1, 2**16, 1000).astype(np.uint16)
q, r = pim.div(x, d)
assert np.array_equal(q, x.astype(np.uint64) // d)
assert np.array_equal(r, x.astype(np.uint64) % d)
print("int16 add/mul/div: 1000 rows, bit-exact")

# --- fp32, exact IEEE RNE
a = rng.standard_normal(512).astype(np.float32)
b = rng.standard_normal(512).astype(np.float32)
for op, want in [("fp_add", a + b), ("fp_sub", a - b),
                 ("fp_mul", a * b), ("fp_div", a / b)]:
    got = getattr(pim, op)(a, b)
    assert np.array_equal(got, want.astype(np.float32))
    print(f"fp32 {op[3:]}: 512 rows, bit-exact vs numpy (IEEE RNE)")

# --- bf16 has no native numpy dtype: bit-pattern arrays + fmt=
xb = BF16.random_bits(rng, 256, emin=120, emax=132).astype(np.uint64)
yb = BF16.random_bits(rng, 256, emin=120, emax=132).astype(np.uint64)
zb = pim.fp_add(xb, yb, fmt="bf16")
assert all(int(z) == BF16.op_exact("add", int(p), int(q)) for z, p, q
           in zip(zb, xb, yb))
print("bf16 add: 256 rows, bit-exact vs the exact rational oracle")

# --- latency & throughput on the memristive case study (paper Fig. 9)
pim_dev = PIM_DEFAULT
for name, prog in [("int32 add", bitserial.build_add(32)),
                   ("fp32 add", bitserial_fp.build_fp_add(FP32)),
                   ("int32 add (bit-parallel)",
                    bitparallel.build_bp_add(32))]:
    cost = prog.parallel_cost() or prog.cost()
    thr = pim_dev.throughput_ops(cost)
    print(f"{name:26s}: {pim_dev.cycles(cost):6d} cycles "
          f"= {pim_dev.latency_s(cost)*1e6:7.2f} us, "
          f"{thr/1e9:9.1f} GOPS over {pim_dev.parallel_rows/2**20:.0f} Mi rows "
          f"({thr / GPU_DEFAULT.throughput_ops(4):6.1f}x the GPU roofline)")
