"""AritPIM as a numerics backend inside a model: an int8 PIMLinear layer.

Quantizes a small MLP's weights to int8 and evaluates the GEMMs with the
in-memory bit-serial algorithms (exact integer arithmetic on the PIM
abstract machine), comparing against the float reference.

    PYTHONPATH=src python examples/pim_linear_inference.py
"""

import numpy as np

from repro.core.pim_numerics import PIMVectorUnit, pim_linear_i8

rng = np.random.default_rng(1)
unit = PIMVectorUnit(backend="pallas")


def quant(w):
    s = np.abs(w).max() / 127.0
    return np.clip(np.round(w / s), -127, 127).astype(np.int8), s


# two-layer MLP
x = rng.standard_normal((4, 32)).astype(np.float32)
w1 = rng.standard_normal((32, 16)).astype(np.float32) / np.sqrt(32)
w2 = rng.standard_normal((16, 8)).astype(np.float32) / np.sqrt(16)

xq, sx = quant(x)
w1q, s1 = quant(w1)
h_pim = pim_linear_i8(unit, xq, w1q).astype(np.float32) * sx * s1
h_pim = np.maximum(h_pim, 0)
hq, sh = quant(h_pim)
w2q, s2 = quant(w2)
y_pim = pim_linear_i8(unit, hq, w2q).astype(np.float32) * sh * s2

y_ref = np.maximum(x @ w1, 0) @ w2
rel = np.abs(y_pim - y_ref).max() / np.abs(y_ref).max()
print(f"PIM int8 2-layer MLP vs float reference: max rel err = {rel:.4f}")
assert rel < 0.06
print("int8 GEMMs themselves are EXACT (verified in tests); the error is "
      "pure quantization.")
