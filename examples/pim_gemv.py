"""Compound-program fusion quickstart (DESIGN.md §13).

Three escalating uses of the lazy expression frontend:

  1. a fused elementwise chain -- ``(a*b)+c`` recorded as a DAG and
     lowered into ONE compiled gate program (one pack, one execution,
     one unpack), vs the same chain as three eager ufunc calls;
  2. ``pim.dot`` -- an in-memory dot product: an element-parallel
     multiply feeding a log-depth adder tree that never leaves the
     packed word domain;
  3. ``pim.gemv`` -- every output lane reduces in parallel rows, so a
     64x1024 int16 GEMV takes 1 + log2(1024) program dispatches total.

    PYTHONPATH=src python examples/pim_gemv.py
"""

import time

import numpy as np

from repro import pim_ufunc as pim

rng = np.random.default_rng(0)
kw = dict(backend="ref")

# ---- 1. fused elementwise chain ------------------------------------------
a = rng.integers(0, 256, 8192).astype(np.uint64)
b = rng.integers(0, 256, 8192).astype(np.uint64)
c = rng.integers(0, 256, 8192).astype(np.uint64)

expr = (pim.lazy(a, width=8) * pim.lazy(b, width=8)) + pim.lazy(c, width=8)
prep = pim.fuse(expr, **kw)
fused = prep.run()
print(f"fused chain: {prep.fused_ops} ops in one program "
      f"{prep.provenance} -> bit-exact: "
      f"{bool(np.array_equal(fused, a * b + c))}")

unfused = pim.add(pim.mul(a, b, width=8, **kw), c, width=16, **kw)
print(f"unfused chain agrees: {bool(np.array_equal(unfused, fused))}")

# ---- 2. in-memory dot product --------------------------------------------
from repro.core.floatfmt import FP16

xf = FP16.random_bits(rng, 8192, emin=10, emax=20) \
    .astype(np.uint16).view(np.float16)
yf = FP16.random_bits(rng, 8192, emin=10, emax=20) \
    .astype(np.uint16).view(np.float16)
d = pim.dot(xf, yf, **kw)
# the reference is the same-shape binary tree (fp adds round per level)
t = (xf * yf).astype(np.float16)
while len(t) > 1:
    t = (t[:len(t) // 2] + t[len(t) // 2:]).astype(np.float16)
print(f"fp16 dot(8192): pim={d}  host-tree={t[0]}  "
      f"bit-exact: {d.view(np.uint16) == t[0].view(np.uint16)}")

# ---- 3. GEMV: all output lanes reduce at once ----------------------------
m, k = 64, 1024
w = rng.integers(0, 1 << 16, (m, k)).astype(np.uint64)
v = rng.integers(0, 1 << 16, k).astype(np.uint64)
pim.gemv(w, v, width=16, **kw)          # warm up (compiles the tree)
t0 = time.perf_counter()
y = pim.gemv(w, v, width=16, **kw)
dt = time.perf_counter() - t0
ok = np.array_equal(np.asarray(y, np.uint64), w @ v)
print(f"i16 gemv {m}x{k}: exact vs numpy: {ok}  "
      f"({dt * 1e3:.1f} ms, {m * k / dt:,.0f} products/s)")
