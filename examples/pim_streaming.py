"""Streaming + sharded PIM arithmetic at scale (DESIGN.md §8).

A million fp16 additions served by one shared gate program: rows are tiled
into word-aligned chunks, host packing of chunk k+1 overlaps device
execution of chunk k, and each chunk's packed word axis is sharded over all
available devices with ``jax.shard_map``.

Force a multi-device CPU to see the sharded path locally:

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python examples/pim_streaming.py
"""

import os
import sys
import time

# must be set before jax initializes its backends
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import jax                                                     # noqa: E402
import numpy as np                                             # noqa: E402

from repro import pim_ufunc as pim                             # noqa: E402
from repro.core.pim_numerics import program_for                # noqa: E402
from repro.kernels import ops as kops                          # noqa: E402

N = 1 << 20 if "--small" not in sys.argv else 1 << 16
rng = np.random.default_rng(0)

print(f"devices: {len(jax.devices())}, rows: {N}, "
      f"chunk: {pim.config.chunk_rows}")

# fp16 addition: exponents kept mid-range (the paper excludes
# overflow/underflow and NaN/Inf/subnormals)
x = (rng.integers(10, 21, N).astype(np.uint16) << 10 |
     rng.integers(0, 1 << 10, N).astype(np.uint16)).view(np.float16)
y = (rng.integers(10, 21, N).astype(np.uint16) << 10 |
     rng.integers(0, 1 << 10, N).astype(np.uint16)).view(np.float16)

# compile once at the streaming chunk shape (all chunks share it), then time
warm = min(N, pim.config.chunk_rows)
pim.fp_add(x[:warm], y[:warm])
t0 = time.perf_counter()
z = pim.fp_add(x, y)
dt = time.perf_counter() - t0
print(f"pim.fp_add: {N} rows in {dt*1e3:.1f} ms "
      f"= {N/dt/1e6:.2f} M rows/s (streamed + sharded)")

# spot-check a sample against numpy's IEEE fp16 addition
idx = rng.integers(0, N, 1000)
assert np.array_equal(z[idx], (x[idx] + y[idx]).astype(np.float16))
print("sampled 1000 rows: bit-exact vs numpy IEEE RNE")

# the same executor, explicitly unsharded, for comparison
t0 = time.perf_counter()
kops.run_program_streaming(
    program_for("fp-serial", "add", "fp16"),
    {"x": x.view(np.uint16).astype(np.uint64),
     "y": y.view(np.uint16).astype(np.uint64)}, N, backend="ref", mesh=None)
dt1 = time.perf_counter() - t0
print(f"unsharded streaming baseline: {N} rows in {dt1*1e3:.1f} ms "
      f"= {N/dt1/1e6:.2f} M rows/s")
