"""End-to-end driver: train a ~small qwen3-family LM for a few hundred
steps with the production code path (pjit sharding rules, fault-tolerant
loop, async checkpoints, deterministic data).

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import sys

from repro.launch import train

if __name__ == "__main__":
    args = sys.argv[1:]
    if not any(a.startswith("--steps") for a in args):
        args += ["--steps", "200"]
    train.main(["--arch", "qwen3-8b", "--reduced", "--d-model", "128",
                "--layers", "4", "--batch", "8", "--seq", "128",
                "--ckpt-dir", "/tmp/repro_example_ckpt",
                "--ckpt-every", "50"] + args)
