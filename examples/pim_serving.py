"""Batched PIM serving quickstart (DESIGN.md §10).

Mixed traffic -- many small requests over several distinct programs --
through the batched serving runtime: requests are grouped by compiled-
program structure, each group executes as one packed state, and results
scatter back per request.  Compare against the per-request serial loop.

    PYTHONPATH=src python examples/pim_serving.py

The same runtime serves JSON lines over stdin/stdout:

    printf '%s\n' \
        '{"op":"add","dtype":"uint16","x":[3,5],"y":[4,6]}' \
        '{"op":"div","dtype":"uint8","x":[17],"y":[5]}' \
        '{"op":"fp_add","dtype":"float16","x":[1.5],"y":[0.25]}' | \
        PYTHONPATH=src python -m repro.launch.serve --pim-serve \
            --pim-window-ms 5 --pim-max-batch-rows 65536
"""

import time

import numpy as np

from repro import pim_ufunc as pim
from repro.runtime import pim_batch, telemetry

rng = np.random.default_rng(0)
N = 512                                  # rows per request


def fp16(n):
    # normal-range fp16 (the paper excludes NaN/Inf/subnormals)
    return (rng.integers(10, 21, n).astype(np.uint16) << 10 |
            rng.integers(0, 1 << 10, n).astype(np.uint16)).view(np.float16)


# 48 requests interleaved over 6 distinct programs
traffic = []
for _ in range(8):
    x = rng.integers(0, 1 << 16, N).astype(np.uint16)
    y = rng.integers(0, 1 << 16, N).astype(np.uint16)
    d = rng.integers(1, 1 << 16, N).astype(np.uint16)
    traffic += [("add", x, y), ("mul", x, y), ("div", x, d),
                ("fp_add", fp16(N), fp16(N)), ("fp_sub", fp16(N), fp16(N)),
                ("fp_mul", fp16(N), fp16(N))]

# prepare() parses/validates and binds each request to its gate program
# without executing -- the handle the planner groups by content hash
preps = [pim.prepare(op, x, y) for op, x, y in traffic]
print(f"{len(preps)} requests, "
      f"{len({p.key for p in preps})} distinct programs, "
      f"{sum(p.n_rows for p in preps)} total rows")

runtime = pim_batch.BatchRuntime(pin_cap=16)
# warm-up both paths: compile every program at both the per-request and
# the coalesced-group shapes, so the timings below are pure serving
runtime.execute(preps)
for op, x, y in traffic:
    getattr(pim, op)(x, y)

telemetry.drain_model_counters()         # window the analytical cost gauge
t0 = time.perf_counter()
results = runtime.execute(preps)
dt_batched = time.perf_counter() - t0
print(runtime.stats.summary(pinned=len(runtime.pins)))

# per-batch telemetry (DESIGN.md §15): latency percentiles from the
# runtime's own registry, and the modeled device cost of what just ran --
# PIM cycles on the memristive device model next to host wall clock
exec_h = runtime.metrics.summary("pim.batch.exec_us")
occ_h = runtime.metrics.summary("pim.batch.occupancy_rows")
print(f"exec_us  p50={exec_h['p50']:.0f} p99={exec_h['p99']:.0f} "
      f"(n={exec_h['count']})  "
      f"occupancy p50={occ_h['p50']:.0f} rows")
model = telemetry.drain_model_counters()
cyc = model.get("pim.model.cycles", 0)
print(f"modeled: {model.get('pim.exec.dispatches', 0)} dispatches, "
      f"{cyc:,} PIM cycles = "
      f"{cyc * telemetry.PIM_DEFAULT.cycle_ns * 1e-3:.1f} us on-device, "
      f"{model.get('pim.model.energy_pj', 0.0) * 1e-6:.2f} uJ modeled "
      f"energy")

# the serial loop: one program execution per request (--pim-stdin's model)
t0 = time.perf_counter()
serial = [getattr(pim, op)(x, y) for op, x, y in traffic]
dt_serial = time.perf_counter() - t0

# bit-exactness: coalesced == per-request, row for row (div's (q, r) too)
for (op, _, _), res, want in zip(traffic, results, serial):
    if op == "div":
        assert np.array_equal(res.value[0], want[0])
        assert np.array_equal(res.value[1], want[1])
    else:
        assert np.array_equal(res.value, want)
print("batched results bit-exact vs per-request execution")

rows = sum(p.n_rows for p in preps)
print(f"serial : {dt_serial * 1e3:7.1f} ms = {rows / dt_serial:10,.0f} rows/s")
print(f"batched: {dt_batched * 1e3:7.1f} ms = {rows / dt_batched:10,.0f} "
      f"rows/s ({dt_serial / dt_batched:.1f}x)")
runtime.close()
