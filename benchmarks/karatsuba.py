"""Paper §3.2 fn.3: the Karatsuba crossover sits near N≈20 for bit-serial
in-memory multiplication (vs thousands of digits on CPUs)."""

from __future__ import annotations

from repro.core import bitserial


def rows():
    out = []
    for n in (8, 12, 16, 20, 24, 32, 48, 64):
        naive = bitserial.build_mul(n, karatsuba=False).cost()
        kar = bitserial.build_mul(n, karatsuba=True, thresh=14).cost()
        out.append({
            "N": n,
            "shift_add_nor": naive.nor_gates,
            "karatsuba_nor": kar.nor_gates,
            "speedup": round(naive.nor_gates / kar.nor_gates, 3),
        })
    return out


def crossover():
    for r in rows():
        if r["speedup"] > 1.0:
            return r["N"]
    return None
