"""Paper §4.4: variable normalization costs only ~7% more than variable
shift (binary-search trick), vs ~300% for the naive count-then-shift."""

from __future__ import annotations

from repro.core import bitserial_fp
from repro.core.gates import Builder
from repro.core.bitserial import ripple_add


def _naive_normalize_cost(nx: int) -> int:
    """Count leading zeros with adders, then variable-shift (the strawman
    the paper improves on)."""
    b = Builder()
    x = b.input("x", nx)
    # prefix-OR then popcount of zeros via ripple adders
    pref = [x[-1]]
    for i in reversed(range(nx - 1)):
        pref.append(b.or_(pref[-1], x[i]))
    ones = [b.not_(p) for p in pref]
    acc = [ones[0]]
    for o in ones[1:]:
        acc, _ = ripple_add(b, acc + [b.const(0)] * 0,
                            [o] + [b.const(0)] * (len(acc) - 1))
    t = acc
    from repro.core.bitserial_fp import var_shift_left
    z = var_shift_left(b, x, t[: max(1, (nx - 1).bit_length())])
    b.output("z", z)
    return b.finish().cost().nor_gates


def rows():
    out = []
    for nx in (8, 16, 24, 32, 53):
        vs = bitserial_fp.build_var_shift(nx, (nx - 1).bit_length()).cost()
        vn = bitserial_fp.build_var_normalize(nx).cost()
        naive = _naive_normalize_cost(nx)
        out.append({
            "Nx": nx,
            "var_shift_nor": vs.nor_gates,
            "var_norm_nor": vn.nor_gates,
            "overhead_pct": round(100 * (vn.nor_gates / vs.nor_gates - 1), 1),
            "naive_norm_nor": naive,
            "naive_overhead_pct":
                round(100 * (naive / vs.nor_gates - 1), 1),
        })
    return out
