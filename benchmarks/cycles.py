"""Paper Table: latency (abstract steps / NOR cycles) + area (cells) for
all 16 arithmetic variants, bit-serial and bit-parallel, 16/32-bit."""

from __future__ import annotations

from repro.core import (bitparallel, bitparallel_fp, bitserial, bitserial_fp)
from repro.core.floatfmt import BF16, FP16, FP32


def rows():
    out = []

    def add(name, prog, parallel=False):
        c = prog.parallel_cost() if parallel else prog.cost()
        out.append({
            "op": name,
            "steps": c.abstract_steps,
            "nor_cycles": c.nor_gates,
            "nor_cycles_norm9": c.nor_gates_normalized,
            "cells": c.cells,
        })

    for n in (16, 32):
        add(f"serial add{n}", bitserial.build_add(n))
        add(f"serial sub{n}", bitserial.build_sub(n))
        add(f"serial mul{n} (shift-add)",
            bitserial.build_mul(n, karatsuba=False))
        add(f"serial mul{n} (karatsuba)", bitserial.build_mul(n))
        add(f"serial div{n}", bitserial.build_div(n))
        add(f"parallel add{n}", bitparallel.build_bp_add(n), parallel=True)
        add(f"parallel mul{n}", bitparallel.build_bp_mul(n, cpk=256),
            parallel=True)
        add(f"parallel div{n}", bitparallel.build_bp_div(n, cpk=384),
            parallel=True)
    for fname, fmt in (("fp16", FP16), ("bf16", BF16), ("fp32", FP32)):
        add(f"serial {fname} add (signed)", bitserial_fp.build_fp_add(fmt))
        add(f"serial {fname} add (unsigned)",
            bitserial_fp.build_fp_add(fmt, signed=False))
        add(f"serial {fname} mul", bitserial_fp.build_fp_mul(fmt))
        add(f"serial {fname} div", bitserial_fp.build_fp_div(fmt))
        add(f"parallel {fname} add", bitparallel_fp.build_bp_fp_add(fmt),
            parallel=True)
        add(f"parallel {fname} mul",
            bitparallel_fp.build_bp_fp_mul(fmt, cpk=512), parallel=True)
        add(f"parallel {fname} div",
            bitparallel_fp.build_bp_fp_div(fmt, cpk=640), parallel=True)
    return out
