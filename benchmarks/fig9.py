"""Paper Fig. 9: AritPIM throughput & throughput/Watt vs a bandwidth-bound
GPU, 32-bit (and 16-bit) numbers, memristive case study (RACER params,
8 GB of 1024x1024 crossbars = 64 Mi parallel rows)."""

from __future__ import annotations

from repro.core import bitparallel, bitparallel_fp, bitserial, bitserial_fp
from repro.core.device_model import GPU_DEFAULT, PIM_DEFAULT
from repro.core.floatfmt import FP16, FP32


def rows():
    pim, gpu = PIM_DEFAULT, GPU_DEFAULT
    out = []

    def add(name, cost, elem_bytes, parallel):
        thr = pim.parallel_rows / (pim.cycles(cost) * pim.cycle_ns * 1e-9)
        tpw = pim.throughput_per_watt(cost)
        gthr = gpu.throughput_ops(elem_bytes)
        gtpw = gpu.throughput_per_watt(elem_bytes)
        out.append({
            "op": name,
            "pim_gops": round(thr / 1e9, 1),
            "gpu_gops": round(gthr / 1e9, 1),
            "speedup": round(thr / gthr, 1),
            "pim_gops_per_w": round(tpw / 1e9, 2),
            "gpu_gops_per_w": round(gtpw / 1e9, 3),
            "energy_ratio": round(tpw / gtpw, 1),
        })

    add("int32 add (bit-serial)", bitserial.build_add(32).cost(), 4, False)
    add("int32 mul (bit-serial)", bitserial.build_mul(32).cost(), 4, False)
    add("int32 div (bit-serial)", bitserial.build_div(32).cost(), 4, False)
    add("fp32 add (bit-serial)", bitserial_fp.build_fp_add(FP32).cost(),
        4, False)
    add("fp32 mul (bit-serial)", bitserial_fp.build_fp_mul(FP32).cost(),
        4, False)
    add("fp32 div (bit-serial)", bitserial_fp.build_fp_div(FP32).cost(),
        4, False)
    add("fp16 add (bit-serial)", bitserial_fp.build_fp_add(FP16).cost(),
        2, False)
    # bit-parallel: fewer rows per array are usable as operands span k
    # partitions, but latency shrinks; throughput shown per-row-equal for
    # comparability with the paper's presentation
    add("int32 add (bit-parallel)",
        bitparallel.build_bp_add(32).parallel_cost(), 4, True)
    add("int32 mul (bit-parallel)",
        bitparallel.build_bp_mul(32, cpk=256).parallel_cost(), 4, True)
    add("int32 div (bit-parallel)",
        bitparallel.build_bp_div(32, cpk=384).parallel_cost(), 4, True)
    add("fp32 add (bit-parallel)",
        bitparallel_fp.build_bp_fp_add(FP32).parallel_cost(), 4, True)
    return out
