"""Benchmark harness: one module per paper table/figure.

``python -m benchmarks.run`` prints ``name,us_per_call,derived`` CSV rows:
  * per-algorithm NOR-cycle latencies -> microseconds on the memristive
    device model (paper Tables / Fig. 9 substrate),
  * Karatsuba crossover (paper §3.2 fn. 3),
  * variable-normalization overhead (paper §4.4),
  * Fig. 9 throughput / throughput-per-Watt vs the GPU roofline,
  * PIM executor kernel wall-time (element-parallel emulation rate), for
    both the levelized pipeline and the gate-serial baseline.

``--json PATH`` additionally writes the rows as machine-readable JSON
(see BENCH_<n>.json checked in per PR for the perf trajectory);
``--only PREFIX`` restricts to row-name prefixes (e.g. ``--only kernel``
for the smoke invocation wired into the test suite); ``--compare
BENCH_<n>.json`` prints a per-row delta table against a previous run and
exits nonzero when any tracked ``kernel/`` row regresses by more than
``--threshold`` (default 20%) -- the perf-regression gate future PRs run.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

from repro.core.device_model import PIM_DEFAULT
from repro.runtime import telemetry


def _rate(n: int, dt: float):
    """rows/s, guarded: a zero duration (possible only with a broken or
    too-coarse clock) reports None instead of a nonsense inf rate."""
    return round(n / dt) if dt > 0 else None


def _best_of(fn, reps: int = 8) -> float:
    """min-of-reps wall time via the monotonic high-resolution clock
    (time.time() is coarse enough on some hosts to return 0 deltas)."""
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _lat_fields(samples_s) -> dict:
    """p50/p99 of the per-call wall samples, in microseconds.  Percentiles
    ride next to the min-of-reps headline so the checked-in BENCH_<n>.json
    records each row's jitter, not just its floor."""
    s = np.asarray(samples_s, dtype=float) * 1e6
    return {"lat_p50_us": round(float(np.percentile(s, 50)), 1),
            "lat_p99_us": round(float(np.percentile(s, 99)), 1)}


def _model_fields(counters: dict, calls: int) -> dict:
    """Analytical device cost per call from the drained telemetry model
    counters (DESIGN.md §15): NOR cycles on the memristive device model
    and the command-energy estimate.  Empty when the measured path never
    dispatched through the instrumented executors (e.g. pure numpy)."""
    calls = max(calls, 1)
    cycles = counters.get("pim.model.cycles", 0) / calls
    if not cycles:
        return {}
    epj = counters.get("pim.model.energy_pj", 0.0) / calls
    return {"model_cycles": int(round(cycles)),
            "model_us": round(cycles * PIM_DEFAULT.cycle_ns * 1e-3, 3),
            "model_energy_nj": round(epj * 1e-3, 4)}


def _measured(fn, reps: int = 8):
    """One benchmark measurement: min-of-reps wall time plus the derived
    fields every tracked row now carries -- wall p50/p99 and the modeled
    device cycles/energy drained from the telemetry registry over the
    same ``reps`` calls."""
    telemetry.drain_model_counters()            # window starts clean
    samples = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        samples.append(time.perf_counter() - t0)
    counters = telemetry.drain_model_counters()
    return min(samples), {**_lat_fields(samples),
                          **_model_fields(counters, reps)}


def _model_of_one(fn) -> dict:
    """Modeled cost of a single call (for rows whose timing loop mixes
    two configurations and cannot attribute the drained counters)."""
    telemetry.drain_model_counters()
    fn()
    return _model_fields(telemetry.drain_model_counters(), 1)


def _sharded_row_subprocess(row_name):
    """Measure one sharded 1M-row kernel row in a child process with a
    forced 4-device CPU backend.  Isolation is the honest methodology: the
    XLA device-split flag divides the host's thread pool for *every* array
    op in the process, so measuring the unsharded rows under it would tax
    them with the sharded row's configuration (and the flag only takes
    effect before jax initializes anyway).  ``row_name`` is matched
    exactly (several sharded rows share a name prefix)."""
    import subprocess
    import tempfile

    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["_ARITPIM_SHARDED_BENCH_CHILD"] = "1"
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=4").strip()
    env["PYTHONPATH"] = os.path.join(repo, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    with tempfile.NamedTemporaryFile(suffix=".json") as tmp:
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.run",
             "--only", row_name, "--json", tmp.name],
            cwd=repo, env=env, capture_output=True, text=True, timeout=1200)
        if proc.returncode != 0:
            raise RuntimeError(
                f"sharded benchmark subprocess failed: {proc.stderr[-800:]}")
        with open(tmp.name) as f:
            doc = json.load(f)
    (row,) = [r for r in doc["rows"] if r["name"] == row_name]
    us = row.pop("us_per_call")
    name = row.pop("name")
    return name, us, row


def _warm_start_probe(cache_dir: str) -> None:
    """Child-process body for the warm-start rows (``--warm-start-probe``):
    point the ufunc frontend at ``cache_dir``, warm from disk, then run the
    mixed 8-op serving suite (uint16 + fp16 add/sub/mul/div, 1024 rows
    each) once -- the time-to-first-result a fresh server pays.  On an
    empty directory this is the cold path (levelize + trace + XLA compile
    for all 8 programs, artifacts written); on a populated one it is the
    warm path (schedules + AOT executables deserialized, zero recompiles).
    Prints one JSON object on stdout; a blake2b digest of all outputs lets
    the parent assert cold and warm runs are bit-identical."""
    import hashlib

    from repro import pim_ufunc as pim
    from repro.kernels import ops as kops
    from repro.runtime import telemetry

    t0 = time.perf_counter()
    pim.configure(cache_dir=cache_dir)
    pim._ensure_artifact_cache()
    counts = kops.artifact_cache().warm()
    warm_us = (time.perf_counter() - t0) * 1e6

    rng = np.random.default_rng(0)
    n = 1024
    x = rng.integers(0, 1 << 16, n).astype(np.uint16)
    y = rng.integers(0, 1 << 16, n).astype(np.uint16)
    d = rng.integers(1, 1 << 16, n).astype(np.uint16)

    def fp16(k):
        return (rng.integers(10, 21, k).astype(np.uint16) << 10 |
                rng.integers(0, 1 << 10, k).astype(np.uint16)
                ).view(np.float16)

    fa, fb, fd = fp16(n), fp16(n), fp16(n)
    suite = [("add", x, y), ("sub", x, y), ("mul", x, y), ("div", x, d),
             ("fp_add", fa, fb), ("fp_sub", fa, fb), ("fp_mul", fa, fb),
             ("fp_div", fa, fd)]
    h = hashlib.blake2b(digest_size=8)
    t1 = time.perf_counter()
    for op, a, b in suite:
        h.update(np.asarray(getattr(pim, op)(a, b)).tobytes())
    first_us = (time.perf_counter() - t1) * 1e6
    reg = telemetry.REGISTRY
    json.dump({
        "total_us": round(warm_us + first_us, 1),
        "warm_us": round(warm_us, 1),
        "first_runs_us": round(first_us, 1),
        "digest": h.hexdigest(),
        "schedules": counts["schedules"],
        "executables": counts["executables"],
        "levelized": int(reg.counter("pim.cache.levelized")),
        "disk_hits": int(reg.counter("pim.cache.disk_hits")),
        "disk_writes": int(reg.counter("pim.cache.disk_writes")),
    }, sys.stdout)
    print()


def _warm_start_rows(only: str = ""):
    """Cold vs warm process start for the mixed 8-op serving suite
    (DESIGN.md §16).  Two identical child processes share one fresh cache
    directory: the first (cold) levelizes and compiles all 8 programs and
    persists the artifacts; the second (warm) restores them via
    ``ArtifactCache.warm()``.  Each child reports time-to-first-result for
    the whole suite; the warm row carries ``cold_start_us`` and the
    tracked ``speedup_vs_cold`` (acceptance: >= 10x)."""
    import subprocess
    import tempfile

    rows = []
    names = ("kernel/warm_start_mixed8_cold", "kernel/warm_start_mixed8_warm")
    if only and not any(nm.startswith(only) for nm in names):
        return rows
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(repo, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")

    with tempfile.TemporaryDirectory() as cache_dir:
        def probe():
            proc = subprocess.run(
                [sys.executable, "-m", "benchmarks.run",
                 "--warm-start-probe", cache_dir],
                cwd=repo, env=env, capture_output=True, text=True,
                timeout=1200)
            if proc.returncode != 0:
                raise RuntimeError("warm-start probe failed: "
                                   f"{proc.stderr[-800:]}")
            return json.loads(proc.stdout.strip().splitlines()[-1])

        cold = probe()
        warm = probe()
    if warm["digest"] != cold["digest"]:
        raise RuntimeError(
            "warm-start outputs diverged from cold run: "
            f"{warm['digest']} != {cold['digest']}")
    common = {"requests": 8, "programs": 8, "rows_per_request": 1024}
    rows.append((names[0], cold["total_us"], dict(
        common, first_runs_us=cold["first_runs_us"],
        levelized=cold["levelized"], disk_writes=cold["disk_writes"])))
    rows.append((names[1], warm["total_us"], dict(
        common, first_runs_us=warm["first_runs_us"],
        warm_us=warm["warm_us"], schedules=warm["schedules"],
        executables=warm["executables"], levelized=warm["levelized"],
        disk_hits=warm["disk_hits"],
        cold_start_us=cold["total_us"],
        speedup_vs_cold=round(cold["total_us"] / warm["total_us"], 1))))
    if only:
        rows = [r for r in rows if r[0].startswith(only)]
    return rows


def _kernel_rows(only: str = ""):
    """Wall-time of the end-to-end executor pipeline on fp16 element-
    parallel addition: 8192 rows levelized vs gate-serial, plus the scale
    path -- 1 Mi rows through the chunked streaming executor, unsharded and
    row-sharded over every available device (DESIGN.md §8)."""
    import jax

    from repro.core import bitserial_fp
    from repro.core.floatfmt import FP16
    from repro.kernels import ops as kops

    prog = bitserial_fp.build_fp_add(FP16)
    rng = np.random.default_rng(0)
    n = 8192
    x = FP16.random_bits(rng, n, emin=10, emax=20).astype(np.uint64)
    y = FP16.random_bits(rng, n, emin=10, emax=20).astype(np.uint64)

    def bench(**kw):
        # the warm-up call is timed as compile_us: first-call latency for
        # this config in this process (levelize + trace + XLA compile when
        # cold; near the steady-state call when the artifact cache or a
        # sibling row already compiled it) -- the cold-start figure the
        # persistent artifact cache attacks (DESIGN.md §16)
        t0 = time.perf_counter()
        kops.run_program(prog, {"x": x, "y": y}, n, **kw)   # warm up
        compile_us = round((time.perf_counter() - t0) * 1e6, 1)
        # min-of-20: this host-shared CPU jitters 30-40% between runs, and
        # the 8k row is the PR-over-PR perf trajectory anchor
        dt, extra = _measured(
            lambda: kops.run_program(prog, {"x": x, "y": y}, n, **kw),
            reps=20)
        return dt, {**extra, "compile_us": compile_us}

    rows = []

    def want_row(name):
        """Row-granular gating (name extends the --only prefix), so
        single-row invocations don't pay for their siblings."""
        return not only or name.startswith(only)

    _base = []

    def base_dt():
        """The tracked ref-slots wall time; benched lazily exactly once
        (several rows report their ratio against it)."""
        if not _base:
            _base.append(bench(backend="ref"))
        return _base[0][0]

    if want_row("kernel/fp16_add_8k_rows"):
        # tracked row: the default executor path (contiguous-slot schedule,
        # scan executors, butterfly bridges -- DESIGN.md §9)
        dt = base_dt()
        sched = kops.program_schedule(prog)
        rows.append(("kernel/fp16_add_8k_rows", dt * 1e6, {
            "rows_per_s": _rate(n, dt), "backend": "ref", "levelized": 1,
            "schedule": "slots", "levels": int(sched.n_levels),
            "level_width": int(sched.width), "cells": int(sched.n_cells),
            "copy_gates": int(sched.copy_gates), **_base[0][1]}))
    if want_row("kernel/fp16_add_8k_rows_dense"):
        dtd, exd = bench(backend="ref", schedule="dense")
        rows.append(("kernel/fp16_add_8k_rows_dense", dtd * 1e6, {
            "rows_per_s": _rate(n, dtd), "backend": "ref", "levelized": 1,
            "schedule": "dense",
            "speedup_slots": round(dtd / base_dt(), 2), **exd}))
    if want_row("kernel/fp16_add_8k_rows_serial"):
        dts, exs = bench(backend="ref", levelized=False)
        rows.append(("kernel/fp16_add_8k_rows_serial", dts * 1e6, {
            "rows_per_s": _rate(n, dts), "backend": "ref", "levelized": 0,
            "speedup_levelized": round(dts / base_dt(), 2), **exs}))
    if want_row("kernel/fp16_add_8k_rows_pallas"):
        dtp, exp_ = bench(backend="pallas", schedule="dense")
        rows.append(("kernel/fp16_add_8k_rows_pallas", dtp * 1e6, {
            "rows_per_s": _rate(n, dtp), "backend": "pallas",
            "levelized": 1, "schedule": "dense", **exp_}))
    if want_row("kernel/fp16_add_8k_rows_pallas_fused"):
        # the slot-schedule pallas kernel: scatter-free scan body, one
        # fused pallas_call -- the row that must be <= the tracked ref row
        dtf, exf = bench(backend="pallas", schedule="slots")
        rows.append(("kernel/fp16_add_8k_rows_pallas_fused", dtf * 1e6, {
            "rows_per_s": _rate(n, dtf), "backend": "pallas",
            "levelized": 1, "schedule": "slots",
            "vs_ref": round(dtf / base_dt(), 3), **exf}))
    if want_row("kernel/fp16_add_8k_rows_rows64"):
        # the paired-uint32 word layout (ExecPlan layout="rows64",
        # DESIGN.md §11): 64 rows per word-pair, halved trailing word axis
        dt64, ex64 = bench(plan=kops.make_plan(backend="ref",
                                               layout="rows64"))
        rows.append(("kernel/fp16_add_8k_rows_rows64", dt64 * 1e6, {
            "rows_per_s": _rate(n, dt64), "backend": "ref", "levelized": 1,
            "schedule": "slots", "layout": "rows64",
            "vs_rows32": round(dt64 / base_dt(), 3), **ex64}))
    if want_row("kernel/fp16_add_8k_rows_verified"):
        # verified execution with checking on but no faults injected: the
        # retry/spot-check scaffolding of the verified dispatcher.  The
        # XOR check plane is emitted on the device (pim_exec.check_words)
        # and only when a FaultModel is present alongside the policy, so
        # verify-only plans never pay a fold at all (DESIGN.md §14).
        # Acceptance: <10% overhead over the ref row; a plan with
        # FaultModel/verify unset pays exactly 0% (it never enters the
        # verified dispatcher -- tests/test_faults.py pins that).
        # overhead_vs_base is the median of per-pair ratios from
        # call-by-call interleaving (order alternated to cancel order
        # bias): this host's 30-40% drift between separate measurement
        # windows would otherwise swamp the few-percent real cost.
        pln_v = kops.make_plan(backend="ref", verify=True)
        pln_b = kops.make_plan(backend="ref")

        def _one(p):
            t0 = time.perf_counter()
            kops.run_program(prog, {"x": x, "y": y}, n, plan=p)
            return time.perf_counter() - t0

        _one(pln_v), _one(pln_b)                      # warm up
        vts, ratios = [], []
        for i in range(40):
            if i % 2:
                v = _one(pln_v)
                b = _one(pln_b)
            else:
                b = _one(pln_b)
                v = _one(pln_v)
            vts.append(v)
            ratios.append(v / b)
        dtv = min(vts)
        rows.append(("kernel/fp16_add_8k_rows_verified", dtv * 1e6, {
            "rows_per_s": _rate(n, dtv), "backend": "ref", "levelized": 1,
            "schedule": "slots", "verified": 1,
            "overhead_vs_base": round(float(np.median(ratios)) - 1.0, 3),
            **_lat_fields(vts), **_model_of_one(lambda: _one(pln_v))}))

    # straight-line static-slice emission (the Mosaic-lowerable shape):
    # segmented jaxpr chain on ref, fully unrolled kernel on pallas.  On
    # CPU the unrolled forms pay per-op dispatch/interpret overhead; these
    # rows track that gap honestly (hardware is the target).
    if want_row("kernel/fp16_add_8k_rows_static"):
        dss, exss = bench(backend="ref", schedule="slots-static")
        rows.append(("kernel/fp16_add_8k_rows_static", dss * 1e6, {
            "rows_per_s": _rate(n, dss), "backend": "ref", "levelized": 1,
            "schedule": "slots-static", **exss}))
    if want_row("kernel/fp16_add_8k_rows_pallas_static"):
        dsp, exsp = bench(backend="pallas", schedule="slots-static")
        rows.append(("kernel/fp16_add_8k_rows_pallas_static", dsp * 1e6, {
            "rows_per_s": _rate(n, dsp), "backend": "pallas",
            "levelized": 1, "schedule": "slots-static", **exsp}))

    # ---- compound-program fusion: packed-domain reduction trees
    # (DESIGN.md §13).  speedup_vs_unfused is the tracked claim: the fused
    # tree (one pack, log2(K) packed-domain add levels, one scalar unpack)
    # vs the identical pairing through per-op value-domain round trips.
    # Measured as the median of per-pair ratios from call-by-call
    # interleaving (order alternated) -- same methodology as the verified
    # row: this host's 30-40% drift between separate measurement windows
    # would otherwise swamp the real fused-vs-unfused gap.
    def _fused_vs_unfused(run_fused, run_unfused, pairs=8):
        run_fused(), run_unfused()                            # warm up
        fts, ratios = [], []
        for i in range(pairs):
            if i % 2:
                f = _best_of(run_fused, reps=1)
                u = _best_of(run_unfused, reps=1)
            else:
                u = _best_of(run_unfused, reps=1)
                f = _best_of(run_fused, reps=1)
            fts.append(f)
            ratios.append(u / f)
        return min(fts), float(np.median(ratios)), fts

    if want_row("kernel/fp16_dot_8k"):
        from repro import pim_ufunc as pim
        xd = x.copy()
        yd = y.copy()
        run_dot = lambda: pim.dot(xd, yd, fmt="fp16", backend="ref")
        dtd, ratio, dts_s = _fused_vs_unfused(
            run_dot,
            lambda: pim.dot(xd, yd, fmt="fp16", backend="ref",
                            fused=False))
        rows.append(("kernel/fp16_dot_8k", dtd * 1e6, {
            "rows_per_s": _rate(n, dtd), "backend": "ref", "levelized": 1,
            "schedule": "slots", "fused": 1, "reduce_rows": n,
            "speedup_vs_unfused": round(ratio, 2),
            **_lat_fields(dts_s), **_model_of_one(run_dot)}))
    if want_row("kernel/i16_gemv_64x1k"):
        from repro import pim_ufunc as pim
        gm, gk = 64, 1024
        ga = rng.integers(0, 1 << 16, (gm, gk)).astype(np.uint64)
        gx = rng.integers(0, 1 << 16, gk).astype(np.uint64)
        run_gemv = lambda: pim.gemv(ga, gx, width=16, backend="ref")
        dtg, gratio, gts_s = _fused_vs_unfused(
            run_gemv,
            lambda: pim.gemv(ga, gx, width=16, backend="ref",
                             fused=False), pairs=5)
        rows.append(("kernel/i16_gemv_64x1k", dtg * 1e6, {
            "rows_per_s": _rate(gm * gk, dtg), "backend": "ref",
            "levelized": 1, "schedule": "slots", "fused": 1,
            "m": gm, "k": gk,
            "speedup_vs_unfused": round(gratio, 2),
            **_lat_fields(gts_s), **_model_of_one(run_gemv)}))
    if want_row("kernel/i16_gemv_64x1k_verified"):
        # the packed reduction tree under verified execution (DESIGN.md
        # §14): per-level on-device check words + the host compare, no
        # faults injected.  Same interleaved median-of-pair-ratios
        # methodology as the fp16 verified row (host drift would swamp
        # the real cost in separate windows).
        from repro import pim_ufunc as pim
        gm, gk = 64, 1024
        ga = rng.integers(0, 1 << 16, (gm, gk)).astype(np.uint64)
        gx = rng.integers(0, 1 << 16, gk).astype(np.uint64)

        def _one_gemv(verified):
            t0 = time.perf_counter()
            pim.gemv(ga, gx, width=16, backend="ref",
                     verify=True if verified else None)
            return time.perf_counter() - t0

        _one_gemv(True), _one_gemv(False)             # warm up
        vts, ratios = [], []
        for i in range(8):
            if i % 2:
                v = _one_gemv(True)
                b = _one_gemv(False)
            else:
                b = _one_gemv(False)
                v = _one_gemv(True)
            vts.append(v)
            ratios.append(v / b)
        dtgv = min(vts)
        rows.append(("kernel/i16_gemv_64x1k_verified", dtgv * 1e6, {
            "rows_per_s": _rate(gm * gk, dtgv), "backend": "ref",
            "levelized": 1, "schedule": "slots", "fused": 1,
            "verified": 1, "m": gm, "k": gk,
            "overhead_vs_base": round(float(np.median(ratios)) - 1.0, 3),
            **_lat_fields(vts),
            **_model_of_one(lambda: _one_gemv(True))}))

    # ---- scale path: 1 Mi rows, chunked streaming +/- row sharding
    nm = 1 << 20
    chunk = kops.DEFAULT_CHUNK_ROWS

    def bench_stream(mesh, layout="rows32"):
        xm = FP16.random_bits(rng, nm, emin=10, emax=20).astype(np.uint64)
        ym = FP16.random_bits(rng, nm, emin=10, emax=20).astype(np.uint64)
        stream_plan = kops.make_plan(backend="ref", chunk_rows=chunk,
                                     mesh=mesh, layout=layout)
        run = lambda: kops.run_program_streaming(
            prog, {"x": xm, "y": ym}, nm, stream_plan)
        run()                               # warm up (compiles chunk shape)
        return _measured(run, reps=3)

    if want_row("kernel/fp16_add_1M_rows_stream"):
        dt1, ex1 = bench_stream(mesh=None)
        rows.append(("kernel/fp16_add_1M_rows_stream", dt1 * 1e6, {
            "rows_per_s": _rate(nm, dt1), "backend": "ref", "levelized": 1,
            "chunk_rows": chunk, "n_devices": 1, **ex1}))

    def sharded_row(name, layout):
        is_child = os.environ.get("_ARITPIM_SHARDED_BENCH_CHILD") == "1"
        if len(jax.devices()) > 1:          # already multi-device: in-process
            mesh = kops.row_mesh()
            dt4, ex4 = bench_stream(mesh=mesh, layout=layout)
            return (name, dt4 * 1e6, {
                "rows_per_s": _rate(nm, dt4), "backend": "ref",
                "levelized": 1, "chunk_rows": chunk, "layout": layout,
                "n_devices": int(mesh.devices.size), **ex4})
        if is_child:
            # the device-split flag did not take (e.g. a non-CPU backend
            # ignores it): record the degenerate single-device measurement
            # rather than recursing into another identical child
            dt4, ex4 = bench_stream(mesh=None, layout=layout)
            return (name, dt4 * 1e6, {
                "rows_per_s": _rate(nm, dt4), "backend": "ref",
                "levelized": 1, "chunk_rows": chunk, "layout": layout,
                "n_devices": 1, **ex4})
        return _sharded_row_subprocess(name)

    if want_row("kernel/fp16_add_1M_rows_sharded"):
        rows.append(sharded_row("kernel/fp16_add_1M_rows_sharded",
                                "rows32"))
    if want_row("kernel/fp16_add_1M_rows64_sharded"):
        # the sharded scale path under the paired word layout: half the
        # words per shard for the same 1M rows
        rows.append(sharded_row("kernel/fp16_add_1M_rows64_sharded",
                                "rows64"))
    return rows


def _serve_rows(only: str = ""):
    """Mixed-traffic serving throughput (ISSUE 4 acceptance row): the same
    interleaved request stream -- 8 distinct programs (uint16 add/sub/mul/
    div + fp16 add/sub/mul/div), round-robin -- executed two ways: the
    per-request serial loop (``--pim-stdin``'s execution model, one gate
    program per request) vs the batched planner/coalescer
    (``runtime/pim_batch``, ``--pim-serve``'s model: group by program
    content hash, execute each group as one packed state, pipelined).
    Both paths pay identical parse/validation work per request; the only
    difference is row-axis coalescing."""
    from repro import pim_ufunc as pim
    from repro.runtime import pim_batch

    rng = np.random.default_rng(0)
    n_req_per_op = 8
    rows_per_req = 1024

    def fp16(n):
        # mid-range exponents: the paper excludes overflow/underflow
        return (rng.integers(10, 21, n).astype(np.uint16) << 10 |
                rng.integers(0, 1 << 10, n).astype(np.uint16)
                ).view(np.float16)

    traffic = []
    for _ in range(n_req_per_op):
        n = rows_per_req
        x = rng.integers(0, 1 << 16, n).astype(np.uint16)
        y = rng.integers(0, 1 << 16, n).astype(np.uint16)
        d = rng.integers(1, 1 << 16, n).astype(np.uint16)
        fa, fb, fd = fp16(n), fp16(n), fp16(n)   # fd nonzero (exp >= 10)
        traffic += [("add", x, y), ("sub", x, y), ("mul", x, y),
                    ("div", x, d), ("fp_add", fa, fb), ("fp_sub", fa, fb),
                    ("fp_mul", fa, fb), ("fp_div", fa, fd)]
    total = len(traffic) * rows_per_req

    def serial():
        for op, x, y in traffic:
            getattr(pim, op)(x, y)

    runtime = pim_batch.BatchRuntime(pin_cap=16)

    def batched():
        runtime.execute([pim.prepare(op, x, y) for op, x, y in traffic])

    serial()                    # warm: compile all 8 programs, both shapes
    batched()
    dts = _best_of(serial, reps=3)
    dtb = _best_of(batched, reps=3)
    runtime.close()

    # the same mixed traffic -- grown with compound requests (a fused
    # depth-3 expression through the runtime plus packed-tree dot/gemv
    # calls, DESIGN.md §13/§14) -- under a nonzero injected fault rate
    # with verified execution: the cost of serving *correct* answers off
    # faulty media across every execution path the verifier covers.
    # overhead_vs_clean compares against the identical grown stream with
    # verification off, so the ratio isolates the fault-tolerance cost.
    from repro.kernels import ops as kops
    from repro.runtime.faults import FaultModel
    ex_x = rng.integers(0, 1 << 8, rows_per_req).astype(np.uint8)
    ex_y = rng.integers(1, 1 << 8, rows_per_req).astype(np.uint8)
    ex_z = rng.integers(0, 1 << 8, rows_per_req).astype(np.uint8)
    dot_x = rng.integers(0, 256, 256).astype(np.uint8)
    dot_y = rng.integers(0, 256, 256).astype(np.uint8)
    gemv_a = rng.integers(0, 1 << 16, (4, 128)).astype(np.uint64)
    gemv_x = rng.integers(0, 1 << 16, 128).astype(np.uint64)

    def _expr_prep():
        lx, ly, lz = pim.lazy(ex_x), pim.lazy(ex_y), pim.lazy(ex_z)
        return pim.sub(pim.add(pim.mul(lx, ly), lz), lx).fuse()

    def _grown(rt):
        rs = rt.execute([pim.prepare(op, x, y) for op, x, y in traffic]
                        + [_expr_prep()])
        bad = [r for r in rs if r.error is not None]
        if bad:
            raise RuntimeError(f"serving failed: {bad[0].error}")
        pim.dot(dot_x, dot_y)
        pim.gemv(gemv_a, gemv_x, width=16)

    crt = pim_batch.BatchRuntime(pin_cap=16)
    _grown(crt)                 # warm the compound programs
    dtc = _best_of(lambda: _grown(crt), reps=3)
    crt.close()
    frt = pim_batch.BatchRuntime(pin_cap=16)
    with pim.options(faults=FaultModel(seed=7, p_flip=5e-4), verify=True):
        _grown(frt)             # warm (+ proves every request recovers)
        dtf = _best_of(lambda: _grown(frt), reps=3)
    st = frt.stats
    frt.close()
    kops.drain_health()
    total_grown = total + rows_per_req + dot_x.size + gemv_a.size
    common = {"requests": len(traffic), "programs": 8,
              "rows_per_request": rows_per_req}
    return [
        ("serve/mixed_8op_serial", dts * 1e6,
         dict(common, rows_per_s=_rate(total, dts))),
        ("serve/mixed_8op_batched", dtb * 1e6,
         dict(common, rows_per_s=_rate(total, dtb),
              speedup_vs_serial=round(dts / dtb, 2))),
        ("serve/mixed_8op_faulty", dtf * 1e6,
         dict(common, rows_per_s=_rate(total_grown, dtf),
              p_flip=5e-4, verified=1, compound_requests=3,
              faults_detected=st.faults_detected,
              faults_corrected=st.faults_corrected,
              retries=st.retries,
              overhead_vs_clean=round(dtf / dtc - 1.0, 3))),
    ]


def collect_rows(only: str = "") -> list:
    """All benchmark rows as (name, us_per_call, derived-dict) tuples."""
    rows = []

    def want(prefix):
        return not only or prefix.startswith(only) or only.startswith(prefix)

    if want("cycles"):
        from . import cycles
        for r in cycles.rows():
            us = r["nor_cycles"] * PIM_DEFAULT.cycle_ns * 1e-3
            rows.append((f"cycles/{r['op'].replace(' ', '_')}", us, {
                "steps": r["steps"], "nor": r["nor_cycles"],
                "nor9": r["nor_cycles_norm9"], "cells": r["cells"]}))
        from repro.core import bitserial_fp as bsf64
        from repro.core.floatfmt import FP64
        c64 = bsf64.build_fp_add(FP64).cost()
        rows.append(("cycles/serial_fp64_add",
                     c64.nor_gates * PIM_DEFAULT.cycle_ns * 1e-3,
                     {"steps": c64.abstract_steps, "nor": c64.nor_gates}))

    if want("karatsuba"):
        from . import karatsuba
        for r in karatsuba.rows():
            us = r["karatsuba_nor"] * PIM_DEFAULT.cycle_ns * 1e-3
            rows.append((f"karatsuba/N{r['N']}", us,
                         {"speedup_vs_shift_add": r["speedup"]}))
        rows.append(("karatsuba/crossover", 0.0, {"N": karatsuba.crossover()}))

    if want("varnorm"):
        from . import varshift
        for r in varshift.rows():
            us = r["var_norm_nor"] * PIM_DEFAULT.cycle_ns * 1e-3
            rows.append((f"varnorm/Nx{r['Nx']}", us, {
                "overhead_pct": r["overhead_pct"],
                "naive_overhead_pct": r["naive_overhead_pct"]}))

    if want("fig9"):
        from . import fig9
        for r in fig9.rows():
            rows.append((f"fig9/{r['op'].replace(' ', '_')}", 0.0, {
                "pim_gops": r["pim_gops"], "gpu_gops": r["gpu_gops"],
                "speedup": r["speedup"], "energy_ratio": r["energy_ratio"]}))

    if want("offload"):
        from repro.configs import registry
        from repro.core.offload import decode_step_plan
        for arch in ("rwkv6-1.6b", "qwen3-8b"):
            plans = decode_step_plan(registry.get(arch), batch=128, seq=32768)
            n_off = sum(p.offload for p in plans)
            tot_tpu = sum(p.tpu_us for p in plans)
            tot_pim = sum(p.pim_us if p.offload else p.tpu_us for p in plans)
            rows.append((f"offload/{arch}", tot_pim, {
                "classes_offloaded": f"{n_off}/{len(plans)}",
                "elementwise_us_tpu": round(tot_tpu, 1)}))

    if want("kernel"):
        rows.extend(_kernel_rows(only))
        rows.extend(_warm_start_rows(only))
    if want("serve"):
        rows.extend(_serve_rows(only))
    if only:
        rows = [r for r in rows if r[0].startswith(only)]
    return rows


def compare_rows(rows, baseline_path: str, threshold: float = 0.20,
                 complete: bool = True):
    """Per-row delta table against a previous BENCH_<n>.json.

    Rows are matched by name; only rows present in both runs with nonzero
    wall times are ratioed.  *Tracked* rows (``kernel/`` wall-time rows --
    the executor perf trajectory) whose time regresses by more than
    ``threshold`` are returned as failures; derived-model rows (cycles/
    karatsuba/fig9/...) are shown for drift but never gate.  When
    ``complete`` (a full run, no ``--only`` filter), a tracked baseline
    row that the current run no longer produces is itself a failure --
    dropping or renaming a tracked row must not pass the gate vacuously.
    """
    with open(baseline_path) as f:
        base = {r["name"]: r for r in json.load(f)["rows"]}
    failures = []
    print(f"\ncomparison vs {baseline_path} "
          f"(gate: kernel/* rows, +{threshold:.0%}):")
    print(f"{'row':44s} {'base_us':>12s} {'now_us':>12s} {'delta':>8s}")
    current = set()
    for name, us, _ in rows:
        current.add(name)
        old = base.get(name)
        if old is None:
            print(f"{name:44s} {'-':>12s} {us:12.1f} {'new':>8s}")
            continue
        old_us = old.get("us_per_call", 0.0)
        if not old_us or not us:
            continue
        delta = us / old_us - 1.0
        flag = ""
        if name.startswith("kernel/") and delta > threshold:
            flag = "  REGRESSED"
            failures.append((name, old_us, us, delta))
        print(f"{name:44s} {old_us:12.1f} {us:12.1f} {delta:+8.1%}{flag}")
    if complete:
        for name in sorted(base):
            if name.startswith("kernel/") and name not in current:
                print(f"{name:44s} {'?':>12s} {'-':>12s} "
                      f"{'MISSING':>8s}  REGRESSED")
                failures.append((name, base[name].get("us_per_call", 0.0),
                                 float("nan"), float("inf")))
    return failures


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", metavar="PATH",
                    help="also write rows as machine-readable JSON")
    ap.add_argument("--only", default="",
                    help="restrict to row-name prefix (e.g. 'kernel')")
    ap.add_argument("--compare", metavar="BASELINE",
                    help="compare against a previous BENCH_<n>.json and "
                         "exit nonzero when a tracked kernel/ row regresses "
                         "past --threshold (the perf-regression gate)")
    ap.add_argument("--threshold", type=float, default=0.20,
                    help="allowed fractional slowdown for tracked rows "
                         "under --compare (default 0.20)")
    ap.add_argument("--warm-start-probe", metavar="DIR",
                    help=argparse.SUPPRESS)   # child mode for the
    #                                           warm-start rows
    ap.add_argument("--devices", type=int, default=0,
                    help="force an N-device CPU backend in this process "
                         "(0 = leave the backend alone; the sharded kernel "
                         "row then measures itself in a 4-device child)")
    args = ap.parse_args(argv)

    if args.warm_start_probe:
        _warm_start_probe(args.warm_start_probe)
        return

    # XLA can split a CPU host into N devices, but only if the flag is set
    # before jax initializes (a no-op when jax was already imported)
    if args.devices > 1 and "jax" not in sys.modules \
            and "--xla_force_host_platform_device_count" not in \
            os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "") +
            f" --xla_force_host_platform_device_count={args.devices}").strip()

    rows = collect_rows(args.only)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        dstr = ";".join(f"{k}={v}" for k, v in derived.items())
        print(f"{name},{us:.3f},{dstr}")

    if args.json:
        doc = {
            "meta": {
                "suite": "aritpim-repro",
                "tier1": "benchmarks.run",
                "python": sys.version.split()[0],
                "device_cycle_ns": PIM_DEFAULT.cycle_ns,
            },
            "rows": [{"name": n, "us_per_call": round(us, 3), **d}
                     for n, us, d in rows],
        }
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
            f.write("\n")

    if args.compare:
        failures = compare_rows(rows, args.compare, args.threshold,
                                complete=not args.only)
        if failures:
            print(f"\n{len(failures)} tracked row(s) regressed more than "
                  f"{args.threshold:.0%} (or went missing):")
            for name, old_us, us, delta in failures:
                print(f"  {name}: {old_us:.1f}us -> {us:.1f}us "
                      f"({delta:+.1%})")
            sys.exit(1)
        print("\nperf gate: OK")


if __name__ == "__main__":
    main()
