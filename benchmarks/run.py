"""Benchmark harness: one module per paper table/figure.

``python -m benchmarks.run`` prints ``name,us_per_call,derived`` CSV rows:
  * per-algorithm NOR-cycle latencies -> microseconds on the memristive
    device model (paper Tables / Fig. 9 substrate),
  * Karatsuba crossover (paper §3.2 fn. 3),
  * variable-normalization overhead (paper §4.4),
  * Fig. 9 throughput / throughput-per-Watt vs the GPU roofline,
  * PIM executor kernel wall-time (element-parallel emulation rate).
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.device_model import PIM_DEFAULT


def main() -> None:
    print("name,us_per_call,derived")
    from . import cycles, fig9, karatsuba, varshift

    for r in cycles.rows():
        us = r["nor_cycles"] * PIM_DEFAULT.cycle_ns * 1e-3
        print(f"cycles/{r['op'].replace(' ', '_')},{us:.3f},"
              f"steps={r['steps']};nor={r['nor_cycles']};"
              f"nor9={r['nor_cycles_norm9']};cells={r['cells']}")

    for r in karatsuba.rows():
        us = r["karatsuba_nor"] * PIM_DEFAULT.cycle_ns * 1e-3
        print(f"karatsuba/N{r['N']},{us:.3f},"
              f"speedup_vs_shift_add={r['speedup']}")
    print(f"karatsuba/crossover,{0.0:.3f},N={karatsuba.crossover()}")

    for r in varshift.rows():
        us = r["var_norm_nor"] * PIM_DEFAULT.cycle_ns * 1e-3
        print(f"varnorm/Nx{r['Nx']},{us:.3f},"
              f"overhead_pct={r['overhead_pct']};"
              f"naive_overhead_pct={r['naive_overhead_pct']}")

    for r in fig9.rows():
        us = 0.0
        print(f"fig9/{r['op'].replace(' ', '_')},{us:.3f},"
              f"pim_gops={r['pim_gops']};gpu_gops={r['gpu_gops']};"
              f"speedup={r['speedup']};energy_ratio={r['energy_ratio']}")

    # fp64 extension (beyond the paper's 32-bit evaluation)
    from repro.core import bitserial_fp as bsf64
    from repro.core.floatfmt import FP64
    c64 = bsf64.build_fp_add(FP64).cost()
    print(f"cycles/serial_fp64_add,{c64.nor_gates * PIM_DEFAULT.cycle_ns * 1e-3:.3f},"
          f"steps={c64.abstract_steps};nor={c64.nor_gates}")

    # PIM-offload planner (AritPIM as a serving feature)
    from repro.core.offload import decode_step_plan
    from repro.configs import registry
    for arch in ("rwkv6-1.6b", "qwen3-8b"):
        plans = decode_step_plan(registry.get(arch), batch=128, seq=32768)
        n_off = sum(p.offload for p in plans)
        tot_tpu = sum(p.tpu_us for p in plans)
        tot_pim = sum(p.pim_us if p.offload else p.tpu_us for p in plans)
        print(f"offload/{arch},{tot_pim:.1f},"
              f"classes_offloaded={n_off}/{len(plans)};"
              f"elementwise_us_tpu={tot_tpu:.1f}")

    # kernel wall-time: element-parallel fp16 add on the Pallas executor
    from repro.core import bitserial_fp
    from repro.core.floatfmt import FP16
    from repro.kernels import ops as kops
    prog = bitserial_fp.build_fp_add(FP16)
    rng = np.random.default_rng(0)
    n = 8192
    x = FP16.random_bits(rng, n, emin=10, emax=20).astype(np.uint64)
    y = FP16.random_bits(rng, n, emin=10, emax=20).astype(np.uint64)
    kops.run_program(prog, {"x": x, "y": y}, n, backend="ref")  # warm up
    t0 = time.time()
    kops.run_program(prog, {"x": x, "y": y}, n, backend="ref")
    dt = time.time() - t0
    print(f"kernel/fp16_add_8k_rows,{dt * 1e6:.1f},"
          f"rows_per_s={n / dt:.0f}")


if __name__ == "__main__":
    main()
