"""Benchmark harness: one module per paper table/figure.

``python -m benchmarks.run`` prints ``name,us_per_call,derived`` CSV rows:
  * per-algorithm NOR-cycle latencies -> microseconds on the memristive
    device model (paper Tables / Fig. 9 substrate),
  * Karatsuba crossover (paper §3.2 fn. 3),
  * variable-normalization overhead (paper §4.4),
  * Fig. 9 throughput / throughput-per-Watt vs the GPU roofline,
  * PIM executor kernel wall-time (element-parallel emulation rate), for
    both the levelized pipeline and the gate-serial baseline.

``--json PATH`` additionally writes the rows as machine-readable JSON
(see BENCH_<n>.json checked in per PR for the perf trajectory);
``--only PREFIX`` restricts to row-name prefixes (e.g. ``--only kernel``
for the smoke invocation wired into the test suite).
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np

from repro.core.device_model import PIM_DEFAULT


def _kernel_rows():
    """Wall-time of the end-to-end executor pipeline on fp16 element-
    parallel addition, 8192 rows: levelized (default) vs gate-serial."""
    from repro.core import bitserial_fp
    from repro.core.floatfmt import FP16
    from repro.kernels import ops as kops

    prog = bitserial_fp.build_fp_add(FP16)
    rng = np.random.default_rng(0)
    n = 8192
    x = FP16.random_bits(rng, n, emin=10, emax=20).astype(np.uint64)
    y = FP16.random_bits(rng, n, emin=10, emax=20).astype(np.uint64)

    def bench(**kw):
        kops.run_program(prog, {"x": x, "y": y}, n, **kw)   # warm up
        best = float("inf")
        for _ in range(8):                  # min-of-8: robust to CPU noise
            t0 = time.time()
            kops.run_program(prog, {"x": x, "y": y}, n, **kw)
            best = min(best, time.time() - t0)
        return best

    rows = []
    dt = bench(backend="ref")
    sched = kops.program_schedule(prog)
    rows.append(("kernel/fp16_add_8k_rows", dt * 1e6, {
        "rows_per_s": round(n / dt), "backend": "ref", "levelized": 1,
        "levels": int(sched.n_levels), "level_width": int(sched.width),
        "cells": int(sched.n_cells)}))
    dts = bench(backend="ref", levelized=False)
    rows.append(("kernel/fp16_add_8k_rows_serial", dts * 1e6, {
        "rows_per_s": round(n / dts), "backend": "ref", "levelized": 0,
        "speedup_levelized": round(dts / dt, 2)}))
    dtp = bench(backend="pallas")
    rows.append(("kernel/fp16_add_8k_rows_pallas", dtp * 1e6, {
        "rows_per_s": round(n / dtp), "backend": "pallas", "levelized": 1}))
    return rows


def collect_rows(only: str = "") -> list:
    """All benchmark rows as (name, us_per_call, derived-dict) tuples."""
    rows = []

    def want(prefix):
        return not only or prefix.startswith(only) or only.startswith(prefix)

    if want("cycles"):
        from . import cycles
        for r in cycles.rows():
            us = r["nor_cycles"] * PIM_DEFAULT.cycle_ns * 1e-3
            rows.append((f"cycles/{r['op'].replace(' ', '_')}", us, {
                "steps": r["steps"], "nor": r["nor_cycles"],
                "nor9": r["nor_cycles_norm9"], "cells": r["cells"]}))
        from repro.core import bitserial_fp as bsf64
        from repro.core.floatfmt import FP64
        c64 = bsf64.build_fp_add(FP64).cost()
        rows.append(("cycles/serial_fp64_add",
                     c64.nor_gates * PIM_DEFAULT.cycle_ns * 1e-3,
                     {"steps": c64.abstract_steps, "nor": c64.nor_gates}))

    if want("karatsuba"):
        from . import karatsuba
        for r in karatsuba.rows():
            us = r["karatsuba_nor"] * PIM_DEFAULT.cycle_ns * 1e-3
            rows.append((f"karatsuba/N{r['N']}", us,
                         {"speedup_vs_shift_add": r["speedup"]}))
        rows.append(("karatsuba/crossover", 0.0, {"N": karatsuba.crossover()}))

    if want("varnorm"):
        from . import varshift
        for r in varshift.rows():
            us = r["var_norm_nor"] * PIM_DEFAULT.cycle_ns * 1e-3
            rows.append((f"varnorm/Nx{r['Nx']}", us, {
                "overhead_pct": r["overhead_pct"],
                "naive_overhead_pct": r["naive_overhead_pct"]}))

    if want("fig9"):
        from . import fig9
        for r in fig9.rows():
            rows.append((f"fig9/{r['op'].replace(' ', '_')}", 0.0, {
                "pim_gops": r["pim_gops"], "gpu_gops": r["gpu_gops"],
                "speedup": r["speedup"], "energy_ratio": r["energy_ratio"]}))

    if want("offload"):
        from repro.configs import registry
        from repro.core.offload import decode_step_plan
        for arch in ("rwkv6-1.6b", "qwen3-8b"):
            plans = decode_step_plan(registry.get(arch), batch=128, seq=32768)
            n_off = sum(p.offload for p in plans)
            tot_tpu = sum(p.tpu_us for p in plans)
            tot_pim = sum(p.pim_us if p.offload else p.tpu_us for p in plans)
            rows.append((f"offload/{arch}", tot_pim, {
                "classes_offloaded": f"{n_off}/{len(plans)}",
                "elementwise_us_tpu": round(tot_tpu, 1)}))

    if want("kernel"):
        rows.extend(_kernel_rows())
    if only:
        rows = [r for r in rows if r[0].startswith(only)]
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", metavar="PATH",
                    help="also write rows as machine-readable JSON")
    ap.add_argument("--only", default="",
                    help="restrict to row-name prefix (e.g. 'kernel')")
    args = ap.parse_args(argv)

    rows = collect_rows(args.only)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        dstr = ";".join(f"{k}={v}" for k, v in derived.items())
        print(f"{name},{us:.3f},{dstr}")

    if args.json:
        doc = {
            "meta": {
                "suite": "aritpim-repro",
                "tier1": "benchmarks.run",
                "python": sys.version.split()[0],
                "device_cycle_ns": PIM_DEFAULT.cycle_ns,
            },
            "rows": [{"name": n, "us_per_call": round(us, 3), **d}
                     for n, us, d in rows],
        }
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
            f.write("\n")


if __name__ == "__main__":
    main()
